//! Minimal command-line argument parser (clap is not in the offline vendor
//! set).
//!
//! Supports `program <subcommand> [--flag] [--key value] ...` with typed
//! accessors, unknown-option detection, and generated usage text.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Declared option (for usage text and validation).
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

/// Parsed arguments of one invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]` against the declared options.
    pub fn parse(argv: &[String], specs: &[OptSpec]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value form.
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .with_context(|| format!("unknown option --{name}"))?;
                if spec.takes_value {
                    let value = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .with_context(|| format!("--{name} requires a value"))?
                            .clone(),
                    };
                    args.values.insert(name.to_string(), value);
                } else {
                    if inline.is_some() {
                        bail!("--{name} does not take a value");
                    }
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        self.get(name)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{name}: bad number '{v}'")))
            .transpose()
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        self.get(name)
            .map(|v| v.parse::<usize>().with_context(|| format!("--{name}: bad integer '{v}'")))
            .transpose()
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>> {
        self.get(name)
            .map(|v| v.parse::<u64>().with_context(|| format!("--{name}: bad integer '{v}'")))
            .transpose()
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render usage text for a subcommand table + option specs.
pub fn usage(program: &str, subcommands: &[(&str, &str)], specs: &[OptSpec]) -> String {
    let mut out = format!("usage: {program} <command> [options]\n\ncommands:\n");
    for (name, help) in subcommands {
        out.push_str(&format!("  {name:<14} {help}\n"));
    }
    out.push_str("\noptions:\n");
    for s in specs {
        let arg = if s.takes_value { format!("--{} <v>", s.name) } else { format!("--{}", s.name) };
        out.push_str(&format!("  {arg:<22} {}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "scale", takes_value: true, help: "" },
            OptSpec { name: "verbose", takes_value: false, help: "" },
            OptSpec { name: "out", takes_value: true, help: "" },
        ]
    }

    fn parse(tokens: &[&str]) -> Result<Args> {
        let argv: Vec<String> = tokens.iter().map(|s| s.to_string()).collect();
        Args::parse(&argv, &specs())
    }

    #[test]
    fn parses_subcommand_options_and_flags() {
        let a = parse(&["table2", "--scale", "0.5", "--verbose", "extra"]).unwrap();
        assert_eq!(a.subcommand, "table2");
        assert_eq!(a.get_f64("scale").unwrap(), Some(0.5));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["extra".to_string()]);
    }

    #[test]
    fn equals_form() {
        let a = parse(&["x", "--scale=0.25"]).unwrap();
        assert_eq!(a.get_f64("scale").unwrap(), Some(0.25));
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(parse(&["x", "--nope"]).is_err());
        assert!(parse(&["x", "--scale"]).is_err());
        assert!(parse(&["x", "--verbose=1"]).is_err());
    }

    #[test]
    fn bad_number_reported() {
        let a = parse(&["x", "--scale", "abc"]).unwrap();
        assert!(a.get_f64("scale").is_err());
    }

    #[test]
    fn usage_lists_everything() {
        let u = usage("repro", &[("table1", "run table 1")], &specs());
        assert!(u.contains("table1"));
        assert!(u.contains("--scale"));
    }
}

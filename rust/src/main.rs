//! `repro` — the budgetsvm launcher.
//!
//! Regenerates every table and figure of Glasmachers & Qaadan (2018), runs
//! single training jobs on the built-in dataset profiles or user LIBSVM
//! files, precomputes lookup tables, and smoke-checks the PJRT runtime.

use anyhow::{bail, Result};

use budgetsvm::budget::{shared_lookup_table, Strategy};
use budgetsvm::cli::{usage, Args, OptSpec};
use budgetsvm::config::ExperimentConfig;
use budgetsvm::coordinator;
use budgetsvm::experiments;
use budgetsvm::kernel::KernelSpec;
use budgetsvm::runtime::Runtime;
use budgetsvm::solver::SolverSpec;

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("all", "run the full campaign: tables 1-3 + figures 2-3"),
    ("table1", "dataset stats + exact-SVM (SMO) reference accuracy"),
    ("table2", "test accuracy of the 4 merge solvers x budgets x runs"),
    ("table3", "training-time improvement, merging frequency, agreement"),
    ("figure2", "h(m,k) and WD(m,k) surfaces (CSV + ASCII)"),
    ("figure3", "merging-time Section A/B breakdown"),
    ("bench", "perf harnesses: kernel-row/fit (BENCH_kernel.json), --maintenance, or --all"),
    ("serve", "online serving + streaming ingest: --port <p> | --replay <file.libsvm>"),
    ("train", "single training run: repro train <profile|file.libsvm>"),
    ("eval", "evaluate a saved model: repro eval <model.bsvm> <file.libsvm>"),
    ("precompute", "build and save a lookup table artifact"),
    ("runtime-check", "load AOT artifacts and verify PJRT execution"),
    ("help", "show this help"),
];

fn opt_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "config", takes_value: true, help: "JSON config file" },
        OptSpec { name: "scale", takes_value: true, help: "dataset size multiplier (default 0.1)" },
        OptSpec { name: "passes-factor", takes_value: true, help: "multiplier on default passes" },
        OptSpec { name: "runs", takes_value: true, help: "repetitions per cell (default 5)" },
        OptSpec { name: "grid", takes_value: true, help: "lookup grid size (default 400)" },
        OptSpec { name: "seed", takes_value: true, help: "base RNG seed" },
        OptSpec { name: "threads", takes_value: true, help: "worker threads (0 = all cores)" },
        OptSpec { name: "datasets", takes_value: true, help: "comma-separated profile subset" },
        OptSpec { name: "out", takes_value: true, help: "output directory (default results/)" },
        OptSpec { name: "budget", takes_value: true, help: "train: budget B (default 100)" },
        OptSpec {
            name: "strategy",
            takes_value: true,
            help: "train: gss|gss-precise|lookup-h|lookup-wd|removal|projection",
        },
        OptSpec {
            name: "kernel",
            takes_value: true,
            help: "train: gaussian:<gamma>|linear|poly:<degree>[:<coef0>] \
                   (non-gaussian kernels need --strategy removal|projection)",
        },
        OptSpec {
            name: "solver",
            takes_value: true,
            help: "train/serve: binary solver family member, bsgd (primal, default) \
                   or bdca (dual coordinate ascent on a cached Gram slab)",
        },
        OptSpec {
            name: "dual-epochs",
            takes_value: true,
            help: "train/serve: dual-ascent sweeps per pass for --solver bdca (default 2)",
        },
        OptSpec { name: "passes", takes_value: true, help: "train: passes override" },
        OptSpec { name: "c", takes_value: true, help: "train: C override" },
        OptSpec { name: "gamma", takes_value: true, help: "train: gaussian gamma override" },
        OptSpec {
            name: "maint-slack",
            takes_value: true,
            help: "train/serve: allowed budget overshoot W before an amortized \
                   multi-pair maintenance sweep runs (default 0 = classic per-overflow)",
        },
        OptSpec {
            name: "maint-pairs",
            takes_value: true,
            help: "train/serve: pairs shed per maintenance event (default 0 = auto, ceil(W)+1)",
        },
        OptSpec {
            name: "fast-exp",
            takes_value: false,
            help: "train/serve/eval: vectorized exp tier for Gaussian tiles (pinned \
                   <= 1e-14 relative error; default = libm exp, bit-identical engine)",
        },
        OptSpec {
            name: "simd",
            takes_value: true,
            help: "SIMD tier override: scalar|avx2|avx512|neon (same as the \
                   BUDGETSVM_SIMD env var; a tier this machine cannot run falls \
                   back to the best available with a warning)",
        },
        OptSpec { name: "json", takes_value: false, help: "train: machine-readable output" },
        OptSpec { name: "quick", takes_value: false, help: "bench: smoke mode (short samples)" },
        OptSpec {
            name: "maintenance",
            takes_value: false,
            help: "bench: budget-maintenance amortization harness (BENCH_maintenance.json)",
        },
        OptSpec {
            name: "solver-bench",
            takes_value: false,
            help: "bench: solver-family harness, BSGD vs BDCA at equal budget \
                   (BENCH_solver.json, accuracy-parity gated in CI)",
        },
        OptSpec {
            name: "all",
            takes_value: false,
            help: "bench: run kernel + maintenance + solver harnesses and write a \
                   merged top-level BENCH_summary.json (per-bench files unchanged)",
        },
        OptSpec { name: "model-out", takes_value: true, help: "train: save the model here" },
        OptSpec { name: "table-out", takes_value: true, help: "precompute: output path" },
        OptSpec { name: "artifacts", takes_value: true, help: "runtime-check: artifacts dir" },
        OptSpec {
            name: "port",
            takes_value: true,
            help: "serve: TCP port on 127.0.0.1 (default 7878)",
        },
        OptSpec {
            name: "shards",
            takes_value: true,
            help: "serve: ingest shard workers (default 4)",
        },
        OptSpec {
            name: "publish-every",
            takes_value: true,
            help: "serve: rows between snapshot/publish events (default 1024)",
        },
        OptSpec {
            name: "publish-adapt",
            takes_value: false,
            help: "serve: stall-aware adaptive publish cadence (scale publish-every \
                   up to 16x under expensive merges, back down when idle)",
        },
        OptSpec {
            name: "replay",
            takes_value: true,
            help: "serve: offline replay benchmark over a LIBSVM file (no network)",
        },
        OptSpec {
            name: "model",
            takes_value: true,
            help: "serve: initial model to publish (.bsvm)",
        },
        OptSpec {
            name: "resilience",
            takes_value: false,
            help: "bench: deterministic fault-injection harness — worker panic, torn-write \
                   crash + recovery, stalled client (BENCH_resilience.json, zero-loss \
                   gated in CI)",
        },
        OptSpec {
            name: "observability",
            takes_value: false,
            help: "bench: telemetry overhead gate — instrumented vs disabled BSGD hot \
                   loop plus scrape completeness (BENCH_observability.json, <= 2% \
                   overhead gated in CI)",
        },
        OptSpec {
            name: "metrics-port",
            takes_value: true,
            help: "serve: loopback port for the Prometheus-text metrics endpoint \
                   (default 0 = disabled)",
        },
        OptSpec {
            name: "telemetry-log",
            takes_value: true,
            help: "serve: append lifecycle events (maintenance, admission transitions, \
                   restarts, publishes/rollbacks/shadow rejections) as JSONL here \
                   (default = disabled)",
        },
        OptSpec {
            name: "wal-dir",
            takes_value: true,
            help: "serve: directory for the append-only WAL + checkpoint pair \
                   (crash-safe ingest; default = volatile, no persistence)",
        },
        OptSpec {
            name: "recover",
            takes_value: false,
            help: "serve: replay the --wal-dir WAL over its checkpoint at startup and \
                   resume byte-identical to the pre-crash acked state",
        },
        OptSpec {
            name: "queue-rows",
            takes_value: true,
            help: "serve: ingest queue bound in rows — shed maintenance at half depth, \
                   reject train batches (typed 'overloaded' reply) at full depth \
                   (default 0 = unbounded)",
        },
        OptSpec {
            name: "predict-deadline-ms",
            takes_value: true,
            help: "serve: per-request predict deadline; requests still queued past it \
                   answer a typed 'overloaded' reply (default 0 = no deadline)",
        },
        OptSpec {
            name: "io-timeout-secs",
            takes_value: true,
            help: "serve: socket read/write timeout — a stalled or dead client is \
                   disconnected instead of pinning its session thread (default 0 = none)",
        },
        OptSpec {
            name: "shadow-eval",
            takes_value: false,
            help: "serve: gate publishes through shadow evaluation against the incumbent \
                   on live predict traffic; regressing candidates are auto-rejected",
        },
        OptSpec {
            name: "history",
            takes_value: true,
            help: "serve: registry versions retained for rollback (default 8)",
        },
        OptSpec {
            name: "wal-rotate",
            takes_value: false,
            help: "serve: rotate the WAL at every durable checkpoint (bounded replay: \
                   recovery reads the checkpoint plus only the rows past it)",
        },
        OptSpec {
            name: "coordinator",
            takes_value: false,
            help: "serve: multi-node coordinator — deal acked train rows over the \
                   --nodes serve processes, merge their snapshots into the served \
                   model, fail predict traffic over across the replicas",
        },
        OptSpec {
            name: "nodes",
            takes_value: true,
            help: "serve --coordinator: comma-separated host:port list of serve nodes; \
                   bench --resilience: node count for the multi-node kill/partition \
                   scenario (default 0 = single-process harness only)",
        },
    ]
}

fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(x) = args.get_f64("scale")? {
        cfg.scale = x;
    }
    if let Some(x) = args.get_f64("passes-factor")? {
        cfg.passes_factor = x;
    }
    if let Some(x) = args.get_usize("runs")? {
        cfg.runs = x;
    }
    if let Some(x) = args.get_usize("grid")? {
        cfg.grid = x;
    }
    if let Some(x) = args.get_u64("seed")? {
        cfg.seed = x;
    }
    if let Some(x) = args.get_usize("threads")? {
        cfg.threads = x;
    }
    if let Some(list) = args.get("datasets") {
        cfg.datasets = list.split(',').map(|s| s.trim().to_string()).collect();
    }
    if let Some(x) = args.get("out") {
        cfg.out_dir = x.to_string();
    }
    if args.flag("fast-exp") {
        cfg.fast_exp = true;
    }
    if let Some(s) = args.get("solver") {
        cfg.solver = SolverSpec::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown solver '{s}' (expected bsgd or bdca)"))?;
    }
    if let Some(x) = args.get_usize("dual-epochs")? {
        cfg.dual_epochs = x;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let specs = opt_specs();
    let args = Args::parse(&argv, &specs)?;
    if let Some(tier) = args.get("simd") {
        // Must land before the engine's one-time tier detection; the env
        // var is the single source of truth so library users see the same
        // override surface as the CLI.
        std::env::set_var("BUDGETSVM_SIMD", tier);
    }
    let cfg = config_from(&args)?;

    match args.subcommand.as_str() {
        "" | "help" => {
            print!("{}", usage("repro", SUBCOMMANDS, &specs));
        }
        "all" => {
            let summary = coordinator::run_campaign(&cfg)?;
            println!("## Table 1\n\n{}", summary.table1);
            println!("## Table 2\n\n{}", summary.table2);
            println!("## Table 3\n\n{}", summary.table3);
            println!("## Figure 2\n\n{}", summary.figure2);
            println!("## Figure 3\n\n{}", summary.figure3);
            println!(
                "campaign finished in {:.1}s; outputs in {}/",
                summary.wall_seconds, cfg.out_dir
            );
        }
        "table1" => {
            let rows = experiments::table1::run(&cfg)?;
            println!("{}", experiments::table1::render(&rows, &cfg)?);
        }
        "table2" => {
            let cells = experiments::table2::run(&cfg)?;
            println!("{}", experiments::table2::render(&cells, &cfg)?);
            let violations = experiments::table2::indistinguishability_violations(&cells, 2.0);
            if violations.is_empty() {
                println!("check: method accuracies are statistically indistinguishable ✓");
            } else {
                println!("check: spread exceeded 2x pooled std on:");
                for v in violations {
                    println!("  {v}");
                }
            }
        }
        "table3" => {
            let (rows, cells) = experiments::table3::run(&cfg)?;
            println!("{}", experiments::table3::render(&rows, &cells, &cfg)?);
        }
        "figure2" => {
            let table = experiments::figure2::run(&cfg)?;
            println!("{}", experiments::figure2::render(&table));
            println!("grid CSV written to {}/figure2.csv", cfg.out_dir);
        }
        "figure3" => {
            let bars = experiments::figure3::run(&cfg)?;
            println!("{}", experiments::figure3::render(&bars, &cfg)?);
        }
        "bench" => {
            if args.flag("all") {
                // One invocation, one trajectory artifact: kernel +
                // maintenance + solver harnesses, merged into
                // BENCH_summary.json (the per-bench files keep their paths
                // for the gates).
                let kernel = experiments::kernel_bench::run(args.flag("quick"), cfg.threads)?;
                println!("{kernel}");
                let kpath = experiments::kernel_bench::write(&kernel, &cfg.out_dir)?;
                eprintln!("bench report written to {kpath}");
                let maint = experiments::maint_bench::run(args.flag("quick"))?;
                print!("{}", experiments::maint_bench::render(&maint));
                let mpath = experiments::maint_bench::write(&maint, &cfg.out_dir)?;
                eprintln!("maintenance bench report written to {mpath}");
                let solver = experiments::solver_bench::run(args.flag("quick"))?;
                print!("{}", experiments::solver_bench::render(&solver));
                let sbpath = experiments::solver_bench::write(&solver, &cfg.out_dir)?;
                eprintln!("solver bench report written to {sbpath}");
                let spath =
                    experiments::write_bench_summary(&cfg.out_dir, &kernel, &maint, &solver)?;
                eprintln!("merged bench summary written to {spath}");
            } else if args.flag("resilience") {
                let (report, path) = coordinator::run_resilience_bench(
                    args.flag("quick"),
                    cfg.seed,
                    args.get_usize("nodes")?.unwrap_or(0),
                    &cfg.out_dir,
                )?;
                println!("{report}");
                eprintln!("resilience bench report written to {path}");
            } else if args.flag("observability") {
                let (report, path) = coordinator::run_observability_bench(
                    args.flag("quick"),
                    cfg.seed,
                    &cfg.out_dir,
                )?;
                println!("{report}");
                eprintln!("observability bench report written to {path}");
            } else if args.flag("solver-bench") {
                let report = experiments::solver_bench::run(args.flag("quick"))?;
                print!("{}", experiments::solver_bench::render(&report));
                let path = experiments::solver_bench::write(&report, &cfg.out_dir)?;
                eprintln!("solver bench report written to {path}");
            } else if args.flag("maintenance") {
                let report = experiments::maint_bench::run(args.flag("quick"))?;
                print!("{}", experiments::maint_bench::render(&report));
                let path = experiments::maint_bench::write(&report, &cfg.out_dir)?;
                eprintln!("maintenance bench report written to {path}");
            } else {
                let report = experiments::kernel_bench::run(args.flag("quick"), cfg.threads)?;
                println!("{report}");
                let path = experiments::kernel_bench::write(&report, &cfg.out_dir)?;
                eprintln!("bench report written to {path}");
            }
        }
        "serve" => {
            let mut scfg = budgetsvm::serve::ServeConfig::new();
            if let Some(p) = args.get_usize("port")? {
                scfg.port = u16::try_from(p).map_err(|_| anyhow::anyhow!("--port out of range"))?;
            }
            if let Some(s) = args.get_usize("shards")? {
                scfg.shards = s;
            }
            if let Some(pe) = args.get_usize("publish-every")? {
                scfg.publish_every = pe;
            }
            scfg.publish_adapt = args.flag("publish-adapt");
            scfg.threads = cfg.threads;
            scfg.seed = cfg.seed;
            // `--solver bdca` trains the ingest shards with the dual
            // solver; `--dual-epochs` tunes its per-pass sweep count.
            scfg.solver = cfg.solver;
            scfg.svm.dual_epochs = cfg.dual_epochs;
            scfg.svm.grid = cfg.grid;
            if let Some(b) = args.get_usize("budget")? {
                scfg.svm.budget = b;
            }
            // CLI flag wins; a JSON --config file can also set these.
            scfg.svm.maint_slack = args.get_f64("maint-slack")?.unwrap_or(cfg.maint_slack);
            scfg.svm.maint_pairs = args.get_usize("maint-pairs")?.unwrap_or(cfg.maint_pairs);
            // `--fast-exp` (or `fast_exp` in a JSON config) selects the
            // exponential tier for pipeline-trained AND pre-published
            // models alike.
            scfg.svm.fast_exp = cfg.fast_exp;
            // Fault-tolerance surface: backpressure, deadlines, timeouts,
            // crash-safe persistence, registry lifecycle.
            if let Some(q) = args.get_usize("queue-rows")? {
                scfg.queue_rows = q;
            }
            if let Some(ms) = args.get_u64("predict-deadline-ms")? {
                scfg.predict_deadline_ms = ms;
            }
            if let Some(secs) = args.get_u64("io-timeout-secs")? {
                scfg.io_timeout_secs = secs;
            }
            if let Some(dir) = args.get("wal-dir") {
                scfg.wal_dir = Some(dir.to_string());
            }
            scfg.recover = args.flag("recover");
            scfg.wal_rotate = args.flag("wal-rotate");
            // Multi-node front: `serve --coordinator --nodes a:p,b:p` deals
            // to remote serve processes instead of training locally.
            scfg.coordinator = args.flag("coordinator");
            if let Some(list) = args.get("nodes") {
                scfg.nodes = list.split(',').map(|s| s.trim().to_string()).collect();
            }
            scfg.shadow_eval = args.flag("shadow-eval");
            if let Some(h) = args.get_usize("history")? {
                scfg.history = h;
            }
            // Observability surface: Prometheus endpoint + JSONL events.
            if let Some(p) = args.get_usize("metrics-port")? {
                scfg.metrics_port =
                    u16::try_from(p).map_err(|_| anyhow::anyhow!("--metrics-port out of range"))?;
            }
            if let Some(path) = args.get("telemetry-log") {
                scfg.telemetry_log = Some(path.to_string());
            }
            let kernel_opt = args.get("kernel").map(KernelSpec::parse).transpose()?;
            let kernel = match (kernel_opt, args.get_f64("gamma")?) {
                (Some(k), _) => Some(k),
                (None, Some(g)) => Some(KernelSpec::Gaussian { gamma: g }),
                (None, None) => None,
            };
            match args.get("strategy") {
                Some(s) => {
                    scfg.svm.strategy = Strategy::parse(s)
                        .ok_or_else(|| anyhow::anyhow!("unknown strategy '{s}'"))?;
                }
                // Like `repro train`: non-Gaussian kernels default to
                // removal maintenance instead of erroring out.
                None => {
                    if let Some(k) = &kernel {
                        if !k.supports_merging() {
                            scfg.svm.strategy = Strategy::Removal;
                        }
                    }
                }
            }
            let model_in = args.get("model");
            match args.get("replay") {
                Some(file) => {
                    let summary = coordinator::run_serve_replay(
                        file,
                        &scfg,
                        kernel,
                        args.get_f64("c")?,
                        model_in,
                        &cfg.out_dir,
                    )?;
                    println!(
                        "replayed {} rows against snapshot v{}: served labels \
                         byte-match offline predict_batch",
                        summary.rows, summary.version
                    );
                    println!("bench report written to {}", summary.bench_path);
                }
                None => {
                    // The paper's C convention needs a fixed n; a live
                    // ingest stream has none, so reject rather than
                    // silently ignore the flag.
                    if args.get_f64("c")?.is_some() {
                        bail!("--c requires --replay (a live stream has no fixed n)");
                    }
                    if let Some(k) = kernel {
                        scfg.svm.kernel = k;
                    }
                    coordinator::run_serve_tcp(&scfg, model_in, None)?;
                }
            }
        }
        "train" => {
            let data = args.positional().first().map(String::as_str).unwrap_or("ijcnn");
            let budget = args.get_usize("budget")?.unwrap_or(100);
            let kernel = args.get("kernel").map(KernelSpec::parse).transpose()?;
            let strategy = match args.get("strategy") {
                Some(s) => {
                    Strategy::parse(s).ok_or_else(|| anyhow::anyhow!("unknown strategy '{s}'"))?
                }
                // Merging needs the Gaussian geometry; default non-Gaussian
                // kernels to removal instead of erroring out.
                None => match &kernel {
                    Some(k) if !k.supports_merging() => Strategy::Removal,
                    _ => Strategy::parse("lookup-wd").unwrap(),
                },
            };
            let run = coordinator::run_single(
                data,
                budget,
                strategy,
                kernel,
                &cfg,
                args.get_usize("passes")?,
                args.get_f64("c")?,
                args.get_f64("gamma")?,
                args.get_f64("maint-slack")?.unwrap_or(cfg.maint_slack),
                args.get_usize("maint-pairs")?.unwrap_or(cfg.maint_pairs),
                cfg.solver,
            )?;
            if let Some(path) = args.get("model-out") {
                budgetsvm::model::io::save_any(&run.model, path)?;
                eprintln!("model saved to {path}");
            }
            if args.flag("json") {
                println!("{}", coordinator::single_run_json(&run, strategy));
            } else {
                println!("dataset            : {} ({} rows)", run.dataset, run.n_train);
                println!("solver             : {}", cfg.solver.name());
                println!("strategy           : {}", strategy.name());
                println!("kernel             : {}", run.model.kernel_spec().describe());
                println!(
                    "simd tier          : {}{}",
                    budgetsvm::kernel::simd::active().name(),
                    if cfg.fast_exp { " + fast-exp" } else { "" }
                );
                println!("steps              : {}", run.summary.steps);
                println!("support vectors    : {}", run.model.num_sv());
                println!(
                    "merging frequency  : {:.1}%",
                    100.0 * run.summary.merging_frequency()
                );
                println!("train accuracy     : {:.2}%", 100.0 * run.train_accuracy);
                if let Some(acc) = run.test_accuracy {
                    println!("test accuracy      : {:.2}%", 100.0 * acc);
                }
                println!("wall time          : {:.3}s", run.summary.wall_seconds);
                println!(
                    "maintenance time   : {:.3}s ({:.1}% of accounted time)",
                    run.summary.profiler.maintenance_seconds(),
                    100.0 * run.summary.maintenance_fraction()
                );
            }
        }
        "eval" => {
            let pos = args.positional();
            let (model_path, data_path) = match pos {
                [m, d, ..] => (m.as_str(), d.as_str()),
                _ => bail!("usage: repro eval <model.bsvm> <file.libsvm> [--gamma ...]"),
            };
            // Reads both BSVMMDL1 (legacy) and BSVMMDL2 files.
            let mut model = budgetsvm::model::io::load_any(model_path)?;
            model.set_fast_exp(cfg.fast_exp);
            let ds = budgetsvm::data::libsvm::read_file(data_path, model.dim())?;
            let acc = model.accuracy(&ds);
            println!(
                "model: {} SVs, d={}, kernel={}, bias={:.6}",
                model.num_sv(),
                model.dim(),
                model.kernel_spec().describe(),
                model.bias()
            );
            println!("rows evaluated : {}", ds.len());
            println!("accuracy       : {:.3}%", 100.0 * acc);
        }
        "precompute" => {
            let out = args
                .get("table-out")
                .map(String::from)
                .unwrap_or_else(|| format!("artifacts/table{}.tbl", cfg.grid));
            let t = shared_lookup_table(cfg.grid);
            if let Some(parent) = std::path::Path::new(&out).parent() {
                std::fs::create_dir_all(parent)?;
            }
            t.save(&out)?;
            println!("built {0}x{0} lookup table -> {1}", cfg.grid, out);
        }
        "runtime-check" => {
            let dir = args.get("artifacts").unwrap_or("artifacts");
            let rt = Runtime::load(dir)?;
            println!(
                "loaded PJRT runtime: batch_n={}, decision variants {:?}",
                rt.batch_n(),
                rt.decision_variants()
            );
            // Tiny numeric check: train a 2-D model, compare PJRT vs native.
            let ds = budgetsvm::data::synthetic::two_moons(512, 0.1, 7);
            let mut opts = budgetsvm::solver::BsgdOptions::with_c(30, 10.0, 2.0, ds.len());
            opts.passes = 2;
            let report = budgetsvm::solver::train_bsgd(&ds, &opts);
            let native = report.model.accuracy(&ds);
            let pjrt = rt.accuracy(&report.model, &ds)?;
            println!("two-moons accuracy: native={native:.4} pjrt={pjrt:.4}");
            if (native - pjrt).abs() > 0.01 {
                bail!("PJRT accuracy diverges from native");
            }
            println!("runtime check OK");
        }
        other => {
            bail!("unknown command '{other}'; run `repro help`");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The help text is generated from `SUBCOMMANDS`/`opt_specs()`, so
    /// covering those tables covers the text: every real subcommand and
    /// option must appear, names must be unique, and the serve surface
    /// (this PR's subsystem) must be present — the help can no longer
    /// drift from the real option set without failing here.
    #[test]
    fn usage_covers_every_subcommand_and_option() {
        let specs = opt_specs();
        let text = usage("repro", SUBCOMMANDS, &specs);
        for (name, help) in SUBCOMMANDS {
            assert!(!help.is_empty(), "subcommand {name} needs help text");
            assert!(text.contains(name), "usage text is missing subcommand '{name}'");
        }
        for s in &specs {
            assert!(!s.help.is_empty(), "option --{} needs help text", s.name);
            assert!(
                text.contains(&format!("--{}", s.name)),
                "usage text is missing option --{}",
                s.name
            );
        }
    }

    #[test]
    fn subcommand_and_option_names_are_unique() {
        let mut sub: Vec<&str> = SUBCOMMANDS.iter().map(|(n, _)| *n).collect();
        sub.sort_unstable();
        sub.dedup();
        assert_eq!(sub.len(), SUBCOMMANDS.len(), "duplicate subcommand name");
        let specs = opt_specs();
        let mut names: Vec<&str> = specs.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), specs.len(), "duplicate option name");
    }

    #[test]
    fn serve_surface_is_declared() {
        assert!(SUBCOMMANDS.iter().any(|(n, _)| *n == "serve"));
        let specs = opt_specs();
        for opt in [
            "port",
            "shards",
            "publish-every",
            "replay",
            "model",
            "wal-dir",
            "queue-rows",
            "predict-deadline-ms",
            "io-timeout-secs",
            "history",
            "nodes",
        ] {
            let spec = specs
                .iter()
                .find(|s| s.name == opt)
                .unwrap_or_else(|| panic!("serve option --{opt} is not declared"));
            assert!(spec.takes_value, "--{opt} must take a value");
        }
        for flag in ["recover", "shadow-eval", "wal-rotate", "coordinator"] {
            let spec = specs
                .iter()
                .find(|s| s.name == flag)
                .unwrap_or_else(|| panic!("serve flag --{flag} is not declared"));
            assert!(!spec.takes_value, "--{flag} must be a flag");
        }
    }

    #[test]
    fn maintenance_surface_is_declared() {
        let specs = opt_specs();
        for opt in ["maint-slack", "maint-pairs"] {
            let spec = specs
                .iter()
                .find(|s| s.name == opt)
                .unwrap_or_else(|| panic!("maintenance option --{opt} is not declared"));
            assert!(spec.takes_value, "--{opt} must take a value");
        }
        for flag in ["maintenance", "publish-adapt"] {
            let spec = specs
                .iter()
                .find(|s| s.name == flag)
                .unwrap_or_else(|| panic!("flag --{flag} is not declared"));
            assert!(!spec.takes_value, "--{flag} must be a flag");
        }
    }

    #[test]
    fn simd_and_bench_surface_is_declared() {
        let specs = opt_specs();
        for flag in ["fast-exp", "all", "resilience", "observability"] {
            let spec = specs
                .iter()
                .find(|s| s.name == flag)
                .unwrap_or_else(|| panic!("flag --{flag} is not declared"));
            assert!(!spec.takes_value, "--{flag} must be a flag");
        }
        let simd = specs
            .iter()
            .find(|s| s.name == "simd")
            .expect("option --simd is not declared");
        assert!(simd.takes_value, "--simd must take a value");
        for tier in ["scalar", "avx2", "avx512", "neon"] {
            assert!(simd.help.contains(tier), "--simd help must name tier {tier}");
        }
        let argv: Vec<String> =
            ["train", "--simd", "avx512"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert_eq!(args.get("simd"), Some("avx512"));
    }

    #[test]
    fn observability_surface_is_declared_and_parses() {
        let specs = opt_specs();
        for opt in ["metrics-port", "telemetry-log"] {
            let spec = specs
                .iter()
                .find(|s| s.name == opt)
                .unwrap_or_else(|| panic!("observability option --{opt} is not declared"));
            assert!(spec.takes_value, "--{opt} must take a value");
        }
        let argv: Vec<String> = [
            "serve",
            "--metrics-port",
            "9102",
            "--telemetry-log",
            "/tmp/events.jsonl",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert_eq!(args.get_usize("metrics-port").unwrap(), Some(9102));
        assert_eq!(args.get("telemetry-log"), Some("/tmp/events.jsonl"));

        let argv: Vec<String> =
            ["bench", "--observability", "--quick"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert!(args.flag("observability") && args.flag("quick"));
    }

    #[test]
    fn resilience_serve_options_parse_through_the_cli() {
        let argv: Vec<String> = [
            "serve",
            "--wal-dir",
            "/tmp/wals",
            "--recover",
            "--queue-rows",
            "4096",
            "--predict-deadline-ms",
            "250",
            "--io-timeout-secs",
            "30",
            "--shadow-eval",
            "--history",
            "4",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert_eq!(args.get("wal-dir"), Some("/tmp/wals"));
        assert!(args.flag("recover"));
        assert!(args.flag("shadow-eval"));
        assert_eq!(args.get_usize("queue-rows").unwrap(), Some(4096));
        assert_eq!(args.get_u64("predict-deadline-ms").unwrap(), Some(250));
        assert_eq!(args.get_u64("io-timeout-secs").unwrap(), Some(30));
        assert_eq!(args.get_usize("history").unwrap(), Some(4));

        let argv: Vec<String> =
            ["bench", "--resilience", "--quick"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert!(args.flag("resilience") && args.flag("quick"));
    }

    #[test]
    fn cluster_serve_options_parse_through_the_cli() {
        let argv: Vec<String> = [
            "serve",
            "--coordinator",
            "--nodes",
            "127.0.0.1:7001, 127.0.0.1:7002,127.0.0.1:7003",
            "--wal-rotate",
            "--wal-dir",
            "/tmp/wals",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert!(args.flag("coordinator"));
        assert!(args.flag("wal-rotate"));
        // The node list splits on commas and trims whitespace, exactly as
        // the serve dispatch does before ServeConfig::validate sees it.
        let nodes: Vec<String> = args
            .get("nodes")
            .unwrap()
            .split(',')
            .map(|s| s.trim().to_string())
            .collect();
        assert_eq!(nodes, ["127.0.0.1:7001", "127.0.0.1:7002", "127.0.0.1:7003"]);

        // The same --nodes option is the cluster size on the bench side.
        let argv: Vec<String> = ["bench", "--resilience", "--nodes", "3", "--quick"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert!(args.flag("resilience"));
        assert_eq!(args.get_usize("nodes").unwrap(), Some(3));
    }

    #[test]
    fn solver_surface_is_declared() {
        let specs = opt_specs();
        for opt in ["solver", "dual-epochs"] {
            let spec = specs
                .iter()
                .find(|s| s.name == opt)
                .unwrap_or_else(|| panic!("solver option --{opt} is not declared"));
            assert!(spec.takes_value, "--{opt} must take a value");
        }
        let bench = specs
            .iter()
            .find(|s| s.name == "solver-bench")
            .expect("flag --solver-bench is not declared");
        assert!(!bench.takes_value, "--solver-bench must be a flag");
    }

    #[test]
    fn solver_options_parse_through_the_cli() {
        let argv: Vec<String> = ["train", "ijcnn", "--solver", "bdca", "--dual-epochs", "3"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        let cfg = config_from(&args).unwrap();
        assert_eq!(cfg.solver, SolverSpec::Bdca);
        assert_eq!(cfg.dual_epochs, 3);

        // Unknown family members and degenerate epoch counts are rejected.
        let argv: Vec<String> =
            ["train", "ijcnn", "--solver", "smo"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert!(config_from(&args).is_err());
        let argv: Vec<String> =
            ["train", "ijcnn", "--dual-epochs", "0"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert!(config_from(&args).is_err());

        // The bench leg flag parses alongside --quick.
        let argv: Vec<String> =
            ["bench", "--solver-bench", "--quick"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert!(args.flag("solver-bench") && args.flag("quick"));
    }

    #[test]
    fn fast_exp_and_bench_all_parse_through_the_cli() {
        let argv: Vec<String> =
            ["train", "ijcnn", "--fast-exp"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert!(args.flag("fast-exp"));
        let cfg = config_from(&args).unwrap();
        assert!(cfg.fast_exp);

        let argv: Vec<String> =
            ["bench", "--all", "--quick"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert!(args.flag("all") && args.flag("quick"));
        assert!(!config_from(&args).unwrap().fast_exp);
    }

    #[test]
    fn maintenance_options_parse_through_the_cli() {
        let argv: Vec<String> = [
            "train",
            "ijcnn",
            "--maint-slack",
            "16",
            "--maint-pairs",
            "4",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert_eq!(args.get_f64("maint-slack").unwrap(), Some(16.0));
        assert_eq!(args.get_usize("maint-pairs").unwrap(), Some(4));

        let argv: Vec<String> =
            ["bench", "--maintenance", "--quick"].iter().map(|s| s.to_string()).collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert!(args.flag("maintenance") && args.flag("quick"));

        let argv: Vec<String> = ["serve", "--publish-adapt", "--replay", "s.libsvm"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert!(args.flag("publish-adapt"));
    }

    #[test]
    fn serve_options_parse_through_the_cli() {
        let argv: Vec<String> = [
            "serve",
            "--replay",
            "stream.libsvm",
            "--shards",
            "4",
            "--publish-every",
            "512",
            "--port",
            "9000",
            "--model",
            "m.bsvm",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&argv, &opt_specs()).unwrap();
        assert_eq!(args.subcommand, "serve");
        assert_eq!(args.get("replay"), Some("stream.libsvm"));
        assert_eq!(args.get_usize("shards").unwrap(), Some(4));
        assert_eq!(args.get_usize("publish-every").unwrap(), Some(512));
        assert_eq!(args.get_usize("port").unwrap(), Some(9000));
        assert_eq!(args.get("model"), Some("m.bsvm"));
    }
}

//! Sequential Minimal Optimization dual solver — a compact LIBSVM stand-in
//! used for the "exact model" accuracy reference of Table 1 (the real
//! LIBSVM is external; see DESIGN.md §5).
//!
//! Solves the C-SVM dual
//! `min ½αᵀQα − eᵀα  s.t.  0 ≤ α ≤ C,  yᵀα = 0`,  `Q_ij = y_i y_j k(x_i,x_j)`
//! with first-order maximal-violating-pair working-set selection
//! (Keerthi et al. / LIBSVM WSS1) and a precomputed kernel matrix, so it is
//! intended for the subsampled reference runs (n ≲ 4000), not for scale —
//! scale is BSGD's job, which is the point of the paper.
//!
//! The core is kernel-generic (only Gram evaluations are needed);
//! [`SmoEstimator`] exposes it behind the unified [`Estimator`] surface
//! with a buffered `partial_fit` (each call appends the new rows and
//! re-solves — exact but O(n²) per call, matching SMO's batch nature),
//! while [`train_smo`] / [`SmoOptions`] remain the legacy Gaussian shim.

use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::data::Dataset;
use crate::kernel::{Gaussian, Kernel, KernelSpec, Linear, Polynomial};
use crate::model::{AnyModel, BudgetModel};

use super::api::Estimator;

/// Options for the legacy SMO reference solver (Gaussian kernel only).
#[derive(Debug, Clone)]
pub struct SmoOptions {
    /// Box constraint C.
    pub c: f64,
    /// Gaussian kernel bandwidth γ.
    pub gamma: f64,
    /// KKT violation tolerance (LIBSVM default 1e-3).
    pub tolerance: f64,
    /// Hard iteration cap (0 = `1000·n`).
    pub max_iterations: usize,
    /// Refuse to build the kernel matrix beyond this many rows.
    pub max_rows: usize,
}

impl Default for SmoOptions {
    fn default() -> Self {
        SmoOptions { c: 1.0, gamma: 1.0, tolerance: 1e-3, max_iterations: 0, max_rows: 4096 }
    }
}

/// Result of a legacy SMO run.
#[derive(Debug)]
pub struct SmoReport {
    /// Trained model (SVs only, bias set).
    pub model: BudgetModel,
    /// Dual iterations used.
    pub iterations: usize,
    /// Final KKT gap `m(α) − M(α)`.
    pub kkt_gap: f64,
    /// Whether the tolerance was reached within the iteration cap.
    pub converged: bool,
    pub wall_seconds: f64,
    /// Number of support vectors (0 < α).
    pub num_sv: usize,
    /// Number of bounded support vectors (α = C).
    pub num_bounded: usize,
}

/// Solver statistics of one SMO solve (kernel-generic sibling of the
/// non-model fields of [`SmoReport`]).
#[derive(Debug, Clone, Copy)]
pub struct SmoStats {
    pub iterations: usize,
    pub kkt_gap: f64,
    pub converged: bool,
    pub wall_seconds: f64,
    pub num_sv: usize,
    pub num_bounded: usize,
}

/// Kernel-independent solver knobs.
#[derive(Debug, Clone, Copy)]
struct SmoParams {
    c: f64,
    tolerance: f64,
    max_iterations: usize,
    max_rows: usize,
}

/// Train an exact (non-budgeted) SVM with SMO on any kernel.
fn smo_core<K: Kernel + Copy>(
    train: &Dataset,
    kernel: K,
    params: &SmoParams,
) -> Result<(BudgetModel<K>, SmoStats)> {
    let n = train.len();
    ensure!(n >= 2, "need at least two rows");
    ensure!(
        n <= params.max_rows,
        "SMO reference solver capped at {} rows (got {n}); subsample first",
        params.max_rows
    );
    ensure!(params.c > 0.0 && params.c.is_finite(), "C must be positive, got {}", params.c);
    let wall = Instant::now();

    let y: Vec<f64> = (0..n).map(|i| train.label(i) as f64).collect();

    // Full kernel matrix in f32 (n ≤ 4096 → ≤ 64 MiB); row norms come
    // cached with the dataset.
    let norms = train.norms();
    let mut k = vec![0.0f32; n * n];
    for i in 0..n {
        k[i * n + i] = kernel.self_eval(norms[i]) as f32;
        for j in (i + 1)..n {
            let v = kernel.eval(train.row(i), norms[i], train.row(j), norms[j]) as f32;
            k[i * n + j] = v;
            k[j * n + i] = v;
        }
    }

    let mut alpha = vec![0.0f64; n];
    // G = Qα − e starts at −e.
    let mut g = vec![-1.0f64; n];

    let max_iter = if params.max_iterations == 0 { 1000 * n } else { params.max_iterations };
    let mut iterations = 0usize;
    let mut gap = f64::INFINITY;
    let mut converged = false;

    while iterations < max_iter {
        // Maximal violating pair.
        let mut m_val = f64::NEG_INFINITY;
        let mut m_idx = usize::MAX;
        let mut big_m_val = f64::INFINITY;
        let mut big_m_idx = usize::MAX;
        for t in 0..n {
            let yg = -y[t] * g[t];
            let in_up = (y[t] > 0.0 && alpha[t] < params.c) || (y[t] < 0.0 && alpha[t] > 0.0);
            let in_low = (y[t] < 0.0 && alpha[t] < params.c) || (y[t] > 0.0 && alpha[t] > 0.0);
            if in_up && yg > m_val {
                m_val = yg;
                m_idx = t;
            }
            if in_low && yg < big_m_val {
                big_m_val = yg;
                big_m_idx = t;
            }
        }
        gap = m_val - big_m_val;
        if gap < params.tolerance || m_idx == usize::MAX || big_m_idx == usize::MAX {
            converged = gap < params.tolerance;
            break;
        }
        let (i, j) = (m_idx, big_m_idx);

        // Optimal unconstrained step along (y_i e_i, −y_j e_j).
        let quad =
            (k[i * n + i] + k[j * n + j] - 2.0 * k[i * n + j]) as f64;
        let quad = quad.max(1e-12);
        let mut t_step = gap / quad;

        // Box constraints.
        let bound_i = if y[i] > 0.0 { params.c - alpha[i] } else { alpha[i] };
        let bound_j = if y[j] > 0.0 { alpha[j] } else { params.c - alpha[j] };
        t_step = t_step.min(bound_i).min(bound_j);

        alpha[i] += y[i] * t_step;
        alpha[j] -= y[j] * t_step;

        // Gradient update: G_t += t·y_t·(K_ti − K_tj).
        for t in 0..n {
            g[t] += t_step * y[t] * (k[t * n + i] - k[t * n + j]) as f64;
        }
        iterations += 1;
    }

    // Bias from free SVs (0 < α < C): G_i = y_i Σ_j α_j y_j K_ij − 1
    // ⇒ Σ_j α_j y_j K_ij = y_i (G_i + 1), so b = y_i − y_i (G_i + 1).
    let mut b_sum = 0.0;
    let mut b_cnt = 0usize;
    for i in 0..n {
        if alpha[i] > 1e-8 && alpha[i] < params.c - 1e-8 {
            b_sum += y[i] - y[i] * (g[i] + 1.0);
            b_cnt += 1;
        }
    }
    let bias = if b_cnt > 0 {
        b_sum / b_cnt as f64
    } else {
        // All SVs at bounds: midpoint of the violating-pair interval.
        let mut lo = f64::NEG_INFINITY;
        let mut hi = f64::INFINITY;
        for i in 0..n {
            let v = y[i] - y[i] * (g[i] + 1.0);
            if (y[i] > 0.0 && alpha[i] < params.c - 1e-8) || (y[i] < 0.0 && alpha[i] > 1e-8) {
                hi = hi.min(v);
            } else {
                lo = lo.max(v);
            }
        }
        if lo.is_finite() && hi.is_finite() {
            0.5 * (lo + hi)
        } else {
            0.0
        }
    };

    // Assemble the sparse model.
    let num_sv = alpha.iter().filter(|&&a| a > 1e-8).count();
    let num_bounded = alpha.iter().filter(|&&a| a > params.c - 1e-8).count();
    let mut model = BudgetModel::new(train.dim(), kernel, num_sv);
    for i in 0..n {
        if alpha[i] > 1e-8 {
            model.push(train.row(i), alpha[i] * y[i]);
        }
    }
    model.bias = bias;

    let stats = SmoStats {
        iterations,
        kkt_gap: gap,
        converged,
        wall_seconds: wall.elapsed().as_secs_f64(),
        num_sv,
        num_bounded,
    };
    Ok((model, stats))
}

/// Exact dual solver behind the unified [`Estimator`] surface,
/// kernel-generic via [`KernelSpec`].
///
/// `partial_fit` buffers: each call appends the incoming rows to an
/// internal dataset and re-solves the dual on everything seen so far —
/// semantically a true "all data so far" exact model, at batch-solver
/// cost. A single `partial_fit` on a fresh estimator therefore equals
/// `fit` on the same data.
pub struct SmoEstimator {
    kernel: KernelSpec,
    params: SmoParams,
    buffer: Option<Dataset>,
    model: Option<AnyModel>,
    stats: Option<SmoStats>,
}

impl SmoEstimator {
    /// Build an unfitted estimator with LIBSVM-style defaults
    /// (tolerance 1e-3, iteration cap `1000·n`, 4096-row cap).
    pub fn new(kernel: KernelSpec, c: f64) -> Result<Self> {
        kernel.validate()?;
        ensure!(c.is_finite() && c > 0.0, "C must be positive, got {c}");
        Ok(SmoEstimator {
            kernel,
            params: SmoParams { c, tolerance: 1e-3, max_iterations: 0, max_rows: 4096 },
            buffer: None,
            model: None,
            stats: None,
        })
    }

    /// Set the KKT tolerance.
    pub fn tolerance(mut self, tolerance: f64) -> Self {
        self.params.tolerance = tolerance;
        self
    }

    /// Set the hard iteration cap (0 = `1000·n`).
    pub fn max_iterations(mut self, max_iterations: usize) -> Self {
        self.params.max_iterations = max_iterations;
        self
    }

    /// Set the kernel-matrix row cap.
    pub fn max_rows(mut self, max_rows: usize) -> Self {
        self.params.max_rows = max_rows;
        self
    }

    /// The trained model, if fitted.
    pub fn model(&self) -> Option<&AnyModel> {
        self.model.as_ref()
    }

    /// Statistics of the most recent solve, if fitted.
    pub fn stats(&self) -> Option<&SmoStats> {
        self.stats.as_ref()
    }

    /// Consume the estimator, returning the trained model.
    pub fn into_model(self) -> Result<AnyModel> {
        self.model.context("estimator is not fitted")
    }

    fn solve(&mut self) -> Result<()> {
        let data = self.buffer.as_ref().expect("buffer populated by fit/partial_fit");
        let (model, stats) = match self.kernel {
            KernelSpec::Gaussian { gamma } => {
                let (m, s) = smo_core(data, Gaussian::new(gamma), &self.params)?;
                (AnyModel::Gaussian(m), s)
            }
            KernelSpec::Linear => {
                let (m, s) = smo_core(data, Linear, &self.params)?;
                (AnyModel::Linear(m), s)
            }
            KernelSpec::Polynomial { degree, coef0 } => {
                let (m, s) = smo_core(data, Polynomial::new(1.0, coef0, degree), &self.params)?;
                (AnyModel::Polynomial(m), s)
            }
        };
        self.model = Some(model);
        self.stats = Some(stats);
        Ok(())
    }
}

impl Estimator for SmoEstimator {
    type Data = Dataset;

    fn fit(&mut self, data: &Dataset) -> Result<()> {
        ensure!(!data.is_empty(), "cannot train on an empty dataset");
        self.buffer = Some(data.clone());
        self.model = None;
        self.stats = None;
        self.solve()
    }

    fn partial_fit(&mut self, data: &Dataset) -> Result<()> {
        ensure!(!data.is_empty(), "cannot train on an empty dataset");
        // Check the row cap before touching the buffer so a rejected batch
        // does not poison the estimator (the previous model keeps serving
        // and smaller batches remain ingestible).
        let buffered = self.buffer.as_ref().map_or(0, Dataset::len);
        ensure!(
            buffered + data.len() <= self.params.max_rows,
            "ingesting {} rows would exceed the SMO row cap of {} ({} already \
             buffered); raise max_rows or refit on a subsample",
            data.len(),
            self.params.max_rows,
            buffered
        );
        match &mut self.buffer {
            None => self.buffer = Some(data.clone()),
            Some(buf) => {
                ensure!(
                    buf.dim() == data.dim(),
                    "dataset dimension {} does not match the buffered dimension {}",
                    data.dim(),
                    buf.dim()
                );
                for i in 0..data.len() {
                    buf.push_row(data.row(i), data.label(i));
                }
            }
        }
        self.solve()
    }

    fn decision_function(&self, x: &[f32]) -> Result<Vec<f64>> {
        let model = self.model.as_ref().context("estimator is not fitted")?;
        ensure!(x.len() == model.dim(), "feature row has wrong dimension");
        Ok(vec![model.decision(x)])
    }

    fn predict(&self, x: &[f32]) -> Result<f32> {
        let model = self.model.as_ref().context("estimator is not fitted")?;
        ensure!(x.len() == model.dim(), "feature row has wrong dimension");
        Ok(model.predict(x))
    }

    fn dim(&self) -> Option<usize> {
        self.model.as_ref().map(|m| m.dim())
    }
}

/// Train an exact (non-budgeted) Gaussian SVM with SMO (legacy shim over
/// the kernel-generic core).
pub fn train_smo(train: &Dataset, opts: &SmoOptions) -> Result<SmoReport> {
    ensure!(opts.gamma > 0.0, "gamma must be positive, got {}", opts.gamma);
    let params = SmoParams {
        c: opts.c,
        tolerance: opts.tolerance,
        max_iterations: opts.max_iterations,
        max_rows: opts.max_rows,
    };
    let (model, stats) = smo_core(train, Gaussian::new(opts.gamma), &params)?;
    Ok(SmoReport {
        model,
        iterations: stats.iterations,
        kkt_gap: stats.kkt_gap,
        converged: stats.converged,
        wall_seconds: stats.wall_seconds,
        num_sv: stats.num_sv,
        num_bounded: stats.num_bounded,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::data::Dataset;

    #[test]
    fn separable_problem_reaches_full_accuracy() {
        // Two tight, well-separated blobs.
        let mut ds = Dataset::empty("blobs", 2);
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..60 {
            ds.push_row(&[rng.normal() as f32 * 0.2 - 2.0, rng.normal() as f32 * 0.2], 1.0);
            ds.push_row(&[rng.normal() as f32 * 0.2 + 2.0, rng.normal() as f32 * 0.2], -1.0);
        }
        let report =
            train_smo(&ds, &SmoOptions { c: 10.0, gamma: 0.5, ..Default::default() }).unwrap();
        assert!(report.converged);
        assert_eq!(report.model.accuracy(&ds), 1.0);
        // A separable problem needs few SVs.
        assert!(report.num_sv < 30, "num_sv={}", report.num_sv);
    }

    #[test]
    fn two_moons_nonlinear_boundary() {
        let ds = two_moons(300, 0.1, 11);
        let report =
            train_smo(&ds, &SmoOptions { c: 10.0, gamma: 4.0, ..Default::default() }).unwrap();
        assert!(report.converged, "gap={}", report.kkt_gap);
        let acc = report.model.accuracy(&ds);
        assert!(acc > 0.98, "accuracy {acc}");
    }

    #[test]
    fn dual_feasibility_holds() {
        let ds = two_moons(150, 0.15, 5);
        let c = 2.0;
        let report = train_smo(&ds, &SmoOptions { c, gamma: 3.0, ..Default::default() }).unwrap();
        // Σ α_i y_i = 0 within tolerance and 0 ≤ α_i·y_i·y_i ≤ C: model
        // stores α_i·y_i, so |coef| ≤ C and Σ coef = 0.
        let mut sum = 0.0;
        for j in 0..report.model.num_sv() {
            let a = report.model.alpha(j);
            assert!(a.abs() <= c + 1e-6, "coef {a} exceeds C");
            sum += a;
        }
        assert!(sum.abs() < 1e-6, "Σ α y = {sum}");
    }

    #[test]
    fn rejects_oversized_problems() {
        let ds = two_moons(300, 0.1, 1);
        let err = train_smo(
            &ds,
            &SmoOptions { c: 1.0, gamma: 1.0, max_rows: 100, ..Default::default() },
        );
        assert!(err.is_err());
    }

    #[test]
    fn beats_bsgd_slightly_as_exact_reference() {
        // The exact solver should be at least as good as a tightly budgeted
        // BSGD model on the same data — that is its role in Table 1.
        let ds = two_moons(400, 0.15, 8);
        let smo =
            train_smo(&ds, &SmoOptions { c: 10.0, gamma: 3.0, ..Default::default() }).unwrap();
        let mut opts = crate::solver::BsgdOptions::with_c(15, 10.0, 3.0, ds.len());
        opts.passes = 3;
        let bsgd = crate::solver::train_bsgd(&ds, &opts);
        assert!(smo.model.accuracy(&ds) + 1e-9 >= bsgd.model.accuracy(&ds) - 0.05);
    }

    #[test]
    fn linear_kernel_separable_blobs_via_estimator() {
        let mut ds = Dataset::empty("blobs", 2);
        let mut rng = crate::util::rng::Rng::new(7);
        for _ in 0..50 {
            ds.push_row(&[rng.normal() as f32 * 0.3 - 2.0, rng.normal() as f32 * 0.3], 1.0);
            ds.push_row(&[rng.normal() as f32 * 0.3 + 2.0, rng.normal() as f32 * 0.3], -1.0);
        }
        let mut est = SmoEstimator::new(KernelSpec::linear(), 10.0).unwrap();
        est.fit(&ds).unwrap();
        let preds = est.predict_batch(ds.features()).unwrap();
        let acc = crate::metrics::accuracy(&preds, ds.labels());
        assert!(acc > 0.98, "linear SMO accuracy {acc}");
        assert_eq!(est.model().unwrap().kernel_spec(), KernelSpec::linear());
    }

    #[test]
    fn buffered_partial_fit_equals_fit_on_the_union() {
        let ds = two_moons(200, 0.12, 13);
        // Split into two halves.
        let idx_a: Vec<usize> = (0..100).collect();
        let idx_b: Vec<usize> = (100..200).collect();
        let half_a = ds.subset(&idx_a, "a");
        let half_b = ds.subset(&idx_b, "b");

        let mut streamed = SmoEstimator::new(KernelSpec::gaussian(3.0), 10.0).unwrap();
        streamed.partial_fit(&half_a).unwrap();
        streamed.partial_fit(&half_b).unwrap();

        let mut batch = SmoEstimator::new(KernelSpec::gaussian(3.0), 10.0).unwrap();
        batch.fit(&ds).unwrap();

        for i in (0..200).step_by(17) {
            let a = streamed.decision_function(ds.row(i)).unwrap()[0];
            let b = batch.decision_function(ds.row(i)).unwrap()[0];
            assert!((a - b).abs() < 1e-6, "row {i}: {a} vs {b}");
        }
    }
}

//! The unified estimator surface: one `fit` / `partial_fit` /
//! `decision_function` / `predict_batch` contract implemented by every
//! trainer in this crate (BSGD, BDCA, one-vs-rest multiclass, Pegasos,
//! SMO), plus the configuration split into model hyperparameters
//! ([`SvmConfig`]) and run/instrumentation knobs ([`RunConfig`]), and the
//! solver-family registration ([`SolverSpec`] → [`AnyEstimator`]) that
//! lets serving shards, the one-vs-rest reducer and the coordinator pick
//! a binary trainer at runtime.
//!
//! ```no_run
//! use budgetsvm::data::synthetic::two_moons;
//! use budgetsvm::kernel::KernelSpec;
//! use budgetsvm::solver::{BsgdEstimator, Estimator, RunConfig, SvmConfig};
//!
//! let train = two_moons(2000, 0.12, 42);
//! let config = SvmConfig::new()
//!     .kernel(KernelSpec::gaussian(2.0))
//!     .budget(50)
//!     .c(10.0, train.len());
//! let mut est = BsgdEstimator::new(config, RunConfig::new().passes(5)).unwrap();
//! est.fit(&train).unwrap();
//! let preds = est.predict_batch(train.features()).unwrap();
//! # let _ = preds;
//! ```

use anyhow::{ensure, Context, Result};

use crate::budget::{MaintenanceConfig, MergeSolver, Strategy};
use crate::data::Dataset;
use crate::kernel::KernelSpec;
use crate::metrics::{AgreementStats, SectionProfiler};
use crate::model::AnyModel;

use super::bdca::BdcaEstimator;
use super::bsgd::{BsgdEstimator, CurvePoint};
use super::schedule::LearningRate;

/// Model hyperparameters of a (budgeted) kernel SVM: everything that
/// defines *what* is learned, as opposed to *how the run is executed*
/// ([`RunConfig`]). Built with chainable setters.
#[derive(Debug, Clone)]
pub struct SvmConfig {
    /// Kernel selection (typed; replaces the old flat `gamma: f64` field).
    pub kernel: KernelSpec,
    /// Budget B — maximum number of support vectors. `0` means unbudgeted
    /// (the Pegasos regime); the budgeted BSGD estimator requires `B ≥ 2`.
    pub budget: usize,
    /// Regularization λ (the paper tunes `C = 1/(n·λ)`).
    pub lambda: f64,
    /// Budget maintenance strategy; must be compatible with the kernel
    /// (see the [`crate::budget`] compatibility matrix).
    pub strategy: Strategy,
    /// Lookup-table grid resolution for the lookup merge solvers
    /// (paper: 400).
    pub grid: usize,
    /// Maintenance slack `W`: the model may overshoot the budget by up to
    /// `W` SVs before a maintenance event triggers; the event then sheds
    /// the whole batch in one amortized sweep. `0` (the default) is the
    /// classic maintain-every-overflow regime — bit-identical to training
    /// without the slack machinery. Models returned from `fit` /
    /// `partial_fit` always respect the budget (end-of-ingest
    /// enforcement), whatever the slack.
    pub maint_slack: f64,
    /// Pairs shed per maintenance event; `0` = auto (`⌈W⌉ + 1`, exactly
    /// the overshoot a trigger guarantees).
    pub maint_pairs: usize,
    /// Opt-in fast exponential tier for the blocked Gaussian tile path
    /// (`--fast-exp`): the vectorized `exp_v` (≤ 1e-14 relative error,
    /// pinned in `tests/simd.rs`) replaces libm `exp` in
    /// `Kernel::eval_block`. `false` (the default) keeps libm exponential
    /// semantics (exact bit-identity to the pre-SIMD engine additionally
    /// needs the scalar tile tier — on AVX2 hardware the dot accumulation
    /// fuses FMA, which differs at `f32` rounding on non-dyadic data). A
    /// runtime execution choice: it changes no hyperparameter and is
    /// never serialized with a model; non-Gaussian kernels ignore it
    /// (they evaluate no exponential).
    pub fast_exp: bool,
    /// Dual-ascent epochs: randomized coordinate-ascent sweeps over the
    /// budgeted SV set that the dual solver family (BDCA) runs after each
    /// streaming pass. Only read by [`super::BdcaEstimator`]; the primal
    /// solvers ignore it.
    pub dual_epochs: usize,
}

impl Default for SvmConfig {
    fn default() -> Self {
        SvmConfig {
            kernel: KernelSpec::gaussian(1.0),
            budget: 100,
            lambda: 1e-4,
            strategy: Strategy::Merge(MergeSolver::LookupWd),
            grid: 400,
            maint_slack: 0.0,
            maint_pairs: 0,
            fast_exp: false,
            dual_epochs: 2,
        }
    }
}

impl SvmConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the kernel.
    pub fn kernel(mut self, kernel: KernelSpec) -> Self {
        self.kernel = kernel;
        self
    }

    /// Set the support-vector budget.
    pub fn budget(mut self, budget: usize) -> Self {
        self.budget = budget;
        self
    }

    /// Set the regularization λ directly.
    pub fn lambda(mut self, lambda: f64) -> Self {
        self.lambda = lambda;
        self
    }

    /// Derive λ from the paper's `C` convention: `λ = 1/(n·C)`.
    pub fn c(mut self, c: f64, n_train: usize) -> Self {
        self.lambda = 1.0 / (c * n_train as f64);
        self
    }

    /// Set the budget maintenance strategy.
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Set the lookup-table grid resolution.
    pub fn grid(mut self, grid: usize) -> Self {
        self.grid = grid;
        self
    }

    /// Set the maintenance slack `W` (allowed budget overshoot before an
    /// amortized multi-pair maintenance event runs).
    pub fn maint_slack(mut self, slack: f64) -> Self {
        self.maint_slack = slack;
        self
    }

    /// Set the per-event pair quota (`0` = auto, `⌈W⌉ + 1`).
    pub fn maint_pairs(mut self, pairs: usize) -> Self {
        self.maint_pairs = pairs;
        self
    }

    /// Opt into the fast exponential tier of the blocked Gaussian tile
    /// path (see the field docs; no-op for non-Gaussian kernels).
    pub fn fast_exp(mut self, fast_exp: bool) -> Self {
        self.fast_exp = fast_exp;
        self
    }

    /// Set the dual-ascent epoch count (BDCA only; ignored by the primal
    /// solvers).
    pub fn dual_epochs(mut self, epochs: usize) -> Self {
        self.dual_epochs = epochs;
        self
    }

    /// The budget-maintenance slice of this configuration — what
    /// [`crate::budget::policy`] builds a [`crate::budget::MaintenancePolicy`]
    /// from.
    pub fn maintenance(&self) -> MaintenanceConfig {
        MaintenanceConfig {
            strategy: self.strategy,
            grid: self.grid,
            slack: self.maint_slack,
            pairs: self.maint_pairs,
        }
    }

    /// Validate hyperparameters and the kernel/strategy combination.
    /// `budget == 0` (unbudgeted) is accepted here; budgeted estimators
    /// impose their own `B ≥ 2` on top.
    pub fn validate(&self) -> Result<()> {
        self.kernel.validate()?;
        ensure!(
            self.lambda.is_finite() && self.lambda > 0.0,
            "lambda must be positive and finite, got {}",
            self.lambda
        );
        ensure!(self.grid >= 2, "lookup grid must be at least 2, got {}", self.grid);
        ensure!(
            self.dual_epochs >= 1,
            "need at least one dual-ascent epoch, got {}",
            self.dual_epochs
        );
        self.maintenance().validate()?;
        ensure!(
            self.strategy.valid_for(&self.kernel),
            "maintenance strategy {} is not valid for the {} kernel: merge-based \
             maintenance requires the Gaussian closed-form geometry — use the \
             removal or projection strategy instead",
            self.strategy.name(),
            self.kernel.describe()
        );
        Ok(())
    }
}

/// Run/instrumentation knobs: everything about *how* a training run is
/// executed and observed, none of which changes the hypothesis class.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Passes (epochs) over the data in [`Estimator::fit`]
    /// (`partial_fit` always performs exactly one pass).
    pub passes: usize,
    /// RNG seed controlling the visit order.
    pub seed: u64,
    /// Shuffle the visit order each `fit` pass. `partial_fit` never
    /// shuffles — it consumes the stream in presented order, which is what
    /// makes `fit` (with `shuffle = false`, one pass) and a single
    /// `partial_fit` bit-identical.
    pub shuffle: bool,
    /// Learning-rate schedule; `None` = Pegasos `1/(λt)`.
    pub learning_rate: Option<LearningRate>,
    /// Record Table-3-style agreement statistics (Gaussian + merge only;
    /// expensive, for the audit experiment).
    pub audit: bool,
    /// Record an objective/accuracy curve every `curve_every` steps
    /// (0 = never).
    pub curve_every: u64,
    /// Rows subsampled for each curve evaluation.
    pub curve_sample: usize,
    /// Worker threads for the embarrassingly-parallel layers: per-class
    /// one-vs-rest training, chunked batch prediction/accuracy, and curve
    /// evaluation. `0` = all hardware threads, `1` = fully serial. The
    /// thread count never changes results — work splits at machine / row
    /// granularity with order-preserving reduction, so `threads = N` is
    /// bit-identical to `threads = 1`.
    pub threads: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            passes: 1,
            seed: 0,
            shuffle: true,
            learning_rate: None,
            audit: false,
            curve_every: 0,
            curve_sample: 512,
            threads: 0,
        }
    }
}

impl RunConfig {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn passes(mut self, passes: usize) -> Self {
        self.passes = passes;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn shuffle(mut self, shuffle: bool) -> Self {
        self.shuffle = shuffle;
        self
    }

    pub fn learning_rate(mut self, lr: LearningRate) -> Self {
        self.learning_rate = Some(lr);
        self
    }

    pub fn audit(mut self, audit: bool) -> Self {
        self.audit = audit;
        self
    }

    pub fn curve(mut self, every: u64, sample: usize) -> Self {
        self.curve_every = every;
        self.curve_sample = sample;
        self
    }

    /// Worker threads (0 = all hardware threads, 1 = serial; results are
    /// identical either way).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.passes >= 1, "need at least one pass, got {}", self.passes);
        if self.curve_every > 0 {
            ensure!(self.curve_sample >= 1, "curve_sample must be positive when curves are on");
        }
        Ok(())
    }
}

/// Everything an SGD-family training run produces besides the model itself
/// (the kernel-generic sibling of the legacy `TrainReport`, which bundles
/// the Gaussian model).
#[derive(Debug, Clone, Default)]
pub struct FitSummary {
    /// SGD steps executed so far (cumulative across `partial_fit` calls).
    pub steps: u64,
    /// Steps that violated the margin and inserted an SV.
    pub sv_inserts: u64,
    /// Budget maintenance events triggered.
    pub maintenance_events: u64,
    /// Section timings (SGD / maintenance A / maintenance B).
    pub profiler: SectionProfiler,
    /// Total wall time spent inside training loops.
    pub wall_seconds: f64,
    /// Sum of weight degradations over all maintenance events.
    pub total_weight_degradation: f64,
    /// Objective curve (empty unless `curve_every > 0`).
    pub curve: Vec<CurvePoint>,
    /// Agreement statistics (present iff `audit`).
    pub agreement: Option<AgreementStats>,
}

impl FitSummary {
    /// Fraction of SGD steps that triggered budget maintenance — the
    /// paper's "merging frequency" (Table 3).
    pub fn merging_frequency(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.maintenance_events as f64 / self.steps as f64
        }
    }

    /// Fraction of total accounted time spent in budget maintenance.
    pub fn maintenance_fraction(&self) -> f64 {
        let total = self.profiler.total_seconds();
        if total <= 0.0 {
            0.0
        } else {
            self.profiler.maintenance_seconds() / total
        }
    }
}

/// The unified training/inference contract.
///
/// `Data` is the dataset type an implementation ingests: the binary
/// trainers ([`super::BsgdEstimator`], [`super::PegasosEstimator`],
/// [`super::SmoEstimator`]) consume [`crate::data::Dataset`] (±1 labels);
/// the one-vs-rest reducer ([`super::OneVsRestEstimator`]) consumes
/// [`crate::solver::multiclass::MulticlassDataset`] (class indices).
///
/// Inference methods take flat `f32` feature rows, so a serving layer can
/// drive any estimator without constructing a labeled dataset.
pub trait Estimator {
    /// Dataset type this estimator trains on.
    type Data;

    /// Reset any learned state and train from scratch.
    fn fit(&mut self, data: &Self::Data) -> Result<()>;

    /// Streaming/online ingest — the production path: continue training
    /// (without resetting) with one pass over `data` in presented order.
    /// On a fresh estimator this initializes the model from the first
    /// batch.
    fn partial_fit(&mut self, data: &Self::Data) -> Result<()>;

    /// Raw decision value(s) for one feature row: one entry for binary
    /// estimators, K entries (per-class scores) for multiclass.
    fn decision_function(&self, x: &[f32]) -> Result<Vec<f64>>;

    /// Predicted label for one feature row: ±1 for binary estimators, the
    /// class index (as `f32`) for multiclass.
    fn predict(&self, x: &[f32]) -> Result<f32>;

    /// Feature dimension, once fitted.
    fn dim(&self) -> Option<usize>;

    /// Whether the estimator holds a trained model.
    fn is_fitted(&self) -> bool {
        self.dim().is_some()
    }

    /// Predictions for a flat row-major batch (`x.len()` must be a
    /// multiple of [`Estimator::dim`]).
    fn predict_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        let d = self.dim().context("estimator is not fitted")?;
        ensure!(
            x.len() % d == 0,
            "batch buffer length {} is not a multiple of the feature dimension {d}",
            x.len()
        );
        x.chunks_exact(d).map(|row| self.predict(row)).collect()
    }
}

/// Which member of the budgeted binary solver family trains a model:
/// the primal SGD trainer (BSGD, the paper's solver) or the dual
/// coordinate-ascent trainer (BDCA, its sister-paper sibling). Both share
/// [`SvmConfig`]/[`RunConfig`], the budget-maintenance pipeline and the
/// [`Estimator`] contract, so everything downstream (serving shards,
/// one-vs-rest reduction, the coordinator) selects a solver by this spec
/// instead of hard-wiring a concrete type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SolverSpec {
    /// Budgeted primal SGD with merging/removal/projection maintenance
    /// (Wang et al. 2012 + the paper's merge solvers). The default.
    #[default]
    Bsgd,
    /// Budgeted dual coordinate ascent over a churn-aware Gram cache.
    Bdca,
}

impl SolverSpec {
    pub fn name(&self) -> &'static str {
        match self {
            SolverSpec::Bsgd => "bsgd",
            SolverSpec::Bdca => "bdca",
        }
    }

    pub fn parse(s: &str) -> Option<SolverSpec> {
        match s.to_ascii_lowercase().as_str() {
            "bsgd" => Some(SolverSpec::Bsgd),
            "bdca" => Some(SolverSpec::Bdca),
            _ => None,
        }
    }
}

/// Runtime-dispatched member of the binary solver family — the estimator
/// counterpart of [`crate::model::AnyModel`] / [`crate::budget::AnyPolicy`].
/// Built from a [`SolverSpec`] so shard factories and one-vs-rest wiring
/// stay solver-agnostic.
#[derive(Debug)]
pub enum AnyEstimator {
    Bsgd(BsgdEstimator),
    Bdca(BdcaEstimator),
}

impl AnyEstimator {
    pub fn new(solver: SolverSpec, config: SvmConfig, run: RunConfig) -> Result<Self> {
        Ok(match solver {
            SolverSpec::Bsgd => AnyEstimator::Bsgd(BsgdEstimator::new(config, run)?),
            SolverSpec::Bdca => AnyEstimator::Bdca(BdcaEstimator::new(config, run)?),
        })
    }

    /// Shard-deterministic constructor (see [`super::bsgd::shard_seed`]):
    /// the solver-agnostic factory the serving layer builds its ingest
    /// shards from.
    pub fn new_shard(
        solver: SolverSpec,
        config: SvmConfig,
        run: RunConfig,
        shard: usize,
    ) -> Result<Self> {
        Ok(match solver {
            SolverSpec::Bsgd => AnyEstimator::Bsgd(BsgdEstimator::new_shard(config, run, shard)?),
            SolverSpec::Bdca => AnyEstimator::Bdca(BdcaEstimator::new_shard(config, run, shard)?),
        })
    }

    pub fn solver(&self) -> SolverSpec {
        match self {
            AnyEstimator::Bsgd(_) => SolverSpec::Bsgd,
            AnyEstimator::Bdca(_) => SolverSpec::Bdca,
        }
    }

    pub fn config(&self) -> &SvmConfig {
        match self {
            AnyEstimator::Bsgd(e) => e.config(),
            AnyEstimator::Bdca(e) => e.config(),
        }
    }

    /// Snapshot of the current model plus the step counter it was taken at
    /// (`None` until the first ingest) — what the serving layer publishes.
    pub fn snapshot(&self) -> Option<(AnyModel, u64)> {
        match self {
            AnyEstimator::Bsgd(e) => e.snapshot(),
            AnyEstimator::Bdca(e) => e.snapshot(),
        }
    }

    pub fn model(&self) -> Option<&AnyModel> {
        match self {
            AnyEstimator::Bsgd(e) => e.model(),
            AnyEstimator::Bdca(e) => e.model(),
        }
    }

    pub fn summary(&self) -> Option<&FitSummary> {
        match self {
            AnyEstimator::Bsgd(e) => e.summary(),
            AnyEstimator::Bdca(e) => e.summary(),
        }
    }

    pub fn into_model(self) -> Result<AnyModel> {
        match self {
            AnyEstimator::Bsgd(e) => e.into_model(),
            AnyEstimator::Bdca(e) => e.into_model(),
        }
    }
}

impl Estimator for AnyEstimator {
    type Data = Dataset;

    fn fit(&mut self, data: &Dataset) -> Result<()> {
        match self {
            AnyEstimator::Bsgd(e) => e.fit(data),
            AnyEstimator::Bdca(e) => e.fit(data),
        }
    }

    fn partial_fit(&mut self, data: &Dataset) -> Result<()> {
        match self {
            AnyEstimator::Bsgd(e) => e.partial_fit(data),
            AnyEstimator::Bdca(e) => e.partial_fit(data),
        }
    }

    fn decision_function(&self, x: &[f32]) -> Result<Vec<f64>> {
        match self {
            AnyEstimator::Bsgd(e) => e.decision_function(x),
            AnyEstimator::Bdca(e) => e.decision_function(x),
        }
    }

    fn predict(&self, x: &[f32]) -> Result<f32> {
        match self {
            AnyEstimator::Bsgd(e) => e.predict(x),
            AnyEstimator::Bdca(e) => e.predict(x),
        }
    }

    fn dim(&self) -> Option<usize> {
        match self {
            AnyEstimator::Bsgd(e) => e.dim(),
            AnyEstimator::Bdca(e) => e.dim(),
        }
    }

    fn predict_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        match self {
            AnyEstimator::Bsgd(e) => e.predict_batch(x),
            AnyEstimator::Bdca(e) => e.predict_batch(x),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn svm_config_builder_chains() {
        let cfg = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(50)
            .c(10.0, 1000)
            .strategy(Strategy::Removal)
            .grid(100);
        assert_eq!(cfg.budget, 50);
        assert!((cfg.lambda - 1.0 / 10_000.0).abs() < 1e-18);
        assert_eq!(cfg.grid, 100);
        cfg.validate().unwrap();
    }

    #[test]
    fn merge_strategy_rejected_for_non_gaussian_kernels() {
        let bad = SvmConfig::new().kernel(KernelSpec::linear());
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("removal or projection"), "{err}");
        // Removal fixes it.
        SvmConfig::new()
            .kernel(KernelSpec::linear())
            .strategy(Strategy::Removal)
            .validate()
            .unwrap();
    }

    #[test]
    fn fast_exp_knob_chains_and_validates_for_every_kernel() {
        let cfg = SvmConfig::new().fast_exp(true);
        assert!(cfg.fast_exp);
        cfg.validate().unwrap();
        assert!(!SvmConfig::new().fast_exp);
        // Harmless (ignored) on kernels without an exponential.
        SvmConfig::new()
            .kernel(KernelSpec::linear())
            .strategy(Strategy::Removal)
            .fast_exp(true)
            .validate()
            .unwrap();
    }

    #[test]
    fn maintenance_slice_mirrors_the_config() {
        let cfg = SvmConfig::new().maint_slack(8.0).maint_pairs(3).grid(100);
        let m = cfg.maintenance();
        assert_eq!(m.slack, 8.0);
        assert_eq!(m.pairs, 3);
        assert_eq!(m.grid, 100);
        assert_eq!(m.strategy, cfg.strategy);
        cfg.validate().unwrap();
        assert!(SvmConfig::new().maint_slack(-2.0).validate().is_err());
        assert!(SvmConfig::new().maint_slack(f64::INFINITY).validate().is_err());
    }

    #[test]
    fn config_validation_rejects_bad_numbers() {
        assert!(SvmConfig::new().lambda(0.0).validate().is_err());
        assert!(SvmConfig::new().lambda(-1.0).validate().is_err());
        assert!(SvmConfig::new().lambda(f64::NAN).validate().is_err());
        assert!(SvmConfig::new().grid(1).validate().is_err());
        assert!(SvmConfig::new().kernel(KernelSpec::gaussian(0.0)).validate().is_err());
        assert!(RunConfig::new().passes(0).validate().is_err());
        RunConfig::new().passes(3).curve(100, 64).validate().unwrap();
    }

    #[test]
    fn run_config_threads_knob() {
        let run = RunConfig::new().threads(4);
        assert_eq!(run.threads, 4);
        run.validate().unwrap();
        // 0 (all cores) and 1 (serial) are both valid.
        RunConfig::new().threads(0).validate().unwrap();
        RunConfig::new().threads(1).validate().unwrap();
    }

    #[test]
    fn dual_epochs_knob_chains_and_validates() {
        let cfg = SvmConfig::new().dual_epochs(5);
        assert_eq!(cfg.dual_epochs, 5);
        cfg.validate().unwrap();
        assert_eq!(SvmConfig::new().dual_epochs, 2);
        assert!(SvmConfig::new().dual_epochs(0).validate().is_err());
    }

    #[test]
    fn solver_spec_parsing_and_names() {
        assert_eq!(SolverSpec::parse("bsgd"), Some(SolverSpec::Bsgd));
        assert_eq!(SolverSpec::parse("BDCA"), Some(SolverSpec::Bdca));
        assert_eq!(SolverSpec::parse("bogus"), None);
        assert_eq!(SolverSpec::default(), SolverSpec::Bsgd);
        for spec in [SolverSpec::Bsgd, SolverSpec::Bdca] {
            assert_eq!(SolverSpec::parse(spec.name()), Some(spec));
        }
    }

    #[test]
    fn any_estimator_dispatches_both_family_members() {
        use crate::data::synthetic::two_moons;
        let train = two_moons(200, 0.12, 7);
        for spec in [SolverSpec::Bsgd, SolverSpec::Bdca] {
            let config = SvmConfig::new()
                .kernel(KernelSpec::gaussian(2.0))
                .budget(40)
                .c(10.0, train.len());
            let mut est =
                AnyEstimator::new(spec, config, RunConfig::new().passes(2).seed(3)).unwrap();
            assert_eq!(est.solver(), spec);
            assert!(!est.is_fitted());
            assert!(est.snapshot().is_none());
            est.fit(&train).unwrap();
            assert_eq!(est.dim(), Some(train.dim()));
            let preds = est.predict_batch(train.features()).unwrap();
            assert_eq!(preds.len(), train.len());
            assert!(est.model().unwrap().num_sv() <= 40, "{spec:?}");
            assert!(est.summary().unwrap().steps > 0);
            let (snap, steps) = est.snapshot().unwrap();
            assert_eq!(steps, est.summary().unwrap().steps);
            assert_eq!(snap.num_sv(), est.model().unwrap().num_sv());
            let model = est.into_model().unwrap();
            assert!(model.num_sv() <= 40);
        }
    }

    #[test]
    fn fit_summary_ratios() {
        let mut s = FitSummary { steps: 100, maintenance_events: 25, ..Default::default() };
        assert!((s.merging_frequency() - 0.25).abs() < 1e-15);
        s.steps = 0;
        assert_eq!(s.merging_frequency(), 0.0);
        assert_eq!(s.maintenance_fraction(), 0.0);
    }
}

//! Learning-rate schedules for the SGD solvers.

/// Learning rate η_t as a function of the 1-based step counter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LearningRate {
    /// Pegasos schedule `η_t = 1/(λ·t)` — the BSGD default (guarantees
    /// O(log t / t) convergence on the λ-strongly-convex SVM objective).
    PegasosInvT { lambda: f64 },
    /// `η_t = η₀/√t` (robbins-monro style, for ablation).
    InvSqrt { eta0: f64 },
    /// Constant step size (for ablation).
    Constant { eta0: f64 },
}

impl LearningRate {
    #[inline]
    pub fn eta(&self, t: u64) -> f64 {
        debug_assert!(t >= 1);
        match *self {
            LearningRate::PegasosInvT { lambda } => 1.0 / (lambda * t as f64),
            LearningRate::InvSqrt { eta0 } => eta0 / (t as f64).sqrt(),
            LearningRate::Constant { eta0 } => eta0,
        }
    }

    /// Multiplicative shrink factor `(1 − η_t·λ)` applied to `w` each step.
    #[inline]
    pub fn shrink(&self, t: u64, lambda: f64) -> f64 {
        (1.0 - self.eta(t) * lambda).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pegasos_shrink_is_one_minus_inv_t() {
        let lr = LearningRate::PegasosInvT { lambda: 0.25 };
        assert!((lr.eta(4) - 1.0).abs() < 1e-12);
        assert!((lr.shrink(4, 0.25) - 0.75).abs() < 1e-12);
        // t = 1 → shrink 0 (w starts at 0, so this is harmless).
        assert_eq!(lr.shrink(1, 0.25), 0.0);
    }

    #[test]
    fn schedules_decay() {
        let inv = LearningRate::InvSqrt { eta0: 1.0 };
        assert!(inv.eta(100) < inv.eta(10));
        let c = LearningRate::Constant { eta0: 0.1 };
        assert_eq!(c.eta(1), c.eta(1000));
    }

    #[test]
    fn shrink_clamped_nonnegative() {
        let c = LearningRate::Constant { eta0: 100.0 };
        assert_eq!(c.shrink(1, 1.0), 0.0);
    }
}

//! Unbudgeted kernelized Pegasos (Shalev-Shwartz et al. 2011) — the
//! baseline BSGD degenerates to when the budget never binds. Model size
//! grows with the number of margin violations (linear in n, Steinwart
//! 2003), which is exactly the scaling problem budgets address.
//!
//! [`PegasosEstimator`] is the [`Estimator`]-surface implementation: the
//! shared SGD core with `budget = 0` (the maintenance branch never runs),
//! kernel-generic and streaming-capable. [`train_pegasos`] /
//! [`PegasosOptions`] remain as the legacy Gaussian-only shim.

use anyhow::{Context, Result};

use crate::data::Dataset;
use crate::kernel::KernelSpec;
use crate::metrics::SectionProfiler;
use crate::model::{AnyModel, BudgetModel};

use super::api::{Estimator, FitSummary, RunConfig};
use super::bsgd::BsgdEstimator;

/// Options for a legacy unbudgeted Pegasos run (Gaussian kernel only).
#[derive(Debug, Clone)]
pub struct PegasosOptions {
    pub lambda: f64,
    pub gamma: f64,
    pub passes: usize,
    pub seed: u64,
}

/// Report of a legacy Pegasos run.
#[derive(Debug, Clone)]
pub struct PegasosReport {
    pub model: BudgetModel,
    pub steps: u64,
    pub sv_inserts: u64,
    pub wall_seconds: f64,
    pub profiler: SectionProfiler,
}

/// Unbudgeted kernel SGD behind the unified [`Estimator`] surface. This is
/// plain [`BsgdEstimator`] machinery with the budget pinned to 0, so the
/// model grows with every margin violation.
pub struct PegasosEstimator {
    inner: BsgdEstimator,
}

impl PegasosEstimator {
    /// Build an unfitted estimator (validates kernel and λ).
    pub fn new(kernel: KernelSpec, lambda: f64, run: RunConfig) -> Result<Self> {
        Ok(PegasosEstimator { inner: BsgdEstimator::new_unbudgeted(kernel, lambda, run)? })
    }

    /// The trained model, if fitted.
    pub fn model(&self) -> Option<&AnyModel> {
        self.inner.model()
    }

    /// Cumulative training statistics, if fitted.
    pub fn summary(&self) -> Option<&FitSummary> {
        self.inner.summary()
    }

    /// Consume the estimator, returning the trained model.
    pub fn into_model(self) -> Result<AnyModel> {
        self.inner.into_model()
    }
}

impl Estimator for PegasosEstimator {
    type Data = Dataset;

    fn fit(&mut self, data: &Dataset) -> Result<()> {
        self.inner.fit(data)
    }

    fn partial_fit(&mut self, data: &Dataset) -> Result<()> {
        self.inner.partial_fit(data)
    }

    fn decision_function(&self, x: &[f32]) -> Result<Vec<f64>> {
        self.inner.decision_function(x)
    }

    fn predict(&self, x: &[f32]) -> Result<f32> {
        self.inner.predict(x)
    }

    fn dim(&self) -> Option<usize> {
        self.inner.dim()
    }
}

/// Train an unbudgeted kernel SVM with Pegasos SGD (legacy shim over
/// [`PegasosEstimator`]).
pub fn train_pegasos(train: &Dataset, opts: &PegasosOptions) -> PegasosReport {
    assert!(opts.lambda > 0.0);
    let run = RunConfig::new().passes(opts.passes).seed(opts.seed);
    let mut est = PegasosEstimator::new(KernelSpec::gaussian(opts.gamma), opts.lambda, run)
        .expect("invalid PegasosOptions");
    est.fit(train).expect("Pegasos training failed");
    let summary = est.summary().expect("fitted").clone();
    let model = est
        .into_model()
        .and_then(AnyModel::into_gaussian)
        .context("gaussian pegasos run")
        .expect("gaussian pegasos run");
    PegasosReport {
        model,
        steps: summary.steps,
        sv_inserts: summary.sv_inserts,
        wall_seconds: summary.wall_seconds,
        profiler: summary.profiler,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;

    #[test]
    fn learns_two_moons_unbudgeted() {
        let ds = two_moons(400, 0.12, 9);
        let opts = PegasosOptions {
            lambda: 1.0 / (10.0 * ds.len() as f64),
            gamma: 2.0,
            passes: 5,
            seed: 3,
        };
        let report = train_pegasos(&ds, &opts);
        let acc = report.model.accuracy(&ds);
        assert!(acc > 0.93, "accuracy {acc}");
        // Unbudgeted: the model grows with margin violations, unchecked.
        assert!(report.model.num_sv() > 20, "num_sv={}", report.model.num_sv());
        assert!(report.model.num_sv() as u64 == report.sv_inserts);
        assert_eq!(report.steps, 5 * 400);
    }

    #[test]
    fn model_growth_tracks_margin_violations() {
        let ds = two_moons(300, 0.2, 4);
        let opts = PegasosOptions {
            lambda: 1.0 / (10.0 * ds.len() as f64),
            gamma: 2.0,
            passes: 1,
            seed: 0,
        };
        let report = train_pegasos(&ds, &opts);
        assert_eq!(report.model.num_sv() as u64, report.sv_inserts);
    }

    #[test]
    fn estimator_surface_supports_linear_kernel_streaming() {
        let mut ds = Dataset::empty("sep", 2);
        let mut rng = crate::util::rng::Rng::new(2);
        for _ in 0..100 {
            ds.push_row(&[rng.normal() as f32 * 0.2 - 1.5, rng.normal() as f32], 1.0);
            ds.push_row(&[rng.normal() as f32 * 0.2 + 1.5, rng.normal() as f32], -1.0);
        }
        let lambda = 1.0 / (10.0 * ds.len() as f64);
        let mut est =
            PegasosEstimator::new(KernelSpec::linear(), lambda, RunConfig::new()).unwrap();
        est.partial_fit(&ds).unwrap();
        est.partial_fit(&ds).unwrap();
        assert_eq!(est.summary().unwrap().steps, 2 * 200);
        let preds = est.predict_batch(ds.features()).unwrap();
        let acc = crate::metrics::accuracy(&preds, ds.labels());
        assert!(acc > 0.9, "linear pegasos accuracy {acc}");
    }

    #[test]
    fn rejects_bad_lambda() {
        assert!(PegasosEstimator::new(KernelSpec::gaussian(1.0), 0.0, RunConfig::new()).is_err());
    }
}

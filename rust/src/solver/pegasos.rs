//! Unbudgeted kernelized Pegasos (Shalev-Shwartz et al. 2011) — the
//! baseline BSGD degenerates to when the budget never binds. Model size
//! grows with the number of margin violations (linear in n, Steinwart
//! 2003), which is exactly the scaling problem budgets address.

use std::time::Instant;

use crate::data::Dataset;
use crate::kernel::Gaussian;
use crate::metrics::{Section, SectionProfiler};
use crate::model::BudgetModel;
use crate::util::rng::Rng;

use super::schedule::LearningRate;

/// Options for an unbudgeted Pegasos run.
#[derive(Debug, Clone)]
pub struct PegasosOptions {
    pub lambda: f64,
    pub gamma: f64,
    pub passes: usize,
    pub seed: u64,
}

/// Report of a Pegasos run.
#[derive(Debug, Clone)]
pub struct PegasosReport {
    pub model: BudgetModel,
    pub steps: u64,
    pub sv_inserts: u64,
    pub wall_seconds: f64,
    pub profiler: SectionProfiler,
}

/// Train an unbudgeted kernel SVM with Pegasos SGD.
pub fn train_pegasos(train: &Dataset, opts: &PegasosOptions) -> PegasosReport {
    assert!(opts.lambda > 0.0);
    let n = train.len();
    let kernel = Gaussian::new(opts.gamma);
    let lr = LearningRate::PegasosInvT { lambda: opts.lambda };
    let mut model = BudgetModel::new(train.dim(), kernel, n.min(4096));
    let mut prof = SectionProfiler::new();
    let mut rng = Rng::new(opts.seed);
    let norms: Vec<f32> = (0..n).map(|i| crate::kernel::norm2(train.row(i))).collect();

    let mut steps = 0u64;
    let mut sv_inserts = 0u64;
    let mut order: Vec<usize> = (0..n).collect();
    let wall = Instant::now();
    for _ in 0..opts.passes {
        rng.shuffle(&mut order);
        for &i in &order {
            steps += 1;
            let t0 = Instant::now();
            let y = train.label(i) as f64;
            let margin = y * model.decision_with_norm(train.row(i), norms[i]);
            model.rescale(lr.shrink(steps, opts.lambda));
            if margin < 1.0 {
                model.push(train.row(i), lr.eta(steps) * y);
                sv_inserts += 1;
            }
            prof.add(Section::SgdStep, t0.elapsed());
        }
    }
    PegasosReport {
        model,
        steps,
        sv_inserts,
        wall_seconds: wall.elapsed().as_secs_f64(),
        profiler: prof,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;

    #[test]
    fn learns_two_moons_unbudgeted() {
        let ds = two_moons(400, 0.12, 9);
        let opts = PegasosOptions {
            lambda: 1.0 / (10.0 * ds.len() as f64),
            gamma: 2.0,
            passes: 5,
            seed: 3,
        };
        let report = train_pegasos(&ds, &opts);
        let acc = report.model.accuracy(&ds);
        assert!(acc > 0.93, "accuracy {acc}");
        // Unbudgeted: the model grows with margin violations, unchecked.
        assert!(report.model.num_sv() > 20, "num_sv={}", report.model.num_sv());
        assert!(report.model.num_sv() as u64 == report.sv_inserts);
        assert_eq!(report.steps, 5 * 400);
    }

    #[test]
    fn model_growth_tracks_margin_violations() {
        let ds = two_moons(300, 0.2, 4);
        let opts = PegasosOptions {
            lambda: 1.0 / (10.0 * ds.len() as f64),
            gamma: 2.0,
            passes: 1,
            seed: 0,
        };
        let report = train_pegasos(&ds, &opts);
        assert_eq!(report.model.num_sv() as u64, report.sv_inserts);
    }
}

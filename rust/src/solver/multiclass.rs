//! Multi-class budgeted SVM via one-vs-rest BSGD.
//!
//! The paper's Section 2 notes that other loss functions / reductions
//! "allow to generalize SVMs to other tasks like multi-class
//! classification"; this module provides the standard one-vs-rest
//! reduction: K independent budgeted binary machines, each trained with the
//! same merge-solver machinery (so the lookup speed-up applies K-fold), and
//! prediction by maximal decision value.
//!
//! [`OneVsRestEstimator`] is the [`Estimator`]-surface implementation —
//! kernel-generic and streaming-capable like its binary machines; all K
//! machines share one process-wide `Arc<LookupTable>` per grid resolution
//! (see [`crate::budget::lookup::shared`]), so the 400×400 table is built
//! once, not K times. [`train_multiclass`] / [`MulticlassModel`] remain as
//! the legacy Gaussian shim.

use anyhow::{ensure, Context, Result};

use crate::data::Dataset;
use crate::model::{AnyModel, BudgetModel};

use super::api::{Estimator, RunConfig, SvmConfig};
use super::bsgd::{BsgdEstimator, BsgdOptions};

/// Rows with integer class labels in `0..k`.
#[derive(Debug, Clone)]
pub struct MulticlassDataset {
    x: Vec<f32>,
    y: Vec<usize>,
    n: usize,
    d: usize,
    k: usize,
}

impl MulticlassDataset {
    pub fn new(x: Vec<f32>, y: Vec<usize>, d: usize) -> Result<Self> {
        ensure!(d > 0, "dimension must be positive");
        ensure!(x.len() % d == 0, "feature buffer not a multiple of d");
        let n = x.len() / d;
        ensure!(y.len() == n, "label count mismatch");
        let k = y.iter().copied().max().map(|m| m + 1).unwrap_or(0);
        ensure!(k >= 2, "need at least two classes");
        Ok(MulticlassDataset { x, y, n, d, k })
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn num_classes(&self) -> usize {
        self.k
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    pub fn label(&self, i: usize) -> usize {
        self.y[i]
    }

    /// The binary one-vs-rest view for class `c` (+1 = class c).
    fn binary_view(&self, c: usize) -> Dataset {
        let labels: Vec<f32> =
            self.y.iter().map(|&yi| if yi == c { 1.0 } else { -1.0 }).collect();
        Dataset::new(format!("ovr-{c}"), self.x.clone(), labels, self.d)
    }
}

/// Per-class seed derivation (kept identical to the historical
/// `train_multiclass` convention so legacy runs stay reproducible).
fn class_seed(base: u64, c: usize) -> u64 {
    base ^ (0xC1A55 + c as u64)
}

/// One-vs-rest reduction behind the unified [`Estimator`] surface:
/// K budgeted binary machines ([`BsgdEstimator`]), prediction by maximal
/// decision value. `Data` is [`MulticlassDataset`] (class-index labels);
/// inference still takes plain feature rows, returning the per-class score
/// vector from `decision_function` and the argmax class from `predict`.
pub struct OneVsRestEstimator {
    config: SvmConfig,
    run: RunConfig,
    machines: Vec<BsgdEstimator>,
}

impl OneVsRestEstimator {
    /// Validate the configuration pair and build an unfitted estimator.
    /// The number of classes is learned from the first `fit`/`partial_fit`
    /// batch.
    pub fn new(config: SvmConfig, run: RunConfig) -> Result<Self> {
        // Fail fast on bad configs (each machine re-validates on build).
        config.validate()?;
        run.validate()?;
        ensure!(!run.audit, "audit instrumentation is a binary-trainer feature");
        Ok(OneVsRestEstimator { config, run, machines: Vec::new() })
    }

    fn build_machines(&mut self, k: usize) -> Result<()> {
        self.machines = (0..k)
            .map(|c| {
                let mut run = self.run.clone();
                run.seed = class_seed(self.run.seed, c);
                BsgdEstimator::new(self.config.clone(), run)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }

    /// Number of classes (0 before the first fit).
    pub fn num_classes(&self) -> usize {
        self.machines.len()
    }

    /// The per-class binary machine.
    pub fn machine(&self, c: usize) -> Option<&BsgdEstimator> {
        self.machines.get(c)
    }

    /// Total support vectors across all machines (≤ K·B).
    pub fn total_sv(&self) -> usize {
        self.machines.iter().filter_map(|m| m.model()).map(|m| m.num_sv()).sum()
    }

    /// Classification accuracy on a multiclass dataset.
    pub fn accuracy(&self, ds: &MulticlassDataset) -> Result<f64> {
        if ds.is_empty() {
            return Ok(0.0);
        }
        let mut correct = 0usize;
        for i in 0..ds.len() {
            if self.predict(ds.row(i))? as usize == ds.label(i) {
                correct += 1;
            }
        }
        Ok(correct as f64 / ds.len() as f64)
    }

    /// Consume the estimator, returning the legacy Gaussian ensemble
    /// (errors for non-Gaussian kernels).
    pub fn into_multiclass_model(self) -> Result<MulticlassModel> {
        ensure!(!self.machines.is_empty(), "estimator is not fitted");
        let machines = self
            .machines
            .into_iter()
            .map(|m| m.into_model().and_then(AnyModel::into_gaussian))
            .collect::<Result<Vec<_>>>()?;
        Ok(MulticlassModel { machines })
    }

    fn ingest(&mut self, ds: &MulticlassDataset, reset: bool) -> Result<()> {
        ensure!(!ds.is_empty(), "cannot train on an empty dataset");
        if reset || self.machines.is_empty() {
            self.build_machines(ds.num_classes())?;
        }
        ensure!(
            ds.num_classes() <= self.machines.len(),
            "batch contains class {} but the estimator was initialized with {} classes",
            ds.num_classes() - 1,
            self.machines.len()
        );
        for (c, machine) in self.machines.iter_mut().enumerate() {
            let view = ds.binary_view(c);
            if reset {
                machine.fit(&view)?;
            } else {
                machine.partial_fit(&view)?;
            }
        }
        Ok(())
    }
}

impl Estimator for OneVsRestEstimator {
    type Data = MulticlassDataset;

    fn fit(&mut self, data: &MulticlassDataset) -> Result<()> {
        self.ingest(data, true)
    }

    fn partial_fit(&mut self, data: &MulticlassDataset) -> Result<()> {
        self.ingest(data, false)
    }

    /// Per-class decision values (length = number of classes).
    fn decision_function(&self, x: &[f32]) -> Result<Vec<f64>> {
        ensure!(!self.machines.is_empty(), "estimator is not fitted");
        self.machines.iter().map(|m| m.decision_function(x).map(|v| v[0])).collect()
    }

    /// Predicted class index (as `f32`) = argmax of the decision values.
    fn predict(&self, x: &[f32]) -> Result<f32> {
        let scores = self.decision_function(x)?;
        let best = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(c, _)| c)
            .context("no classes")?;
        Ok(best as f32)
    }

    fn dim(&self) -> Option<usize> {
        self.machines.first().and_then(|m| m.dim())
    }
}

/// A trained one-vs-rest ensemble (legacy Gaussian surface).
pub struct MulticlassModel {
    machines: Vec<BudgetModel>,
}

impl MulticlassModel {
    /// Predicted class = argmax of the per-class decision values.
    pub fn predict(&self, x: &[f32]) -> usize {
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for (c, m) in self.machines.iter().enumerate() {
            let v = m.decision(x);
            if v > best_v {
                best_v = v;
                best = c;
            }
        }
        best
    }

    /// Per-class decision values.
    pub fn decision(&self, x: &[f32]) -> Vec<f64> {
        self.machines.iter().map(|m| m.decision(x)).collect()
    }

    pub fn num_classes(&self) -> usize {
        self.machines.len()
    }

    /// Total support vectors across all machines (≤ K·B).
    pub fn total_sv(&self) -> usize {
        self.machines.iter().map(|m| m.num_sv()).sum()
    }

    pub fn machine(&self, c: usize) -> &BudgetModel {
        &self.machines[c]
    }

    pub fn accuracy(&self, ds: &MulticlassDataset) -> f64 {
        if ds.is_empty() {
            return 0.0;
        }
        let correct =
            (0..ds.len()).filter(|&i| self.predict(ds.row(i)) == ds.label(i)).count();
        correct as f64 / ds.len() as f64
    }
}

/// Train K one-vs-rest budgeted machines (legacy Gaussian shim over
/// [`OneVsRestEstimator`]). `opts.budget` is the per-machine budget.
pub fn train_multiclass(ds: &MulticlassDataset, opts: &BsgdOptions) -> MulticlassModel {
    opts.validate().expect("invalid BsgdOptions");
    let (config, run) = opts.split();
    let mut est = OneVsRestEstimator::new(config, run).expect("validated options");
    est.fit(ds).expect("one-vs-rest training failed");
    est.into_multiclass_model().expect("gaussian ensemble")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::Strategy;
    use crate::kernel::KernelSpec;
    use crate::util::rng::Rng;

    /// Three well-separated 2-D Gaussian blobs.
    fn three_blobs(n: usize, seed: u64) -> MulticlassDataset {
        let mut rng = Rng::new(seed);
        let centers = [(0.0f64, 0.0f64), (4.0, 0.0), (2.0, 3.5)];
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % 3;
            x.push((centers[c].0 + 0.5 * rng.normal()) as f32);
            x.push((centers[c].1 + 0.5 * rng.normal()) as f32);
            y.push(c);
        }
        MulticlassDataset::new(x, y, 2).unwrap()
    }

    #[test]
    fn learns_three_blobs_under_budget() {
        let train = three_blobs(600, 1);
        let test = three_blobs(300, 2);
        let mut opts = BsgdOptions::with_c(20, 10.0, 1.0, train.len());
        opts.passes = 4;
        let model = train_multiclass(&train, &opts);
        assert_eq!(model.num_classes(), 3);
        assert!(model.total_sv() <= 3 * 20);
        let acc = model.accuracy(&test);
        assert!(acc > 0.95, "multiclass accuracy {acc}");
    }

    #[test]
    fn decision_vector_has_k_entries_and_argmax_matches_predict() {
        let train = three_blobs(300, 3);
        let mut opts = BsgdOptions::with_c(15, 10.0, 1.0, train.len());
        opts.passes = 3;
        let model = train_multiclass(&train, &opts);
        for i in 0..20 {
            let d = model.decision(train.row(i));
            assert_eq!(d.len(), 3);
            let argmax = d
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(argmax, model.predict(train.row(i)));
        }
    }

    #[test]
    fn rejects_degenerate_inputs() {
        // Single class is not a classification problem.
        assert!(MulticlassDataset::new(vec![1.0, 2.0], vec![0], 2).is_err());
        assert!(MulticlassDataset::new(vec![1.0, 2.0], vec![0, 1], 1).is_ok());
        assert!(MulticlassDataset::new(vec![1.0], vec![0, 1], 2).is_err());
    }

    #[test]
    fn per_class_budgets_hold_individually() {
        let train = three_blobs(400, 7);
        let mut opts = BsgdOptions::with_c(8, 10.0, 1.0, train.len());
        opts.passes = 2;
        let model = train_multiclass(&train, &opts);
        for c in 0..3 {
            assert!(model.machine(c).num_sv() <= 8, "class {c}");
        }
    }

    #[test]
    fn estimator_surface_matches_legacy_ensemble() {
        let train = three_blobs(300, 5);
        let mut opts = BsgdOptions::with_c(12, 10.0, 1.0, train.len());
        opts.passes = 2;
        let legacy = train_multiclass(&train, &opts);

        let (config, run) = opts.split();
        let mut est = OneVsRestEstimator::new(config, run).unwrap();
        est.fit(&train).unwrap();
        assert_eq!(est.num_classes(), 3);
        for i in (0..train.len()).step_by(29) {
            let scores = est.decision_function(train.row(i)).unwrap();
            let legacy_scores = legacy.decision(train.row(i));
            for (a, b) in scores.iter().zip(&legacy_scores) {
                assert!((a - b).abs() < 1e-12, "row {i}");
            }
            assert_eq!(est.predict(train.row(i)).unwrap() as usize, legacy.predict(train.row(i)));
        }
    }

    #[test]
    fn streaming_partial_fit_equals_unshuffled_fit() {
        let train = three_blobs(240, 9);
        let config = SvmConfig::new()
            .kernel(KernelSpec::gaussian(1.0))
            .budget(10)
            .c(10.0, train.len());
        let run = RunConfig::new().passes(1).shuffle(false).seed(3);

        let mut fitted = OneVsRestEstimator::new(config.clone(), run.clone()).unwrap();
        fitted.fit(&train).unwrap();
        let mut streamed = OneVsRestEstimator::new(config, run).unwrap();
        streamed.partial_fit(&train).unwrap();

        for i in (0..train.len()).step_by(13) {
            let a = fitted.decision_function(train.row(i)).unwrap();
            let b = streamed.decision_function(train.row(i)).unwrap();
            for (va, vb) in a.iter().zip(&b) {
                assert!((va - vb).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn non_gaussian_one_vs_rest_trains_with_removal() {
        let train = three_blobs(300, 21);
        let config = SvmConfig::new()
            .kernel(KernelSpec::polynomial(2, 1.0))
            .budget(15)
            .strategy(Strategy::Removal)
            .c(10.0, train.len());
        let mut est = OneVsRestEstimator::new(config, RunConfig::new().passes(3)).unwrap();
        est.fit(&train).unwrap();
        let acc = est.accuracy(&train).unwrap();
        assert!(acc > 0.85, "polynomial OvR accuracy {acc}");
        assert!(est.total_sv() <= 3 * 15);
    }

    #[test]
    fn partial_fit_rejects_unseen_classes() {
        let train = three_blobs(120, 2);
        let config =
            SvmConfig::new().kernel(KernelSpec::gaussian(1.0)).budget(8).c(10.0, train.len());
        let mut est = OneVsRestEstimator::new(config, RunConfig::new()).unwrap();
        // Initialize with only classes {0, 1}.
        let two_class = MulticlassDataset::new(
            vec![0.0, 0.0, 4.0, 0.0, 0.1, 0.1, 4.1, 0.1],
            vec![0, 1, 0, 1],
            2,
        )
        .unwrap();
        est.partial_fit(&two_class).unwrap();
        assert_eq!(est.num_classes(), 2);
        // A batch containing class 2 must be rejected, not silently dropped.
        assert!(est.partial_fit(&train).is_err());
    }
}

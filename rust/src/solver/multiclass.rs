//! Multi-class budgeted SVM via one-vs-rest BSGD.
//!
//! The paper's Section 2 notes that other loss functions / reductions
//! "allow to generalize SVMs to other tasks like multi-class
//! classification"; this module provides the standard one-vs-rest
//! reduction: K independent budgeted binary machines, each trained with the
//! same merge-solver machinery (so the lookup speed-up applies K-fold), and
//! prediction by maximal decision value.

use anyhow::{ensure, Result};

use crate::data::Dataset;
use crate::model::BudgetModel;
use crate::solver::{train_bsgd, BsgdOptions};

/// Rows with integer class labels in `0..k`.
#[derive(Debug, Clone)]
pub struct MulticlassDataset {
    x: Vec<f32>,
    y: Vec<usize>,
    n: usize,
    d: usize,
    k: usize,
}

impl MulticlassDataset {
    pub fn new(x: Vec<f32>, y: Vec<usize>, d: usize) -> Result<Self> {
        ensure!(d > 0, "dimension must be positive");
        ensure!(x.len() % d == 0, "feature buffer not a multiple of d");
        let n = x.len() / d;
        ensure!(y.len() == n, "label count mismatch");
        let k = y.iter().copied().max().map(|m| m + 1).unwrap_or(0);
        ensure!(k >= 2, "need at least two classes");
        Ok(MulticlassDataset { x, y, n, d, k })
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn num_classes(&self) -> usize {
        self.k
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    pub fn label(&self, i: usize) -> usize {
        self.y[i]
    }

    /// The binary one-vs-rest view for class `c` (+1 = class c).
    fn binary_view(&self, c: usize) -> Dataset {
        let labels: Vec<f32> =
            self.y.iter().map(|&yi| if yi == c { 1.0 } else { -1.0 }).collect();
        Dataset::new(format!("ovr-{c}"), self.x.clone(), labels, self.d)
    }
}

/// A trained one-vs-rest ensemble.
pub struct MulticlassModel {
    machines: Vec<BudgetModel>,
}

impl MulticlassModel {
    /// Predicted class = argmax of the per-class decision values.
    pub fn predict(&self, x: &[f32]) -> usize {
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for (c, m) in self.machines.iter().enumerate() {
            let v = m.decision(x);
            if v > best_v {
                best_v = v;
                best = c;
            }
        }
        best
    }

    /// Per-class decision values.
    pub fn decision(&self, x: &[f32]) -> Vec<f64> {
        self.machines.iter().map(|m| m.decision(x)).collect()
    }

    pub fn num_classes(&self) -> usize {
        self.machines.len()
    }

    /// Total support vectors across all machines (≤ K·B).
    pub fn total_sv(&self) -> usize {
        self.machines.iter().map(|m| m.num_sv()).sum()
    }

    pub fn machine(&self, c: usize) -> &BudgetModel {
        &self.machines[c]
    }

    pub fn accuracy(&self, ds: &MulticlassDataset) -> f64 {
        if ds.is_empty() {
            return 0.0;
        }
        let correct =
            (0..ds.len()).filter(|&i| self.predict(ds.row(i)) == ds.label(i)).count();
        correct as f64 / ds.len() as f64
    }
}

/// Train K one-vs-rest budgeted machines. `opts.budget` is the per-machine
/// budget; the machines are independent, so the experiment runner can
/// parallelize over classes if desired (here: sequential, deterministic).
pub fn train_multiclass(ds: &MulticlassDataset, opts: &BsgdOptions) -> MulticlassModel {
    let machines = (0..ds.num_classes())
        .map(|c| {
            let view = ds.binary_view(c);
            let mut class_opts = opts.clone();
            class_opts.seed = opts.seed ^ (0xC1A55 + c as u64);
            train_bsgd(&view, &class_opts).model
        })
        .collect();
    MulticlassModel { machines }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Three well-separated 2-D Gaussian blobs.
    fn three_blobs(n: usize, seed: u64) -> MulticlassDataset {
        let mut rng = Rng::new(seed);
        let centers = [(0.0f64, 0.0f64), (4.0, 0.0), (2.0, 3.5)];
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % 3;
            x.push((centers[c].0 + 0.5 * rng.normal()) as f32);
            x.push((centers[c].1 + 0.5 * rng.normal()) as f32);
            y.push(c);
        }
        MulticlassDataset::new(x, y, 2).unwrap()
    }

    #[test]
    fn learns_three_blobs_under_budget() {
        let train = three_blobs(600, 1);
        let test = three_blobs(300, 2);
        let mut opts = BsgdOptions::with_c(20, 10.0, 1.0, train.len());
        opts.passes = 4;
        let model = train_multiclass(&train, &opts);
        assert_eq!(model.num_classes(), 3);
        assert!(model.total_sv() <= 3 * 20);
        let acc = model.accuracy(&test);
        assert!(acc > 0.95, "multiclass accuracy {acc}");
    }

    #[test]
    fn decision_vector_has_k_entries_and_argmax_matches_predict() {
        let train = three_blobs(300, 3);
        let mut opts = BsgdOptions::with_c(15, 10.0, 1.0, train.len());
        opts.passes = 3;
        let model = train_multiclass(&train, &opts);
        for i in 0..20 {
            let d = model.decision(train.row(i));
            assert_eq!(d.len(), 3);
            let argmax = d
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(argmax, model.predict(train.row(i)));
        }
    }

    #[test]
    fn rejects_degenerate_inputs() {
        // Single class is not a classification problem.
        assert!(MulticlassDataset::new(vec![1.0, 2.0], vec![0], 2).is_err());
        assert!(MulticlassDataset::new(vec![1.0, 2.0], vec![0, 1], 1).is_ok());
        assert!(MulticlassDataset::new(vec![1.0], vec![0, 1], 2).is_err());
    }

    #[test]
    fn per_class_budgets_hold_individually() {
        let train = three_blobs(400, 7);
        let mut opts = BsgdOptions::with_c(8, 10.0, 1.0, train.len());
        opts.passes = 2;
        let model = train_multiclass(&train, &opts);
        for c in 0..3 {
            assert!(model.machine(c).num_sv() <= 8, "class {c}");
        }
    }
}

//! Multi-class budgeted SVM via one-vs-rest over the binary solver family.
//!
//! The paper's Section 2 notes that other loss functions / reductions
//! "allow to generalize SVMs to other tasks like multi-class
//! classification"; this module provides the standard one-vs-rest
//! reduction: K independent budgeted binary machines — any member of the
//! [`super::api::SolverSpec`] family (primal BSGD by default, dual BDCA
//! via [`OneVsRestEstimator::with_solver`]) — each trained with the same
//! budget-maintenance machinery (so the lookup speed-up applies K-fold),
//! and prediction by maximal decision value.
//!
//! [`OneVsRestEstimator`] is the [`Estimator`]-surface implementation —
//! kernel-generic and streaming-capable like its binary machines; all K
//! machines share one process-wide `Arc<LookupTable>` per grid resolution
//! (see [`crate::budget::lookup::shared`]), so the 400×400 table is built
//! once, not K times. [`train_multiclass`] / [`MulticlassModel`] remain as
//! the legacy Gaussian shim.
//!
//! Training is embarrassingly parallel across classes: `fit`/`partial_fit`
//! run the K machines on the shared [`crate::util::parallel`] pool
//! (`RunConfig::threads`, 0 = all cores). Each machine owns an
//! independent per-class RNG stream derived from the base seed, so the
//! result is *bit-identical* for every thread count — `threads = N`
//! reproduces the `threads = 1` serial output exactly. Batch prediction
//! and accuracy are likewise chunked across rows, with each row's norm
//! computed once and shared by all K machines.

use anyhow::{ensure, Context, Result};

use crate::data::Dataset;
use crate::kernel::norm2;
use crate::model::{AnyModel, BudgetModel};
use crate::util::parallel;

use super::api::{AnyEstimator, Estimator, RunConfig, SolverSpec, SvmConfig};
use super::bsgd::BsgdOptions;

/// Rows with integer class labels in `0..k`.
#[derive(Debug, Clone)]
pub struct MulticlassDataset {
    x: Vec<f32>,
    y: Vec<usize>,
    /// Row norms, computed once and shared by every per-class binary view.
    row_norms: Vec<f32>,
    n: usize,
    d: usize,
    k: usize,
}

impl MulticlassDataset {
    pub fn new(x: Vec<f32>, y: Vec<usize>, d: usize) -> Result<Self> {
        ensure!(d > 0, "dimension must be positive");
        ensure!(x.len() % d == 0, "feature buffer not a multiple of d");
        let n = x.len() / d;
        ensure!(y.len() == n, "label count mismatch");
        let k = y.iter().copied().max().map(|m| m + 1).unwrap_or(0);
        ensure!(k >= 2, "need at least two classes");
        let row_norms = (0..n).map(|i| norm2(&x[i * d..(i + 1) * d])).collect();
        Ok(MulticlassDataset { x, y, row_norms, n, d, k })
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn num_classes(&self) -> usize {
        self.k
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    pub fn label(&self, i: usize) -> usize {
        self.y[i]
    }

    /// The binary one-vs-rest view for class `c` (+1 = class c). The
    /// feature matrix is cloned (the binary `Dataset` owns its rows) but
    /// the row norms are reused from this dataset instead of being
    /// recomputed per class. During a parallel fit at most `threads` such
    /// views are alive at once — each job builds its view on entry and
    /// drops it with the job.
    fn binary_view(&self, c: usize) -> Dataset {
        let labels: Vec<f32> =
            self.y.iter().map(|&yi| if yi == c { 1.0 } else { -1.0 }).collect();
        Dataset::with_norms(
            format!("ovr-{c}"),
            self.x.clone(),
            labels,
            self.d,
            self.row_norms.clone(),
        )
    }
}

/// Per-class seed derivation (kept identical to the historical
/// `train_multiclass` convention so legacy runs stay reproducible).
fn class_seed(base: u64, c: usize) -> u64 {
    base ^ (0xC1A55 + c as u64)
}

/// One-vs-rest reduction behind the unified [`Estimator`] surface:
/// K budgeted binary machines of one solver family member
/// ([`AnyEstimator`]; BSGD by default), prediction by maximal decision
/// value. `Data` is [`MulticlassDataset`] (class-index labels); inference
/// still takes plain feature rows, returning the per-class score vector
/// from `decision_function` and the argmax class from `predict`.
pub struct OneVsRestEstimator {
    solver: SolverSpec,
    config: SvmConfig,
    run: RunConfig,
    machines: Vec<AnyEstimator>,
}

impl OneVsRestEstimator {
    /// Validate the configuration pair and build an unfitted estimator on
    /// the default primal (BSGD) machines. The number of classes is
    /// learned from the first `fit`/`partial_fit` batch.
    pub fn new(config: SvmConfig, run: RunConfig) -> Result<Self> {
        Self::with_solver(SolverSpec::Bsgd, config, run)
    }

    /// [`OneVsRestEstimator::new`] with an explicit solver family member
    /// for the K binary machines (`--solver bsgd|bdca`).
    pub fn with_solver(solver: SolverSpec, config: SvmConfig, run: RunConfig) -> Result<Self> {
        // Fail fast on bad configs (each machine re-validates on build).
        config.validate()?;
        run.validate()?;
        ensure!(!run.audit, "audit instrumentation is a binary-trainer feature");
        Ok(OneVsRestEstimator { solver, config, run, machines: Vec::new() })
    }

    fn build_machines(&mut self, k: usize) -> Result<()> {
        self.machines = (0..k)
            .map(|c| {
                let mut run = self.run.clone();
                run.seed = class_seed(self.run.seed, c);
                // The ensemble owns the worker pool; machines stay serial
                // inside so K-way class parallelism never oversubscribes.
                run.threads = 1;
                AnyEstimator::new(self.solver, self.config.clone(), run)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(())
    }

    /// The solver family member the binary machines use.
    pub fn solver(&self) -> SolverSpec {
        self.solver
    }

    /// Number of classes (0 before the first fit).
    pub fn num_classes(&self) -> usize {
        self.machines.len()
    }

    /// The per-class binary machine.
    pub fn machine(&self, c: usize) -> Option<&AnyEstimator> {
        self.machines.get(c)
    }

    /// Total support vectors across all machines (≤ K·B).
    pub fn total_sv(&self) -> usize {
        self.machines.iter().filter_map(|m| m.model()).map(|m| m.num_sv()).sum()
    }

    /// Borrow the fitted per-class models (errors before the first fit).
    fn models(&self) -> Result<Vec<&AnyModel>> {
        ensure!(!self.machines.is_empty(), "estimator is not fitted");
        self.machines.iter().map(|m| m.model().context("machine is not fitted")).collect()
    }

    /// Classification accuracy on a multiclass dataset, evaluated in
    /// row-chunks on the shared pool (`RunConfig::threads`). Each row's
    /// norm is computed once and reused by all K machines; the correct
    /// count reduces over integers, so the result is identical for every
    /// thread count.
    pub fn accuracy(&self, ds: &MulticlassDataset) -> Result<f64> {
        if ds.is_empty() {
            return Ok(0.0);
        }
        let models = self.models()?;
        ensure!(ds.dim() == models[0].dim(), "dataset dimension mismatch");
        let correct: usize = parallel::map_ranges(ds.len(), self.run.threads, |r| {
            let mut correct = 0usize;
            for i in r {
                if argmax_class_with_norm(&models, ds.row(i), ds.row_norms[i]) == ds.label(i) {
                    correct += 1;
                }
            }
            correct
        })
        .into_iter()
        .sum();
        Ok(correct as f64 / ds.len() as f64)
    }

    /// Consume the estimator, returning the legacy Gaussian ensemble
    /// (errors for non-Gaussian kernels).
    pub fn into_multiclass_model(self) -> Result<MulticlassModel> {
        ensure!(!self.machines.is_empty(), "estimator is not fitted");
        let machines = self
            .machines
            .into_iter()
            .map(|m| m.into_model().and_then(AnyModel::into_gaussian))
            .collect::<Result<Vec<_>>>()?;
        Ok(MulticlassModel { machines })
    }

    fn ingest(&mut self, ds: &MulticlassDataset, reset: bool) -> Result<()> {
        ensure!(!ds.is_empty(), "cannot train on an empty dataset");
        if reset || self.machines.is_empty() {
            self.build_machines(ds.num_classes())?;
        }
        ensure!(
            ds.num_classes() <= self.machines.len(),
            "batch contains class {} but the estimator was initialized with {} classes",
            ds.num_classes() - 1,
            self.machines.len()
        );
        // One job per class on the shared pool. The dataset is shared
        // read-only; each job builds its own ±1 view and drives its own
        // machine (independent per-class seed), so any thread count —
        // including the serial `threads = 1` — produces bit-identical
        // machines.
        let threads = parallel::resolve_threads(self.run.threads).min(self.machines.len());
        if threads <= 1 {
            for (c, machine) in self.machines.iter_mut().enumerate() {
                let view = ds.binary_view(c);
                if reset {
                    machine.fit(&view)?;
                } else {
                    machine.partial_fit(&view)?;
                }
            }
        } else {
            let jobs: Vec<_> = self
                .machines
                .iter_mut()
                .enumerate()
                .map(|(c, machine)| {
                    move || -> Result<()> {
                        let view = ds.binary_view(c);
                        if reset {
                            machine.fit(&view)
                        } else {
                            machine.partial_fit(&view)
                        }
                    }
                })
                .collect();
            for outcome in parallel::run_jobs(jobs, threads) {
                outcome?;
            }
        }
        Ok(())
    }
}

/// Argmax class over the per-class decision values, computing the row norm
/// once for all machines. Ties resolve to the highest class index, exactly
/// like the `Iterator::max_by` the per-row `predict` path uses.
fn argmax_class(models: &[&AnyModel], x: &[f32]) -> usize {
    argmax_class_with_norm(models, x, norm2(x))
}

/// [`argmax_class`] with a caller-supplied (cached) row norm.
fn argmax_class_with_norm(models: &[&AnyModel], x: &[f32], xn: f32) -> usize {
    let mut best = 0usize;
    let mut best_v = f64::NEG_INFINITY;
    for (c, m) in models.iter().enumerate() {
        let v = m.decision_with_norm(x, xn);
        if v >= best_v {
            best_v = v;
            best = c;
        }
    }
    best
}

impl Estimator for OneVsRestEstimator {
    type Data = MulticlassDataset;

    fn fit(&mut self, data: &MulticlassDataset) -> Result<()> {
        self.ingest(data, true)
    }

    fn partial_fit(&mut self, data: &MulticlassDataset) -> Result<()> {
        self.ingest(data, false)
    }

    /// Per-class decision values (length = number of classes). The row
    /// norm is computed once and shared by all K machines.
    fn decision_function(&self, x: &[f32]) -> Result<Vec<f64>> {
        let models = self.models()?;
        let xn = norm2(x);
        models
            .iter()
            .map(|m| {
                ensure!(x.len() == m.dim(), "feature row has wrong dimension");
                Ok(m.decision_with_norm(x, xn))
            })
            .collect()
    }

    /// Predicted class index (as `f32`) = argmax of the decision values.
    fn predict(&self, x: &[f32]) -> Result<f32> {
        let scores = self.decision_function(x)?;
        let best = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(c, _)| c)
            .context("no classes")?;
        Ok(best as f32)
    }

    fn dim(&self) -> Option<usize> {
        self.machines.first().and_then(|m| m.dim())
    }

    /// Chunked parallel batch prediction (`RunConfig::threads` workers):
    /// each row's norm is computed once for all K machines; chunks
    /// concatenate in order, so the output is identical for every thread
    /// count.
    fn predict_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        let models = self.models()?;
        let d = models[0].dim();
        ensure!(
            x.len() % d == 0,
            "batch buffer length {} is not a multiple of the feature dimension {d}",
            x.len()
        );
        Ok(parallel::map_ranges(x.len() / d, self.run.threads, |r| {
            r.map(|i| argmax_class(&models, &x[i * d..(i + 1) * d]) as f32).collect::<Vec<f32>>()
        })
        .into_iter()
        .flatten()
        .collect())
    }
}

/// A trained one-vs-rest ensemble (legacy Gaussian surface).
pub struct MulticlassModel {
    machines: Vec<BudgetModel>,
}

impl MulticlassModel {
    /// Predicted class = argmax of the per-class decision values (the row
    /// norm is computed once for all machines).
    pub fn predict(&self, x: &[f32]) -> usize {
        let xn = norm2(x);
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for (c, m) in self.machines.iter().enumerate() {
            let v = m.decision_with_norm(x, xn);
            if v > best_v {
                best_v = v;
                best = c;
            }
        }
        best
    }

    /// Per-class decision values (one shared norm computation).
    pub fn decision(&self, x: &[f32]) -> Vec<f64> {
        let xn = norm2(x);
        self.machines.iter().map(|m| m.decision_with_norm(x, xn)).collect()
    }

    pub fn num_classes(&self) -> usize {
        self.machines.len()
    }

    /// Total support vectors across all machines (≤ K·B).
    pub fn total_sv(&self) -> usize {
        self.machines.iter().map(|m| m.num_sv()).sum()
    }

    pub fn machine(&self, c: usize) -> &BudgetModel {
        &self.machines[c]
    }

    pub fn accuracy(&self, ds: &MulticlassDataset) -> f64 {
        if ds.is_empty() {
            return 0.0;
        }
        let correct =
            (0..ds.len()).filter(|&i| self.predict(ds.row(i)) == ds.label(i)).count();
        correct as f64 / ds.len() as f64
    }
}

/// Train K one-vs-rest budgeted machines (legacy Gaussian shim over
/// [`OneVsRestEstimator`]). `opts.budget` is the per-machine budget.
pub fn train_multiclass(ds: &MulticlassDataset, opts: &BsgdOptions) -> MulticlassModel {
    opts.validate().expect("invalid BsgdOptions");
    let (config, run) = opts.split();
    let mut est = OneVsRestEstimator::new(config, run).expect("validated options");
    est.fit(ds).expect("one-vs-rest training failed");
    est.into_multiclass_model().expect("gaussian ensemble")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::Strategy;
    use crate::kernel::KernelSpec;
    use crate::util::rng::Rng;

    /// Three well-separated 2-D Gaussian blobs.
    fn three_blobs(n: usize, seed: u64) -> MulticlassDataset {
        let mut rng = Rng::new(seed);
        let centers = [(0.0f64, 0.0f64), (4.0, 0.0), (2.0, 3.5)];
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % 3;
            x.push((centers[c].0 + 0.5 * rng.normal()) as f32);
            x.push((centers[c].1 + 0.5 * rng.normal()) as f32);
            y.push(c);
        }
        MulticlassDataset::new(x, y, 2).unwrap()
    }

    #[test]
    fn learns_three_blobs_under_budget() {
        let train = three_blobs(600, 1);
        let test = three_blobs(300, 2);
        let mut opts = BsgdOptions::with_c(20, 10.0, 1.0, train.len());
        opts.passes = 4;
        let model = train_multiclass(&train, &opts);
        assert_eq!(model.num_classes(), 3);
        assert!(model.total_sv() <= 3 * 20);
        let acc = model.accuracy(&test);
        assert!(acc > 0.95, "multiclass accuracy {acc}");
    }

    #[test]
    fn decision_vector_has_k_entries_and_argmax_matches_predict() {
        let train = three_blobs(300, 3);
        let mut opts = BsgdOptions::with_c(15, 10.0, 1.0, train.len());
        opts.passes = 3;
        let model = train_multiclass(&train, &opts);
        for i in 0..20 {
            let d = model.decision(train.row(i));
            assert_eq!(d.len(), 3);
            let argmax = d
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(argmax, model.predict(train.row(i)));
        }
    }

    #[test]
    fn rejects_degenerate_inputs() {
        // Single class is not a classification problem.
        assert!(MulticlassDataset::new(vec![1.0, 2.0], vec![0], 2).is_err());
        assert!(MulticlassDataset::new(vec![1.0, 2.0], vec![0, 1], 1).is_ok());
        assert!(MulticlassDataset::new(vec![1.0], vec![0, 1], 2).is_err());
    }

    #[test]
    fn per_class_budgets_hold_individually() {
        let train = three_blobs(400, 7);
        let mut opts = BsgdOptions::with_c(8, 10.0, 1.0, train.len());
        opts.passes = 2;
        let model = train_multiclass(&train, &opts);
        for c in 0..3 {
            assert!(model.machine(c).num_sv() <= 8, "class {c}");
        }
    }

    #[test]
    fn estimator_surface_matches_legacy_ensemble() {
        let train = three_blobs(300, 5);
        let mut opts = BsgdOptions::with_c(12, 10.0, 1.0, train.len());
        opts.passes = 2;
        let legacy = train_multiclass(&train, &opts);

        let (config, run) = opts.split();
        let mut est = OneVsRestEstimator::new(config, run).unwrap();
        est.fit(&train).unwrap();
        assert_eq!(est.num_classes(), 3);
        for i in (0..train.len()).step_by(29) {
            let scores = est.decision_function(train.row(i)).unwrap();
            let legacy_scores = legacy.decision(train.row(i));
            for (a, b) in scores.iter().zip(&legacy_scores) {
                assert!((a - b).abs() < 1e-12, "row {i}");
            }
            assert_eq!(est.predict(train.row(i)).unwrap() as usize, legacy.predict(train.row(i)));
        }
    }

    #[test]
    fn streaming_partial_fit_equals_unshuffled_fit() {
        let train = three_blobs(240, 9);
        let config = SvmConfig::new()
            .kernel(KernelSpec::gaussian(1.0))
            .budget(10)
            .c(10.0, train.len());
        let run = RunConfig::new().passes(1).shuffle(false).seed(3);

        let mut fitted = OneVsRestEstimator::new(config.clone(), run.clone()).unwrap();
        fitted.fit(&train).unwrap();
        let mut streamed = OneVsRestEstimator::new(config, run).unwrap();
        streamed.partial_fit(&train).unwrap();

        for i in (0..train.len()).step_by(13) {
            let a = fitted.decision_function(train.row(i)).unwrap();
            let b = streamed.decision_function(train.row(i)).unwrap();
            for (va, vb) in a.iter().zip(&b) {
                assert!((va - vb).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn non_gaussian_one_vs_rest_trains_with_removal() {
        let train = three_blobs(300, 21);
        let config = SvmConfig::new()
            .kernel(KernelSpec::polynomial(2, 1.0))
            .budget(15)
            .strategy(Strategy::Removal)
            .c(10.0, train.len());
        let mut est = OneVsRestEstimator::new(config, RunConfig::new().passes(3)).unwrap();
        est.fit(&train).unwrap();
        let acc = est.accuracy(&train).unwrap();
        assert!(acc > 0.85, "polynomial OvR accuracy {acc}");
        assert!(est.total_sv() <= 3 * 15);
    }

    #[test]
    fn dual_solver_one_vs_rest_learns_and_holds_budgets() {
        let train = three_blobs(450, 11);
        let test = three_blobs(210, 12);
        let config = SvmConfig::new()
            .kernel(KernelSpec::gaussian(1.0))
            .budget(20)
            .c(10.0, train.len());
        let mut est = OneVsRestEstimator::with_solver(
            SolverSpec::Bdca,
            config.clone(),
            RunConfig::new().passes(3).seed(4),
        )
        .unwrap();
        assert_eq!(est.solver(), SolverSpec::Bdca);
        est.fit(&train).unwrap();
        assert_eq!(est.num_classes(), 3);
        assert!(est.total_sv() <= 3 * 20);
        let acc = est.accuracy(&test).unwrap();
        assert!(acc > 0.9, "dual OvR accuracy {acc}");
        // Class parallelism stays bit-identical for dual machines too.
        let mut par = OneVsRestEstimator::with_solver(
            SolverSpec::Bdca,
            config,
            RunConfig::new().passes(3).seed(4).threads(4),
        )
        .unwrap();
        par.fit(&train).unwrap();
        for i in (0..train.len()).step_by(31) {
            let a = est.decision_function(train.row(i)).unwrap();
            let b = par.decision_function(train.row(i)).unwrap();
            for (va, vb) in a.iter().zip(&b) {
                assert_eq!(va.to_bits(), vb.to_bits(), "row {i}");
            }
        }
    }

    #[test]
    fn parallel_fit_is_bit_identical_to_serial() {
        let train = three_blobs(320, 17);
        let test = three_blobs(160, 18);
        let config = SvmConfig::new()
            .kernel(KernelSpec::gaussian(1.0))
            .budget(12)
            .c(10.0, train.len());
        let mut results: Vec<Vec<f64>> = Vec::new();
        let mut accs = Vec::new();
        for threads in [1usize, 4] {
            let run = RunConfig::new().passes(2).seed(5).threads(threads);
            let mut est = OneVsRestEstimator::new(config.clone(), run).unwrap();
            est.fit(&train).unwrap();
            let mut flat = Vec::new();
            for i in (0..train.len()).step_by(7) {
                flat.extend(est.decision_function(train.row(i)).unwrap());
            }
            results.push(flat);
            accs.push(est.accuracy(&test).unwrap());
        }
        assert_eq!(results[0].len(), results[1].len());
        for (a, b) in results[0].iter().zip(&results[1]) {
            assert!(
                a.to_bits() == b.to_bits(),
                "threads=4 must be bit-identical to threads=1: {a} vs {b}"
            );
        }
        assert_eq!(accs[0], accs[1]);
    }

    #[test]
    fn predict_batch_matches_per_row_predict() {
        let train = three_blobs(240, 31);
        let config = SvmConfig::new()
            .kernel(KernelSpec::gaussian(1.0))
            .budget(10)
            .c(10.0, train.len());
        let mut est =
            OneVsRestEstimator::new(config, RunConfig::new().passes(2).threads(3)).unwrap();
        est.fit(&train).unwrap();
        // Flat buffer of all rows.
        let mut flat = Vec::with_capacity(train.len() * 2);
        for i in 0..train.len() {
            flat.extend_from_slice(train.row(i));
        }
        let batch = est.predict_batch(&flat).unwrap();
        assert_eq!(batch.len(), train.len());
        for i in 0..train.len() {
            assert_eq!(batch[i], est.predict(train.row(i)).unwrap(), "row {i}");
        }
    }

    #[test]
    fn partial_fit_rejects_unseen_classes() {
        let train = three_blobs(120, 2);
        let config =
            SvmConfig::new().kernel(KernelSpec::gaussian(1.0)).budget(8).c(10.0, train.len());
        let mut est = OneVsRestEstimator::new(config, RunConfig::new()).unwrap();
        // Initialize with only classes {0, 1}.
        let two_class = MulticlassDataset::new(
            vec![0.0, 0.0, 4.0, 0.0, 0.1, 0.1, 4.1, 0.1],
            vec![0, 1, 0, 1],
            2,
        )
        .unwrap();
        est.partial_fit(&two_class).unwrap();
        assert_eq!(est.num_classes(), 2);
        // A batch containing class 2 must be rejected, not silently dropped.
        assert!(est.partial_fit(&train).is_err());
    }
}

//! BDCA: budgeted dual coordinate ascent on a churn-aware Gram cache.
//!
//! The dual sibling of [`super::bsgd`] (the sister paper of the merging
//! work, arXiv:1806.10182): instead of primal SGD steps, the trainer
//! maintains the C-SVM **dual** variables of the stored support vectors —
//! one box-constrained coefficient `a_j ∈ [0, C]` per SV, carried inside
//! the model as the label-signed effective coefficient `α_j = y_j·a_j` —
//! and improves the dual objective
//!
//! ```text
//! D(a) = Σ_j a_j − ½ Σ_{i,j} α_i α_j k(x_i, x_j)
//! ```
//!
//! by randomized coordinate ascent with the closed-form per-coordinate
//! maximizer `a_j ← clip(a_j + (1 − y_j f(x_j)) / k(x_j, x_j), 0, C)`.
//! Streaming rows enter by the same rule: a margin violator is an exact
//! coordinate step on a fresh coordinate (`a = 0`), so insertions and
//! sweep updates alike never decrease `D` — the invariant pinned by
//! `tests/dual_invariants.rs`. `C = 1/(λ·n)` (the paper's convention),
//! calibrated on the first ingest batch.
//!
//! Every `f(x_j)` a sweep needs is a dot product over a cached kernel row:
//! the [`GramCache`] mirrors the budget-sized Gram matrix, filled through
//! the blocked tile engine (all SIMD tiers apply), grown incrementally on
//! insert and kept exact under budget-maintenance churn via the
//! [`crate::budget::ChurnObserver`] hook — removal victims replay
//! bit-identically, opaque merge/projection events invalidate and the
//! trainer rebuilds (timed as [`Section::GramFill`]; the sweeps themselves
//! as [`Section::DualAscent`]).
//!
//! Budget overflow dispatches through the *same*
//! [`crate::budget::MaintenancePolicy`] pipeline as BSGD
//! (merge/removal/projection); after an event the trainer folds the lazy
//! scale and clips coefficients back onto the box exactly (merged `|α_z|`
//! may exceed `C`), so the dual iterate leaving any `fit`/`partial_fit`
//! is always feasible.

use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::budget::{gaussian_policy, generic_policy, AnyPolicy, GramCache, MaintenancePolicy};
use crate::data::Dataset;
use crate::kernel::Kernel;
use crate::metrics::Section;
use crate::model::{AnyModel, BudgetModel};
use crate::telemetry;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::api::{Estimator, FitSummary, RunConfig, SvmConfig};
use super::bsgd::shard_seed;

/// Coordinates whose diagonal kernel value is at most this are skipped
/// (e.g. the zero vector under the linear kernel): the closed-form update
/// divides by `k(x_j, x_j)`.
const K_DIAG_FLOOR: f64 = 1e-12;

/// The dual trainer's per-ingest hyperparameters (the dual analogue of
/// `SgdHyper`).
struct BdcaHyper {
    budget: usize,
    /// Box upper bound `C = 1/(λ·n)`.
    box_c: f64,
    /// Coordinate-ascent sweeps after each streaming pass.
    epochs: usize,
}

/// One streaming ingest: `passes` passes over `train` (each pass = one
/// insertion scan + `epochs` randomized coordinate-ascent sweeps), budget
/// maintenance dispatched through `policy` with the Gram cache observing
/// churn. Mirrors `run_sgd_passes`' accounting: `steps`, `sv_inserts`,
/// `maintenance_events`, weight degradation and wall time accumulate into
/// `summary`; scan/sweep time lands in [`Section::DualAscent`], cache
/// fills in [`Section::GramFill`].
#[allow(clippy::too_many_arguments)]
fn run_bdca_passes<K: Kernel + Copy>(
    model: &mut BudgetModel<K>,
    gram: &mut GramCache,
    train: &Dataset,
    passes: usize,
    shuffle: bool,
    hyper: &BdcaHyper,
    rng: &mut Rng,
    summary: &mut FitSummary,
    policy: &mut dyn MaintenancePolicy<K>,
) {
    let wall_start = Instant::now();
    let norms = train.norms();
    let mut order: Vec<usize> = (0..train.len()).collect();
    for _pass in 0..passes {
        if shuffle {
            rng.shuffle(&mut order);
        }
        for &i in &order {
            summary.steps += 1;
            let mut inserted = false;
            {
                let _scan = telemetry::span(Section::DualAscent, &mut summary.profiler);
                let x = train.row(i);
                let y = train.label(i) as f64;
                let margin = y * model.decision_with_norm(x, norms[i]);
                if margin < 1.0 {
                    // Exact coordinate-ascent step on a fresh coordinate
                    // (a = 0): a₀ = clip((1 − y·f(x)) / k(x, x), 0, C) > 0
                    // exactly when the margin is violated.
                    let kxx = model.kernel().self_eval(norms[i]);
                    if kxx > K_DIAG_FLOOR {
                        let a0 = ((1.0 - margin) / kxx).min(hyper.box_c);
                        if a0 > 0.0 {
                            model.push(x, y * a0);
                            summary.sv_inserts += 1;
                            inserted = true;
                        }
                    }
                }
            }
            if inserted {
                let _fill = telemetry::span(Section::GramFill, &mut summary.profiler);
                gram.push_row(model);
            }

            if hyper.budget > 0 && policy.trigger(model.num_sv(), hyper.budget) {
                summary.maintenance_events += 1;
                telemetry::registry::count(telemetry::Counter::MaintenanceEvents);
                telemetry::emit("maintenance", || {
                    vec![
                        ("solver", Json::str("bdca")),
                        ("num_sv", Json::num(model.num_sv() as f64)),
                        ("budget", Json::num(hyper.budget as f64)),
                    ]
                });
                summary.total_weight_degradation +=
                    policy.maintain_observed(model, hyper.budget, &mut summary.profiler, gram);
                resync_after_maintenance(model, gram, hyper.box_c, summary);
            }
        }
        // Randomized coordinate-ascent epochs over the stored SV set.
        for _ in 0..hyper.epochs {
            let _sweep = telemetry::span(Section::DualAscent, &mut summary.profiler);
            dual_sweep(model, gram, hyper.box_c, rng);
        }
    }
    // Hard budget enforcement at the end of the ingest call (see the BSGD
    // twin): with slack the model may still overshoot here; shed the
    // excess so callers always see a budget-respecting, box-feasible
    // model. A no-op when slack = 0.
    while hyper.budget > 0 && model.num_sv() > hyper.budget {
        summary.maintenance_events += 1;
        telemetry::registry::count(telemetry::Counter::MaintenanceEvents);
        telemetry::emit("maintenance", || {
            vec![
                ("solver", Json::str("bdca")),
                ("num_sv", Json::num(model.num_sv() as f64)),
                ("budget", Json::num(hyper.budget as f64)),
            ]
        });
        summary.total_weight_degradation +=
            policy.maintain_observed(model, hyper.budget, &mut summary.profiler, gram);
        resync_after_maintenance(model, gram, hyper.box_c, summary);
    }
    summary.wall_seconds += wall_start.elapsed().as_secs_f64();
}

/// Restore the dual invariants after a maintenance event: fold the lazy
/// scale, clip coefficients back onto the box *exactly* (a merged `|α_z|`
/// may exceed `C`; removal/projection rewrites may too), and rebuild the
/// Gram mirror if the event was opaque ([`GramCache::is_stale`]).
fn resync_after_maintenance<K: Kernel + Copy>(
    model: &mut BudgetModel<K>,
    gram: &mut GramCache,
    box_c: f64,
    summary: &mut FitSummary,
) {
    model.fold_scale();
    for j in 0..model.num_sv() {
        let a = model.alpha(j);
        if a.abs() > box_c {
            // set_alpha, not add_alpha: the assignment must land on the
            // boundary exactly, not an ulp past it.
            model.set_alpha(j, a.signum() * box_c);
        }
    }
    if gram.is_stale() {
        let _fill = telemetry::span(Section::GramFill, &mut summary.profiler);
        gram.rebuild(model);
    }
}

/// One randomized coordinate-ascent sweep: visit every stored SV in a
/// fresh random permutation and apply the closed-form box-clipped
/// maximizer. Exact per-coordinate maximization of a concave parabola
/// clamped to its feasible interval — `D` never decreases. Coordinates at
/// `a = 0` are skipped: their label is no longer recoverable from the
/// signed coefficient, they contribute nothing to `f`, and budget
/// maintenance sheds them first (min-|α|).
fn dual_sweep<K: Kernel + Copy>(
    model: &mut BudgetModel<K>,
    gram: &GramCache,
    box_c: f64,
    rng: &mut Rng,
) {
    let n = model.num_sv();
    debug_assert_eq!(gram.len(), n, "Gram mirror out of sync with the model");
    if n == 0 {
        return;
    }
    for j in rng.permutation(n) {
        let alpha_j = model.alpha(j);
        if alpha_j == 0.0 {
            continue;
        }
        let y_j = if alpha_j >= 0.0 { 1.0 } else { -1.0 };
        let a_j = alpha_j.abs();
        let row = gram.row(j);
        let kjj = row[j];
        if kjj <= K_DIAG_FLOOR {
            continue;
        }
        // f(x_j) off the cached row — Gauss–Seidel: always against the
        // *current* coefficients, including this sweep's earlier updates.
        let mut f_j = model.bias;
        for (i, &k_ij) in row.iter().enumerate() {
            f_j += model.alpha(i) * k_ij;
        }
        let new_a = (a_j + (1.0 - y_j * f_j) / kjj).clamp(0.0, box_c);
        if new_a != a_j {
            model.set_alpha(j, y_j * new_a);
        }
    }
}

/// The dual objective `D(a) = Σ_j a_j − ½ Σ_j α_j f(x_j)` evaluated off
/// the cached Gram rows (`a_j = |α_j|` by the signed-coefficient
/// convention; the trainer keeps the bias at zero).
fn dual_objective_of<K: Kernel + Copy>(model: &BudgetModel<K>, gram: &GramCache) -> f64 {
    let n = model.num_sv();
    debug_assert_eq!(gram.len(), n, "Gram mirror out of sync with the model");
    let mut d = 0.0;
    for j in 0..n {
        let alpha_j = model.alpha(j);
        let row = gram.row(j);
        let mut f_j = 0.0;
        for (i, &k_ij) in row.iter().enumerate() {
            f_j += model.alpha(i) * k_ij;
        }
        d += alpha_j.abs() - 0.5 * alpha_j * f_j;
    }
    d
}

/// `true` iff `gram` is bit-identical to a fresh [`GramCache::rebuild`]
/// from `model` — the exactness invariant the churn discipline maintains.
fn gram_matches_fresh<K: Kernel + Copy>(model: &BudgetModel<K>, gram: &GramCache) -> bool {
    if gram.is_stale() || gram.len() != model.num_sv() {
        return false;
    }
    let mut fresh = GramCache::new(gram.capacity());
    fresh.rebuild(model);
    (0..gram.len())
        .all(|j| gram.row(j).iter().zip(fresh.row(j)).all(|(a, b)| a.to_bits() == b.to_bits()))
}

/// Internal trained state of a [`BdcaEstimator`].
struct BdcaState {
    model: AnyModel,
    summary: FitSummary,
    /// Maintenance policy, kept across `partial_fit` calls (scratch
    /// buffers and the removal min-|α| index survive the stream).
    policy: Option<AnyPolicy>,
    rng: Rng,
    /// The budget-sized Gram mirror the sweeps read their rows from.
    gram: GramCache,
    /// Dual box upper bound `C = 1/(λ·n)`, calibrated on the first ingest
    /// batch and fixed for the rest of the stream.
    box_c: f64,
}

/// Budgeted dual coordinate-ascent trainer behind the unified
/// [`Estimator`] surface: kernel-generic, streaming-capable, with the
/// same budget-maintenance pipeline as [`super::BsgdEstimator`] (merge on
/// Gaussian, removal/projection everywhere) observed by a churn-aware
/// Gram cache. See the module docs for the algorithm.
///
/// Differences from the primal twin: no learning-rate schedule (the
/// closed-form coordinate maximizer has no step size), no objective
/// curves and no merge-solver audit (both are primal-SGD
/// instrumentation); `SvmConfig::dual_epochs` controls the sweeps per
/// pass instead.
pub struct BdcaEstimator {
    config: SvmConfig,
    run: RunConfig,
    state: Option<BdcaState>,
}

impl BdcaEstimator {
    /// Validate the configuration pair and build an unfitted estimator.
    pub fn new(config: SvmConfig, run: RunConfig) -> Result<Self> {
        config.validate()?;
        run.validate()?;
        ensure!(
            config.budget >= 2,
            "budgeted dual ascent needs a budget of at least 2 (merging needs a pair), got {}",
            config.budget
        );
        ensure!(
            !run.audit,
            "audit instrumentation compares merge solvers on the primal SGD path; \
             the dual trainer does not support it"
        );
        ensure!(
            run.curve_every == 0,
            "objective curves are primal-SGD instrumentation; the dual trainer \
             does not record them"
        );
        Ok(BdcaEstimator { config, run, state: None })
    }

    /// Shard-local construction for the sharded streaming-ingest pipeline
    /// (same [`shard_seed`] convention as the primal twin, so swapping
    /// solvers keeps shard decorrelation and reproducibility).
    pub fn new_shard(config: SvmConfig, mut run: RunConfig, shard: usize) -> Result<Self> {
        run.seed = shard_seed(run.seed, shard);
        run.threads = 1;
        Self::new(config, run)
    }

    /// Snapshot export for the serving layer: a clone of the current model
    /// plus the cumulative step count (the publish weight of this shard).
    /// `None` before the first ingest.
    pub fn snapshot(&self) -> Option<(AnyModel, u64)> {
        self.state.as_ref().map(|s| (s.model.clone(), s.summary.steps))
    }

    /// The model hyperparameters this estimator was built with.
    pub fn config(&self) -> &SvmConfig {
        &self.config
    }

    /// The trained model, if fitted.
    pub fn model(&self) -> Option<&AnyModel> {
        self.state.as_ref().map(|s| &s.model)
    }

    /// Cumulative training statistics, if fitted.
    pub fn summary(&self) -> Option<&FitSummary> {
        self.state.as_ref().map(|s| &s.summary)
    }

    /// Consume the estimator, returning the trained model.
    pub fn into_model(self) -> Result<AnyModel> {
        Ok(self.state.context("estimator is not fitted")?.model)
    }

    /// The dual box upper bound `C = 1/(λ·n)` in effect (`None` before
    /// the first ingest).
    pub fn box_c(&self) -> Option<f64> {
        self.state.as_ref().map(|s| s.box_c)
    }

    /// Current dual objective `D(a)` off the cached Gram rows (`None`
    /// before the first ingest). Every ingest leaves the cache in sync,
    /// so this is always evaluable on a fitted estimator.
    pub fn dual_objective(&self) -> Option<f64> {
        let st = self.state.as_ref()?;
        Some(match &st.model {
            AnyModel::Gaussian(m) => dual_objective_of(m, &st.gram),
            AnyModel::Linear(m) => dual_objective_of(m, &st.gram),
            AnyModel::Polynomial(m) => dual_objective_of(m, &st.gram),
        })
    }

    /// Verification probe (driven by the dual-invariants suite): is the
    /// churn-maintained Gram cache bit-identical to a fresh recomputation
    /// from the current model? `None` before the first ingest.
    pub fn gram_matches_fresh_rebuild(&self) -> Option<bool> {
        let st = self.state.as_ref()?;
        Some(match &st.model {
            AnyModel::Gaussian(m) => gram_matches_fresh(m, &st.gram),
            AnyModel::Linear(m) => gram_matches_fresh(m, &st.gram),
            AnyModel::Polynomial(m) => gram_matches_fresh(m, &st.gram),
        })
    }

    /// Run `epochs` extra coordinate-ascent sweeps on the fitted state and
    /// return the dual objective after each — the monotonicity probe the
    /// dual-invariants suite drives.
    pub fn ascend_epochs(&mut self, epochs: usize) -> Result<Vec<f64>> {
        let st = self.state.as_mut().context("estimator is not fitted")?;
        let mut objectives = Vec::with_capacity(epochs);
        for _ in 0..epochs {
            let d = {
                let _sweep = telemetry::span(Section::DualAscent, &mut st.summary.profiler);
                match &mut st.model {
                    AnyModel::Gaussian(m) => {
                        dual_sweep(m, &st.gram, st.box_c, &mut st.rng);
                        dual_objective_of(m, &st.gram)
                    }
                    AnyModel::Linear(m) => {
                        dual_sweep(m, &st.gram, st.box_c, &mut st.rng);
                        dual_objective_of(m, &st.gram)
                    }
                    AnyModel::Polynomial(m) => {
                        dual_sweep(m, &st.gram, st.box_c, &mut st.rng);
                        dual_objective_of(m, &st.gram)
                    }
                }
            };
            objectives.push(d);
        }
        Ok(objectives)
    }

    /// One ingest call: `passes` passes over `train` (insertion scan +
    /// `dual_epochs` sweeps each), shuffling between passes iff `shuffle`.
    /// Creates the state — model, Gram cache, the box bound `C` — on
    /// first use.
    fn ingest(&mut self, train: &Dataset, passes: usize, shuffle: bool) -> Result<()> {
        ensure!(!train.is_empty(), "cannot train on an empty dataset");
        if self.state.is_none() {
            // Room for the slack overshoot plus the triggering insert;
            // the Gram mirror is sized to match the model exactly.
            let capacity = self.config.budget + (self.config.maint_slack.ceil() as usize) + 1;
            let mut model = AnyModel::new(train.dim(), self.config.kernel, capacity)?;
            model.set_fast_exp(self.config.fast_exp);
            self.state = Some(BdcaState {
                model,
                summary: FitSummary::default(),
                policy: None,
                rng: Rng::new(self.run.seed),
                gram: GramCache::new(capacity),
                box_c: 1.0 / (self.config.lambda * train.len() as f64),
            });
        }
        let maint = self.config.maintenance();
        let st = self.state.as_mut().unwrap();
        ensure!(
            st.model.dim() == train.dim(),
            "dataset dimension {} does not match the fitted model dimension {}",
            train.dim(),
            st.model.dim()
        );
        let hyper = BdcaHyper {
            budget: self.config.budget,
            box_c: st.box_c,
            epochs: self.config.dual_epochs,
        };
        match &mut st.model {
            AnyModel::Gaussian(model) => {
                let mut policy = match st.policy.take() {
                    Some(AnyPolicy::Gaussian(p)) => p,
                    _ => gaussian_policy(&maint),
                };
                run_bdca_passes(
                    model,
                    &mut st.gram,
                    train,
                    passes,
                    shuffle,
                    &hyper,
                    &mut st.rng,
                    &mut st.summary,
                    policy.as_mut(),
                );
                st.policy = Some(AnyPolicy::Gaussian(policy));
            }
            AnyModel::Linear(model) => {
                let mut policy = match st.policy.take() {
                    Some(AnyPolicy::Linear(p)) => p,
                    _ => generic_policy(&maint)?,
                };
                run_bdca_passes(
                    model,
                    &mut st.gram,
                    train,
                    passes,
                    shuffle,
                    &hyper,
                    &mut st.rng,
                    &mut st.summary,
                    policy.as_mut(),
                );
                st.policy = Some(AnyPolicy::Linear(policy));
            }
            AnyModel::Polynomial(model) => {
                let mut policy = match st.policy.take() {
                    Some(AnyPolicy::Polynomial(p)) => p,
                    _ => generic_policy(&maint)?,
                };
                run_bdca_passes(
                    model,
                    &mut st.gram,
                    train,
                    passes,
                    shuffle,
                    &hyper,
                    &mut st.rng,
                    &mut st.summary,
                    policy.as_mut(),
                );
                st.policy = Some(AnyPolicy::Polynomial(policy));
            }
        }
        Ok(())
    }
}

impl Estimator for BdcaEstimator {
    type Data = Dataset;

    fn fit(&mut self, data: &Dataset) -> Result<()> {
        self.state = None;
        self.ingest(data, self.run.passes, self.run.shuffle)
    }

    fn partial_fit(&mut self, data: &Dataset) -> Result<()> {
        self.ingest(data, 1, false)
    }

    fn decision_function(&self, x: &[f32]) -> Result<Vec<f64>> {
        let st = self.state.as_ref().context("estimator is not fitted")?;
        ensure!(x.len() == st.model.dim(), "feature row has wrong dimension");
        Ok(vec![st.model.decision(x)])
    }

    fn predict(&self, x: &[f32]) -> Result<f32> {
        let st = self.state.as_ref().context("estimator is not fitted")?;
        ensure!(x.len() == st.model.dim(), "feature row has wrong dimension");
        Ok(st.model.predict(x))
    }

    fn dim(&self) -> Option<usize> {
        self.state.as_ref().map(|s| s.model.dim())
    }

    /// Chunked parallel batch prediction over `RunConfig::threads` workers
    /// (row-granular split: identical output for every thread count).
    fn predict_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        let st = self.state.as_ref().context("estimator is not fitted")?;
        let d = st.model.dim();
        ensure!(
            x.len() % d == 0,
            "batch buffer length {} is not a multiple of the feature dimension {d}",
            x.len()
        );
        Ok(st
            .model
            .decision_rows(x, self.run.threads)
            .into_iter()
            .map(|f| if f >= 0.0 { 1.0 } else { -1.0 })
            .collect())
    }
}

impl std::fmt::Debug for BdcaEstimator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BdcaEstimator")
            .field("budget", &self.config.budget)
            .field("kernel", &self.config.kernel)
            .field("dual_epochs", &self.config.dual_epochs)
            .field("fitted", &self.state.is_some())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::Strategy;
    use crate::data::synthetic::two_moons;
    use crate::kernel::KernelSpec;
    use crate::metrics::accuracy;

    fn moons() -> Dataset {
        two_moons(600, 0.12, 42)
    }

    fn moons_config(n: usize, budget: usize) -> SvmConfig {
        SvmConfig::new().kernel(KernelSpec::gaussian(2.0)).budget(budget).c(10.0, n)
    }

    fn fitted(budget: usize, passes: usize, seed: u64) -> (Dataset, BdcaEstimator) {
        let ds = moons();
        let config = moons_config(ds.len(), budget);
        let mut est =
            BdcaEstimator::new(config, RunConfig::new().passes(passes).seed(seed)).unwrap();
        est.fit(&ds).unwrap();
        (ds, est)
    }

    #[test]
    fn learns_two_moons_under_budget() {
        let (ds, est) = fitted(50, 4, 1);
        let model = est.model().unwrap();
        assert!(model.num_sv() <= 50);
        assert!(est.summary().unwrap().maintenance_events > 0, "budget must bind");
        let preds = est.predict_batch(ds.features()).unwrap();
        let acc = accuracy(&preds, ds.labels());
        assert!(acc > 0.9, "accuracy {acc}");
        // Dual-time accounting: sweeps and fills were timed, the primal
        // sections stayed empty.
        let prof = &est.summary().unwrap().profiler;
        assert!(prof.events(Section::DualAscent) > 0);
        assert!(prof.events(Section::GramFill) > 0);
        assert_eq!(prof.events(Section::SgdStep), 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, a) = fitted(40, 3, 9);
        let (_, b) = fitted(40, 3, 9);
        let da = a.model().unwrap().decision_rows(ds.features(), 1);
        let db = b.model().unwrap().decision_rows(ds.features(), 1);
        assert_eq!(da.len(), db.len());
        for (x, y) in da.iter().zip(&db) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(
            a.dual_objective().unwrap().to_bits(),
            b.dual_objective().unwrap().to_bits()
        );
    }

    #[test]
    fn partial_fit_equals_unshuffled_single_pass_fit() {
        let ds = moons();
        let config = moons_config(ds.len(), 40);
        let run = RunConfig::new().passes(1).shuffle(false).seed(5);
        let mut by_fit = BdcaEstimator::new(config.clone(), run.clone()).unwrap();
        by_fit.fit(&ds).unwrap();
        let mut by_stream = BdcaEstimator::new(config, run).unwrap();
        by_stream.partial_fit(&ds).unwrap();
        let fa = by_fit.model().unwrap().decision_rows(ds.features(), 1);
        let fb = by_stream.model().unwrap().decision_rows(ds.features(), 1);
        for (x, y) in fa.iter().zip(&fb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn alpha_stays_in_the_box_under_churn() {
        let ds = moons();
        let config = moons_config(ds.len(), 30);
        let mut est = BdcaEstimator::new(config, RunConfig::new().seed(3)).unwrap();
        for _ in 0..4 {
            est.partial_fit(&ds).unwrap();
            let c = est.box_c().unwrap();
            let model = est.model().unwrap();
            assert!(model.num_sv() <= 30, "budget violated");
            for j in 0..model.num_sv() {
                let a = model.alpha(j).abs();
                assert!(a <= c, "|α_{j}| = {a} outside [0, {c}]");
            }
        }
    }

    #[test]
    fn dual_objective_is_monotone_when_budget_does_not_bind() {
        let ds = two_moons(120, 0.12, 7);
        // Budget above n: insertions and sweeps are the only operations,
        // so D must never decrease (exact box-clipped maximization).
        let config = moons_config(ds.len(), 200);
        let mut est =
            BdcaEstimator::new(config, RunConfig::new().passes(1).shuffle(false).seed(2)).unwrap();
        est.fit(&ds).unwrap();
        assert_eq!(est.summary().unwrap().maintenance_events, 0);
        let mut last = est.dual_objective().unwrap();
        assert!(last.is_finite());
        for (e, d) in est.ascend_epochs(6).unwrap().into_iter().enumerate() {
            assert!(
                d >= last - 1e-9 * (1.0 + last.abs()),
                "epoch {e}: dual objective fell {last} -> {d}"
            );
            last = d;
        }
    }

    #[test]
    fn non_gaussian_kernels_train_with_removal() {
        let ds = moons();
        for kernel in [KernelSpec::linear(), KernelSpec::polynomial(3, 1.0)] {
            let config = SvmConfig::new()
                .kernel(kernel)
                .strategy(Strategy::Removal)
                .budget(40)
                .c(10.0, ds.len());
            let mut est =
                BdcaEstimator::new(config, RunConfig::new().passes(2).seed(4)).unwrap();
            est.fit(&ds).unwrap();
            assert!(est.model().unwrap().num_sv() <= 40, "{kernel:?}");
            assert!(est.dual_objective().unwrap().is_finite());
        }
    }

    #[test]
    fn snapshot_is_a_clone() {
        let (ds, mut est) = fitted(40, 2, 11);
        let (snap, steps) = est.snapshot().unwrap();
        assert_eq!(steps, est.summary().unwrap().steps);
        est.partial_fit(&ds).unwrap();
        // The snapshot is detached from further training.
        assert!(snap.num_sv() <= 40);
        assert!(est.summary().unwrap().steps > steps);
    }

    #[test]
    fn rejects_bad_configurations() {
        let cfg = SvmConfig::new();
        assert!(BdcaEstimator::new(cfg.clone().budget(1), RunConfig::new()).is_err());
        assert!(BdcaEstimator::new(cfg.clone(), RunConfig::new().audit(true)).is_err());
        assert!(BdcaEstimator::new(cfg.clone(), RunConfig::new().curve(10, 32)).is_err());
        assert!(BdcaEstimator::new(cfg.clone().dual_epochs(0), RunConfig::new()).is_err());
        // Merge maintenance still requires the Gaussian kernel.
        assert!(BdcaEstimator::new(
            cfg.kernel(KernelSpec::linear()),
            RunConfig::new()
        )
        .is_err());
    }

    #[test]
    fn unfitted_estimator_errors() {
        let est = BdcaEstimator::new(SvmConfig::new(), RunConfig::new()).unwrap();
        assert!(!est.is_fitted());
        assert!(est.decision_function(&[0.0, 0.0]).is_err());
        assert!(est.predict(&[0.0, 0.0]).is_err());
        assert!(est.predict_batch(&[0.0, 0.0]).is_err());
        assert!(est.dual_objective().is_none());
        assert!(est.box_c().is_none());
        let mut est = est;
        assert!(est.ascend_epochs(1).is_err());
    }

    #[test]
    fn accuracy_parity_with_the_primal_twin_at_equal_budget() {
        use super::super::bsgd::BsgdEstimator;
        let ds = moons();
        let test = two_moons(400, 0.12, 43);
        let budget = 60;
        let config = moons_config(ds.len(), budget);
        let run = RunConfig::new().passes(6).seed(1);
        let mut primal = BsgdEstimator::new(config.clone(), run.clone()).unwrap();
        primal.fit(&ds).unwrap();
        let mut dual = BdcaEstimator::new(config, run).unwrap();
        dual.fit(&ds).unwrap();
        let acc_p = accuracy(&primal.predict_batch(test.features()).unwrap(), test.labels());
        let acc_d = accuracy(&dual.predict_batch(test.features()).unwrap(), test.labels());
        // The acceptance gate: the dual solver reaches parity (within
        // 0.01, one-sided) with BSGD at the same budget.
        assert!(
            acc_p - acc_d <= 0.01,
            "dual accuracy {acc_d} more than 0.01 below primal {acc_p}"
        );
    }
}

//! Training algorithms behind one unified [`Estimator`] surface
//! (`fit` / `partial_fit` / `decision_function` / `predict_batch`):
//!
//! * [`api`] — the [`Estimator`] trait plus the configuration split into
//!   model hyperparameters ([`SvmConfig`], with a typed [`crate::kernel::KernelSpec`])
//!   and run/instrumentation knobs ([`RunConfig`]).
//! * [`bsgd`] — Budgeted Stochastic Gradient Descent (Wang et al. 2012),
//!   the system this paper accelerates; fully instrumented
//!   ([`BsgdEstimator`], legacy [`train_bsgd`]).
//! * [`multiclass`] — one-vs-rest reduction (the paper's "other tasks"
//!   generalization), K budgeted machines sharing one lookup table
//!   ([`OneVsRestEstimator`], legacy `train_multiclass`).
//! * [`pegasos`] — unbudgeted kernelized Pegasos baseline
//!   ([`PegasosEstimator`], legacy `train_pegasos`).
//! * [`smo`] — a working-set SMO dual solver standing in for LIBSVM as the
//!   "exact model" reference of Table 1 ([`SmoEstimator`], legacy
//!   `train_smo`).
//! * [`schedule`] — learning-rate schedules.

pub mod api;
pub mod bsgd;
pub mod multiclass;
pub mod pegasos;
pub mod schedule;
pub mod smo;

pub use api::{Estimator, FitSummary, RunConfig, SvmConfig};
pub use bsgd::{train_bsgd, BsgdEstimator, BsgdOptions, CurvePoint, TrainReport};
pub use multiclass::{MulticlassDataset, OneVsRestEstimator};
pub use pegasos::PegasosEstimator;
pub use schedule::LearningRate;
pub use smo::SmoEstimator;

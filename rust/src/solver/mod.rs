//! The solver family: every trainer in the crate behind one unified
//! [`Estimator`] surface (`fit` / `partial_fit` / `decision_function` /
//! `predict_batch`).
//!
//! # The family, by optimization view
//!
//! **Primal, budgeted** — [`bsgd`]: Budgeted Stochastic Gradient Descent
//! (Wang et al. 2012), the system this paper accelerates. One SGD step
//! per streaming row (margin check, Pegasos-style `1/(λt)` shrink,
//! insert on violation), budget maintenance on overflow. Cheapest per
//! row; accuracy depends on the learning-rate schedule.
//!
//! **Primal, unbudgeted** — [`pegasos`]: kernelized Pegasos baseline.
//! The same SGD step with the maintenance branch never firing — the SV
//! set grows without bound. Reference quality for small streams; memory
//! makes it unusable beyond that.
//!
//! **Dual, budgeted** — [`bdca`]: Budgeted Dual Coordinate Ascent (the
//! sister paper, arXiv:1806.10182). Maintains box-constrained dual
//! coefficients `a_j ∈ [0, C]` over the stored SVs and sweeps them with
//! closed-form coordinate updates off a churn-aware Gram cache
//! ([`crate::budget::GramCache`]). No step size to tune, monotone dual
//! objective between maintenance events; costs `O(B)` per coordinate
//! update plus the cached `(B+slack)²` Gram slab.
//!
//! **Dual, exact** — [`smo`]: working-set SMO standing in for LIBSVM as
//! the "exact model" reference of Table 1. No budget, no streaming —
//! batch-only, for ground truth on subsampled data.
//!
//! # The budget-maintenance contract
//!
//! Both budgeted trainers dispatch overflow through the same
//! [`crate::budget::MaintenancePolicy`] pipeline (merge on Gaussian
//! kernels, removal/projection on every kernel; see the
//! [`crate::budget`] compatibility matrix) and guarantee `num_sv ≤ B` on
//! every model leaving `fit`/`partial_fit`. BDCA additionally registers
//! its Gram cache as a [`crate::budget::ChurnObserver`] so the cache
//! stays exact (removal) or is rebuilt (merge/projection) across events,
//! and re-clips coefficients onto the dual box afterwards.
//!
//! # Picking a solver
//!
//! * Default to **BSGD** (`--solver bsgd`): the paper's solver, fastest
//!   per row, the right choice when the stream is long and the budget
//!   tight.
//! * Pick **BDCA** (`--solver bdca`) when step-size sensitivity hurts —
//!   it has no learning rate, its dual objective is monotone per epoch,
//!   and repeated sweeps squeeze more quality out of the *same* B stored
//!   vectors (at the cost of the Gram slab and `O(B²)` sweep time).
//! * **Pegasos** for unbudgeted reference runs, **SMO** for exact
//!   references on small data.
//!
//! Both family members plug into everything downstream through
//! [`SolverSpec`] → [`AnyEstimator`]: serving shards
//! (`serve::ShardedIngest`), the one-vs-rest reduction and the
//! coordinator select a solver at runtime instead of hard-wiring a type.
//!
//! # Layout
//!
//! * [`api`] — the [`Estimator`] trait, the configuration split
//!   ([`SvmConfig`] / [`RunConfig`]) and the family registration
//!   ([`SolverSpec`], [`AnyEstimator`]).
//! * [`bsgd`] — the budgeted primal trainer ([`BsgdEstimator`], legacy
//!   [`train_bsgd`]).
//! * [`bdca`] — the budgeted dual trainer ([`BdcaEstimator`]).
//! * [`multiclass`] — one-vs-rest reduction over K binary machines of
//!   either solver, sharing one lookup table ([`OneVsRestEstimator`]).
//! * [`pegasos`] — unbudgeted kernelized Pegasos ([`PegasosEstimator`]).
//! * [`smo`] — the exact dual reference ([`SmoEstimator`]).
//! * [`schedule`] — learning-rate schedules (primal only).

pub mod api;
pub mod bdca;
pub mod bsgd;
pub mod multiclass;
pub mod pegasos;
pub mod schedule;
pub mod smo;

pub use api::{AnyEstimator, Estimator, FitSummary, RunConfig, SolverSpec, SvmConfig};
pub use bdca::BdcaEstimator;
pub use bsgd::{train_bsgd, BsgdEstimator, BsgdOptions, CurvePoint, TrainReport};
pub use multiclass::{MulticlassDataset, OneVsRestEstimator};
pub use pegasos::PegasosEstimator;
pub use schedule::LearningRate;
pub use smo::SmoEstimator;

//! Training algorithms.
//!
//! * [`bsgd`] — Budgeted Stochastic Gradient Descent (Wang et al. 2012),
//!   the system this paper accelerates; fully instrumented.
//! * [`multiclass`] — one-vs-rest reduction (the paper's "other tasks"
//!   generalization), K budgeted machines sharing the merge machinery.
//! * [`pegasos`] — unbudgeted kernelized Pegasos baseline.
//! * [`smo`] — a working-set SMO dual solver standing in for LIBSVM as the
//!   "exact model" reference of Table 1.
//! * [`schedule`] — learning-rate schedules.

pub mod bsgd;
pub mod multiclass;
pub mod pegasos;
pub mod schedule;
pub mod smo;

pub use bsgd::{train_bsgd, BsgdOptions, CurvePoint, TrainReport};
pub use schedule::LearningRate;

//! Budgeted Stochastic Gradient Descent (BSGD) — Wang, Crammer & Vucetic
//! (JMLR 2012) — with the merge-solver choice of Glasmachers & Qaadan
//! (2018) as a first-class option.
//!
//! Per step (Pegasos update on the primal objective (1) of the paper):
//!
//! ```text
//! margin = y_i · f(x_i)                 (with the pre-update model)
//! w ← (1 − η_t λ) · w                   (O(1) via the lazy global scale)
//! if margin < 1:  w ← w + η_t y_i φ(x_i)  (insert SV)
//! if #SV > B:     budget maintenance     (merge / remove / project)
//! ```
//!
//! The trainer is instrumented exactly along the paper's profiler
//! attribution: SGD-step time vs. budget-maintenance time, with maintenance
//! split into Section A (computing `h`/`WD` per candidate) and Section B
//! (everything else) — the data behind Figure 3 and Table 3.

use std::time::Instant;

use crate::budget::{audit_event, LookupTable, Maintainer, MergeSolver, Strategy};
use crate::data::Dataset;
use crate::kernel::Gaussian;
use crate::metrics::{AgreementStats, Section, SectionProfiler};
use crate::model::BudgetModel;
use crate::util::rng::Rng;

use super::schedule::LearningRate;

/// Options for one BSGD training run.
#[derive(Debug, Clone)]
pub struct BsgdOptions {
    /// Budget B — maximum number of support vectors.
    pub budget: usize,
    /// Regularization λ (the paper tunes `C = 1/(n·λ)`).
    pub lambda: f64,
    /// Gaussian kernel bandwidth γ.
    pub gamma: f64,
    /// Passes (epochs) over the training data.
    pub passes: usize,
    /// RNG seed controlling the visit order.
    pub seed: u64,
    /// Budget maintenance strategy.
    pub strategy: Strategy,
    /// Lookup-table grid resolution (paper: 400).
    pub grid: usize,
    /// Learning-rate schedule; `None` = Pegasos `1/(λt)`.
    pub learning_rate: Option<LearningRate>,
    /// Record Table-3-style agreement statistics (runs GSS-standard,
    /// Lookup-WD and GSS-precise side by side at every maintenance event —
    /// expensive, for the audit experiment only).
    pub audit: bool,
    /// Record an objective/accuracy curve every `curve_every` steps
    /// (0 = never).
    pub curve_every: u64,
    /// Rows subsampled for each curve evaluation.
    pub curve_sample: usize,
}

impl BsgdOptions {
    /// Sensible defaults for a (budget, λ, γ) triple: Lookup-WD merging with
    /// the paper's 400×400 grid, one pass.
    pub fn new(budget: usize, lambda: f64, gamma: f64) -> Self {
        BsgdOptions {
            budget,
            lambda,
            gamma,
            passes: 1,
            seed: 0,
            strategy: Strategy::Merge(MergeSolver::LookupWd),
            grid: 400,
            learning_rate: None,
            audit: false,
            curve_every: 0,
            curve_sample: 512,
        }
    }

    /// Derive λ from the paper's `C` convention: `λ = 1/(n·C)`.
    pub fn with_c(budget: usize, c: f64, gamma: f64, n_train: usize) -> Self {
        Self::new(budget, 1.0 / (c * n_train as f64), gamma)
    }
}

/// One point of the training curve.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    pub step: u64,
    /// Estimated primal objective `λ/2‖w‖² + mean hinge` on a fixed sample.
    pub objective: f64,
    /// Accuracy on the same sample.
    pub sample_accuracy: f64,
    /// Support vectors at this step.
    pub num_sv: usize,
}

/// Everything a training run produces.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub model: BudgetModel,
    /// SGD steps executed (= passes · n).
    pub steps: u64,
    /// Steps that violated the margin and inserted an SV.
    pub sv_inserts: u64,
    /// Budget maintenance events triggered.
    pub maintenance_events: u64,
    /// Section timings (SGD / maintenance A / maintenance B).
    pub profiler: SectionProfiler,
    /// Total wall time of the training loop.
    pub wall_seconds: f64,
    /// Sum of weight degradations over all maintenance events.
    pub total_weight_degradation: f64,
    /// Objective curve (empty unless `curve_every > 0`).
    pub curve: Vec<CurvePoint>,
    /// Agreement statistics (present iff `audit`).
    pub agreement: Option<AgreementStats>,
}

impl TrainReport {
    /// Fraction of SGD steps that triggered budget maintenance — the
    /// paper's "merging frequency" (Table 3).
    pub fn merging_frequency(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.maintenance_events as f64 / self.steps as f64
        }
    }

    /// Fraction of total accounted time spent in budget maintenance.
    pub fn maintenance_fraction(&self) -> f64 {
        let total = self.profiler.total_seconds();
        if total <= 0.0 {
            0.0
        } else {
            self.profiler.maintenance_seconds() / total
        }
    }
}

/// Train a budgeted SVM with SGD. See module docs for the update rule.
pub fn train_bsgd(train: &Dataset, opts: &BsgdOptions) -> TrainReport {
    assert!(opts.budget >= 2, "budget must be at least 2 (merging needs a pair)");
    assert!(opts.lambda > 0.0);
    assert!(!train.is_empty());

    let n = train.len();
    let d = train.dim();
    let kernel = Gaussian::new(opts.gamma);
    let lr = opts.learning_rate.unwrap_or(LearningRate::PegasosInvT { lambda: opts.lambda });

    let mut model = BudgetModel::new(d, kernel, opts.budget + 1);
    let mut maintainer = Maintainer::new(opts.strategy, opts.grid);
    let mut prof = SectionProfiler::new();
    let mut rng = Rng::new(opts.seed);
    let mut agreement = opts.audit.then(AgreementStats::new);
    // The audit needs a table even when the primary strategy is GSS.
    let audit_table: Option<LookupTable> =
        opts.audit.then(|| LookupTable::build(opts.grid.max(2)));

    // Precompute row norms once (reused by every margin evaluation).
    let norms: Vec<f32> = (0..n).map(|i| crate::kernel::norm2(train.row(i))).collect();

    // Fixed evaluation sample for the curve.
    let curve_idx: Vec<usize> = if opts.curve_every > 0 {
        rng.sample_indices(n, opts.curve_sample.min(n))
    } else {
        Vec::new()
    };

    let mut steps: u64 = 0;
    let mut sv_inserts: u64 = 0;
    let mut maintenance_events: u64 = 0;
    let mut total_wd = 0.0f64;
    let mut curve = Vec::new();
    let mut order: Vec<usize> = (0..n).collect();

    let wall_start = Instant::now();
    for _pass in 0..opts.passes {
        rng.shuffle(&mut order);
        for &i in &order {
            steps += 1;
            let t_sgd = Instant::now();
            let x = train.row(i);
            let y = train.label(i) as f64;
            let margin = y * model.decision_with_norm(x, norms[i]);
            model.rescale(lr.shrink(steps, opts.lambda));
            let violated = margin < 1.0;
            if violated {
                model.push(x, lr.eta(steps) * y);
                sv_inserts += 1;
            }
            prof.add(Section::SgdStep, t_sgd.elapsed());

            if model.num_sv() > opts.budget {
                maintenance_events += 1;
                if let (Some(stats), Some(table)) = (agreement.as_mut(), audit_table.as_ref()) {
                    if let Some(rec) = audit_event(&model, table) {
                        stats.events += 1;
                        stats.equal_decisions += rec.equal as u64;
                        if !rec.equal {
                            stats.wd_diff_on_disagreement.push(rec.wd_diff);
                        }
                        if rec.factors_valid {
                            stats.factor_gss.push(rec.factor_gss);
                            stats.factor_lookup.push(rec.factor_lookup);
                        }
                    }
                }
                total_wd += maintainer.maintain(&mut model, &mut prof);
            }

            if opts.curve_every > 0 && steps % opts.curve_every == 0 {
                curve.push(curve_point(&model, train, &curve_idx, opts.lambda, steps));
            }
        }
    }
    let wall_seconds = wall_start.elapsed().as_secs_f64();
    if opts.curve_every > 0 {
        curve.push(curve_point(&model, train, &curve_idx, opts.lambda, steps));
    }

    TrainReport {
        model,
        steps,
        sv_inserts,
        maintenance_events,
        profiler: prof,
        wall_seconds,
        total_weight_degradation: total_wd,
        curve,
        agreement,
    }
}

fn curve_point(
    model: &BudgetModel,
    train: &Dataset,
    idx: &[usize],
    lambda: f64,
    step: u64,
) -> CurvePoint {
    let mut hinge = 0.0f64;
    let mut correct = 0usize;
    for &i in idx {
        let f = model.decision(train.row(i));
        let y = train.label(i) as f64;
        hinge += (1.0 - y * f).max(0.0);
        if (f >= 0.0) == (y >= 0.0) {
            correct += 1;
        }
    }
    let m = idx.len().max(1) as f64;
    CurvePoint {
        step,
        objective: 0.5 * lambda * model.weight_norm2() + hinge / m,
        sample_accuracy: correct as f64 / m,
        num_sv: model.num_sv(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;

    fn moons_opts(budget: usize) -> (Dataset, BsgdOptions) {
        let ds = two_moons(600, 0.12, 42);
        let n = ds.len();
        // C = 10 → λ = 1/(10 n); γ = 2 suits the moon scale.
        let mut opts = BsgdOptions::with_c(budget, 10.0, 2.0, n);
        opts.passes = 6;
        opts.seed = 1;
        (ds, opts)
    }

    #[test]
    fn learns_two_moons_under_budget() {
        let (ds, opts) = moons_opts(30);
        let report = train_bsgd(&ds, &opts);
        assert!(report.model.num_sv() <= 30);
        let acc = report.model.accuracy(&ds);
        assert!(acc > 0.9, "train accuracy {acc}");
        assert_eq!(report.steps, 6 * 600);
        assert!(report.maintenance_events > 0, "budget must actually bind");
    }

    #[test]
    fn all_four_merge_solvers_reach_similar_accuracy() {
        let (ds, base) = moons_opts(25);
        let mut accs = Vec::new();
        for solver in MergeSolver::ALL {
            let mut opts = base.clone();
            opts.strategy = Strategy::Merge(solver);
            let report = train_bsgd(&ds, &opts);
            accs.push((solver.name(), report.model.accuracy(&ds)));
        }
        for &(name, acc) in &accs {
            assert!(acc > 0.88, "{name}: accuracy {acc}");
        }
        let max = accs.iter().map(|&(_, a)| a).fold(0.0, f64::max);
        let min = accs.iter().map(|&(_, a)| a).fold(1.0, f64::min);
        assert!(max - min < 0.08, "solver accuracies spread too wide: {accs:?}");
    }

    #[test]
    fn budget_constraint_never_violated_after_training() {
        for budget in [5usize, 17, 64] {
            let (ds, mut opts) = moons_opts(budget);
            opts.budget = budget;
            opts.passes = 2;
            let report = train_bsgd(&ds, &opts);
            assert!(report.model.num_sv() <= budget, "B={budget}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, opts) = moons_opts(20);
        let r1 = train_bsgd(&ds, &opts);
        let r2 = train_bsgd(&ds, &opts);
        assert_eq!(r1.steps, r2.steps);
        assert_eq!(r1.sv_inserts, r2.sv_inserts);
        assert_eq!(r1.maintenance_events, r2.maintenance_events);
        assert_eq!(r1.model.num_sv(), r2.model.num_sv());
        let probe = [0.3f32, 0.2];
        assert!((r1.model.decision(&probe) - r2.model.decision(&probe)).abs() < 1e-12);
    }

    #[test]
    fn curve_is_recorded_and_objective_decreases() {
        let (ds, mut opts) = moons_opts(40);
        opts.curve_every = 300;
        opts.passes = 8;
        let report = train_bsgd(&ds, &opts);
        assert!(report.curve.len() >= 8);
        let first = report.curve.first().unwrap().objective;
        let last = report.curve.last().unwrap().objective;
        assert!(
            last < first,
            "objective should decrease: first={first} last={last}"
        );
    }

    #[test]
    fn audit_mode_collects_agreement_stats() {
        let (ds, mut opts) = moons_opts(15);
        opts.audit = true;
        opts.passes = 2;
        opts.grid = 100;
        let report = train_bsgd(&ds, &opts);
        let stats = report.agreement.expect("audit stats");
        assert!(stats.events > 0);
        assert!(stats.equal_fraction() > 0.5, "agreement {}", stats.equal_fraction());
        assert!(stats.factor_gss.mean() >= 1.0 - 1e-9);
        assert!(stats.factor_lookup.mean() >= 1.0 - 1e-9);
    }

    #[test]
    fn unbinding_budget_means_no_maintenance() {
        let (ds, mut opts) = moons_opts(10_000);
        opts.budget = 10_000;
        opts.passes = 1;
        let report = train_bsgd(&ds, &opts);
        assert_eq!(report.maintenance_events, 0);
        assert_eq!(report.merging_frequency(), 0.0);
    }

    #[test]
    fn removal_and_projection_strategies_also_train() {
        for strat in [Strategy::Removal, Strategy::Projection] {
            let (ds, mut opts) = moons_opts(20);
            opts.strategy = strat;
            opts.passes = 3;
            let report = train_bsgd(&ds, &opts);
            assert!(report.model.num_sv() <= 20);
            let acc = report.model.accuracy(&ds);
            assert!(acc > 0.75, "{strat:?}: {acc}");
        }
    }

    #[test]
    fn merging_frequency_matches_event_count() {
        let (ds, opts) = moons_opts(12);
        let report = train_bsgd(&ds, &opts);
        let expect = report.maintenance_events as f64 / report.steps as f64;
        assert!((report.merging_frequency() - expect).abs() < 1e-15);
    }
}

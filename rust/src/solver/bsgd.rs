//! Budgeted Stochastic Gradient Descent (BSGD) — Wang, Crammer & Vucetic
//! (JMLR 2012) — with the merge-solver choice of Glasmachers & Qaadan
//! (2018) as a first-class option.
//!
//! Per step (Pegasos update on the primal objective (1) of the paper):
//!
//! ```text
//! margin = y_i · f(x_i)                 (with the pre-update model)
//! w ← (1 − η_t λ) · w                   (O(1) via the lazy global scale)
//! if margin < 1:  w ← w + η_t y_i φ(x_i)  (insert SV)
//! if policy.trigger(#SV, B):  policy.maintain(...)   (merge / remove /
//!                                                     project; slack-aware)
//! ```
//!
//! Budget maintenance goes through the single
//! [`crate::budget::MaintenancePolicy`] surface — the trigger rule
//! (`#SV − B > slack`) and the per-event batching both live in the
//! policy, not in this loop; with the default `slack = 0` the behavior is
//! the classic maintain-every-overflow regime, bit-for-bit.
//!
//! The trainer is instrumented exactly along the paper's profiler
//! attribution: SGD-step time vs. budget-maintenance time, with maintenance
//! split into Section A (computing `h`/`WD` per candidate) and Section B
//! (everything else) — the data behind Figure 3 and Table 3.
//!
//! Two surfaces share one generic SGD core ([`run_sgd_passes`]):
//!
//! * [`BsgdEstimator`] — the [`Estimator`]-trait implementation: kernel
//!   selection via [`SvmConfig`], streaming ingest via `partial_fit`.
//!   Gaussian models get the full strategy menu (merge/removal/projection
//!   plus the audit instrumentation); other kernels run removal or
//!   projection maintenance (the merge geometry is Gaussian-specific, and
//!   `SvmConfig::validate` rejects the combination up front).
//! * [`train_bsgd`] / [`BsgdOptions`] — the legacy Gaussian-only entry
//!   point, kept as a thin shim over the estimator so the experiment suite
//!   regenerates every paper table unchanged. Prefer the estimator surface
//!   in new code.

use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::budget::{
    audit_event, gaussian_policy, generic_policy, shared_lookup_table, AnyPolicy,
    MaintenancePolicy, MergeSolver, Strategy,
};
use crate::data::Dataset;
use crate::kernel::{Gaussian, Kernel, KernelSpec};
use crate::metrics::{AgreementStats, Section, SectionProfiler};
use crate::model::{AnyModel, BudgetModel};
use crate::telemetry;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::api::{Estimator, FitSummary, RunConfig, SvmConfig};
use super::schedule::LearningRate;

/// Options for one legacy BSGD training run (Gaussian kernel only).
///
/// Legacy shim: this flat struct predates the [`SvmConfig`] (model
/// hyperparameters) / [`RunConfig`] (run knobs) split — [`BsgdOptions::split`]
/// produces that pair, and [`train_bsgd`] is now a thin wrapper over
/// [`BsgdEstimator`]. Prefer the estimator surface in new code.
#[derive(Debug, Clone)]
pub struct BsgdOptions {
    /// Budget B — maximum number of support vectors.
    pub budget: usize,
    /// Regularization λ (the paper tunes `C = 1/(n·λ)`).
    pub lambda: f64,
    /// Gaussian kernel bandwidth γ.
    pub gamma: f64,
    /// Passes (epochs) over the training data.
    pub passes: usize,
    /// RNG seed controlling the visit order.
    pub seed: u64,
    /// Budget maintenance strategy.
    pub strategy: Strategy,
    /// Lookup-table grid resolution (paper: 400).
    pub grid: usize,
    /// Learning-rate schedule; `None` = Pegasos `1/(λt)`.
    pub learning_rate: Option<LearningRate>,
    /// Record Table-3-style agreement statistics (runs GSS-standard,
    /// Lookup-WD and GSS-precise side by side at every maintenance event —
    /// expensive, for the audit experiment only).
    pub audit: bool,
    /// Record an objective/accuracy curve every `curve_every` steps
    /// (0 = never).
    pub curve_every: u64,
    /// Rows subsampled for each curve evaluation.
    pub curve_sample: usize,
}

impl BsgdOptions {
    /// Sensible defaults for a (budget, λ, γ) triple: Lookup-WD merging with
    /// the paper's 400×400 grid, one pass.
    pub fn new(budget: usize, lambda: f64, gamma: f64) -> Self {
        BsgdOptions {
            budget,
            lambda,
            gamma,
            passes: 1,
            seed: 0,
            strategy: Strategy::Merge(MergeSolver::LookupWd),
            grid: 400,
            learning_rate: None,
            audit: false,
            curve_every: 0,
            curve_sample: 512,
        }
    }

    /// Derive λ from the paper's `C` convention: `λ = 1/(n·C)`.
    pub fn with_c(budget: usize, c: f64, gamma: f64, n_train: usize) -> Self {
        Self::new(budget, 1.0 / (c * n_train as f64), gamma)
    }

    /// Reject invalid hyperparameters with a descriptive error instead of
    /// letting a bad config panic (or silently misbehave) mid-run. Called
    /// by [`train_bsgd`] and the CLI. Delegates to the `SvmConfig` /
    /// `RunConfig` validators (one source of truth for the λ/γ/grid
    /// invariants) plus the budgeted-trainer `B ≥ 2` requirement.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.budget >= 2,
            "budget must be at least 2 (merging needs a pair), got {}",
            self.budget
        );
        let (config, run) = self.split();
        config.validate()?;
        run.validate()
    }

    /// Split into the new configuration pair: model hyperparameters
    /// ([`SvmConfig`]) and run/instrumentation knobs ([`RunConfig`]).
    pub fn split(&self) -> (SvmConfig, RunConfig) {
        (
            SvmConfig {
                kernel: KernelSpec::Gaussian { gamma: self.gamma },
                budget: self.budget,
                lambda: self.lambda,
                strategy: self.strategy,
                grid: self.grid,
                // Legacy surface: classic per-overflow maintenance,
                // libm exp semantics, primal-only (dual knob at default).
                maint_slack: 0.0,
                maint_pairs: 0,
                fast_exp: false,
                dual_epochs: 2,
            },
            RunConfig {
                passes: self.passes,
                seed: self.seed,
                shuffle: true,
                learning_rate: self.learning_rate,
                audit: self.audit,
                curve_every: self.curve_every,
                curve_sample: self.curve_sample,
                threads: 1,
            },
        )
    }
}

/// One point of the training curve.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    pub step: u64,
    /// Estimated primal objective `λ/2‖w‖² + mean hinge` on a fixed sample.
    pub objective: f64,
    /// Accuracy on the same sample.
    pub sample_accuracy: f64,
    /// Support vectors at this step.
    pub num_sv: usize,
}

/// Everything a legacy training run produces: the Gaussian model plus the
/// kernel-generic [`FitSummary`] fields, flattened (pre-split layout).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub model: BudgetModel,
    /// SGD steps executed (= passes · n).
    pub steps: u64,
    /// Steps that violated the margin and inserted an SV.
    pub sv_inserts: u64,
    /// Budget maintenance events triggered.
    pub maintenance_events: u64,
    /// Section timings (SGD / maintenance A / maintenance B).
    pub profiler: SectionProfiler,
    /// Total wall time of the training loop.
    pub wall_seconds: f64,
    /// Sum of weight degradations over all maintenance events.
    pub total_weight_degradation: f64,
    /// Objective curve (empty unless `curve_every > 0`).
    pub curve: Vec<CurvePoint>,
    /// Agreement statistics (present iff `audit`).
    pub agreement: Option<AgreementStats>,
}

impl TrainReport {
    /// Fraction of SGD steps that triggered budget maintenance — the
    /// paper's "merging frequency" (Table 3).
    pub fn merging_frequency(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.maintenance_events as f64 / self.steps as f64
        }
    }

    /// Fraction of total accounted time spent in budget maintenance.
    pub fn maintenance_fraction(&self) -> f64 {
        let total = self.profiler.total_seconds();
        if total <= 0.0 {
            0.0
        } else {
            self.profiler.maintenance_seconds() / total
        }
    }
}

/// SGD hyperparameters threaded through the generic pass loop.
pub(crate) struct SgdHyper {
    /// 0 = unbudgeted (the maintenance branch never triggers).
    pub budget: usize,
    pub lambda: f64,
    pub lr: LearningRate,
    pub curve_every: u64,
    pub curve_sample: usize,
    /// Resolved worker-thread count for curve evaluation (≥ 1).
    pub threads: usize,
}

/// The kernel-generic SGD pass loop shared by the budgeted BSGD estimator
/// (all kernels), the legacy `train_bsgd` path and the unbudgeted Pegasos
/// estimator (`budget = 0`).
///
/// Budget maintenance dispatches through the single
/// [`MaintenancePolicy`] surface: the policy owns the trigger rule
/// (slack-aware overshoot) and the event executor — there is no strategy
/// branching in this loop. After the passes the policy's hard enforcement
/// runs, so the model leaves every ingest call with `num_sv ≤ budget`
/// even when slack allowed a transient overshoot (a no-op in the classic
/// `slack = 0` regime). `audit` (optional) observes the pre-maintenance
/// model state for the Table-3 agreement instrumentation. Counters,
/// timings and the objective curve accumulate into `summary` (whose
/// `agreement` field is not touched here — the audit hook owns those
/// statistics).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_sgd_passes<K: Kernel + Copy>(
    model: &mut BudgetModel<K>,
    train: &Dataset,
    passes: usize,
    shuffle: bool,
    hyper: &SgdHyper,
    rng: &mut Rng,
    summary: &mut FitSummary,
    policy: &mut dyn MaintenancePolicy<K>,
    mut audit: Option<&mut dyn FnMut(&BudgetModel<K>)>,
) {
    let n = train.len();
    debug_assert!(n > 0);

    // Row norms come precomputed with the dataset (bit-identical to the
    // `norm2(row)` this loop used to recompute per ingest call).
    let norms = train.norms();

    // Fixed evaluation sample for the curve.
    let curve_idx: Vec<usize> = if hyper.curve_every > 0 {
        rng.sample_indices(n, hyper.curve_sample.min(n))
    } else {
        Vec::new()
    };

    let mut order: Vec<usize> = (0..n).collect();
    let wall_start = Instant::now();
    for _pass in 0..passes {
        if shuffle {
            rng.shuffle(&mut order);
        }
        for &i in &order {
            summary.steps += 1;
            let steps = summary.steps;
            {
                // RAII span: drops (and records) exactly where the old
                // `Instant::now()`/`add()` pair ended — bit-identical
                // profiler totals, plus the histogram feed.
                let _step = telemetry::span(Section::SgdStep, &mut summary.profiler);
                let x = train.row(i);
                let y = train.label(i) as f64;
                let margin = y * model.decision_with_norm(x, norms[i]);
                model.rescale(hyper.lr.shrink(steps, hyper.lambda));
                if margin < 1.0 {
                    model.push(x, hyper.lr.eta(steps) * y);
                    summary.sv_inserts += 1;
                }
            }

            if hyper.budget > 0 && policy.trigger(model.num_sv(), hyper.budget) {
                summary.maintenance_events += 1;
                telemetry::registry::count(telemetry::Counter::MaintenanceEvents);
                telemetry::emit("maintenance", || {
                    vec![
                        ("solver", Json::str("bsgd")),
                        ("num_sv", Json::num(model.num_sv() as f64)),
                        ("budget", Json::num(hyper.budget as f64)),
                    ]
                });
                if let Some(hook) = audit.as_mut() {
                    (*hook)(model);
                }
                summary.total_weight_degradation +=
                    policy.maintain(model, hyper.budget, &mut summary.profiler);
            }

            if hyper.curve_every > 0 && steps % hyper.curve_every == 0 {
                summary.curve.push(curve_point(
                    model,
                    train,
                    &curve_idx,
                    hyper.lambda,
                    steps,
                    hyper.threads,
                ));
            }
        }
    }
    // Final flush of the curve — but only if the last in-loop sample did
    // not already record this exact step. Without the guard, a run whose
    // step count is a multiple of `curve_every` logged a duplicate final
    // point, and a model trained through many small `partial_fit` batches
    // accumulated one duplicate per ingest call, corrupting the cumulative
    // curve accounting.
    if hyper.curve_every > 0 && summary.curve.last().map(|p| p.step) != Some(summary.steps) {
        summary.curve.push(curve_point(
            model,
            train,
            &curve_idx,
            hyper.lambda,
            summary.steps,
            hyper.threads,
        ));
    }
    // Hard budget enforcement at the end of the ingest call: with slack
    // the model may still hold up to `budget + ⌈slack⌉` SVs here; shed
    // the excess so callers (and the serving publish path) always see a
    // budget-respecting model. Counted as maintenance events — and
    // observed by the audit hook — like any in-loop event, which is why
    // this is an explicit loop rather than `MaintenancePolicy::enforce`
    // (enforce has no access to the summary counters or the audit
    // instrumentation). A no-op when slack = 0 (the in-loop trigger
    // already capped the model), preserving the classic event accounting
    // bit-for-bit.
    while hyper.budget > 0 && model.num_sv() > hyper.budget {
        summary.maintenance_events += 1;
        telemetry::registry::count(telemetry::Counter::MaintenanceEvents);
        telemetry::emit("maintenance", || {
            vec![
                ("solver", Json::str("bsgd")),
                ("num_sv", Json::num(model.num_sv() as f64)),
                ("budget", Json::num(hyper.budget as f64)),
            ]
        });
        if let Some(hook) = audit.as_mut() {
            (*hook)(model);
        }
        summary.total_weight_degradation +=
            policy.maintain(model, hyper.budget, &mut summary.profiler);
    }
    summary.wall_seconds += wall_start.elapsed().as_secs_f64();
}

fn curve_point<K: Kernel + Copy>(
    model: &BudgetModel<K>,
    train: &Dataset,
    idx: &[usize],
    lambda: f64,
    step: u64,
    threads: usize,
) -> CurvePoint {
    // Decision values in chunked parallel (row-granular, order-preserving:
    // identical output for every thread count); the hinge/accuracy
    // reduction stays sequential so summation order — and therefore the
    // curve — is independent of the thread count. Tiny samples stay
    // serial (spawn overhead beats the work).
    let threads = if idx.len() < 64 { 1 } else { threads };
    let decisions: Vec<f64> = crate::util::parallel::map_ranges(idx.len(), threads, |r| {
        idx[r]
            .iter()
            .map(|&i| model.decision_with_norm(train.row(i), train.norm(i)))
            .collect::<Vec<f64>>()
    })
    .into_iter()
    .flatten()
    .collect();
    let mut hinge = 0.0f64;
    let mut correct = 0usize;
    for (&i, &f) in idx.iter().zip(&decisions) {
        let y = train.label(i) as f64;
        hinge += (1.0 - y * f).max(0.0);
        if (f >= 0.0) == (y >= 0.0) {
            correct += 1;
        }
    }
    let m = idx.len().max(1) as f64;
    CurvePoint {
        step,
        objective: 0.5 * lambda * model.weight_norm2() + hinge / m,
        sample_accuracy: correct as f64 / m,
        num_sv: model.num_sv(),
    }
}

/// Internal trained state of a [`BsgdEstimator`].
struct BsgdState {
    model: AnyModel,
    summary: FitSummary,
    /// The maintenance policy, kept across `partial_fit` calls so its
    /// scratch (merge-engine buffers, the removal min-|α| index) survives
    /// the whole stream.
    policy: Option<AnyPolicy>,
    rng: Rng,
}

/// Budgeted SGD trainer behind the unified [`Estimator`] surface:
/// kernel-generic (via [`SvmConfig::kernel`]), streaming-capable (via
/// [`Estimator::partial_fit`]), with the paper's merge-based maintenance
/// available on Gaussian models and removal/projection on every kernel.
pub struct BsgdEstimator {
    config: SvmConfig,
    run: RunConfig,
    state: Option<BsgdState>,
}

impl BsgdEstimator {
    /// Validate the configuration pair and build an unfitted estimator.
    pub fn new(config: SvmConfig, run: RunConfig) -> Result<Self> {
        config.validate()?;
        run.validate()?;
        ensure!(
            config.budget >= 2,
            "budgeted SGD needs a budget of at least 2 (merging needs a pair), got {}; \
             use PegasosEstimator for unbudgeted training",
            config.budget
        );
        if run.audit {
            ensure!(
                config.kernel.supports_merging(),
                "audit instrumentation compares merge solvers and requires the Gaussian kernel"
            );
        }
        Ok(BsgdEstimator { config, run, state: None })
    }

    /// Unbudgeted construction (budget = 0: the maintenance branch never
    /// runs) — the engine behind `PegasosEstimator`.
    pub(crate) fn new_unbudgeted(kernel: KernelSpec, lambda: f64, run: RunConfig) -> Result<Self> {
        let config = SvmConfig::new()
            .kernel(kernel)
            .budget(0)
            .lambda(lambda)
            .strategy(Strategy::Removal);
        config.validate()?;
        run.validate()?;
        ensure!(!run.audit, "audit instrumentation requires a budgeted Gaussian merge run");
        Ok(BsgdEstimator { config, run, state: None })
    }

    /// Shard-local construction for the sharded streaming-ingest pipeline:
    /// identical hyperparameters, but the RNG seed is derived per shard via
    /// [`shard_seed`] so the `S` independent `partial_fit` streams are
    /// decorrelated yet reproducible, and the machine stays serial inside
    /// (the pipeline owns the cross-shard parallelism).
    pub fn new_shard(config: SvmConfig, mut run: RunConfig, shard: usize) -> Result<Self> {
        run.seed = shard_seed(run.seed, shard);
        run.threads = 1;
        Self::new(config, run)
    }

    /// Snapshot export for the serving layer: a clone of the current model
    /// plus the cumulative SGD step count (the publish weight of this
    /// shard). `None` before the first ingest.
    pub fn snapshot(&self) -> Option<(AnyModel, u64)> {
        self.state.as_ref().map(|s| (s.model.clone(), s.summary.steps))
    }

    /// The model hyperparameters this estimator was built with.
    pub fn config(&self) -> &SvmConfig {
        &self.config
    }

    /// The trained model, if fitted.
    pub fn model(&self) -> Option<&AnyModel> {
        self.state.as_ref().map(|s| &s.model)
    }

    /// Cumulative training statistics, if fitted.
    pub fn summary(&self) -> Option<&FitSummary> {
        self.state.as_ref().map(|s| &s.summary)
    }

    /// Consume the estimator, returning the trained model.
    pub fn into_model(self) -> Result<AnyModel> {
        Ok(self.state.context("estimator is not fitted")?.model)
    }

    /// Consume into the legacy [`TrainReport`] (Gaussian models only).
    pub fn into_train_report(self) -> Result<TrainReport> {
        let st = self.state.context("estimator is not fitted")?;
        let model = st.model.into_gaussian()?;
        let s = st.summary;
        Ok(TrainReport {
            model,
            steps: s.steps,
            sv_inserts: s.sv_inserts,
            maintenance_events: s.maintenance_events,
            profiler: s.profiler,
            wall_seconds: s.wall_seconds,
            total_weight_degradation: s.total_weight_degradation,
            curve: s.curve,
            agreement: s.agreement,
        })
    }

    /// One ingest call: `passes` passes over `train`, shuffling between
    /// passes iff `shuffle`. Creates the state on first use.
    fn ingest(&mut self, train: &Dataset, passes: usize, shuffle: bool) -> Result<()> {
        ensure!(!train.is_empty(), "cannot train on an empty dataset");
        if self.state.is_none() {
            let capacity = if self.config.budget > 0 {
                // Room for the slack overshoot plus the triggering insert.
                self.config.budget + (self.config.maint_slack.ceil() as usize) + 1
            } else {
                train.len().min(4096)
            };
            let mut model = AnyModel::new(train.dim(), self.config.kernel, capacity)?;
            // Execution tier, not a hyperparameter: applied to the model's
            // blocked tile path at creation (no-op for non-Gaussian).
            model.set_fast_exp(self.config.fast_exp);
            self.state = Some(BsgdState {
                model,
                summary: FitSummary {
                    agreement: self.run.audit.then(AgreementStats::new),
                    ..Default::default()
                },
                policy: None,
                rng: Rng::new(self.run.seed),
            });
        }
        let hyper = SgdHyper {
            budget: self.config.budget,
            lambda: self.config.lambda,
            lr: self
                .run
                .learning_rate
                .unwrap_or(LearningRate::PegasosInvT { lambda: self.config.lambda }),
            curve_every: self.run.curve_every,
            curve_sample: self.run.curve_sample,
            threads: crate::util::parallel::resolve_threads(self.run.threads),
        };
        let maint = self.config.maintenance();
        let grid = self.config.grid;
        let st = self.state.as_mut().unwrap();
        ensure!(
            st.model.dim() == train.dim(),
            "dataset dimension {} does not match the fitted model dimension {}",
            train.dim(),
            st.model.dim()
        );
        match &mut st.model {
            AnyModel::Gaussian(model) => {
                // Full-featured Gaussian path: any strategy + optional audit.
                let mut policy = match st.policy.take() {
                    Some(AnyPolicy::Gaussian(p)) => p,
                    _ => gaussian_policy(&maint),
                };
                let audit_table =
                    st.summary.agreement.is_some().then(|| shared_lookup_table(grid.max(2)));
                let mut agreement = st.summary.agreement.take();
                {
                    let mut audit_hook = |m: &BudgetModel<Gaussian>| {
                        if let (Some(stats), Some(table)) =
                            (agreement.as_mut(), audit_table.as_ref())
                        {
                            if let Some(rec) = audit_event(m, table) {
                                stats.events += 1;
                                stats.equal_decisions += rec.equal as u64;
                                if !rec.equal {
                                    stats.wd_diff_on_disagreement.push(rec.wd_diff);
                                }
                                if rec.factors_valid {
                                    stats.factor_gss.push(rec.factor_gss);
                                    stats.factor_lookup.push(rec.factor_lookup);
                                }
                            }
                        }
                    };
                    let audit_opt: Option<&mut dyn FnMut(&BudgetModel<Gaussian>)> =
                        if audit_table.is_some() { Some(&mut audit_hook) } else { None };
                    run_sgd_passes(
                        model,
                        train,
                        passes,
                        shuffle,
                        &hyper,
                        &mut st.rng,
                        &mut st.summary,
                        policy.as_mut(),
                        audit_opt,
                    );
                }
                st.summary.agreement = agreement;
                st.policy = Some(AnyPolicy::Gaussian(policy));
            }
            AnyModel::Linear(model) => {
                let mut policy = match st.policy.take() {
                    Some(AnyPolicy::Linear(p)) => p,
                    _ => generic_policy(&maint)?,
                };
                run_sgd_passes(
                    model,
                    train,
                    passes,
                    shuffle,
                    &hyper,
                    &mut st.rng,
                    &mut st.summary,
                    policy.as_mut(),
                    None,
                );
                st.policy = Some(AnyPolicy::Linear(policy));
            }
            AnyModel::Polynomial(model) => {
                let mut policy = match st.policy.take() {
                    Some(AnyPolicy::Polynomial(p)) => p,
                    _ => generic_policy(&maint)?,
                };
                run_sgd_passes(
                    model,
                    train,
                    passes,
                    shuffle,
                    &hyper,
                    &mut st.rng,
                    &mut st.summary,
                    policy.as_mut(),
                    None,
                );
                st.policy = Some(AnyPolicy::Polynomial(policy));
            }
        }
        Ok(())
    }
}

/// Per-shard seed derivation for sharded `partial_fit` ingest: a fixed
/// tweak keyed by the shard index, analogous to the per-class convention
/// in `solver::multiclass` (kept stable so sharded runs stay reproducible
/// across releases).
pub fn shard_seed(base: u64, shard: usize) -> u64 {
    base ^ 0x5EED ^ (shard as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

impl Estimator for BsgdEstimator {
    type Data = Dataset;

    fn fit(&mut self, data: &Dataset) -> Result<()> {
        self.state = None;
        self.ingest(data, self.run.passes, self.run.shuffle)
    }

    fn partial_fit(&mut self, data: &Dataset) -> Result<()> {
        self.ingest(data, 1, false)
    }

    fn decision_function(&self, x: &[f32]) -> Result<Vec<f64>> {
        let st = self.state.as_ref().context("estimator is not fitted")?;
        ensure!(x.len() == st.model.dim(), "feature row has wrong dimension");
        Ok(vec![st.model.decision(x)])
    }

    fn predict(&self, x: &[f32]) -> Result<f32> {
        let st = self.state.as_ref().context("estimator is not fitted")?;
        ensure!(x.len() == st.model.dim(), "feature row has wrong dimension");
        Ok(st.model.predict(x))
    }

    fn dim(&self) -> Option<usize> {
        self.state.as_ref().map(|s| s.model.dim())
    }

    /// Chunked parallel batch prediction over `RunConfig::threads` workers
    /// (row-granular split: identical output for every thread count).
    fn predict_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        let st = self.state.as_ref().context("estimator is not fitted")?;
        let d = st.model.dim();
        ensure!(
            x.len() % d == 0,
            "batch buffer length {} is not a multiple of the feature dimension {d}",
            x.len()
        );
        Ok(st
            .model
            .decision_rows(x, self.run.threads)
            .into_iter()
            .map(|f| if f >= 0.0 { 1.0 } else { -1.0 })
            .collect())
    }
}

/// Train a budgeted SVM with SGD (legacy Gaussian-only surface; thin shim
/// over [`BsgdEstimator`]). Panics on invalid options — call
/// [`BsgdOptions::validate`] first (as the CLI does) to fail gracefully.
pub fn train_bsgd(train: &Dataset, opts: &BsgdOptions) -> TrainReport {
    opts.validate().expect("invalid BsgdOptions");
    assert!(!train.is_empty());
    let (config, run) = opts.split();
    let mut est = BsgdEstimator::new(config, run).expect("validated options");
    est.fit(train).expect("BSGD training failed");
    est.into_train_report().expect("fitted estimator")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;

    fn moons_opts(budget: usize) -> (Dataset, BsgdOptions) {
        let ds = two_moons(600, 0.12, 42);
        let n = ds.len();
        // C = 10 → λ = 1/(10 n); γ = 2 suits the moon scale.
        let mut opts = BsgdOptions::with_c(budget, 10.0, 2.0, n);
        opts.passes = 6;
        opts.seed = 1;
        (ds, opts)
    }

    #[test]
    fn learns_two_moons_under_budget() {
        let (ds, opts) = moons_opts(30);
        let report = train_bsgd(&ds, &opts);
        assert!(report.model.num_sv() <= 30);
        let acc = report.model.accuracy(&ds);
        assert!(acc > 0.9, "train accuracy {acc}");
        assert_eq!(report.steps, 6 * 600);
        assert!(report.maintenance_events > 0, "budget must actually bind");
    }

    #[test]
    fn all_four_merge_solvers_reach_similar_accuracy() {
        let (ds, base) = moons_opts(25);
        let mut accs = Vec::new();
        for solver in MergeSolver::ALL {
            let mut opts = base.clone();
            opts.strategy = Strategy::Merge(solver);
            let report = train_bsgd(&ds, &opts);
            accs.push((solver.name(), report.model.accuracy(&ds)));
        }
        for &(name, acc) in &accs {
            assert!(acc > 0.88, "{name}: accuracy {acc}");
        }
        let max = accs.iter().map(|&(_, a)| a).fold(0.0, f64::max);
        let min = accs.iter().map(|&(_, a)| a).fold(1.0, f64::min);
        assert!(max - min < 0.08, "solver accuracies spread too wide: {accs:?}");
    }

    #[test]
    fn budget_constraint_never_violated_after_training() {
        for budget in [5usize, 17, 64] {
            let (ds, mut opts) = moons_opts(budget);
            opts.budget = budget;
            opts.passes = 2;
            let report = train_bsgd(&ds, &opts);
            assert!(report.model.num_sv() <= budget, "B={budget}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, opts) = moons_opts(20);
        let r1 = train_bsgd(&ds, &opts);
        let r2 = train_bsgd(&ds, &opts);
        assert_eq!(r1.steps, r2.steps);
        assert_eq!(r1.sv_inserts, r2.sv_inserts);
        assert_eq!(r1.maintenance_events, r2.maintenance_events);
        assert_eq!(r1.model.num_sv(), r2.model.num_sv());
        let probe = [0.3f32, 0.2];
        assert!((r1.model.decision(&probe) - r2.model.decision(&probe)).abs() < 1e-12);
    }

    #[test]
    fn curve_is_recorded_and_objective_decreases() {
        let (ds, mut opts) = moons_opts(40);
        opts.curve_every = 300;
        opts.passes = 8;
        let report = train_bsgd(&ds, &opts);
        assert!(report.curve.len() >= 8);
        let first = report.curve.first().unwrap().objective;
        let last = report.curve.last().unwrap().objective;
        assert!(
            last < first,
            "objective should decrease: first={first} last={last}"
        );
    }

    #[test]
    fn audit_mode_collects_agreement_stats() {
        let (ds, mut opts) = moons_opts(15);
        opts.audit = true;
        opts.passes = 2;
        opts.grid = 100;
        let report = train_bsgd(&ds, &opts);
        let stats = report.agreement.expect("audit stats");
        assert!(stats.events > 0);
        assert!(stats.equal_fraction() > 0.5, "agreement {}", stats.equal_fraction());
        assert!(stats.factor_gss.mean() >= 1.0 - 1e-9);
        assert!(stats.factor_lookup.mean() >= 1.0 - 1e-9);
    }

    #[test]
    fn unbinding_budget_means_no_maintenance() {
        let (ds, mut opts) = moons_opts(10_000);
        opts.budget = 10_000;
        opts.passes = 1;
        let report = train_bsgd(&ds, &opts);
        assert_eq!(report.maintenance_events, 0);
        assert_eq!(report.merging_frequency(), 0.0);
    }

    #[test]
    fn removal_and_projection_strategies_also_train() {
        for strat in [Strategy::Removal, Strategy::Projection] {
            let (ds, mut opts) = moons_opts(20);
            opts.strategy = strat;
            opts.passes = 3;
            let report = train_bsgd(&ds, &opts);
            assert!(report.model.num_sv() <= 20);
            let acc = report.model.accuracy(&ds);
            assert!(acc > 0.75, "{strat:?}: {acc}");
        }
    }

    #[test]
    fn merging_frequency_matches_event_count() {
        let (ds, opts) = moons_opts(12);
        let report = train_bsgd(&ds, &opts);
        let expect = report.maintenance_events as f64 / report.steps as f64;
        assert!((report.merging_frequency() - expect).abs() < 1e-15);
    }

    #[test]
    fn validate_rejects_bad_options() {
        let mut opts = BsgdOptions::new(0, 1e-3, 1.0);
        assert!(opts.validate().is_err(), "budget 0");
        opts.budget = 50;
        opts.lambda = 0.0;
        assert!(opts.validate().is_err(), "lambda 0");
        opts.lambda = 1e-3;
        opts.gamma = -2.0;
        assert!(opts.validate().is_err(), "negative gamma");
        opts.gamma = 1.0;
        opts.grid = 1;
        assert!(opts.validate().is_err(), "grid 1");
        opts.grid = 400;
        opts.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "invalid BsgdOptions")]
    fn train_bsgd_panics_with_message_on_bad_config() {
        let ds = two_moons(50, 0.1, 1);
        let opts = BsgdOptions::new(0, 1e-3, 1.0);
        train_bsgd(&ds, &opts);
    }

    // ---- estimator-surface behaviour ----

    #[test]
    fn estimator_fit_matches_legacy_train_bsgd() {
        let (ds, opts) = moons_opts(25);
        let legacy = train_bsgd(&ds, &opts);
        let (config, run) = opts.split();
        let mut est = BsgdEstimator::new(config, run).unwrap();
        est.fit(&ds).unwrap();
        let summary = est.summary().unwrap();
        assert_eq!(summary.steps, legacy.steps);
        assert_eq!(summary.sv_inserts, legacy.sv_inserts);
        assert_eq!(summary.maintenance_events, legacy.maintenance_events);
        let model = est.model().unwrap();
        let probe = [0.25f32, -0.4];
        assert!((model.decision(&probe) - legacy.model.decision(&probe)).abs() < 1e-12);
    }

    #[test]
    fn partial_fit_equals_single_unshuffled_fit_pass() {
        let ds = two_moons(300, 0.12, 9);
        let config = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(20)
            .c(10.0, ds.len());
        let run = RunConfig::new().passes(1).shuffle(false).seed(7);

        let mut fitted = BsgdEstimator::new(config.clone(), run.clone()).unwrap();
        fitted.fit(&ds).unwrap();

        let mut streamed = BsgdEstimator::new(config, run).unwrap();
        streamed.partial_fit(&ds).unwrap();

        assert_eq!(fitted.summary().unwrap().steps, streamed.summary().unwrap().steps);
        for i in 0..20 {
            let a = fitted.decision_function(ds.row(i)).unwrap()[0];
            let b = streamed.decision_function(ds.row(i)).unwrap()[0];
            assert!((a - b).abs() < 1e-12, "row {i}: {a} vs {b}");
        }
    }

    #[test]
    fn partial_fit_continues_streaming() {
        let ds = two_moons(400, 0.12, 5);
        let config = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(25)
            .c(10.0, ds.len());
        let mut est = BsgdEstimator::new(config, RunConfig::new()).unwrap();
        for _ in 0..4 {
            est.partial_fit(&ds).unwrap();
        }
        assert_eq!(est.summary().unwrap().steps, 4 * 400);
        assert!(est.model().unwrap().num_sv() <= 25);
        let acc: f64 = {
            let preds = est.predict_batch(ds.features()).unwrap();
            crate::metrics::accuracy(&preds, ds.labels())
        };
        assert!(acc > 0.85, "streamed accuracy {acc}");
    }

    #[test]
    fn fit_and_repeated_partial_fit_report_consistent_cumulative_ratios() {
        // Regression test for FitSummary accounting: a model trained
        // through N small ingest batches must report the same cumulative
        // merging frequency / maintenance ratios as one N-pass fit over
        // the identical visit order.
        let ds = two_moons(300, 0.12, 9);
        let config = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(20)
            .c(10.0, ds.len());
        let passes = 4usize;

        let mut fitted = BsgdEstimator::new(
            config.clone(),
            RunConfig::new().passes(passes).shuffle(false).seed(7),
        )
        .unwrap();
        fitted.fit(&ds).unwrap();

        let mut streamed =
            BsgdEstimator::new(config, RunConfig::new().shuffle(false).seed(7)).unwrap();
        for _ in 0..passes {
            streamed.partial_fit(&ds).unwrap();
        }

        let a = fitted.summary().unwrap();
        let b = streamed.summary().unwrap();
        assert_eq!(a.steps, (passes * ds.len()) as u64);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.sv_inserts, b.sv_inserts);
        assert_eq!(a.maintenance_events, b.maintenance_events);
        assert!(a.maintenance_events > 0, "budget must bind for the test to mean anything");
        assert!((a.merging_frequency() - b.merging_frequency()).abs() < 1e-15);
        // Section *event* counts are deterministic (times are wall-clock);
        // both fractions must be well-defined and bounded.
        for s in [Section::SgdStep, Section::MaintA, Section::MaintScan, Section::MaintApply] {
            assert_eq!(a.profiler.events(s), b.profiler.events(s), "{s:?}");
        }
        for s in [&a, &b] {
            let f = s.maintenance_fraction();
            assert!((0.0..=1.0).contains(&f), "maintenance fraction {f}");
        }
    }

    #[test]
    fn curve_steps_stay_unique_across_ingest_calls() {
        // The final curve flush must not duplicate an in-loop sample —
        // neither within one fit whose step count divides curve_every,
        // nor across many partial_fit ingest batches.
        let ds = two_moons(200, 0.12, 4);
        let config = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(25)
            .c(10.0, ds.len());
        let run = RunConfig::new().shuffle(false).seed(3).curve(100, 64);
        let mut est = BsgdEstimator::new(config.clone(), run.clone()).unwrap();
        for _ in 0..3 {
            est.partial_fit(&ds).unwrap();
        }
        let curve = &est.summary().unwrap().curve;
        assert!(!curve.is_empty());
        for pair in curve.windows(2) {
            assert!(pair[0].step < pair[1].step, "duplicate/regressing curve step");
        }
        assert_eq!(curve.last().unwrap().step, 600);

        // One fit with steps divisible by curve_every: same property.
        let mut fitted =
            BsgdEstimator::new(config, run.passes(2)).unwrap();
        fitted.fit(&ds).unwrap();
        let curve = &fitted.summary().unwrap().curve;
        for pair in curve.windows(2) {
            assert!(pair[0].step < pair[1].step, "duplicate/regressing curve step");
        }
        assert_eq!(curve.last().unwrap().step, 400);
    }

    #[test]
    fn shard_seeds_are_distinct_and_stable() {
        let base = 42u64;
        let seeds: Vec<u64> = (0..8).map(|s| shard_seed(base, s)).collect();
        for (i, &a) in seeds.iter().enumerate() {
            assert_ne!(a, base, "shard {i} must not reuse the base seed");
            for &b in &seeds[i + 1..] {
                assert_ne!(a, b, "shard seeds collide");
            }
        }
        // Stable convention (reproducibility across releases).
        assert_eq!(shard_seed(base, 0), base ^ 0x5EED);
    }

    #[test]
    fn snapshot_exports_model_clone_and_steps() {
        let ds = two_moons(150, 0.12, 6);
        let config = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(15)
            .c(10.0, ds.len());
        let mut est = BsgdEstimator::new(config, RunConfig::new().shuffle(false)).unwrap();
        assert!(est.snapshot().is_none());
        est.partial_fit(&ds).unwrap();
        let (snap, steps) = est.snapshot().unwrap();
        assert_eq!(steps, 150);
        let probe = [0.1f32, 0.4];
        assert_eq!(
            snap.decision(&probe).to_bits(),
            est.model().unwrap().decision(&probe).to_bits()
        );
        // The snapshot is a clone: further training must not affect it.
        let before = snap.decision(&probe);
        est.partial_fit(&ds).unwrap();
        assert_eq!(snap.decision(&probe).to_bits(), before.to_bits());
    }

    #[test]
    fn non_gaussian_kernels_train_with_removal() {
        // Linearly separable blobs: the linear kernel should do well.
        let mut ds = Dataset::empty("blobs", 2);
        let mut rng = Rng::new(11);
        for _ in 0..150 {
            ds.push_row(&[rng.normal() as f32 * 0.3 - 2.0, rng.normal() as f32 * 0.3], 1.0);
            ds.push_row(&[rng.normal() as f32 * 0.3 + 2.0, rng.normal() as f32 * 0.3], -1.0);
        }
        for kernel in [KernelSpec::linear(), KernelSpec::polynomial(2, 1.0)] {
            let config = SvmConfig::new()
                .kernel(kernel)
                .budget(30)
                .strategy(Strategy::Removal)
                .c(10.0, ds.len());
            let mut est = BsgdEstimator::new(config, RunConfig::new().passes(4)).unwrap();
            est.fit(&ds).unwrap();
            assert!(est.model().unwrap().num_sv() <= 30);
            let preds = est.predict_batch(ds.features()).unwrap();
            let acc = crate::metrics::accuracy(&preds, ds.labels());
            assert!(acc > 0.9, "{}: accuracy {acc}", kernel.describe());
        }
    }

    #[test]
    fn merge_with_non_gaussian_kernel_is_rejected_at_construction() {
        let config = SvmConfig::new().kernel(KernelSpec::linear()).budget(10);
        let err = match BsgdEstimator::new(config, RunConfig::new()) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("merge + linear must be rejected"),
        };
        assert!(err.contains("removal or projection"), "{err}");
    }

    #[test]
    fn unfitted_estimator_errors_cleanly() {
        let est =
            BsgdEstimator::new(SvmConfig::new(), RunConfig::new()).unwrap();
        assert!(!est.is_fitted());
        assert!(est.predict(&[0.0, 0.0]).is_err());
        assert!(est.decision_function(&[0.0, 0.0]).is_err());
        assert!(est.predict_batch(&[0.0, 0.0]).is_err());
    }
}

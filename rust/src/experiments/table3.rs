//! Table 3: training-time improvement of the lookup methods over
//! GSS-standard, merging frequency, decision agreement and WD factors.
//!
//! Left half (per dataset × budget): relative improvement of total
//! training time, `(t_GSS − t_lookup)/t_GSS`, averaged over runs.
//! Right half (budget = first budget): merging frequency, fraction of
//! events where GSS-standard and Lookup-WD pick the same partner, and the
//! factor by which each method's (exact) WD exceeds the GSS-precise
//! optimum — collected by the audit instrumentation running both solvers
//! side by side inside a single BSGD run, exactly as the paper describes.

use anyhow::Result;

use super::report::{write_csv, MarkdownTable};
use super::{options_for, prepare, runner::run_jobs};
use crate::budget::{MergeSolver, Strategy};
use crate::config::ExperimentConfig;
use crate::solver::train_bsgd;
use crate::util::stats::mean;

/// Timing cell for one (dataset, budget, method): per-run wall seconds.
#[derive(Debug, Clone)]
pub struct TimeCell {
    pub dataset: String,
    pub budget: usize,
    pub method: MergeSolver,
    pub wall_seconds: Vec<f64>,
    pub maint_seconds: Vec<f64>,
    pub section_a_seconds: Vec<f64>,
}

/// One Table-3 row (per dataset × budget, plus audit stats on the first
/// budget).
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub dataset: String,
    pub budget: usize,
    /// (t_GSS − t_Lookup-h)/t_GSS, percent.
    pub improvement_lookup_h: f64,
    /// (t_GSS − t_Lookup-WD)/t_GSS, percent.
    pub improvement_lookup_wd: f64,
    /// Maintenance events / SGD steps (only on the first budget row).
    pub merging_frequency: Option<f64>,
    /// Fraction of equal GSS vs Lookup-WD decisions.
    pub equal_decisions: Option<f64>,
    /// Mean exact-WD factor of GSS-standard vs GSS-precise optimum.
    pub factor_gss: Option<f64>,
    /// Mean exact-WD factor of Lookup-WD vs GSS-precise optimum.
    pub factor_lookup: Option<f64>,
}

/// Methods timed for this table.
const TIMED: [MergeSolver; 3] =
    [MergeSolver::GssStandard, MergeSolver::LookupH, MergeSolver::LookupWd];

/// Run the Table-3 experiment. Returns (rows, raw timing cells).
pub fn run(cfg: &ExperimentConfig) -> Result<(Vec<Table3Row>, Vec<TimeCell>)> {
    let mut rows = Vec::new();
    let mut all_cells = Vec::new();
    for profile in cfg.profiles() {
        let prep = std::sync::Arc::new(prepare(profile, cfg));

        // Timing runs: (method, budget, run). Timing jobs run single-file
        // (threads=1) to avoid cross-run interference on shared caches —
        // the numbers feed a time-ratio claim.
        let mut jobs = Vec::new();
        for &budget in &profile.budgets {
            for &method in &TIMED {
                for run_idx in 0..cfg.runs {
                    let prep = std::sync::Arc::clone(&prep);
                    let cfg2 = cfg.clone();
                    jobs.push(move || {
                        let opts =
                            options_for(&prep, &cfg2, Strategy::Merge(method), budget, run_idx);
                        let report = train_bsgd(&prep.train, &opts);
                        (
                            budget,
                            method,
                            report.wall_seconds,
                            report.profiler.maintenance_seconds(),
                            report.profiler.seconds(crate::metrics::Section::MaintA),
                        )
                    });
                }
            }
        }
        let results = run_jobs(jobs, 1);
        let mut cells: Vec<TimeCell> = Vec::new();
        for &budget in &profile.budgets {
            for &method in &TIMED {
                let mine: Vec<&(usize, MergeSolver, f64, f64, f64)> = results
                    .iter()
                    .filter(|(b, m, ..)| *b == budget && *m == method)
                    .collect();
                cells.push(TimeCell {
                    dataset: profile.name.to_uppercase(),
                    budget,
                    method,
                    wall_seconds: mine.iter().map(|r| r.2).collect(),
                    maint_seconds: mine.iter().map(|r| r.3).collect(),
                    section_a_seconds: mine.iter().map(|r| r.4).collect(),
                });
            }
        }

        // Audit run (budget = first) for the right half of the table.
        let audit = {
            let mut opts = options_for(
                &prep,
                cfg,
                Strategy::Merge(MergeSolver::GssStandard),
                profile.budgets[0],
                0,
            );
            opts.audit = true;
            train_bsgd(&prep.train, &opts)
        };
        let stats = audit.agreement.clone().expect("audit enabled");

        for (bi, &budget) in profile.budgets.iter().enumerate() {
            let wall = |m: MergeSolver| {
                mean(
                    &cells
                        .iter()
                        .find(|c| c.budget == budget && c.method == m)
                        .unwrap()
                        .wall_seconds,
                )
            };
            let t_gss = wall(MergeSolver::GssStandard);
            let improvement = |m: MergeSolver| 100.0 * (t_gss - wall(m)) / t_gss.max(1e-12);
            rows.push(Table3Row {
                dataset: profile.name.to_uppercase(),
                budget,
                improvement_lookup_h: improvement(MergeSolver::LookupH),
                improvement_lookup_wd: improvement(MergeSolver::LookupWd),
                merging_frequency: (bi == 0).then(|| audit.merging_frequency()),
                equal_decisions: (bi == 0 && stats.events > 0).then(|| stats.equal_fraction()),
                factor_gss: (bi == 0 && stats.factor_gss.count() > 0)
                    .then(|| stats.factor_gss.mean()),
                factor_lookup: (bi == 0 && stats.factor_lookup.count() > 0)
                    .then(|| stats.factor_lookup.mean()),
            });
        }
        all_cells.extend(cells);
    }
    Ok((rows, all_cells))
}

/// Render + persist the table.
pub fn render(rows: &[Table3Row], cells: &[TimeCell], cfg: &ExperimentConfig) -> Result<String> {
    let mut t = MarkdownTable::new(&[
        "data set",
        "budget",
        "Lookup-h vs GSS",
        "Lookup-WD vs GSS",
        "merging freq",
        "equal decisions",
        "factor GSS",
        "factor Lookup-WD",
    ]);
    let opt = |v: Option<f64>, f: &dyn Fn(f64) -> String| v.map(f).unwrap_or_default();
    for r in rows {
        t.row(vec![
            r.dataset.clone(),
            r.budget.to_string(),
            format!("{:+.3}%", r.improvement_lookup_h),
            format!("{:+.3}%", r.improvement_lookup_wd),
            opt(r.merging_frequency, &|v| format!("{:.0}%", 100.0 * v)),
            opt(r.equal_decisions, &|v| format!("{:.2}%", 100.0 * v)),
            opt(r.factor_gss, &|v| format!("{v:.5}")),
            opt(r.factor_lookup, &|v| format!("{v:.5}")),
        ]);
    }
    let csv: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                c.dataset.clone(),
                c.budget.to_string(),
                c.method.name().to_string(),
                format!("{:.6}", mean(&c.wall_seconds)),
                format!("{:.6}", mean(&c.maint_seconds)),
                format!("{:.6}", mean(&c.section_a_seconds)),
            ]
        })
        .collect();
    write_csv(
        std::path::Path::new(&cfg.out_dir).join("table3_timing.csv"),
        &["dataset", "budget", "method", "wall_s", "maintenance_s", "section_a_s"],
        &csv,
    )?;
    let csv2: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.dataset.clone(),
                r.budget.to_string(),
                format!("{:.4}", r.improvement_lookup_h),
                format!("{:.4}", r.improvement_lookup_wd),
                r.merging_frequency.map(|v| format!("{v:.4}")).unwrap_or_default(),
                r.equal_decisions.map(|v| format!("{v:.4}")).unwrap_or_default(),
                r.factor_gss.map(|v| format!("{v:.6}")).unwrap_or_default(),
                r.factor_lookup.map(|v| format!("{v:.6}")).unwrap_or_default(),
            ]
        })
        .collect();
    write_csv(
        std::path::Path::new(&cfg.out_dir).join("table3.csv"),
        &[
            "dataset", "budget", "improvement_lookup_h_pct", "improvement_lookup_wd_pct",
            "merging_frequency", "equal_decisions", "factor_gss", "factor_lookup",
        ],
        &csv2,
    )?;
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_table3_reproduces_paper_shape() {
        // SUSY: high merging frequency → plenty of maintenance events even
        // at tiny scale.
        let cfg = ExperimentConfig {
            scale: 0.02,
            runs: 2,
            // The paper's grid: the "lookup is more precise than
            // GSS-standard" claim needs the fine 400×400 table.
            grid: 400,
            datasets: vec!["susy".into()],
            out_dir: std::env::temp_dir()
                .join("budgetsvm-t3-test")
                .to_string_lossy()
                .into_owned(),
            ..Default::default()
        };
        let (rows, cells) = run(&cfg).unwrap();
        assert_eq!(rows.len(), 2); // two budgets
        assert_eq!(cells.len(), 6); // 2 budgets × 3 methods
        let first = &rows[0];
        // Shape checks (paper): lookup never slower than GSS by a margin,
        // agreement high, factors ≥ 1 with lookup ≤ gss. The timing claim
        // only holds in optimized builds — debug-mode inlining/bounds-check
        // behaviour distorts the per-candidate cost ratio completely.
        if !cfg!(debug_assertions) {
            assert!(
                first.improvement_lookup_wd > -10.0,
                "wd impr {}",
                first.improvement_lookup_wd
            );
        }
        let eq = first.equal_decisions.unwrap();
        assert!(eq > 0.6, "agreement {eq}");
        let fg = first.factor_gss.unwrap();
        let fl = first.factor_lookup.unwrap();
        assert!(fg >= 1.0 - 1e-9 && fl >= 1.0 - 1e-9);
        assert!(fl <= fg + 1e-6, "lookup factor {fl} vs gss {fg}");
        assert!(first.merging_frequency.unwrap() > 0.0);
        let rendered = render(&rows, &cells, &cfg).unwrap();
        assert!(rendered.contains("SUSY"));
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}

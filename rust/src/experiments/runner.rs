//! Threaded experiment runner.
//!
//! Executes a list of independent jobs on a worker pool (std threads + a
//! shared work queue; tokio is not in the offline vendor set and the jobs
//! are CPU-bound anyway). Results come back in submission order.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// Run `jobs` on `threads` workers; returns results in job order.
pub fn run_jobs<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    let threads = threads.clamp(1, n.max(1));
    // Queue of (index, job); results slotted by index.
    let queue: Arc<Mutex<VecDeque<(usize, F)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().collect()));
    let results: Arc<Mutex<Vec<Option<T>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let queue = Arc::clone(&queue);
            let results = Arc::clone(&results);
            scope.spawn(move || loop {
                let job = queue.lock().unwrap().pop_front();
                match job {
                    Some((idx, f)) => {
                        let out = f();
                        results.lock().unwrap()[idx] = Some(out);
                    }
                    None => break,
                }
            });
        }
    });

    Arc::try_unwrap(results)
        .unwrap_or_else(|_| panic!("worker leaked a results handle"))
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every job must produce a result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_preserve_submission_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..50)
            .map(|i| {
                Box::new(move || {
                    // Uneven work so completion order scrambles.
                    let mut acc = 0usize;
                    for k in 0..((50 - i) * 1000) {
                        acc = acc.wrapping_add(k);
                    }
                    std::hint::black_box(acc);
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = run_jobs(jobs, 8);
        assert_eq!(out, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_works() {
        let jobs: Vec<_> = (0..5).map(|i| move || i * 2).collect();
        assert_eq!(run_jobs(jobs, 1), vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn more_threads_than_jobs() {
        let jobs: Vec<_> = (0..3).map(|i| move || i).collect();
        assert_eq!(run_jobs(jobs, 64), vec![0, 1, 2]);
    }

    #[test]
    fn empty_job_list() {
        let jobs: Vec<fn() -> u8> = Vec::new();
        assert!(run_jobs(jobs, 4).is_empty());
    }
}

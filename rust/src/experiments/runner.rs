//! Threaded experiment runner.
//!
//! The pool implementation lives in [`crate::util::parallel`] since it is
//! shared with one-vs-rest training and batch prediction; this module
//! re-exports [`run_jobs`] so experiment code keeps its historical import
//! path (`super::runner::run_jobs`).

pub use crate::util::parallel::run_jobs;

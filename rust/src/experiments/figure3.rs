//! Figure 3: breakdown of the merging time into Section A (computing `h`
//! via GSS or lookup — or looking up `WD` for Lookup-WD) and Section B
//! (all other budget-maintenance work: κ kernel row, loop overhead, `α_z`,
//! constructing `z`).
//!
//! One training run per (dataset, method) at the first budget, single-
//! threaded timing; output is a grouped ASCII bar chart plus
//! `figure3.csv`.

use anyhow::Result;

use super::report::{bar, write_csv};
use super::{options_for, prepare, runner::run_jobs, METHODS};
use crate::budget::{MergeSolver, Strategy};
use crate::config::ExperimentConfig;
use crate::metrics::Section;
use crate::solver::train_bsgd;

/// One bar of the figure.
#[derive(Debug, Clone)]
pub struct Figure3Bar {
    pub dataset: String,
    pub method: MergeSolver,
    pub section_a_seconds: f64,
    pub section_b_seconds: f64,
    pub maintenance_events: u64,
}

/// Run the Figure-3 experiment.
pub fn run(cfg: &ExperimentConfig) -> Result<Vec<Figure3Bar>> {
    let mut bars = Vec::new();
    for profile in cfg.profiles() {
        let prep = std::sync::Arc::new(prepare(profile, cfg));
        let budget = profile.budgets[0];
        let jobs: Vec<_> = METHODS
            .iter()
            .map(|&method| {
                let prep = std::sync::Arc::clone(&prep);
                let cfg = cfg.clone();
                move || {
                    let opts = options_for(&prep, &cfg, Strategy::Merge(method), budget, 0);
                    let report = train_bsgd(&prep.train, &opts);
                    Figure3Bar {
                        dataset: prep.profile.name.to_uppercase(),
                        method,
                        section_a_seconds: report.profiler.seconds(Section::MaintA),
                        section_b_seconds: report.profiler.section_b_seconds(),
                        maintenance_events: report.maintenance_events,
                    }
                }
            })
            .collect();
        // Single-threaded: these are timing measurements.
        bars.extend(run_jobs(jobs, 1));
    }
    Ok(bars)
}

/// Render + persist.
pub fn render(bars: &[Figure3Bar], cfg: &ExperimentConfig) -> Result<String> {
    let max = bars
        .iter()
        .map(|b| b.section_a_seconds + b.section_b_seconds)
        .fold(0.0f64, f64::max);
    let mut out = String::new();
    out.push_str("Merging-time breakdown (A = compute h / lookup WD, B = other merge ops)\n\n");
    let mut csv = Vec::new();
    let mut last_dataset = String::new();
    for b in bars {
        if b.dataset != last_dataset {
            out.push_str(&format!("{}\n", b.dataset));
            last_dataset = b.dataset.clone();
        }
        let total = b.section_a_seconds + b.section_b_seconds;
        out.push_str(&format!(
            "  {:<13} A {:>8.3}s  B {:>8.3}s  |{}{}|\n",
            b.method.name(),
            b.section_a_seconds,
            b.section_b_seconds,
            bar(b.section_a_seconds, max, 40),
            "·".repeat(
                bar(total, max, 40).chars().count()
                    - bar(b.section_a_seconds, max, 40).chars().count()
            ),
        ));
        csv.push(vec![
            b.dataset.clone(),
            b.method.name().to_string(),
            format!("{:.6}", b.section_a_seconds),
            format!("{:.6}", b.section_b_seconds),
            b.maintenance_events.to_string(),
        ]);
    }
    write_csv(
        std::path::Path::new(&cfg.out_dir).join("figure3.csv"),
        &["dataset", "method", "section_a_s", "section_b_s", "maintenance_events"],
        &csv,
    )?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure3_shape_lookup_shrinks_section_a() {
        // SUSY: the hard profile with high merging frequency, so Section A
        // accumulates enough events for a stable ordering even in debug
        // builds.
        let cfg = ExperimentConfig {
            scale: 0.02,
            grid: 100,
            datasets: vec!["susy".into()],
            out_dir: std::env::temp_dir()
                .join("budgetsvm-f3-test")
                .to_string_lossy()
                .into_owned(),
            ..Default::default()
        };
        let bars = run(&cfg).unwrap();
        assert_eq!(bars.len(), 4);
        let a = |m: MergeSolver| {
            bars.iter().find(|b| b.method == m).unwrap().section_a_seconds
        };
        // The paper's Figure 3 shape: GSS-precise > GSS-standard > lookups
        // in Section A.
        assert!(a(MergeSolver::GssPrecise) > a(MergeSolver::GssStandard));
        assert!(a(MergeSolver::GssStandard) > a(MergeSolver::LookupWd));
        assert!(a(MergeSolver::GssStandard) > a(MergeSolver::LookupH));
        // All methods do essentially the same number of events.
        let events: Vec<u64> = bars.iter().map(|b| b.maintenance_events).collect();
        let spread = *events.iter().max().unwrap() - *events.iter().min().unwrap();
        assert!(spread as f64 <= 0.05 * *events.iter().max().unwrap() as f64 + 2.0);
        let text = render(&bars, &cfg).unwrap();
        assert!(text.contains("SUSY"));
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}

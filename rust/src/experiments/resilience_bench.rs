//! Tracked resilience harness (`repro bench --resilience`): drives the
//! serve tier through a deterministic [`FaultPlan`] — a shard-worker
//! panic, a torn-write crash between WAL append and checkpoint, a
//! stalled client — and reports what the fault-tolerance machinery
//! actually delivered, as `BENCH_resilience.json`:
//!
//! 1. **Durability** — acked (WAL-framed) rows vs rows recovered by
//!    `ShardedIngest::recover`; `rows_lost` must be 0, and the recovered
//!    model must be byte-identical to an uninterrupted reference run
//!    over the same acked rows (CI gates on both).
//! 2. **Supervision** — worker restarts and re-queued rows from the
//!    injected panic.
//! 3. **Registry lifecycle** — a rollback exercised against the
//!    recovered history, and a degenerate shadow candidate pushed
//!    through the live-traffic gate (must be auto-rejected).
//! 4. **Latency under stalls** — micro-batcher p50/p99 for healthy
//!    clients while one injected slow client stalls between requests,
//!    plus the typed zero-deadline expiry path.
//!
//! Every trigger in the plan is a row count, so the whole harness is
//! deterministic in `(seed, plan)` up to wall-clock columns.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::data::Dataset;
use crate::model::AnyModel;
use crate::serve::faults::is_injected_crash;
use crate::serve::{
    wal, BatcherOptions, FaultPlan, MicroBatcher, ModelRegistry, PredictError, ShadowPolicy,
    ShardedIngest,
};
use crate::solver::{RunConfig, SolverSpec, SvmConfig};
use crate::util::json::Json;
use crate::util::parallel;
use crate::util::stats::quantile_sorted;

/// File name of the emitted report.
pub const REPORT_FILE: &str = "BENCH_resilience.json";

/// Rows per ingest chunk on the faulted run (small enough that the
/// injected panic is healed on a later chunk, before the crash fires).
const INGEST_CHUNK: usize = 128;

/// Healthy concurrent prediction clients in the stall phase.
const PREDICT_CLIENTS: usize = 4;

/// Live rows sampled (evenly across the stream, so both classes appear)
/// into the shadow window before the degenerate candidate is judged.
const SHADOW_SAMPLE_ROWS: usize = 64;

/// Run the harness: a faulted ingest over `stream` under `plan`, then
/// recovery, rollback, shadow-gate and stalled-client phases. `scratch`
/// hosts the WAL/checkpoint/dump files (created if missing; stale bench
/// files are overwritten). Returns the JSON report.
pub fn run(
    stream: &Dataset,
    svm: &SvmConfig,
    seed: u64,
    shards: usize,
    publish_every: usize,
    plan: FaultPlan,
    scratch: &Path,
) -> Result<Json> {
    ensure!(!stream.is_empty(), "bench stream must not be empty");
    std::fs::create_dir_all(scratch)
        .with_context(|| format!("cannot create scratch directory {}", scratch.display()))?;
    let wal_path = scratch.join("bench-serve.wal");
    let ckpt_path = scratch.join("bench-serve.ckpt");
    let _ = std::fs::remove_file(&wal_path);
    let _ = std::fs::remove_file(&ckpt_path);

    // ---- phase 1: faulted ingest (panic + torn-write crash) ----
    let reg_faulted = Arc::new(ModelRegistry::new());
    let mut ing = ShardedIngest::new(
        svm.clone(),
        RunConfig::new().seed(seed),
        shards,
        publish_every,
        Arc::clone(&reg_faulted),
    )?;
    ing.enable_wal(&wal_path)?;
    ing.checkpoint_at(&ckpt_path);
    ing.fault_inject(plan)?;
    let mut crashed = false;
    let mut start = 0usize;
    while start < stream.len() {
        let idx: Vec<usize> = (start..(start + INGEST_CHUNK).min(stream.len())).collect();
        match ing.ingest(&stream.subset(&idx, "resilience-chunk")) {
            Ok(()) => {}
            Err(e) => {
                let msg = e.to_string();
                ensure!(is_injected_crash(&msg), "unexpected pipeline failure: {msg}");
                crashed = true;
                break;
            }
        }
        start += INGEST_CHUNK;
    }
    let faulted = ing.finish()?;

    // ---- phase 2: the durability ledger (WAL truth after the crash) ----
    let replayed =
        wal::replay(&wal_path, None).context("replaying the WAL the crash left behind")?;
    let acked_rows = replayed.rows.len() as u64;

    // ---- phase 3: recovery ----
    let reg_rec = Arc::new(ModelRegistry::new());
    let (rec, recovery) = ShardedIngest::recover(
        SolverSpec::Bsgd,
        svm.clone(),
        RunConfig::new().seed(seed),
        shards,
        publish_every,
        Arc::clone(&reg_rec),
        &wal_path,
        Some(&ckpt_path),
    )?;
    let recovered_rows = rec.rows_ingested();
    let rows_lost = acked_rows.saturating_sub(recovered_rows);

    // ---- phase 4: byte-identity against an uninterrupted reference ----
    // The reference pipeline never sees a fault and trains exactly the
    // acked rows; determinism promises the recovered model matches it
    // byte for byte.
    let reg_ref = Arc::new(ModelRegistry::new());
    let mut reference = ShardedIngest::new(
        svm.clone(),
        RunConfig::new().seed(seed),
        shards,
        publish_every,
        Arc::clone(&reg_ref),
    )?;
    let mut byte_identical = false;
    if !replayed.rows.is_empty() {
        reference.ingest(&replayed.rows)?;
        reference.publish_now()?;
        let rec_dump = scratch.join("bench-recovered.mdl");
        let ref_dump = scratch.join("bench-reference.mdl");
        reg_rec.dump(&rec_dump)?;
        reg_ref.dump(&ref_dump)?;
        byte_identical = std::fs::read(&rec_dump)? == std::fs::read(&ref_dump)?;
    }
    reference.finish()?;

    // ---- phase 5: rollback against the recovered history ----
    let mut restored_version = 0u64;
    if reg_rec.history_len() >= 2 {
        restored_version = reg_rec.rollback(1)?;
    }
    let rec_life = reg_rec.lifecycle_stats();
    rec.finish()?;

    // ---- phase 6: shadow gate — a degenerate candidate must not oust
    // the incumbent the reference registry serves ----
    let d = stream.dim();
    let step = (stream.len() / SHADOW_SAMPLE_ROWS).max(1);
    for i in (0..stream.len()).step_by(step) {
        reg_ref.record_live_rows(stream.row(i), d);
    }
    // A single SV at the origin with a positive coefficient: a constant
    // "+1" classifier, maximally wrong on one class.
    let mut degenerate = AnyModel::new(d, svm.kernel, 2)?;
    degenerate.push(&vec![0.0f32; d], 1.0);
    let outcome = reg_ref.publish_shadowed(degenerate, &ShadowPolicy::default());
    let shadow_life = reg_ref.lifecycle_stats();

    // ---- phase 7: predict latency while one client stalls ----
    let batcher = MicroBatcher::new(
        Arc::clone(&reg_ref),
        BatcherOptions { max_batch_rows: 64, threads: 2 },
    );
    let stall = Duration::from_millis(plan.stall_client_ms.max(1));
    let stop = Arc::new(AtomicBool::new(false));
    let staller = {
        let client = batcher.client();
        let row: Vec<f32> = stream.row(0).to_vec();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(stall);
                if client.predict_deadline(&row, row.len(), Some(Duration::from_secs(30))).is_err()
                {
                    break;
                }
            }
        })
    };
    let t0 = Instant::now();
    let per_client: Vec<Vec<f64>> =
        parallel::map_ranges(stream.len(), PREDICT_CLIENTS, |range| {
            let client = batcher.client();
            let mut lat = Vec::with_capacity(range.len());
            for i in range {
                let t = Instant::now();
                client
                    .predict_deadline(stream.row(i), d, Some(Duration::from_secs(30)))
                    .expect("bench predict failed");
                lat.push(t.elapsed().as_secs_f64());
            }
            lat
        });
    let predict_seconds = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    // The typed expiry path: zero-deadline requests must come back as
    // `Overloaded`, never as a hang or an untyped error.
    let client = batcher.client();
    let mut deadline_expired = 0u64;
    for i in 0..8.min(stream.len()) {
        if let Err(PredictError::Overloaded { .. }) =
            client.predict_deadline(stream.row(i), d, Some(Duration::ZERO))
        {
            deadline_expired += 1;
        }
    }
    let _ = staller.join();
    let bstats = client.stats();
    batcher.shutdown();

    let mut latencies: Vec<f64> = per_client.into_iter().flatten().collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50_us = quantile_sorted(&latencies, 0.5) * 1e6;
    let p99_us = quantile_sorted(&latencies, 0.99) * 1e6;

    Ok(Json::object(vec![
        ("schema", Json::str("bench_resilience/v1")),
        ("rows", Json::num(stream.len() as f64)),
        ("dim", Json::num(d as f64)),
        ("shards", Json::num(shards as f64)),
        ("seed", Json::num(seed as f64)),
        ("publish_every", Json::num(publish_every as f64)),
        (
            "fault_plan",
            Json::object(vec![
                (
                    "worker_panic_shard",
                    plan.worker_panic.map(|p| Json::num(p.shard as f64)).unwrap_or(Json::Null),
                ),
                (
                    "worker_panic_after_rows",
                    plan.worker_panic
                        .map(|p| Json::num(p.after_rows as f64))
                        .unwrap_or(Json::Null),
                ),
                (
                    "crash_at_rows",
                    plan.crash_at_rows.map(|r| Json::num(r as f64)).unwrap_or(Json::Null),
                ),
                ("tear_wal_on_crash", Json::Bool(plan.tear_wal_on_crash)),
                ("stall_client_ms", Json::num(plan.stall_client_ms as f64)),
            ]),
        ),
        (
            "recovery",
            Json::object(vec![
                ("crashed", Json::Bool(crashed)),
                ("acked_rows", Json::num(acked_rows as f64)),
                ("torn_tail_dropped", Json::Bool(replayed.torn_tail)),
                ("recovered_rows", Json::num(recovered_rows as f64)),
                ("rows_lost", Json::num(rows_lost as f64)),
                ("byte_identical", Json::Bool(byte_identical)),
                ("recovery_seconds", Json::num(recovery.recovery_seconds)),
                ("checkpoint_rows", Json::num(recovery.checkpoint_rows as f64)),
                ("checkpoint_version", Json::num(recovery.checkpoint_version as f64)),
            ]),
        ),
        (
            "supervision",
            Json::object(vec![
                ("worker_restarts", Json::num(faulted.worker_restarts as f64)),
                ("rows_requeued", Json::num(faulted.rows_requeued as f64)),
                ("rows_before_crash", Json::num(faulted.rows as f64)),
            ]),
        ),
        (
            "lifecycle",
            Json::object(vec![
                ("history_len", Json::num(reg_rec.history_len() as f64)),
                ("rollbacks", Json::num(rec_life.rollbacks as f64)),
                ("restored_version", Json::num(restored_version as f64)),
                ("shadow_candidate_rejected", Json::Bool(!outcome.accepted)),
                ("shadow_rejected_total", Json::num(shadow_life.rejected as f64)),
                (
                    "shadow_agreement",
                    outcome.agreement.map(Json::num).unwrap_or(Json::Null),
                ),
                ("shadow_evaluated_rows", Json::num(outcome.evaluated_rows as f64)),
            ]),
        ),
        (
            "predict",
            Json::object(vec![
                ("stall_client_ms", Json::num(plan.stall_client_ms as f64)),
                ("p50_us", Json::num(p50_us)),
                ("p99_us", Json::num(p99_us)),
                (
                    "rows_per_s",
                    Json::num(stream.len() as f64 / predict_seconds.max(1e-12)),
                ),
                ("deadline_expired", Json::num(deadline_expired as f64)),
                ("expired_total", Json::num(bstats.expired as f64)),
            ]),
        ),
    ]))
}

/// Write the report as `BENCH_resilience.json` under `out_dir` (created
/// if missing); returns the written path.
pub fn write(report: &Json, out_dir: &str) -> Result<String> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("cannot create output directory {out_dir}"))?;
    let path = format!("{}/{}", out_dir.trim_end_matches('/'), REPORT_FILE);
    std::fs::write(&path, format!("{report}\n"))
        .with_context(|| format!("cannot write {path}"))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::kernel::KernelSpec;

    #[test]
    fn harness_reports_zero_loss_and_byte_identical_recovery() {
        let ds = two_moons(400, 0.12, 17);
        let svm = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(25)
            .c(10.0, ds.len());
        // Explicit plan: the panic (shard 0 at 30 rows) fires inside the
        // first 128-row chunk and is healed on the second; the crash at
        // row 250 fires during the second chunk's WAL append, leaving a
        // torn tail. All row counts, fully deterministic.
        let mut plan = FaultPlan::none().with_worker_panic(0, 30).with_crash_at_rows(250, true);
        plan.stall_client_ms = 5;
        let scratch = std::env::temp_dir().join("budgetsvm-resilience-bench");
        let report = run(&ds, &svm, 7, 2, 100, plan, &scratch).unwrap();

        assert_eq!(
            report.get("schema").and_then(Json::as_str),
            Some("bench_resilience/v1")
        );
        let rec = report.get("recovery").expect("recovery section");
        assert_eq!(rec.get("crashed"), Some(&Json::Bool(true)));
        // Chunks are 128 rows: the crash fires while ingesting rows
        // 128..256, which are WAL-framed (acked) before the simulated
        // death — so the ledger holds exactly 256 rows, torn tail dropped.
        assert_eq!(rec.get("acked_rows").and_then(Json::as_usize), Some(256));
        assert_eq!(rec.get("torn_tail_dropped"), Some(&Json::Bool(true)));
        assert_eq!(rec.get("recovered_rows").and_then(Json::as_usize), Some(256));
        assert_eq!(rec.get("rows_lost").and_then(Json::as_usize), Some(0));
        assert_eq!(rec.get("byte_identical"), Some(&Json::Bool(true)));
        assert!(rec.get("recovery_seconds").and_then(Json::as_f64).unwrap() >= 0.0);
        // The cadence publish at row 128 checkpointed before the crash.
        assert_eq!(rec.get("checkpoint_rows").and_then(Json::as_usize), Some(128));

        let sup = report.get("supervision").expect("supervision section");
        assert!(sup.get("worker_restarts").and_then(Json::as_usize).unwrap() >= 1);
        assert!(sup.get("rows_requeued").and_then(Json::as_usize).unwrap() > 0);

        let life = report.get("lifecycle").expect("lifecycle section");
        assert_eq!(life.get("rollbacks").and_then(Json::as_usize), Some(1));
        assert_eq!(life.get("shadow_candidate_rejected"), Some(&Json::Bool(true)));
        assert!(life.get("shadow_evaluated_rows").and_then(Json::as_usize).unwrap() >= 32);

        let pred = report.get("predict").expect("predict section");
        assert!(pred.get("p99_us").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(pred.get("deadline_expired").and_then(Json::as_usize), Some(8));

        // Round-trips through the in-repo JSON parser, and the writer
        // lands it under the canonical name.
        assert_eq!(Json::parse(&report.to_string()).unwrap(), report);
        let out = scratch.to_string_lossy().into_owned();
        let path = write(&report, &out).unwrap();
        assert!(path.ends_with(REPORT_FILE));
        std::fs::remove_dir_all(&scratch).ok();
    }
}

//! Tracked resilience harness (`repro bench --resilience`): drives the
//! serve tier through a deterministic [`FaultPlan`] — a shard-worker
//! panic, a torn-write crash between WAL append and checkpoint, a
//! stalled client — and reports what the fault-tolerance machinery
//! actually delivered, as `BENCH_resilience.json`:
//!
//! 1. **Durability** — acked (WAL-framed) rows vs rows recovered by
//!    `ShardedIngest::recover`; `rows_lost` must be 0, and the recovered
//!    model must be byte-identical to an uninterrupted reference run
//!    over the same acked rows (CI gates on both).
//! 2. **Supervision** — worker restarts and re-queued rows from the
//!    injected panic.
//! 3. **Registry lifecycle** — a rollback exercised against the
//!    recovered history, and a degenerate shadow candidate pushed
//!    through the live-traffic gate (must be auto-rejected).
//! 4. **Latency under stalls** — micro-batcher p50/p99 for healthy
//!    clients while one injected slow client stalls between requests,
//!    plus the typed zero-deadline expiry path.
//!
//! Every trigger in the plan is a row count, so the whole harness is
//! deterministic in `(seed, plan)` up to wall-clock columns.
//!
//! With `--nodes N` the harness additionally runs the **multi-node**
//! scenario ([`run_cluster`]): N real serve nodes on loopback behind a
//! [`ClusterCoordinator`], a seeded [`NetFaultPlan`] (node kill,
//! partition, slow replies, one corrupted reply) keyed to the dealt-row
//! clock, and a zero-loss audit of every coordinator-acked row against
//! the nodes' WALs. The whole scenario runs twice and the merged and
//! recovered models must match byte for byte — the report then nests
//! both runs as `bench_resilience/v2` (see [`compose`]).

use std::collections::HashMap;
use std::net::TcpListener;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::data::Dataset;
use crate::model::AnyModel;
use crate::serve::faults::is_injected_crash;
use crate::serve::protocol::format_features;
use crate::serve::{
    canonical_train_line, serve_connections, wal, BatcherOptions, ClusterCoordinator, FaultPlan,
    MicroBatcher, ModelRegistry, NetFaultPlan, NodeLink, PredictError, ServeState, ShadowPolicy,
    ShardedIngest,
};
use crate::solver::{RunConfig, SolverSpec, SvmConfig};
use crate::util::backoff::Backoff;
use crate::util::json::Json;
use crate::util::parallel;
use crate::util::stats::quantile_sorted;

/// File name of the emitted report.
pub const REPORT_FILE: &str = "BENCH_resilience.json";

/// Rows per ingest chunk on the faulted run (small enough that the
/// injected panic is healed on a later chunk, before the crash fires).
const INGEST_CHUNK: usize = 128;

/// Healthy concurrent prediction clients in the stall phase.
const PREDICT_CLIENTS: usize = 4;

/// Live rows sampled (evenly across the stream, so both classes appear)
/// into the shadow window before the degenerate candidate is judged.
const SHADOW_SAMPLE_ROWS: usize = 64;

/// Run the harness: a faulted ingest over `stream` under `plan`, then
/// recovery, rollback, shadow-gate and stalled-client phases. `scratch`
/// hosts the WAL/checkpoint/dump files (created if missing; stale bench
/// files are overwritten). Returns the JSON report.
pub fn run(
    stream: &Dataset,
    svm: &SvmConfig,
    seed: u64,
    shards: usize,
    publish_every: usize,
    plan: FaultPlan,
    scratch: &Path,
) -> Result<Json> {
    ensure!(!stream.is_empty(), "bench stream must not be empty");
    std::fs::create_dir_all(scratch)
        .with_context(|| format!("cannot create scratch directory {}", scratch.display()))?;
    let wal_path = scratch.join("bench-serve.wal");
    let ckpt_path = scratch.join("bench-serve.ckpt");
    let _ = std::fs::remove_file(&wal_path);
    let _ = std::fs::remove_file(&ckpt_path);

    // ---- phase 1: faulted ingest (panic + torn-write crash) ----
    let reg_faulted = Arc::new(ModelRegistry::new());
    let mut ing = ShardedIngest::new(
        svm.clone(),
        RunConfig::new().seed(seed),
        shards,
        publish_every,
        Arc::clone(&reg_faulted),
    )?;
    ing.enable_wal(&wal_path)?;
    ing.checkpoint_at(&ckpt_path);
    ing.fault_inject(plan)?;
    let mut crashed = false;
    let mut start = 0usize;
    while start < stream.len() {
        let idx: Vec<usize> = (start..(start + INGEST_CHUNK).min(stream.len())).collect();
        match ing.ingest(&stream.subset(&idx, "resilience-chunk")) {
            Ok(()) => {}
            Err(e) => {
                let msg = e.to_string();
                ensure!(is_injected_crash(&msg), "unexpected pipeline failure: {msg}");
                crashed = true;
                break;
            }
        }
        start += INGEST_CHUNK;
    }
    let faulted = ing.finish()?;

    // ---- phase 2: the durability ledger (WAL truth after the crash) ----
    let replayed =
        wal::replay(&wal_path, None).context("replaying the WAL the crash left behind")?;
    let acked_rows = replayed.rows.len() as u64;

    // ---- phase 3: recovery ----
    let reg_rec = Arc::new(ModelRegistry::new());
    let (rec, recovery) = ShardedIngest::recover(
        SolverSpec::Bsgd,
        svm.clone(),
        RunConfig::new().seed(seed),
        shards,
        publish_every,
        Arc::clone(&reg_rec),
        &wal_path,
        Some(&ckpt_path),
        false,
    )?;
    let recovered_rows = rec.rows_ingested();
    let rows_lost = acked_rows.saturating_sub(recovered_rows);

    // ---- phase 4: byte-identity against an uninterrupted reference ----
    // The reference pipeline never sees a fault and trains exactly the
    // acked rows; determinism promises the recovered model matches it
    // byte for byte.
    let reg_ref = Arc::new(ModelRegistry::new());
    let mut reference = ShardedIngest::new(
        svm.clone(),
        RunConfig::new().seed(seed),
        shards,
        publish_every,
        Arc::clone(&reg_ref),
    )?;
    let mut byte_identical = false;
    if !replayed.rows.is_empty() {
        reference.ingest(&replayed.rows)?;
        reference.publish_now()?;
        let rec_dump = scratch.join("bench-recovered.mdl");
        let ref_dump = scratch.join("bench-reference.mdl");
        reg_rec.dump(&rec_dump)?;
        reg_ref.dump(&ref_dump)?;
        byte_identical = std::fs::read(&rec_dump)? == std::fs::read(&ref_dump)?;
    }
    reference.finish()?;

    // ---- phase 5: rollback against the recovered history ----
    let mut restored_version = 0u64;
    if reg_rec.history_len() >= 2 {
        restored_version = reg_rec.rollback(1)?;
    }
    let rec_life = reg_rec.lifecycle_stats();
    rec.finish()?;

    // ---- phase 6: shadow gate — a degenerate candidate must not oust
    // the incumbent the reference registry serves ----
    let d = stream.dim();
    let step = (stream.len() / SHADOW_SAMPLE_ROWS).max(1);
    for i in (0..stream.len()).step_by(step) {
        reg_ref.record_live_rows(stream.row(i), d);
    }
    // A single SV at the origin with a positive coefficient: a constant
    // "+1" classifier, maximally wrong on one class.
    let mut degenerate = AnyModel::new(d, svm.kernel, 2)?;
    degenerate.push(&vec![0.0f32; d], 1.0);
    let outcome = reg_ref.publish_shadowed(degenerate, &ShadowPolicy::default());
    let shadow_life = reg_ref.lifecycle_stats();

    // ---- phase 7: predict latency while one client stalls ----
    let batcher = MicroBatcher::new(
        Arc::clone(&reg_ref),
        BatcherOptions { max_batch_rows: 64, threads: 2 },
    );
    let stall = Duration::from_millis(plan.stall_client_ms.max(1));
    let stop = Arc::new(AtomicBool::new(false));
    let staller = {
        let client = batcher.client();
        let row: Vec<f32> = stream.row(0).to_vec();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(stall);
                if client.predict_deadline(&row, row.len(), Some(Duration::from_secs(30))).is_err()
                {
                    break;
                }
            }
        })
    };
    let t0 = Instant::now();
    let per_client: Vec<Vec<f64>> =
        parallel::map_ranges(stream.len(), PREDICT_CLIENTS, |range| {
            let client = batcher.client();
            let mut lat = Vec::with_capacity(range.len());
            for i in range {
                let t = Instant::now();
                client
                    .predict_deadline(stream.row(i), d, Some(Duration::from_secs(30)))
                    .expect("bench predict failed");
                lat.push(t.elapsed().as_secs_f64());
            }
            lat
        });
    let predict_seconds = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    // The typed expiry path: zero-deadline requests must come back as
    // `Overloaded`, never as a hang or an untyped error.
    let client = batcher.client();
    let mut deadline_expired = 0u64;
    for i in 0..8.min(stream.len()) {
        if let Err(PredictError::Overloaded { .. }) =
            client.predict_deadline(stream.row(i), d, Some(Duration::ZERO))
        {
            deadline_expired += 1;
        }
    }
    let _ = staller.join();
    let bstats = client.stats();
    batcher.shutdown();

    let mut latencies: Vec<f64> = per_client.into_iter().flatten().collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50_us = quantile_sorted(&latencies, 0.5) * 1e6;
    let p99_us = quantile_sorted(&latencies, 0.99) * 1e6;

    Ok(Json::object(vec![
        ("schema", Json::str("bench_resilience/v1")),
        ("rows", Json::num(stream.len() as f64)),
        ("dim", Json::num(d as f64)),
        ("shards", Json::num(shards as f64)),
        ("seed", Json::num(seed as f64)),
        ("publish_every", Json::num(publish_every as f64)),
        (
            "fault_plan",
            Json::object(vec![
                (
                    "worker_panic_shard",
                    plan.worker_panic.map(|p| Json::num(p.shard as f64)).unwrap_or(Json::Null),
                ),
                (
                    "worker_panic_after_rows",
                    plan.worker_panic
                        .map(|p| Json::num(p.after_rows as f64))
                        .unwrap_or(Json::Null),
                ),
                (
                    "crash_at_rows",
                    plan.crash_at_rows.map(|r| Json::num(r as f64)).unwrap_or(Json::Null),
                ),
                ("tear_wal_on_crash", Json::Bool(plan.tear_wal_on_crash)),
                ("stall_client_ms", Json::num(plan.stall_client_ms as f64)),
            ]),
        ),
        (
            "recovery",
            Json::object(vec![
                ("crashed", Json::Bool(crashed)),
                ("acked_rows", Json::num(acked_rows as f64)),
                ("torn_tail_dropped", Json::Bool(replayed.torn_tail)),
                ("recovered_rows", Json::num(recovered_rows as f64)),
                ("rows_lost", Json::num(rows_lost as f64)),
                ("byte_identical", Json::Bool(byte_identical)),
                ("recovery_seconds", Json::num(recovery.recovery_seconds)),
                ("checkpoint_rows", Json::num(recovery.checkpoint_rows as f64)),
                ("checkpoint_version", Json::num(recovery.checkpoint_version as f64)),
            ]),
        ),
        (
            "supervision",
            Json::object(vec![
                ("worker_restarts", Json::num(faulted.worker_restarts as f64)),
                ("rows_requeued", Json::num(faulted.rows_requeued as f64)),
                ("rows_before_crash", Json::num(faulted.rows as f64)),
            ]),
        ),
        (
            "lifecycle",
            Json::object(vec![
                ("history_len", Json::num(reg_rec.history_len() as f64)),
                ("rollbacks", Json::num(rec_life.rollbacks as f64)),
                ("restored_version", Json::num(restored_version as f64)),
                ("shadow_candidate_rejected", Json::Bool(!outcome.accepted)),
                ("shadow_rejected_total", Json::num(shadow_life.rejected as f64)),
                (
                    "shadow_agreement",
                    outcome.agreement.map(Json::num).unwrap_or(Json::Null),
                ),
                ("shadow_evaluated_rows", Json::num(outcome.evaluated_rows as f64)),
            ]),
        ),
        (
            "predict",
            Json::object(vec![
                ("stall_client_ms", Json::num(plan.stall_client_ms as f64)),
                ("p50_us", Json::num(p50_us)),
                ("p99_us", Json::num(p99_us)),
                (
                    "rows_per_s",
                    Json::num(stream.len() as f64 / predict_seconds.max(1e-12)),
                ),
                ("deadline_expired", Json::num(deadline_expired as f64)),
                ("expired_total", Json::num(bstats.expired as f64)),
            ]),
        ),
    ]))
}

// ---------------------------------------------------------------------
// Multi-node scenario: kill + partition + failover under a seeded plan
// ---------------------------------------------------------------------

/// Shards per cluster node. The multi-shard path is the single-node
/// harness's job; in the cluster every node *is* one shard.
const NODE_SHARDS: usize = 1;

/// Rows per coordinator chunk. Heartbeat probes and the sync-cadence
/// check run at chunk boundaries, so the whole probe/merge schedule is
/// keyed to the dealt-row clock and replays identically.
const CLUSTER_CHUNK: usize = 32;

/// Per-node derived seed: node solvers and link backoff jitter.
fn node_seed(seed: u64, node: usize) -> u64 {
    seed ^ (node as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Spawn one in-process serve node on loopback: a real [`ServeState`]
/// behind [`serve_connections`], ingest chunk 1 (a node's ack means the
/// row is WAL-framed), WAL + checkpoint under `dir`. The acceptor
/// thread is detached — a node outlives the coordinator run, exactly
/// like a real remote process would.
fn spawn_node(svm: &SvmConfig, seed: u64, dir: &Path) -> Result<String> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("cannot create node directory {}", dir.display()))?;
    let _ = std::fs::remove_file(dir.join(wal::WAL_FILE));
    let _ = std::fs::remove_file(dir.join(wal::CHECKPOINT_FILE));
    let registry = Arc::new(ModelRegistry::new());
    let mut pipeline = ShardedIngest::new(
        svm.clone(),
        RunConfig::new().seed(seed),
        NODE_SHARDS,
        usize::MAX / 4, // cadence publishes off: the coordinator's `flush` decides
        Arc::clone(&registry),
    )?;
    pipeline.enable_wal(dir.join(wal::WAL_FILE))?;
    pipeline.checkpoint_at(dir.join(wal::CHECKPOINT_FILE));
    let batcher = MicroBatcher::new(
        Arc::clone(&registry),
        BatcherOptions { max_batch_rows: 16, threads: 1 },
    );
    let client = batcher.client();
    let state = Arc::new(ServeState::new(registry, client, Some(pipeline), 1));
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    std::thread::spawn(move || {
        let _ = serve_connections(listener, state, None);
        batcher.shutdown();
    });
    Ok(addr)
}

/// Everything one cluster run produces that the caller gates or
/// reports.
struct ClusterOutcome {
    stats: crate::serve::ClusterStats,
    predicts_ok: u64,
    rows_lost: u64,
    duplicate_rows: u64,
    wal_rows_total: u64,
    killed_wal_rows: u64,
    killed_recovered_rows: u64,
    merged_dump: Vec<u8>,
    killed_dump: Vec<u8>,
}

/// One pass of the multi-node scenario: deal the whole stream through a
/// coordinator whose links carry `plan`, then audit the nodes' WALs
/// against the coordinator's acked ledger and recover the killed node
/// offline from its own WAL + checkpoint.
fn cluster_scenario(
    stream: &Dataset,
    svm: &SvmConfig,
    seed: u64,
    nodes: usize,
    plan: NetFaultPlan,
    sync_every: u64,
    scratch: &Path,
) -> Result<ClusterOutcome> {
    std::fs::create_dir_all(scratch)
        .with_context(|| format!("cannot create scratch directory {}", scratch.display()))?;
    let node_dirs: Vec<std::path::PathBuf> =
        (0..nodes).map(|i| scratch.join(format!("node-{i}"))).collect();
    let dealt = Arc::new(AtomicU64::new(0));
    let mut links = Vec::with_capacity(nodes);
    for (i, dir) in node_dirs.iter().enumerate() {
        let addr = spawn_node(svm, node_seed(seed, i), dir)?;
        let backoff = Backoff::new(
            Duration::from_micros(500),
            Duration::from_millis(8),
            2,
            node_seed(seed, i),
        );
        links.push(
            NodeLink::new(i, addr, Some(Duration::from_secs(5)), backoff)
                .with_faults(plan, Arc::clone(&dealt)),
        );
    }
    let mut coord = ClusterCoordinator::new(
        links,
        svm.clone(),
        Arc::new(ModelRegistry::new()),
        sync_every,
    )
    .with_deal_clock(Arc::clone(&dealt));
    coord.record_acked_lines();

    let (killed, kill_at) = plan.kill_node.context("the cluster plan must kill a node")?;
    let part_from = plan.partition.map(|(_, from, _)| from);
    // The probe/merge cadence is row-keyed; holding it off while the
    // dealt clock sits right on the kill trigger pins the failure
    // order — the killed node takes its first failure *inside* a deal,
    // so the in-flight row is always re-dealt — without changing what
    // is tested.
    let near_kill = |clock: u64| clock >= kill_at && clock < kill_at + 4;

    let mut predicts_ok = 0u64;
    let mut burst_done = false;
    for start in (0..stream.len()).step_by(CLUSTER_CHUNK) {
        for i in start..(start + CLUSTER_CHUNK).min(stream.len()) {
            // One predict burst over every replica the instant the
            // partition window opens: the partitioned node is still in
            // the rotation, so exactly one exchange hits the cut link
            // and the failover path fires — deterministically, because
            // the burst briefly advances the shared clock into the
            // window (a client predict racing the partition).
            if !burst_done && part_from == Some(i as u64 + 1) {
                burst_done = true;
                dealt.store(i as u64 + 1, Ordering::SeqCst);
                let line = format!("predict{}", format_features(stream.row(i)));
                for _ in 0..nodes {
                    if coord.forward_predict(&line).starts_with("ok") {
                        predicts_ok += 1;
                    }
                }
                dealt.store(i as u64, Ordering::SeqCst);
            }
            coord.deal_train(stream.label(i), stream.row(i))?;
        }
        if !near_kill(dealt.load(Ordering::SeqCst)) {
            coord.heartbeat_tick();
            let _ = coord.maybe_sync();
        }
    }
    // Final pull + merge + publish over whatever is still up.
    coord.sync_models()?;
    let stats = coord.stats();
    let merged_dump_path = scratch.join("merged.mdl");
    coord.registry().dump(&merged_dump_path)?;
    let merged_dump = std::fs::read(&merged_dump_path)?;

    // ---- zero-loss audit: every acked line must appear in some node's
    // WAL. The lines are re-built from the WAL replays with the same
    // canonical rule the coordinator deals with, so the comparison is
    // exact string equality. ----
    let mut ledger: HashMap<String, i64> = HashMap::new();
    for line in coord.acked_lines() {
        *ledger.entry(line.clone()).or_insert(0) += 1;
    }
    drop(coord); // close the links; node sessions end at EOF
    let mut wal_rows_total = 0u64;
    let mut killed_wal_rows = 0u64;
    for (i, dir) in node_dirs.iter().enumerate() {
        let replayed = wal::replay(&dir.join(wal::WAL_FILE), None)
            .with_context(|| format!("replaying node {i}'s WAL"))?;
        ensure!(!replayed.torn_tail, "node {i}: a cut link must never tear the node's WAL");
        let n = replayed.rows.len() as u64;
        wal_rows_total += n;
        if i == killed {
            killed_wal_rows = n;
        }
        for r in 0..replayed.rows.len() {
            let line = canonical_train_line(replayed.rows.label(r), replayed.rows.row(r));
            *ledger.entry(line).or_insert(0) -= 1;
        }
    }
    // Positive counts are acked rows missing from every WAL (loss);
    // negative counts are at-least-once duplicates (benign: a row the
    // coordinator re-sent because the ack, not the append, was lost).
    let rows_lost: u64 = ledger.values().filter(|&&c| c > 0).map(|&c| c as u64).sum();
    let duplicate_rows: u64 = ledger.values().filter(|&&c| c < 0).map(|&c| (-c) as u64).sum();

    // ---- the killed node recovers offline from its own WAL +
    // checkpoint: node-local durability holds even for the node the
    // cluster lost. ----
    let killed_dir = &node_dirs[killed];
    let ckpt_path = killed_dir.join(wal::CHECKPOINT_FILE);
    let reg_rec = Arc::new(ModelRegistry::new());
    let (rec, _recovery) = ShardedIngest::recover(
        SolverSpec::Bsgd,
        svm.clone(),
        RunConfig::new().seed(node_seed(seed, killed)),
        NODE_SHARDS,
        usize::MAX / 4,
        Arc::clone(&reg_rec),
        &killed_dir.join(wal::WAL_FILE),
        ckpt_path.exists().then_some(ckpt_path.as_path()),
        false,
    )?;
    let killed_recovered_rows = rec.rows_ingested();
    rec.finish()?;
    let killed_dump_path = scratch.join("killed-recovered.mdl");
    reg_rec.dump(&killed_dump_path)?;
    let killed_dump = std::fs::read(&killed_dump_path)?;

    Ok(ClusterOutcome {
        stats,
        predicts_ok,
        rows_lost,
        duplicate_rows,
        wal_rows_total,
        killed_wal_rows,
        killed_recovered_rows,
        merged_dump,
        killed_dump,
    })
}

/// Run the multi-node scenario twice under the same seeded
/// [`NetFaultPlan`] and report the fault-tolerance counters plus the
/// run-to-run determinism gate (merged model, killed-node recovered
/// model and every row count must match across runs). `nodes >= 3` so
/// the killed, partitioned and surviving roles land on distinct nodes.
pub fn run_cluster(
    stream: &Dataset,
    svm: &SvmConfig,
    seed: u64,
    nodes: usize,
    scratch: &Path,
) -> Result<Json> {
    ensure!(nodes >= 3, "the cluster scenario needs >= 3 nodes (kill + partition + survivor)");
    ensure!(
        stream.len() >= 2 * CLUSTER_CHUNK,
        "cluster stream too short for the row-keyed fault schedule"
    );
    let plan = NetFaultPlan::seeded(seed, stream.len() as u64, nodes);
    let sync_every = (stream.len() as u64 / 8).max(1);
    let a = cluster_scenario(stream, svm, seed, nodes, plan, sync_every, &scratch.join("run-a"))?;
    let b = cluster_scenario(stream, svm, seed, nodes, plan, sync_every, &scratch.join("run-b"))?;
    let deterministic = a.merged_dump == b.merged_dump
        && a.killed_dump == b.killed_dump
        && a.stats.acked_rows == b.stats.acked_rows
        && a.stats.rows_redealt == b.stats.rows_redealt
        && a.wal_rows_total == b.wal_rows_total;
    let (killed, kill_at) = plan.kill_node.unwrap_or((0, 0));
    let (part, part_from, part_span) = plan.partition.unwrap_or((0, 0, 0));
    Ok(Json::object(vec![
        ("nodes", Json::num(nodes as f64)),
        ("rows", Json::num(stream.len() as f64)),
        ("seed", Json::num(seed as f64)),
        (
            "fault_plan",
            Json::object(vec![
                ("kill_node", Json::num(killed as f64)),
                ("kill_at_rows", Json::num(kill_at as f64)),
                ("partition_node", Json::num(part as f64)),
                ("partition_from_rows", Json::num(part_from as f64)),
                ("partition_for_rows", Json::num(part_span as f64)),
                (
                    "slow_node",
                    plan.slow_node.map(|(n, _)| Json::num(n as f64)).unwrap_or(Json::Null),
                ),
                (
                    "slow_ms",
                    plan.slow_node.map(|(_, ms)| Json::num(ms as f64)).unwrap_or(Json::Null),
                ),
                (
                    "corrupt_reply_node",
                    plan.corrupt_reply.map(|(n, _)| Json::num(n as f64)).unwrap_or(Json::Null),
                ),
                (
                    "corrupt_reply_at_rows",
                    plan.corrupt_reply
                        .map(|(_, at)| Json::num(at as f64))
                        .unwrap_or(Json::Null),
                ),
            ]),
        ),
        ("rows_dealt", Json::num(a.stats.rows_dealt as f64)),
        ("acked_rows", Json::num(a.stats.acked_rows as f64)),
        ("rows_redealt", Json::num(a.stats.rows_redealt as f64)),
        ("failovers", Json::num(a.stats.failovers as f64)),
        ("refused", Json::num(a.stats.refused as f64)),
        ("predicts_ok", Json::num(a.predicts_ok as f64)),
        ("rows_lost", Json::num(a.rows_lost as f64)),
        ("duplicate_rows", Json::num(a.duplicate_rows as f64)),
        ("wal_rows_total", Json::num(a.wal_rows_total as f64)),
        ("killed_node_wal_rows", Json::num(a.killed_wal_rows as f64)),
        (
            "killed_node_recovered_rows",
            Json::num(a.killed_recovered_rows as f64),
        ),
        ("nodes_up_at_end", Json::num(a.stats.nodes_up as f64)),
        (
            "node_states",
            Json::Array(a.stats.states.iter().map(|s| Json::str(s)).collect()),
        ),
        ("merged_version", Json::num(a.stats.merged_version as f64)),
        ("deterministic_across_runs", Json::Bool(deterministic)),
    ]))
}

/// Stitch the single-node report and (optionally) the cluster report
/// into the versioned on-disk schema: without a cluster run the v1
/// report passes through byte-compatible; with one, v2 nests both.
pub fn compose(single: Json, cluster: Option<Json>) -> Json {
    match cluster {
        None => single,
        Some(c) => Json::object(vec![
            ("schema", Json::str("bench_resilience/v2")),
            ("single_node", single),
            ("cluster", c),
        ]),
    }
}

/// Write the report as `BENCH_resilience.json` under `out_dir` (created
/// if missing); returns the written path.
pub fn write(report: &Json, out_dir: &str) -> Result<String> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("cannot create output directory {out_dir}"))?;
    let path = format!("{}/{}", out_dir.trim_end_matches('/'), REPORT_FILE);
    std::fs::write(&path, format!("{report}\n"))
        .with_context(|| format!("cannot write {path}"))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::kernel::KernelSpec;

    #[test]
    fn harness_reports_zero_loss_and_byte_identical_recovery() {
        let ds = two_moons(400, 0.12, 17);
        let svm = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(25)
            .c(10.0, ds.len());
        // Explicit plan: the panic (shard 0 at 30 rows) fires inside the
        // first 128-row chunk and is healed on the second; the crash at
        // row 250 fires during the second chunk's WAL append, leaving a
        // torn tail. All row counts, fully deterministic.
        let mut plan = FaultPlan::none().with_worker_panic(0, 30).with_crash_at_rows(250, true);
        plan.stall_client_ms = 5;
        let scratch = std::env::temp_dir().join("budgetsvm-resilience-bench");
        let report = run(&ds, &svm, 7, 2, 100, plan, &scratch).unwrap();

        assert_eq!(
            report.get("schema").and_then(Json::as_str),
            Some("bench_resilience/v1")
        );
        let rec = report.get("recovery").expect("recovery section");
        assert_eq!(rec.get("crashed"), Some(&Json::Bool(true)));
        // Chunks are 128 rows: the crash fires while ingesting rows
        // 128..256, which are WAL-framed (acked) before the simulated
        // death — so the ledger holds exactly 256 rows, torn tail dropped.
        assert_eq!(rec.get("acked_rows").and_then(Json::as_usize), Some(256));
        assert_eq!(rec.get("torn_tail_dropped"), Some(&Json::Bool(true)));
        assert_eq!(rec.get("recovered_rows").and_then(Json::as_usize), Some(256));
        assert_eq!(rec.get("rows_lost").and_then(Json::as_usize), Some(0));
        assert_eq!(rec.get("byte_identical"), Some(&Json::Bool(true)));
        assert!(rec.get("recovery_seconds").and_then(Json::as_f64).unwrap() >= 0.0);
        // The cadence publish at row 128 checkpointed before the crash.
        assert_eq!(rec.get("checkpoint_rows").and_then(Json::as_usize), Some(128));

        let sup = report.get("supervision").expect("supervision section");
        assert!(sup.get("worker_restarts").and_then(Json::as_usize).unwrap() >= 1);
        assert!(sup.get("rows_requeued").and_then(Json::as_usize).unwrap() > 0);

        let life = report.get("lifecycle").expect("lifecycle section");
        assert_eq!(life.get("rollbacks").and_then(Json::as_usize), Some(1));
        assert_eq!(life.get("shadow_candidate_rejected"), Some(&Json::Bool(true)));
        assert!(life.get("shadow_evaluated_rows").and_then(Json::as_usize).unwrap() >= 32);

        let pred = report.get("predict").expect("predict section");
        assert!(pred.get("p99_us").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(pred.get("deadline_expired").and_then(Json::as_usize), Some(8));

        // Round-trips through the in-repo JSON parser, and the writer
        // lands it under the canonical name.
        assert_eq!(Json::parse(&report.to_string()).unwrap(), report);
        let out = scratch.to_string_lossy().into_owned();
        let path = write(&report, &out).unwrap();
        assert!(path.ends_with(REPORT_FILE));
        std::fs::remove_dir_all(&scratch).ok();
    }

    #[test]
    fn cluster_scenario_survives_node_loss_without_losing_acked_rows() {
        let ds = two_moons(160, 0.12, 23);
        let svm = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(20)
            .c(10.0, ds.len());
        let scratch = std::env::temp_dir().join("budgetsvm-cluster-bench-test");
        std::fs::remove_dir_all(&scratch).ok();
        let report = run_cluster(&ds, &svm, 29, 3, &scratch).unwrap();

        // The headline gates, same as CI: nothing acked is lost, the
        // kill forced at least one re-deal, the partition forced at
        // least one predict failover, and the whole schedule replays
        // byte-identically.
        assert_eq!(report.get("rows_lost").and_then(Json::as_usize), Some(0));
        assert_eq!(
            report.get("acked_rows").and_then(Json::as_usize),
            Some(ds.len()),
            "every dealt row must end up acked by some node"
        );
        assert!(report.get("rows_redealt").and_then(Json::as_usize).unwrap() >= 1);
        assert!(report.get("failovers").and_then(Json::as_usize).unwrap() >= 1);
        assert!(report.get("predicts_ok").and_then(Json::as_usize).unwrap() >= 1);
        assert_eq!(
            report.get("deterministic_across_runs"),
            Some(&Json::Bool(true))
        );

        // Node-local durability holds even on the node the cluster
        // lost: offline recovery replays exactly what it acked.
        let killed_wal = report.get("killed_node_wal_rows").and_then(Json::as_usize).unwrap();
        assert!(killed_wal >= 1, "the killed node served before dying");
        assert_eq!(
            report.get("killed_node_recovered_rows").and_then(Json::as_usize),
            Some(killed_wal)
        );

        // The kill is permanent; the partition heals. With 3 nodes that
        // leaves exactly one node down at the end.
        let killed = report
            .get("fault_plan")
            .and_then(|p| p.get("kill_node"))
            .and_then(Json::as_usize)
            .unwrap();
        match report.get("node_states") {
            Some(Json::Array(states)) => assert_eq!(states[killed], Json::str("down")),
            other => panic!("node_states missing: {other:?}"),
        }
        assert_eq!(report.get("nodes_up_at_end").and_then(Json::as_usize), Some(2));
        assert!(report.get("merged_version").and_then(Json::as_usize).unwrap() >= 1);

        // v2 composition nests both reports; without a cluster run the
        // v1 report passes through untouched.
        let single = Json::object(vec![("schema", Json::str("bench_resilience/v1"))]);
        let composed = compose(single.clone(), Some(report.clone()));
        assert_eq!(
            composed.get("schema").and_then(Json::as_str),
            Some("bench_resilience/v2")
        );
        assert_eq!(composed.get("cluster"), Some(&report));
        assert_eq!(composed.get("single_node"), Some(&single));
        assert_eq!(compose(single.clone(), None), single);
        assert_eq!(Json::parse(&report.to_string()).unwrap(), report);
        std::fs::remove_dir_all(&scratch).ok();
    }
}

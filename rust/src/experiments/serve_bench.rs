//! Tracked serving bench harness (`repro serve --replay`): throughput and
//! latency of the online subsystem, emitted as `BENCH_serve.json` so CI
//! can archive the trajectory alongside `BENCH_kernel.json`.
//!
//! Per shard count (the acceptance sweep is `{1, 4}`):
//!
//! 1. **Streaming ingest** — rows/s through [`ShardedIngest`] fed in
//!    fixed-size chunks, plus the per-publish ingest stall (shard drain +
//!    merge + registry swap; readers are never paused).
//! 2. **Micro-batched prediction** — four concurrent clients issue
//!    single-row requests through the [`MicroBatcher`]; per-request wall
//!    latency is recorded and reported as p50/p99 with the aggregate
//!    rows/s.
//! 3. **Agreement** — the served labels of this shard count against the
//!    1-shard (serial-equivalent) labels, plus plain accuracy on the
//!    stream's own labels.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::data::Dataset;
use crate::serve::{BatcherOptions, MicroBatcher, ModelRegistry, ShardedIngest};
use crate::solver::{RunConfig, SvmConfig};
use crate::util::json::Json;
use crate::util::parallel;
use crate::util::stats::quantile_sorted;

/// File name of the emitted report.
pub const REPORT_FILE: &str = "BENCH_serve.json";

/// Rows per ingest chunk (the granularity a stream source hands over).
const INGEST_CHUNK: usize = 256;

/// Concurrent prediction clients in the latency phase.
const PREDICT_CLIENTS: usize = 4;

/// One shard-count arm of the sweep (the shard count itself is recorded
/// inside `cell`).
struct Arm {
    labels: Vec<f32>,
    cell: Json,
}

/// Run the harness over `shard_counts` (first entry is the serial
/// baseline for the agreement column; callers pass `[1, 4]`). Returns the
/// JSON report and the registry of the *last* arm, so a caller can keep
/// serving or byte-check the published model.
pub fn run(
    stream: &Dataset,
    svm: &SvmConfig,
    seed: u64,
    shard_counts: &[usize],
    publish_every: usize,
    publish_adapt: bool,
    threads: usize,
) -> Result<(Json, Arc<ModelRegistry>)> {
    ensure!(!stream.is_empty(), "bench stream must not be empty");
    ensure!(!shard_counts.is_empty(), "need at least one shard count");
    let mut arms: Vec<Arm> = Vec::new();
    let mut last_registry = None;
    for &shards in shard_counts {
        let (arm, registry) =
            run_arm(stream, svm, seed, shards, publish_every, publish_adapt, threads)
                .with_context(|| format!("bench arm with {shards} shard(s) failed"))?;
        arms.push(arm);
        last_registry = Some(registry);
    }

    // Agreement of each arm against the first (serial baseline) arm.
    let baseline: Vec<f32> = arms[0].labels.clone();
    let cells: Vec<Json> = arms
        .into_iter()
        .map(|arm| {
            let agree = arm
                .labels
                .iter()
                .zip(&baseline)
                .filter(|(a, b)| a == b)
                .count() as f64
                / baseline.len() as f64;
            let mut obj = match arm.cell {
                Json::Object(o) => o,
                _ => unreachable!("arm cells are objects"),
            };
            obj.insert("agreement_vs_serial".to_string(), Json::num(agree));
            Json::Object(obj)
        })
        .collect();

    let report = Json::object(vec![
        ("schema", Json::str("bench_serve/v1")),
        ("rows", Json::num(stream.len() as f64)),
        ("dim", Json::num(stream.dim() as f64)),
        ("publish_every", Json::num(publish_every as f64)),
        ("publish_adapt", Json::Bool(publish_adapt)),
        ("ingest_chunk", Json::num(INGEST_CHUNK as f64)),
        ("predict_clients", Json::num(PREDICT_CLIENTS as f64)),
        ("shards", Json::array(cells)),
    ]);
    Ok((report, last_registry.expect("at least one arm ran")))
}

#[allow(clippy::too_many_arguments)]
fn run_arm(
    stream: &Dataset,
    svm: &SvmConfig,
    seed: u64,
    shards: usize,
    publish_every: usize,
    publish_adapt: bool,
    threads: usize,
) -> Result<(Arm, Arc<ModelRegistry>)> {
    // ---- phase 1: streaming ingest ----
    let registry = Arc::new(ModelRegistry::new());
    let mut ingest = ShardedIngest::new(
        svm.clone(),
        RunConfig::new().seed(seed),
        shards,
        publish_every,
        Arc::clone(&registry),
    )?
    .with_adaptive_cadence(publish_adapt);
    let t0 = Instant::now();
    let mut start = 0usize;
    while start < stream.len() {
        let idx: Vec<usize> = (start..(start + INGEST_CHUNK).min(stream.len())).collect();
        ingest.ingest(&stream.subset(&idx, "bench-chunk"))?;
        start += INGEST_CHUNK;
    }
    let report = ingest.finish()?;
    let ingest_seconds = t0.elapsed().as_secs_f64();

    // ---- phase 2: micro-batched prediction latency ----
    let batcher = MicroBatcher::new(
        Arc::clone(&registry),
        BatcherOptions { max_batch_rows: 64, threads },
    );
    let d = stream.dim();
    let t1 = Instant::now();
    // One contiguous row range per client; per-range results keep row
    // order, so the concatenated labels line up with the stream.
    let per_client: Vec<(Vec<f32>, Vec<f64>)> =
        parallel::map_ranges(stream.len(), PREDICT_CLIENTS, |range| {
            let client = batcher.client();
            let mut labels = Vec::with_capacity(range.len());
            let mut lat = Vec::with_capacity(range.len());
            for i in range {
                let t = Instant::now();
                let reply = client.predict(stream.row(i), d).expect("bench predict failed");
                lat.push(t.elapsed().as_secs_f64());
                labels.push(reply.labels[0]);
            }
            (labels, lat)
        });
    let predict_seconds = t1.elapsed().as_secs_f64();
    batcher.shutdown();

    let mut labels = Vec::with_capacity(stream.len());
    let mut latencies = Vec::with_capacity(stream.len());
    for (l, lat) in per_client {
        labels.extend(l);
        latencies.extend(lat);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50_us = quantile_sorted(&latencies, 0.5) * 1e6;
    let p99_us = quantile_sorted(&latencies, 0.99) * 1e6;

    let correct =
        labels.iter().zip(stream.labels()).filter(|(a, b)| a == b).count() as f64;
    let accuracy = correct / stream.len() as f64;

    let cell = Json::object(vec![
        ("shards", Json::num(shards as f64)),
        ("ingest_seconds", Json::num(ingest_seconds)),
        (
            "ingest_rows_per_s",
            Json::num(report.rows as f64 / ingest_seconds.max(1e-12)),
        ),
        ("publishes", Json::num(report.publishes as f64)),
        ("publish_stall_mean_ms", Json::num(report.stall_mean_seconds() * 1e3)),
        ("publish_stall_max_ms", Json::num(report.stall_max_seconds() * 1e3)),
        ("publish_every_final", Json::num(report.final_publish_every as f64)),
        ("published_version", Json::num(report.last_version as f64)),
        ("predict_p50_us", Json::num(p50_us)),
        ("predict_p99_us", Json::num(p99_us)),
        (
            "predict_rows_per_s",
            Json::num(stream.len() as f64 / predict_seconds.max(1e-12)),
        ),
        ("num_sv", Json::num(registry.current().map(|s| s.model().num_sv()).unwrap_or(0) as f64)),
        ("stream_accuracy", Json::num(accuracy)),
    ]);
    Ok((Arm { labels, cell }, registry))
}

/// Write the report as `BENCH_serve.json` under `out_dir` (created if
/// missing); returns the written path.
pub fn write(report: &Json, out_dir: &str) -> Result<String> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("cannot create output directory {out_dir}"))?;
    let path = format!("{}/{}", out_dir.trim_end_matches('/'), REPORT_FILE);
    std::fs::write(&path, format!("{report}\n"))
        .with_context(|| format!("cannot write {path}"))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::kernel::KernelSpec;

    #[test]
    fn harness_produces_well_formed_report() {
        let ds = two_moons(600, 0.12, 17);
        let svm = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(25)
            .c(10.0, ds.len());
        let (report, registry) = run(&ds, &svm, 3, &[1, 2], 256, false, 2).unwrap();
        assert_eq!(report.get("schema").and_then(Json::as_str), Some("bench_serve/v1"));
        assert_eq!(report.get("rows").and_then(Json::as_usize), Some(600));
        assert_eq!(report.get("publish_adapt"), Some(&Json::Bool(false)));
        let cells = report.get("shards").and_then(Json::as_array).expect("shards array");
        assert_eq!(cells.len(), 2);
        for cell in cells {
            assert!(cell.get("ingest_rows_per_s").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(cell.get("publishes").and_then(Json::as_f64).unwrap() >= 1.0);
            assert_eq!(cell.get("publish_every_final").and_then(Json::as_usize), Some(256));
            let p50 = cell.get("predict_p50_us").and_then(Json::as_f64).unwrap();
            let p99 = cell.get("predict_p99_us").and_then(Json::as_f64).unwrap();
            assert!(p50 > 0.0 && p99 >= p50, "p50={p50} p99={p99}");
            assert!(cell.get("stream_accuracy").and_then(Json::as_f64).unwrap() > 0.8);
            let agree = cell.get("agreement_vs_serial").and_then(Json::as_f64).unwrap();
            assert!(agree > 0.85, "agreement {agree}");
        }
        // The serial arm agrees with itself perfectly.
        assert_eq!(
            cells[0].get("agreement_vs_serial").and_then(Json::as_f64),
            Some(1.0)
        );
        // The returned registry holds the last arm's published model.
        assert!(registry.current().is_some());
        // Round-trips through the in-repo JSON parser.
        assert_eq!(Json::parse(&report.to_string()).unwrap(), report);
    }
}

//! Table 1: dataset statistics, hyperparameters, and the "exact" SVM
//! accuracy reference.
//!
//! The paper reports LIBSVM's test accuracy per dataset; our stand-in is
//! the in-repo SMO solver (DESIGN.md §5) run on a subsample capped at
//! `cfg.smo_max_rows` (exact dual training is quadratic-to-cubic in n —
//! the very scaling problem BSGD exists to avoid, as Section 1 argues).

use anyhow::Result;

use super::report::{write_csv, MarkdownTable};
use super::{prepare, runner::run_jobs};
use crate::config::ExperimentConfig;
use crate::solver::smo::{train_smo, SmoOptions};
use crate::util::rng::Rng;

/// One Table-1 row.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub dataset: String,
    pub n_train: usize,
    pub n_test: usize,
    pub features: usize,
    pub log2_c: i32,
    pub log2_gamma: i32,
    /// Exact-solver (SMO) test accuracy in percent.
    pub smo_accuracy: f64,
    /// Rows the SMO solver actually trained on.
    pub smo_rows: usize,
    pub smo_converged: bool,
}

/// Run the Table-1 experiment.
pub fn run(cfg: &ExperimentConfig) -> Result<Vec<Table1Row>> {
    let profiles = cfg.profiles();
    let jobs: Vec<_> = profiles
        .iter()
        .map(|profile| {
            let profile = *profile;
            let cfg = cfg.clone();
            move || -> Result<Table1Row> {
                let prep = prepare(profile, &cfg);
                let mut rng = Rng::new(cfg.seed ^ 0x7AB1E1);
                let sub = prep.train.subsample(cfg.smo_max_rows, &mut rng);
                let report = train_smo(
                    &sub,
                    &SmoOptions {
                        c: profile.c(),
                        gamma: profile.gamma(),
                        max_rows: cfg.smo_max_rows,
                        ..Default::default()
                    },
                )?;
                Ok(Table1Row {
                    dataset: profile.name.to_uppercase(),
                    n_train: prep.train.len(),
                    n_test: prep.test.len(),
                    features: profile.dim,
                    log2_c: profile.log2_c,
                    log2_gamma: profile.log2_gamma,
                    smo_accuracy: 100.0 * report.model.accuracy(&prep.test),
                    smo_rows: sub.len(),
                    smo_converged: report.converged,
                })
            }
        })
        .collect();

    let results: Result<Vec<_>> =
        run_jobs(jobs, cfg.effective_threads()).into_iter().collect();
    results
}

/// Render + persist the table.
pub fn render(rows: &[Table1Row], cfg: &ExperimentConfig) -> Result<String> {
    let mut t = MarkdownTable::new(&[
        "data set", "size", "features", "C", "gamma", "accuracy (SMO ref)", "SMO rows",
    ]);
    let mut csv = Vec::new();
    for r in rows {
        t.row(vec![
            r.dataset.clone(),
            format!("{}", r.n_train),
            format!("{}", r.features),
            format!("2^{}", r.log2_c),
            format!("2^{}", r.log2_gamma),
            format!("{:.2}%{}", r.smo_accuracy, if r.smo_converged { "" } else { " (cap)" }),
            format!("{}", r.smo_rows),
        ]);
        csv.push(vec![
            r.dataset.clone(),
            r.n_train.to_string(),
            r.n_test.to_string(),
            r.features.to_string(),
            r.log2_c.to_string(),
            r.log2_gamma.to_string(),
            format!("{:.4}", r.smo_accuracy),
            r.smo_rows.to_string(),
            r.smo_converged.to_string(),
        ]);
    }
    write_csv(
        std::path::Path::new(&cfg.out_dir).join("table1.csv"),
        &[
            "dataset", "n_train", "n_test", "features", "log2_c", "log2_gamma",
            "smo_accuracy_pct", "smo_rows", "smo_converged",
        ],
        &csv,
    )?;
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_table1_runs() {
        let cfg = ExperimentConfig {
            scale: 0.004,
            smo_max_rows: 300,
            datasets: vec!["phishing".into(), "skin".into()],
            out_dir: std::env::temp_dir()
                .join("budgetsvm-t1-test")
                .to_string_lossy()
                .into_owned(),
            ..Default::default()
        };
        let rows = run(&cfg).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.smo_accuracy > 50.0, "{}: {}", r.dataset, r.smo_accuracy);
            assert!(r.smo_rows <= 300);
        }
        // SKIN is nearly separable: the reference must be high even tiny.
        let skin = rows.iter().find(|r| r.dataset == "SKIN").unwrap();
        assert!(skin.smo_accuracy > 90.0, "skin {}", skin.smo_accuracy);
        let rendered = render(&rows, &cfg).unwrap();
        assert!(rendered.contains("SKIN"));
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}

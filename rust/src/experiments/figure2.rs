//! Figure 2: the graphs of `h(m,κ)` (2a) and `WD(m,κ)` (2b).
//!
//! Emits the full grid as CSV (`figure2.csv`: m, kappa, h, s, wd — ready
//! for gnuplot/matplotlib surface plots) plus a coarse ASCII heat map of
//! each function so the structure — the `h` discontinuity at
//! `m = 1/2, κ < e⁻²` and the smooth WD surface — is visible in a
//! terminal.

use anyhow::Result;

use crate::budget::LookupTable;
use crate::config::ExperimentConfig;

/// Build the table and export the CSV. Returns the table used. (One-shot
/// export path: an owned build that drops afterwards beats pinning a copy
/// in the process-wide cache.)
pub fn run(cfg: &ExperimentConfig) -> Result<LookupTable> {
    let table = LookupTable::build(cfg.grid);
    let dir = std::path::Path::new(&cfg.out_dir);
    std::fs::create_dir_all(dir)?;
    let f = std::fs::File::create(dir.join("figure2.csv"))?;
    table.export_csv(f)?;
    Ok(table)
}

/// ASCII heat map of a `[0,1]²` function sampled on `rows × cols` cells.
pub fn ascii_heatmap(
    f: &dyn Fn(f64, f64) -> f64,
    rows: usize,
    cols: usize,
    lo: f64,
    hi: f64,
) -> String {
    const SHADES: &[char] = &[' ', '░', '▒', '▓', '█'];
    let mut out = String::new();
    for r in (0..rows).rev() {
        let m = r as f64 / (rows - 1) as f64;
        out.push_str(&format!("m={m:4.2} |"));
        for c in 0..cols {
            let kappa = c as f64 / (cols - 1) as f64;
            let v = ((f(m, kappa) - lo) / (hi - lo)).clamp(0.0, 1.0);
            let idx = ((v * (SHADES.len() - 1) as f64).round()) as usize;
            out.push(SHADES[idx.min(SHADES.len() - 1)]);
        }
        out.push('\n');
    }
    out.push_str(&format!("       +{}\n", "-".repeat(cols)));
    out.push_str(&format!("        κ=0{}κ=1\n", " ".repeat(cols.saturating_sub(6))));
    out
}

/// Render both panels for the terminal.
pub fn render(table: &LookupTable) -> String {
    let mut out = String::new();
    out.push_str("Figure 2a: h(m, κ)  (note the jump across m=1/2 for κ < e⁻² ≈ 0.135)\n");
    out.push_str(&ascii_heatmap(&|m, k| table.lookup_h(m, k), 21, 64, 0.0, 1.0));
    out.push_str("\nFigure 2b: WD(m, κ)  (log scale, as in the paper)\n");
    out.push_str(&ascii_heatmap(
        &|m, k| (table.lookup_wd(m, k).max(1e-12)).log10(),
        21,
        64,
        -8.0,
        0.0,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure2_csv_and_heatmaps() {
        let cfg = ExperimentConfig {
            grid: 40,
            out_dir: std::env::temp_dir()
                .join("budgetsvm-f2-test")
                .to_string_lossy()
                .into_owned(),
            ..Default::default()
        };
        let table = run(&cfg).unwrap();
        let csv =
            std::fs::read_to_string(std::path::Path::new(&cfg.out_dir).join("figure2.csv"))
                .unwrap();
        assert!(csv.starts_with("m,kappa,h,s,wd"));
        assert_eq!(csv.lines().count(), 1 + 40 * 40);
        let text = render(&table);
        assert!(text.contains("Figure 2a"));
        assert!(text.contains("Figure 2b"));
        // The h surface must show the discontinuity: at low κ, h jumps from
        // ≈1 (m<1/2) to ≈0 (m>1/2).
        assert!(table.lookup_h(0.30, 0.05) > 0.9);
        assert!(table.lookup_h(0.70, 0.05) < 0.1);
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}

//! Table 2: test accuracy of the four merge solvers at two budget sizes,
//! averaged over `cfg.runs` seeds (paper: 5 runs, mean ± std).
//!
//! The reproduction target is the paper's *finding*, not its absolute
//! numbers (our data is synthetic): all four methods are statistically
//! indistinguishable — differences within one run-to-run standard
//! deviation.

use anyhow::Result;

use super::report::{pm, write_csv, MarkdownTable};
use super::{options_for, prepare, runner::run_jobs, METHODS};
use crate::budget::{MergeSolver, Strategy};
use crate::config::ExperimentConfig;
use crate::solver::train_bsgd;
use crate::util::stats::{mean, std};

/// Accuracy cell: one (dataset, budget, method) with per-run values.
#[derive(Debug, Clone)]
pub struct Table2Cell {
    pub dataset: String,
    pub budget: usize,
    pub method: MergeSolver,
    /// Test accuracies (percent), one per run.
    pub accuracies: Vec<f64>,
}

impl Table2Cell {
    pub fn mean(&self) -> f64 {
        mean(&self.accuracies)
    }

    pub fn std(&self) -> f64 {
        std(&self.accuracies)
    }
}

/// Run the Table-2 sweep.
pub fn run(cfg: &ExperimentConfig) -> Result<Vec<Table2Cell>> {
    let mut cells = Vec::new();
    for profile in cfg.profiles() {
        let prep = std::sync::Arc::new(prepare(profile, cfg));
        for &budget in &profile.budgets {
            // One job per (method, run); group afterwards.
            let mut jobs = Vec::new();
            for &method in &METHODS {
                for run_idx in 0..cfg.runs {
                    let prep = std::sync::Arc::clone(&prep);
                    let cfg = cfg.clone();
                    jobs.push(move || {
                        let opts =
                            options_for(&prep, &cfg, Strategy::Merge(method), budget, run_idx);
                        let report = train_bsgd(&prep.train, &opts);
                        (method, 100.0 * report.model.accuracy(&prep.test))
                    });
                }
            }
            let results = run_jobs(jobs, cfg.effective_threads());
            for &method in &METHODS {
                let accuracies: Vec<f64> = results
                    .iter()
                    .filter(|(m, _)| *m == method)
                    .map(|(_, a)| *a)
                    .collect();
                cells.push(Table2Cell {
                    dataset: profile.name.to_uppercase(),
                    budget,
                    method,
                    accuracies,
                });
            }
        }
    }
    Ok(cells)
}

/// Render + persist. Layout mirrors the paper: one row per (dataset,
/// budget), one column per method.
pub fn render(cells: &[Table2Cell], cfg: &ExperimentConfig) -> Result<String> {
    let mut t = MarkdownTable::new(&[
        "data set",
        "budget",
        "GSS-precise",
        "GSS-standard",
        "Lookup-h",
        "Lookup-WD",
    ]);
    let mut csv = Vec::new();
    let mut keys: Vec<(String, usize)> = Vec::new();
    for c in cells {
        let k = (c.dataset.clone(), c.budget);
        if !keys.contains(&k) {
            keys.push(k);
        }
    }
    for (dataset, budget) in keys {
        let cell = |m: MergeSolver| {
            cells
                .iter()
                .find(|c| c.dataset == dataset && c.budget == budget && c.method == m)
                .expect("cell present")
        };
        let row: Vec<String> = vec![
            dataset.clone(),
            budget.to_string(),
            pm(cell(MergeSolver::GssPrecise).mean(), cell(MergeSolver::GssPrecise).std(), 3),
            pm(cell(MergeSolver::GssStandard).mean(), cell(MergeSolver::GssStandard).std(), 3),
            pm(cell(MergeSolver::LookupH).mean(), cell(MergeSolver::LookupH).std(), 3),
            pm(cell(MergeSolver::LookupWd).mean(), cell(MergeSolver::LookupWd).std(), 3),
        ];
        t.row(row);
        for &m in &METHODS {
            let c = cell(m);
            csv.push(vec![
                dataset.clone(),
                budget.to_string(),
                m.name().to_string(),
                format!("{:.4}", c.mean()),
                format!("{:.4}", c.std()),
                c.accuracies.iter().map(|a| format!("{a:.4}")).collect::<Vec<_>>().join(";"),
            ]);
        }
    }
    write_csv(
        std::path::Path::new(&cfg.out_dir).join("table2.csv"),
        &["dataset", "budget", "method", "mean_accuracy_pct", "std_accuracy_pct", "runs"],
        &csv,
    )?;
    Ok(t.render())
}

/// The paper's headline check on this table: per (dataset, budget), the
/// spread of method means should be within ~one pooled std (no method
/// systematically better or worse). Returns the list of violations.
pub fn indistinguishability_violations(cells: &[Table2Cell], slack: f64) -> Vec<String> {
    let mut violations = Vec::new();
    let mut keys: Vec<(String, usize)> = Vec::new();
    for c in cells {
        let k = (c.dataset.clone(), c.budget);
        if !keys.contains(&k) {
            keys.push(k);
        }
    }
    for (dataset, budget) in keys {
        let group: Vec<&Table2Cell> = cells
            .iter()
            .filter(|c| c.dataset == dataset && c.budget == budget)
            .collect();
        let means: Vec<f64> = group.iter().map(|c| c.mean()).collect();
        let pooled_std = mean(&group.iter().map(|c| c.std()).collect::<Vec<_>>());
        let spread = means.iter().cloned().fold(f64::MIN, f64::max)
            - means.iter().cloned().fold(f64::MAX, f64::min);
        if spread > slack * pooled_std.max(0.05) {
            violations.push(format!(
                "{dataset} B={budget}: spread {spread:.3} vs pooled std {pooled_std:.3}"
            ));
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_table2_runs_and_methods_agree() {
        let cfg = ExperimentConfig {
            scale: 0.01,
            runs: 2,
            grid: 100,
            datasets: vec!["phishing".into()],
            out_dir: std::env::temp_dir()
                .join("budgetsvm-t2-test")
                .to_string_lossy()
                .into_owned(),
            ..Default::default()
        };
        let cells = run(&cfg).unwrap();
        // 1 dataset × 2 budgets × 4 methods.
        assert_eq!(cells.len(), 8);
        for c in &cells {
            assert_eq!(c.accuracies.len(), 2);
            assert!(c.mean() > 55.0, "{} B={} {}: {}", c.dataset, c.budget, c.method.name(), c.mean());
        }
        let rendered = render(&cells, &cfg).unwrap();
        assert!(rendered.contains("PHISHING"));
        // With tiny data the variance is large; just exercise the checker.
        let _ = indistinguishability_violations(&cells, 3.0);
        std::fs::remove_dir_all(&cfg.out_dir).ok();
    }
}

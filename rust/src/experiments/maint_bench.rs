//! Tracked budget-maintenance bench harness (`repro bench --maintenance`):
//! the measurable side of the amortized multi-pair maintenance pipeline,
//! emitted as `BENCH_maintenance.json` so CI can archive the trajectory
//! alongside `BENCH_kernel.json` / `BENCH_serve.json`.
//!
//! One binary training job per cell of
//!
//! `strategy ∈ {Lookup-WD (table), GSS-standard (iterative)} ×
//!  slack ∈ {0, B/16, B/4}`,
//!
//! all on the same stream, budget and seed, recording
//!
//! * maintenance **events** and events/s (slack `W` batches `⌈W⌉+1` pairs
//!   per event, so events shrink by that factor — deterministic, gated in
//!   CI),
//! * the **maintenance-time share** of the accounted wall time and its
//!   scan / solver / apply split (the paper's Figure-3 attribution,
//!   refined — the quantity the amortized sweep is meant to reduce),
//! * steps/s and final train accuracy (the sweep must not cost quality).

use std::time::Instant;

use anyhow::{Context, Result};

use crate::budget::{MergeSolver, Strategy};
use crate::data::synthetic::two_moons;
use crate::kernel::KernelSpec;
use crate::metrics::Section;
use crate::solver::{BsgdEstimator, Estimator, RunConfig, SvmConfig};
use crate::util::json::Json;

/// File name of the emitted report.
pub const REPORT_FILE: &str = "BENCH_maintenance.json";

/// Budget of the bench workload.
pub const BUDGET: usize = 64;

/// The lookup-vs-iterative solver pair the sweep compares.
pub const SOLVERS: [(MergeSolver, &str); 2] =
    [(MergeSolver::LookupWd, "lookup"), (MergeSolver::GssStandard, "iterative-gss")];

/// Slack points, as fractions of the budget: {0, B/16, B/4}.
pub const SLACK_DIVISORS: [usize; 3] = [0, 16, 4];

fn slack_points(budget: usize) -> Vec<f64> {
    SLACK_DIVISORS
        .iter()
        .map(|&d| if d == 0 { 0.0 } else { (budget / d) as f64 })
        .collect()
}

/// Run the full harness. `quick` shrinks the workload for CI smoke runs.
/// Returns the JSON report (the caller decides where it goes).
pub fn run(quick: bool) -> Result<Json> {
    let n = if quick { 2000 } else { 8000 };
    let passes = if quick { 2 } else { 4 };
    let ds = two_moons(n, 0.12, 20180501);
    let mut cells = Vec::new();

    for &(solver, solver_kind) in &SOLVERS {
        for &slack in &slack_points(BUDGET) {
            let config = SvmConfig::new()
                .kernel(KernelSpec::gaussian(2.0))
                .budget(BUDGET)
                .c(10.0, ds.len())
                .strategy(Strategy::Merge(solver))
                .grid(400)
                .maint_slack(slack);
            let run = RunConfig::new().passes(passes).seed(7).threads(1);
            let mut est = BsgdEstimator::new(config, run)?;
            let t0 = Instant::now();
            est.fit(&ds)?;
            let wall = t0.elapsed().as_secs_f64();
            let summary = est.summary().context("fitted estimator")?;
            let prof = &summary.profiler;
            let accuracy = {
                let preds = est.predict_batch(ds.features())?;
                crate::metrics::accuracy(&preds, ds.labels())
            };
            let model = est.model().context("fitted estimator")?;
            cells.push(Json::object(vec![
                ("strategy", Json::str(Strategy::Merge(solver).name())),
                ("solver", Json::str(solver_kind)),
                ("slack", Json::num(slack)),
                ("steps", Json::num(summary.steps as f64)),
                ("maintenance_events", Json::num(summary.maintenance_events as f64)),
                (
                    "events_per_s",
                    Json::num(summary.maintenance_events as f64 / wall.max(1e-12)),
                ),
                ("steps_per_s", Json::num(summary.steps as f64 / wall.max(1e-12))),
                ("maintenance_share", Json::num(summary.maintenance_fraction())),
                ("scan_seconds", Json::num(prof.seconds(Section::MaintScan))),
                ("solve_seconds", Json::num(prof.seconds(Section::MaintA))),
                ("apply_seconds", Json::num(prof.seconds(Section::MaintApply))),
                ("wall_seconds", Json::num(wall)),
                ("num_sv", Json::num(model.num_sv() as f64)),
                ("train_accuracy", Json::num(accuracy)),
            ]));
        }
    }

    Ok(Json::object(vec![
        ("schema", Json::str("bench_maintenance/v1")),
        ("rows", Json::num(n as f64)),
        ("passes", Json::num(passes as f64)),
        ("budget", Json::num(BUDGET as f64)),
        ("quick", Json::Bool(quick)),
        ("cells", Json::array(cells)),
    ]))
}

/// Human-readable summary of a report (printed by `repro bench
/// --maintenance`).
pub fn render(report: &Json) -> String {
    let mut out = String::from(
        "Budget-maintenance amortization (events, time share, scan/solve/apply)\n\n",
    );
    if let Some(cells) = report.get("cells").and_then(Json::as_array) {
        for c in cells {
            let g = |k: &str| c.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
            let strategy =
                c.get("strategy").and_then(Json::as_str).unwrap_or("?").to_string();
            out.push_str(&format!(
                "  {strategy:<13} slack {:>4.0}  events {:>7.0} ({:>9.0}/s)  \
                 maint share {:>5.1}%  scan/solve/apply {:.3}/{:.3}/{:.3}s  acc {:.3}\n",
                g("slack"),
                g("maintenance_events"),
                g("events_per_s"),
                100.0 * g("maintenance_share"),
                g("scan_seconds"),
                g("solve_seconds"),
                g("apply_seconds"),
                g("train_accuracy"),
            ));
        }
    }
    out
}

/// Write the report as `BENCH_maintenance.json` under `out_dir` (created
/// if missing); returns the written path.
pub fn write(report: &Json, out_dir: &str) -> Result<String> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("cannot create output directory {out_dir}"))?;
    let path = format!("{}/{}", out_dir.trim_end_matches('/'), REPORT_FILE);
    std::fs::write(&path, format!("{report}\n"))
        .with_context(|| format!("cannot write {path}"))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_harness_produces_well_formed_report() {
        let report = run(true).expect("maintenance bench runs");
        assert_eq!(
            report.get("schema").and_then(Json::as_str),
            Some("bench_maintenance/v1")
        );
        let cells = report.get("cells").and_then(Json::as_array).expect("cells");
        assert_eq!(cells.len(), SOLVERS.len() * SLACK_DIVISORS.len());
        for cell in cells {
            let share = cell.get("maintenance_share").and_then(Json::as_f64).unwrap();
            assert!((0.0..=1.0).contains(&share), "share {share}");
            assert!(cell.get("maintenance_events").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(cell.get("num_sv").and_then(Json::as_usize).unwrap() <= BUDGET);
            assert!(cell.get("train_accuracy").and_then(Json::as_f64).unwrap() > 0.8);
        }
        // The amortization invariant is deterministic: within a solver,
        // slack > 0 must run strictly fewer maintenance events.
        for &(_, kind) in &SOLVERS {
            let events: Vec<f64> = cells
                .iter()
                .filter(|c| c.get("solver").and_then(Json::as_str) == Some(kind))
                .map(|c| c.get("maintenance_events").and_then(Json::as_f64).unwrap())
                .collect();
            assert_eq!(events.len(), SLACK_DIVISORS.len());
            assert!(
                events[1] < events[0] && events[2] < events[1],
                "{kind}: events must fall with slack, got {events:?}"
            );
        }
        // Round-trips through the in-repo JSON parser.
        assert_eq!(Json::parse(&report.to_string()).unwrap(), report);
    }
}

//! Tracked kernel-engine bench harness (`repro bench`,
//! `cargo bench --bench bench_kernel`).
//!
//! Measures the two quantities this system's perf story hangs on and emits
//! them as machine-readable `BENCH_kernel.json` so CI can archive the
//! trajectory:
//!
//! 1. **Kernel-row throughput** — ns per `k(x, sv_j), j = 1..B` row over
//!    `B ∈ {64, 256, 1024}` × `d ∈ {16, 128, 784}`, in four arms: the
//!    blocked engine on the dispatched SIMD tier, the same engine under
//!    the forced-scalar override, the SIMD tier with the opt-in fast-exp
//!    exponential, and the pre-tiling one-SV-at-a-time scalar reference —
//!    plus a `per_tier` column with the row time under every tier
//!    available on this machine (scalar/avx2/avx512/neon, forced).
//!    A `kappa_scan` section times the batched multi-pivot
//!    `kernel_rows_for_svs` (one tile pass for all pivots) against the
//!    row-wise equivalent, dispatched and forced-scalar. A
//!    `fused_decision` section times the fused α·κ decision path
//!    (`decision_with_norm` riding `tile_decision`) against the unfused
//!    materialize-then-reduce equivalent, per available tier.
//! 2. **Multiclass training scaling** — one-vs-rest `fit` steps/s with one
//!    worker vs all workers on a ≥4-class synthetic dataset (same seeds:
//!    the two runs produce bit-identical machines; only the wall clock
//!    differs).

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::kernel::{norm2, simd, Gaussian, KernelSpec, TILE};
use crate::model::BudgetModel;
use crate::solver::{Estimator, MulticlassDataset, OneVsRestEstimator, RunConfig, SvmConfig};
use crate::util::bench::Bencher;
use crate::util::json::Json;
use crate::util::parallel;
use crate::util::rng::Rng;

/// Budgets of the kernel-row sweep.
pub const SWEEP_B: [usize; 3] = [64, 256, 1024];
/// Dimensions of the kernel-row sweep (16/128 bracket the paper's
/// datasets; 784 = MNIST-shaped rows).
pub const SWEEP_D: [usize; 3] = [16, 128, 784];

/// File name of the emitted report.
pub const REPORT_FILE: &str = "BENCH_kernel.json";

fn random_model(b: usize, d: usize, rng: &mut Rng) -> BudgetModel {
    let mut m = BudgetModel::new(d, Gaussian::new(1.0 / d as f64), b);
    for _ in 0..b {
        let row: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        m.push(&row, rng.normal());
    }
    m
}

/// `k`-armed Gaussian blobs on a circle — the multiclass scaling workload.
fn blobs(k: usize, n: usize, seed: u64) -> MulticlassDataset {
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n * 2);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let c = i % k;
        let angle = (c as f64) * std::f64::consts::TAU / (k as f64);
        x.push((3.0 * angle.cos() + 0.45 * rng.normal()) as f32);
        x.push((3.0 * angle.sin() + 0.45 * rng.normal()) as f32);
        y.push(c);
    }
    MulticlassDataset::new(x, y, 2).expect("valid synthetic multiclass data")
}

/// One timed one-vs-rest fit; returns (wall seconds, total SGD steps).
fn timed_fit(
    train: &MulticlassDataset,
    config: &SvmConfig,
    passes: usize,
    threads: usize,
) -> Result<(f64, u64)> {
    let run = RunConfig::new().passes(passes).seed(11).threads(threads);
    let mut est = OneVsRestEstimator::new(config.clone(), run)?;
    let t0 = Instant::now();
    est.fit(train)?;
    let secs = t0.elapsed().as_secs_f64();
    let steps: u64 = (0..est.num_classes())
        .map(|c| {
            est.machine(c)
                .and_then(|m| m.summary())
                .map(|s| s.steps)
                .unwrap_or(0)
        })
        .sum();
    Ok((secs, steps))
}

/// Run the full harness. `quick` shrinks warmup/samples/workload for CI
/// smoke runs; `threads` is the multi-thread arm's worker count (0 = all
/// cores). Returns the JSON report (the caller decides where it goes).
pub fn run(quick: bool, threads: usize) -> Result<Json> {
    let mut bencher = Bencher::new();
    if quick {
        bencher.sample_time = Duration::from_millis(10);
        bencher.samples = 5;
        bencher.warmup = Duration::from_millis(20);
    }

    // ---- 1. kernel-row throughput sweep ----
    let mut rng = Rng::new(0xB10C);
    let mut sweep = Vec::new();
    let mut kappa = Vec::new();
    let mut fused = Vec::new();
    for &b in &SWEEP_B {
        for &d in &SWEEP_D {
            let model = random_model(b, d, &mut rng);
            let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let xn = norm2(&x);
            let mut out = vec![0.0f64; b];
            // Dispatched tier (SIMD when the hardware supports it).
            let blocked = bencher
                .bench(&format!("kernel_row/blocked/B{b}/d{d}"), || {
                    model.kernel_row(&x, xn, &mut out)
                })
                .mean_ns();
            // The same blocked engine under the forced-scalar override.
            let forced = simd::with_forced_scalar(|| {
                bencher
                    .bench(&format!("kernel_row/forced_scalar/B{b}/d{d}"), || {
                        model.kernel_row(&x, xn, &mut out)
                    })
                    .mean_ns()
            });
            // Dispatched tier + the opt-in fast-exp exponential.
            let mut fast_model = model.clone();
            fast_model.set_fast_exp(true);
            let fast = bencher
                .bench(&format!("kernel_row/fast_exp/B{b}/d{d}"), || {
                    fast_model.kernel_row(&x, xn, &mut out)
                })
                .mean_ns();
            // Pre-tiling one-SV-at-a-time reference.
            let scalar = bencher
                .bench(&format!("kernel_row/scalar/B{b}/d{d}"), || {
                    model.kernel_row_scalar(&x, xn, &mut out)
                })
                .mean_ns();
            // Row time under every tier this machine can run (forced).
            let tier_cols: Vec<(&str, Json)> = simd::Tier::ALL
                .iter()
                .filter(|t| t.available())
                .map(|&t| {
                    let ns = simd::with_forced_tier(t, || {
                        bencher
                            .bench(&format!("kernel_row/tier_{}/B{b}/d{d}", t.name()), || {
                                model.kernel_row(&x, xn, &mut out)
                            })
                            .mean_ns()
                    });
                    (t.name(), Json::num(ns))
                })
                .collect();
            sweep.push(Json::object(vec![
                ("b", Json::num(b as f64)),
                ("d", Json::num(d as f64)),
                ("ns_per_row_blocked", Json::num(blocked)),
                ("ns_per_row_forced_scalar", Json::num(forced)),
                ("ns_per_row_fast_exp", Json::num(fast)),
                ("ns_per_row_scalar", Json::num(scalar)),
                ("speedup", Json::num(scalar / blocked.max(1e-9))),
                ("speedup_fast_exp", Json::num(scalar / fast.max(1e-9))),
                ("per_tier", Json::object(tier_cols)),
            ]));

            // κ scan: 4 pivots' rows in one tile pass vs row-wise.
            let queries = [0usize, b / 3, 2 * b / 3, b - 1];
            let mut rows = vec![0.0f64; queries.len() * b];
            let scan = bencher
                .bench(&format!("kappa_scan/multi/B{b}/d{d}"), || {
                    model.kernel_rows_for_svs(&queries, &mut rows)
                })
                .mean_ns();
            let scan_forced = simd::with_forced_scalar(|| {
                bencher
                    .bench(&format!("kappa_scan/multi_forced_scalar/B{b}/d{d}"), || {
                        model.kernel_rows_for_svs(&queries, &mut rows)
                    })
                    .mean_ns()
            });
            let scan_rowwise = bencher
                .bench(&format!("kappa_scan/rowwise/B{b}/d{d}"), || {
                    for (q, &sv) in queries.iter().enumerate() {
                        model.kernel_row(
                            model.sv(sv),
                            model.sv_norm2(sv),
                            &mut rows[q * b..(q + 1) * b],
                        );
                    }
                })
                .mean_ns();
            kappa.push(Json::object(vec![
                ("b", Json::num(b as f64)),
                ("d", Json::num(d as f64)),
                ("queries", Json::num(queries.len() as f64)),
                ("ns_per_scan", Json::num(scan)),
                ("ns_per_scan_forced_scalar", Json::num(scan_forced)),
                ("ns_per_scan_rowwise", Json::num(scan_rowwise)),
            ]));

            // Fused α·κ decision (one tile pass, no materialized κ row)
            // vs the unfused materialize-then-reduce equivalent, per tier.
            let weights: Vec<f64> = (0..b).map(|_| rng.normal()).collect();
            let mut tier_rows = Vec::new();
            for &t in simd::Tier::ALL.iter().filter(|t| t.available()) {
                let fused_ns = simd::with_forced_tier(t, || {
                    bencher
                        .bench(
                            &format!("fused_decision/fused/{}/B{b}/d{d}", t.name()),
                            || model.decision_with_norm(&x, xn),
                        )
                        .mean_ns()
                });
                let unfused_ns = simd::with_forced_tier(t, || {
                    bencher
                        .bench(
                            &format!("fused_decision/unfused/{}/B{b}/d{d}", t.name()),
                            || {
                                model.kernel_row(&x, xn, &mut out);
                                let acc: f64 =
                                    weights.iter().zip(&out).map(|(a, k)| a * k).sum();
                                0.5 * acc + 0.25
                            },
                        )
                        .mean_ns()
                });
                tier_rows.push(Json::object(vec![
                    ("tier", Json::str(t.name())),
                    ("ns_fused", Json::num(fused_ns)),
                    ("ns_unfused", Json::num(unfused_ns)),
                    ("speedup", Json::num(unfused_ns / fused_ns.max(1e-9))),
                ]));
            }
            fused.push(Json::object(vec![
                ("b", Json::num(b as f64)),
                ("d", Json::num(d as f64)),
                ("tiers", Json::array(tier_rows)),
            ]));
        }
    }

    // ---- 2. multiclass one-vs-rest fit scaling ----
    let classes = 4;
    let n = if quick { 800 } else { 4000 };
    let passes = if quick { 2 } else { 3 };
    let train = blobs(classes, n, 7);
    let config = SvmConfig::new()
        .kernel(KernelSpec::gaussian(0.5))
        .budget(64)
        .c(10.0, train.len());
    let mt = parallel::resolve_threads(threads).max(2).min(classes.max(2));
    // Two runs per arm; keep the faster wall time of each (less noise).
    let mut best_1t = f64::INFINITY;
    let mut best_mt = f64::INFINITY;
    let mut steps_total = 0u64;
    for _ in 0..2 {
        let (s1, steps) = timed_fit(&train, &config, passes, 1)?;
        let (sm, _) = timed_fit(&train, &config, passes, mt)?;
        best_1t = best_1t.min(s1);
        best_mt = best_mt.min(sm);
        steps_total = steps;
    }
    let multiclass = Json::object(vec![
        ("classes", Json::num(classes as f64)),
        ("rows", Json::num(n as f64)),
        ("passes", Json::num(passes as f64)),
        ("budget", Json::num(64.0)),
        ("threads_mt", Json::num(mt as f64)),
        ("steps", Json::num(steps_total as f64)),
        ("seconds_1t", Json::num(best_1t)),
        ("seconds_mt", Json::num(best_mt)),
        ("steps_per_s_1t", Json::num(steps_total as f64 / best_1t.max(1e-12))),
        ("steps_per_s_mt", Json::num(steps_total as f64 / best_mt.max(1e-12))),
        ("speedup", Json::num(best_1t / best_mt.max(1e-12))),
    ]);

    Ok(Json::object(vec![
        ("schema", Json::str("bench_kernel/v3")),
        ("tile", Json::num(TILE as f64)),
        ("simd_tier", Json::str(simd::detected().name())),
        ("quick", Json::Bool(quick)),
        ("kernel_row", Json::array(sweep)),
        ("kappa_scan", Json::array(kappa)),
        ("fused_decision", Json::array(fused)),
        ("multiclass_fit", multiclass),
    ]))
}

/// Write the report as `BENCH_kernel.json` under `out_dir` (created if
/// missing); returns the written path.
pub fn write(report: &Json, out_dir: &str) -> Result<String> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("cannot create output directory {out_dir}"))?;
    let path = format!("{}/{}", out_dir.trim_end_matches('/'), REPORT_FILE);
    std::fs::write(&path, format!("{report}\n"))
        .with_context(|| format!("cannot write {path}"))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_harness_produces_well_formed_report() {
        let report = run(true, 2).expect("bench harness runs");
        assert_eq!(report.get("schema").and_then(Json::as_str), Some("bench_kernel/v3"));
        let tier = report.get("simd_tier").and_then(Json::as_str).expect("simd tier");
        assert!(
            simd::Tier::ALL.iter().any(|t| t.name() == tier),
            "unexpected tier {tier}"
        );
        let sweep = report.get("kernel_row").and_then(Json::as_array).expect("sweep array");
        assert_eq!(sweep.len(), SWEEP_B.len() * SWEEP_D.len());
        for cell in sweep {
            assert!(cell.get("ns_per_row_blocked").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(cell.get("ns_per_row_forced_scalar").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(cell.get("ns_per_row_fast_exp").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(cell.get("ns_per_row_scalar").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(cell.get("speedup").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(cell.get("speedup_fast_exp").and_then(Json::as_f64).unwrap() > 0.0);
            // The scalar tier is always available, so per_tier is never empty.
            let per_tier = cell.get("per_tier").expect("per_tier column");
            assert!(per_tier.get("scalar").and_then(Json::as_f64).unwrap() > 0.0);
        }
        let fused =
            report.get("fused_decision").and_then(Json::as_array).expect("fused array");
        assert_eq!(fused.len(), SWEEP_B.len() * SWEEP_D.len());
        for cell in fused {
            let tiers = cell.get("tiers").and_then(Json::as_array).expect("tier rows");
            assert!(!tiers.is_empty());
            for row in tiers {
                let name = row.get("tier").and_then(Json::as_str).expect("tier name");
                assert!(simd::Tier::ALL.iter().any(|t| t.name() == name));
                assert!(row.get("ns_fused").and_then(Json::as_f64).unwrap() > 0.0);
                assert!(row.get("ns_unfused").and_then(Json::as_f64).unwrap() > 0.0);
            }
        }
        let kappa = report.get("kappa_scan").and_then(Json::as_array).expect("kappa array");
        assert_eq!(kappa.len(), SWEEP_B.len() * SWEEP_D.len());
        for cell in kappa {
            assert_eq!(cell.get("queries").and_then(Json::as_f64), Some(4.0));
            assert!(cell.get("ns_per_scan").and_then(Json::as_f64).unwrap() > 0.0);
            assert!(
                cell.get("ns_per_scan_forced_scalar").and_then(Json::as_f64).unwrap() > 0.0
            );
            assert!(cell.get("ns_per_scan_rowwise").and_then(Json::as_f64).unwrap() > 0.0);
        }
        let mc = report.get("multiclass_fit").expect("multiclass section");
        assert!(mc.get("steps").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(mc.get("seconds_1t").and_then(Json::as_f64).unwrap() > 0.0);
        // Round-trips through the in-repo JSON parser.
        let text = report.to_string();
        assert_eq!(Json::parse(&text).unwrap(), report);
    }
}

//! Tracked observability-overhead gate (`repro bench --observability`):
//! proves the telemetry subsystem is cheap enough to leave on, and that
//! one Prometheus scrape really carries the whole contract. Emits
//! `BENCH_observability.json` with two sections, both CI-gated:
//!
//! 1. **Hot-loop overhead** — the BSGD step loop trained with telemetry
//!    recording enabled vs globally disabled
//!    ([`registry::set_enabled`], the one-relaxed-load arm), min-of-R
//!    wall per arm with the arms interleaved so drift hits both
//!    equally. CI asserts `overhead_pct <=` [`MAX_OVERHEAD_PCT`].
//! 2. **Scrape completeness** — after exercising every training section
//!    (BSGD merge + removal maintenance, BDCA dual ascent/Gram fill)
//!    and every serve stage (WAL-backed sharded ingest behind admission
//!    control, publish, shadow gate, micro-batcher predicts incl. one
//!    zero-deadline expiry), a single [`prometheus::render`] scrape
//!    must contain every registered counter, gauge, and stage
//!    histogram.
//!
//! The harness holds the registry's toggle lock for its whole run, so
//! concurrently running tests never observe a surprise disable window.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::budget::{MergeSolver, Strategy};
use crate::data::synthetic::two_moons;
use crate::kernel::KernelSpec;
use crate::model::AnyModel;
use crate::serve::{BatcherOptions, MicroBatcher, ModelRegistry, ShadowPolicy, ShardedIngest};
use crate::solver::{AnyEstimator, Estimator, RunConfig, SolverSpec, SvmConfig};
use crate::telemetry::{prometheus, registry, Counter, Gauge, Stage};
use crate::util::json::Json;

/// File name of the emitted report.
pub const REPORT_FILE: &str = "BENCH_observability.json";

/// The CI-gated ceiling on instrumented-vs-disabled hot-loop overhead.
pub const MAX_OVERHEAD_PCT: f64 = 2.0;

/// Re-enables telemetry even if the harness unwinds mid-arm.
struct ReEnable;

impl Drop for ReEnable {
    fn drop(&mut self) {
        registry::set_enabled(true);
    }
}

/// Run the harness. `scratch` hosts the WAL files of the serve exercise
/// (created if missing). Deterministic in `seed` up to wall-clock
/// columns. Returns the JSON report.
pub fn run(quick: bool, seed: u64, scratch: &Path) -> Result<Json> {
    // Serialize with every test that toggles or asserts on the global
    // enable flag; restore the flag no matter how we exit.
    let _toggle = registry::toggle_lock();
    let _reenable = ReEnable;

    let rows = if quick { 4_000 } else { 8_000 };
    let passes = if quick { 2 } else { 3 };
    let repeats = if quick { 5 } else { 7 };
    let budget = if quick { 150 } else { 200 };
    let ds = two_moons(rows, 0.12, seed ^ 0x0B5);
    let svm = SvmConfig::new()
        .kernel(KernelSpec::gaussian(2.0))
        .budget(budget)
        .c(10.0, ds.len())
        .strategy(Strategy::Merge(MergeSolver::LookupWd));

    // ---- phase 1: hot-loop overhead (the tentpole gate) ----
    // Identical seed => identical work in both arms; only the recording
    // differs. Min-of-R filters scheduler noise; interleaving the arms
    // spreads thermal/frequency drift across both.
    let fit_once = |enabled: bool| -> Result<(f64, u64)> {
        registry::set_enabled(enabled);
        let mut est = AnyEstimator::new(
            SolverSpec::Bsgd,
            svm.clone(),
            RunConfig::new().passes(passes).seed(seed).threads(1),
        )?;
        let t = Instant::now();
        est.fit(&ds)?;
        let wall = t.elapsed().as_secs_f64();
        let steps = est.summary().context("fitted estimator has a summary")?.steps;
        Ok((wall, steps))
    };
    fit_once(true)?; // warm-up: page in data, settle the allocator
    let mut enabled_s = f64::INFINITY;
    let mut disabled_s = f64::INFINITY;
    let mut steps = 0u64;
    for _ in 0..repeats {
        let (w, s) = fit_once(false)?;
        disabled_s = disabled_s.min(w);
        let (w, _) = fit_once(true)?;
        enabled_s = enabled_s.min(w);
        steps = s;
    }
    registry::set_enabled(true);
    let overhead_pct = (enabled_s / disabled_s - 1.0) * 100.0;

    // ---- phase 2: cover the remaining training sections ----
    // Removal maintenance samples MaintScan/MaintApply; the dual solver
    // samples DualAscent/GramFill. Tiny fits — coverage, not timing.
    let cover = two_moons(600, 0.12, seed ^ 0x0B6);
    let removal_svm = SvmConfig::new()
        .kernel(KernelSpec::gaussian(2.0))
        .budget(40)
        .c(10.0, cover.len())
        .strategy(Strategy::Removal);
    let mut est = AnyEstimator::new(
        SolverSpec::Bsgd,
        removal_svm,
        RunConfig::new().passes(1).seed(seed).threads(1),
    )?;
    est.fit(&cover)?;
    let mut est = AnyEstimator::new(
        SolverSpec::Bdca,
        svm.clone().budget(40),
        RunConfig::new().passes(1).seed(seed).threads(1),
    )?;
    est.fit(&cover)?;

    // ---- phase 3: exercise every serve stage ----
    std::fs::create_dir_all(scratch)
        .with_context(|| format!("cannot create scratch directory {}", scratch.display()))?;
    let wal_path = scratch.join("obs-bench.wal");
    let _ = std::fs::remove_file(&wal_path);
    let reg = Arc::new(ModelRegistry::new());
    let serve_svm = SvmConfig::new()
        .kernel(KernelSpec::gaussian(2.0))
        .budget(40)
        .c(10.0, cover.len());
    let mut ing = ShardedIngest::new(
        serve_svm,
        RunConfig::new().seed(seed),
        2,
        cover.len(), // publish explicitly below, not on cadence
        Arc::clone(&reg),
    )?;
    ing.enable_wal(&wal_path)?; // WalAppend samples
    let mut ing = ing.with_admission(1 << 20, 1 << 19); // AdmissionDecide samples
    const CHUNK: usize = 128;
    let mut start = 0usize;
    while start < cover.len() {
        let idx: Vec<usize> = (start..(start + CHUNK).min(cover.len())).collect();
        ing.ingest(&cover.subset(&idx, "obs-chunk"))?;
        start += CHUNK;
    }
    ing.publish_now()?; // ShardMerge + PublishStall samples

    // Shadow gate: live rows into the window, then a degenerate constant
    // classifier through the gate — evaluated (ShadowEval samples) and
    // rejected against the incumbent.
    let d = cover.dim();
    for i in (0..cover.len()).step_by((cover.len() / 64).max(1)) {
        reg.record_live_rows(cover.row(i), d);
    }
    let mut degenerate = AnyModel::new(d, KernelSpec::gaussian(2.0), 2)?;
    degenerate.push(&vec![0.0f32; d], 1.0);
    let _ = reg.publish_shadowed(degenerate, &ShadowPolicy::default());

    // Micro-batcher: served predicts sample BatchQueueWait; one
    // zero-deadline request exercises the typed expiry path.
    let batcher = MicroBatcher::new(
        Arc::clone(&reg),
        BatcherOptions { max_batch_rows: 32, threads: 2 },
    );
    let client = batcher.client();
    for i in 0..64.min(cover.len()) {
        client
            .predict_deadline(cover.row(i), d, Some(Duration::from_secs(30)))
            .expect("bench predict failed");
    }
    let _ = client.predict_deadline(cover.row(0), d, Some(Duration::ZERO));
    batcher.shutdown();
    ing.finish()?;

    // ---- phase 4: one scrape must carry the whole contract ----
    let text = prometheus::render();
    let mut missing: Vec<Json> = Vec::new();
    for c in Counter::ALL {
        if !text.contains(c.key()) {
            missing.push(Json::str(c.key()));
        }
    }
    for g in Gauge::ALL {
        if !text.contains(g.key()) {
            missing.push(Json::str(g.key()));
        }
    }
    for s in Stage::ALL {
        for suffix in ["_seconds_count", "_seconds_sum"] {
            let name = format!("budgetsvm_{}{suffix}", s.key());
            if !text.contains(&name) {
                missing.push(Json::str(name));
            }
        }
    }
    let complete = missing.is_empty();
    let sampled: Vec<Stage> =
        Stage::ALL.into_iter().filter(|&s| registry::stage_snapshot(s).count > 0).collect();
    let train_sampled = [
        Stage::SgdStep,
        Stage::MaintA,
        Stage::MaintScan,
        Stage::MaintApply,
        Stage::DualAscent,
        Stage::GramFill,
    ]
    .iter()
    .all(|s| sampled.contains(s));
    let serve_sampled = [
        Stage::BatchQueueWait,
        Stage::WalAppend,
        Stage::AdmissionDecide,
        Stage::PublishStall,
        Stage::ShardMerge,
        Stage::ShadowEval,
    ]
    .iter()
    .all(|s| sampled.contains(s));

    Ok(Json::object(vec![
        ("schema", Json::str("bench_observability/v1")),
        ("quick", Json::Bool(quick)),
        ("seed", Json::num(seed as f64)),
        (
            "hot_loop",
            Json::object(vec![
                ("rows", Json::num(rows as f64)),
                ("passes", Json::num(passes as f64)),
                ("budget", Json::num(budget as f64)),
                ("repeats", Json::num(repeats as f64)),
                ("steps", Json::num(steps as f64)),
                ("instrumented_seconds", Json::num(enabled_s)),
                ("disabled_seconds", Json::num(disabled_s)),
                ("overhead_pct", Json::num(overhead_pct)),
                ("max_overhead_pct", Json::num(MAX_OVERHEAD_PCT)),
                ("within_budget", Json::Bool(overhead_pct <= MAX_OVERHEAD_PCT)),
            ]),
        ),
        (
            "scrape",
            Json::object(vec![
                ("complete", Json::Bool(complete)),
                ("missing", Json::array(missing)),
                (
                    "sampled_stages",
                    Json::array(sampled.iter().map(|s| Json::str(s.key())).collect()),
                ),
                ("train_sections_sampled", Json::Bool(train_sampled)),
                ("serve_stages_sampled", Json::Bool(serve_sampled)),
            ]),
        ),
    ]))
}

/// Write the report as `BENCH_observability.json` under `out_dir`
/// (created if missing); returns the written path.
pub fn write(report: &Json, out_dir: &str) -> Result<String> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("cannot create output directory {out_dir}"))?;
    let path = format!("{}/{}", out_dir.trim_end_matches('/'), REPORT_FILE);
    std::fs::write(&path, format!("{report}\n"))
        .with_context(|| format!("cannot write {path}"))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_reports_a_complete_scrape_with_every_stage_sampled() {
        let scratch = std::env::temp_dir().join("budgetsvm-observability-bench");
        let report = run(true, 23, &scratch).unwrap();
        assert_eq!(
            report.get("schema").and_then(Json::as_str),
            Some("bench_observability/v1")
        );

        let hot = report.get("hot_loop").expect("hot_loop section");
        assert!(hot.get("steps").and_then(Json::as_usize).unwrap() > 0);
        assert!(hot.get("instrumented_seconds").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(hot.get("disabled_seconds").and_then(Json::as_f64).unwrap() > 0.0);
        // The overhead number itself is asserted by the dedicated CI job,
        // where the harness runs alone; under the parallel test runner it
        // would be noise, so here we only require it to be finite.
        assert!(hot.get("overhead_pct").and_then(Json::as_f64).unwrap().is_finite());

        let scrape = report.get("scrape").expect("scrape section");
        assert_eq!(scrape.get("complete"), Some(&Json::Bool(true)));
        assert_eq!(scrape.get("missing").and_then(Json::as_array).unwrap().len(), 0);
        assert_eq!(scrape.get("train_sections_sampled"), Some(&Json::Bool(true)));
        assert_eq!(scrape.get("serve_stages_sampled"), Some(&Json::Bool(true)));

        assert_eq!(Json::parse(&report.to_string()).unwrap(), report);
        let out = scratch.to_string_lossy().into_owned();
        let path = write(&report, &out).unwrap();
        assert!(path.ends_with(REPORT_FILE));
        std::fs::remove_dir_all(&scratch).ok();
    }
}

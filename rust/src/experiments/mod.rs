//! Experiment suite: regenerates every table and figure of the paper.
//!
//! | Paper artifact | Module | CLI |
//! |---|---|---|
//! | Table 1 (datasets + exact accuracy) | [`table1`] | `repro table1` |
//! | Table 2 (accuracy, 4 methods × budgets) | [`table2`] | `repro table2` |
//! | Table 3 (speed-up, merging freq, agreement) | [`table3`] | `repro table3` |
//! | Figure 2 (h and WD graphs) | [`figure2`] | `repro figure2` |
//! | Figure 3 (merging-time breakdown) | [`figure3`] | `repro figure3` |
//!
//! [`runner`] executes training jobs across worker threads; [`report`]
//! formats markdown/CSV; [`kernel_bench`] is the tracked perf harness
//! behind `repro bench` (emits `BENCH_kernel.json`); [`maint_bench`] its
//! budget-maintenance sibling behind `repro bench --maintenance` (emits
//! `BENCH_maintenance.json`); [`solver_bench`] the solver-family one
//! behind `repro bench --solver-bench` (BSGD vs BDCA at equal budget,
//! emits `BENCH_solver.json`); [`serve_bench`] the serving one behind
//! `repro serve --replay` (emits `BENCH_serve.json`);
//! [`resilience_bench`] the fault-tolerance one behind
//! `repro bench --resilience` (deterministic fault injection, emits
//! `BENCH_resilience.json`); [`observability_bench`] the telemetry
//! overhead gate behind `repro bench --observability` (instrumented vs
//! disabled hot-loop cost + scrape completeness, emits
//! `BENCH_observability.json`). `repro bench --all` runs the kernel +
//! maintenance + solver harnesses back to back and merges their reports
//! (plus `BENCH_serve.json` / `BENCH_resilience.json` /
//! `BENCH_observability.json`, when already present in the output
//! directory) into one top-level `BENCH_summary.json` via
//! [`write_bench_summary`] — the single perf-trajectory artifact CI
//! uploads.

pub mod figure2;
pub mod figure3;
pub mod kernel_bench;
pub mod maint_bench;
pub mod observability_bench;
pub mod report;
pub mod resilience_bench;
pub mod runner;
pub mod serve_bench;
pub mod solver_bench;
pub mod table1;
pub mod table2;
pub mod table3;

use anyhow::{Context, Result};

use crate::budget::{MergeSolver, Strategy};
use crate::config::ExperimentConfig;
use crate::data::synthetic::Profile;
use crate::data::Dataset;
use crate::solver::BsgdOptions;
use crate::util::json::Json;

/// File name of the merged bench summary (`repro bench --all`).
pub const SUMMARY_FILE: &str = "BENCH_summary.json";

/// Merge the kernel, maintenance and solver bench reports (and, when
/// they already exist under `out_dir`, the serve and resilience reports)
/// into one top-level `BENCH_summary.json`; returns the written path.
/// The per-bench files keep their own paths — this is purely the
/// one-artifact view of the perf trajectory.
pub fn write_bench_summary(
    out_dir: &str,
    kernel: &Json,
    maintenance: &Json,
    solver: &Json,
) -> Result<String> {
    // Reports produced by other jobs fold in when present; absent is fine
    // (each bench runs in its own CI job), but any other read failure
    // must not silently drop the section.
    let sidecar = |file: &str| -> Result<Json> {
        let path = format!("{}/{}", out_dir.trim_end_matches('/'), file);
        match std::fs::read_to_string(&path) {
            Ok(text) => {
                Json::parse(&text).with_context(|| format!("existing {path} is not valid JSON"))
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Json::Null),
            Err(e) => Err(e).with_context(|| format!("cannot read existing {path}")),
        }
    };
    let serve = sidecar(serve_bench::REPORT_FILE)?;
    let resilience = sidecar(resilience_bench::REPORT_FILE)?;
    let observability = sidecar(observability_bench::REPORT_FILE)?;
    let summary = Json::object(vec![
        ("schema", Json::str("bench_summary/v1")),
        ("kernel", kernel.clone()),
        ("maintenance", maintenance.clone()),
        ("solver", solver.clone()),
        ("serve", serve),
        ("resilience", resilience),
        ("observability", observability),
    ]);
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("cannot create output directory {out_dir}"))?;
    let path = format!("{}/{}", out_dir.trim_end_matches('/'), SUMMARY_FILE);
    std::fs::write(&path, format!("{summary}\n"))
        .with_context(|| format!("cannot write {path}"))?;
    Ok(path)
}

/// A prepared (train, test) pair for one profile under a config.
pub struct Prepared {
    pub profile: &'static Profile,
    pub train: Dataset,
    pub test: Dataset,
    pub lambda: f64,
}

/// Generate and preprocess one profile's data (deterministic in
/// `cfg.seed`): synthetic generation at `cfg.scale`, then min/max scaling
/// to [-1, 1] fitted on train (LIBSVM `svm-scale` convention), matching the
/// paper's standard preprocessing.
pub fn prepare(profile: &'static Profile, cfg: &ExperimentConfig) -> Prepared {
    let (mut train, mut test) = profile.generate(cfg.scale, cfg.seed);
    let scaling = train.fit_scaling();
    train.apply_scaling(&scaling);
    test.apply_scaling(&scaling);
    let lambda = profile.lambda(train.len());
    Prepared { profile, train, test, lambda }
}

/// BSGD options for one (profile, strategy, budget, run) cell.
pub fn options_for(
    prep: &Prepared,
    cfg: &ExperimentConfig,
    strategy: Strategy,
    budget: usize,
    run: usize,
) -> BsgdOptions {
    let mut opts = BsgdOptions::new(budget, prep.lambda, prep.profile.gamma());
    opts.passes = cfg.passes_for(prep.profile);
    opts.seed = cfg.seed ^ (0x9E37 + run as u64 * 0x1_0001);
    opts.strategy = strategy;
    opts.grid = cfg.grid;
    opts
}

/// The four merge solvers in the paper's column order.
pub const METHODS: [MergeSolver; 4] = [
    MergeSolver::GssPrecise,
    MergeSolver::GssStandard,
    MergeSolver::LookupH,
    MergeSolver::LookupWd,
];

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig { scale: 0.004, runs: 1, ..Default::default() }
    }

    #[test]
    fn prepare_scales_features() {
        let cfg = tiny_cfg();
        let p = Profile::by_name("ijcnn").unwrap();
        let prep = prepare(p, &cfg);
        for i in 0..prep.train.len().min(200) {
            for &v in prep.train.row(i) {
                assert!((-1.0..=1.0).contains(&v));
            }
        }
        assert!(prep.lambda > 0.0);
    }

    #[test]
    fn bench_summary_merges_reports_and_roundtrips() {
        let dir = std::env::temp_dir().join("budgetsvm-bench-summary");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.to_string_lossy().into_owned();
        let kernel = Json::object(vec![("schema", Json::str("bench_kernel/v2"))]);
        let maint = Json::object(vec![("schema", Json::str("bench_maintenance/v1"))]);
        let solver = Json::object(vec![("schema", Json::str("bench_solver/v1"))]);
        // No serve report present: the slot is null.
        let path = write_bench_summary(&out, &kernel, &maint, &solver).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.get("schema").and_then(Json::as_str), Some("bench_summary/v1"));
        assert_eq!(back.get("kernel"), Some(&kernel));
        assert_eq!(back.get("maintenance"), Some(&maint));
        assert_eq!(back.get("solver"), Some(&solver));
        assert_eq!(back.get("serve"), Some(&Json::Null));
        assert_eq!(back.get("resilience"), Some(&Json::Null));
        assert_eq!(back.get("observability"), Some(&Json::Null));
        // With sidecar reports on disk they are folded in.
        let serve = Json::object(vec![("schema", Json::str("bench_serve/v1"))]);
        std::fs::write(dir.join(serve_bench::REPORT_FILE), format!("{serve}\n")).unwrap();
        let resil = Json::object(vec![("schema", Json::str("bench_resilience/v1"))]);
        std::fs::write(dir.join(resilience_bench::REPORT_FILE), format!("{resil}\n"))
            .unwrap();
        let obs = Json::object(vec![("schema", Json::str("bench_observability/v1"))]);
        std::fs::write(dir.join(observability_bench::REPORT_FILE), format!("{obs}\n"))
            .unwrap();
        let path = write_bench_summary(&out, &kernel, &maint, &solver).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back.get("serve"), Some(&serve));
        assert_eq!(back.get("resilience"), Some(&resil));
        assert_eq!(back.get("observability"), Some(&obs));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn options_vary_by_run_seed() {
        let cfg = tiny_cfg();
        let p = Profile::by_name("adult").unwrap();
        let prep = prepare(p, &cfg);
        let o1 = options_for(&prep, &cfg, Strategy::Removal, 50, 0);
        let o2 = options_for(&prep, &cfg, Strategy::Removal, 50, 1);
        assert_ne!(o1.seed, o2.seed);
        assert_eq!(o1.budget, 50);
    }
}

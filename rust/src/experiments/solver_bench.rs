//! Tracked solver-family bench harness (`repro bench --solver-bench`):
//! the primal (BSGD) and dual (BDCA) budgeted trainers head to head on
//! the same stream, budget and seed, emitted as `BENCH_solver.json` so CI
//! can gate accuracy parity and archive the trajectory alongside
//! `BENCH_kernel.json` / `BENCH_maintenance.json` / `BENCH_serve.json`.
//!
//! One training job per [`crate::solver::SolverSpec`], recording
//!
//! * **epochs/s** and steps/s (the dual sweeps make a BDCA pass more
//!   expensive than a primal one — this is the price being tracked),
//! * the **Gram-fill share** of the dual-solver time
//!   ([`Section::GramFill`] vs [`Section::DualAscent`]): how much of BDCA
//!   goes into keeping the `(B+slack)²` slab exact under churn rather
//!   than into coordinate updates,
//! * train/test accuracy at the **same budget B** — the parity gate: the
//!   dual solver must match the primal one within 0.01 test accuracy
//!   (`parity_gap` in the report, gated in CI).

use std::time::Instant;

use anyhow::{Context, Result};

use crate::data::synthetic::two_moons;
use crate::data::Dataset;
use crate::kernel::KernelSpec;
use crate::metrics::Section;
use crate::solver::{AnyEstimator, Estimator, RunConfig, SolverSpec, SvmConfig};
use crate::util::json::Json;

/// File name of the emitted report.
pub const REPORT_FILE: &str = "BENCH_solver.json";

/// Maximum test-accuracy deficit of BDCA vs BSGD the harness (and CI)
/// accepts at equal budget.
pub const PARITY_TOLERANCE: f64 = 0.01;

/// The family members the harness compares, in report order.
pub const SOLVERS: [SolverSpec; 2] = [SolverSpec::Bsgd, SolverSpec::Bdca];

fn accuracy_on(est: &AnyEstimator, ds: &Dataset) -> Result<f64> {
    let preds = est.predict_batch(ds.features())?;
    Ok(crate::metrics::accuracy(&preds, ds.labels()))
}

/// Run the full harness. `quick` shrinks the workload for CI smoke runs.
/// Returns the JSON report (the caller decides where it goes).
pub fn run(quick: bool) -> Result<Json> {
    let n = if quick { 600 } else { 4000 };
    let n_test = if quick { 400 } else { 1000 };
    let budget = if quick { 60 } else { 100 };
    let passes = 6;
    let train = two_moons(n, 0.12, 42);
    let test = two_moons(n_test, 0.12, 43);

    let mut cells = Vec::new();
    let mut test_accs = Vec::new();
    for solver in SOLVERS {
        let config = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(budget)
            .c(10.0, train.len())
            .grid(400);
        let run = RunConfig::new().passes(passes).seed(1).threads(1);
        let mut est = AnyEstimator::new(solver, config, run)?;
        let t0 = Instant::now();
        est.fit(&train)?;
        let wall = t0.elapsed().as_secs_f64();
        let summary = est.summary().context("fitted estimator")?;
        let prof = &summary.profiler;
        let dual = prof.dual_seconds();
        let gram_fill = prof.seconds(Section::GramFill);
        let train_acc = accuracy_on(&est, &train)?;
        let test_acc = accuracy_on(&est, &test)?;
        test_accs.push(test_acc);
        let model = est.model().context("fitted estimator")?;
        cells.push(Json::object(vec![
            ("solver", Json::str(solver.name())),
            ("steps", Json::num(summary.steps as f64)),
            ("steps_per_s", Json::num(summary.steps as f64 / wall.max(1e-12))),
            ("epochs_per_s", Json::num(passes as f64 / wall.max(1e-12))),
            ("wall_seconds", Json::num(wall)),
            ("maintenance_events", Json::num(summary.maintenance_events as f64)),
            ("maintenance_share", Json::num(summary.maintenance_fraction())),
            ("dual_seconds", Json::num(dual)),
            ("gram_fill_seconds", Json::num(gram_fill)),
            (
                "gram_fill_share",
                Json::num(if dual > 0.0 { gram_fill / dual } else { 0.0 }),
            ),
            ("num_sv", Json::num(model.num_sv() as f64)),
            ("train_accuracy", Json::num(train_acc)),
            ("test_accuracy", Json::num(test_acc)),
        ]));
    }

    // Signed deficit of the dual solver: positive = BDCA behind BSGD.
    let parity_gap = test_accs[0] - test_accs[1];
    Ok(Json::object(vec![
        ("schema", Json::str("bench_solver/v1")),
        ("rows", Json::num(n as f64)),
        ("test_rows", Json::num(n_test as f64)),
        ("passes", Json::num(passes as f64)),
        ("budget", Json::num(budget as f64)),
        ("quick", Json::Bool(quick)),
        ("parity_gap", Json::num(parity_gap)),
        ("parity_tolerance", Json::num(PARITY_TOLERANCE)),
        ("cells", Json::array(cells)),
    ]))
}

/// Human-readable summary of a report (printed by `repro bench
/// --solver-bench`).
pub fn render(report: &Json) -> String {
    let mut out = String::from(
        "Solver family at equal budget (epochs/s, Gram-fill share, accuracy)\n\n",
    );
    if let Some(cells) = report.get("cells").and_then(Json::as_array) {
        for c in cells {
            let g = |k: &str| c.get(k).and_then(Json::as_f64).unwrap_or(f64::NAN);
            let solver = c.get("solver").and_then(Json::as_str).unwrap_or("?").to_string();
            out.push_str(&format!(
                "  {solver:<5} epochs/s {:>8.1}  steps/s {:>9.0}  \
                 gram-fill share {:>5.1}%  sv {:>4.0}  acc train/test {:.3}/{:.3}\n",
                g("epochs_per_s"),
                g("steps_per_s"),
                100.0 * g("gram_fill_share"),
                g("num_sv"),
                g("train_accuracy"),
                g("test_accuracy"),
            ));
        }
    }
    let gap = report.get("parity_gap").and_then(Json::as_f64).unwrap_or(f64::NAN);
    let tol = report.get("parity_tolerance").and_then(Json::as_f64).unwrap_or(f64::NAN);
    out.push_str(&format!(
        "\n  parity gap (bsgd - bdca test accuracy): {gap:+.4} (tolerance {tol:.2})\n"
    ));
    out
}

/// Write the report as `BENCH_solver.json` under `out_dir` (created if
/// missing); returns the written path.
pub fn write(report: &Json, out_dir: &str) -> Result<String> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("cannot create output directory {out_dir}"))?;
    let path = format!("{}/{}", out_dir.trim_end_matches('/'), REPORT_FILE);
    std::fs::write(&path, format!("{report}\n"))
        .with_context(|| format!("cannot write {path}"))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_harness_produces_well_formed_report_and_holds_parity() {
        let report = run(true).expect("solver bench runs");
        assert_eq!(report.get("schema").and_then(Json::as_str), Some("bench_solver/v1"));
        let budget = report.get("budget").and_then(Json::as_usize).unwrap();
        let cells = report.get("cells").and_then(Json::as_array).expect("cells");
        assert_eq!(cells.len(), SOLVERS.len());
        for (cell, solver) in cells.iter().zip(SOLVERS) {
            assert_eq!(cell.get("solver").and_then(Json::as_str), Some(solver.name()));
            assert!(cell.get("num_sv").and_then(Json::as_usize).unwrap() <= budget);
            let share = cell.get("gram_fill_share").and_then(Json::as_f64).unwrap();
            assert!((0.0..=1.0).contains(&share), "gram-fill share {share}");
            let acc = cell.get("test_accuracy").and_then(Json::as_f64).unwrap();
            assert!(acc > 0.85, "{} test accuracy {acc}", solver.name());
            let dual = cell.get("dual_seconds").and_then(Json::as_f64).unwrap();
            match solver {
                // The primal solver never touches the dual sections.
                SolverSpec::Bsgd => assert_eq!(dual, 0.0),
                // The dual solver spends real time in both of them.
                SolverSpec::Bdca => {
                    assert!(dual > 0.0);
                    assert!(cell.get("gram_fill_seconds").and_then(Json::as_f64).unwrap() > 0.0);
                }
            }
        }
        // The headline gate: equal-budget accuracy parity.
        let gap = report.get("parity_gap").and_then(Json::as_f64).unwrap();
        assert!(gap <= PARITY_TOLERANCE, "parity gap {gap} exceeds {PARITY_TOLERANCE}");
        // Round-trips through the in-repo JSON parser.
        assert_eq!(Json::parse(&report.to_string()).unwrap(), report);
    }
}

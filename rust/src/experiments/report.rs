//! Report formatting: markdown tables, CSV files, ASCII bar charts.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// A simple column-aligned markdown table builder.
pub struct MarkdownTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl MarkdownTable {
    pub fn new(header: &[&str]) -> Self {
        MarkdownTable { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with per-column width alignment.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            line
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push('|');
        for w in &width {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Write CSV rows (first row = header) to `path`, creating parents.
pub fn write_csv(path: impl AsRef<Path>, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("cannot create {}", path.display()))?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Horizontal ASCII bar scaled to `max_width` characters.
pub fn bar(value: f64, max_value: f64, max_width: usize) -> String {
    if max_value <= 0.0 {
        return String::new();
    }
    let w = ((value / max_value) * max_width as f64).round().max(0.0) as usize;
    "█".repeat(w.min(max_width))
}

/// `mean ± std` cell with fixed decimals.
pub fn pm(mean: f64, std: f64, decimals: usize) -> String {
    format!("{:.d$} ± {:.d$}", mean, std, d = decimals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let mut t = MarkdownTable::new(&["name", "v"]);
        t.row(vec!["a".into(), "1.5".into()]);
        t.row(vec!["long-name".into(), "22".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("| name      |"));
        assert!(lines[2].starts_with("| a"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        MarkdownTable::new(&["a", "b"]).row(vec!["x".into()]);
    }

    #[test]
    fn csv_write_and_content() {
        let dir = std::env::temp_dir().join("budgetsvm-report-test");
        let path = dir.join("t.csv");
        write_csv(&path, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bars_scale() {
        assert_eq!(bar(5.0, 10.0, 10).chars().count(), 5);
        assert_eq!(bar(10.0, 10.0, 10).chars().count(), 10);
        assert_eq!(bar(0.0, 10.0, 10), "");
    }

    #[test]
    fn pm_formatting() {
        assert_eq!(pm(84.2345, 0.787, 2), "84.23 ± 0.79");
    }
}

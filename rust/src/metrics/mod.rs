//! Instrumentation: section timing (Figure 3's A/B breakdown), agreement
//! statistics between merge solvers (Table 3), and accuracy helpers.

use std::time::Duration;

use crate::util::stats::Welford;

/// Timed sections of the trainer, mirroring (and refining) the paper's
/// profiler attribution:
///
/// * `SgdStep` — margin computation + coefficient update (everything outside
///   budget maintenance),
/// * `MaintA` — Figure 3 "Section A": the per-candidate *solver* — computing
///   `h` (GSS or lookup) or looking up `WD` for the Lookup-WD method,
/// * `MaintScan` — candidate search: victim selection (argmin |α| / the
///   pivot argsort of a multi-pair sweep) plus the blocked κ kernel row(s)
///   and candidate bookkeeping,
/// * `MaintApply` — executing the decision: winner selection, `α_z`,
///   constructing merge vectors, swap-removes/pushes (and, for projection,
///   the Cholesky solve + coefficient update).
///
/// `MaintScan + MaintApply` together are the paper's Figure 3 "Section B"
/// ([`SectionProfiler::section_b_seconds`]); the finer split makes the
/// amortization claim of multi-pair maintenance measurable (one scan shared
/// by many pairs shrinks `MaintScan` per merged pair).
///
/// The dual solver family (BDCA) adds two sections of its own so
/// Figure-3-style consumers see where dual training time goes:
///
/// * `DualAscent` — randomized coordinate-ascent epoch sweeps over the
///   budgeted SV set (closed-form per-coordinate updates off cached Gram
///   rows),
/// * `GramFill` — filling the [`crate::budget::GramCache`]: blocked kernel
///   rows on SV insert and full slab rebuilds after opaque maintenance
///   churn.
///
/// Both stay at zero for the primal solvers, so the existing BSGD
/// accounting ([`SectionProfiler::total_seconds`] et al.) is unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Section {
    SgdStep,
    MaintA,
    MaintScan,
    MaintApply,
    DualAscent,
    GramFill,
}

const N_SECTIONS: usize = 6;

/// Accumulates wall time per [`Section`] in nanoseconds.
#[derive(Debug, Clone, Default)]
pub struct SectionProfiler {
    ns: [u64; N_SECTIONS],
    events: [u64; N_SECTIONS],
}

impl SectionProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, section: Section, elapsed: Duration) {
        self.add_ns(section, elapsed.as_nanos() as u64);
    }

    #[inline]
    pub fn add_ns(&mut self, section: Section, ns: u64) {
        self.ns[section as usize] += ns;
        self.events[section as usize] += 1;
    }

    pub fn ns(&self, section: Section) -> u64 {
        self.ns[section as usize]
    }

    pub fn seconds(&self, section: Section) -> f64 {
        self.ns[section as usize] as f64 * 1e-9
    }

    pub fn events(&self, section: Section) -> u64 {
        self.events[section as usize]
    }

    /// Figure 3's "Section B": all maintenance work outside the
    /// per-candidate solver (candidate scan + apply).
    pub fn section_b_seconds(&self) -> f64 {
        self.seconds(Section::MaintScan) + self.seconds(Section::MaintApply)
    }

    /// Total maintenance time (A + scan + apply).
    pub fn maintenance_seconds(&self) -> f64 {
        self.seconds(Section::MaintA) + self.section_b_seconds()
    }

    /// Total dual-solver time: coordinate-ascent epoch sweeps plus Gram
    /// cache fills. Zero for the primal solvers.
    pub fn dual_seconds(&self) -> f64 {
        self.seconds(Section::DualAscent) + self.seconds(Section::GramFill)
    }

    /// Total accounted time.
    pub fn total_seconds(&self) -> f64 {
        self.seconds(Section::SgdStep) + self.maintenance_seconds() + self.dual_seconds()
    }

    pub fn merge(&mut self, other: &SectionProfiler) {
        for i in 0..N_SECTIONS {
            self.ns[i] += other.ns[i];
            self.events[i] += other.events[i];
        }
    }
}

/// Statistics on how often two merge solvers take the same decision and how
/// far their weight degradations are from the exact optimum (Table 3, right
/// half).
#[derive(Debug, Clone, Default)]
pub struct AgreementStats {
    /// Budget-maintenance events audited.
    pub events: u64,
    /// Events where GSS-standard and Lookup-WD chose the same partner.
    pub equal_decisions: u64,
    /// |WD_gss − WD_lookup| on disagreeing events (exact WD of each choice).
    pub wd_diff_on_disagreement: Welford,
    /// WD(GSS-standard's choice) / WD(GSS-precise best) — paper's "factor GSS".
    pub factor_gss: Welford,
    /// WD(Lookup-WD's choice) / WD(GSS-precise best) — paper's "factor lookup-WD".
    pub factor_lookup: Welford,
}

impl AgreementStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fraction of events with identical decisions.
    pub fn equal_fraction(&self) -> f64 {
        if self.events == 0 {
            0.0
        } else {
            self.equal_decisions as f64 / self.events as f64
        }
    }

    pub fn merge(&mut self, other: &AgreementStats) {
        self.events += other.events;
        self.equal_decisions += other.equal_decisions;
        self.wd_diff_on_disagreement.merge(&other.wd_diff_on_disagreement);
        self.factor_gss.merge(&other.factor_gss);
        self.factor_lookup.merge(&other.factor_lookup);
    }
}

/// Classification accuracy of predictions vs. labels.
pub fn accuracy(predictions: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(predictions.len(), labels.len());
    if predictions.is_empty() {
        return 0.0;
    }
    let correct = predictions
        .iter()
        .zip(labels)
        .filter(|(p, l)| (**p >= 0.0) == (**l >= 0.0))
        .count();
    correct as f64 / predictions.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiler_accumulates() {
        let mut p = SectionProfiler::new();
        p.add_ns(Section::MaintA, 100);
        p.add_ns(Section::MaintA, 50);
        p.add_ns(Section::MaintScan, 15);
        p.add_ns(Section::MaintApply, 10);
        assert_eq!(p.ns(Section::MaintA), 150);
        assert_eq!(p.events(Section::MaintA), 2);
        assert!((p.section_b_seconds() - 25e-9).abs() < 1e-15);
        assert!((p.maintenance_seconds() - 175e-9).abs() < 1e-15);
    }

    #[test]
    fn dual_sections_split_from_maintenance_accounting() {
        let mut p = SectionProfiler::new();
        p.add_ns(Section::SgdStep, 100);
        p.add_ns(Section::DualAscent, 40);
        p.add_ns(Section::GramFill, 20);
        // Dual work never leaks into the primal maintenance accounting …
        assert!((p.maintenance_seconds() - 0.0).abs() < 1e-15);
        assert!((p.dual_seconds() - 60e-9).abs() < 1e-15);
        // … but is part of the total accounted time.
        assert!((p.total_seconds() - 160e-9).abs() < 1e-15);
        assert_eq!(p.events(Section::DualAscent), 1);
        assert_eq!(p.events(Section::GramFill), 1);
    }

    #[test]
    fn merge_covers_dual_sections() {
        let mut a = SectionProfiler::new();
        let mut b = SectionProfiler::new();
        a.add_ns(Section::DualAscent, 10);
        b.add_ns(Section::DualAscent, 30);
        b.add_ns(Section::GramFill, 5);
        a.merge(&b);
        assert_eq!(a.ns(Section::DualAscent), 40);
        assert_eq!(a.events(Section::DualAscent), 2);
        assert_eq!(a.ns(Section::GramFill), 5);
    }

    #[test]
    fn profiler_merge() {
        let mut a = SectionProfiler::new();
        let mut b = SectionProfiler::new();
        a.add_ns(Section::SgdStep, 10);
        b.add_ns(Section::SgdStep, 30);
        a.merge(&b);
        assert_eq!(a.ns(Section::SgdStep), 40);
        assert_eq!(a.events(Section::SgdStep), 2);
    }

    #[test]
    fn agreement_fraction() {
        let mut s = AgreementStats::new();
        s.events = 10;
        s.equal_decisions = 9;
        assert!((s.equal_fraction() - 0.9).abs() < 1e-12);
        assert_eq!(AgreementStats::new().equal_fraction(), 0.0);
    }

    #[test]
    fn accuracy_counts_sign_agreement() {
        let preds = [0.5f32, -2.0, 0.0, -0.1];
        let labels = [1.0f32, -1.0, -1.0, 1.0];
        // 0.0 counts as +1 prediction → row 3 wrong, row 4 wrong.
        assert!((accuracy(&preds, &labels) - 0.5).abs() < 1e-12);
    }
}

//! Instrumentation and the **observability contract**: section timing
//! (Figure 3's A/B breakdown), agreement statistics between merge
//! solvers (Table 3), accuracy helpers — and the rules every metric in
//! the process plays by.
//!
//! # Observability contract
//!
//! Two instrumentation layers coexist, fed through one seam:
//!
//! * **Run-local accounting (this module).** [`SectionProfiler`]
//!   accumulates exact per-[`Section`] nanosecond totals for one
//!   training run — the source of truth for the paper's Figure-3 A/B
//!   attribution and every `BENCH_*.json` artifact. Deterministic,
//!   owned by the run, summed without sampling error.
//! * **Process-global telemetry ([`crate::telemetry`]).** Atomic
//!   counters, gauges, and log-scale latency histograms in static
//!   storage, scrapeable at any time (serve `metrics` verb,
//!   `--metrics-port` Prometheus endpoint). Histograms trade ≤ 12.5%
//!   relative sample error for wait-free recording.
//!
//! The seam: [`SectionProfiler::add_ns`] forwards every sample it
//! receives into the matching [`crate::telemetry::Stage`] histogram.
//! Instrumenting code once — with [`crate::telemetry::span`] or an
//! explicit profiler `add` — feeds both layers; they can never drift
//! apart on what was measured.
//!
//! ## Always-on vs bench-only
//!
//! * **Always-on**: counters, gauges, and stage histograms
//!   (`telemetry::registry`). Budget: one relaxed atomic load when
//!   disabled, a handful of relaxed RMWs when enabled — ≤ 2% overhead
//!   on the BSGD step loop, enforced by the CI `observability-smoke`
//!   gate over `repro bench --observability`.
//! * **Bench-only**: [`AgreementStats`] audits (a second merge solver
//!   runs per event), per-run JSON artifacts, and the JSONL event log
//!   (`--telemetry-log`, off unless a sink is installed).
//!
//! ## Metric-key naming
//!
//! * Counters: `budgetsvm_<noun>_total` (monotone).
//! * Gauges: `budgetsvm_<noun>[_<unit>]`, e.g.
//!   `budgetsvm_queue_depth_rows`.
//! * Latency histograms: `budgetsvm_<stage>_seconds`, where `<stage>`
//!   is `train_<section>` for solver sections and `serve_<stage>` for
//!   serving stages; explicit quantile gauges ride alongside as
//!   `budgetsvm_<stage>_quantile_seconds{q="0.5|0.99|0.999"}`.
//!
//! New metrics must follow these patterns; the telemetry registry's
//! key-uniqueness test is the enforcement point.

use std::time::Duration;

use crate::util::stats::Welford;

/// Timed sections of the trainer, mirroring (and refining) the paper's
/// profiler attribution:
///
/// * `SgdStep` — margin computation + coefficient update (everything outside
///   budget maintenance),
/// * `MaintA` — Figure 3 "Section A": the per-candidate *solver* — computing
///   `h` (GSS or lookup) or looking up `WD` for the Lookup-WD method,
/// * `MaintScan` — candidate search: victim selection (argmin |α| / the
///   pivot argsort of a multi-pair sweep) plus the blocked κ kernel row(s)
///   and candidate bookkeeping,
/// * `MaintApply` — executing the decision: winner selection, `α_z`,
///   constructing merge vectors, swap-removes/pushes (and, for projection,
///   the Cholesky solve + coefficient update).
///
/// `MaintScan + MaintApply` together are the paper's Figure 3 "Section B"
/// ([`SectionProfiler::section_b_seconds`]); the finer split makes the
/// amortization claim of multi-pair maintenance measurable (one scan shared
/// by many pairs shrinks `MaintScan` per merged pair).
///
/// The dual solver family (BDCA) adds two sections of its own so
/// Figure-3-style consumers see where dual training time goes:
///
/// * `DualAscent` — randomized coordinate-ascent epoch sweeps over the
///   budgeted SV set (closed-form per-coordinate updates off cached Gram
///   rows),
/// * `GramFill` — filling the [`crate::budget::GramCache`]: blocked kernel
///   rows on SV insert and full slab rebuilds after opaque maintenance
///   churn.
///
/// Both stay at zero for the primal solvers, so the existing BSGD
/// accounting ([`SectionProfiler::total_seconds`] et al.) is unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Section {
    SgdStep,
    MaintA,
    MaintScan,
    MaintApply,
    DualAscent,
    GramFill,
}

const N_SECTIONS: usize = 6;

/// Accumulates wall time per [`Section`] in nanoseconds.
#[derive(Debug, Clone, Default)]
pub struct SectionProfiler {
    ns: [u64; N_SECTIONS],
    events: [u64; N_SECTIONS],
}

impl SectionProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, section: Section, elapsed: Duration) {
        self.add_ns(section, elapsed.as_nanos() as u64);
    }

    #[inline]
    pub fn add_ns(&mut self, section: Section, ns: u64) {
        self.ns[section as usize] += ns;
        self.events[section as usize] += 1;
        // The one seam between run-local accounting and process-global
        // telemetry: every profiled sample also lands in the section's
        // latency histogram. (`merge` deliberately bypasses this —
        // merged shard totals are not new samples.)
        crate::telemetry::registry::record_section_ns(section, ns);
    }

    pub fn ns(&self, section: Section) -> u64 {
        self.ns[section as usize]
    }

    pub fn seconds(&self, section: Section) -> f64 {
        self.ns[section as usize] as f64 * 1e-9
    }

    pub fn events(&self, section: Section) -> u64 {
        self.events[section as usize]
    }

    /// Figure 3's "Section B": all maintenance work outside the
    /// per-candidate solver (candidate scan + apply).
    pub fn section_b_seconds(&self) -> f64 {
        self.seconds(Section::MaintScan) + self.seconds(Section::MaintApply)
    }

    /// Total maintenance time (A + scan + apply).
    pub fn maintenance_seconds(&self) -> f64 {
        self.seconds(Section::MaintA) + self.section_b_seconds()
    }

    /// Total dual-solver time: coordinate-ascent epoch sweeps plus Gram
    /// cache fills. Zero for the primal solvers.
    pub fn dual_seconds(&self) -> f64 {
        self.seconds(Section::DualAscent) + self.seconds(Section::GramFill)
    }

    /// Total accounted time. Summed over *all* sections by index — a
    /// newly added [`Section`] variant is counted automatically instead
    /// of silently missing from the total until someone remembers to
    /// extend a hand-written sum.
    pub fn total_seconds(&self) -> f64 {
        self.ns.iter().sum::<u64>() as f64 * 1e-9
    }

    pub fn merge(&mut self, other: &SectionProfiler) {
        for i in 0..N_SECTIONS {
            self.ns[i] += other.ns[i];
            self.events[i] += other.events[i];
        }
    }
}

/// Statistics on how often two merge solvers take the same decision and how
/// far their weight degradations are from the exact optimum (Table 3, right
/// half).
#[derive(Debug, Clone, Default)]
pub struct AgreementStats {
    /// Budget-maintenance events audited.
    pub events: u64,
    /// Events where GSS-standard and Lookup-WD chose the same partner.
    pub equal_decisions: u64,
    /// |WD_gss − WD_lookup| on disagreeing events (exact WD of each choice).
    pub wd_diff_on_disagreement: Welford,
    /// WD(GSS-standard's choice) / WD(GSS-precise best) — paper's "factor GSS".
    pub factor_gss: Welford,
    /// WD(Lookup-WD's choice) / WD(GSS-precise best) — paper's "factor lookup-WD".
    pub factor_lookup: Welford,
}

impl AgreementStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fraction of events with identical decisions.
    pub fn equal_fraction(&self) -> f64 {
        if self.events == 0 {
            0.0
        } else {
            self.equal_decisions as f64 / self.events as f64
        }
    }

    pub fn merge(&mut self, other: &AgreementStats) {
        self.events += other.events;
        self.equal_decisions += other.equal_decisions;
        self.wd_diff_on_disagreement.merge(&other.wd_diff_on_disagreement);
        self.factor_gss.merge(&other.factor_gss);
        self.factor_lookup.merge(&other.factor_lookup);
    }
}

/// Classification accuracy of predictions vs. labels.
///
/// Sign agreement with an explicit NaN rule: a NaN prediction (or
/// label) **counts as incorrect**. The naive sign compare would
/// silently score a NaN prediction as the −1 side (`NaN >= 0.0` is
/// false) and call it *correct* against a negative label — a poisoned
/// model must never look half-right.
pub fn accuracy(predictions: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(predictions.len(), labels.len());
    if predictions.is_empty() {
        return 0.0;
    }
    let correct = predictions
        .iter()
        .zip(labels)
        .filter(|(p, l)| !p.is_nan() && !l.is_nan() && (**p >= 0.0) == (**l >= 0.0))
        .count();
    correct as f64 / predictions.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiler_accumulates() {
        let mut p = SectionProfiler::new();
        p.add_ns(Section::MaintA, 100);
        p.add_ns(Section::MaintA, 50);
        p.add_ns(Section::MaintScan, 15);
        p.add_ns(Section::MaintApply, 10);
        assert_eq!(p.ns(Section::MaintA), 150);
        assert_eq!(p.events(Section::MaintA), 2);
        assert!((p.section_b_seconds() - 25e-9).abs() < 1e-15);
        assert!((p.maintenance_seconds() - 175e-9).abs() < 1e-15);
    }

    #[test]
    fn dual_sections_split_from_maintenance_accounting() {
        let mut p = SectionProfiler::new();
        p.add_ns(Section::SgdStep, 100);
        p.add_ns(Section::DualAscent, 40);
        p.add_ns(Section::GramFill, 20);
        // Dual work never leaks into the primal maintenance accounting …
        assert!((p.maintenance_seconds() - 0.0).abs() < 1e-15);
        assert!((p.dual_seconds() - 60e-9).abs() < 1e-15);
        // … but is part of the total accounted time.
        assert!((p.total_seconds() - 160e-9).abs() < 1e-15);
        assert_eq!(p.events(Section::DualAscent), 1);
        assert_eq!(p.events(Section::GramFill), 1);
    }

    #[test]
    fn merge_covers_dual_sections() {
        let mut a = SectionProfiler::new();
        let mut b = SectionProfiler::new();
        a.add_ns(Section::DualAscent, 10);
        b.add_ns(Section::DualAscent, 30);
        b.add_ns(Section::GramFill, 5);
        a.merge(&b);
        assert_eq!(a.ns(Section::DualAscent), 40);
        assert_eq!(a.events(Section::DualAscent), 2);
        assert_eq!(a.ns(Section::GramFill), 5);
    }

    #[test]
    fn profiler_merge() {
        let mut a = SectionProfiler::new();
        let mut b = SectionProfiler::new();
        a.add_ns(Section::SgdStep, 10);
        b.add_ns(Section::SgdStep, 30);
        a.merge(&b);
        assert_eq!(a.ns(Section::SgdStep), 40);
        assert_eq!(a.events(Section::SgdStep), 2);
    }

    #[test]
    fn agreement_fraction() {
        let mut s = AgreementStats::new();
        s.events = 10;
        s.equal_decisions = 9;
        assert!((s.equal_fraction() - 0.9).abs() < 1e-12);
        assert_eq!(AgreementStats::new().equal_fraction(), 0.0);
    }

    #[test]
    fn accuracy_counts_sign_agreement() {
        let preds = [0.5f32, -2.0, 0.0, -0.1];
        let labels = [1.0f32, -1.0, -1.0, 1.0];
        // 0.0 counts as +1 prediction → row 3 wrong, row 4 wrong.
        assert!((accuracy(&preds, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn total_seconds_covers_every_section_by_construction() {
        // Feed every section a distinct amount; the total must be the
        // exact sum — no hand-written section list to forget to extend.
        let all = [
            Section::SgdStep,
            Section::MaintA,
            Section::MaintScan,
            Section::MaintApply,
            Section::DualAscent,
            Section::GramFill,
        ];
        let mut p = SectionProfiler::new();
        let mut expect_ns = 0u64;
        for (i, &s) in all.iter().enumerate() {
            let ns = 10 + i as u64;
            p.add_ns(s, ns);
            expect_ns += ns;
        }
        assert!((p.total_seconds() - expect_ns as f64 * 1e-9).abs() < 1e-15);
    }

    #[test]
    fn accuracy_counts_nan_predictions_as_incorrect() {
        // The naive sign compare would call a NaN prediction "correct"
        // against a −1 label; the contract says NaN is always wrong.
        let preds = [f32::NAN, f32::NAN, 1.0, -1.0];
        let labels = [-1.0f32, 1.0, 1.0, -1.0];
        assert!((accuracy(&preds, &labels) - 0.5).abs() < 1e-12);
        // All-NaN predictions score zero, even against NaN labels.
        assert_eq!(accuracy(&[f32::NAN; 4], &[-1.0f32, 1.0, -1.0, 1.0]), 0.0);
        assert_eq!(accuracy(&[f32::NAN; 2], &[f32::NAN; 2]), 0.0);
    }

    #[test]
    fn accuracy_nan_properties_hold_on_random_vectors() {
        // Deterministic xorshift so the property sweep is reproducible.
        let mut state = 0x1234_5678_9ABC_DEFu64;
        let mut next = move || {
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            state.wrapping_mul(0x2545_F491_4F6C_DD1D)
        };
        for _ in 0..50 {
            let n = 1 + (next() % 64) as usize;
            let mut preds: Vec<f32> = Vec::with_capacity(n);
            let mut labels: Vec<f32> = Vec::with_capacity(n);
            for _ in 0..n {
                preds.push(match next() % 4 {
                    0 => f32::NAN,
                    1 => -1.0,
                    2 => 0.5,
                    _ => (next() % 7) as f32 - 3.0,
                });
                labels.push(if next() % 2 == 0 { 1.0 } else { -1.0 });
            }
            let acc = accuracy(&preds, &labels);
            // Property 1: replacing every NaN with the matching label can
            // only raise (never lower) the accuracy.
            let healed: Vec<f32> = preds
                .iter()
                .zip(&labels)
                .map(|(p, l)| if p.is_nan() { *l } else { *p })
                .collect();
            assert!(accuracy(&healed, &labels) >= acc);
            // Property 2: the NaN rows contribute exactly zero — the
            // score equals correct-finite-pairs / total.
            let finite_correct = preds
                .iter()
                .zip(&labels)
                .filter(|(p, l)| !p.is_nan() && (**p >= 0.0) == (**l >= 0.0))
                .count();
            assert!((acc - finite_correct as f64 / n as f64).abs() < 1e-12);
        }
    }
}

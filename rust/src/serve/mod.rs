//! Online serving + streaming ingest over [`crate::model::AnyModel`].
//!
//! This is the first subsystem where training and prediction run
//! *concurrently* on the same model lineage, and the first with an
//! explicit failure domain: deadlines, admission control, crash-safe
//! persistence, and a supervised worker pool. Seven pieces compose it:
//!
//! * [`registry`] — [`ModelRegistry`]: an atomically hot-swappable,
//!   monotonically versioned **bounded history** of immutable model
//!   snapshots. Readers clone an `Arc` under a briefly-held read lock and
//!   then never touch shared state again; publishers build the snapshot
//!   off to the side and swap one pointer. [`ModelRegistry::rollback`]
//!   reinstates an older model *under a fresh stamp* (versions never move
//!   backwards for readers), and [`ModelRegistry::publish_shadowed`]
//!   gates candidates through shadow evaluation over live traffic.
//! * [`batcher`] — [`MicroBatcher`]: the prediction front end. Concurrent
//!   requests are coalesced by a queue + condvar into one
//!   `decision_rows` call per wakeup. Every request may carry a
//!   **deadline**; a request whose deadline passes while queued is
//!   answered with a typed overloaded error instead of ever blocking its
//!   client past the budget.
//! * [`ingest`] — [`ShardedIngest`]: the streaming-ingest pipeline.
//!   Incoming labeled rows are partitioned round-robin across `S`
//!   long-lived **supervised** shard workers
//!   ([`crate::util::parallel::spawn_worker`]), each running an
//!   independent `partial_fit` stream with a deterministic per-shard seed
//!   ([`crate::solver::bsgd::shard_seed`]). A panicking worker is caught,
//!   its unacknowledged rows re-queued, and the shard healed from a fresh
//!   estimator (bit-exact via WAL replay). [`merge`] periodically folds
//!   the shard models into one budget-respecting model which is published
//!   into the registry.
//! * [`wal`] — crash-safe persistence: a CRC-framed append-only WAL of
//!   acknowledged train rows plus atomic (tmp + rename) checkpoints of
//!   the registry incumbent.
//! * [`faults`] — [`FaultPlan`]: deterministic, row-count-scheduled fault
//!   injection (worker panics, torn-write crashes, slow-client stalls)
//!   behind an explicit test/bench hook.
//! * [`protocol`] — the line-oriented wire front end, with socket
//!   read/write timeouts and bounded line buffering so a dead or
//!   malicious client can never pin a session thread.
//! * [`cluster`] — the multi-node tier: a coordinator that deals acked
//!   train rows to remote shard nodes over the same wire protocol,
//!   merges their snapshots, and fans predict traffic over the replicas
//!   with failover. See the fault-tolerance contract below.
//!
//! # Wire protocol (v1, line-oriented UTF-8 — see [`protocol`])
//!
//! ```text
//! predict <i:v ...>          -> ok <+1|-1> v<version>
//!                            -> overloaded predict deadline exceeded after <n> ms
//! train <label> <i:v ...>    -> ok queued <buffered-rows>
//!                            -> overloaded ingest queue at capacity; retry later
//! flush                      -> ok published v<version>
//! stats                      -> ok <json>
//! metrics                    -> ok <json>            (telemetry registry snapshot)
//! health                     -> ok <version> <ingested-rows>   (heartbeat probe)
//! snapshot                   -> ok <version> <ingested-rows> <hex>  (model pull)
//! snapshot load <ver> <hex>  -> ok loaded <ver>      (replica re-sync push)
//! quit                       -> ok bye              (connection closes)
//! anything else              -> err <message>
//! ```
//!
//! ## Reply vocabulary
//!
//! * `ok …` — the verb succeeded. For `train`, `ok queued n` means the
//!   row is **buffered** (volatile); durability is acquired when the
//!   ingest front drains the buffer into the pipeline, which (with a WAL
//!   attached) appends + syncs the rows *before* dispatching them to
//!   shard workers. A crash between `ok queued` and the drain may lose
//!   those buffered rows; a crash after the drain never does.
//! * `overloaded …` — a typed backpressure reply, *not* an error: the
//!   request was well-formed but the tier declined it to protect itself
//!   (predict deadline expired in queue, or ingest admission rejected the
//!   batch). Clients should back off and retry.
//! * `err …` — the line was malformed (bad arity, non-finite literal,
//!   oversized line, non-UTF-8 bytes, unknown verb) or the operation
//!   failed. The session stays usable; only that line is affected.
//! * Socket timeouts: a session that neither sends nor receives within
//!   the configured io-timeout is answered `err session idle timeout`
//!   and closed — a stalled client costs one bounded thread-second, not
//!   a pinned thread.
//!
//! Feature tokens use the LIBSVM convention: 1-based ascending indices,
//! omitted features are zero, values must be finite. The serving
//! dimension is fixed by the initial model (or, lacking one, by the
//! largest index of the first valid `train` line) and every later row
//! must fit inside it.
//!
//! # Multi-node fault-tolerance contract (see [`cluster`])
//!
//! `repro serve --coordinator --nodes host:port,...` runs this process
//! as a **coordinator** over `N` ordinary serve nodes. The topology is
//! a star: clients speak the same v1 wire protocol to the coordinator,
//! which deals `train` rows to the nodes, pulls and merges their
//! snapshots, and routes `predict` over the node replicas. The contract
//! the tier upholds, in order of what it costs to break:
//!
//! * **No acked row is lost to a node death.** A node's `ok` is the
//!   client's ack, and nodes run with a WAL, so an acked row is durable
//!   on the node that acked it; a killed node's rows are recovered by
//!   WAL replay (`--recover`). Rows dealt to a node that dies *before*
//!   acking are re-dealt to survivors — **at-least-once**: a node that
//!   applied a row whose ack was lost may replay it as a duplicate, and
//!   the coordinator never deals an acked sequence number twice.
//! * **Node loss degrades, never stops, the tier.** Every
//!   coordinator↔node exchange runs under the client side of the
//!   io-timeout plus a seeded equal-jitter backoff with a bounded retry
//!   budget ([`crate::util::backoff`]). Budget exhaustion feeds a
//!   per-node state machine `up → suspect → down → rejoining → up`
//!   ([`cluster::NodeHealth`]) driven by `health` heartbeat probes; a
//!   down node is out of both the deal and the predict rotations until
//!   probes succeed again.
//! * **A rejoining node never serves stale models.** Before readmission
//!   the coordinator pushes its latest merged model (`snapshot load`) —
//!   only a confirmed push (or having nothing merged yet) flips the
//!   node back to up.
//! * **Predict availability beats freshness.** Predicts fail over
//!   sequentially across up replicas; with every replica down the
//!   coordinator answers from its own last merged model. Failovers are
//!   counted (`budgetsvm_failovers_total`), never silent.
//! * **Deterministic under a seeded schedule.** Fault injection at the
//!   network layer ([`faults::NetFaultPlan`]) is keyed on the
//!   coordinator's dealt-row clock, never wall time, so a cluster
//!   scenario (kill + partition mid-ingest) replays identically —
//!   `repro bench --resilience --nodes N` gates zero acked-row loss and
//!   byte-identical merged models across two runs of the same seed.
//!
//! # Ingest admission ladder (degradation order)
//!
//! ```text
//! queue depth:   0 ──────── shed ─────────── max
//! decision:      accept  │  shed-maintenance  │  reject-train
//!                        │  (defer publishes; │  (typed overloaded
//!                        │   multi-merge      │   reply; client
//!                        │   slack absorbs it)│   retries later)
//! ```
//!
//! A publish-stall EWMA feeds the same ladder: expensive merges push the
//! tier into shed-maintenance even at shallow queues. Deferred publishes
//! are counted and flushed when pressure clears.
//!
//! # WAL / recovery invariants (see [`wal`])
//!
//! * **Ack = durable**: a row is acknowledged into the pipeline only
//!   after its WAL frame is appended *and synced*; the WAL write strictly
//!   precedes shard dispatch.
//! * **WAL is the source of truth**: recovery
//!   ([`ShardedIngest::recover`], `repro serve --recover`) replays the
//!   *entire* WAL through a fresh deterministic pipeline. The checkpoint
//!   (registry incumbent + rows covered, atomically written) only
//!   provides instant availability while replay runs — except under
//!   **rotation** (`--wal-rotate`), where segments older than the last
//!   durable checkpoint are truncated away, the checkpoint model becomes
//!   the generation base (merged into every publish, weighted by the
//!   rows it covers), and replay covers only the tail since rotation.
//! * **Byte-identity**: deterministic per-shard seeds, round-robin
//!   partitioning by global row index, and batch-boundary invariance make
//!   the recovered state byte-identical (`BSVMMDL2` dump) to an
//!   uninterrupted run over the same acked rows.
//! * **Torn tails**: a crash mid-append leaves a partial/CRC-failing
//!   frame; replay stops there and resume truncates it. Only
//!   unacknowledged bytes are ever dropped — acked rows are never lost.
//!
//! # Registry lifecycle state machine
//!
//! ```text
//!            publish / publish_shadowed(accept)
//!   empty ────────────────────────────────────► serving v (incumbent)
//!                                               │        ▲ │
//!              shadow gate rejects candidate    │        │ │ rollback(n)
//!              (incumbent keeps serving,        └────────┘ │ reinstates
//!               stats.rejected += 1)             candidate  │ older model
//!                                                dropped    ▼ under fresh
//!                                                         serving v+1
//! ```
//!
//! Versions are stamped under the publish lock and never reused: readers
//! observe a strictly monotonic sequence even across rollbacks and
//! rejected candidates. Shadow evaluation scores a candidate against the
//! incumbent over a sliding window of recent live predict rows; the
//! decision (agreement, accepted/rejected, rollback count) is visible in
//! the `stats` JSON and in `BENCH_resilience.json`.
//!
//! # Snapshot / publish lifecycle
//!
//! ```text
//!   rows ──[WAL append+sync]──round-robin──► shard 0..S-1 workers
//!                               │             (partial_fit, per-shard seed,
//!                               │              panics caught + healed)
//!        every publish_every rows (or an explicit flush,
//!        unless admission is shedding maintenance):
//!                               │ snapshot command, queued AFTER the
//!                               │ shard's pending batches (channel order)
//!                               ▼
//!        weighted merge (weights ∝ shard SGD steps)
//!        budget enforced via the configured maintenance strategy
//!        scale folded  ──►  registry publish (shadow-gated if enabled)
//!                      ──►  checkpoint written atomically (if enabled)
//! ```
//!
//! Readers are never paused: a publish builds the merged model entirely
//! off to the side and installs it with a single pointer swap, so the
//! "publish stall" is an *ingest-side* pause only (shard drain + merge),
//! measured and reported by the bench harnesses
//! (`experiments::serve_bench`, `experiments::resilience_bench`).
//!
//! # Shard-merge semantics (invariants, in the style of `model/store.rs`)
//!
//! * The merged model carries `Σ_s w_s · f_s` with `w_s = steps_s / Σ
//!   steps` — a step-weighted average of the shard decision functions —
//!   plus the equally weighted average bias.
//! * A single-shard publish (`S = 1`) short-circuits to a clone of the
//!   shard model, so the pipeline at one shard is *equivalent* to serial
//!   `partial_fit` (decision values match to f64 rounding; the only
//!   difference is the folded scale).
//! * The merged model never exceeds the configured budget: excess SVs are
//!   reduced through the same merge/removal/projection machinery training
//!   uses, so a published model is always a valid budgeted model.
//! * Published snapshots have their lazy scale folded (`Φ = 1`), which is
//!   what makes a `BSVMMDL2` dump→load of a snapshot bit-identical to the
//!   in-memory model it was taken from.
//! * Versions are stamped under the publish lock: they are strictly
//!   monotonic, and a reader holding snapshot `v` observes exactly the
//!   model published as `v` (stamp and contents live in one immutable
//!   allocation — no torn reads).
//!
//! # Monitoring
//!
//! Every serve stage feeds the process-wide telemetry registry
//! ([`crate::telemetry`]): counters for the admission ladder
//! (accept/shed/reject), deadline expiries, worker restarts, requeued
//! rows, publishes, rollbacks, and shadow rejections; gauges for ingest
//! queue depth and the incumbent model (version, SV count); and
//! log-scale latency histograms for batcher queue wait, WAL append +
//! fsync, admission decisions, publish stalls, shard merges, and shadow
//! evaluation windows. Three surfaces expose it:
//!
//! * **`stats` verb** — the JSON payload carries a pinned `telemetry`
//!   sub-object with the operator-facing core (queue depth, admission
//!   counters, WAL fsync p99, deadline expiries, lifecycle counters).
//!   Its key set is a wire contract, guarded by a schema drift test in
//!   [`protocol`].
//! * **`metrics` verb** — the full registry snapshot as JSON (every
//!   counter, gauge, and per-stage histogram summary with p50/p99/p999),
//!   for clients already speaking the line protocol.
//! * **Prometheus endpoint** — `repro serve --metrics-port <p>` spawns a
//!   loopback HTTP listener answering any path with a text-format
//!   (`text/plain; version=0.0.4`) scrape. Example excerpt:
//!
//! ```text
//! # TYPE budgetsvm_admission_accept_total counter
//! budgetsvm_admission_accept_total 4182
//! # TYPE budgetsvm_queue_depth_rows gauge
//! budgetsvm_queue_depth_rows 96
//! # TYPE budgetsvm_serve_wal_append_seconds histogram
//! budgetsvm_serve_wal_append_seconds_bucket{le="0.000016383"} 310
//! budgetsvm_serve_wal_append_seconds_bucket{le="+Inf"} 327
//! budgetsvm_serve_wal_append_seconds_sum 0.004913
//! budgetsvm_serve_wal_append_seconds_count 327
//! # TYPE budgetsvm_serve_wal_append_quantile_seconds gauge
//! budgetsvm_serve_wal_append_quantile_seconds{q="0.99"} 0.000024575
//! ```
//!
//! A JSONL event log (`repro serve --telemetry-log <file>`) additionally
//! records discrete lifecycle events — maintenance runs, admission-ladder
//! transitions, worker restarts, publishes, rollbacks, shadow rejections —
//! with monotonic `ts_ns` timestamps for offline timeline reconstruction.

pub mod batcher;
pub mod cluster;
pub mod faults;
pub mod ingest;
pub mod merge;
pub mod protocol;
pub mod registry;
pub mod wal;

pub use batcher::{
    BatcherClient, BatcherOptions, BatcherStats, MicroBatcher, PredictError, PredictReply,
};
pub use cluster::{
    canonical_train_line, run_coordinator_tcp, ClusterCoordinator, ClusterStats, NodeHealth,
    NodeLink, NodeState,
};
pub use faults::{FaultPlan, NetFaultPlan, WorkerPanic};
pub use ingest::{
    Admission, IngestHealth, IngestReport, RecoveryReport, ShardedIngest,
};
pub use merge::merge_shard_models;
pub use protocol::{serve_connections, serve_session, ServeState};
pub use registry::{
    LifecycleStats, ModelRegistry, ModelSnapshot, ShadowOutcome, ShadowPolicy,
};
pub use wal::{WalWriter, CHECKPOINT_FILE, WAL_FILE};

use anyhow::{ensure, Result};

use crate::solver::{SolverSpec, SvmConfig};

/// Configuration of the serving subsystem (`repro serve`): the request
/// front end, the ingest pipeline, the resilience knobs, and the model
/// hyperparameters used for models trained *by* the pipeline (ignored
/// when serving a pre-trained model that is never updated).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// TCP port for `repro serve --port`. The listener binds loopback
    /// only — the wire protocol is unauthenticated, so external exposure
    /// goes through a local proxy. Replay mode never opens a socket.
    pub port: u16,
    /// Ingest shard workers `S` (each an independent `partial_fit` stream).
    pub shards: usize,
    /// Rows between automatic snapshot/publish events.
    pub publish_every: usize,
    /// Stall-aware adaptive publish cadence: scale `publish_every` up
    /// (≤ 16×) while publish stalls are expensive, back down when idle.
    /// Off by default — adapted cadences follow the wall clock, so
    /// run-to-run bit-identity of *when* snapshots publish is traded for
    /// throughput (published model contents stay correct either way).
    pub publish_adapt: bool,
    /// Micro-batcher coalescing cap (rows per prediction batch).
    pub batch_max_rows: usize,
    /// Ingest-front buffering: `train` rows accumulated before they are
    /// handed to the shard pipeline as one batch.
    pub ingest_chunk: usize,
    /// Worker threads for batched prediction (0 = all cores).
    pub threads: usize,
    /// Base RNG seed (shards derive their own via `shard_seed`).
    pub seed: u64,
    /// Binary solver the ingest shards train with (`--solver bsgd|bdca`).
    pub solver: SolverSpec,
    /// Ingest queue bound in rows: at half this depth cadence publishes
    /// are deferred (shed-maintenance), at the full depth train batches
    /// are rejected with a typed overloaded reply. 0 = unbounded.
    pub queue_rows: usize,
    /// Predict deadline in milliseconds: requests still queued past this
    /// budget get a typed overloaded reply. 0 = no deadline.
    pub predict_deadline_ms: u64,
    /// Socket read/write timeout in seconds; an idle or stalled client is
    /// disconnected after this long, and the same budget bounds every
    /// coordinator↔node exchange in cluster mode. 0 = no timeout.
    pub io_timeout_secs: u64,
    /// Directory for the WAL + checkpoint pair (crash-safe persistence).
    /// `None` = volatile ingest (no WAL, no checkpoint).
    pub wal_dir: Option<String>,
    /// Recover from the `wal_dir` pair at startup instead of starting
    /// fresh (requires `wal_dir`).
    pub recover: bool,
    /// Rotate the WAL at every durable checkpoint (`--wal-rotate`):
    /// segments older than the checkpoint are truncated away and the
    /// checkpoint model becomes the generation base, keeping WAL size
    /// proportional to the checkpoint cadence instead of the stream
    /// length (requires `wal_dir`).
    pub wal_rotate: bool,
    /// Run as a cluster coordinator (`--coordinator`): deal train rows
    /// to the `nodes`, merge their snapshots, route predicts over them.
    pub coordinator: bool,
    /// Cluster node addresses (`--nodes host:port,...`), coordinator
    /// mode only.
    pub nodes: Vec<String>,
    /// Gate publishes through shadow evaluation against the incumbent
    /// over live predict traffic.
    pub shadow_eval: bool,
    /// Registry versions retained for rollback (min 1).
    pub history: usize,
    /// Loopback port for the Prometheus-text metrics endpoint
    /// (`repro serve --metrics-port`). 0 = endpoint disabled.
    pub metrics_port: u16,
    /// Path for the JSONL telemetry event log
    /// (`repro serve --telemetry-log`). `None` = event log disabled.
    pub telemetry_log: Option<String>,
    /// Hyperparameters for pipeline-trained models.
    pub svm: SvmConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            port: 7878,
            shards: 4,
            publish_every: 1024,
            publish_adapt: false,
            batch_max_rows: 64,
            ingest_chunk: 64,
            threads: 0,
            seed: 0,
            solver: SolverSpec::Bsgd,
            queue_rows: 0,
            predict_deadline_ms: 0,
            io_timeout_secs: 0,
            wal_dir: None,
            recover: false,
            wal_rotate: false,
            coordinator: false,
            nodes: Vec::new(),
            shadow_eval: false,
            history: registry::DEFAULT_HISTORY,
            metrics_port: 0,
            telemetry_log: None,
            svm: SvmConfig::default(),
        }
    }
}

impl ServeConfig {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.shards >= 1, "need at least one ingest shard, got {}", self.shards);
        ensure!(self.publish_every >= 1, "publish_every must be at least 1");
        ensure!(self.batch_max_rows >= 1, "batch_max_rows must be at least 1");
        ensure!(self.ingest_chunk >= 1, "ingest_chunk must be at least 1");
        ensure!(self.history >= 1, "registry history must retain at least one version");
        ensure!(
            !self.recover || self.wal_dir.is_some(),
            "--recover needs --wal-dir (nothing to recover from)"
        );
        ensure!(
            !self.wal_rotate || self.wal_dir.is_some(),
            "--wal-rotate needs --wal-dir (nothing to rotate)"
        );
        ensure!(
            !self.coordinator || !self.nodes.is_empty(),
            "--coordinator needs --nodes host:port,... (no cluster to coordinate)"
        );
        ensure!(
            self.nodes.is_empty() || self.coordinator,
            "--nodes only makes sense with --coordinator"
        );
        for addr in &self.nodes {
            ensure!(
                addr.rsplit_once(':')
                    .is_some_and(|(h, p)| !h.is_empty() && p.parse::<u16>().is_ok()),
                "bad node address '{addr}' (want host:port)"
            );
        }
        self.svm.validate()?;
        ensure!(
            self.svm.budget >= 2,
            "the ingest pipeline trains budgeted models (budget >= 2), got {}",
            self.svm.budget
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_defaults_validate() {
        ServeConfig::new().validate().unwrap();
    }

    #[test]
    fn coordinator_config_validates_with_well_formed_nodes() {
        let cfg = ServeConfig {
            coordinator: true,
            nodes: vec!["127.0.0.1:9001".into(), "10.0.0.7:9002".into()],
            ..Default::default()
        };
        cfg.validate().unwrap();
        let rotated = ServeConfig {
            wal_rotate: true,
            wal_dir: Some("/tmp/wal".into()),
            ..Default::default()
        };
        rotated.validate().unwrap();
    }

    #[test]
    fn serve_config_rejects_degenerate_knobs() {
        for bad in [
            ServeConfig { shards: 0, ..Default::default() },
            ServeConfig { publish_every: 0, ..Default::default() },
            ServeConfig { batch_max_rows: 0, ..Default::default() },
            ServeConfig { ingest_chunk: 0, ..Default::default() },
            ServeConfig { history: 0, ..Default::default() },
            ServeConfig { recover: true, wal_dir: None, ..Default::default() },
            ServeConfig { wal_rotate: true, wal_dir: None, ..Default::default() },
            ServeConfig { coordinator: true, ..Default::default() },
            ServeConfig { nodes: vec!["127.0.0.1:9000".into()], ..Default::default() },
            ServeConfig {
                coordinator: true,
                nodes: vec!["127.0.0.1:bad".into()],
                ..Default::default()
            },
            ServeConfig {
                coordinator: true,
                nodes: vec![":9000".into()],
                ..Default::default()
            },
            ServeConfig {
                svm: SvmConfig::new().budget(1),
                ..Default::default()
            },
        ] {
            assert!(bad.validate().is_err());
        }
    }
}

//! Online serving + streaming ingest over [`crate::model::AnyModel`].
//!
//! This is the first subsystem where training and prediction run
//! *concurrently* on the same model lineage. Three pieces compose it:
//!
//! * [`registry`] — [`ModelRegistry`]: an atomically hot-swappable,
//!   monotonically versioned slot of immutable model snapshots. Readers
//!   clone an `Arc` under a briefly-held read lock and then never touch
//!   shared state again; publishers build the snapshot off to the side and
//!   swap one pointer. Snapshots round-trip through the versioned
//!   `BSVMMDL2` format ([`crate::model::io`]) bit-identically.
//! * [`batcher`] — [`MicroBatcher`]: the prediction front end. Concurrent
//!   requests are coalesced by a queue + condvar into one
//!   `decision_rows` call per wakeup, so every request rides the blocked
//!   SoA tile engine instead of a scalar `decision_function` each.
//! * [`ingest`] — [`ShardedIngest`]: the streaming-ingest pipeline.
//!   Incoming labeled rows are partitioned round-robin across `S`
//!   long-lived shard workers ([`crate::util::parallel::spawn_worker`]),
//!   each running an independent `partial_fit` stream on a shard
//!   estimator from the solver-agnostic factory
//!   ([`crate::solver::AnyEstimator::new_shard`], `--solver bsgd|bdca`)
//!   with a deterministic per-shard seed
//!   ([`crate::solver::bsgd::shard_seed`]). [`merge`] periodically folds
//!   the shard models into one budget-respecting model which is published
//!   into the registry.
//!
//! # Wire protocol (v1, line-oriented UTF-8 — see [`protocol`])
//!
//! ```text
//! predict <i:v ...>          -> ok <+1|-1> v<version>
//! train <label> <i:v ...>    -> ok queued <buffered-rows>
//! flush                      -> ok published v<version>
//! stats                      -> ok <json>
//! quit                       -> ok bye              (connection closes)
//! anything else              -> err <message>
//! ```
//!
//! Feature tokens use the LIBSVM convention: 1-based ascending indices,
//! omitted features are zero. The serving dimension is fixed by the
//! initial model (or, lacking one, by the largest index of the first
//! `train` line) and every later row must fit inside it. Any parse or
//! dispatch failure answers `err <reason>` on that line only; the session
//! stays usable.
//!
//! # Snapshot / publish lifecycle
//!
//! ```text
//!   rows ──round-robin──► shard 0..S-1 workers (partial_fit, per-shard seed)
//!                               │
//!        every publish_every rows (or an explicit flush):
//!                               │ snapshot command, queued AFTER the
//!                               │ shard's pending batches (channel order)
//!                               ▼
//!        weighted merge (weights ∝ shard SGD steps)
//!        budget enforced via the configured maintenance strategy
//!        scale folded  ──►  registry.publish(model)  [one Arc swap]
//! ```
//!
//! Readers are never paused: a publish builds the merged model entirely
//! off to the side and installs it with a single pointer swap, so the
//! "publish stall" is an *ingest-side* pause only (shard drain + merge),
//! measured and reported by the bench harness
//! (`experiments::serve_bench`, `BENCH_serve.json`).
//!
//! # Shard-merge semantics (invariants, in the style of `model/store.rs`)
//!
//! * The merged model carries `Σ_s w_s · f_s` with `w_s = steps_s / Σ
//!   steps` — a step-weighted average of the shard decision functions —
//!   plus the equally weighted average bias.
//! * A single-shard publish (`S = 1`) short-circuits to a clone of the
//!   shard model, so the pipeline at one shard is *equivalent* to serial
//!   `partial_fit` (decision values match to f64 rounding; the only
//!   difference is the folded scale).
//! * The merged model never exceeds the configured budget: excess SVs are
//!   reduced through the same merge/removal/projection machinery training
//!   uses, so a published model is always a valid budgeted model.
//! * Published snapshots have their lazy scale folded (`Φ = 1`), which is
//!   what makes a `BSVMMDL2` dump→load of a snapshot bit-identical to the
//!   in-memory model it was taken from.
//! * Versions are stamped under the publish lock: they are strictly
//!   monotonic, and a reader holding snapshot `v` observes exactly the
//!   model published as `v` (stamp and contents live in one immutable
//!   allocation — no torn reads).

pub mod batcher;
pub mod ingest;
pub mod merge;
pub mod protocol;
pub mod registry;

pub use batcher::{BatcherClient, BatcherOptions, BatcherStats, MicroBatcher, PredictReply};
pub use ingest::{IngestReport, ShardedIngest};
pub use merge::merge_shard_models;
pub use protocol::{serve_connections, serve_session, ServeState};
pub use registry::{ModelRegistry, ModelSnapshot};

use anyhow::{ensure, Result};

use crate::solver::{SolverSpec, SvmConfig};

/// Configuration of the serving subsystem (`repro serve`): the request
/// front end, the ingest pipeline, and the model hyperparameters used for
/// models trained *by* the pipeline (ignored when serving a pre-trained
/// model that is never updated).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// TCP port for `repro serve --port`. The listener binds loopback
    /// only — the wire protocol is unauthenticated, so external exposure
    /// goes through a local proxy. Replay mode never opens a socket.
    pub port: u16,
    /// Ingest shard workers `S` (each an independent `partial_fit` stream).
    pub shards: usize,
    /// Rows between automatic snapshot/publish events.
    pub publish_every: usize,
    /// Stall-aware adaptive publish cadence: scale `publish_every` up
    /// (≤ 16×) while publish stalls are expensive, back down when idle.
    /// Off by default — adapted cadences follow the wall clock, so
    /// run-to-run bit-identity of *when* snapshots publish is traded for
    /// throughput (published model contents stay correct either way).
    pub publish_adapt: bool,
    /// Micro-batcher coalescing cap (rows per prediction batch).
    pub batch_max_rows: usize,
    /// Ingest-front buffering: `train` rows accumulated before they are
    /// handed to the shard pipeline as one batch.
    pub ingest_chunk: usize,
    /// Worker threads for batched prediction (0 = all cores).
    pub threads: usize,
    /// Base RNG seed (shards derive their own via `shard_seed`).
    pub seed: u64,
    /// Binary solver the ingest shards train with (`--solver bsgd|bdca`).
    pub solver: SolverSpec,
    /// Hyperparameters for pipeline-trained models.
    pub svm: SvmConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            port: 7878,
            shards: 4,
            publish_every: 1024,
            publish_adapt: false,
            batch_max_rows: 64,
            ingest_chunk: 64,
            threads: 0,
            seed: 0,
            solver: SolverSpec::Bsgd,
            svm: SvmConfig::default(),
        }
    }
}

impl ServeConfig {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.shards >= 1, "need at least one ingest shard, got {}", self.shards);
        ensure!(self.publish_every >= 1, "publish_every must be at least 1");
        ensure!(self.batch_max_rows >= 1, "batch_max_rows must be at least 1");
        ensure!(self.ingest_chunk >= 1, "ingest_chunk must be at least 1");
        self.svm.validate()?;
        ensure!(
            self.svm.budget >= 2,
            "the ingest pipeline trains budgeted models (budget >= 2), got {}",
            self.svm.budget
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_config_defaults_validate() {
        ServeConfig::new().validate().unwrap();
    }

    #[test]
    fn serve_config_rejects_degenerate_knobs() {
        for bad in [
            ServeConfig { shards: 0, ..Default::default() },
            ServeConfig { publish_every: 0, ..Default::default() },
            ServeConfig { batch_max_rows: 0, ..Default::default() },
            ServeConfig { ingest_chunk: 0, ..Default::default() },
            ServeConfig {
                svm: SvmConfig::new().budget(1),
                ..Default::default()
            },
        ] {
            assert!(bad.validate().is_err());
        }
    }
}

//! One coordinator↔node connection: a line-protocol client with the
//! same defensive I/O discipline the server applies to its clients.
//!
//! A [`NodeLink`] owns at most one TCP connection to its node and
//! exposes two calls:
//!
//! * [`NodeLink::exchange`] — a single request/reply round trip, no
//!   retries. Socket read/write timeouts are applied at connect time
//!   (the CLIENT side of the `--io-timeout-secs` knob), replies are read
//!   through [`protocol::read_bounded_line`] with the protocol's
//!   [`protocol::MAX_LINE_BYTES`] bound, and a reply that is not valid
//!   UTF-8 or does not start with a protocol verb (`ok` / `overloaded` /
//!   `err`) drops the connection and fails the exchange — a corrupt
//!   reply is indistinguishable from a broken peer.
//! * [`NodeLink::request`] — `exchange` wrapped in the link's seeded
//!   equal-jitter [`Backoff`]: on failure the connection is dropped, the
//!   next delay slept, and the exchange retried from a fresh connection
//!   until the retry budget is exhausted (a typed, permanent error the
//!   coordinator maps to a node-health failure). Success resets the
//!   budget.
//!
//! For the deterministic cluster benches a [`NetFaultPlan`] can be
//! installed together with a shared dealt-row counter; the link then
//! simulates cut links (kill/partition), slow replies, and a one-shot
//! corrupted reply at the scheduled row counts, all below `request` so
//! the real backoff/health machinery is what gets exercised.

use std::io::{self, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::super::faults::NetFaultPlan;
use super::super::protocol;
use crate::util::backoff::Backoff;

/// A reply line from a node, already validated to start with a protocol
/// verb.
pub type Reply = String;

/// One coordinator-side connection to a cluster node.
pub struct NodeLink {
    index: usize,
    addr: String,
    io_timeout: Option<Duration>,
    backoff: Backoff,
    conn: Option<(BufReader<TcpStream>, TcpStream)>,
    faults: Option<(NetFaultPlan, Arc<AtomicU64>)>,
    corrupt_fired: bool,
}

impl NodeLink {
    /// A link to node `index` at `addr` (`host:port`), not yet
    /// connected. `io_timeout` is applied to every socket as both the
    /// read and the write timeout; `backoff` governs `request` retries.
    pub fn new(index: usize, addr: String, io_timeout: Option<Duration>, backoff: Backoff) -> Self {
        NodeLink {
            index,
            addr,
            io_timeout,
            backoff,
            conn: None,
            faults: None,
            corrupt_fired: false,
        }
    }

    /// Install a network fault schedule for this link. `dealt` is the
    /// coordinator's global dealt-row counter, shared across links, that
    /// the plan's triggers are keyed on.
    pub fn with_faults(mut self, plan: NetFaultPlan, dealt: Arc<AtomicU64>) -> Self {
        self.faults = Some((plan, dealt));
        self
    }

    /// This link's node index.
    pub fn index(&self) -> usize {
        self.index
    }

    /// This link's node address.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Drop the connection (next exchange reconnects).
    pub fn disconnect(&mut self) {
        self.conn = None;
    }

    fn dealt_rows(&self) -> u64 {
        self.faults.as_ref().map_or(0, |(_, dealt)| dealt.load(Ordering::SeqCst))
    }

    fn connect(&mut self) -> Result<()> {
        if self.conn.is_some() {
            return Ok(());
        }
        let stream = TcpStream::connect(&self.addr)
            .map_err(|e| anyhow!("node {} ({}): connect failed: {e}", self.index, self.addr))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(self.io_timeout)?;
        stream.set_write_timeout(self.io_timeout)?;
        let reader = BufReader::new(stream.try_clone()?);
        self.conn = Some((reader, stream));
        Ok(())
    }

    /// One request/reply round trip, no retries. Any failure drops the
    /// connection so the next attempt starts clean.
    pub fn exchange(&mut self, line: &str) -> Result<Reply> {
        let rows = self.dealt_rows();
        if let Some((plan, _)) = &self.faults {
            if plan.link_cut(self.index, rows) {
                self.disconnect();
                bail!("node {} ({}): link cut (injected)", self.index, self.addr);
            }
        }
        let result = self.exchange_inner(line, rows);
        if result.is_err() {
            self.disconnect();
        }
        result
    }

    fn exchange_inner(&mut self, line: &str, rows: u64) -> Result<Reply> {
        self.connect()?;
        let (reader, writer) = self.conn.as_mut().expect("connected above");
        writer
            .write_all(line.as_bytes())
            .and_then(|()| writer.write_all(b"\n"))
            .and_then(|()| writer.flush())
            .map_err(|e| anyhow!("node {} ({}): write failed: {e}", self.index, self.addr))?;
        if let Some((plan, _)) = &self.faults {
            if let Some((slow, delay_ms)) = plan.slow_node {
                if slow == self.index && delay_ms > 0 {
                    std::thread::sleep(Duration::from_millis(delay_ms));
                }
            }
        }
        let (bytes, truncated) = read_reply(reader)
            .map_err(|e| anyhow!("node {} ({}): read failed: {e}", self.index, self.addr))?
            .ok_or_else(|| {
                anyhow!("node {} ({}): peer closed the connection", self.index, self.addr)
            })?;
        if truncated {
            bail!(
                "node {} ({}): reply exceeds {} bytes",
                self.index,
                self.addr,
                protocol::MAX_LINE_BYTES
            );
        }
        let mut reply = String::from_utf8(bytes)
            .map_err(|_| anyhow!("node {} ({}): reply is not UTF-8", self.index, self.addr))?
            .trim_end()
            .to_string();
        if let Some((plan, _)) = &self.faults {
            if let Some((node, at_rows)) = plan.corrupt_reply {
                if node == self.index && rows >= at_rows && !self.corrupt_fired {
                    self.corrupt_fired = true;
                    reply = scramble(&reply);
                }
            }
        }
        if !is_protocol_reply(&reply) {
            bail!("node {} ({}): malformed reply '{reply}'", self.index, self.addr);
        }
        Ok(reply)
    }

    /// `exchange` with seeded-jitter retries until the retry budget is
    /// exhausted. Success resets the budget; exhaustion is the signal
    /// the coordinator feeds into [`super::NodeHealth::on_failure`].
    pub fn request(&mut self, line: &str) -> Result<Reply> {
        loop {
            match self.exchange(line) {
                Ok(reply) => {
                    self.backoff.reset();
                    return Ok(reply);
                }
                Err(err) => {
                    let delay = self.backoff.next_delay().map_err(|budget| {
                        anyhow!("node {} ({}): {budget}: last error: {err}", self.index, self.addr)
                    })?;
                    std::thread::sleep(delay);
                }
            }
        }
    }

    /// Probe the node's `health` verb — a single exchange, no retries,
    /// so the heartbeat cadence stays fixed regardless of node state.
    /// Returns `(model_version, rows_ingested)`.
    pub fn probe(&mut self) -> Result<(u64, u64)> {
        let reply = self.exchange("health")?;
        let mut parts = reply.split_whitespace();
        match (parts.next(), parts.next(), parts.next()) {
            (Some("ok"), Some(v), Some(r)) => {
                let version = v.parse().map_err(|_| anyhow!("bad health version '{v}'"))?;
                let rows = r.parse().map_err(|_| anyhow!("bad health row count '{r}'"))?;
                Ok((version, rows))
            }
            _ => bail!("node {} ({}): unexpected health reply '{reply}'", self.index, self.addr),
        }
    }
}

/// Read one bounded reply line; `Ok(None)` is a clean EOF.
fn read_reply(reader: &mut BufReader<TcpStream>) -> io::Result<Option<(Vec<u8>, bool)>> {
    protocol::read_bounded_line(reader, protocol::MAX_LINE_BYTES)
}

/// Whether a reply line starts with one of the protocol's reply verbs.
fn is_protocol_reply(reply: &str) -> bool {
    reply.starts_with("ok") || reply.starts_with("overloaded") || reply.starts_with("err")
}

/// Deterministically mangle a reply so it fails verb validation.
fn scramble(reply: &str) -> String {
    let mut out = String::with_capacity(reply.len() + 1);
    out.push('\u{fffd}');
    out.extend(reply.chars().rev());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn link_to(addr: String, budget: u32) -> NodeLink {
        let backoff =
            Backoff::new(Duration::from_micros(100), Duration::from_millis(2), budget, 7);
        NodeLink::new(0, addr, Some(Duration::from_secs(2)), backoff)
    }

    /// Echo server: accepts `conns` connections in sequence, answering
    /// every line on each with `reply` until the peer hangs up, then
    /// exits (so tests can join it).
    fn spawn_echo(reply: &'static str, conns: usize) -> (String, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            for _ in 0..conns {
                let Ok((stream, _)) = listener.accept() else { return };
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut stream = stream;
                while let Ok(Some((_line, _))) =
                    protocol::read_bounded_line(&mut reader, protocol::MAX_LINE_BYTES)
                {
                    if writeln!(stream, "{reply}").is_err() {
                        break;
                    }
                }
            }
        });
        (addr, handle)
    }

    #[test]
    fn a_request_round_trips_and_resets_the_budget() {
        let (addr, handle) = spawn_echo("ok 1 2", 1);
        let mut link = link_to(addr, 2);
        assert_eq!(link.request("health").unwrap(), "ok 1 2");
        assert_eq!(link.request("health").unwrap(), "ok 1 2");
        assert_eq!(link.probe().unwrap(), (1, 2));
        drop(link);
        handle.join().unwrap();
    }

    #[test]
    fn a_malformed_reply_is_an_exchange_error_not_a_panic() {
        let (addr, handle) = spawn_echo("definitely not a protocol reply", 1);
        let mut link = link_to(addr, 1);
        let err = link.exchange("health").unwrap_err().to_string();
        assert!(err.contains("malformed reply"), "got: {err}");
        drop(link);
        handle.join().unwrap();
    }

    #[test]
    fn retry_budget_exhaustion_is_a_typed_permanent_error() {
        // Nothing listens on this address: bind a port, then drop it.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut link = link_to(addr, 3);
        let err = link.request("health").unwrap_err().to_string();
        assert!(err.contains("retry budget exhausted after 3 attempts"), "got: {err}");
    }

    #[test]
    fn an_injected_link_cut_fails_without_touching_the_network() {
        let dealt = Arc::new(AtomicU64::new(50));
        let plan = NetFaultPlan::none().with_kill(0, 40);
        // Address is never dialled: the cut fires before connect.
        let mut link = link_to("127.0.0.1:1".to_string(), 1).with_faults(plan, Arc::clone(&dealt));
        let err = link.exchange("health").unwrap_err().to_string();
        assert!(err.contains("link cut (injected)"), "got: {err}");
        // Before the trigger the schedule stays out of the way (the
        // connect itself then fails, which is a different error).
        dealt.store(10, Ordering::SeqCst);
        let err = link.exchange("health").unwrap_err().to_string();
        assert!(err.contains("connect failed"), "got: {err}");
    }

    #[test]
    fn a_corrupted_reply_fires_once_then_the_link_recovers() {
        let (addr, handle) = spawn_echo("ok 3 4", 2);
        let dealt = Arc::new(AtomicU64::new(100));
        let plan = NetFaultPlan::none().with_corrupt_reply(0, 10);
        let mut link = link_to(addr, 4).with_faults(plan, dealt);
        // request() eats the one corrupted reply via a retry and then
        // succeeds against the same server.
        assert_eq!(link.request("health").unwrap(), "ok 3 4");
        assert!(link.corrupt_fired);
        drop(link);
        handle.join().unwrap();
    }
}

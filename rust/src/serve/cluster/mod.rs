//! Multi-node serve tier: a coordinator that deals acked train rows to
//! remote shard nodes over the line protocol, merges their snapshots
//! into one served model, and survives node loss.
//!
//! The cluster reuses the single-process pieces wholesale — every node
//! is an ordinary `repro serve` process (loopback TCP, line protocol,
//! WAL + checkpoint per node), and the coordinator is a thin router
//! built from three parts:
//!
//! * [`node`] — [`NodeLink`]: one coordinator↔node connection. Socket
//!   read/write timeouts and bounded line reads (the same discipline the
//!   server applies to clients), every exchange wrapped in a seeded
//!   equal-jitter [`crate::util::backoff::Backoff`] with a retry budget,
//!   and a [`super::faults::NetFaultPlan`] injection point for the
//!   deterministic cluster benches.
//! * [`heartbeat`] — [`NodeHealth`]: the per-node availability state
//!   machine (`up → suspect → down → rejoining → up`), driven by probe
//!   and exchange outcomes. Pure state — no I/O — so the transitions are
//!   unit-testable and deterministic.
//! * [`coordinator`] — [`ClusterCoordinator`]: deals rows round-robin
//!   over up nodes (a node's ack is the client's ack; rows orphaned by a
//!   node going down are re-dealt to survivors, at-least-once with
//!   coordinator-side dedup by row sequence number), pulls node
//!   snapshots on cadence, merges them via [`super::merge`], publishes
//!   the merged model locally and pushes it back to every up node (the
//!   prediction replicas), and fans predict traffic out over the
//!   replicas with sequential failover.
//!
//! Failure semantics, in one table:
//!
//! | failure                | detection              | response                                   |
//! |------------------------|------------------------|--------------------------------------------|
//! | node stops answering   | retry budget exhausted | mark suspect→down, re-deal unacked rows    |
//! | node partitioned       | same                   | same; heals via heartbeat probes           |
//! | node rejoins           | probe succeeds on down | push latest merged snapshot, then serve    |
//! | replica dead on predict| exchange fails         | fail over to next replica, else local model|
//! | corrupt reply          | malformed reply line   | drop connection, retry through backoff     |

pub mod coordinator;
pub mod heartbeat;
pub mod node;

pub use coordinator::{canonical_train_line, run_coordinator_tcp, ClusterCoordinator, ClusterStats};
pub use heartbeat::{NodeHealth, NodeState};
pub use node::NodeLink;

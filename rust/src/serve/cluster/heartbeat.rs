//! Per-node availability state machine, driven by heartbeat probes and
//! exchange outcomes.
//!
//! The coordinator holds one [`NodeHealth`] per node and feeds it two
//! events: `on_success` (a probe or exchange completed) and `on_failure`
//! (the link's retry budget was exhausted). The machine is pure state —
//! no I/O, no clocks — so transitions are deterministic and the cluster
//! benches replay identically run-to-run:
//!
//! ```text
//!        on_failure              on_failure × threshold
//!   Up ─────────────▶ Suspect ─────────────────────────▶ Down
//!    ▲                   │ on_success                      │ on_success
//!    │                   ▼                                 ▼
//!    │◀────────────── (Up) ◀── mark_synced ─────────── Rejoining
//! ```
//!
//! A down node that answers a probe does **not** go straight back to
//! `Up`: it first passes through `Rejoining`, where the coordinator
//! pushes the latest merged snapshot (`snapshot load`) before the node
//! is allowed back into the deal and predict rotations. That re-sync is
//! what keeps a rejoining replica from serving a stale model.

/// Availability of one cluster node, as observed by the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Healthy: in the deal and predict rotations.
    Up,
    /// One or more recent failures, below the down threshold. Still
    /// excluded from new work; the next success restores `Up`.
    Suspect,
    /// Failure count crossed the threshold: out of both rotations, its
    /// unacked rows re-dealt to survivors. Probes continue.
    Down,
    /// A probe succeeded on a down node; waiting for the coordinator to
    /// push the latest merged snapshot before rejoining the rotations.
    Rejoining,
}

impl NodeState {
    /// Whether the node may take new rows and predict traffic.
    pub fn is_up(self) -> bool {
        matches!(self, NodeState::Up)
    }

    /// Stable lower-case label for reports and stats.
    pub fn label(self) -> &'static str {
        match self {
            NodeState::Up => "up",
            NodeState::Suspect => "suspect",
            NodeState::Down => "down",
            NodeState::Rejoining => "rejoining",
        }
    }
}

/// The state machine for one node: current [`NodeState`] plus the
/// consecutive-failure count that drives the suspect→down transition.
#[derive(Debug, Clone, Copy)]
pub struct NodeHealth {
    state: NodeState,
    failures: u32,
    down_threshold: u32,
}

impl NodeHealth {
    /// A healthy node that goes down after `down_threshold` consecutive
    /// failures (clamped to at least 1: the first failure always at
    /// least suspects the node).
    pub fn new(down_threshold: u32) -> Self {
        NodeHealth { state: NodeState::Up, failures: 0, down_threshold: down_threshold.max(1) }
    }

    /// Current availability.
    pub fn state(&self) -> NodeState {
        self.state
    }

    /// Consecutive failures since the last success.
    pub fn failures(&self) -> u32 {
        self.failures
    }

    /// A probe or exchange failed (retry budget exhausted). Returns the
    /// new state; the caller re-deals the node's unacked rows iff this
    /// transition reached `Down`.
    pub fn on_failure(&mut self) -> NodeState {
        self.failures = self.failures.saturating_add(1);
        self.state = match self.state {
            NodeState::Down => NodeState::Down,
            // A rejoining node that fails its re-sync goes straight back
            // down — it never served while stale.
            NodeState::Rejoining => NodeState::Down,
            _ if self.failures >= self.down_threshold => NodeState::Down,
            _ => NodeState::Suspect,
        };
        self.state
    }

    /// A probe or exchange succeeded. A down node moves to `Rejoining`
    /// (it must be re-synced before serving); anything else is `Up`.
    pub fn on_success(&mut self) -> NodeState {
        self.failures = 0;
        self.state = match self.state {
            NodeState::Down | NodeState::Rejoining => NodeState::Rejoining,
            _ => NodeState::Up,
        };
        self.state
    }

    /// The coordinator finished pushing the merged snapshot to a
    /// rejoining node: back into the rotations.
    pub fn mark_synced(&mut self) -> NodeState {
        if self.state == NodeState::Rejoining {
            self.state = NodeState::Up;
            self.failures = 0;
        }
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failures_walk_up_suspect_down_and_success_resets() {
        let mut h = NodeHealth::new(3);
        assert_eq!(h.state(), NodeState::Up);
        assert_eq!(h.on_failure(), NodeState::Suspect);
        assert_eq!(h.on_failure(), NodeState::Suspect);
        // A success below the threshold fully restores the node.
        assert_eq!(h.on_success(), NodeState::Up);
        assert_eq!(h.failures(), 0);
        assert_eq!(h.on_failure(), NodeState::Suspect);
        assert_eq!(h.on_failure(), NodeState::Suspect);
        assert_eq!(h.on_failure(), NodeState::Down);
        // Further failures keep it down, not deeper.
        assert_eq!(h.on_failure(), NodeState::Down);
    }

    #[test]
    fn a_down_node_rejoins_only_through_resync() {
        let mut h = NodeHealth::new(1);
        assert_eq!(h.on_failure(), NodeState::Down);
        // Probe succeeds: rejoining, but not yet in the rotations.
        assert_eq!(h.on_success(), NodeState::Rejoining);
        assert!(!h.state().is_up());
        // Re-sync completes: up.
        assert_eq!(h.mark_synced(), NodeState::Up);
        assert!(h.state().is_up());
    }

    #[test]
    fn a_failed_resync_drops_the_node_back_down() {
        let mut h = NodeHealth::new(2);
        h.on_failure();
        h.on_failure();
        assert_eq!(h.state(), NodeState::Down);
        assert_eq!(h.on_success(), NodeState::Rejoining);
        assert_eq!(h.on_failure(), NodeState::Down);
        // mark_synced on a non-rejoining node is a no-op.
        assert_eq!(h.mark_synced(), NodeState::Down);
    }

    #[test]
    fn threshold_is_clamped_to_at_least_one() {
        let mut h = NodeHealth::new(0);
        assert_eq!(h.on_failure(), NodeState::Down);
    }

    #[test]
    fn state_labels_are_stable() {
        assert_eq!(NodeState::Up.label(), "up");
        assert_eq!(NodeState::Suspect.label(), "suspect");
        assert_eq!(NodeState::Down.label(), "down");
        assert_eq!(NodeState::Rejoining.label(), "rejoining");
    }
}

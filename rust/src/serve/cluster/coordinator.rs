//! The cluster coordinator: deals acked train rows over the up nodes,
//! merges their snapshots into one served model, and fans predict
//! traffic out over the replicas with failover.
//!
//! Dealing contract (at-least-once, dedup by sequence number): every
//! train row gets a global sequence number and is held in the target
//! node's unacked queue until that node's `ok` comes back — the node's
//! ack is the client's ack. If the link's retry budget runs out, the
//! node takes a health failure and *all* of its unacked rows are
//! re-dealt to survivors. An acked row is dropped from coordinator
//! state entirely, so it can never be dealt twice by the coordinator;
//! a node that died after applying a row whose ack was lost may hold a
//! duplicate (at-least-once), which WAL replay tolerates and the
//! resilience bench's loss accounting treats as benign.
//!
//! Model flow: on a row cadence the coordinator asks every up node to
//! `flush` and `snapshot`, merges the returned shard models through
//! [`merge_shard_models`] weighted by each node's ingested row count,
//! publishes the merged model into its local [`ModelRegistry`] (the
//! failover replica of last resort), and pushes it back to every up
//! node with `snapshot load` — which is also exactly how a rejoining
//! node is re-synced before it re-enters the rotations.

use std::collections::VecDeque;
use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Result};

use super::super::merge::merge_shard_models;
use super::super::protocol::{self, MAX_LINE_BYTES};
use super::super::registry::ModelRegistry;
use super::super::ServeConfig;
use super::heartbeat::{NodeHealth, NodeState};
use super::node::NodeLink;
use crate::solver::SvmConfig;
use crate::telemetry::{self, Counter, Gauge, Stage};
use crate::util::backoff::Backoff;
use crate::util::json::Json;

/// Heartbeat cadence of the TCP coordinator's probe thread.
const HEARTBEAT_INTERVAL: Duration = Duration::from_secs(1);

/// Consecutive failures before a node is declared down.
const DOWN_THRESHOLD: u32 = 3;

/// A row router over a set of cluster nodes. Single-threaded by
/// design — the TCP front serializes sessions through a mutex, and the
/// benches drive it directly — which is what keeps a seeded scenario
/// deterministic.
pub struct ClusterCoordinator {
    links: Vec<NodeLink>,
    health: Vec<NodeHealth>,
    /// Per node: rows dealt to it whose ack has not arrived. Drained
    /// and re-dealt when the node goes down.
    pending: Vec<VecDeque<(u64, String)>>,
    registry: Arc<ModelRegistry>,
    svm: SvmConfig,
    /// Global dealt-row clock, shared with the links' fault schedules.
    dealt: Arc<AtomicU64>,
    seq: u64,
    acked: u64,
    rows_redealt: u64,
    failovers: u64,
    refused: u64,
    deal_rr: usize,
    predict_rr: usize,
    /// Pull + merge + publish after this many acked rows (0 = only on
    /// explicit `flush`).
    sync_every: u64,
    last_sync: u64,
    /// Bench hook: canonical wire lines of every acked row, for the
    /// zero-loss audit against the nodes' WALs.
    acked_ledger: Option<Vec<String>>,
}

/// Point-in-time counters for `stats` replies and bench reports.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    pub nodes: usize,
    pub nodes_up: usize,
    pub rows_dealt: u64,
    pub acked_rows: u64,
    pub rows_redealt: u64,
    pub failovers: u64,
    pub refused: u64,
    pub merged_version: u64,
    pub states: Vec<&'static str>,
}

impl ClusterStats {
    /// The stats as the JSON object the coordinator's `stats` verb
    /// returns.
    pub fn to_json(&self) -> Json {
        Json::object(vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("nodes_up", Json::num(self.nodes_up as f64)),
            ("rows_dealt", Json::num(self.rows_dealt as f64)),
            ("acked_rows", Json::num(self.acked_rows as f64)),
            ("rows_redealt", Json::num(self.rows_redealt as f64)),
            ("failovers", Json::num(self.failovers as f64)),
            ("refused", Json::num(self.refused as f64)),
            ("merged_version", Json::num(self.merged_version as f64)),
            (
                "node_states",
                Json::Array(self.states.iter().map(|s| Json::str(s)).collect()),
            ),
        ])
    }
}

impl ClusterCoordinator {
    /// A coordinator over `links` (one per node, same order as the
    /// node indices baked into them). `sync_every` is the acked-row
    /// cadence of the pull→merge→publish→push cycle.
    pub fn new(
        links: Vec<NodeLink>,
        svm: SvmConfig,
        registry: Arc<ModelRegistry>,
        sync_every: u64,
    ) -> Self {
        assert!(!links.is_empty(), "a cluster needs at least one node");
        let n = links.len();
        let coord = ClusterCoordinator {
            links,
            health: vec![NodeHealth::new(DOWN_THRESHOLD); n],
            pending: (0..n).map(|_| VecDeque::new()).collect(),
            registry,
            svm,
            dealt: Arc::new(AtomicU64::new(0)),
            seq: 0,
            acked: 0,
            rows_redealt: 0,
            failovers: 0,
            refused: 0,
            deal_rr: 0,
            predict_rr: 0,
            sync_every,
            last_sync: 0,
            acked_ledger: None,
        };
        coord.publish_nodes_up();
        coord
    }

    /// Share the dealt-row clock with the links' fault schedules (the
    /// benches build the links around the same counter).
    pub fn with_deal_clock(mut self, dealt: Arc<AtomicU64>) -> Self {
        dealt.store(self.seq, Ordering::SeqCst);
        self.dealt = dealt;
        self
    }

    /// Record the canonical wire line of every acked row (bench loss
    /// audit).
    pub fn record_acked_lines(&mut self) {
        self.acked_ledger = Some(Vec::new());
    }

    /// The recorded acked lines (empty unless [`record_acked_lines`]
    /// was called).
    ///
    /// [`record_acked_lines`]: ClusterCoordinator::record_acked_lines
    pub fn acked_lines(&self) -> &[String] {
        self.acked_ledger.as_deref().unwrap_or(&[])
    }

    /// The coordinator's local registry (merged models).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Current per-node availability.
    pub fn node_states(&self) -> Vec<NodeState> {
        self.health.iter().map(|h| h.state()).collect()
    }

    fn publish_nodes_up(&self) {
        let up = self.health.iter().filter(|h| h.state().is_up()).count();
        telemetry::registry::gauge_set(Gauge::NodesUp, up as u64);
    }

    /// First up node at or after `start` in ring order.
    fn next_up_from(&self, start: usize) -> Option<usize> {
        let n = self.links.len();
        (0..n).map(|k| (start + k) % n).find(|&i| self.health[i].state().is_up())
    }

    fn node_success(&mut self, node: usize) {
        self.health[node].on_success();
        self.publish_nodes_up();
    }

    /// Feed a link failure into the node's health; returns the state it
    /// landed in.
    fn node_failure(&mut self, node: usize) -> NodeState {
        let state = self.health[node].on_failure();
        self.publish_nodes_up();
        state
    }

    /// Deal one labeled row as its [`canonical_train_line`].
    pub fn deal_train(&mut self, label: f32, row: &[f32]) -> Result<String> {
        self.deal_train_line(&canonical_train_line(label, row))
    }

    /// Deal one raw `train ...` wire line (the TCP front forwards client
    /// lines verbatim after validating the verb and label).
    pub fn deal_train_line(&mut self, line: &str) -> Result<String> {
        let mut parts = line.split_whitespace();
        ensure!(parts.next() == Some("train"), "deal_train_line takes a train line");
        let label_tok = parts.next().ok_or_else(|| anyhow!("train needs a label"))?;
        let label: f64 =
            label_tok.parse().map_err(|_| anyhow!("bad label '{label_tok}'"))?;
        ensure!(label.is_finite(), "non-finite label '{label_tok}'");
        let seq = self.seq;
        self.seq += 1;
        self.dealt.store(self.seq, Ordering::SeqCst);
        self.deal(seq, line.to_string())
    }

    /// The deal loop: route every queued row to an up node, absorbing
    /// refusals by rotating and link death by re-dealing the dead
    /// node's unacked queue. Returns the ack reply of the row that
    /// triggered the call.
    fn deal(&mut self, seq: u64, line: String) -> Result<String> {
        let mut work: VecDeque<(u64, String)> = VecDeque::new();
        work.push_back((seq, line));
        let mut last_reply = String::new();
        'rows: while let Some((seq, line)) = work.pop_front() {
            let mut attempts = 0usize;
            loop {
                attempts += 1;
                ensure!(
                    attempts <= 4 * self.links.len() + 8,
                    "row {seq}: no node accepted it after {attempts} attempts"
                );
                let Some(node) = self.next_up_from(self.deal_rr) else {
                    bail!("cluster fully degraded: no node is up to take row {seq}");
                };
                self.deal_rr = (node + 1) % self.links.len();
                self.pending[node].push_back((seq, line.clone()));
                match self.links[node].request(&line) {
                    Ok(reply) if reply.starts_with("ok") => {
                        self.pending[node].pop_back();
                        self.node_success(node);
                        self.acked += 1;
                        if let Some(ledger) = &mut self.acked_ledger {
                            ledger.push(line.clone());
                        }
                        last_reply = reply;
                        continue 'rows;
                    }
                    Ok(_refusal) => {
                        // `overloaded` / `err`: the node answered and
                        // declined — the link is healthy, rotate on.
                        self.pending[node].pop_back();
                        self.node_success(node);
                        self.refused += 1;
                    }
                    Err(_) => {
                        // Retry budget exhausted: health failure, and
                        // everything unacked on this node goes back
                        // into the work queue (at-least-once).
                        self.node_failure(node);
                        let orphans: Vec<(u64, String)> =
                            self.pending[node].drain(..).collect();
                        let n = orphans.len() as u64;
                        self.rows_redealt += n;
                        telemetry::registry::count_n(Counter::RowsRedealt, n);
                        for item in orphans.into_iter().rev() {
                            work.push_front(item);
                        }
                        continue 'rows;
                    }
                }
            }
        }
        Ok(last_reply)
    }

    /// Forward a `predict ...` wire line to a replica, failing over
    /// across the up nodes and falling back to the local merged model.
    /// Infallible by the protocol's contract: failures become `err`
    /// replies.
    pub fn forward_predict(&mut self, line: &str) -> String {
        for _ in 0..self.links.len() {
            let Some(node) = self.next_up_from(self.predict_rr) else { break };
            self.predict_rr = (node + 1) % self.links.len();
            match self.links[node].exchange(line) {
                Ok(reply) => {
                    self.node_success(node);
                    return reply;
                }
                Err(_) => {
                    self.node_failure(node);
                    self.failovers += 1;
                    telemetry::registry::count(Counter::Failovers);
                }
            }
        }
        self.local_predict(line)
    }

    /// Answer a predict from the coordinator's own merged model — the
    /// replica of last resort when every node is out.
    fn local_predict(&self, line: &str) -> String {
        let Some(snap) = self.registry.current() else {
            return "err no replica is up and no model is merged yet".to_string();
        };
        let mut parts = line.split_whitespace();
        if parts.next() != Some("predict") {
            return "err expected a predict line".to_string();
        }
        match protocol::parse_features(parts, snap.model().dim()) {
            Ok(row) => {
                let label = if snap.model().decision(&row) > 0.0 { "+1" } else { "-1" };
                format!("ok {label} v{} local", snap.version())
            }
            Err(msg) => format!("err {msg}"),
        }
    }

    /// One heartbeat pass: probe every node's `health` verb (a single
    /// exchange, so the cadence is fixed), feed the outcome into its
    /// state machine, and re-sync any node that just came back.
    pub fn heartbeat_tick(&mut self) {
        for i in 0..self.links.len() {
            let t0 = Instant::now();
            let probe = self.links[i].probe();
            telemetry::registry::record_stage_ns(
                Stage::Heartbeat,
                t0.elapsed().as_nanos() as u64,
            );
            match probe {
                Ok(_) => {
                    if self.health[i].on_success() == NodeState::Rejoining {
                        self.resync_node(i);
                    }
                }
                Err(_) => {
                    self.health[i].on_failure();
                }
            }
        }
        self.publish_nodes_up();
    }

    /// Push the latest merged model to a rejoining node; only a
    /// successful push (or having nothing to push) readmits it.
    fn resync_node(&mut self, node: usize) {
        let Some(snap) = self.registry.current() else {
            // Nothing merged yet — the node cannot be staler than us.
            self.health[node].mark_synced();
            return;
        };
        let mut bytes = Vec::new();
        if crate::model::io::save_any_writer(snap.model(), &mut bytes).is_err() {
            return;
        }
        let line =
            format!("snapshot load {} {}", snap.version(), protocol::hex_encode(&bytes));
        match self.links[node].request(&line) {
            Ok(reply) if reply.starts_with("ok") => {
                self.health[node].mark_synced();
            }
            _ => {
                self.health[node].on_failure();
            }
        }
    }

    /// Pull a snapshot from every up node (after a `flush`), merge the
    /// shard models weighted by each node's ingested rows, publish the
    /// merged model locally, and push it back to the up replicas.
    /// Returns the local registry version of the merge.
    pub fn sync_models(&mut self) -> Result<u64> {
        let mut models = Vec::new();
        let mut weights = Vec::new();
        for i in 0..self.links.len() {
            if !self.health[i].state().is_up() {
                continue;
            }
            // A flush refusal (e.g. nothing ingested yet) is an answer,
            // not a link failure — the snapshot pull below decides.
            if self.links[i].request("flush").is_err() {
                self.node_failure(i);
                continue;
            }
            let reply = match self.links[i].request("snapshot") {
                Ok(r) => r,
                Err(_) => {
                    self.node_failure(i);
                    continue;
                }
            };
            let mut parts = reply.split_whitespace();
            let (Some("ok"), Some(_ver), Some(rows_tok), Some(hex)) =
                (parts.next(), parts.next(), parts.next(), parts.next())
            else {
                continue; // `err no model published yet` and kin
            };
            let Ok(rows) = rows_tok.parse::<u64>() else { continue };
            let Ok(bytes) = protocol::hex_decode(hex) else { continue };
            let Ok(model) = crate::model::io::load_any_reader(&bytes[..]) else { continue };
            models.push(model);
            weights.push(rows.max(1) as f64);
        }
        ensure!(!models.is_empty(), "no up node produced a snapshot to merge");
        let merged =
            merge_shard_models(models, &weights, self.svm.budget, &self.svm.maintenance())?;
        let mut bytes = Vec::new();
        crate::model::io::save_any_writer(&merged, &mut bytes)?;
        let version = self.registry.publish(merged);
        let push = format!("snapshot load {version} {}", protocol::hex_encode(&bytes));
        for i in 0..self.links.len() {
            if !self.health[i].state().is_up() {
                continue;
            }
            if self.links[i].request(&push).is_err() {
                self.node_failure(i);
            }
        }
        self.last_sync = self.acked;
        Ok(version)
    }

    /// Run the sync cycle if the acked-row cadence is due. Early in a
    /// stream no node may have anything to snapshot yet; that is not an
    /// error, just "not yet".
    pub fn maybe_sync(&mut self) -> Option<u64> {
        if self.sync_every == 0 || self.acked.saturating_sub(self.last_sync) < self.sync_every
        {
            return None;
        }
        self.sync_models().ok()
    }

    /// Point-in-time counters.
    pub fn stats(&self) -> ClusterStats {
        ClusterStats {
            nodes: self.links.len(),
            nodes_up: self.health.iter().filter(|h| h.state().is_up()).count(),
            rows_dealt: self.seq,
            acked_rows: self.acked,
            rows_redealt: self.rows_redealt,
            failovers: self.failovers,
            refused: self.refused,
            merged_version: self.registry.version(),
            states: self.health.iter().map(|h| h.state().label()).collect(),
        }
    }
}

/// The canonical `train` wire line for a labeled dense row. The line
/// always carries the highest feature index explicitly (a `d:0` token
/// if the last component is zero) so every node pins the same serving
/// dimension no matter which row it sees first. The resilience bench
/// rebuilds these lines from WAL replays for its zero-loss audit, so
/// the mapping must stay a pure function of `(label, row)`.
pub fn canonical_train_line(label: f32, row: &[f32]) -> String {
    let mut feats = protocol::format_features(row);
    if let Some(&last) = row.last() {
        if last == 0.0 {
            feats.push_str(&format!(" {}:0", row.len()));
        }
    }
    let label = if label > 0.0 { 1 } else { -1 };
    format!("train {label}{feats}")
}

/// Answer one coordinator-session line (trimmed, non-empty, not
/// `quit`). Same infallible contract as the node protocol.
fn coordinator_line(coord: &Mutex<ClusterCoordinator>, line: &str) -> String {
    let mut c = coord.lock().expect("coordinator lock poisoned");
    let verb = line.split_whitespace().next().unwrap_or("");
    match verb {
        "predict" => c.forward_predict(line),
        "train" => match c.deal_train_line(line) {
            Ok(reply) => {
                let _ = c.maybe_sync();
                reply
            }
            Err(e) => format!("err {e}"),
        },
        "flush" => match c.sync_models() {
            Ok(v) => format!("ok published v{v}"),
            Err(e) => format!("err {e}"),
        },
        "stats" => format!("ok {}", c.stats().to_json()),
        "health" => {
            let s = c.stats();
            format!("ok {} {}", s.merged_version, s.acked_rows)
        }
        _ => format!("err unknown verb '{verb}' in coordinator mode"),
    }
}

/// One client session against the coordinator: same line discipline as
/// the node server (bounded reads, `err` on malformed input, `quit` to
/// leave).
fn coordinator_session(
    coord: &Mutex<ClusterCoordinator>,
    stream: TcpStream,
    io_timeout: Option<Duration>,
) -> Result<()> {
    stream.set_read_timeout(io_timeout)?;
    stream.set_write_timeout(io_timeout)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    loop {
        let Some((bytes, truncated)) =
            protocol::read_bounded_line(&mut reader, MAX_LINE_BYTES)?
        else {
            return Ok(());
        };
        if truncated {
            writeln!(writer, "err line exceeds {MAX_LINE_BYTES} bytes")?;
            continue;
        }
        let Ok(text) = String::from_utf8(bytes) else {
            writeln!(writer, "err line is not valid UTF-8")?;
            continue;
        };
        let line = text.trim();
        if line.is_empty() {
            continue;
        }
        if line == "quit" {
            writeln!(writer, "ok bye")?;
            return Ok(());
        }
        writeln!(writer, "{}", coordinator_line(coord, line))?;
    }
}

/// Run the coordinator's TCP front: build one [`NodeLink`] per
/// `--nodes` entry, start the heartbeat thread, and serve client
/// sessions on loopback. `max_connections` bounds the accept loop for
/// harnesses (`None` = serve forever).
pub fn run_coordinator_tcp(scfg: &ServeConfig, max_connections: Option<usize>) -> Result<()> {
    scfg.validate()?;
    ensure!(scfg.coordinator, "run_coordinator_tcp needs coordinator mode");
    let io_timeout =
        (scfg.io_timeout_secs > 0).then(|| Duration::from_secs(scfg.io_timeout_secs));
    let registry = Arc::new(ModelRegistry::with_history(scfg.history));
    let links: Vec<NodeLink> = scfg
        .nodes
        .iter()
        .enumerate()
        .map(|(i, addr)| {
            let backoff = Backoff::new(
                Duration::from_millis(50),
                Duration::from_secs(2),
                4,
                scfg.seed ^ (i as u64).wrapping_mul(0x9E37_79B9),
            );
            NodeLink::new(i, addr.clone(), io_timeout, backoff)
        })
        .collect();
    let coord = Arc::new(Mutex::new(ClusterCoordinator::new(
        links,
        scfg.svm.clone(),
        registry,
        scfg.publish_every as u64,
    )));
    let listener = TcpListener::bind(("127.0.0.1", scfg.port))?;
    let local = listener.local_addr()?;
    eprintln!(
        "coordinator listening on {local} over {} node(s): {}",
        scfg.nodes.len(),
        scfg.nodes.join(", ")
    );

    let stop = Arc::new(AtomicBool::new(false));
    let heartbeat = {
        let coord = Arc::clone(&coord);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                coord.lock().expect("coordinator lock poisoned").heartbeat_tick();
                std::thread::sleep(HEARTBEAT_INTERVAL);
            }
        })
    };

    let mut served = 0usize;
    let mut handles = Vec::new();
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let coord = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let _ = coordinator_session(&coord, stream, io_timeout);
        }));
        handles.retain(|h| !h.is_finished());
        served += 1;
        if let Some(max) = max_connections {
            if served >= max {
                break;
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    stop.store(true, Ordering::SeqCst);
    heartbeat.join().ok();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::faults::NetFaultPlan;

    fn test_link(index: usize, addr: String, budget: u32) -> NodeLink {
        let backoff = Backoff::new(
            Duration::from_micros(200),
            Duration::from_millis(2),
            budget,
            17 + index as u64,
        );
        NodeLink::new(index, addr, Some(Duration::from_secs(2)), backoff)
    }

    fn test_coordinator(links: Vec<NodeLink>) -> ClusterCoordinator {
        ClusterCoordinator::new(
            links,
            SvmConfig::default(),
            Arc::new(ModelRegistry::new()),
            0, // no automatic sync in unit tests
        )
    }

    /// A node that acks `ok_lines` train lines on its first connection,
    /// then drops the connection *and* the listener (a dead node).
    fn spawn_dying_node(ok_lines: usize) -> (String, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let Ok((stream, _)) = listener.accept() else { return };
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut stream = stream;
            for _ in 0..ok_lines {
                match protocol::read_bounded_line(&mut reader, MAX_LINE_BYTES) {
                    Ok(Some(_)) => {
                        if writeln!(stream, "ok queued 1").is_err() {
                            return;
                        }
                    }
                    _ => return,
                }
            }
        });
        (addr, handle)
    }

    /// A node that answers every line on every connection with `reply`
    /// until `conns` connections have come and gone.
    fn spawn_steady_node(
        reply: &'static str,
        conns: usize,
    ) -> (String, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            for _ in 0..conns {
                let Ok((stream, _)) = listener.accept() else { return };
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut stream = stream;
                while let Ok(Some((_line, _))) =
                    protocol::read_bounded_line(&mut reader, MAX_LINE_BYTES)
                {
                    if writeln!(stream, "{reply}").is_err() {
                        break;
                    }
                }
            }
        });
        (addr, handle)
    }

    #[test]
    fn rows_orphaned_by_a_dead_node_are_redealt_to_survivors() {
        // Node 0 acks one row then dies; node 1 survives. With a
        // down-threshold of 3 the deal loop keeps probing node 0 until
        // its health crosses into Down, re-dealing each orphaned row.
        let (addr0, h0) = spawn_dying_node(1);
        let (addr1, h1) = spawn_steady_node("ok queued 1", 1);
        let links = vec![test_link(0, addr0, 1), test_link(1, addr1, 1)];
        let mut coord = test_coordinator(links);
        coord.record_acked_lines();
        for i in 0..6 {
            let label = if i % 2 == 0 { 1.0 } else { -1.0 };
            let reply = coord.deal_train(label, &[0.5, i as f32]).unwrap();
            assert!(reply.starts_with("ok"), "row {i}: {reply}");
        }
        let stats = coord.stats();
        assert_eq!(stats.acked_rows, 6, "every row must end up acked somewhere");
        assert_eq!(stats.rows_dealt, 6);
        assert!(stats.rows_redealt >= 1, "the orphaned row must be re-dealt");
        assert_eq!(coord.acked_lines().len(), 6);
        assert_eq!(stats.nodes_up, 1);
        assert_eq!(coord.node_states()[0], NodeState::Down);
        drop(coord);
        h0.join().unwrap();
        h1.join().unwrap();
    }

    #[test]
    fn dealing_fails_typed_when_every_node_is_down() {
        // Nothing listens on either address.
        let dead = || {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let links = vec![test_link(0, dead(), 1), test_link(1, dead(), 1)];
        let mut coord = test_coordinator(links);
        let err = coord.deal_train(1.0, &[1.0]).unwrap_err().to_string();
        assert!(err.contains("cluster fully degraded"), "got: {err}");
        assert_eq!(coord.stats().nodes_up, 0);
    }

    #[test]
    fn predict_fails_over_to_the_next_replica_and_counts_it() {
        let dead_addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let (addr1, h1) = spawn_steady_node("ok +1 v3", 1);
        let links = vec![test_link(0, dead_addr, 1), test_link(1, addr1, 1)];
        let mut coord = test_coordinator(links);
        let reply = coord.forward_predict("predict 1:0.5");
        assert_eq!(reply, "ok +1 v3");
        let stats = coord.stats();
        assert!(stats.failovers >= 1);
        // With every replica gone and nothing merged, predict answers a
        // typed err rather than hanging.
        let links = vec![test_link(0, {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        }, 1)];
        let mut lone = test_coordinator(links);
        lone.node_failure(0);
        lone.node_failure(0);
        lone.node_failure(0);
        let reply = lone.forward_predict("predict 1:0.5");
        assert!(reply.starts_with("err "), "got: {reply}");
        drop(coord);
        h1.join().unwrap();
    }

    #[test]
    fn a_partitioned_node_goes_down_then_rejoins_through_the_heartbeat() {
        // The node's server is healthy the whole time; the *link* is
        // partitioned by the fault schedule until the dealt-row clock
        // passes 50.
        let (addr, handle) = spawn_steady_node("ok 0 0", 1);
        let dealt = Arc::new(AtomicU64::new(0));
        let plan = NetFaultPlan::none().with_partition(0, 0, 50);
        let link =
            test_link(0, addr, 1).with_faults(plan, Arc::clone(&dealt));
        let mut coord =
            test_coordinator(vec![link]).with_deal_clock(Arc::clone(&dealt));
        for _ in 0..DOWN_THRESHOLD {
            coord.heartbeat_tick();
        }
        assert_eq!(coord.node_states()[0], NodeState::Down);
        assert_eq!(coord.stats().nodes_up, 0);
        // The partition heals once the clock passes the window. Nothing
        // is merged yet, so the re-sync is a no-op and one tick brings
        // the node all the way back.
        dealt.store(100, Ordering::SeqCst);
        coord.heartbeat_tick();
        assert_eq!(coord.node_states()[0], NodeState::Up);
        assert_eq!(coord.stats().nodes_up, 1);
        drop(coord);
        handle.join().unwrap();
    }

    #[test]
    fn coordinator_sessions_speak_the_protocol_surface() {
        let (addr, handle) = spawn_steady_node("ok queued 1", 1);
        let coord = Mutex::new(test_coordinator(vec![test_link(0, addr, 2)]));
        for (line, want_prefix) in [
            ("stats", "ok {"),
            ("health", "ok 0 0"),
            ("train 1 1:0.5", "ok queued"),
            ("train", "err "),
            ("train x 1:0.5", "err "),
            ("flush", "err "), // steady node's "ok queued 1" is not a snapshot
            ("bogus", "err unknown verb"),
        ] {
            let reply = coordinator_line(&coord, line);
            assert!(reply.starts_with(want_prefix), "{line} -> {reply}");
        }
        let stats = coord.lock().unwrap().stats();
        assert_eq!(stats.acked_rows, 1);
        drop(coord);
        handle.join().unwrap();
    }
}

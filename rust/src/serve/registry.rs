//! Hot-swappable model registry: the single point of truth the prediction
//! front end reads and the ingest pipeline publishes into.
//!
//! Concurrency discipline:
//!
//! * A snapshot is an **immutable** `(version, model)` pair in one `Arc`
//!   allocation, so the stamp can never disagree with the contents a
//!   reader observes.
//! * Readers take a read lock only long enough to clone the `Arc`
//!   (no allocation, no model work under the lock), then evaluate against
//!   their private snapshot for as long as they like — a concurrent
//!   publish never blocks or invalidates them.
//! * Publishers build the new model entirely outside the lock; the write
//!   lock covers one version stamp + one pointer swap. Stamping under the
//!   lock makes versions strictly monotonic in publish order even with
//!   racing publishers.
//! * Published models have their lazy scale folded, which (together with
//!   the effective-coefficient `BSVMMDL2` encoding) makes
//!   [`ModelRegistry::dump`] → [`ModelRegistry::publish_from_file`]
//!   bit-identical to the in-memory snapshot.
//!
//! Lifecycle (this file is the registry half of the serve tier's failure
//! domain — see `serve/mod.rs` for the full state machine):
//!
//! * The registry keeps a **bounded version history** (newest at the
//!   back). [`ModelRegistry::rollback`] reinstates the model from `n`
//!   publishes ago **under a fresh version stamp** — version numbers are
//!   strictly monotonic even across rollbacks, so concurrent readers
//!   never observe time moving backwards.
//! * [`ModelRegistry::publish_shadowed`] gates a candidate through
//!   **shadow evaluation**: the candidate re-scores a sliding window of
//!   recent live prediction rows (fed by the serving path via
//!   [`ModelRegistry::record_live_rows`]) and is compared against the
//!   incumbent's decisions on the same rows. If the candidate flips more
//!   than [`ShadowPolicy::max_disagreement`] of the window, it is
//!   auto-rejected and the incumbent keeps serving; the decision is
//!   recorded in [`LifecycleStats`] and surfaced over the protocol's
//!   `stats` verb.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{bail, Context, Result};

use crate::model::{io, AnyModel};
use crate::telemetry::{self, Counter, Gauge, Stage};
use crate::util::json::Json;

/// Default number of retained versions (incumbent included).
pub const DEFAULT_HISTORY: usize = 8;

/// Default sliding-window capacity for shadow evaluation, in rows.
pub const DEFAULT_SHADOW_WINDOW: usize = 256;

/// One immutable published model with its monotonic version stamp.
#[derive(Debug)]
pub struct ModelSnapshot {
    version: u64,
    model: AnyModel,
}

impl ModelSnapshot {
    /// Monotonic publish stamp (1 = first publish).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// The published model (scale folded).
    pub fn model(&self) -> &AnyModel {
        &self.model
    }
}

/// Shadow-evaluation gate for [`ModelRegistry::publish_shadowed`].
#[derive(Debug, Clone, Copy)]
pub struct ShadowPolicy {
    /// Minimum live rows in the window before the gate can judge; below
    /// this the candidate publishes unconditionally (cold start).
    pub min_rows: usize,
    /// Maximum tolerated fraction of window rows whose predicted label
    /// flips relative to the incumbent before the candidate is rejected.
    pub max_disagreement: f64,
}

impl Default for ShadowPolicy {
    fn default() -> Self {
        ShadowPolicy { min_rows: 32, max_disagreement: 0.25 }
    }
}

/// Outcome of one shadowed publish attempt.
#[derive(Debug, Clone, Copy)]
pub struct ShadowOutcome {
    /// Whether the candidate was installed.
    pub accepted: bool,
    /// The serving version after the decision (new stamp if accepted,
    /// incumbent stamp if rejected).
    pub version: u64,
    /// Fraction of evaluated rows whose label agreed with the incumbent
    /// (`None` when the gate could not judge — empty window or no
    /// incumbent — and the candidate published unconditionally).
    pub agreement: Option<f64>,
    /// Live rows the gate scored.
    pub evaluated_rows: usize,
}

/// Aggregate lifecycle counters (monotonic over the registry's lifetime).
#[derive(Debug, Clone, Copy, Default)]
pub struct LifecycleStats {
    /// Successful publishes (including rollback re-publishes).
    pub published: u64,
    /// Candidates rejected by the shadow gate.
    pub rejected: u64,
    /// Rollback re-publishes.
    pub rollbacks: u64,
    /// Agreement of the most recent shadow evaluation, if any ran.
    pub last_agreement: Option<f64>,
    /// Whether the most recent shadowed candidate was accepted.
    pub last_accepted: Option<bool>,
}

#[derive(Debug)]
struct Inner {
    /// Retained versions, oldest at the front, incumbent at the back.
    history: VecDeque<Arc<ModelSnapshot>>,
    /// Next stamp to hand out; never reused, even across rollback.
    next_version: u64,
    capacity: usize,
    stats: LifecycleStats,
}

#[derive(Debug, Default)]
struct ShadowWindow {
    rows: VecDeque<f32>,
    dim: usize,
    capacity_rows: usize,
}

/// Atomic hot-swap registry of [`ModelSnapshot`]s with bounded history,
/// rollback and shadow evaluation.
#[derive(Debug)]
pub struct ModelRegistry {
    inner: RwLock<Inner>,
    window: Mutex<ShadowWindow>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::with_history(DEFAULT_HISTORY)
    }
}

impl ModelRegistry {
    /// Empty registry (no model until the first [`ModelRegistry::publish`])
    /// retaining [`DEFAULT_HISTORY`] versions.
    pub fn new() -> Self {
        Self::default()
    }

    /// Empty registry retaining up to `capacity` versions (min 1).
    pub fn with_history(capacity: usize) -> Self {
        ModelRegistry {
            inner: RwLock::new(Inner {
                history: VecDeque::new(),
                next_version: 1,
                capacity: capacity.max(1),
                stats: LifecycleStats::default(),
            }),
            window: Mutex::new(ShadowWindow {
                rows: VecDeque::new(),
                dim: 0,
                capacity_rows: DEFAULT_SHADOW_WINDOW,
            }),
        }
    }

    /// Publish a model as the next version and return its stamp. The
    /// model's lazy scale is folded first (see the module docs); the swap
    /// itself is a single push under the write lock.
    pub fn publish(&self, mut model: AnyModel) -> u64 {
        model.fold_scale();
        let mut inner = self.inner.write().expect("registry lock poisoned");
        Self::install(&mut inner, model)
    }

    /// Install `model` (scale already folded) as the next version.
    fn install(inner: &mut Inner, model: AnyModel) -> u64 {
        let version = inner.next_version;
        let num_sv = model.num_sv();
        inner.next_version += 1;
        inner.history.push_back(Arc::new(ModelSnapshot { version, model }));
        while inner.history.len() > inner.capacity {
            inner.history.pop_front();
        }
        inner.stats.published += 1;
        telemetry::registry::count(Counter::Publishes);
        telemetry::registry::gauge_set(Gauge::ModelVersion, version);
        telemetry::registry::gauge_set(Gauge::ModelNumSv, num_sv as u64);
        telemetry::emit("publish", || {
            vec![
                ("version", Json::num(version as f64)),
                ("num_sv", Json::num(num_sv as f64)),
            ]
        });
        version
    }

    /// The current snapshot (`None` before the first publish). O(1): one
    /// read-lock acquisition and one `Arc` clone.
    pub fn current(&self) -> Option<Arc<ModelSnapshot>> {
        self.inner.read().expect("registry lock poisoned").history.back().cloned()
    }

    /// Version of the current snapshot (0 before the first publish).
    pub fn version(&self) -> u64 {
        self.current().map(|s| s.version).unwrap_or(0)
    }

    /// Number of retained versions (incumbent included).
    pub fn history_len(&self) -> usize {
        self.inner.read().expect("registry lock poisoned").history.len()
    }

    /// Lifecycle counters (publishes, shadow rejections, rollbacks).
    pub fn lifecycle_stats(&self) -> LifecycleStats {
        self.inner.read().expect("registry lock poisoned").stats
    }

    /// Reinstate the model from `n` publishes before the incumbent
    /// (`rollback(1)` = previous version) **under a fresh version stamp**,
    /// so reader-observed versions stay monotonic. Returns the new stamp.
    /// Errors when the history does not reach back that far.
    pub fn rollback(&self, n: usize) -> Result<u64> {
        if n == 0 {
            bail!("rollback(0) is a no-op: the incumbent is already serving");
        }
        let mut inner = self.inner.write().expect("registry lock poisoned");
        let len = inner.history.len();
        if n >= len {
            bail!(
                "rollback depth {n} exceeds retained history ({len} version{} held)",
                if len == 1 { "" } else { "s" }
            );
        }
        let model = inner.history[len - 1 - n].model.clone();
        let version = Self::install(&mut inner, model);
        inner.stats.rollbacks += 1;
        telemetry::registry::count(Counter::Rollbacks);
        telemetry::emit("rollback", || {
            vec![("depth", Json::num(n as f64)), ("version", Json::num(version as f64))]
        });
        Ok(version)
    }

    /// Record live prediction rows into the shadow sliding window.
    /// `rows.len()` must be a multiple of `dim`; rows with a different
    /// dimension than the window's current one reset the window (the
    /// serving dimension changed, so older probes are meaningless).
    pub fn record_live_rows(&self, rows: &[f32], dim: usize) {
        if dim == 0 || rows.is_empty() || rows.len() % dim != 0 {
            return;
        }
        let mut w = self.window.lock().expect("shadow window lock poisoned");
        if w.dim != dim {
            w.rows.clear();
            w.dim = dim;
        }
        for &v in rows {
            w.rows.push_back(v);
        }
        let cap = w.capacity_rows * dim;
        while w.rows.len() > cap {
            w.rows.pop_front();
        }
    }

    /// Rows currently held in the shadow window.
    pub fn shadow_window_rows(&self) -> usize {
        let w = self.window.lock().expect("shadow window lock poisoned");
        if w.dim == 0 {
            0
        } else {
            w.rows.len() / w.dim
        }
    }

    /// Gate `candidate` through shadow evaluation against the incumbent
    /// over the live-row window. On acceptance the candidate becomes the
    /// next version; on rejection the incumbent keeps serving and the
    /// rejection is counted. Publishes unconditionally when the gate
    /// cannot judge (no incumbent, dimension change, or fewer than
    /// [`ShadowPolicy::min_rows`] window rows).
    pub fn publish_shadowed(
        &self,
        mut candidate: AnyModel,
        policy: &ShadowPolicy,
    ) -> ShadowOutcome {
        candidate.fold_scale();
        // Copy the window out so scoring runs without holding any lock.
        let (probe, dim) = {
            let w = self.window.lock().expect("shadow window lock poisoned");
            (w.rows.iter().copied().collect::<Vec<f32>>(), w.dim)
        };
        let incumbent = self.current();
        let verdict = match &incumbent {
            Some(inc)
                if dim == candidate.dim()
                    && inc.model.dim() == dim
                    && probe.len() / dim.max(1) >= policy.min_rows.max(1) =>
            {
                // The shadow-eval window: both models re-score the probe
                // rows — the latency cost of gating one publish.
                let _eval = telemetry::stage_span(Stage::ShadowEval);
                let n = probe.len() / dim;
                let old = inc.model.decision_rows(&probe, 1);
                let new = candidate.decision_rows(&probe, 1);
                let agree = old
                    .iter()
                    .zip(new.iter())
                    .filter(|(a, b)| (**a >= 0.0) == (**b >= 0.0))
                    .count();
                Some((agree as f64 / n as f64, n))
            }
            _ => None,
        };
        let mut inner = self.inner.write().expect("registry lock poisoned");
        match verdict {
            Some((agreement, n)) if 1.0 - agreement > policy.max_disagreement => {
                inner.stats.rejected += 1;
                inner.stats.last_agreement = Some(agreement);
                inner.stats.last_accepted = Some(false);
                telemetry::registry::count(Counter::ShadowRejected);
                telemetry::emit("shadow_reject", || {
                    vec![
                        ("agreement", Json::num(agreement)),
                        ("evaluated_rows", Json::num(n as f64)),
                    ]
                });
                let version = inner.history.back().map(|s| s.version).unwrap_or(0);
                ShadowOutcome { accepted: false, version, agreement: Some(agreement), evaluated_rows: n }
            }
            Some((agreement, n)) => {
                let version = Self::install(&mut inner, candidate);
                inner.stats.last_agreement = Some(agreement);
                inner.stats.last_accepted = Some(true);
                ShadowOutcome { accepted: true, version, agreement: Some(agreement), evaluated_rows: n }
            }
            None => {
                let version = Self::install(&mut inner, candidate);
                inner.stats.last_accepted = Some(true);
                ShadowOutcome { accepted: true, version, agreement: None, evaluated_rows: 0 }
            }
        }
    }

    /// Dump the current snapshot in the `BSVMMDL2` format; returns the
    /// dumped version. Errors if nothing has been published.
    pub fn dump(&self, path: impl AsRef<std::path::Path>) -> Result<u64> {
        let snap = self.current().context("registry is empty: nothing published yet")?;
        io::save_any(&snap.model, path)?;
        Ok(snap.version)
    }

    /// Load a `BSVMMDL1/2` file and publish it as the next version.
    /// `fast_exp` selects the exponential tier of the published model
    /// (an execution choice the model format deliberately does not carry;
    /// pass `false` for libm semantics — the serving entry points thread
    /// their `SvmConfig::fast_exp` through here).
    pub fn publish_from_file(
        &self,
        path: impl AsRef<std::path::Path>,
        fast_exp: bool,
    ) -> Result<u64> {
        let mut model = io::load_any(path)?;
        model.set_fast_exp(fast_exp);
        Ok(self.publish(model))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelSpec;

    /// A tiny model whose observable fields encode `tag`, so readers can
    /// check stamp/contents consistency: bias == tag and num_sv == 1.
    fn tagged_model(tag: u64) -> AnyModel {
        let mut m = AnyModel::new(2, KernelSpec::gaussian(1.0), 1).unwrap();
        m.push(&[tag as f32, -(tag as f32)], 1.0);
        m.set_bias(tag as f64);
        m
    }

    /// A constant-sign model: decision(x) == bias for the zero SV.
    fn constant_model(bias: f64) -> AnyModel {
        let mut m = AnyModel::new(2, KernelSpec::gaussian(1.0), 1).unwrap();
        m.push(&[0.0, 0.0], 0.0);
        m.set_bias(bias);
        m
    }

    #[test]
    fn empty_registry_reports_no_model() {
        let reg = ModelRegistry::new();
        assert!(reg.current().is_none());
        assert_eq!(reg.version(), 0);
        assert_eq!(reg.history_len(), 0);
        assert!(reg.dump(std::env::temp_dir().join("never.bsvm")).is_err());
    }

    #[test]
    fn publish_stamps_monotonic_versions() {
        let reg = ModelRegistry::new();
        assert_eq!(reg.publish(tagged_model(1)), 1);
        assert_eq!(reg.publish(tagged_model(2)), 2);
        let snap = reg.current().unwrap();
        assert_eq!(snap.version(), 2);
        assert_eq!(snap.model().bias(), 2.0);
        assert_eq!(reg.version(), 2);
        assert_eq!(reg.lifecycle_stats().published, 2);
    }

    #[test]
    fn history_is_bounded_and_rollback_reinstates_under_new_stamp() {
        let reg = ModelRegistry::with_history(3);
        for tag in 1..=5u64 {
            reg.publish(tagged_model(tag));
        }
        // Capacity 3: versions 3, 4, 5 retained.
        assert_eq!(reg.history_len(), 3);
        // Rolling back past the retained window errors.
        assert!(reg.rollback(3).is_err());
        assert!(reg.rollback(0).is_err());
        // rollback(2) reinstates version 3's contents under stamp 6.
        let v = reg.rollback(2).unwrap();
        assert_eq!(v, 6);
        let snap = reg.current().unwrap();
        assert_eq!(snap.version(), 6);
        assert_eq!(snap.model().bias(), 3.0);
        let stats = reg.lifecycle_stats();
        assert_eq!(stats.rollbacks, 1);
        assert_eq!(stats.published, 6);
    }

    #[test]
    fn shadow_gate_rejects_degraded_candidate_and_keeps_incumbent() {
        let reg = ModelRegistry::new();
        reg.publish(constant_model(5.0)); // incumbent: always +1
        // Live traffic: 64 probes (contents are irrelevant for a
        // constant-sign model).
        let rows: Vec<f32> = (0..128).map(|i| i as f32 * 0.01).collect();
        reg.record_live_rows(&rows, 2);
        assert_eq!(reg.shadow_window_rows(), 64);
        let policy = ShadowPolicy { min_rows: 32, max_disagreement: 0.25 };
        // A sign-flipped candidate disagrees on every window row.
        let out = reg.publish_shadowed(constant_model(-5.0), &policy);
        assert!(!out.accepted);
        assert_eq!(out.version, 1, "incumbent must keep serving");
        assert_eq!(out.agreement, Some(0.0));
        assert_eq!(out.evaluated_rows, 64);
        assert_eq!(reg.version(), 1);
        let stats = reg.lifecycle_stats();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.last_accepted, Some(false));
        // An agreeing candidate sails through as version 2.
        let out = reg.publish_shadowed(constant_model(4.0), &policy);
        assert!(out.accepted);
        assert_eq!(out.version, 2);
        assert_eq!(out.agreement, Some(1.0));
        assert_eq!(reg.version(), 2);
    }

    #[test]
    fn shadow_gate_publishes_unconditionally_below_min_rows() {
        let reg = ModelRegistry::new();
        reg.publish(constant_model(1.0));
        reg.record_live_rows(&[0.1, 0.2], 2); // one row < min_rows
        let out = reg.publish_shadowed(constant_model(-1.0), &ShadowPolicy::default());
        assert!(out.accepted);
        assert_eq!(out.agreement, None);
        assert_eq!(reg.version(), 2);
    }

    #[test]
    fn shadow_window_is_bounded_and_resets_on_dimension_change() {
        let reg = ModelRegistry::new();
        let many: Vec<f32> = vec![0.5; 2 * (DEFAULT_SHADOW_WINDOW + 50)];
        reg.record_live_rows(&many, 2);
        assert_eq!(reg.shadow_window_rows(), DEFAULT_SHADOW_WINDOW);
        reg.record_live_rows(&[0.1, 0.2, 0.3], 3);
        assert_eq!(reg.shadow_window_rows(), 1);
    }

    #[test]
    fn concurrent_readers_never_observe_a_torn_snapshot() {
        // Publisher walks versions 1..=N where the model's bias encodes
        // the version; readers assert stamp == contents on every sample
        // and that their observed versions never go backwards.
        const N: u64 = 300;
        let reg = Arc::new(ModelRegistry::new());
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for _ in 0..4 {
                let reg = Arc::clone(&reg);
                handles.push(scope.spawn(move || {
                    let mut last = 0u64;
                    loop {
                        if let Some(snap) = reg.current() {
                            let v = snap.version();
                            assert_eq!(
                                snap.model().bias(),
                                v as f64,
                                "torn snapshot: stamp {v} but contents {}",
                                snap.model().bias()
                            );
                            assert!(v >= last, "version went backwards: {last} -> {v}");
                            last = v;
                            if v == N {
                                break;
                            }
                        }
                        std::hint::spin_loop();
                    }
                }));
            }
            for tag in 1..=N {
                let v = reg.publish(tagged_model(tag));
                assert_eq!(v, tag);
            }
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    #[test]
    fn dump_then_reload_predicts_bit_identically() {
        // A mid-stream snapshot (scale folded on publish) must survive the
        // BSVMMDL2 round trip with bit-identical decision values.
        let mut m = AnyModel::new(3, KernelSpec::gaussian(0.7), 4).unwrap();
        m.push(&[1.0, 0.5, -0.25], 0.8);
        m.push(&[-0.5, 2.0, 0.125], -1.5);
        m.push(&[0.0, -1.0, 1.0], 0.3);
        m.set_bias(0.0625);
        let reg = ModelRegistry::new();
        reg.publish(m);
        let dir = std::env::temp_dir().join("budgetsvm-registry");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.bsvm");
        let v = reg.dump(&path).unwrap();
        assert_eq!(v, 1);
        let snap = reg.current().unwrap();
        let back = crate::model::io::load_any(&path).unwrap();
        for probe in [[0.0f32, 0.0, 0.0], [0.3, -0.7, 1.1], [2.0, 0.5, -0.5]] {
            assert_eq!(
                snap.model().decision(&probe).to_bits(),
                back.decision(&probe).to_bits()
            );
        }
        // And publishing the file bumps the version.
        let v2 = reg.publish_from_file(&path, false).unwrap();
        assert_eq!(v2, 2);
        std::fs::remove_file(&path).ok();
    }
}

//! Hot-swappable model registry: the single point of truth the prediction
//! front end reads and the ingest pipeline publishes into.
//!
//! Concurrency discipline:
//!
//! * A snapshot is an **immutable** `(version, model)` pair in one `Arc`
//!   allocation, so the stamp can never disagree with the contents a
//!   reader observes.
//! * Readers take a read lock only long enough to clone the `Arc`
//!   (no allocation, no model work under the lock), then evaluate against
//!   their private snapshot for as long as they like — a concurrent
//!   publish never blocks or invalidates them.
//! * Publishers build the new model entirely outside the lock; the write
//!   lock covers one version stamp + one pointer swap. Stamping under the
//!   lock makes versions strictly monotonic in publish order even with
//!   racing publishers.
//! * Published models have their lazy scale folded, which (together with
//!   the effective-coefficient `BSVMMDL2` encoding) makes
//!   [`ModelRegistry::dump`] → [`ModelRegistry::publish_from_file`]
//!   bit-identical to the in-memory snapshot.

use std::sync::{Arc, RwLock};

use anyhow::{Context, Result};

use crate::model::{io, AnyModel};

/// One immutable published model with its monotonic version stamp.
#[derive(Debug)]
pub struct ModelSnapshot {
    version: u64,
    model: AnyModel,
}

impl ModelSnapshot {
    /// Monotonic publish stamp (1 = first publish).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// The published model (scale folded).
    pub fn model(&self) -> &AnyModel {
        &self.model
    }
}

/// Atomic hot-swap registry of [`ModelSnapshot`]s.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    slot: RwLock<Option<Arc<ModelSnapshot>>>,
}

impl ModelRegistry {
    /// Empty registry (no model until the first [`ModelRegistry::publish`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish a model as the next version and return its stamp. The
    /// model's lazy scale is folded first (see the module docs); the swap
    /// itself is a single pointer store under the write lock.
    pub fn publish(&self, mut model: AnyModel) -> u64 {
        model.fold_scale();
        let mut slot = self.slot.write().expect("registry lock poisoned");
        // The next version is derived from the slot itself, under the same
        // write lock that installs it — one source of truth, strictly
        // monotonic even with racing publishers.
        let version = slot.as_ref().map(|s| s.version).unwrap_or(0) + 1;
        *slot = Some(Arc::new(ModelSnapshot { version, model }));
        version
    }

    /// The current snapshot (`None` before the first publish). O(1): one
    /// read-lock acquisition and one `Arc` clone.
    pub fn current(&self) -> Option<Arc<ModelSnapshot>> {
        self.slot.read().expect("registry lock poisoned").clone()
    }

    /// Version of the current snapshot (0 before the first publish).
    pub fn version(&self) -> u64 {
        self.current().map(|s| s.version).unwrap_or(0)
    }

    /// Dump the current snapshot in the `BSVMMDL2` format; returns the
    /// dumped version. Errors if nothing has been published.
    pub fn dump(&self, path: impl AsRef<std::path::Path>) -> Result<u64> {
        let snap = self.current().context("registry is empty: nothing published yet")?;
        io::save_any(&snap.model, path)?;
        Ok(snap.version)
    }

    /// Load a `BSVMMDL1/2` file and publish it as the next version.
    /// `fast_exp` selects the exponential tier of the published model
    /// (an execution choice the model format deliberately does not carry;
    /// pass `false` for libm semantics — the serving entry points thread
    /// their `SvmConfig::fast_exp` through here).
    pub fn publish_from_file(
        &self,
        path: impl AsRef<std::path::Path>,
        fast_exp: bool,
    ) -> Result<u64> {
        let mut model = io::load_any(path)?;
        model.set_fast_exp(fast_exp);
        Ok(self.publish(model))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelSpec;

    /// A tiny model whose observable fields encode `tag`, so readers can
    /// check stamp/contents consistency: bias == tag and num_sv == 1.
    fn tagged_model(tag: u64) -> AnyModel {
        let mut m = AnyModel::new(2, KernelSpec::gaussian(1.0), 1).unwrap();
        m.push(&[tag as f32, -(tag as f32)], 1.0);
        m.set_bias(tag as f64);
        m
    }

    #[test]
    fn empty_registry_reports_no_model() {
        let reg = ModelRegistry::new();
        assert!(reg.current().is_none());
        assert_eq!(reg.version(), 0);
        assert!(reg.dump(std::env::temp_dir().join("never.bsvm")).is_err());
    }

    #[test]
    fn publish_stamps_monotonic_versions() {
        let reg = ModelRegistry::new();
        assert_eq!(reg.publish(tagged_model(1)), 1);
        assert_eq!(reg.publish(tagged_model(2)), 2);
        let snap = reg.current().unwrap();
        assert_eq!(snap.version(), 2);
        assert_eq!(snap.model().bias(), 2.0);
        assert_eq!(reg.version(), 2);
    }

    #[test]
    fn concurrent_readers_never_observe_a_torn_snapshot() {
        // Publisher walks versions 1..=N where the model's bias encodes
        // the version; readers assert stamp == contents on every sample
        // and that their observed versions never go backwards.
        const N: u64 = 300;
        let reg = Arc::new(ModelRegistry::new());
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for _ in 0..4 {
                let reg = Arc::clone(&reg);
                handles.push(scope.spawn(move || {
                    let mut last = 0u64;
                    loop {
                        if let Some(snap) = reg.current() {
                            let v = snap.version();
                            assert_eq!(
                                snap.model().bias(),
                                v as f64,
                                "torn snapshot: stamp {v} but contents {}",
                                snap.model().bias()
                            );
                            assert!(v >= last, "version went backwards: {last} -> {v}");
                            last = v;
                            if v == N {
                                break;
                            }
                        }
                        std::hint::spin_loop();
                    }
                }));
            }
            for tag in 1..=N {
                let v = reg.publish(tagged_model(tag));
                assert_eq!(v, tag);
            }
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    #[test]
    fn dump_then_reload_predicts_bit_identically() {
        // A mid-stream snapshot (scale folded on publish) must survive the
        // BSVMMDL2 round trip with bit-identical decision values.
        let mut m = AnyModel::new(3, KernelSpec::gaussian(0.7), 4).unwrap();
        m.push(&[1.0, 0.5, -0.25], 0.8);
        m.push(&[-0.5, 2.0, 0.125], -1.5);
        m.push(&[0.0, -1.0, 1.0], 0.3);
        m.set_bias(0.0625);
        let reg = ModelRegistry::new();
        reg.publish(m);
        let dir = std::env::temp_dir().join("budgetsvm-registry");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.bsvm");
        let v = reg.dump(&path).unwrap();
        assert_eq!(v, 1);
        let snap = reg.current().unwrap();
        let back = crate::model::io::load_any(&path).unwrap();
        for probe in [[0.0f32, 0.0, 0.0], [0.3, -0.7, 1.1], [2.0, 0.5, -0.5]] {
            assert_eq!(
                snap.model().decision(&probe).to_bits(),
                back.decision(&probe).to_bits()
            );
        }
        // And publishing the file bumps the version.
        let v2 = reg.publish_from_file(&path, false).unwrap();
        assert_eq!(v2, 2);
        std::fs::remove_file(&path).ok();
    }
}

//! Line protocol + session loop of `repro serve` (see the [`super`]
//! module docs for the full wire grammar and reply vocabulary).
//!
//! The session loop is generic over `BufRead`/`Write`, so the same code
//! path answers a TCP connection, an in-memory replay (the offline
//! `--replay` benchmark and the tests), or any future transport. One
//! [`ServeState`] is shared by every session: the registry and the
//! batcher client are lock-free/short-lock concurrent, while the ingest
//! front (row buffer + shard pipeline) sits behind one mutex — training
//! rows are cheap to buffer and the pipeline itself fans out to shard
//! workers immediately.
//!
//! Robustness: request lines are read through a **bounded** buffer
//! ([`MAX_LINE_BYTES`]) so an attacker cannot balloon memory with one
//! endless line (the oversized line is consumed and answered `err …`,
//! the session survives); non-UTF-8 bytes answer `err …` per line
//! instead of killing the session; socket read/write timeouts (set by
//! [`serve_connections`] from the state's io-timeout) turn a stalled or
//! dead client into a bounded `err session idle timeout` + disconnect,
//! never a pinned thread.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::data::Dataset;
use crate::telemetry::registry as metrics_registry;
use crate::telemetry::{Counter, Gauge, Stage};
use crate::util::json::Json;

use super::batcher::{BatcherClient, PredictError};
use super::ingest::{Admission, ShardedIngest};
use super::registry::ModelRegistry;

/// Hard cap on one request line (bytes, newline excluded). Longer lines
/// are consumed (the session stays line-synchronized) but answered with
/// a typed error.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// Buffering ingest front: accumulates `train` rows and hands them to the
/// shard pipeline in `chunk`-row batches (plus on every explicit flush).
struct IngestFront {
    pipeline: Option<ShardedIngest>,
    buf_x: Vec<f32>,
    buf_y: Vec<f32>,
    /// Serving dimension; 0 until pinned by the initial model or the
    /// first `train` line.
    dim: usize,
    chunk: usize,
}

impl IngestFront {
    fn buffered_rows(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.buf_x.len() / self.dim
        }
    }

    fn drain_to_pipeline(&mut self) -> Result<(), String> {
        if self.buf_y.is_empty() {
            return Ok(());
        }
        let pipeline = self.pipeline.as_mut().ok_or("ingest is disabled on this server")?;
        let batch = Dataset::new(
            "wire",
            std::mem::take(&mut self.buf_x),
            std::mem::take(&mut self.buf_y),
            self.dim,
        );
        match pipeline.ingest(&batch) {
            Ok(()) => Ok(()),
            Err(e) => {
                // Rows were acknowledged with `ok queued`; on a pipeline
                // failure keep them buffered for the next drain attempt
                // (at-least-once — never silently dropped) rather than
                // losing them with the taken buffers.
                self.buf_x.extend_from_slice(batch.features());
                self.buf_y.extend_from_slice(batch.labels());
                Err(e.to_string())
            }
        }
    }
}

/// Shared state of one serving process.
pub struct ServeState {
    registry: Arc<ModelRegistry>,
    client: BatcherClient,
    ingest: Mutex<IngestFront>,
    /// Lock-free mirror of the serving dimension (0 until pinned), so the
    /// predict path never touches the ingest mutex — a publish stall on
    /// the ingest side must not delay readers.
    dim: AtomicUsize,
    /// Per-request predict deadline (`None` = wait however long).
    predict_deadline: Option<Duration>,
    /// Socket read/write timeout applied by [`serve_connections`].
    io_timeout: Option<Duration>,
}

impl ServeState {
    /// Assemble the serving state. `pipeline` is `None` for predict-only
    /// servers (replay benchmarking of a frozen model); `chunk` is the
    /// ingest-front buffer size in rows.
    pub fn new(
        registry: Arc<ModelRegistry>,
        client: BatcherClient,
        pipeline: Option<ShardedIngest>,
        chunk: usize,
    ) -> Self {
        let dim = registry.current().map(|s| s.model().dim()).unwrap_or(0);
        ServeState {
            registry,
            client,
            ingest: Mutex::new(IngestFront {
                pipeline,
                buf_x: Vec::new(),
                buf_y: Vec::new(),
                dim,
                chunk: chunk.max(1),
            }),
            dim: AtomicUsize::new(dim),
            predict_deadline: None,
            io_timeout: None,
        }
    }

    /// Expire queued predict requests after `deadline` with a typed
    /// `overloaded` reply (`None` = no deadline).
    pub fn with_predict_deadline(mut self, deadline: Option<Duration>) -> Self {
        self.predict_deadline = deadline;
        self
    }

    /// Disconnect sessions whose socket stalls for `timeout`
    /// (`None` = no socket timeouts).
    pub fn with_io_timeout(mut self, timeout: Option<Duration>) -> Self {
        self.io_timeout = timeout;
        self
    }

    /// The configured socket timeout (applied per accepted connection).
    pub fn io_timeout(&self) -> Option<Duration> {
        self.io_timeout
    }

    /// The serving dimension (0 until pinned). Lock-free; falls back to
    /// the current registry snapshot when the mirror is still unset (a
    /// model was published without going through this state's ingest).
    fn dim(&self) -> usize {
        let d = self.dim.load(Ordering::Relaxed);
        if d != 0 {
            return d;
        }
        match self.registry.current() {
            Some(snap) => {
                let d = snap.model().dim();
                self.dim.store(d, Ordering::Relaxed);
                d
            }
            None => 0,
        }
    }
}

/// Parse LIBSVM feature tokens (`idx:val`, 1-based ascending convention)
/// into a dense row of dimension `d`. Values must be finite — NaN or
/// infinite literals poison every downstream kernel evaluation, so they
/// are rejected at the wire.
pub(crate) fn parse_features<'a>(
    tokens: impl Iterator<Item = &'a str>,
    d: usize,
) -> Result<Vec<f32>, String> {
    let mut row = vec![0.0f32; d];
    for tok in tokens {
        let (i, v) = tok.split_once(':').ok_or_else(|| format!("bad feature token '{tok}'"))?;
        let idx: usize = i.parse().map_err(|_| format!("bad feature index '{i}'"))?;
        if idx == 0 {
            return Err("feature indices are 1-based".to_string());
        }
        if idx > d {
            return Err(format!("feature index {idx} exceeds the serving dimension {d}"));
        }
        let val: f32 = v.parse().map_err(|_| format!("bad feature value '{v}'"))?;
        if !val.is_finite() {
            return Err(format!("non-finite feature value '{v}'"));
        }
        row[idx - 1] = val;
    }
    Ok(row)
}

/// Largest feature index on a LIBSVM-ish line (0 if none parse).
fn max_index<'a>(tokens: impl Iterator<Item = &'a str>) -> usize {
    tokens
        .filter_map(|tok| tok.split_once(':').and_then(|(i, _)| i.parse::<usize>().ok()))
        .max()
        .unwrap_or(0)
}

/// Answer one request line (already trimmed, non-empty, not `quit`).
/// Infallible by contract: protocol failures become `err ...` responses
/// and backpressure becomes `overloaded ...` responses.
pub fn handle_line(state: &ServeState, line: &str) -> String {
    match dispatch(state, line) {
        Ok(resp) => resp,
        Err(msg) => format!("err {msg}"),
    }
}

fn dispatch(state: &ServeState, line: &str) -> Result<String, String> {
    let mut parts = line.split_whitespace();
    let verb = parts.next().unwrap_or("");
    match verb {
        "predict" => {
            let d = state.dim();
            if d == 0 {
                return Err("no model published yet".to_string());
            }
            let row = parse_features(parts, d)?;
            match state.client.predict_deadline(&row, d, state.predict_deadline) {
                Ok(reply) => {
                    // Live traffic feeds the shadow-evaluation window.
                    state.registry.record_live_rows(&row, d);
                    let label = if reply.labels[0] > 0.0 { "+1" } else { "-1" };
                    Ok(format!("ok {label} v{}", reply.version))
                }
                Err(PredictError::Overloaded { waited_ms }) => {
                    Ok(format!("overloaded predict deadline exceeded after {waited_ms} ms"))
                }
                Err(PredictError::Failed(msg)) => Err(msg),
            }
        }
        "train" => {
            let label_tok = parts.next().ok_or("train needs a label")?;
            let label: f64 =
                label_tok.parse().map_err(|_| format!("bad label '{label_tok}'"))?;
            if !label.is_finite() {
                return Err(format!("non-finite label '{label_tok}'"));
            }
            let label = if label > 0.0 { 1.0f32 } else { -1.0f32 };
            let mut front = state.ingest.lock().expect("ingest lock poisoned");
            if front.pipeline.is_none() {
                return Err("ingest is disabled on this server".to_string());
            }
            // Admission pre-check: at capacity the row is refused before
            // buffering, so `ok queued` is never followed by silent loss.
            if let Some(p) = front.pipeline.as_ref() {
                if p.admission_state() == Admission::RejectTrain {
                    return Ok("overloaded ingest queue at capacity; retry later".to_string());
                }
            }
            if front.dim == 0 {
                // First labeled row pins the serving dimension — but only
                // once the whole line parses, so a malformed first line
                // cannot permanently commit a wrong dimension.
                let feats: Vec<&str> = parts.collect();
                let d = max_index(feats.iter().copied());
                if d == 0 {
                    return Err("cannot infer dimension from an empty row".to_string());
                }
                let row = parse_features(feats.into_iter(), d)?;
                front.dim = d;
                state.dim.store(d, Ordering::Relaxed);
                front.buf_x.extend_from_slice(&row);
            } else {
                let d = front.dim;
                let row = parse_features(parts, d)?;
                front.buf_x.extend_from_slice(&row);
            }
            front.buf_y.push(label);
            if front.buffered_rows() >= front.chunk {
                if let Err(msg) = front.drain_to_pipeline() {
                    // Admission turned reject between the pre-check and
                    // the drain: rows stay buffered (at-least-once), the
                    // client gets the typed backpressure reply.
                    if msg.contains("overloaded") {
                        return Ok(
                            "overloaded ingest queue at capacity; retry later".to_string()
                        );
                    }
                    return Err(msg);
                }
            }
            Ok(format!("ok queued {}", front.buffered_rows()))
        }
        "flush" => {
            let mut front = state.ingest.lock().expect("ingest lock poisoned");
            front.drain_to_pipeline()?;
            let pipeline =
                front.pipeline.as_mut().ok_or("ingest is disabled on this server")?;
            let version = pipeline.publish_now().map_err(|e| e.to_string())?;
            Ok(format!("ok published v{version}"))
        }
        "stats" => {
            let (dim, buffered, ingested, health) = {
                let front = state.ingest.lock().expect("ingest lock poisoned");
                (
                    front.dim,
                    front.buffered_rows(),
                    front.pipeline.as_ref().map(|p| p.rows_ingested()).unwrap_or(0),
                    front.pipeline.as_ref().map(|p| p.health()),
                )
            };
            let (version, num_sv) = match state.registry.current() {
                Some(s) => (s.version(), s.model().num_sv()),
                None => (0, 0),
            };
            let life = state.registry.lifecycle_stats();
            let bstats = state.client.stats();
            let mut pairs = vec![
                ("version", Json::num(version as f64)),
                ("num_sv", Json::num(num_sv as f64)),
                ("dim", Json::num(dim as f64)),
                ("buffered_rows", Json::num(buffered as f64)),
                ("ingested_rows", Json::num(ingested as f64)),
                ("history_len", Json::num(state.registry.history_len() as f64)),
                ("published", Json::num(life.published as f64)),
                ("rollbacks", Json::num(life.rollbacks as f64)),
                ("shadow_rejected", Json::num(life.rejected as f64)),
                (
                    "shadow_last_agreement",
                    life.last_agreement.map(Json::num).unwrap_or(Json::Null),
                ),
                (
                    "shadow_last_accepted",
                    life.last_accepted.map(Json::Bool).unwrap_or(Json::Null),
                ),
                (
                    "shadow_window_rows",
                    Json::num(state.registry.shadow_window_rows() as f64),
                ),
                ("predict_expired", Json::num(bstats.expired as f64)),
                ("telemetry", telemetry_summary()),
            ];
            if let Some(h) = health {
                pairs.push(("admission", Json::str(h.admission.as_str())));
                pairs.push(("pending_rows", Json::num(h.pending_rows as f64)));
                pairs.push(("worker_restarts", Json::num(h.worker_restarts as f64)));
                pairs.push(("rows_requeued", Json::num(h.rows_requeued as f64)));
                pairs.push(("rejected_rows", Json::num(h.rejected_rows as f64)));
                pairs.push(("deferred_publishes", Json::num(h.deferred_publishes as f64)));
                pairs.push(("wal_rows", Json::num(h.wal_rows as f64)));
            }
            Ok(format!("ok {}", Json::object(pairs)))
        }
        "metrics" => {
            // The full registry snapshot as JSON — the wire twin of the
            // Prometheus endpoint, for clients already on the line
            // protocol.
            Ok(format!("ok {}", metrics_registry::snapshot().to_json()))
        }
        "health" => {
            // Heartbeat probe (cluster coordinator → node): cheap, no
            // locks beyond the ingest front, answers even with no model.
            if parts.next().is_some() {
                return Err("health takes no arguments".to_string());
            }
            let ingested = {
                let front = state.ingest.lock().expect("ingest lock poisoned");
                front.pipeline.as_ref().map(|p| p.rows_ingested()).unwrap_or(0)
            };
            Ok(format!("ok {} {}", state.registry.version(), ingested))
        }
        "snapshot" => match parts.next() {
            // `snapshot` — pull the incumbent model as hex-encoded
            // BSVMMDL2 bytes (`ok <version> <ingested-rows> <hex>`), the
            // coordinator's merge input. Budgeted models are small by
            // construction (the budget bounds the SV set), which is what
            // makes a hex line under [`MAX_LINE_BYTES`] a workable
            // transfer unit.
            None => {
                let snap = state.registry.current().ok_or("no model published yet")?;
                let mut bytes = Vec::new();
                crate::model::io::save_any_writer(snap.model(), &mut bytes)
                    .map_err(|e| e.to_string())?;
                let ingested = {
                    let front = state.ingest.lock().expect("ingest lock poisoned");
                    front.pipeline.as_ref().map(|p| p.rows_ingested()).unwrap_or(0)
                };
                Ok(format!("ok {} {} {}", snap.version(), ingested, hex_encode(&bytes)))
            }
            // `snapshot load <version> <hex>` — push a merged model into
            // this node's registry (coordinator → replica re-sync). The
            // version token is the coordinator's stamp, echoed back; the
            // registry assigns its own strictly monotonic local version.
            Some("load") => {
                let ver_tok = parts.next().ok_or("snapshot load takes <version> <hex>")?;
                let coord_version: u64 = ver_tok
                    .parse()
                    .map_err(|_| format!("bad snapshot version '{ver_tok}'"))?;
                let hex = parts.next().ok_or("snapshot load takes <version> <hex>")?;
                if parts.next().is_some() {
                    return Err("snapshot load takes <version> <hex>".to_string());
                }
                let bytes = hex_decode(hex)?;
                let model = crate::model::io::load_any_reader(&bytes[..])
                    .map_err(|e| format!("bad snapshot payload: {e}"))?;
                let dim = model.dim();
                state.registry.publish(model);
                state.dim.store(dim, Ordering::Relaxed);
                {
                    let mut front = state.ingest.lock().expect("ingest lock poisoned");
                    if front.dim == 0 {
                        front.dim = dim;
                    }
                }
                Ok(format!("ok loaded {coord_version}"))
            }
            Some(other) => Err(format!("unknown snapshot subcommand '{other}'")),
        },
        other => Err(format!("unknown command '{other}'")),
    }
}

/// Lowercase hex of `bytes` (the wire form of snapshot payloads — no
/// base64 in a dependency-free tree, and hex keeps the line printable).
pub(crate) fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Inverse of [`hex_encode`]; malformed input is a typed wire error.
pub(crate) fn hex_decode(s: &str) -> Result<Vec<u8>, String> {
    if s.len() % 2 != 0 {
        return Err("hex payload has odd length".to_string());
    }
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        let hi = (pair[0] as char)
            .to_digit(16)
            .ok_or_else(|| "bad hex digit in snapshot payload".to_string())?;
        let lo = (pair[1] as char)
            .to_digit(16)
            .ok_or_else(|| "bad hex digit in snapshot payload".to_string())?;
        out.push(((hi << 4) | lo) as u8);
    }
    Ok(out)
}

/// The pinned telemetry summary carried by the `stats` payload: the
/// operator-facing core of the registry (queue depth, admission ladder
/// counters, WAL fsync p99, deadline expiries, lifecycle counters, the
/// resolved SIMD tier) without the full per-stage histogram dump the
/// `metrics` verb serves.
/// The key set is a wire contract — see the schema drift test.
fn telemetry_summary() -> Json {
    let wal_p99 = metrics_registry::stage_snapshot(Stage::WalAppend).quantile(0.99);
    Json::object(vec![
        ("queue_depth", Json::num(metrics_registry::gauge_value(Gauge::QueueDepth) as f64)),
        (
            "admission_accept",
            Json::num(metrics_registry::counter_value(Counter::AdmissionAccept) as f64),
        ),
        (
            "admission_shed",
            Json::num(metrics_registry::counter_value(Counter::AdmissionShed) as f64),
        ),
        (
            "admission_reject",
            Json::num(metrics_registry::counter_value(Counter::AdmissionReject) as f64),
        ),
        (
            "deadline_expired",
            Json::num(metrics_registry::counter_value(Counter::DeadlineExpired) as f64),
        ),
        ("wal_append_p99_ns", Json::num(wal_p99 as f64)),
        (
            "worker_restarts",
            Json::num(metrics_registry::counter_value(Counter::WorkerRestarts) as f64),
        ),
        ("publishes", Json::num(metrics_registry::counter_value(Counter::Publishes) as f64)),
        ("rollbacks", Json::num(metrics_registry::counter_value(Counter::Rollbacks) as f64)),
        (
            "shadow_rejected",
            Json::num(metrics_registry::counter_value(Counter::ShadowRejected) as f64),
        ),
        ("simd_tier", Json::str(crate::kernel::simd::active().name())),
        ("nodes_up", Json::num(metrics_registry::gauge_value(Gauge::NodesUp) as f64)),
        (
            "rows_redealt",
            Json::num(metrics_registry::counter_value(Counter::RowsRedealt) as f64),
        ),
        ("failovers", Json::num(metrics_registry::counter_value(Counter::Failovers) as f64)),
        (
            "heartbeat_p99_ns",
            Json::num(metrics_registry::stage_snapshot(Stage::Heartbeat).quantile(0.99) as f64),
        ),
    ])
}

/// Read one line of at most `max` bytes. Returns `None` at EOF. The
/// returned flag is `true` when the line exceeded `max`: the overflow is
/// consumed through the terminating newline (keeping the stream
/// line-synchronized) but never buffered — memory stays bounded no
/// matter what the peer sends.
pub(crate) fn read_bounded_line<R: BufRead>(
    reader: &mut R,
    max: usize,
) -> std::io::Result<Option<(Vec<u8>, bool)>> {
    let mut line: Vec<u8> = Vec::new();
    let mut truncated = false;
    loop {
        let available = match reader.fill_buf() {
            Ok(buf) => buf,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        };
        if available.is_empty() {
            // EOF: a final unterminated line still counts if anything
            // was read for it.
            return if line.is_empty() && !truncated {
                Ok(None)
            } else {
                Ok(Some((line, truncated)))
            };
        }
        match available.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                if !truncated {
                    let take = max.saturating_sub(line.len()).min(pos);
                    line.extend_from_slice(&available[..take]);
                    if take < pos {
                        truncated = true;
                    }
                }
                reader.consume(pos + 1);
                return Ok(Some((line, truncated)));
            }
            None => {
                let n = available.len();
                if !truncated {
                    let take = max.saturating_sub(line.len()).min(n);
                    line.extend_from_slice(&available[..take]);
                    if take < n {
                        truncated = true;
                    }
                }
                reader.consume(n);
            }
        }
    }
}

/// Run one session: read request lines, answer each, stop at `quit` or
/// EOF. Works for TCP streams and in-memory buffers alike. A socket
/// read/write timeout (see [`ServeState::with_io_timeout`]) surfaces
/// here as `err session idle timeout` + disconnect; oversized and
/// non-UTF-8 lines are answered per line and the session survives.
pub fn serve_session<R: BufRead, W: Write>(
    state: &ServeState,
    mut reader: R,
    mut writer: W,
) -> Result<()> {
    loop {
        let (bytes, truncated) = match read_bounded_line(&mut reader, MAX_LINE_BYTES) {
            Ok(Some(v)) => v,
            Ok(None) => break, // EOF
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Stalled client: one bounded farewell, then hang up — a
                // dead peer must never pin this thread.
                let _ = writeln!(writer, "err session idle timeout");
                let _ = writer.flush();
                break;
            }
            Err(e) => return Err(e).context("session read failed"),
        };
        if truncated {
            writeln!(writer, "err line exceeds {MAX_LINE_BYTES} bytes")?;
            writer.flush()?;
            continue;
        }
        let Ok(text) = std::str::from_utf8(&bytes) else {
            writeln!(writer, "err request is not valid UTF-8")?;
            writer.flush()?;
            continue;
        };
        let t = text.trim();
        if t.is_empty() {
            continue;
        }
        if t == "quit" {
            writeln!(writer, "ok bye")?;
            writer.flush()?;
            break;
        }
        writeln!(writer, "{}", handle_line(state, t))?;
        writer.flush()?;
    }
    Ok(())
}

/// Accept loop over a bound listener: one thread per connection, all
/// sharing `state`. Each accepted socket gets the state's read/write
/// timeouts, so stalled clients are disconnected instead of pinning
/// their session thread. `max_connections` bounds the number of accepted
/// connections (for tests and graceful smoke runs); `None` serves
/// forever.
pub fn serve_connections(
    listener: TcpListener,
    state: Arc<ServeState>,
    max_connections: Option<usize>,
) -> Result<()> {
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut accepted = 0usize;
    for stream in listener.incoming() {
        // Transient accept errors (ECONNABORTED, fd exhaustion under
        // churn) must not kill the server — log and keep accepting.
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("accept failed (continuing): {e}");
                continue;
            }
        };
        if let Some(t) = state.io_timeout() {
            let _ = stream.set_read_timeout(Some(t));
            let _ = stream.set_write_timeout(Some(t));
        }
        accepted += 1;
        let state = Arc::clone(&state);
        // Reap finished sessions so a long-running server holds handles
        // only for live connections, not every connection ever accepted.
        handles.retain(|h| !h.is_finished());
        handles.push(std::thread::spawn(move || {
            let reader = match stream.try_clone() {
                Ok(s) => BufReader::new(s),
                Err(_) => return,
            };
            let _ = serve_session(&state, reader, stream);
        }));
        if let Some(max) = max_connections {
            if accepted >= max {
                break;
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// Format one dense row as the wire's LIBSVM feature tokens (zeros
/// omitted, matching `data::libsvm::write`).
pub fn format_features(row: &[f32]) -> String {
    let mut out = String::new();
    for (j, &v) in row.iter().enumerate() {
        if v != 0.0 {
            out.push_str(&format!(" {}:{}", j + 1, v));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::kernel::KernelSpec;
    use crate::serve::batcher::{BatcherOptions, MicroBatcher};
    use crate::solver::{RunConfig, SvmConfig};

    fn predict_only_state(reg: Arc<ModelRegistry>) -> (ServeState, MicroBatcher) {
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let state = ServeState::new(reg, batcher.client(), None, 16);
        (state, batcher)
    }

    fn registry_with_toy_model() -> Arc<ModelRegistry> {
        let mut m = crate::model::AnyModel::new(2, KernelSpec::gaussian(1.0), 2).unwrap();
        m.push(&[1.0, 0.0], 1.0);
        m.push(&[-1.0, 0.0], -1.0);
        let reg = Arc::new(ModelRegistry::new());
        reg.publish(m);
        reg
    }

    #[test]
    fn predict_lines_answer_with_model_labels() {
        let reg = registry_with_toy_model();
        let snap = reg.current().unwrap();
        let (state, _batcher) = predict_only_state(reg);
        for probe in [[0.9f32, 0.1], [-0.9, 0.1], [0.0, 0.0]] {
            let resp = handle_line(&state, &format!("predict{}", format_features(&probe)));
            let expect = if snap.model().decision(&probe) >= 0.0 { "+1" } else { "-1" };
            assert_eq!(resp, format!("ok {expect} v1"));
        }
    }

    #[test]
    fn malformed_lines_answer_err_and_keep_the_session_alive() {
        let reg = registry_with_toy_model();
        let (state, _batcher) = predict_only_state(reg);
        for bad in [
            "predict 0:1",
            "predict 3:1",
            "predict x:1",
            "predict 1:abc",
            "predict 1:NaN",
            "predict 1:inf",
            "predict 2:-Infinity",
            "bogus",
            "train +1 1:0.5", // ingest disabled on predict-only servers
            "train NaN 1:0.5",
            "flush",
        ] {
            let resp = handle_line(&state, bad);
            assert!(resp.starts_with("err "), "{bad} -> {resp}");
        }
        // Still serving afterwards.
        assert!(handle_line(&state, "predict 1:1").starts_with("ok "));
    }

    #[test]
    fn session_loop_answers_line_by_line_and_honors_quit() {
        let reg = registry_with_toy_model();
        let (state, _batcher) = predict_only_state(reg);
        let input = "predict 1:1\n\nstats\nquit\npredict 1:1\n";
        let mut out: Vec<u8> = Vec::new();
        serve_session(&state, input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        assert!(lines[0].starts_with("ok "));
        assert!(lines[1].starts_with("ok {"));
        assert_eq!(lines[2], "ok bye");
        // The stats payload is valid JSON with the lifecycle fields.
        let json = Json::parse(lines[1].trim_start_matches("ok ")).unwrap();
        assert_eq!(json.get("version").and_then(Json::as_usize), Some(1));
        assert_eq!(json.get("dim").and_then(Json::as_usize), Some(2));
        assert_eq!(json.get("history_len").and_then(Json::as_usize), Some(1));
        assert_eq!(json.get("rollbacks").and_then(Json::as_usize), Some(0));
        assert_eq!(json.get("predict_expired").and_then(Json::as_usize), Some(0));
        // The predict fed the shadow window.
        assert_eq!(json.get("shadow_window_rows").and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn oversized_and_non_utf8_lines_answer_err_without_killing_the_session() {
        let reg = registry_with_toy_model();
        let (state, _batcher) = predict_only_state(reg);
        let mut input: Vec<u8> = Vec::new();
        // One line far past the cap (memory stays bounded; reply typed).
        input.extend_from_slice(b"predict ");
        input.extend(std::iter::repeat(b'a').take(MAX_LINE_BYTES + 100));
        input.push(b'\n');
        // Invalid UTF-8 bytes.
        input.extend_from_slice(&[0xFF, 0xFE, 0x80, b'\n']);
        // A normal request afterwards must still be served.
        input.extend_from_slice(b"predict 1:1\nquit\n");
        let mut out: Vec<u8> = Vec::new();
        serve_session(&state, &input[..], &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4, "{text}");
        assert!(lines[0].contains("err line exceeds"), "{}", lines[0]);
        assert!(lines[1].contains("err request is not valid UTF-8"), "{}", lines[1]);
        assert!(lines[2].starts_with("ok "), "{}", lines[2]);
        assert_eq!(lines[3], "ok bye");
    }

    #[test]
    fn zero_predict_deadline_answers_overloaded_not_err() {
        let reg = registry_with_toy_model();
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let state = ServeState::new(reg, batcher.client(), None, 16)
            .with_predict_deadline(Some(Duration::ZERO));
        let resp = handle_line(&state, "predict 1:1");
        assert!(resp.starts_with("overloaded "), "{resp}");
        batcher.shutdown();
    }

    #[test]
    fn malformed_first_train_line_does_not_pin_the_dimension() {
        let reg = Arc::new(ModelRegistry::new());
        let svm = SvmConfig::new().kernel(KernelSpec::gaussian(2.0)).budget(10).c(10.0, 100);
        let pipeline =
            ShardedIngest::new(svm, RunConfig::new(), 1, 10_000, Arc::clone(&reg)).unwrap();
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let state = ServeState::new(Arc::clone(&reg), batcher.client(), Some(pipeline), 8);
        // A bad value on the would-be dimension-pinning line must leave
        // the dimension unset...
        assert!(handle_line(&state, "train +1 3:bogus").starts_with("err "));
        // ...so a later valid wide row can still establish it.
        assert!(handle_line(&state, "train +1 1:0.5 5:1.0").starts_with("ok queued"));
        assert!(handle_line(&state, "train -1 4:0.25").starts_with("ok queued"));
        batcher.shutdown();
    }

    #[test]
    fn train_flush_lifecycle_publishes_and_serves_the_new_model() {
        let ds = two_moons(240, 0.12, 13);
        let reg = Arc::new(ModelRegistry::new());
        let svm = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(20)
            .c(10.0, ds.len());
        let pipeline =
            ShardedIngest::new(svm, RunConfig::new().seed(5), 2, 10_000, Arc::clone(&reg))
                .unwrap();
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let state = ServeState::new(Arc::clone(&reg), batcher.client(), Some(pipeline), 32);

        // Before any model: predict must fail, train must buffer. Rows are
        // sent with both indices explicit so the first line pins the
        // serving dimension at 2 even if a coordinate is zero.
        assert!(handle_line(&state, "predict 1:0.5 2:0.5").starts_with("err "));
        for i in 0..ds.len() {
            let line = format!(
                "train {} 1:{} 2:{}",
                if ds.label(i) > 0.0 { "+1" } else { "-1" },
                ds.row(i)[0],
                ds.row(i)[1]
            );
            let resp = handle_line(&state, &line);
            assert!(resp.starts_with("ok queued "), "{resp}");
        }
        let resp = handle_line(&state, "flush");
        assert!(resp.starts_with("ok published v"), "{resp}");
        assert_eq!(reg.version(), 1);
        // The published model now serves predictions, and they match the
        // snapshot's own labels.
        let snap = reg.current().unwrap();
        let mut correct = 0usize;
        for i in 0..ds.len() {
            let resp =
                handle_line(&state, &format!("predict{}", format_features(ds.row(i))));
            let expect = if snap.model().decision(ds.row(i)) >= 0.0 { "+1" } else { "-1" };
            assert_eq!(resp, format!("ok {expect} v1"), "row {i}");
            let label: f32 = if resp.contains("+1") { 1.0 } else { -1.0 };
            if label == ds.label(i) {
                correct += 1;
            }
        }
        assert!(correct as f64 / ds.len() as f64 > 0.8, "served accuracy too low");
        batcher.shutdown();
    }

    /// Satellite: the `stats` payload schema is a wire contract. Any key
    /// added to or removed from the payload must be a deliberate change
    /// that updates this pinned list alongside the dashboards that parse
    /// it. Keys are compared as exact sets, not subsets, so drift in
    /// either direction fails.
    #[test]
    fn stats_schema_is_pinned_for_both_server_shapes() {
        let base_keys = [
            "buffered_rows",
            "dim",
            "history_len",
            "ingested_rows",
            "num_sv",
            "predict_expired",
            "published",
            "rollbacks",
            "shadow_last_accepted",
            "shadow_last_agreement",
            "shadow_rejected",
            "shadow_window_rows",
            "telemetry",
            "version",
        ];
        let health_keys = [
            "admission",
            "deferred_publishes",
            "pending_rows",
            "rejected_rows",
            "rows_requeued",
            "wal_rows",
            "worker_restarts",
        ];
        let telemetry_keys = [
            "admission_accept",
            "admission_reject",
            "admission_shed",
            "deadline_expired",
            "failovers",
            "heartbeat_p99_ns",
            "nodes_up",
            "publishes",
            "queue_depth",
            "rollbacks",
            "rows_redealt",
            "shadow_rejected",
            "simd_tier",
            "wal_append_p99_ns",
            "worker_restarts",
        ];
        let keys_of = |resp: &str| -> Vec<String> {
            let json = Json::parse(resp.trim_start_matches("ok ")).unwrap();
            json.as_object().expect("stats payload is an object").keys().cloned().collect()
        };

        // Predict-only server: the base schema, no pipeline health block.
        let reg = registry_with_toy_model();
        let (state, _batcher) = predict_only_state(reg);
        let resp = handle_line(&state, "stats");
        assert_eq!(keys_of(&resp), base_keys, "predict-only stats keys drifted");
        let json = Json::parse(resp.trim_start_matches("ok ")).unwrap();
        let tel = json.get("telemetry").and_then(Json::as_object).expect("telemetry object");
        let tel_keys: Vec<String> = tel.keys().cloned().collect();
        assert_eq!(tel_keys, telemetry_keys, "telemetry sub-object keys drifted");

        // Full ingest server: base schema plus the health block (BTreeMap
        // ordering interleaves them alphabetically).
        let reg = Arc::new(ModelRegistry::new());
        let svm = SvmConfig::new().kernel(KernelSpec::gaussian(2.0)).budget(10).c(10.0, 100);
        let pipeline =
            ShardedIngest::new(svm, RunConfig::new(), 1, 10_000, Arc::clone(&reg)).unwrap();
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let state = ServeState::new(Arc::clone(&reg), batcher.client(), Some(pipeline), 8);
        let mut expected: Vec<String> = base_keys
            .iter()
            .chain(health_keys.iter())
            .map(|s| s.to_string())
            .collect();
        expected.sort();
        assert_eq!(keys_of(&handle_line(&state, "stats")), expected, "ingest stats keys drifted");
        batcher.shutdown();
    }

    #[test]
    fn metrics_verb_serves_the_full_registry_snapshot() {
        let reg = registry_with_toy_model();
        let (state, _batcher) = predict_only_state(reg);
        let resp = handle_line(&state, "metrics");
        assert!(resp.starts_with("ok {"), "{resp}");
        let json = Json::parse(resp.trim_start_matches("ok ")).unwrap();
        for family in ["counters", "gauges", "stages"] {
            assert!(json.get(family).and_then(Json::as_object).is_some(), "missing {family}");
        }
        // Every stage histogram is present whether or not it has samples.
        let stages = json.get("stages").and_then(Json::as_object).unwrap();
        for stage in crate::telemetry::Stage::ALL {
            assert!(stages.contains_key(stage.key()), "stage {} missing", stage.key());
        }
    }

    #[test]
    fn health_answers_version_and_ingested_rows() {
        let reg = registry_with_toy_model();
        let (state, _batcher) = predict_only_state(reg);
        // Predict-only server: version 1, zero ingested rows.
        assert_eq!(handle_line(&state, "health"), "ok 1 0");
    }

    #[test]
    fn snapshot_round_trips_a_model_through_hex() {
        let reg = registry_with_toy_model();
        let expect = {
            let mut bytes = Vec::new();
            crate::model::io::save_any_writer(reg.current().unwrap().model(), &mut bytes)
                .unwrap();
            bytes
        };
        let (state, _batcher) = predict_only_state(Arc::clone(&reg));
        let resp = handle_line(&state, "snapshot");
        let mut toks = resp.split_whitespace();
        assert_eq!(toks.next(), Some("ok"));
        assert_eq!(toks.next(), Some("1"), "version");
        assert_eq!(toks.next(), Some("0"), "ingested rows");
        let hex = toks.next().expect("hex payload");
        assert!(toks.next().is_none());
        assert_eq!(hex_decode(hex).unwrap(), expect, "hex round-trip drifted");
        // Pushing the snapshot back publishes a fresh local version and
        // echoes the coordinator's stamp.
        assert_eq!(handle_line(&state, &format!("snapshot load 7 {hex}")), "ok loaded 7");
        assert_eq!(reg.version(), 2);
        assert!(handle_line(&state, "predict 1:1").starts_with("ok "));
    }

    /// Satellite: malformed `health`/`snapshot` input answers `err` on
    /// that line only — the session survives, it is never disconnected.
    #[test]
    fn health_and_snapshot_answer_err_not_disconnect_on_malformed_input() {
        let reg = registry_with_toy_model();
        let (state, _batcher) = predict_only_state(reg);
        let good_hex = {
            let resp = handle_line(&state, "snapshot");
            resp.split_whitespace().nth(3).unwrap().to_string()
        };
        for bad in [
            "health extra".to_string(),
            "snapshot bogus".to_string(),
            "snapshot load".to_string(),
            "snapshot load 1".to_string(),
            "snapshot load x aabb".to_string(),
            "snapshot load 1 zz".to_string(),
            "snapshot load 1 abc".to_string(), // odd-length hex
            "snapshot load 1 aabbcc".to_string(), // hex fine, bytes not a model
            format!("snapshot load 1 {good_hex} trailing"),
        ] {
            let resp = handle_line(&state, &bad);
            assert!(resp.starts_with("err "), "{bad} -> {resp}");
        }
        // No model published on a fresh registry: snapshot pull errors.
        let empty = Arc::new(ModelRegistry::new());
        let (empty_state, _b2) = predict_only_state(empty);
        assert!(handle_line(&empty_state, "snapshot").starts_with("err "));
        // The original session still serves after every bad line.
        assert!(handle_line(&state, "health").starts_with("ok "));
        assert!(handle_line(&state, "predict 1:1").starts_with("ok "));
    }

    #[test]
    fn tcp_round_trip_on_localhost() {
        let reg = registry_with_toy_model();
        let snap = reg.current().unwrap();
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let state = Arc::new(ServeState::new(reg, batcher.client(), None, 16));
        let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind loopback");
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || serve_connections(listener, state, Some(1)));

        let mut stream = std::net::TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        for probe in [[0.9f32, 0.0], [-0.9, 0.0]] {
            writeln!(stream, "predict{}", format_features(&probe)).unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            let expect = if snap.model().decision(&probe) >= 0.0 { "+1" } else { "-1" };
            assert_eq!(line.trim(), format!("ok {expect} v1"));
        }
        writeln!(stream, "quit").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "ok bye");
        server.join().unwrap().unwrap();
        batcher.shutdown();
    }
}

//! Line protocol + session loop of `repro serve` (see the [`super`]
//! module docs for the full wire grammar).
//!
//! The session loop is generic over `BufRead`/`Write`, so the same code
//! path answers a TCP connection, an in-memory replay (the offline
//! `--replay` benchmark and the tests), or any future transport. One
//! [`ServeState`] is shared by every session: the registry and the
//! batcher client are lock-free/short-lock concurrent, while the ingest
//! front (row buffer + shard pipeline) sits behind one mutex — training
//! rows are cheap to buffer and the pipeline itself fans out to shard
//! workers immediately.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::data::Dataset;
use crate::util::json::Json;

use super::batcher::BatcherClient;
use super::ingest::ShardedIngest;
use super::registry::ModelRegistry;

/// Buffering ingest front: accumulates `train` rows and hands them to the
/// shard pipeline in `chunk`-row batches (plus on every explicit flush).
struct IngestFront {
    pipeline: Option<ShardedIngest>,
    buf_x: Vec<f32>,
    buf_y: Vec<f32>,
    /// Serving dimension; 0 until pinned by the initial model or the
    /// first `train` line.
    dim: usize,
    chunk: usize,
}

impl IngestFront {
    fn buffered_rows(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.buf_x.len() / self.dim
        }
    }

    fn drain_to_pipeline(&mut self) -> Result<(), String> {
        if self.buf_y.is_empty() {
            return Ok(());
        }
        let pipeline = self.pipeline.as_mut().ok_or("ingest is disabled on this server")?;
        let batch = Dataset::new(
            "wire",
            std::mem::take(&mut self.buf_x),
            std::mem::take(&mut self.buf_y),
            self.dim,
        );
        match pipeline.ingest(&batch) {
            Ok(()) => Ok(()),
            Err(e) => {
                // Rows were acknowledged with `ok queued`; on a pipeline
                // failure keep them buffered for the next drain attempt
                // (at-least-once — never silently dropped) rather than
                // losing them with the taken buffers.
                self.buf_x.extend_from_slice(batch.features());
                self.buf_y.extend_from_slice(batch.labels());
                Err(e.to_string())
            }
        }
    }
}

/// Shared state of one serving process.
pub struct ServeState {
    registry: Arc<ModelRegistry>,
    client: BatcherClient,
    ingest: Mutex<IngestFront>,
    /// Lock-free mirror of the serving dimension (0 until pinned), so the
    /// predict path never touches the ingest mutex — a publish stall on
    /// the ingest side must not delay readers.
    dim: AtomicUsize,
}

impl ServeState {
    /// Assemble the serving state. `pipeline` is `None` for predict-only
    /// servers (replay benchmarking of a frozen model); `chunk` is the
    /// ingest-front buffer size in rows.
    pub fn new(
        registry: Arc<ModelRegistry>,
        client: BatcherClient,
        pipeline: Option<ShardedIngest>,
        chunk: usize,
    ) -> Self {
        let dim = registry.current().map(|s| s.model().dim()).unwrap_or(0);
        ServeState {
            registry,
            client,
            ingest: Mutex::new(IngestFront {
                pipeline,
                buf_x: Vec::new(),
                buf_y: Vec::new(),
                dim,
                chunk: chunk.max(1),
            }),
            dim: AtomicUsize::new(dim),
        }
    }

    /// The serving dimension (0 until pinned). Lock-free; falls back to
    /// the current registry snapshot when the mirror is still unset (a
    /// model was published without going through this state's ingest).
    fn dim(&self) -> usize {
        let d = self.dim.load(Ordering::Relaxed);
        if d != 0 {
            return d;
        }
        match self.registry.current() {
            Some(snap) => {
                let d = snap.model().dim();
                self.dim.store(d, Ordering::Relaxed);
                d
            }
            None => 0,
        }
    }
}

/// Parse LIBSVM feature tokens (`idx:val`, 1-based ascending convention)
/// into a dense row of dimension `d`.
fn parse_features<'a>(
    tokens: impl Iterator<Item = &'a str>,
    d: usize,
) -> Result<Vec<f32>, String> {
    let mut row = vec![0.0f32; d];
    for tok in tokens {
        let (i, v) = tok.split_once(':').ok_or_else(|| format!("bad feature token '{tok}'"))?;
        let idx: usize = i.parse().map_err(|_| format!("bad feature index '{i}'"))?;
        if idx == 0 {
            return Err("feature indices are 1-based".to_string());
        }
        if idx > d {
            return Err(format!("feature index {idx} exceeds the serving dimension {d}"));
        }
        let val: f32 = v.parse().map_err(|_| format!("bad feature value '{v}'"))?;
        row[idx - 1] = val;
    }
    Ok(row)
}

/// Largest feature index on a LIBSVM-ish line (0 if none parse).
fn max_index<'a>(tokens: impl Iterator<Item = &'a str>) -> usize {
    tokens
        .filter_map(|tok| tok.split_once(':').and_then(|(i, _)| i.parse::<usize>().ok()))
        .max()
        .unwrap_or(0)
}

/// Answer one request line (already trimmed, non-empty, not `quit`).
/// Infallible by contract: protocol failures become `err ...` responses.
pub fn handle_line(state: &ServeState, line: &str) -> String {
    match dispatch(state, line) {
        Ok(resp) => resp,
        Err(msg) => format!("err {msg}"),
    }
}

fn dispatch(state: &ServeState, line: &str) -> Result<String, String> {
    let mut parts = line.split_whitespace();
    let verb = parts.next().unwrap_or("");
    match verb {
        "predict" => {
            let d = state.dim();
            if d == 0 {
                return Err("no model published yet".to_string());
            }
            let row = parse_features(parts, d)?;
            let reply = state.client.predict(&row, d).map_err(|e| e.to_string())?;
            let label = if reply.labels[0] > 0.0 { "+1" } else { "-1" };
            Ok(format!("ok {label} v{}", reply.version))
        }
        "train" => {
            let label_tok = parts.next().ok_or("train needs a label")?;
            let label: f64 =
                label_tok.parse().map_err(|_| format!("bad label '{label_tok}'"))?;
            let label = if label > 0.0 { 1.0f32 } else { -1.0f32 };
            let mut front = state.ingest.lock().expect("ingest lock poisoned");
            if front.pipeline.is_none() {
                return Err("ingest is disabled on this server".to_string());
            }
            if front.dim == 0 {
                // First labeled row pins the serving dimension — but only
                // once the whole line parses, so a malformed first line
                // cannot permanently commit a wrong dimension.
                let feats: Vec<&str> = parts.collect();
                let d = max_index(feats.iter().copied());
                if d == 0 {
                    return Err("cannot infer dimension from an empty row".to_string());
                }
                let row = parse_features(feats.into_iter(), d)?;
                front.dim = d;
                state.dim.store(d, Ordering::Relaxed);
                front.buf_x.extend_from_slice(&row);
            } else {
                let d = front.dim;
                let row = parse_features(parts, d)?;
                front.buf_x.extend_from_slice(&row);
            }
            front.buf_y.push(label);
            if front.buffered_rows() >= front.chunk {
                front.drain_to_pipeline()?;
            }
            Ok(format!("ok queued {}", front.buffered_rows()))
        }
        "flush" => {
            let mut front = state.ingest.lock().expect("ingest lock poisoned");
            front.drain_to_pipeline()?;
            let pipeline =
                front.pipeline.as_mut().ok_or("ingest is disabled on this server")?;
            let version = pipeline.publish_now().map_err(|e| e.to_string())?;
            Ok(format!("ok published v{version}"))
        }
        "stats" => {
            let (dim, buffered, ingested) = {
                let front = state.ingest.lock().expect("ingest lock poisoned");
                (
                    front.dim,
                    front.buffered_rows(),
                    front.pipeline.as_ref().map(|p| p.rows_ingested()).unwrap_or(0),
                )
            };
            let (version, num_sv) = match state.registry.current() {
                Some(s) => (s.version(), s.model().num_sv()),
                None => (0, 0),
            };
            let json = Json::object(vec![
                ("version", Json::num(version as f64)),
                ("num_sv", Json::num(num_sv as f64)),
                ("dim", Json::num(dim as f64)),
                ("buffered_rows", Json::num(buffered as f64)),
                ("ingested_rows", Json::num(ingested as f64)),
            ]);
            Ok(format!("ok {json}"))
        }
        other => Err(format!("unknown command '{other}'")),
    }
}

/// Run one session: read request lines, answer each, stop at `quit` or
/// EOF. Works for TCP streams and in-memory buffers alike.
pub fn serve_session<R: BufRead, W: Write>(
    state: &ServeState,
    reader: R,
    mut writer: W,
) -> Result<()> {
    for line in reader.lines() {
        let line = line.context("session read failed")?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        if t == "quit" {
            writeln!(writer, "ok bye")?;
            writer.flush()?;
            break;
        }
        writeln!(writer, "{}", handle_line(state, t))?;
        writer.flush()?;
    }
    Ok(())
}

/// Accept loop over a bound listener: one thread per connection, all
/// sharing `state`. `max_connections` bounds the number of accepted
/// connections (for tests and graceful smoke runs); `None` serves
/// forever.
pub fn serve_connections(
    listener: TcpListener,
    state: Arc<ServeState>,
    max_connections: Option<usize>,
) -> Result<()> {
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut accepted = 0usize;
    for stream in listener.incoming() {
        // Transient accept errors (ECONNABORTED, fd exhaustion under
        // churn) must not kill the server — log and keep accepting.
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("accept failed (continuing): {e}");
                continue;
            }
        };
        accepted += 1;
        let state = Arc::clone(&state);
        // Reap finished sessions so a long-running server holds handles
        // only for live connections, not every connection ever accepted.
        handles.retain(|h| !h.is_finished());
        handles.push(std::thread::spawn(move || {
            let reader = match stream.try_clone() {
                Ok(s) => BufReader::new(s),
                Err(_) => return,
            };
            let _ = serve_session(&state, reader, stream);
        }));
        if let Some(max) = max_connections {
            if accepted >= max {
                break;
            }
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// Format one dense row as the wire's LIBSVM feature tokens (zeros
/// omitted, matching `data::libsvm::write`).
pub fn format_features(row: &[f32]) -> String {
    let mut out = String::new();
    for (j, &v) in row.iter().enumerate() {
        if v != 0.0 {
            out.push_str(&format!(" {}:{}", j + 1, v));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::kernel::KernelSpec;
    use crate::serve::batcher::{BatcherOptions, MicroBatcher};
    use crate::solver::{RunConfig, SvmConfig};

    fn predict_only_state(reg: Arc<ModelRegistry>) -> (ServeState, MicroBatcher) {
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let state = ServeState::new(reg, batcher.client(), None, 16);
        (state, batcher)
    }

    fn registry_with_toy_model() -> Arc<ModelRegistry> {
        let mut m = crate::model::AnyModel::new(2, KernelSpec::gaussian(1.0), 2).unwrap();
        m.push(&[1.0, 0.0], 1.0);
        m.push(&[-1.0, 0.0], -1.0);
        let reg = Arc::new(ModelRegistry::new());
        reg.publish(m);
        reg
    }

    #[test]
    fn predict_lines_answer_with_model_labels() {
        let reg = registry_with_toy_model();
        let snap = reg.current().unwrap();
        let (state, _batcher) = predict_only_state(reg);
        for probe in [[0.9f32, 0.1], [-0.9, 0.1], [0.0, 0.0]] {
            let resp = handle_line(&state, &format!("predict{}", format_features(&probe)));
            let expect = if snap.model().decision(&probe) >= 0.0 { "+1" } else { "-1" };
            assert_eq!(resp, format!("ok {expect} v1"));
        }
    }

    #[test]
    fn malformed_lines_answer_err_and_keep_the_session_alive() {
        let reg = registry_with_toy_model();
        let (state, _batcher) = predict_only_state(reg);
        for bad in [
            "predict 0:1",
            "predict 3:1",
            "predict x:1",
            "predict 1:abc",
            "bogus",
            "train +1 1:0.5", // ingest disabled on predict-only servers
            "flush",
        ] {
            let resp = handle_line(&state, bad);
            assert!(resp.starts_with("err "), "{bad} -> {resp}");
        }
        // Still serving afterwards.
        assert!(handle_line(&state, "predict 1:1").starts_with("ok "));
    }

    #[test]
    fn session_loop_answers_line_by_line_and_honors_quit() {
        let reg = registry_with_toy_model();
        let (state, _batcher) = predict_only_state(reg);
        let input = "predict 1:1\n\nstats\nquit\npredict 1:1\n";
        let mut out: Vec<u8> = Vec::new();
        serve_session(&state, input.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        assert!(lines[0].starts_with("ok "));
        assert!(lines[1].starts_with("ok {"));
        assert_eq!(lines[2], "ok bye");
        // The stats payload is valid JSON.
        let json = Json::parse(lines[1].trim_start_matches("ok ")).unwrap();
        assert_eq!(json.get("version").and_then(Json::as_usize), Some(1));
        assert_eq!(json.get("dim").and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn malformed_first_train_line_does_not_pin_the_dimension() {
        let reg = Arc::new(ModelRegistry::new());
        let svm = SvmConfig::new().kernel(KernelSpec::gaussian(2.0)).budget(10).c(10.0, 100);
        let pipeline =
            ShardedIngest::new(svm, RunConfig::new(), 1, 10_000, Arc::clone(&reg)).unwrap();
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let state = ServeState::new(Arc::clone(&reg), batcher.client(), Some(pipeline), 8);
        // A bad value on the would-be dimension-pinning line must leave
        // the dimension unset...
        assert!(handle_line(&state, "train +1 3:bogus").starts_with("err "));
        // ...so a later valid wide row can still establish it.
        assert!(handle_line(&state, "train +1 1:0.5 5:1.0").starts_with("ok queued"));
        assert!(handle_line(&state, "train -1 4:0.25").starts_with("ok queued"));
        batcher.shutdown();
    }

    #[test]
    fn train_flush_lifecycle_publishes_and_serves_the_new_model() {
        let ds = two_moons(240, 0.12, 13);
        let reg = Arc::new(ModelRegistry::new());
        let svm = SvmConfig::new()
            .kernel(KernelSpec::gaussian(2.0))
            .budget(20)
            .c(10.0, ds.len());
        let pipeline =
            ShardedIngest::new(svm, RunConfig::new().seed(5), 2, 10_000, Arc::clone(&reg))
                .unwrap();
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let state = ServeState::new(Arc::clone(&reg), batcher.client(), Some(pipeline), 32);

        // Before any model: predict must fail, train must buffer. Rows are
        // sent with both indices explicit so the first line pins the
        // serving dimension at 2 even if a coordinate is zero.
        assert!(handle_line(&state, "predict 1:0.5 2:0.5").starts_with("err "));
        for i in 0..ds.len() {
            let line = format!(
                "train {} 1:{} 2:{}",
                if ds.label(i) > 0.0 { "+1" } else { "-1" },
                ds.row(i)[0],
                ds.row(i)[1]
            );
            let resp = handle_line(&state, &line);
            assert!(resp.starts_with("ok queued "), "{resp}");
        }
        let resp = handle_line(&state, "flush");
        assert!(resp.starts_with("ok published v"), "{resp}");
        assert_eq!(reg.version(), 1);
        // The published model now serves predictions, and they match the
        // snapshot's own labels.
        let snap = reg.current().unwrap();
        let mut correct = 0usize;
        for i in 0..ds.len() {
            let resp =
                handle_line(&state, &format!("predict{}", format_features(ds.row(i))));
            let expect = if snap.model().decision(ds.row(i)) >= 0.0 { "+1" } else { "-1" };
            assert_eq!(resp, format!("ok {expect} v1"), "row {i}");
            let label: f32 = if resp.contains("+1") { 1.0 } else { -1.0 };
            if label == ds.label(i) {
                correct += 1;
            }
        }
        assert!(correct as f64 / ds.len() as f64 > 0.8, "served accuracy too low");
        batcher.shutdown();
    }

    #[test]
    fn tcp_round_trip_on_localhost() {
        let reg = registry_with_toy_model();
        let snap = reg.current().unwrap();
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let state = Arc::new(ServeState::new(reg, batcher.client(), None, 16));
        let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind loopback");
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || serve_connections(listener, state, Some(1)));

        let mut stream = std::net::TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        for probe in [[0.9f32, 0.0], [-0.9, 0.0]] {
            writeln!(stream, "predict{}", format_features(&probe)).unwrap();
            line.clear();
            reader.read_line(&mut line).unwrap();
            let expect = if snap.model().decision(&probe) >= 0.0 { "+1" } else { "-1" };
            assert_eq!(line.trim(), format!("ok {expect} v1"));
        }
        writeln!(stream, "quit").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "ok bye");
        server.join().unwrap().unwrap();
        batcher.shutdown();
    }
}

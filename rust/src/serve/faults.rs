//! Deterministic fault injection for the serve tier.
//!
//! A [`FaultPlan`] is a seeded, schedule-driven description of the
//! failures to inject into one pipeline run: a shard worker panicking
//! after processing its k-th row, a simulated process crash between the
//! WAL append and the checkpoint (optionally leaving a torn WAL frame
//! behind, exactly what a real crash mid-append produces), and a
//! slow-client stall for the protocol/latency harnesses. Every trigger
//! point is a row count, never a wall-clock time, so a plan replays
//! identically run-to-run — which is what makes the resilience
//! acceptance tests and `experiments::resilience_bench` deterministic.
//!
//! The plan is threaded behind an explicit test/bench hook
//! ([`super::ShardedIngest::fault_inject`]); production entry points
//! simply never install one.

use crate::util::rng::Rng;

/// Marker carried by every injected-crash error message, so harnesses can
/// tell a scheduled crash apart from a genuine failure.
pub const INJECTED_CRASH_MARKER: &str = "injected crash";

/// Returns whether an error message came from a scheduled
/// [`FaultPlan::crash_at_rows`] trigger.
pub fn is_injected_crash(msg: &str) -> bool {
    msg.contains(INJECTED_CRASH_MARKER)
}

/// One shard-worker panic trigger.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerPanic {
    /// Shard whose worker panics.
    pub shard: usize,
    /// The worker panics when its cumulative processed row count would
    /// reach this value (the batch crossing it is lost mid-flight).
    pub after_rows: u64,
}

/// A deterministic, schedule-driven fault schedule for one pipeline run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Panic one shard worker at a scheduled row count.
    pub worker_panic: Option<WorkerPanic>,
    /// Simulate a process crash once the global ingested row count
    /// crosses this value: the triggering batch is WAL-appended (acked)
    /// but never dispatched or checkpointed, and the pipeline refuses all
    /// further work — the caller must go through recovery.
    pub crash_at_rows: Option<u64>,
    /// On the simulated crash, also leave half a WAL frame behind (a torn
    /// write), which recovery must truncate away.
    pub tear_wal_on_crash: bool,
    /// Stall duration for the slow-client arm of the latency harnesses,
    /// in milliseconds (not interpreted by the pipeline itself).
    pub stall_client_ms: u64,
}

impl FaultPlan {
    /// An empty plan (no faults).
    pub fn none() -> Self {
        Self::default()
    }

    /// Derive a full schedule from a seed for a run of roughly
    /// `total_rows` rows over `shards` shards: one worker panic in the
    /// first half of the stream, one torn-write crash in the second half,
    /// and a stall in the tens of milliseconds. Deterministic in
    /// `(seed, total_rows, shards)`.
    pub fn seeded(seed: u64, total_rows: u64, shards: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0xFA_017);
        let total = total_rows.max(8);
        let per_shard = (total / shards.max(1) as u64).max(2);
        FaultPlan {
            worker_panic: Some(WorkerPanic {
                shard: (rng.next_u64() % shards.max(1) as u64) as usize,
                after_rows: 1 + rng.next_u64() % (per_shard / 2).max(1),
            }),
            crash_at_rows: Some(total / 2 + rng.next_u64() % (total / 4).max(1)),
            tear_wal_on_crash: true,
            stall_client_ms: 20 + rng.next_u64() % 40,
        }
    }

    /// Builder: arm a worker panic.
    pub fn with_worker_panic(mut self, shard: usize, after_rows: u64) -> Self {
        self.worker_panic = Some(WorkerPanic { shard, after_rows });
        self
    }

    /// Builder: arm a simulated crash (optionally with a torn WAL tail).
    pub fn with_crash_at_rows(mut self, rows: u64, tear_wal: bool) -> Self {
        self.crash_at_rows = Some(rows);
        self.tear_wal_on_crash = tear_wal;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_deterministic_and_in_range() {
        let a = FaultPlan::seeded(42, 1000, 4);
        let b = FaultPlan::seeded(42, 1000, 4);
        assert_eq!(a, b);
        let c = FaultPlan::seeded(43, 1000, 4);
        assert_ne!(a, c, "different seeds must give different schedules");
        let p = a.worker_panic.unwrap();
        assert!(p.shard < 4);
        assert!(p.after_rows >= 1 && p.after_rows <= 125);
        let crash = a.crash_at_rows.unwrap();
        assert!((500..750).contains(&crash));
        assert!((20..60).contains(&a.stall_client_ms));
        assert!(a.tear_wal_on_crash);
    }

    #[test]
    fn builders_compose() {
        let plan = FaultPlan::none().with_worker_panic(2, 17).with_crash_at_rows(99, false);
        assert_eq!(plan.worker_panic, Some(WorkerPanic { shard: 2, after_rows: 17 }));
        assert_eq!(plan.crash_at_rows, Some(99));
        assert!(!plan.tear_wal_on_crash);
        assert!(is_injected_crash("pipeline dead: injected crash at row 99"));
        assert!(!is_injected_crash("shard worker terminated"));
    }
}

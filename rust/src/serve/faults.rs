//! Deterministic fault injection for the serve tier.
//!
//! A [`FaultPlan`] is a seeded, schedule-driven description of the
//! failures to inject into one pipeline run: a shard worker panicking
//! after processing its k-th row, a simulated process crash between the
//! WAL append and the checkpoint (optionally leaving a torn WAL frame
//! behind, exactly what a real crash mid-append produces), and a
//! slow-client stall for the protocol/latency harnesses. Every trigger
//! point is a row count, never a wall-clock time, so a plan replays
//! identically run-to-run — which is what makes the resilience
//! acceptance tests and `experiments::resilience_bench` deterministic.
//!
//! The plan is threaded behind an explicit test/bench hook
//! ([`super::ShardedIngest::fault_inject`]); production entry points
//! simply never install one.

use crate::util::rng::Rng;

/// Marker carried by every injected-crash error message, so harnesses can
/// tell a scheduled crash apart from a genuine failure.
pub const INJECTED_CRASH_MARKER: &str = "injected crash";

/// Returns whether an error message came from a scheduled
/// [`FaultPlan::crash_at_rows`] trigger.
pub fn is_injected_crash(msg: &str) -> bool {
    msg.contains(INJECTED_CRASH_MARKER)
}

/// One shard-worker panic trigger.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerPanic {
    /// Shard whose worker panics.
    pub shard: usize,
    /// The worker panics when its cumulative processed row count would
    /// reach this value (the batch crossing it is lost mid-flight).
    pub after_rows: u64,
}

/// A deterministic, schedule-driven fault schedule for one pipeline run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Panic one shard worker at a scheduled row count.
    pub worker_panic: Option<WorkerPanic>,
    /// Simulate a process crash once the global ingested row count
    /// crosses this value: the triggering batch is WAL-appended (acked)
    /// but never dispatched or checkpointed, and the pipeline refuses all
    /// further work — the caller must go through recovery.
    pub crash_at_rows: Option<u64>,
    /// On the simulated crash, also leave half a WAL frame behind (a torn
    /// write), which recovery must truncate away.
    pub tear_wal_on_crash: bool,
    /// Stall duration for the slow-client arm of the latency harnesses,
    /// in milliseconds (not interpreted by the pipeline itself).
    pub stall_client_ms: u64,
}

impl FaultPlan {
    /// An empty plan (no faults).
    pub fn none() -> Self {
        Self::default()
    }

    /// Derive a full schedule from a seed for a run of roughly
    /// `total_rows` rows over `shards` shards: one worker panic in the
    /// first half of the stream, one torn-write crash in the second half,
    /// and a stall in the tens of milliseconds. Deterministic in
    /// `(seed, total_rows, shards)`.
    pub fn seeded(seed: u64, total_rows: u64, shards: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0xFA_017);
        let total = total_rows.max(8);
        let per_shard = (total / shards.max(1) as u64).max(2);
        FaultPlan {
            worker_panic: Some(WorkerPanic {
                shard: (rng.next_u64() % shards.max(1) as u64) as usize,
                after_rows: 1 + rng.next_u64() % (per_shard / 2).max(1),
            }),
            crash_at_rows: Some(total / 2 + rng.next_u64() % (total / 4).max(1)),
            tear_wal_on_crash: true,
            stall_client_ms: 20 + rng.next_u64() % 40,
        }
    }

    /// Builder: arm a worker panic.
    pub fn with_worker_panic(mut self, shard: usize, after_rows: u64) -> Self {
        self.worker_panic = Some(WorkerPanic { shard, after_rows });
        self
    }

    /// Builder: arm a simulated crash (optionally with a torn WAL tail).
    pub fn with_crash_at_rows(mut self, rows: u64, tear_wal: bool) -> Self {
        self.crash_at_rows = Some(rows);
        self.tear_wal_on_crash = tear_wal;
        self
    }
}

/// Network-level fault schedule for one cluster run: which
/// coordinator↔node links fail, when, and how. Like [`FaultPlan`],
/// every trigger is a **dealt-row count** (the coordinator's global
/// sequence counter), never a wall-clock time, so a cluster scenario
/// replays identically run-to-run. Injection lives coordinator-side in
/// the node client ([`super::cluster::NodeLink`]), which consults the
/// plan before and after every exchange.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetFaultPlan {
    /// Kill a node: from this dealt-row count on, every exchange with
    /// `(node, from_rows)` fails as a dropped connection, permanently —
    /// the node only comes back if the harness explicitly restarts it.
    pub kill_node: Option<(usize, u64)>,
    /// Partition a node: exchanges with `(node, from_rows, for_rows)`
    /// fail while the dealt-row counter is in
    /// `[from_rows, from_rows + for_rows)`, then heal.
    pub partition: Option<(usize, u64, u64)>,
    /// Slow node: every exchange with `(node, delay_ms)` sleeps before
    /// reading the reply — the backoff/timeout path, not a failure.
    pub slow_node: Option<(usize, u64)>,
    /// Corrupt one reply: the exchange with `node` that first crosses
    /// `(node, at_rows)` has its reply bytes scrambled, forcing the
    /// client's parse-and-retry path.
    pub corrupt_reply: Option<(usize, u64)>,
}

impl NetFaultPlan {
    /// An empty plan (healthy network).
    pub fn none() -> Self {
        Self::default()
    }

    /// Derive a full network-fault schedule from a seed for a run of
    /// roughly `total_rows` dealt rows over `nodes` nodes: one node
    /// killed in the second half of the stream, a *different* node
    /// partitioned across the middle, a third slowed, and one corrupted
    /// reply early on. Deterministic in `(seed, total_rows, nodes)`.
    pub fn seeded(seed: u64, total_rows: u64, nodes: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0x9E7_F1E7);
        let n = nodes.max(1) as u64;
        let total = total_rows.max(8);
        let killed = rng.next_u64() % n;
        let partitioned = if n > 1 { (killed + 1 + rng.next_u64() % (n - 1)) % n } else { 0 };
        let slowed = (killed + partitioned + 1) % n.max(1);
        NetFaultPlan {
            kill_node: Some((killed as usize, total / 2 + rng.next_u64() % (total / 4).max(1))),
            partition: Some((
                partitioned as usize,
                total / 4 + rng.next_u64() % (total / 8).max(1),
                (total / 4).max(2),
            )),
            slow_node: Some((slowed as usize, 1 + rng.next_u64() % 5)),
            corrupt_reply: Some((partitioned as usize, 1 + rng.next_u64() % (total / 8).max(1))),
        }
    }

    /// Builder: kill `node` once `from_rows` rows have been dealt.
    pub fn with_kill(mut self, node: usize, from_rows: u64) -> Self {
        self.kill_node = Some((node, from_rows));
        self
    }

    /// Builder: partition `node` for `for_rows` dealt rows starting at
    /// `from_rows`.
    pub fn with_partition(mut self, node: usize, from_rows: u64, for_rows: u64) -> Self {
        self.partition = Some((node, from_rows, for_rows));
        self
    }

    /// Builder: delay every reply from `node` by `delay_ms`.
    pub fn with_slow(mut self, node: usize, delay_ms: u64) -> Self {
        self.slow_node = Some((node, delay_ms));
        self
    }

    /// Builder: corrupt the first reply from `node` at or after
    /// `at_rows` dealt rows.
    pub fn with_corrupt_reply(mut self, node: usize, at_rows: u64) -> Self {
        self.corrupt_reply = Some((node, at_rows));
        self
    }

    /// Whether an exchange with `node` at dealt-row count `rows` is cut
    /// off by the kill or partition schedule.
    pub fn link_cut(&self, node: usize, rows: u64) -> bool {
        if let Some((dead, from)) = self.kill_node {
            if node == dead && rows >= from {
                return true;
            }
        }
        if let Some((part, from, span)) = self.partition {
            if node == part && rows >= from && rows < from.saturating_add(span) {
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_deterministic_and_in_range() {
        let a = FaultPlan::seeded(42, 1000, 4);
        let b = FaultPlan::seeded(42, 1000, 4);
        assert_eq!(a, b);
        let c = FaultPlan::seeded(43, 1000, 4);
        assert_ne!(a, c, "different seeds must give different schedules");
        let p = a.worker_panic.unwrap();
        assert!(p.shard < 4);
        assert!(p.after_rows >= 1 && p.after_rows <= 125);
        let crash = a.crash_at_rows.unwrap();
        assert!((500..750).contains(&crash));
        assert!((20..60).contains(&a.stall_client_ms));
        assert!(a.tear_wal_on_crash);
    }

    #[test]
    fn seeded_net_plans_are_deterministic_and_spread_over_distinct_nodes() {
        let a = NetFaultPlan::seeded(42, 1000, 3);
        assert_eq!(a, NetFaultPlan::seeded(42, 1000, 3));
        assert_ne!(a, NetFaultPlan::seeded(43, 1000, 3));
        let (killed, kill_at) = a.kill_node.unwrap();
        let (partitioned, part_from, part_span) = a.partition.unwrap();
        assert!(killed < 3 && partitioned < 3);
        assert_ne!(killed, partitioned, "kill and partition must hit different nodes");
        assert!((500..750).contains(&kill_at));
        assert!(part_from >= 250 && part_span >= 2);
        // The schedule drives link_cut: killed stays cut, partition heals.
        assert!(a.link_cut(killed, kill_at));
        assert!(a.link_cut(killed, kill_at + 10_000), "kill is permanent");
        assert!(!a.link_cut(killed, kill_at - 1));
        assert!(a.link_cut(partitioned, part_from));
        assert!(!a.link_cut(partitioned, part_from + part_span), "partition heals");
    }

    #[test]
    fn net_plan_builders_compose() {
        let plan = NetFaultPlan::none()
            .with_kill(0, 100)
            .with_partition(1, 50, 25)
            .with_slow(2, 7)
            .with_corrupt_reply(1, 10);
        assert_eq!(plan.kill_node, Some((0, 100)));
        assert_eq!(plan.partition, Some((1, 50, 25)));
        assert_eq!(plan.slow_node, Some((2, 7)));
        assert_eq!(plan.corrupt_reply, Some((1, 10)));
        assert!(plan.link_cut(1, 60) && !plan.link_cut(1, 80));
        assert!(!plan.link_cut(2, 1_000_000));
    }

    #[test]
    fn builders_compose() {
        let plan = FaultPlan::none().with_worker_panic(2, 17).with_crash_at_rows(99, false);
        assert_eq!(plan.worker_panic, Some(WorkerPanic { shard: 2, after_rows: 17 }));
        assert_eq!(plan.crash_at_rows, Some(99));
        assert!(!plan.tear_wal_on_crash);
        assert!(is_injected_crash("pipeline dead: injected crash at row 99"));
        assert!(!is_injected_crash("shard worker terminated"));
    }
}

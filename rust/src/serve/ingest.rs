//! Sharded streaming-ingest pipeline: `S` long-lived shard workers, each
//! running an independent `partial_fit` stream on a shard estimator built
//! by the solver-agnostic factory (`AnyEstimator::new_shard` — BSGD or
//! BDCA, per `SolverSpec`), with a periodic snapshot → merge → publish
//! step into the [`ModelRegistry`].
//!
//! Determinism: rows are partitioned round-robin by their global stream
//! index, each shard consumes its sub-stream in presented order with a
//! fixed per-shard seed, publishes trigger at deterministic row counts,
//! and the merge folds shard reports in shard order — so a sharded run is
//! bit-identical run-to-run for any thread scheduling. Snapshot commands
//! ride the same per-shard channel as training batches, which (channel
//! FIFO order) guarantees a snapshot reflects every batch sent before it
//! without any extra barrier.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::data::Dataset;
use crate::model::AnyModel;
use crate::solver::{AnyEstimator, Estimator, RunConfig, SolverSpec, SvmConfig};
use crate::util::parallel::{spawn_worker, Worker};

use super::registry::ModelRegistry;

enum ShardCmd {
    /// One pre-partitioned training batch for this shard.
    Ingest(Dataset),
    /// Reply with (model clone, cumulative SGD steps), or `None` if the
    /// shard has not seen a row yet.
    Snapshot(mpsc::Sender<Option<(AnyModel, u64)>>),
}

/// Final accounting of a pipeline run (returned by
/// [`ShardedIngest::finish`]).
#[derive(Debug, Clone)]
pub struct IngestReport {
    /// Total rows ingested across all shards.
    pub rows: u64,
    /// Publish events executed (including the final flush).
    pub publishes: u64,
    /// Ingest-side stall of each publish, in seconds (shard drain +
    /// merge + registry swap; readers are never paused).
    pub publish_stalls: Vec<f64>,
    /// Version of the last published snapshot.
    pub last_version: u64,
    /// `publish_every` in effect after the last publish (equals the
    /// configured value unless adaptive cadence moved it).
    pub final_publish_every: usize,
    /// The cadence in effect at each publish (one entry per publish).
    pub cadence_history: Vec<usize>,
}

impl IngestReport {
    pub fn stall_mean_seconds(&self) -> f64 {
        if self.publish_stalls.is_empty() {
            0.0
        } else {
            self.publish_stalls.iter().sum::<f64>() / self.publish_stalls.len() as f64
        }
    }

    pub fn stall_max_seconds(&self) -> f64 {
        self.publish_stalls.iter().cloned().fold(0.0, f64::max)
    }
}

/// The streaming-ingest pipeline front: partitions labeled rows across
/// shard workers and publishes merged snapshots every `publish_every`
/// rows.
pub struct ShardedIngest {
    workers: Vec<Worker<ShardCmd>>,
    registry: Arc<ModelRegistry>,
    config: SvmConfig,
    publish_every: usize,
    /// The configured (non-adapted) cadence — the floor the adaptive
    /// controller relaxes back to when stalls are cheap.
    base_publish_every: usize,
    /// Stall-aware cadence adaptation (off by default: adapted cadences
    /// depend on wall-clock measurements, so runs stop being bit-identical
    /// run-to-run; publication content stays correct either way).
    adapt: bool,
    cadence_history: Vec<usize>,
    dim: usize,
    rows_total: u64,
    rows_since_publish: usize,
    publish_stalls: Vec<f64>,
    last_version: u64,
}

/// Publish stall (seconds) above which adaptive cadence doubles
/// `publish_every`; a recent mean below a quarter of this relaxes the
/// cadence back toward the configured base.
pub const ADAPT_STALL_THRESHOLD_SECONDS: f64 = 0.020;

/// Cap on how far adaptive cadence may stretch `publish_every` (×base).
const ADAPT_MAX_FACTOR: usize = 16;

/// Publishes averaged by the adaptive controller.
const ADAPT_WINDOW: usize = 4;

impl ShardedIngest {
    /// Build the pipeline with the default primal (BSGD) shard solver —
    /// a thin wrapper over [`ShardedIngest::with_solver`], kept so
    /// existing callers and their trained trajectories are untouched.
    pub fn new(
        config: SvmConfig,
        run: RunConfig,
        shards: usize,
        publish_every: usize,
        registry: Arc<ModelRegistry>,
    ) -> Result<Self> {
        Self::with_solver(SolverSpec::Bsgd, config, run, shards, publish_every, registry)
    }

    /// Build the pipeline: `shards` workers, each owning a shard
    /// estimator from the solver-agnostic factory
    /// ([`AnyEstimator::new_shard`]: deterministic per-shard seed, serial
    /// inside — BSGD and BDCA share the seed convention, so swapping
    /// solvers keeps shard decorrelation). Publishing merges into
    /// `registry` every `publish_every` ingested rows.
    pub fn with_solver(
        solver: SolverSpec,
        config: SvmConfig,
        run: RunConfig,
        shards: usize,
        publish_every: usize,
        registry: Arc<ModelRegistry>,
    ) -> Result<Self> {
        ensure!(shards >= 1, "need at least one shard, got {shards}");
        ensure!(publish_every >= 1, "publish_every must be at least 1");
        let mut workers = Vec::with_capacity(shards);
        for s in 0..shards {
            let mut est = AnyEstimator::new_shard(solver, config.clone(), run.clone(), s)?;
            workers.push(spawn_worker(&format!("ingest-shard-{s}"), move |cmd: ShardCmd| {
                match cmd {
                    ShardCmd::Ingest(ds) => {
                        if !ds.is_empty() {
                            est.partial_fit(&ds)
                                .expect("shard partial_fit failed (dimension mismatch?)");
                        }
                    }
                    ShardCmd::Snapshot(reply) => {
                        let _ = reply.send(est.snapshot());
                    }
                }
            }));
        }
        Ok(ShardedIngest {
            workers,
            registry,
            config,
            publish_every,
            base_publish_every: publish_every,
            adapt: false,
            cadence_history: Vec::new(),
            dim: 0,
            rows_total: 0,
            rows_since_publish: 0,
            publish_stalls: Vec::new(),
            last_version: 0,
        })
    }

    /// Enable/disable stall-aware adaptive publish cadence: when the mean
    /// of the last few publish stalls exceeds
    /// [`ADAPT_STALL_THRESHOLD_SECONDS`], `publish_every` doubles (capped
    /// at 16× the configured base) so the merge cost amortizes over more
    /// rows; when stalls drop well below the threshold it halves back
    /// toward the base, keeping served models fresh on an idle stream.
    pub fn with_adaptive_cadence(mut self, enabled: bool) -> Self {
        self.adapt = enabled;
        self
    }

    /// The cadence currently in effect.
    pub fn current_publish_every(&self) -> usize {
        self.publish_every
    }

    /// Number of shard workers.
    pub fn shards(&self) -> usize {
        self.workers.len()
    }

    /// Total rows ingested so far.
    pub fn rows_ingested(&self) -> u64 {
        self.rows_total
    }

    /// Ingest one batch of labeled rows: rows are dealt round-robin by
    /// global stream index to the shard workers (which train
    /// asynchronously); an automatic snapshot/publish runs whenever
    /// `publish_every` rows have accumulated since the last publish.
    pub fn ingest(&mut self, batch: &Dataset) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        if self.dim == 0 {
            self.dim = batch.dim();
        }
        ensure!(
            batch.dim() == self.dim,
            "batch dimension {} does not match the stream dimension {}",
            batch.dim(),
            self.dim
        );
        let s = self.workers.len();
        let mut parts: Vec<Dataset> =
            (0..s).map(|i| Dataset::empty(format!("shard-{i}"), self.dim)).collect();
        for i in 0..batch.len() {
            let shard = ((self.rows_total + i as u64) % s as u64) as usize;
            parts[shard].push_row(batch.row(i), batch.label(i));
        }
        for (worker, part) in self.workers.iter().zip(parts) {
            if !part.is_empty() {
                worker.send(ShardCmd::Ingest(part))?;
            }
        }
        self.rows_total += batch.len() as u64;
        self.rows_since_publish += batch.len();
        if self.rows_since_publish >= self.publish_every {
            self.publish_now()?;
        }
        Ok(())
    }

    /// Snapshot every shard, merge, and publish into the registry;
    /// returns the new version. The wait for shard queues to drain is
    /// part of the measured stall (readers keep serving the previous
    /// snapshot throughout).
    pub fn publish_now(&mut self) -> Result<u64> {
        ensure!(self.rows_total > 0, "cannot publish before any rows are ingested");
        let t0 = Instant::now();
        let mut pending = Vec::with_capacity(self.workers.len());
        for worker in &self.workers {
            let (tx, rx) = mpsc::channel();
            worker.send(ShardCmd::Snapshot(tx))?;
            pending.push(rx);
        }
        let mut models = Vec::new();
        let mut weights = Vec::new();
        for rx in pending {
            let snap = rx.recv().map_err(|_| anyhow!("shard worker terminated"))?;
            if let Some((model, steps)) = snap {
                models.push(model);
                weights.push(steps as f64);
            }
        }
        ensure!(!models.is_empty(), "no shard has trained a model yet");
        let merged = super::merge::merge_shard_models(
            models,
            &weights,
            self.config.budget,
            &self.config.maintenance(),
        )?;
        let version = self.registry.publish(merged);
        self.publish_stalls.push(t0.elapsed().as_secs_f64());
        self.cadence_history.push(self.publish_every);
        self.rows_since_publish = 0;
        self.last_version = version;
        if self.adapt {
            self.adapt_cadence();
        }
        Ok(version)
    }

    /// Stall-aware cadence controller (runs after each publish when
    /// enabled): see [`ShardedIngest::with_adaptive_cadence`].
    fn adapt_cadence(&mut self) {
        let n = self.publish_stalls.len();
        let recent = &self.publish_stalls[n.saturating_sub(ADAPT_WINDOW)..];
        let mean = recent.iter().sum::<f64>() / recent.len() as f64;
        if mean > ADAPT_STALL_THRESHOLD_SECONDS {
            self.publish_every =
                (self.publish_every * 2).min(self.base_publish_every * ADAPT_MAX_FACTOR);
        } else if mean < ADAPT_STALL_THRESHOLD_SECONDS / 4.0
            && self.publish_every > self.base_publish_every
        {
            self.publish_every = (self.publish_every / 2).max(self.base_publish_every);
        }
    }

    /// Drain everything, publish a final snapshot if rows arrived since
    /// the last one, join the shard workers, and return the accounting.
    pub fn finish(mut self) -> Result<IngestReport> {
        if self.rows_total > 0 && (self.rows_since_publish > 0 || self.last_version == 0) {
            self.publish_now()?;
        }
        for worker in self.workers.drain(..) {
            worker.join();
        }
        Ok(IngestReport {
            rows: self.rows_total,
            publishes: self.publish_stalls.len() as u64,
            publish_stalls: self.publish_stalls,
            last_version: self.last_version,
            final_publish_every: self.publish_every,
            cadence_history: self.cadence_history,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::kernel::KernelSpec;
    use crate::solver::BsgdEstimator;

    fn config_for(n: usize, budget: usize) -> SvmConfig {
        SvmConfig::new().kernel(KernelSpec::gaussian(2.0)).budget(budget).c(10.0, n)
    }

    fn run_pipeline(
        ds: &Dataset,
        shards: usize,
        publish_every: usize,
        chunk: usize,
    ) -> (Arc<ModelRegistry>, IngestReport) {
        let registry = Arc::new(ModelRegistry::new());
        let mut ing = ShardedIngest::new(
            config_for(ds.len(), 30),
            RunConfig::new().seed(11),
            shards,
            publish_every,
            Arc::clone(&registry),
        )
        .unwrap();
        let mut start = 0;
        while start < ds.len() {
            let idx: Vec<usize> = (start..(start + chunk).min(ds.len())).collect();
            ing.ingest(&ds.subset(&idx, "chunk")).unwrap();
            start += chunk;
        }
        let report = ing.finish().unwrap();
        (registry, report)
    }

    #[test]
    fn single_shard_pipeline_matches_serial_partial_fit() {
        let ds = two_moons(600, 0.12, 21);
        let (registry, report) = run_pipeline(&ds, 1, 10_000, 64);
        assert_eq!(report.rows, 600);
        assert_eq!(report.publishes, 1);
        let snap = registry.current().unwrap();

        let mut serial = BsgdEstimator::new_shard(
            config_for(ds.len(), 30),
            RunConfig::new().seed(11),
            0,
        )
        .unwrap();
        serial.partial_fit(&ds).unwrap();
        let model = serial.model().unwrap();
        // Same trajectory; the published snapshot only differs by the
        // folded scale, so decisions agree to f64 rounding.
        for i in (0..ds.len()).step_by(37) {
            let a = snap.model().decision(ds.row(i));
            let b = model.decision(ds.row(i));
            assert!((a - b).abs() <= 1e-12 * (1.0 + b.abs()), "row {i}: {a} vs {b}");
        }
        assert_eq!(snap.model().num_sv(), model.num_sv());
    }

    #[test]
    fn sharded_ingest_is_deterministic_run_to_run() {
        let ds = two_moons(500, 0.12, 33);
        let probes: Vec<usize> = vec![0, 17, 123, 250, 499];
        let (reg1, rep1) = run_pipeline(&ds, 4, 128, 50);
        let (reg2, rep2) = run_pipeline(&ds, 4, 128, 50);
        assert_eq!(rep1.publishes, rep2.publishes);
        assert!(rep1.publishes >= 3, "publish cadence should fire: {}", rep1.publishes);
        let (s1, s2) = (reg1.current().unwrap(), reg2.current().unwrap());
        assert_eq!(s1.model().num_sv(), s2.model().num_sv());
        for &i in &probes {
            assert_eq!(
                s1.model().decision(ds.row(i)).to_bits(),
                s2.model().decision(ds.row(i)).to_bits(),
                "row {i}"
            );
        }
    }

    #[test]
    fn sharded_accuracy_is_close_to_serial() {
        // Tolerance asserted here (recorded per the roadmap issue): the
        // 4-shard weighted-average publish must stay within 0.10 absolute
        // accuracy of the serial 1-shard pipeline on the same stream, and
        // both must actually learn the task.
        let ds = two_moons(1200, 0.1, 5);
        let (reg_serial, _) = run_pipeline(&ds, 1, 100_000, 128);
        let (reg_sharded, _) = run_pipeline(&ds, 4, 400, 128);
        let acc_serial = reg_serial.current().unwrap().model().accuracy(&ds);
        let acc_sharded = reg_sharded.current().unwrap().model().accuracy(&ds);
        assert!(acc_serial > 0.85, "serial accuracy {acc_serial}");
        assert!(acc_sharded > 0.82, "sharded accuracy {acc_sharded}");
        assert!(
            (acc_serial - acc_sharded).abs() <= 0.10,
            "serial {acc_serial} vs sharded {acc_sharded}"
        );
    }

    #[test]
    fn publish_respects_budget_and_counts_rows() {
        let ds = two_moons(400, 0.12, 8);
        let (registry, report) = run_pipeline(&ds, 3, 100, 64);
        assert_eq!(report.rows, 400);
        assert!(report.publishes >= 4);
        assert_eq!(report.last_version, registry.version());
        assert!(registry.current().unwrap().model().num_sv() <= 30);
        assert_eq!(report.publish_stalls.len() as u64, report.publishes);
        assert!(report.stall_max_seconds() >= report.stall_mean_seconds());
    }

    #[test]
    fn cadence_history_is_recorded_and_static_without_adapt() {
        let ds = two_moons(400, 0.12, 8);
        let (_registry, report) = run_pipeline(&ds, 2, 100, 64);
        assert_eq!(report.cadence_history.len() as u64, report.publishes);
        assert!(report.cadence_history.iter().all(|&c| c == 100));
        assert_eq!(report.final_publish_every, 100);
    }

    #[test]
    fn adaptive_cadence_moves_within_bounds() {
        // Wall-clock driven, so only the bounds are asserted: the cadence
        // never leaves [base, 16·base] and every publish records the
        // cadence in effect.
        let ds = two_moons(600, 0.12, 13);
        let registry = Arc::new(ModelRegistry::new());
        let base = 50;
        let mut ing = ShardedIngest::new(
            config_for(ds.len(), 30),
            RunConfig::new().seed(11),
            2,
            base,
            Arc::clone(&registry),
        )
        .unwrap()
        .with_adaptive_cadence(true);
        let mut start = 0;
        while start < ds.len() {
            let idx: Vec<usize> = (start..(start + 64).min(ds.len())).collect();
            ing.ingest(&ds.subset(&idx, "chunk")).unwrap();
            assert!(ing.current_publish_every() >= base);
            assert!(ing.current_publish_every() <= base * 16);
            start += 64;
        }
        let report = ing.finish().unwrap();
        assert_eq!(report.cadence_history.len() as u64, report.publishes);
        for &c in &report.cadence_history {
            assert!((base..=base * 16).contains(&c), "cadence {c}");
        }
        // The published model is still a valid budgeted model.
        assert!(registry.current().unwrap().model().num_sv() <= 30);
    }

    #[test]
    fn default_factory_is_the_bsgd_path_bit_for_bit() {
        let ds = two_moons(300, 0.12, 17);
        let run_with = |solver: Option<SolverSpec>| {
            let registry = Arc::new(ModelRegistry::new());
            let mut ing = match solver {
                Some(spec) => ShardedIngest::with_solver(
                    spec,
                    config_for(ds.len(), 30),
                    RunConfig::new().seed(11),
                    3,
                    120,
                    Arc::clone(&registry),
                ),
                None => ShardedIngest::new(
                    config_for(ds.len(), 30),
                    RunConfig::new().seed(11),
                    3,
                    120,
                    Arc::clone(&registry),
                ),
            }
            .unwrap();
            ing.ingest(&ds).unwrap();
            ing.finish().unwrap();
            registry
        };
        let via_new = run_with(None);
        let via_factory = run_with(Some(SolverSpec::Bsgd));
        let (a, b) = (via_new.current().unwrap(), via_factory.current().unwrap());
        assert_eq!(a.model().num_sv(), b.model().num_sv());
        for i in (0..ds.len()).step_by(29) {
            assert_eq!(
                a.model().decision(ds.row(i)).to_bits(),
                b.model().decision(ds.row(i)).to_bits(),
                "row {i}"
            );
        }
    }

    #[test]
    fn dual_solver_shards_train_and_publish_deterministically() {
        let ds = two_moons(500, 0.12, 19);
        let run_once = || {
            let registry = Arc::new(ModelRegistry::new());
            let mut ing = ShardedIngest::with_solver(
                SolverSpec::Bdca,
                config_for(ds.len(), 30),
                RunConfig::new().seed(11),
                4,
                150,
                Arc::clone(&registry),
            )
            .unwrap();
            let mut start = 0;
            while start < ds.len() {
                let idx: Vec<usize> = (start..(start + 64).min(ds.len())).collect();
                ing.ingest(&ds.subset(&idx, "chunk")).unwrap();
                start += 64;
            }
            let report = ing.finish().unwrap();
            (registry, report)
        };
        let (reg1, rep1) = run_once();
        let (reg2, rep2) = run_once();
        assert_eq!(rep1.rows, 500);
        assert_eq!(rep1.publishes, rep2.publishes);
        let (s1, s2) = (reg1.current().unwrap(), reg2.current().unwrap());
        assert!(s1.model().num_sv() <= 30, "budget violated");
        assert_eq!(s1.model().num_sv(), s2.model().num_sv());
        for &i in &[0usize, 17, 123, 250, 499] {
            assert_eq!(
                s1.model().decision(ds.row(i)).to_bits(),
                s2.model().decision(ds.row(i)).to_bits(),
                "row {i}"
            );
        }
        // And the merged dual model still learns the task.
        assert!(s1.model().accuracy(&ds) > 0.8);
    }

    #[test]
    fn empty_and_mismatched_batches_are_handled() {
        let registry = Arc::new(ModelRegistry::new());
        let mut ing = ShardedIngest::new(
            config_for(100, 10),
            RunConfig::new(),
            2,
            1000,
            Arc::clone(&registry),
        )
        .unwrap();
        // Publishing before any rows is an error.
        assert!(ing.publish_now().is_err());
        ing.ingest(&Dataset::empty("none", 2)).unwrap();
        assert_eq!(ing.rows_ingested(), 0);
        let ds = two_moons(50, 0.1, 1);
        ing.ingest(&ds).unwrap();
        // Dimension is pinned by the first non-empty batch.
        let bad = Dataset::new("bad", vec![0.0; 9], vec![1.0, 1.0, -1.0], 3);
        assert!(ing.ingest(&bad).is_err());
        let report = ing.finish().unwrap();
        assert_eq!(report.rows, 50);
        assert_eq!(registry.version(), report.last_version);
    }
}

//! Sharded streaming-ingest pipeline: `S` long-lived shard workers, each
//! running an independent `partial_fit` stream on a shard estimator built
//! by the solver-agnostic factory (`AnyEstimator::new_shard` — BSGD or
//! BDCA, per `SolverSpec`), with a periodic snapshot → merge → publish
//! step into the [`ModelRegistry`].
//!
//! Determinism: rows are partitioned round-robin by their global stream
//! index, each shard consumes its sub-stream in presented order with a
//! fixed per-shard seed, publishes trigger at deterministic row counts,
//! and the merge folds shard reports in shard order — so a sharded run is
//! bit-identical run-to-run for any thread scheduling. Snapshot commands
//! ride the same per-shard channel as training batches, which (channel
//! FIFO order) guarantees a snapshot reflects every batch sent before it
//! without any extra barrier.
//!
//! ## Failure domain
//!
//! * **Supervised workers** — a shard worker panic is caught
//!   ([`std::panic::catch_unwind`] around the training step); the worker
//!   marks itself poisoned and keeps draining (and dropping) its queue so
//!   nothing deadlocks. The front end heals the shard on the next ingest
//!   or publish: a fresh estimator with the shard's original
//!   deterministic seed is installed, and the shard's rows are re-fed —
//!   from the WAL (full sub-stream, bit-exact trajectory) when one is
//!   attached, or from the unacknowledged in-flight batches otherwise
//!   (no row silently dropped, trajectory approximate).
//! * **Admission control** — dispatched-but-unprocessed rows are counted;
//!   past `shed` the pipeline defers cadence publishes (multi-merge
//!   slack as load shedding), past `max` it rejects train batches with a
//!   typed `overloaded` error. A publish-stall EWMA feeds the same
//!   ladder. See [`ShardedIngest::admission_state`].
//! * **Durability** — with a WAL attached, a batch is appended and synced
//!   *before* it is dispatched; acknowledged rows therefore survive any
//!   crash and [`ShardedIngest::recover`] replays them into a state
//!   byte-identical to an uninterrupted run (see `serve::wal`).
//! * **WAL rotation** (opt-in, [`ShardedIngest::enable_wal_rotation`]) —
//!   the WAL is truncated under every durable checkpoint: the
//!   checkpointed model becomes the *generation base* (merged into every
//!   publish with weight = the rows it covers) and the lanes restart on
//!   generation-derived seeds, so WAL size and replay cost stay bounded
//!   by one publish cadence instead of the full stream history.
//!   Recovery of a rotated run is byte-identical to the same rotated run
//!   left uninterrupted.
//! * **Fault injection** — [`ShardedIngest::fault_inject`] installs a
//!   deterministic [`FaultPlan`] (worker panic at a row count, simulated
//!   crash between WAL append and checkpoint); production entry points
//!   never install one.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use crate::data::Dataset;
use crate::model::AnyModel;
use crate::solver::{AnyEstimator, Estimator, RunConfig, SolverSpec, SvmConfig};
use crate::telemetry::{self, Counter, Gauge, Stage};
use crate::util::json::Json;
use crate::util::parallel::{spawn_worker, Worker};

use super::faults::{FaultPlan, INJECTED_CRASH_MARKER};
use super::registry::{ModelRegistry, ShadowPolicy};
use super::wal::{self, WalWriter};

enum ShardCmd {
    /// One pre-partitioned training batch for this shard, tagged with a
    /// per-shard dispatch sequence number (acknowledged on success).
    Ingest { seq: u64, ds: Dataset },
    /// Reply with the shard's training snapshot, or
    /// [`ShardSnap::Poisoned`] if the worker has died.
    Snapshot(mpsc::Sender<ShardSnap>),
    /// Replace the shard estimator (heal after a poisoning) and clear the
    /// poisoned state.
    Reset(Box<AnyEstimator>),
    /// Fault injection: panic once the cumulative processed row count
    /// would reach the given value.
    ArmPanic(u64),
}

enum ShardSnap {
    /// (model clone, cumulative SGD steps), or `None` if the shard has
    /// not seen a row yet.
    Ready(Option<(AnyModel, u64)>),
    /// The worker panicked and is dropping batches until a reset.
    Poisoned,
}

/// One supervised shard lane: the worker plus the front-end bookkeeping
/// needed to heal it (ack stream and unacknowledged in-flight batches).
struct ShardChannel {
    worker: Worker<ShardCmd>,
    /// Set by the worker when it poisons itself; cleared by the healer.
    poisoned: Arc<AtomicBool>,
    /// Successful-batch acknowledgements (dispatch sequence numbers).
    acks: mpsc::Receiver<u64>,
    /// Last dispatch sequence number handed out.
    next_seq: u64,
    /// Dispatched batches not yet acknowledged, oldest first.
    inflight: VecDeque<(u64, Dataset)>,
}

/// Admission decision for an incoming train batch (the degradation
/// ladder: healthy → shed maintenance → reject).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Queue healthy: train and publish normally.
    Accept,
    /// Under pressure: train, but defer cadence publishes (multi-merge
    /// slack absorbs the deferred maintenance).
    ShedMaintenance,
    /// Queue at capacity: reject the batch with a typed `overloaded`
    /// error; the caller should retry later.
    RejectTrain,
}

impl Admission {
    /// Stable wire name (used by the protocol `stats` verb).
    pub fn as_str(&self) -> &'static str {
        match self {
            Admission::Accept => "accept",
            Admission::ShedMaintenance => "shed-maintenance",
            Admission::RejectTrain => "reject-train",
        }
    }
}

/// Point-in-time health of the pipeline (surfaced over `stats`).
#[derive(Debug, Clone)]
pub struct IngestHealth {
    /// Rows dispatched to shard workers and not yet processed.
    pub pending_rows: u64,
    /// The admission decision the next train batch would receive.
    pub admission: Admission,
    /// Shard workers healed after a panic.
    pub worker_restarts: u64,
    /// Rows re-fed to healed shards.
    pub rows_requeued: u64,
    /// Rows rejected by admission control.
    pub rejected_rows: u64,
    /// Cadence publishes deferred under shed-maintenance.
    pub deferred_publishes: u64,
    /// Exponentially-weighted mean of recent publish stalls, seconds.
    pub stall_ewma_seconds: f64,
    /// Rows durably framed in the WAL (0 when no WAL is attached).
    pub wal_rows: u64,
}

/// What [`ShardedIngest::recover`] reconstructed.
#[derive(Debug, Clone)]
pub struct RecoveryReport {
    /// Acknowledged rows replayed from the WAL.
    pub wal_rows: u64,
    /// Whether a torn tail (crash mid-append) was truncated away.
    pub torn_tail_dropped: bool,
    /// Rows the checkpoint covered (0 if no checkpoint was found).
    pub checkpoint_rows: u64,
    /// Registry version pinned by the checkpoint (0 if none).
    pub checkpoint_version: u64,
    /// Wall-clock time of the whole recovery, seconds.
    pub recovery_seconds: f64,
}

/// Final accounting of a pipeline run (returned by
/// [`ShardedIngest::finish`]).
#[derive(Debug, Clone)]
pub struct IngestReport {
    /// Total rows ingested across all shards.
    pub rows: u64,
    /// Publish events executed (including the final flush).
    pub publishes: u64,
    /// Ingest-side stall of each publish, in seconds (shard drain +
    /// merge + registry swap; readers are never paused).
    pub publish_stalls: Vec<f64>,
    /// Version of the last published snapshot.
    pub last_version: u64,
    /// `publish_every` in effect after the last publish (equals the
    /// configured value unless adaptive cadence moved it).
    pub final_publish_every: usize,
    /// The cadence in effect at each publish (one entry per publish).
    pub cadence_history: Vec<usize>,
    /// Shard workers healed after a panic.
    pub worker_restarts: u64,
    /// Rows re-fed to healed shards.
    pub rows_requeued: u64,
    /// Rows rejected by admission control.
    pub rejected_rows: u64,
    /// Cadence publishes deferred under shed-maintenance.
    pub deferred_publishes: u64,
}

impl IngestReport {
    pub fn stall_mean_seconds(&self) -> f64 {
        if self.publish_stalls.is_empty() {
            0.0
        } else {
            self.publish_stalls.iter().sum::<f64>() / self.publish_stalls.len() as f64
        }
    }

    pub fn stall_max_seconds(&self) -> f64 {
        self.publish_stalls.iter().cloned().fold(0.0, f64::max)
    }
}

/// The streaming-ingest pipeline front: partitions labeled rows across
/// shard workers and publishes merged snapshots every `publish_every`
/// rows.
pub struct ShardedIngest {
    lanes: Vec<ShardChannel>,
    registry: Arc<ModelRegistry>,
    solver: SolverSpec,
    config: SvmConfig,
    run: RunConfig,
    publish_every: usize,
    /// The configured (non-adapted) cadence — the floor the adaptive
    /// controller relaxes back to when stalls are cheap.
    base_publish_every: usize,
    /// Stall-aware cadence adaptation (off by default: adapted cadences
    /// depend on wall-clock measurements, so runs stop being bit-identical
    /// run-to-run; publication content stays correct either way).
    adapt: bool,
    cadence_history: Vec<usize>,
    dim: usize,
    rows_total: u64,
    rows_since_publish: usize,
    publish_stalls: Vec<f64>,
    last_version: u64,
    /// Rows dispatched to shard workers and not yet processed (the
    /// workers decrement as they drain, so this is the live queue depth).
    pending_rows: Arc<AtomicU64>,
    /// Queue depth at which train batches are rejected.
    max_pending_rows: usize,
    /// Queue depth at which cadence publishes are deferred.
    shed_pending_rows: usize,
    stall_ewma: f64,
    shedding: bool,
    deferred_publishes: u64,
    /// Lazily created once the stream dimension is pinned.
    wal_path: Option<PathBuf>,
    wal: Option<WalWriter>,
    checkpoint_path: Option<PathBuf>,
    /// Rotate the WAL under every durable checkpoint (opt-in; see
    /// [`ShardedIngest::enable_wal_rotation`]). Off by default so the
    /// single-WAL full-replay lineage keeps its exact contract.
    rotate_wal: bool,
    /// Generation base: the last durable checkpoint's model and the rows
    /// it covers. Present only in rotation mode after the first
    /// rotation; merged into every publish with weight `rows`.
    base_model: Option<(AnyModel, u64)>,
    faults: Option<FaultPlan>,
    /// Terminal failure (injected crash): every later call bails.
    failed: Option<String>,
    restarts: u64,
    rows_requeued: u64,
    rejected_rows: u64,
    shadow: Option<ShadowPolicy>,
    shadow_rejects: u64,
    /// Previous admission decision — emits an `admission_transition`
    /// event whenever the ladder moves.
    last_admission: Admission,
}

/// Publish stall (seconds) above which adaptive cadence doubles
/// `publish_every`; a recent mean below a quarter of this relaxes the
/// cadence back toward the configured base.
pub const ADAPT_STALL_THRESHOLD_SECONDS: f64 = 0.020;

/// Cap on how far adaptive cadence may stretch `publish_every` (×base).
const ADAPT_MAX_FACTOR: usize = 16;

/// Publishes averaged by the adaptive controller.
const ADAPT_WINDOW: usize = 4;

/// Weight of the newest publish stall in the admission EWMA.
const EWMA_ALPHA: f64 = 0.2;

/// Publish-stall EWMA (seconds) above which admission sheds maintenance
/// even when the queue itself is shallow.
pub const SHED_STALL_EWMA_SECONDS: f64 = 0.050;

impl ShardedIngest {
    /// Build the pipeline with the default primal (BSGD) shard solver —
    /// a thin wrapper over [`ShardedIngest::with_solver`], kept so
    /// existing callers and their trained trajectories are untouched.
    pub fn new(
        config: SvmConfig,
        run: RunConfig,
        shards: usize,
        publish_every: usize,
        registry: Arc<ModelRegistry>,
    ) -> Result<Self> {
        Self::with_solver(SolverSpec::Bsgd, config, run, shards, publish_every, registry)
    }

    /// Build the pipeline: `shards` workers, each owning a shard
    /// estimator from the solver-agnostic factory
    /// ([`AnyEstimator::new_shard`]: deterministic per-shard seed, serial
    /// inside — BSGD and BDCA share the seed convention, so swapping
    /// solvers keeps shard decorrelation). Publishing merges into
    /// `registry` every `publish_every` ingested rows.
    pub fn with_solver(
        solver: SolverSpec,
        config: SvmConfig,
        run: RunConfig,
        shards: usize,
        publish_every: usize,
        registry: Arc<ModelRegistry>,
    ) -> Result<Self> {
        ensure!(shards >= 1, "need at least one shard, got {shards}");
        ensure!(publish_every >= 1, "publish_every must be at least 1");
        let pending_rows = Arc::new(AtomicU64::new(0));
        let mut lanes = Vec::with_capacity(shards);
        for s in 0..shards {
            let est = AnyEstimator::new_shard(solver, config.clone(), run.clone(), s)?;
            lanes.push(Self::spawn_lane(s, est, &pending_rows));
        }
        Ok(ShardedIngest {
            lanes,
            registry,
            solver,
            config,
            run,
            publish_every,
            base_publish_every: publish_every,
            adapt: false,
            cadence_history: Vec::new(),
            dim: 0,
            rows_total: 0,
            rows_since_publish: 0,
            publish_stalls: Vec::new(),
            last_version: 0,
            pending_rows,
            max_pending_rows: usize::MAX,
            shed_pending_rows: usize::MAX,
            stall_ewma: 0.0,
            shedding: false,
            deferred_publishes: 0,
            wal_path: None,
            wal: None,
            checkpoint_path: None,
            rotate_wal: false,
            base_model: None,
            faults: None,
            failed: None,
            restarts: 0,
            rows_requeued: 0,
            rejected_rows: 0,
            shadow: None,
            shadow_rejects: 0,
            last_admission: Admission::Accept,
        })
    }

    /// Spawn one supervised shard worker: training panics are caught, the
    /// worker poisons itself and keeps draining (dropping batches, still
    /// decrementing the queue counter) until the front end resets it.
    fn spawn_lane(s: usize, est: AnyEstimator, pending: &Arc<AtomicU64>) -> ShardChannel {
        let poisoned = Arc::new(AtomicBool::new(false));
        let (ack_tx, acks) = mpsc::channel::<u64>();
        let flag = Arc::clone(&poisoned);
        let pending = Arc::clone(pending);
        let mut est = est;
        let mut dead = false;
        let mut rows_done: u64 = 0;
        let mut armed_panic: Option<u64> = None;
        let worker = spawn_worker(&format!("ingest-shard-{s}"), move |cmd: ShardCmd| match cmd {
            ShardCmd::Ingest { seq, ds } => {
                let n = ds.len() as u64;
                if !dead && n > 0 {
                    let fire = armed_panic.map_or(false, |at| rows_done + n >= at);
                    if fire {
                        armed_panic = None; // one-shot: disarm before firing
                    }
                    let outcome = catch_unwind(AssertUnwindSafe(|| {
                        if fire {
                            panic!("injected shard-worker panic (fault plan)");
                        }
                        est.partial_fit(&ds)
                    }));
                    match outcome {
                        Ok(Ok(())) => {
                            rows_done += n;
                            let _ = ack_tx.send(seq);
                        }
                        // Training error or panic: poison, drop the
                        // batch (it stays unacknowledged in-flight on
                        // the front end and will be re-fed at heal).
                        Ok(Err(_)) | Err(_) => {
                            dead = true;
                            flag.store(true, Ordering::SeqCst);
                        }
                    }
                }
                pending.fetch_sub(n, Ordering::SeqCst);
            }
            ShardCmd::Snapshot(reply) => {
                let snap =
                    if dead { ShardSnap::Poisoned } else { ShardSnap::Ready(est.snapshot()) };
                let _ = reply.send(snap);
            }
            ShardCmd::Reset(fresh) => {
                est = *fresh;
                dead = false;
                rows_done = 0;
                flag.store(false, Ordering::SeqCst);
            }
            ShardCmd::ArmPanic(at) => {
                armed_panic = Some(at);
            }
        });
        ShardChannel { worker, poisoned, acks, next_seq: 0, inflight: VecDeque::new() }
    }

    /// Enable/disable stall-aware adaptive publish cadence: when the mean
    /// of the last few publish stalls exceeds
    /// [`ADAPT_STALL_THRESHOLD_SECONDS`], `publish_every` doubles (capped
    /// at 16× the configured base) so the merge cost amortizes over more
    /// rows; when stalls drop well below the threshold it halves back
    /// toward the base, keeping served models fresh on an idle stream.
    pub fn with_adaptive_cadence(mut self, enabled: bool) -> Self {
        self.adapt = enabled;
        self
    }

    /// Bound the ingest queue: at `shed_pending_rows` dispatched-but-
    /// unprocessed rows cadence publishes are deferred, at
    /// `max_pending_rows` train batches are rejected with a typed
    /// `overloaded` error. Defaults are unbounded (no admission control).
    pub fn with_admission(mut self, max_pending_rows: usize, shed_pending_rows: usize) -> Self {
        self.max_pending_rows = max_pending_rows.max(1);
        self.shed_pending_rows = shed_pending_rows.clamp(1, self.max_pending_rows);
        self
    }

    /// Gate every publish through the registry's shadow evaluation with
    /// this policy (see [`ModelRegistry::publish_shadowed`]).
    pub fn with_shadow_policy(mut self, policy: ShadowPolicy) -> Self {
        self.shadow = Some(policy);
        self
    }

    /// Arm crash-safe persistence: a WAL is created at `path` as soon as
    /// the stream dimension is pinned (first non-empty batch), and every
    /// batch is framed + synced there **before** dispatch — the
    /// acknowledgement point.
    pub fn enable_wal(&mut self, path: impl Into<PathBuf>) -> Result<()> {
        ensure!(self.rows_total == 0, "cannot enable a WAL after rows were ingested without one");
        self.wal_path = Some(path.into());
        Ok(())
    }

    /// Adopt an already-positioned WAL writer (the recovery path). The
    /// writer's row count must equal the rows this pipeline has ingested:
    /// the WAL position doubles as the global row index that round-robin
    /// partitioning (and therefore shard healing) keys off.
    pub fn attach_wal(&mut self, wal: WalWriter) -> Result<()> {
        ensure!(
            wal.rows() == self.rows_total,
            "WAL holds {} rows but the pipeline has ingested {}",
            wal.rows(),
            self.rows_total
        );
        if self.dim == 0 {
            self.dim = wal.dim();
        }
        ensure!(
            wal.dim() == self.dim,
            "WAL dimension {} does not match the stream dimension {}",
            wal.dim(),
            self.dim
        );
        self.wal_path = None;
        self.wal = Some(wal);
        Ok(())
    }

    /// Write a checkpoint (incumbent model + version + rows covered) at
    /// `path` after every publish, atomically (tmp + rename).
    pub fn checkpoint_at(&mut self, path: impl Into<PathBuf>) {
        self.checkpoint_path = Some(path.into());
    }

    /// Opt in to WAL rotation: after every durable checkpoint the WAL is
    /// rotated to an empty generation based at the checkpointed row
    /// count, the checkpointed model becomes the generation base merged
    /// into every later publish (weight = rows it covers), and the lanes
    /// restart on generation-derived seeds. Effective only when a
    /// checkpoint path is set — rotation is anchored to the durable
    /// checkpoint, never ahead of it. Off by default: rotation bounds
    /// WAL growth and replay cost but makes the trained lineage
    /// "base + current generation" instead of "all rows through the
    /// lanes", a distinct (still fully deterministic) trajectory.
    pub fn enable_wal_rotation(&mut self) {
        self.rotate_wal = true;
    }

    /// Deterministic per-generation run configuration: generation 0 is
    /// the configured run verbatim, later generations mix the durable
    /// base row count (recorded in both the WAL v2 header and the
    /// checkpoint) into the seed — so recovery, healing, and an
    /// uninterrupted run all derive identical lane streams from disk
    /// state alone.
    fn generation_run(run: &RunConfig, base: u64) -> RunConfig {
        if base == 0 {
            run.clone()
        } else {
            run.clone().seed(run.seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(base))
        }
    }

    /// Install fresh generation-seeded estimators in every lane and clear
    /// the in-flight bookkeeping. Callers guarantee the lanes are drained
    /// (the publish snapshot loop is a per-lane barrier).
    fn reset_lanes_for_generation(&mut self, base: u64) -> Result<()> {
        for s in 0..self.lanes.len() {
            let fresh = AnyEstimator::new_shard(
                self.solver,
                self.config.clone(),
                Self::generation_run(&self.run, base),
                s,
            )?;
            let lane = &mut self.lanes[s];
            while lane.acks.try_recv().is_ok() {}
            lane.inflight.clear();
            lane.poisoned.store(false, Ordering::SeqCst);
            lane.worker.send(ShardCmd::Reset(Box::new(fresh)))?;
        }
        Ok(())
    }

    /// Recovery hook: adopt a checkpointed model as the generation base
    /// covering `rows` rows, before any WAL-tail rows are replayed.
    fn install_base(&mut self, model: AnyModel, rows: u64) -> Result<()> {
        ensure!(self.rows_total == 0, "generation base must be installed before any ingest");
        if self.dim == 0 {
            self.dim = model.dim();
        }
        ensure!(
            model.dim() == self.dim,
            "checkpoint dimension {} does not match the stream dimension {}",
            model.dim(),
            self.dim
        );
        self.rows_total = rows;
        self.base_model = Some((model, rows));
        self.reset_lanes_for_generation(rows)
    }

    /// Start a new WAL generation under the checkpoint that was just
    /// written: rotate the WAL to an empty segment based at the current
    /// row count, adopt the just-published model as the new generation
    /// base, and reseed the lanes. A no-op when the WAL is already based
    /// here (empty generation), which makes recovery idempotent.
    fn start_generation(&mut self) -> Result<()> {
        let rows = self.rows_total;
        match self.wal.as_mut() {
            Some(wal) if wal.base_rows() != rows => wal.rotate(rows)?,
            _ => return Ok(()),
        }
        let snap = self
            .registry
            .current()
            .ok_or_else(|| anyhow!("cannot rotate the WAL without a published model"))?;
        self.base_model = Some((snap.model().clone(), rows));
        self.reset_lanes_for_generation(rows)
    }

    /// Install a deterministic fault schedule (test/bench hook; see
    /// [`FaultPlan`]). Production entry points never call this.
    pub fn fault_inject(&mut self, plan: FaultPlan) -> Result<()> {
        if let Some(p) = plan.worker_panic {
            ensure!(
                p.shard < self.lanes.len(),
                "fault plan targets shard {} but the pipeline has {}",
                p.shard,
                self.lanes.len()
            );
            self.lanes[p.shard].worker.send(ShardCmd::ArmPanic(p.after_rows))?;
        }
        self.faults = Some(plan);
        Ok(())
    }

    /// The cadence currently in effect.
    pub fn current_publish_every(&self) -> usize {
        self.publish_every
    }

    /// Number of shard workers.
    pub fn shards(&self) -> usize {
        self.lanes.len()
    }

    /// Total rows ingested so far.
    pub fn rows_ingested(&self) -> u64 {
        self.rows_total
    }

    /// The admission decision the next train batch would receive.
    pub fn admission_state(&self) -> Admission {
        let pending = self.pending_rows.load(Ordering::SeqCst);
        if pending >= self.max_pending_rows as u64 {
            Admission::RejectTrain
        } else if pending >= self.shed_pending_rows as u64
            || self.stall_ewma > SHED_STALL_EWMA_SECONDS
        {
            Admission::ShedMaintenance
        } else {
            Admission::Accept
        }
    }

    /// Point-in-time pipeline health (for the protocol `stats` verb).
    pub fn health(&self) -> IngestHealth {
        IngestHealth {
            pending_rows: self.pending_rows.load(Ordering::SeqCst),
            admission: self.admission_state(),
            worker_restarts: self.restarts,
            rows_requeued: self.rows_requeued,
            rejected_rows: self.rejected_rows,
            deferred_publishes: self.deferred_publishes,
            stall_ewma_seconds: self.stall_ewma,
            wal_rows: self.wal.as_ref().map_or(0, |w| w.rows()),
        }
    }

    fn fail_check(&self) -> Result<()> {
        if let Some(msg) = &self.failed {
            bail!("pipeline dead: {msg}");
        }
        Ok(())
    }

    /// Ingest one batch of labeled rows: rows are dealt round-robin by
    /// global stream index to the shard workers (which train
    /// asynchronously); an automatic snapshot/publish runs whenever
    /// `publish_every` rows have accumulated since the last publish
    /// (deferred under shed-maintenance admission). With a WAL attached
    /// the batch is durably framed **before** dispatch; an `Ok` return
    /// is the acknowledgement.
    pub fn ingest(&mut self, batch: &Dataset) -> Result<()> {
        self.fail_check()?;
        if batch.is_empty() {
            return Ok(());
        }
        if self.dim == 0 {
            self.dim = batch.dim();
        }
        ensure!(
            batch.dim() == self.dim,
            "batch dimension {} does not match the stream dimension {}",
            batch.dim(),
            self.dim
        );
        self.heal_poisoned()?;
        self.drain_acks();
        let n = batch.len();
        let decision = {
            let _admit = telemetry::stage_span(Stage::AdmissionDecide);
            self.admission_state()
        };
        if decision != self.last_admission {
            let (from, to) = (self.last_admission.as_str(), decision.as_str());
            let pending = self.pending_rows.load(Ordering::SeqCst);
            telemetry::emit("admission_transition", || {
                vec![
                    ("from", Json::str(from)),
                    ("to", Json::str(to)),
                    ("pending_rows", Json::num(pending as f64)),
                ]
            });
            self.last_admission = decision;
        }
        telemetry::registry::gauge_set(
            Gauge::QueueDepth,
            self.pending_rows.load(Ordering::SeqCst),
        );
        match decision {
            Admission::RejectTrain => {
                telemetry::registry::count(Counter::AdmissionReject);
                self.rejected_rows += n as u64;
                let pending = self.pending_rows.load(Ordering::SeqCst);
                bail!("overloaded: ingest queue at capacity ({pending} rows pending)");
            }
            Admission::ShedMaintenance => {
                telemetry::registry::count(Counter::AdmissionShed);
                self.shedding = true;
            }
            Admission::Accept => {
                telemetry::registry::count(Counter::AdmissionAccept);
                self.shedding = false;
            }
        }
        if self.wal.is_none() {
            if let Some(path) = self.wal_path.take() {
                self.wal = Some(WalWriter::create(&path, self.dim)?);
            }
        }
        // Durability point: once the append returns, the batch is acked.
        if let Some(wal) = self.wal.as_mut() {
            wal.append_rows(batch)?;
        }
        // Scheduled crash between WAL append and dispatch/checkpoint: the
        // batch is acked-durable but never trained; recovery must replay
        // it. Terminal — the pipeline refuses all further work.
        if let Some(plan) = self.faults {
            if let Some(at) = plan.crash_at_rows {
                if self.rows_total < at && at <= self.rows_total + n as u64 {
                    if plan.tear_wal_on_crash {
                        if let Some(wal) = self.wal.as_mut() {
                            wal.inject_torn_frame()?;
                        }
                    }
                    let msg = format!(
                        "{INJECTED_CRASH_MARKER} at row {at} (between WAL append and checkpoint)"
                    );
                    self.failed = Some(msg.clone());
                    bail!("pipeline dead: {msg}");
                }
            }
        }
        self.dispatch(batch)?;
        telemetry::registry::gauge_set(
            Gauge::QueueDepth,
            self.pending_rows.load(Ordering::SeqCst),
        );
        self.rows_total += n as u64;
        self.rows_since_publish += n;
        if self.rows_since_publish >= self.publish_every {
            if self.shedding {
                self.deferred_publishes += 1;
                telemetry::registry::count(Counter::DeferredPublishes);
            } else {
                self.publish_now()?;
            }
        }
        Ok(())
    }

    /// Partition `batch` round-robin by global row index and send each
    /// non-empty part to its shard, tracking it in-flight until acked.
    fn dispatch(&mut self, batch: &Dataset) -> Result<()> {
        let s = self.lanes.len();
        let mut parts: Vec<Dataset> =
            (0..s).map(|i| Dataset::empty(format!("shard-{i}"), self.dim)).collect();
        for i in 0..batch.len() {
            let shard = ((self.rows_total + i as u64) % s as u64) as usize;
            parts[shard].push_row(batch.row(i), batch.label(i));
        }
        for (lane, part) in self.lanes.iter_mut().zip(parts) {
            if !part.is_empty() {
                Self::dispatch_part(&self.pending_rows, lane, part)?;
            }
        }
        Ok(())
    }

    fn dispatch_part(pending: &Arc<AtomicU64>, lane: &mut ShardChannel, part: Dataset) -> Result<()> {
        lane.next_seq += 1;
        let seq = lane.next_seq;
        pending.fetch_add(part.len() as u64, Ordering::SeqCst);
        lane.inflight.push_back((seq, part.clone()));
        lane.worker.send(ShardCmd::Ingest { seq, ds: part })?;
        Ok(())
    }

    /// Drop acknowledged batches from the in-flight queues.
    fn drain_acks(&mut self) {
        for lane in &mut self.lanes {
            while let Ok(seq) = lane.acks.try_recv() {
                while lane.inflight.front().map_or(false, |(q, _)| *q <= seq) {
                    lane.inflight.pop_front();
                }
            }
        }
    }

    /// Heal every poisoned shard: install a fresh estimator with the
    /// shard's original deterministic seed, then re-feed its rows — the
    /// full WAL sub-stream when a WAL is attached (the healed shard
    /// retraces the exact trajectory, bit for bit), or the
    /// unacknowledged in-flight batches otherwise (no acked-into-the-
    /// pipeline row is dropped, but the shard restarts from scratch so
    /// its trajectory is approximate).
    fn heal_poisoned(&mut self) -> Result<()> {
        for s in 0..self.lanes.len() {
            if !self.lanes[s].poisoned.load(Ordering::SeqCst) {
                continue;
            }
            self.restarts += 1;
            telemetry::registry::count(Counter::WorkerRestarts);
            telemetry::emit("worker_restart", || {
                vec![("shard", Json::num(s as f64))]
            });
            let base = self.base_model.as_ref().map_or(0, |(_, rows)| *rows);
            let fresh = AnyEstimator::new_shard(
                self.solver,
                self.config.clone(),
                Self::generation_run(&self.run, base),
                s,
            )?;
            {
                let lane = &mut self.lanes[s];
                // Collect acks the worker sent before dying, so only the
                // genuinely unprocessed suffix counts as lost.
                while let Ok(seq) = lane.acks.try_recv() {
                    while lane.inflight.front().map_or(false, |(q, _)| *q <= seq) {
                        lane.inflight.pop_front();
                    }
                }
                lane.poisoned.store(false, Ordering::SeqCst);
                lane.worker.send(ShardCmd::Reset(Box::new(fresh)))?;
            }
            if self.wal.is_some() {
                // Exact heal: the WAL holds every acked row in global
                // order; this shard's sub-stream is the round-robin
                // slice, re-fed as one batch (batch boundaries do not
                // change the trajectory).
                let path = {
                    let w = self.wal.as_mut().unwrap();
                    w.sync()?;
                    w.path().to_path_buf()
                };
                let replayed = wal::replay(&path, Some(self.dim))?;
                let nshards = self.lanes.len() as u64;
                let mut mine = Dataset::empty(format!("heal-shard-{s}"), self.dim);
                for i in 0..replayed.rows.len() {
                    // Slice by *global* row index: a rotated WAL's frames
                    // start at the generation base, not at row 0.
                    if (replayed.base_rows + i as u64) % nshards == s as u64 {
                        mine.push_row(replayed.rows.row(i), replayed.rows.label(i));
                    }
                }
                let lane = &mut self.lanes[s];
                lane.inflight.clear();
                if !mine.is_empty() {
                    self.rows_requeued += mine.len() as u64;
                    telemetry::registry::count_n(Counter::RowsRequeued, mine.len() as u64);
                    Self::dispatch_part(&self.pending_rows, lane, mine)?;
                }
            } else {
                let parts: Vec<Dataset> =
                    self.lanes[s].inflight.drain(..).map(|(_, ds)| ds).collect();
                for part in parts {
                    self.rows_requeued += part.len() as u64;
                    telemetry::registry::count_n(Counter::RowsRequeued, part.len() as u64);
                    Self::dispatch_part(&self.pending_rows, &mut self.lanes[s], part)?;
                }
            }
        }
        Ok(())
    }

    /// Snapshot every shard, merge, and publish into the registry;
    /// returns the serving version afterwards. The wait for shard queues
    /// to drain is part of the measured stall (readers keep serving the
    /// previous snapshot throughout). A shard found poisoned mid-snapshot
    /// is healed and the snapshot retried, so a publish never silently
    /// acks into a dead shard.
    pub fn publish_now(&mut self) -> Result<u64> {
        self.fail_check()?;
        ensure!(self.rows_total > 0, "cannot publish before any rows are ingested");
        let t0 = Instant::now();
        let mut models = Vec::new();
        let mut weights = Vec::new();
        let mut attempts = 0;
        loop {
            attempts += 1;
            self.heal_poisoned()?;
            self.drain_acks();
            let mut pending = Vec::with_capacity(self.lanes.len());
            for lane in &self.lanes {
                let (tx, rx) = mpsc::channel();
                lane.worker.send(ShardCmd::Snapshot(tx))?;
                pending.push(rx);
            }
            models.clear();
            weights.clear();
            let mut poisoned = false;
            for rx in pending {
                match rx.recv().map_err(|_| anyhow!("shard worker terminated"))? {
                    ShardSnap::Ready(Some((model, steps))) => {
                        models.push(model);
                        weights.push(steps as f64);
                    }
                    ShardSnap::Ready(None) => {}
                    ShardSnap::Poisoned => poisoned = true,
                }
            }
            if !poisoned {
                break;
            }
            ensure!(
                attempts < 3,
                "a shard worker kept dying across {attempts} heal attempts"
            );
        }
        // In rotation mode the generation base rides every merge with
        // weight = the rows it covers, so the publish reflects the whole
        // stream even though the lanes only hold the current generation.
        if let Some((base, rows)) = &self.base_model {
            models.insert(0, base.clone());
            weights.insert(0, *rows as f64);
        }
        ensure!(!models.is_empty(), "no shard has trained a model yet");
        let merged = {
            let _merge = telemetry::stage_span(Stage::ShardMerge);
            super::merge::merge_shard_models(
                models,
                &weights,
                self.config.budget,
                &self.config.maintenance(),
            )?
        };
        let version = match self.shadow {
            Some(policy) => {
                let outcome = self.registry.publish_shadowed(merged, &policy);
                if !outcome.accepted {
                    self.shadow_rejects += 1;
                }
                outcome.version
            }
            None => self.registry.publish(merged),
        };
        telemetry::registry::record_stage_ns(
            Stage::PublishStall,
            t0.elapsed().as_nanos() as u64,
        );
        let stall = t0.elapsed().as_secs_f64();
        self.stall_ewma = if self.publish_stalls.is_empty() {
            stall
        } else {
            EWMA_ALPHA * stall + (1.0 - EWMA_ALPHA) * self.stall_ewma
        };
        self.publish_stalls.push(stall);
        self.cadence_history.push(self.publish_every);
        self.rows_since_publish = 0;
        self.last_version = version;
        if self.adapt {
            self.adapt_cadence();
        }
        if let Some(path) = self.checkpoint_path.clone() {
            if let Some(snap) = self.registry.current() {
                wal::write_checkpoint(&path, snap.model(), self.rows_total, snap.version())?;
                // Rotation rides the durable checkpoint: the rows it
                // covers are now recoverable from the checkpoint alone,
                // so the WAL no longer needs them.
                if self.rotate_wal {
                    self.start_generation()?;
                }
            }
        }
        Ok(version)
    }

    /// Publishes rejected by the shadow gate so far.
    pub fn shadow_rejects(&self) -> u64 {
        self.shadow_rejects
    }

    /// Stall-aware cadence controller (runs after each publish when
    /// enabled): see [`ShardedIngest::with_adaptive_cadence`].
    fn adapt_cadence(&mut self) {
        let n = self.publish_stalls.len();
        let recent = &self.publish_stalls[n.saturating_sub(ADAPT_WINDOW)..];
        let mean = recent.iter().sum::<f64>() / recent.len() as f64;
        if mean > ADAPT_STALL_THRESHOLD_SECONDS {
            self.publish_every =
                (self.publish_every * 2).min(self.base_publish_every * ADAPT_MAX_FACTOR);
        } else if mean < ADAPT_STALL_THRESHOLD_SECONDS / 4.0
            && self.publish_every > self.base_publish_every
        {
            self.publish_every = (self.publish_every / 2).max(self.base_publish_every);
        }
    }

    /// Drain everything, publish a final snapshot if rows arrived since
    /// the last one, join the shard workers, and return the accounting.
    /// A crashed (fault-injected) pipeline skips the final publish but
    /// still joins cleanly.
    pub fn finish(mut self) -> Result<IngestReport> {
        if self.failed.is_none()
            && self.rows_total > 0
            && (self.rows_since_publish > 0 || self.last_version == 0)
        {
            self.publish_now()?;
        }
        for lane in self.lanes.drain(..) {
            lane.worker.join();
        }
        Ok(IngestReport {
            rows: self.rows_total,
            publishes: self.publish_stalls.len() as u64,
            publish_stalls: self.publish_stalls,
            last_version: self.last_version,
            final_publish_every: self.publish_every,
            cadence_history: self.cadence_history,
            worker_restarts: self.restarts,
            rows_requeued: self.rows_requeued,
            rejected_rows: self.rejected_rows,
            deferred_publishes: self.deferred_publishes,
        })
    }

    /// Rebuild a pipeline from its persistence pair after a crash.
    ///
    /// 1. If a checkpoint exists it is published immediately — the serve
    ///    tier has a model before replay finishes (availability).
    /// 2. The WAL is resumed (torn tail truncated) and **every** acked
    ///    row is replayed through a fresh deterministic pipeline — the
    ///    WAL, not the checkpoint, is the source of truth, and the
    ///    pipeline's determinism contract makes the result byte-identical
    ///    to an uninterrupted run over the same acked rows.
    /// 3. The resumed WAL is re-attached so new rows keep appending, and
    ///    a fresh checkpoint is written.
    ///
    /// With `rotate` set the pair is interpreted as a rotating lineage:
    /// the checkpointed model is installed as the generation base, only
    /// the WAL frames **past** the checkpoint are replayed (the bounded
    /// tail — replay cost no longer grows with stream age), and recovery
    /// finishes by rotating the WAL under the fresh checkpoint. A crash
    /// between checkpoint write and rotation (a *torn rotation*) leaves
    /// the WAL one generation behind the checkpoint; the same skip logic
    /// converges it, so torn and clean rotations recover identically.
    /// Recovering a rotated WAL with `rotate` unset is a typed error.
    #[allow(clippy::too_many_arguments)]
    pub fn recover(
        solver: SolverSpec,
        config: SvmConfig,
        run: RunConfig,
        shards: usize,
        publish_every: usize,
        registry: Arc<ModelRegistry>,
        wal_path: &Path,
        checkpoint_path: Option<&Path>,
        rotate: bool,
    ) -> Result<(Self, RecoveryReport)> {
        let t0 = Instant::now();
        let mut checkpoint_rows = 0;
        let mut checkpoint_version = 0;
        let mut checkpoint_model = None;
        if let Some(ckpt) = checkpoint_path {
            if ckpt.exists() {
                let decoded = wal::read_checkpoint(ckpt)?;
                checkpoint_rows = decoded.rows_covered;
                checkpoint_version = decoded.version;
                let mut model = decoded.model;
                model.set_fast_exp(config.fast_exp);
                if rotate {
                    checkpoint_model = Some(model.clone());
                }
                registry.publish(model);
            }
        }
        let (wal_writer, replayed) = WalWriter::resume(wal_path)?;
        let mut pipeline =
            Self::with_solver(solver, config, run, shards, publish_every, registry)?;
        pipeline.rotate_wal = rotate;
        let mut skip = 0usize;
        if let Some(model) = checkpoint_model {
            ensure!(
                checkpoint_rows >= replayed.base_rows,
                "checkpoint covers {} rows but the WAL generation starts at {}",
                checkpoint_rows,
                replayed.base_rows
            );
            skip = (checkpoint_rows - replayed.base_rows) as usize;
            ensure!(
                skip <= replayed.rows.len(),
                "checkpoint covers {} rows but the WAL only reaches {}",
                checkpoint_rows,
                replayed.base_rows + replayed.rows.len() as u64
            );
            pipeline.install_base(model, checkpoint_rows)?;
        } else {
            ensure!(
                replayed.base_rows == 0,
                "WAL was rotated (generation base {}); recover with rotation enabled and \
                 the checkpoint that anchored it",
                replayed.base_rows
            );
        }
        let tail = if skip == 0 {
            replayed.rows.clone()
        } else {
            let idx: Vec<usize> = (skip..replayed.rows.len()).collect();
            replayed.rows.subset(&idx, "wal-tail")
        };
        if !tail.is_empty() {
            pipeline.ingest(&tail)?;
            pipeline.publish_now()?;
        }
        pipeline.attach_wal(wal_writer)?;
        if let Some(ckpt) = checkpoint_path {
            pipeline.checkpoint_at(ckpt);
            if pipeline.rows_total > 0 {
                if let Some(snap) = pipeline.registry.current() {
                    wal::write_checkpoint(ckpt, snap.model(), pipeline.rows_total, snap.version())?;
                }
                if rotate {
                    pipeline.start_generation()?;
                }
            }
        }
        let report = RecoveryReport {
            wal_rows: tail.len() as u64,
            torn_tail_dropped: replayed.torn_tail,
            checkpoint_rows,
            checkpoint_version,
            recovery_seconds: t0.elapsed().as_secs_f64(),
        };
        Ok((pipeline, report))
    }

    /// Test hook: force the queue-depth counter (admission decisions
    /// only; workers never see forced values).
    #[cfg(test)]
    fn force_pending_rows(&self, rows: u64) {
        self.pending_rows.store(rows, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::kernel::KernelSpec;
    use crate::solver::BsgdEstimator;

    fn config_for(n: usize, budget: usize) -> SvmConfig {
        SvmConfig::new().kernel(KernelSpec::gaussian(2.0)).budget(budget).c(10.0, n)
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("budgetsvm-ingest");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn run_pipeline(
        ds: &Dataset,
        shards: usize,
        publish_every: usize,
        chunk: usize,
    ) -> (Arc<ModelRegistry>, IngestReport) {
        let registry = Arc::new(ModelRegistry::new());
        let mut ing = ShardedIngest::new(
            config_for(ds.len(), 30),
            RunConfig::new().seed(11),
            shards,
            publish_every,
            Arc::clone(&registry),
        )
        .unwrap();
        let mut start = 0;
        while start < ds.len() {
            let idx: Vec<usize> = (start..(start + chunk).min(ds.len())).collect();
            ing.ingest(&ds.subset(&idx, "chunk")).unwrap();
            start += chunk;
        }
        let report = ing.finish().unwrap();
        (registry, report)
    }

    #[test]
    fn single_shard_pipeline_matches_serial_partial_fit() {
        let ds = two_moons(600, 0.12, 21);
        let (registry, report) = run_pipeline(&ds, 1, 10_000, 64);
        assert_eq!(report.rows, 600);
        assert_eq!(report.publishes, 1);
        let snap = registry.current().unwrap();

        let mut serial = BsgdEstimator::new_shard(
            config_for(ds.len(), 30),
            RunConfig::new().seed(11),
            0,
        )
        .unwrap();
        serial.partial_fit(&ds).unwrap();
        let model = serial.model().unwrap();
        // Same trajectory; the published snapshot only differs by the
        // folded scale, so decisions agree to f64 rounding.
        for i in (0..ds.len()).step_by(37) {
            let a = snap.model().decision(ds.row(i));
            let b = model.decision(ds.row(i));
            assert!((a - b).abs() <= 1e-12 * (1.0 + b.abs()), "row {i}: {a} vs {b}");
        }
        assert_eq!(snap.model().num_sv(), model.num_sv());
    }

    #[test]
    fn sharded_ingest_is_deterministic_run_to_run() {
        let ds = two_moons(500, 0.12, 33);
        let probes: Vec<usize> = vec![0, 17, 123, 250, 499];
        let (reg1, rep1) = run_pipeline(&ds, 4, 128, 50);
        let (reg2, rep2) = run_pipeline(&ds, 4, 128, 50);
        assert_eq!(rep1.publishes, rep2.publishes);
        assert!(rep1.publishes >= 3, "publish cadence should fire: {}", rep1.publishes);
        let (s1, s2) = (reg1.current().unwrap(), reg2.current().unwrap());
        assert_eq!(s1.model().num_sv(), s2.model().num_sv());
        for &i in &probes {
            assert_eq!(
                s1.model().decision(ds.row(i)).to_bits(),
                s2.model().decision(ds.row(i)).to_bits(),
                "row {i}"
            );
        }
    }

    #[test]
    fn sharded_accuracy_is_close_to_serial() {
        // Tolerance asserted here (recorded per the roadmap issue): the
        // 4-shard weighted-average publish must stay within 0.10 absolute
        // accuracy of the serial 1-shard pipeline on the same stream, and
        // both must actually learn the task.
        let ds = two_moons(1200, 0.1, 5);
        let (reg_serial, _) = run_pipeline(&ds, 1, 100_000, 128);
        let (reg_sharded, _) = run_pipeline(&ds, 4, 400, 128);
        let acc_serial = reg_serial.current().unwrap().model().accuracy(&ds);
        let acc_sharded = reg_sharded.current().unwrap().model().accuracy(&ds);
        assert!(acc_serial > 0.85, "serial accuracy {acc_serial}");
        assert!(acc_sharded > 0.82, "sharded accuracy {acc_sharded}");
        assert!(
            (acc_serial - acc_sharded).abs() <= 0.10,
            "serial {acc_serial} vs sharded {acc_sharded}"
        );
    }

    #[test]
    fn publish_respects_budget_and_counts_rows() {
        let ds = two_moons(400, 0.12, 8);
        let (registry, report) = run_pipeline(&ds, 3, 100, 64);
        assert_eq!(report.rows, 400);
        assert!(report.publishes >= 4);
        assert_eq!(report.last_version, registry.version());
        assert!(registry.current().unwrap().model().num_sv() <= 30);
        assert_eq!(report.publish_stalls.len() as u64, report.publishes);
        assert!(report.stall_max_seconds() >= report.stall_mean_seconds());
        // A fault-free run heals nothing and rejects nothing.
        assert_eq!(report.worker_restarts, 0);
        assert_eq!(report.rows_requeued, 0);
        assert_eq!(report.rejected_rows, 0);
        assert_eq!(report.deferred_publishes, 0);
    }

    #[test]
    fn cadence_history_is_recorded_and_static_without_adapt() {
        let ds = two_moons(400, 0.12, 8);
        let (_registry, report) = run_pipeline(&ds, 2, 100, 64);
        assert_eq!(report.cadence_history.len() as u64, report.publishes);
        assert!(report.cadence_history.iter().all(|&c| c == 100));
        assert_eq!(report.final_publish_every, 100);
    }

    #[test]
    fn adaptive_cadence_moves_within_bounds() {
        // Wall-clock driven, so only the bounds are asserted: the cadence
        // never leaves [base, 16·base] and every publish records the
        // cadence in effect.
        let ds = two_moons(600, 0.12, 13);
        let registry = Arc::new(ModelRegistry::new());
        let base = 50;
        let mut ing = ShardedIngest::new(
            config_for(ds.len(), 30),
            RunConfig::new().seed(11),
            2,
            base,
            Arc::clone(&registry),
        )
        .unwrap()
        .with_adaptive_cadence(true);
        let mut start = 0;
        while start < ds.len() {
            let idx: Vec<usize> = (start..(start + 64).min(ds.len())).collect();
            ing.ingest(&ds.subset(&idx, "chunk")).unwrap();
            assert!(ing.current_publish_every() >= base);
            assert!(ing.current_publish_every() <= base * 16);
            start += 64;
        }
        let report = ing.finish().unwrap();
        assert_eq!(report.cadence_history.len() as u64, report.publishes);
        for &c in &report.cadence_history {
            assert!((base..=base * 16).contains(&c), "cadence {c}");
        }
        // The published model is still a valid budgeted model.
        assert!(registry.current().unwrap().model().num_sv() <= 30);
    }

    #[test]
    fn default_factory_is_the_bsgd_path_bit_for_bit() {
        let ds = two_moons(300, 0.12, 17);
        let run_with = |solver: Option<SolverSpec>| {
            let registry = Arc::new(ModelRegistry::new());
            let mut ing = match solver {
                Some(spec) => ShardedIngest::with_solver(
                    spec,
                    config_for(ds.len(), 30),
                    RunConfig::new().seed(11),
                    3,
                    120,
                    Arc::clone(&registry),
                ),
                None => ShardedIngest::new(
                    config_for(ds.len(), 30),
                    RunConfig::new().seed(11),
                    3,
                    120,
                    Arc::clone(&registry),
                ),
            }
            .unwrap();
            ing.ingest(&ds).unwrap();
            ing.finish().unwrap();
            registry
        };
        let via_new = run_with(None);
        let via_factory = run_with(Some(SolverSpec::Bsgd));
        let (a, b) = (via_new.current().unwrap(), via_factory.current().unwrap());
        assert_eq!(a.model().num_sv(), b.model().num_sv());
        for i in (0..ds.len()).step_by(29) {
            assert_eq!(
                a.model().decision(ds.row(i)).to_bits(),
                b.model().decision(ds.row(i)).to_bits(),
                "row {i}"
            );
        }
    }

    #[test]
    fn dual_solver_shards_train_and_publish_deterministically() {
        let ds = two_moons(500, 0.12, 19);
        let run_once = || {
            let registry = Arc::new(ModelRegistry::new());
            let mut ing = ShardedIngest::with_solver(
                SolverSpec::Bdca,
                config_for(ds.len(), 30),
                RunConfig::new().seed(11),
                4,
                150,
                Arc::clone(&registry),
            )
            .unwrap();
            let mut start = 0;
            while start < ds.len() {
                let idx: Vec<usize> = (start..(start + 64).min(ds.len())).collect();
                ing.ingest(&ds.subset(&idx, "chunk")).unwrap();
                start += 64;
            }
            let report = ing.finish().unwrap();
            (registry, report)
        };
        let (reg1, rep1) = run_once();
        let (reg2, rep2) = run_once();
        assert_eq!(rep1.rows, 500);
        assert_eq!(rep1.publishes, rep2.publishes);
        let (s1, s2) = (reg1.current().unwrap(), reg2.current().unwrap());
        assert!(s1.model().num_sv() <= 30, "budget violated");
        assert_eq!(s1.model().num_sv(), s2.model().num_sv());
        for &i in &[0usize, 17, 123, 250, 499] {
            assert_eq!(
                s1.model().decision(ds.row(i)).to_bits(),
                s2.model().decision(ds.row(i)).to_bits(),
                "row {i}"
            );
        }
        // And the merged dual model still learns the task.
        assert!(s1.model().accuracy(&ds) > 0.8);
    }

    #[test]
    fn empty_and_mismatched_batches_are_handled() {
        let registry = Arc::new(ModelRegistry::new());
        let mut ing = ShardedIngest::new(
            config_for(100, 10),
            RunConfig::new(),
            2,
            1000,
            Arc::clone(&registry),
        )
        .unwrap();
        // Publishing before any rows is an error.
        assert!(ing.publish_now().is_err());
        ing.ingest(&Dataset::empty("none", 2)).unwrap();
        assert_eq!(ing.rows_ingested(), 0);
        let ds = two_moons(50, 0.1, 1);
        ing.ingest(&ds).unwrap();
        // Dimension is pinned by the first non-empty batch.
        let bad = Dataset::new("bad", vec![0.0; 9], vec![1.0, 1.0, -1.0], 3);
        assert!(ing.ingest(&bad).is_err());
        let report = ing.finish().unwrap();
        assert_eq!(report.rows, 50);
        assert_eq!(registry.version(), report.last_version);
    }

    #[test]
    fn worker_panic_without_wal_requeues_unacked_rows() {
        let ds = two_moons(300, 0.12, 9);
        let registry = Arc::new(ModelRegistry::new());
        let mut ing = ShardedIngest::new(
            config_for(ds.len(), 30),
            RunConfig::new().seed(7),
            2,
            10_000,
            Arc::clone(&registry),
        )
        .unwrap();
        // Shard 1 sees ~15 rows per 30-row chunk; panic on its 3rd batch.
        ing.fault_inject(FaultPlan::none().with_worker_panic(1, 40)).unwrap();
        let mut start = 0;
        while start < ds.len() {
            let idx: Vec<usize> = (start..(start + 30).min(ds.len())).collect();
            ing.ingest(&ds.subset(&idx, "chunk")).unwrap();
            start += 30;
        }
        let report = ing.finish().unwrap();
        assert_eq!(report.rows, 300);
        assert!(report.worker_restarts >= 1, "the panic must be healed");
        assert!(report.rows_requeued > 0, "the dropped batch must be re-fed");
        // The pipeline still publishes a valid budgeted model.
        let snap = registry.current().unwrap();
        assert!(snap.model().num_sv() <= 30);
        assert_eq!(report.last_version, registry.version());
    }

    #[test]
    fn worker_panic_heals_via_wal_to_the_unfaulted_trajectory() {
        let ds = two_moons(240, 0.12, 23);
        let run = |faulted: bool| {
            let wal_path = tmp(if faulted { "heal-f.wal" } else { "heal-c.wal" });
            let registry = Arc::new(ModelRegistry::new());
            let mut ing = ShardedIngest::new(
                config_for(ds.len(), 30),
                RunConfig::new().seed(31),
                3,
                100_000,
                Arc::clone(&registry),
            )
            .unwrap();
            ing.enable_wal(&wal_path).unwrap();
            if faulted {
                ing.fault_inject(FaultPlan::none().with_worker_panic(1, 30)).unwrap();
            }
            let mut start = 0;
            while start < ds.len() {
                let idx: Vec<usize> = (start..(start + 40).min(ds.len())).collect();
                ing.ingest(&ds.subset(&idx, "chunk")).unwrap();
                start += 40;
            }
            let report = ing.finish().unwrap();
            let dump = tmp(if faulted { "heal-f.bsvm" } else { "heal-c.bsvm" });
            registry.dump(&dump).unwrap();
            let bytes = std::fs::read(&dump).unwrap();
            std::fs::remove_file(&dump).ok();
            std::fs::remove_file(&wal_path).ok();
            (report, bytes)
        };
        let (clean_report, clean_bytes) = run(false);
        let (faulted_report, faulted_bytes) = run(true);
        assert_eq!(clean_report.rows, 240);
        assert_eq!(faulted_report.rows, 240);
        assert_eq!(clean_report.worker_restarts, 0);
        assert!(faulted_report.worker_restarts >= 1);
        // The WAL heal replays the shard's full sub-stream with the
        // shard's original seed, so the published model is bit-identical
        // to the never-faulted run.
        assert_eq!(clean_bytes, faulted_bytes, "healed trajectory must match the unfaulted one");
    }

    #[test]
    fn injected_crash_after_wal_append_preserves_acked_rows_and_recovery_is_byte_identical() {
        let ds = two_moons(400, 0.12, 41);
        let wal_path = tmp("crash.wal");
        let ckpt_path = tmp("crash.ckpt");
        std::fs::remove_file(&wal_path).ok();
        std::fs::remove_file(&ckpt_path).ok();
        let config = || config_for(ds.len(), 30);
        let run = RunConfig::new().seed(13);

        // Crashed run: WAL + checkpoint armed, torn-write crash at row 150.
        let registry = Arc::new(ModelRegistry::new());
        let mut ing = ShardedIngest::new(config(), run.clone(), 2, 50, Arc::clone(&registry))
            .unwrap();
        ing.enable_wal(&wal_path).unwrap();
        ing.checkpoint_at(&ckpt_path);
        ing.fault_inject(FaultPlan::none().with_crash_at_rows(150, true)).unwrap();
        let mut start = 0;
        let mut crashed = false;
        while start < ds.len() {
            let idx: Vec<usize> = (start..(start + 40).min(ds.len())).collect();
            match ing.ingest(&ds.subset(&idx, "chunk")) {
                Ok(()) => {}
                Err(e) => {
                    let msg = e.to_string();
                    assert!(crate::serve::faults::is_injected_crash(&msg), "{msg}");
                    crashed = true;
                    break;
                }
            }
            start += 40;
        }
        assert!(crashed, "the fault plan must fire");
        // Every later call fails fast.
        assert!(ing.ingest(&ds).is_err());
        assert!(ing.publish_now().is_err());
        let report = ing.finish().unwrap();
        // 120 rows dispatched before the crash; the crashing 40-row batch
        // was WAL-acked but never trained.
        assert_eq!(report.rows, 120);

        // Recover: checkpoint gives instant availability, WAL replay
        // rebuilds the authoritative state over all 160 acked rows.
        let reg2 = Arc::new(ModelRegistry::new());
        let (recovered, rec) = ShardedIngest::recover(
            SolverSpec::Bsgd,
            config(),
            run.clone(),
            2,
            50,
            Arc::clone(&reg2),
            &wal_path,
            Some(&ckpt_path),
            false,
        )
        .unwrap();
        assert_eq!(rec.wal_rows, 160, "all acked rows survive, zero lost");
        assert!(rec.torn_tail_dropped, "the torn frame must be truncated");
        assert_eq!(rec.checkpoint_rows, 80, "checkpoint covered the last cadence publish");
        assert!(rec.checkpoint_version >= 1);
        assert_eq!(recovered.rows_ingested(), 160);
        let dump_rec = tmp("crash-rec.bsvm");
        reg2.dump(&dump_rec).unwrap();

        // Reference: an uninterrupted pipeline over the same 160 rows.
        let reg3 = Arc::new(ModelRegistry::new());
        let mut reference =
            ShardedIngest::new(config(), run, 2, 50, Arc::clone(&reg3)).unwrap();
        let idx: Vec<usize> = (0..160).collect();
        reference.ingest(&ds.subset(&idx, "reference")).unwrap();
        reference.publish_now().unwrap();
        let dump_ref = tmp("crash-ref.bsvm");
        reg3.dump(&dump_ref).unwrap();

        let rec_bytes = std::fs::read(&dump_rec).unwrap();
        let ref_bytes = std::fs::read(&dump_ref).unwrap();
        assert_eq!(rec_bytes, ref_bytes, "recovered state must be byte-identical");

        std::fs::remove_file(&dump_rec).ok();
        std::fs::remove_file(&dump_ref).ok();
        std::fs::remove_file(&wal_path).ok();
        std::fs::remove_file(&ckpt_path).ok();
    }

    #[test]
    fn rotated_wal_stays_bounded_and_crash_recovery_matches_the_uninterrupted_run() {
        let ds = two_moons(360, 0.12, 37);
        let config = || config_for(ds.len(), 30);
        let run = RunConfig::new().seed(19);

        // Reference: a rotated run over all 360 rows, never interrupted,
        // with explicit publishes at 300 and 360 (the recovery below
        // publishes at the same points).
        let ref_wal = tmp("rot-ref.wal");
        let ref_ckpt = tmp("rot-ref.ckpt");
        std::fs::remove_file(&ref_wal).ok();
        std::fs::remove_file(&ref_ckpt).ok();
        let ref_reg = Arc::new(ModelRegistry::new());
        let mut reference =
            ShardedIngest::new(config(), run.clone(), 2, 100, Arc::clone(&ref_reg)).unwrap();
        reference.enable_wal(&ref_wal).unwrap();
        reference.checkpoint_at(&ref_ckpt);
        reference.enable_wal_rotation();
        let mut start = 0;
        while start < 300 {
            let idx: Vec<usize> = (start..start + 60).collect();
            reference.ingest(&ds.subset(&idx, "chunk")).unwrap();
            start += 60;
        }
        reference.publish_now().unwrap();
        let idx: Vec<usize> = (300..360).collect();
        reference.ingest(&ds.subset(&idx, "chunk")).unwrap();
        reference.publish_now().unwrap();
        reference.finish().unwrap();
        // Rotation kept the WAL empty past the last checkpoint instead of
        // holding all 360 frames.
        let left = wal::replay(&ref_wal, None).unwrap();
        assert_eq!(left.base_rows, 360);
        assert!(left.rows.is_empty(), "rotated WAL must only hold the current generation");
        let dump_ref = tmp("rot-ref.bsvm");
        ref_reg.dump(&dump_ref).unwrap();

        // Crashed run: same stream, torn-write crash at row 270 — after
        // the rotation at 240, mid-generation.
        let wal_path = tmp("rot-crash.wal");
        let ckpt_path = tmp("rot-crash.ckpt");
        std::fs::remove_file(&wal_path).ok();
        std::fs::remove_file(&ckpt_path).ok();
        let registry = Arc::new(ModelRegistry::new());
        let mut ing =
            ShardedIngest::new(config(), run.clone(), 2, 100, Arc::clone(&registry)).unwrap();
        ing.enable_wal(&wal_path).unwrap();
        ing.checkpoint_at(&ckpt_path);
        ing.enable_wal_rotation();
        ing.fault_inject(FaultPlan::none().with_crash_at_rows(270, true)).unwrap();
        let mut start = 0;
        let mut crashed = false;
        while start < 300 {
            let idx: Vec<usize> = (start..start + 60).collect();
            if let Err(e) = ing.ingest(&ds.subset(&idx, "chunk")) {
                assert!(crate::serve::faults::is_injected_crash(&e.to_string()));
                crashed = true;
                break;
            }
            start += 60;
        }
        assert!(crashed, "the fault plan must fire");
        ing.finish().unwrap();

        // Recover: only the generation tail (60 rows past the checkpoint
        // at 240) replays — bounded, not the full 300-row history.
        let reg2 = Arc::new(ModelRegistry::new());
        let (mut recovered, rec) = ShardedIngest::recover(
            SolverSpec::Bsgd,
            config(),
            run,
            2,
            100,
            Arc::clone(&reg2),
            &wal_path,
            Some(&ckpt_path),
            true,
        )
        .unwrap();
        assert_eq!(rec.checkpoint_rows, 240);
        assert_eq!(rec.wal_rows, 60, "only the generation tail replays");
        assert!(rec.torn_tail_dropped, "the torn frame must be truncated");
        assert_eq!(recovered.rows_ingested(), 300);
        let idx: Vec<usize> = (300..360).collect();
        recovered.ingest(&ds.subset(&idx, "chunk")).unwrap();
        recovered.publish_now().unwrap();
        recovered.finish().unwrap();
        let dump_rec = tmp("rot-crash.bsvm");
        reg2.dump(&dump_rec).unwrap();

        assert_eq!(
            std::fs::read(&dump_ref).unwrap(),
            std::fs::read(&dump_rec).unwrap(),
            "recovered rotated run must match the uninterrupted one byte for byte"
        );
        for p in [&ref_wal, &ref_ckpt, &dump_ref, &wal_path, &ckpt_path, &dump_rec] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn a_torn_rotation_recovers_byte_identical_to_a_clean_rotation() {
        // A crash between the checkpoint write and the WAL rotation
        // leaves the WAL one generation behind the checkpoint. Before the
        // first rotation the rotated and unrotated pipelines are
        // bit-identical, so running with rotation *disabled* manufactures
        // exactly that torn disk state: checkpoint at 100, WAL still
        // holding all 100 frames at base 0.
        let ds = two_moons(150, 0.12, 43);
        let config = || config_for(ds.len(), 30);
        let run = RunConfig::new().seed(29);
        let first: Vec<usize> = (0..100).collect();
        let extra: Vec<usize> = (100..150).collect();

        let run_to_100 = |wal: &Path, ckpt: &Path, rotate: bool, registry: &Arc<ModelRegistry>| {
            std::fs::remove_file(wal).ok();
            std::fs::remove_file(ckpt).ok();
            let mut ing =
                ShardedIngest::new(config(), run.clone(), 2, 100, Arc::clone(registry)).unwrap();
            ing.enable_wal(wal).unwrap();
            ing.checkpoint_at(ckpt);
            if rotate {
                ing.enable_wal_rotation();
            }
            for half in first.chunks(50) {
                ing.ingest(&ds.subset(half, "chunk")).unwrap();
            }
            ing
        };

        // Clean rotation, never interrupted: rotate at 100, train on.
        let clean_wal = tmp("torn-clean.wal");
        let clean_ckpt = tmp("torn-clean.ckpt");
        let clean_reg = Arc::new(ModelRegistry::new());
        let mut clean = run_to_100(&clean_wal, &clean_ckpt, true, &clean_reg);
        clean.ingest(&ds.subset(&extra, "extra")).unwrap();
        clean.publish_now().unwrap();
        clean.finish().unwrap();
        let dump_clean = tmp("torn-clean.bsvm");
        clean_reg.dump(&dump_clean).unwrap();

        // Torn rotation: checkpoint landed, rotation did not.
        let torn_wal = tmp("torn.wal");
        let torn_ckpt = tmp("torn.ckpt");
        let torn_reg = Arc::new(ModelRegistry::new());
        run_to_100(&torn_wal, &torn_ckpt, false, &torn_reg).finish().unwrap();
        let before = wal::replay(&torn_wal, None).unwrap();
        assert_eq!(
            (before.base_rows, before.rows.len()),
            (0, 100),
            "torn state: the WAL is one generation behind the checkpoint"
        );

        // Recovery skips the 100 checkpoint-covered frames (nothing to
        // replay) and converges the torn state by completing the
        // rotation; the continued run is byte-identical to the clean one.
        let reg2 = Arc::new(ModelRegistry::new());
        let (mut recovered, rec) = ShardedIngest::recover(
            SolverSpec::Bsgd,
            config(),
            run.clone(),
            2,
            100,
            Arc::clone(&reg2),
            &torn_wal,
            Some(&torn_ckpt),
            true,
        )
        .unwrap();
        assert_eq!(rec.checkpoint_rows, 100);
        assert_eq!(rec.wal_rows, 0, "checkpoint-covered frames are skipped, not replayed");
        let after = wal::replay(&torn_wal, None).unwrap();
        assert_eq!((after.base_rows, after.rows.len()), (100, 0), "rotation completed");
        recovered.ingest(&ds.subset(&extra, "extra")).unwrap();
        recovered.publish_now().unwrap();
        recovered.finish().unwrap();
        let dump_torn = tmp("torn.bsvm");
        reg2.dump(&dump_torn).unwrap();

        assert_eq!(
            std::fs::read(&dump_clean).unwrap(),
            std::fs::read(&dump_torn).unwrap(),
            "torn-rotation recovery must be byte-identical to the clean rotation"
        );
        for p in [&clean_wal, &clean_ckpt, &dump_clean, &torn_wal, &torn_ckpt, &dump_torn] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn recovering_a_rotated_wal_without_rotation_is_a_typed_error() {
        let ds = two_moons(100, 0.12, 47);
        let wal_path = tmp("rot-guard.wal");
        let ckpt_path = tmp("rot-guard.ckpt");
        std::fs::remove_file(&wal_path).ok();
        std::fs::remove_file(&ckpt_path).ok();
        let registry = Arc::new(ModelRegistry::new());
        let mut ing = ShardedIngest::new(
            config_for(ds.len(), 30),
            RunConfig::new().seed(3),
            2,
            100,
            Arc::clone(&registry),
        )
        .unwrap();
        ing.enable_wal(&wal_path).unwrap();
        ing.checkpoint_at(&ckpt_path);
        ing.enable_wal_rotation();
        ing.ingest(&ds).unwrap();
        ing.finish().unwrap();

        // The WAL is now based at 100; pretending rotation never existed
        // must fail loudly instead of replaying a truncated history.
        let err = ShardedIngest::recover(
            SolverSpec::Bsgd,
            config_for(ds.len(), 30),
            RunConfig::new().seed(3),
            2,
            100,
            Arc::new(ModelRegistry::new()),
            &wal_path,
            Some(&ckpt_path),
            false,
        )
        .map(|_| ())
        .unwrap_err()
        .to_string();
        assert!(err.contains("recover with rotation enabled"), "{err}");

        // Same refusal when the rotated WAL has lost its checkpoint
        // anchor: a generation base with nothing to rebuild it from.
        std::fs::remove_file(&ckpt_path).unwrap();
        let err = ShardedIngest::recover(
            SolverSpec::Bsgd,
            config_for(ds.len(), 30),
            RunConfig::new().seed(3),
            2,
            100,
            Arc::new(ModelRegistry::new()),
            &wal_path,
            None,
            true,
        )
        .map(|_| ())
        .unwrap_err()
        .to_string();
        assert!(err.contains("recover with rotation enabled"), "{err}");
        std::fs::remove_file(&wal_path).ok();
    }

    #[test]
    fn admission_ladder_sheds_then_rejects_then_recovers() {
        let ds = two_moons(120, 0.12, 3);
        let registry = Arc::new(ModelRegistry::new());
        let mut ing = ShardedIngest::new(
            config_for(ds.len(), 20),
            RunConfig::new().seed(5),
            2,
            10,
            Arc::clone(&registry),
        )
        .unwrap()
        .with_admission(100, 50);
        assert_eq!(ing.admission_state(), Admission::Accept);

        // Shed: the batch trains but its cadence publish is deferred.
        ing.force_pending_rows(60);
        let idx: Vec<usize> = (0..30).collect();
        ing.ingest(&ds.subset(&idx, "shed")).unwrap();
        assert!(ing.health().deferred_publishes >= 1, "publish must be deferred under shed");
        // Drain the workers (snapshot barrier) so their queue-counter
        // decrements can no longer race the forced values below.
        ing.publish_now().unwrap();

        // Reject: the batch is refused with a typed overloaded error.
        ing.force_pending_rows(100);
        assert_eq!(ing.admission_state(), Admission::RejectTrain);
        let idx: Vec<usize> = (30..60).collect();
        let err = ing.ingest(&ds.subset(&idx, "reject")).unwrap_err().to_string();
        assert!(err.contains("overloaded"), "{err}");
        assert_eq!(ing.health().rejected_rows, 30);
        assert_eq!(ing.health().admission, Admission::RejectTrain);

        // Pressure gone: back to normal service, deferred work publishes.
        ing.force_pending_rows(0);
        assert_eq!(ing.admission_state(), Admission::Accept);
        let idx: Vec<usize> = (30..120).collect();
        ing.ingest(&ds.subset(&idx, "resume")).unwrap();
        let report = ing.finish().unwrap();
        assert_eq!(report.rows, 120);
        assert!(report.deferred_publishes >= 1);
        assert_eq!(report.rejected_rows, 30);
        assert!(registry.version() >= 1);
    }

    #[test]
    fn recover_on_missing_wal_is_a_typed_error() {
        let registry = Arc::new(ModelRegistry::new());
        let missing = tmp("never-written.wal");
        std::fs::remove_file(&missing).ok();
        let err = ShardedIngest::recover(
            SolverSpec::Bsgd,
            config_for(100, 10),
            RunConfig::new(),
            2,
            100,
            registry,
            &missing,
            None,
            false,
        );
        assert!(err.is_err());
    }

    #[test]
    fn shadowed_publishes_ride_the_registry_gate() {
        let ds = two_moons(200, 0.12, 29);
        let registry = Arc::new(ModelRegistry::new());
        let mut ing = ShardedIngest::new(
            config_for(ds.len(), 30),
            RunConfig::new().seed(17),
            2,
            60,
            Arc::clone(&registry),
        )
        .unwrap()
        .with_shadow_policy(ShadowPolicy { min_rows: 1_000_000, max_disagreement: 0.25 });
        // min_rows is unreachable, so every publish passes the cold-start
        // branch unconditionally — the plumbing works end to end.
        ing.ingest(&ds).unwrap();
        assert_eq!(ing.shadow_rejects(), 0);
        let report = ing.finish().unwrap();
        assert!(report.publishes >= 1);
        assert!(registry.version() >= 1);
        let stats = registry.lifecycle_stats();
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.last_accepted, Some(true));
    }
}

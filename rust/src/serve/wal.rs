//! Crash-safe persistence for the ingest pipeline: an append-only,
//! CRC-framed write-ahead log of acknowledged train rows plus atomic
//! snapshot checkpoints.
//!
//! ## Durability contract
//!
//! * A train row counts as **acknowledged** only once its WAL frame has
//!   been appended and synced ([`WalWriter::append_rows`] syncs before
//!   returning). Acked rows therefore survive any crash.
//! * The WAL is the **authoritative row log**. Recovery replays the full
//!   WAL through a fresh deterministic pipeline
//!   ([`super::ShardedIngest::recover`]); byte-identity with an
//!   uninterrupted run over the same rows follows from the pipeline's
//!   determinism contract (fixed per-shard seeds, round-robin
//!   partitioning by global row index, batch-boundary invariance).
//! * A **checkpoint** pins the registry incumbent (model + version +
//!   rows covered) for instant serve availability on recovery; it is an
//!   optimization, never the source of truth. Checkpoints are written
//!   atomically (tmp + rename) through the `model::io` writers, so a
//!   crash mid-checkpoint leaves the previous checkpoint intact.
//! * A crash mid-append leaves a **torn tail**: a partial frame or a
//!   frame whose CRC does not match. [`replay`] stops at the first torn
//!   frame (reporting it) and [`WalWriter::resume`] truncates it away —
//!   only unacknowledged bytes are ever dropped.
//!
//! ## Rotation (generations)
//!
//! Without rotation the WAL grows without bound across checkpoints.
//! [`WalWriter::rotate`] — called under a just-written durable
//! checkpoint — atomically replaces the file with an empty
//! **generation** segment whose header records how many rows the
//! checkpoint covers (`base_rows`). The logical row count
//! ([`WalWriter::rows`] = base + frames) never moves backwards, so the
//! pipeline's accounting invariants hold across rotations, and replay
//! reports the base so recovery can place surviving frames at their
//! global row indices. Rotation is atomic (tmp + rename): a crash
//! anywhere inside it leaves either the old segment (plus a stray tmp
//! the next rotation truncates) or the new one — never a torn WAL.
//!
//! ## File formats
//!
//! WAL v1: magic `BSVMWAL1`, u64 LE dimension, then frames of
//! `u32 LE len | u32 LE crc32(payload) | payload` where the payload is
//! `f32 LE label` followed by `dim` `f32 LE` features (`len` must equal
//! `4·(dim+1)`, which bounds every allocation during replay).
//!
//! WAL v2 (rotated generations): magic `BSVMWAL2`, u64 LE dimension,
//! u64 LE base_rows, then the same frame stream. A v1 file reads as
//! base 0.
//!
//! Checkpoint: magic `BSVMCKP1`, u64 LE rows_covered, u64 LE version,
//! u64 LE model_len, u32 LE crc32(model bytes), then the `BSVMMDL2`
//! model body.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::data::Dataset;
use crate::model::{io as model_io, AnyModel};

const WAL_MAGIC: &[u8; 8] = b"BSVMWAL1";
const WAL_MAGIC_V2: &[u8; 8] = b"BSVMWAL2";
const CKPT_MAGIC: &[u8; 8] = b"BSVMCKP1";

/// Default WAL file name under a persistence directory.
pub const WAL_FILE: &str = "serve.wal";

/// Default checkpoint file name under a persistence directory.
pub const CHECKPOINT_FILE: &str = "serve.ckpt";

/// Upper bound on the dimension a WAL header may declare (mirrors the
/// model-loader plausibility bound; keeps a corrupt header from driving
/// replay allocations).
const MAX_WAL_DIM: u64 = 1_000_000;

/// Upper bound on a checkpoint's embedded model, in bytes.
const MAX_CKPT_MODEL_BYTES: u64 = 1_000_000_000;

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = crc_table();

/// CRC-32 (IEEE 802.3 polynomial, the zlib/`cksum -o 3` convention).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Append-only writer over one WAL file. Every append is framed and
/// synced before the call returns — the caller may acknowledge the rows
/// the moment `append_rows` is back.
#[derive(Debug)]
pub struct WalWriter {
    file: File,
    path: PathBuf,
    dim: usize,
    /// Logical rows acked through this WAL lineage: generation base
    /// plus frames in the current segment.
    rows: u64,
    /// Rows rotated away into the current segment's header base.
    base: u64,
}

impl WalWriter {
    /// Create a fresh WAL at `path` (truncating any existing file) for
    /// rows of dimension `dim`.
    pub fn create(path: impl AsRef<Path>, dim: usize) -> Result<Self> {
        ensure!(dim > 0, "WAL dimension must be positive");
        ensure!((dim as u64) <= MAX_WAL_DIM, "implausible WAL dimension {dim}");
        let path = path.as_ref().to_path_buf();
        let mut file = File::create(&path)
            .with_context(|| format!("cannot create WAL {}", path.display()))?;
        file.write_all(WAL_MAGIC)?;
        file.write_all(&(dim as u64).to_le_bytes())?;
        file.sync_data().context("WAL header sync failed")?;
        Ok(WalWriter { file, path, dim, rows: 0, base: 0 })
    }

    /// Reopen an existing WAL for appending: validates the header, scans
    /// the frames, truncates a torn tail if one exists, and positions at
    /// the end. Returns the writer plus what survived the scan.
    pub fn resume(path: impl AsRef<Path>) -> Result<(Self, WalReplay)> {
        let path = path.as_ref().to_path_buf();
        let replayed = replay(&path, None)?;
        let dim = replayed.rows.dim();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .with_context(|| format!("cannot reopen WAL {}", path.display()))?;
        // Drop the torn tail: everything past the last valid frame is
        // unacknowledged by construction.
        file.set_len(replayed.valid_bytes).context("WAL tail truncation failed")?;
        file.seek(SeekFrom::End(0))?;
        file.sync_data().context("WAL truncation sync failed")?;
        let base = replayed.base_rows;
        let rows = base + replayed.rows.len() as u64;
        Ok((WalWriter { file, path, dim, rows, base }, replayed))
    }

    /// The file this writer appends to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Logical rows acked through this WAL lineage: rows already in the
    /// file (or resumed) plus rows rotated away into the generation
    /// base. Never moves backwards, even across [`Self::rotate`].
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Rows covered by the generation base (0 until the first rotation).
    pub fn base_rows(&self) -> u64 {
        self.base
    }

    /// Row dimension of this WAL.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Rotate the WAL under a just-written durable checkpoint covering
    /// `base_rows` rows: atomically replace the file with an empty v2
    /// generation segment whose header carries `base_rows`, dropping
    /// every frame the checkpoint already covers. `base_rows` must equal
    /// the current logical row count — rotating under an older
    /// checkpoint would drop acked rows the checkpoint does not cover.
    pub fn rotate(&mut self, base_rows: u64) -> Result<()> {
        ensure!(
            base_rows == self.rows,
            "rotation base {base_rows} must cover every acked row (have {})",
            self.rows
        );
        let tmp = self.path.with_extension("wal.tmp");
        let mut header = Vec::with_capacity(24);
        header.extend_from_slice(WAL_MAGIC_V2);
        header.extend_from_slice(&(self.dim as u64).to_le_bytes());
        header.extend_from_slice(&base_rows.to_le_bytes());
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("cannot create WAL rotation tmp {}", tmp.display()))?;
            f.write_all(&header).context("WAL rotation header write failed")?;
            f.sync_data().context("WAL rotation sync failed")?;
        }
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("cannot install rotated WAL {}", self.path.display()))?;
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&self.path)
            .with_context(|| format!("cannot reopen rotated WAL {}", self.path.display()))?;
        file.seek(SeekFrom::End(0))?;
        self.file = file;
        self.base = base_rows;
        Ok(())
    }

    /// Frame and durably append every row of `batch`. One buffered write
    /// plus one sync for the whole batch; on return the rows are
    /// acknowledged-safe.
    pub fn append_rows(&mut self, batch: &Dataset) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        ensure!(
            batch.dim() == self.dim,
            "batch dimension {} does not match the WAL dimension {}",
            batch.dim(),
            self.dim
        );
        let frame_len = 4 * (self.dim + 1);
        let mut buf = Vec::with_capacity(batch.len() * (8 + frame_len));
        let mut payload = Vec::with_capacity(frame_len);
        for i in 0..batch.len() {
            payload.clear();
            payload.extend_from_slice(&batch.label(i).to_le_bytes());
            for &v in batch.row(i) {
                payload.extend_from_slice(&v.to_le_bytes());
            }
            buf.extend_from_slice(&(frame_len as u32).to_le_bytes());
            buf.extend_from_slice(&crc32(&payload).to_le_bytes());
            buf.extend_from_slice(&payload);
        }
        {
            // The span covers write + fsync — the full durability cost a
            // train batch pays before it may be acknowledged.
            let _append = crate::telemetry::stage_span(crate::telemetry::Stage::WalAppend);
            self.file.write_all(&buf).context("WAL append failed")?;
            self.sync()?;
        }
        self.rows += batch.len() as u64;
        Ok(())
    }

    /// Flush appended frames to stable storage.
    pub fn sync(&mut self) -> Result<()> {
        self.file.sync_data().context("WAL sync failed")
    }

    /// Fault-injection hook: write half a frame header and stop, exactly
    /// what a crash mid-append leaves behind. The torn bytes are past the
    /// last acknowledged frame, so recovery must drop them and nothing
    /// else.
    pub fn inject_torn_frame(&mut self) -> Result<()> {
        let garbage = [(4 * (self.dim + 1)) as u8, 0, 0, 0, 0xDE];
        self.file.write_all(&garbage).context("torn-frame write failed")?;
        self.sync()
    }
}

/// What a WAL scan recovered.
#[derive(Debug)]
pub struct WalReplay {
    /// Every fully-framed, CRC-valid row, in append order. The global
    /// row index of `rows[i]` is `base_rows + i`.
    pub rows: Dataset,
    /// Whether the scan stopped at a torn/corrupt tail frame.
    pub torn_tail: bool,
    /// File offset just past the last valid frame (the truncation point
    /// for [`WalWriter::resume`]).
    pub valid_bytes: u64,
    /// Rows rotated away into this generation's header base (0 for a
    /// v1 segment).
    pub base_rows: u64,
}

/// Scan a WAL file: header, then frames until EOF or the first torn or
/// CRC-invalid frame. Corruption **after** the last valid frame is
/// reported, not an error — that is the expected shape of a crash.
/// A header that is missing, malformed, or disagrees with `expect_dim`
/// is an error: that is not a torn tail, it is the wrong file.
pub fn replay(path: impl AsRef<Path>, expect_dim: Option<usize>) -> Result<WalReplay> {
    let path = path.as_ref();
    let mut bytes = Vec::new();
    File::open(path)
        .with_context(|| format!("cannot open WAL {}", path.display()))?
        .read_to_end(&mut bytes)
        .with_context(|| format!("cannot read WAL {}", path.display()))?;
    ensure!(bytes.len() >= 16, "WAL {} is shorter than its header", path.display());
    let v2 = &bytes[..8] == WAL_MAGIC_V2;
    ensure!(
        v2 || &bytes[..8] == WAL_MAGIC,
        "not a budgetsvm WAL (bad magic): {}",
        path.display()
    );
    let dim64 = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    ensure!(dim64 > 0 && dim64 <= MAX_WAL_DIM, "implausible WAL dimension {dim64}");
    let dim = dim64 as usize;
    if let Some(d) = expect_dim {
        ensure!(d == dim, "WAL dimension {dim} does not match the expected dimension {d}");
    }
    let (base_rows, header_len) = if v2 {
        ensure!(bytes.len() >= 24, "WAL {} is shorter than its v2 header", path.display());
        (u64::from_le_bytes(bytes[16..24].try_into().unwrap()), 24usize)
    } else {
        (0u64, 16usize)
    };
    let frame_len = 4 * (dim + 1);
    let mut rows = Dataset::empty("wal-replay", dim);
    let mut pos = header_len;
    let mut torn = false;
    let mut row = vec![0.0f32; dim];
    while pos < bytes.len() {
        if pos + 8 + frame_len > bytes.len() {
            torn = true; // partial frame at the tail
            break;
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        let payload = &bytes[pos + 8..pos + 8 + frame_len];
        if len != frame_len || crc32(payload) != crc {
            torn = true; // corrupt frame: stop, keep what came before
            break;
        }
        let label = f32::from_le_bytes(payload[..4].try_into().unwrap());
        for (j, v) in row.iter_mut().enumerate() {
            *v = f32::from_le_bytes(payload[4 + 4 * j..8 + 4 * j].try_into().unwrap());
        }
        rows.push_row(&row, label);
        pos += 8 + frame_len;
    }
    Ok(WalReplay { rows, torn_tail: torn, valid_bytes: pos as u64, base_rows })
}

/// One decoded checkpoint.
#[derive(Debug)]
pub struct Checkpoint {
    /// WAL rows that had been ingested when this checkpoint was written.
    pub rows_covered: u64,
    /// Registry version of the pinned model.
    pub version: u64,
    /// The pinned incumbent (scale folded, as published).
    pub model: AnyModel,
}

/// Atomically write a checkpoint: serialize to `<path>.tmp`, sync,
/// rename over `path`. A crash at any point leaves either the previous
/// checkpoint or the new one — never a torn file at `path`.
pub fn write_checkpoint(
    path: impl AsRef<Path>,
    model: &AnyModel,
    rows_covered: u64,
    version: u64,
) -> Result<()> {
    let path = path.as_ref();
    let mut model_bytes = Vec::new();
    model_io::save_any_writer(model, &mut model_bytes)?;
    let mut out = Vec::with_capacity(36 + model_bytes.len());
    out.extend_from_slice(CKPT_MAGIC);
    out.extend_from_slice(&rows_covered.to_le_bytes());
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&(model_bytes.len() as u64).to_le_bytes());
    out.extend_from_slice(&crc32(&model_bytes).to_le_bytes());
    out.extend_from_slice(&model_bytes);
    let tmp = path.with_extension("ckpt.tmp");
    {
        let mut f = File::create(&tmp)
            .with_context(|| format!("cannot create checkpoint tmp {}", tmp.display()))?;
        f.write_all(&out).context("checkpoint write failed")?;
        f.sync_data().context("checkpoint sync failed")?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("cannot install checkpoint {}", path.display()))?;
    Ok(())
}

/// Read and verify a checkpoint written by [`write_checkpoint`].
pub fn read_checkpoint(path: impl AsRef<Path>) -> Result<Checkpoint> {
    let path = path.as_ref();
    let mut bytes = Vec::new();
    File::open(path)
        .with_context(|| format!("cannot open checkpoint {}", path.display()))?
        .read_to_end(&mut bytes)
        .with_context(|| format!("cannot read checkpoint {}", path.display()))?;
    ensure!(bytes.len() >= 36, "checkpoint {} is shorter than its header", path.display());
    ensure!(&bytes[..8] == CKPT_MAGIC, "not a budgetsvm checkpoint (bad magic)");
    let rows_covered = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let version = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let model_len = u64::from_le_bytes(bytes[24..32].try_into().unwrap());
    let crc = u32::from_le_bytes(bytes[32..36].try_into().unwrap());
    ensure!(model_len <= MAX_CKPT_MODEL_BYTES, "implausible checkpoint model size {model_len}");
    ensure!(
        bytes.len() as u64 == 36 + model_len,
        "checkpoint length {} disagrees with its declared model size {model_len}",
        bytes.len()
    );
    let model_bytes = &bytes[36..];
    ensure!(crc32(model_bytes) == crc, "checkpoint CRC mismatch (corrupt file)");
    let model = model_io::load_any_reader(model_bytes)
        .context("checkpoint model body failed to load")?;
    Ok(Checkpoint { rows_covered, version, model })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelSpec;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("budgetsvm-wal");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn toy_batch(n: usize, dim: usize, salt: f32) -> Dataset {
        let mut ds = Dataset::empty("toy", dim);
        for i in 0..n {
            let row: Vec<f32> = (0..dim).map(|j| salt + i as f32 + j as f32 * 0.5).collect();
            ds.push_row(&row, if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        ds
    }

    #[test]
    fn crc32_matches_the_reference_vector() {
        // The canonical IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn append_then_replay_round_trips_bit_exactly() {
        let path = tmp("roundtrip.wal");
        let mut w = WalWriter::create(&path, 3).unwrap();
        let a = toy_batch(5, 3, 0.25);
        let b = toy_batch(2, 3, -7.5);
        w.append_rows(&a).unwrap();
        w.append_rows(&b).unwrap();
        assert_eq!(w.rows(), 7);
        let back = replay(&path, Some(3)).unwrap();
        assert!(!back.torn_tail);
        assert_eq!(back.rows.len(), 7);
        for i in 0..5 {
            assert_eq!(back.rows.row(i), a.row(i));
            assert_eq!(back.rows.label(i), a.label(i));
        }
        for i in 0..2 {
            assert_eq!(back.rows.row(5 + i), b.row(i));
            assert_eq!(back.rows.label(5 + i), b.label(i));
        }
        // Dimension mismatch is a typed error, not a silent mis-read.
        assert!(replay(&path, Some(4)).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_tail_is_detected_and_resume_truncates_it() {
        let path = tmp("torn.wal");
        let mut w = WalWriter::create(&path, 2).unwrap();
        w.append_rows(&toy_batch(4, 2, 1.0)).unwrap();
        w.inject_torn_frame().unwrap();
        drop(w);
        let back = replay(&path, Some(2)).unwrap();
        assert!(back.torn_tail, "the injected tear must be seen");
        assert_eq!(back.rows.len(), 4, "all acked rows survive the tear");
        // Resume drops the tear and appends cleanly after it.
        let (mut w, replayed) = WalWriter::resume(&path).unwrap();
        assert_eq!(replayed.rows.len(), 4);
        assert_eq!(w.rows(), 4);
        assert_eq!(w.dim(), 2);
        w.append_rows(&toy_batch(3, 2, 9.0)).unwrap();
        let healed = replay(&path, Some(2)).unwrap();
        assert!(!healed.torn_tail);
        assert_eq!(healed.rows.len(), 7);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_frame_stops_replay_at_the_last_valid_row() {
        let path = tmp("bitflip.wal");
        let mut w = WalWriter::create(&path, 2).unwrap();
        w.append_rows(&toy_batch(3, 2, 0.0)).unwrap();
        drop(w);
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one payload bit of the second frame: header(16) +
        // frame0(8+12) + frame1 header(8) + first payload byte.
        let idx = 16 + 20 + 8;
        bytes[idx] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let back = replay(&path, Some(2)).unwrap();
        assert!(back.torn_tail);
        assert_eq!(back.rows.len(), 1, "rows after the corrupt frame are dropped");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wal_header_corruption_is_a_typed_error() {
        let path = tmp("header.wal");
        std::fs::write(&path, b"short").unwrap();
        assert!(replay(&path, None).is_err());
        std::fs::write(&path, b"WRONGMAGxxxxxxxx").unwrap();
        assert!(replay(&path, None).is_err());
        let mut huge = Vec::new();
        huge.extend_from_slice(WAL_MAGIC);
        huge.extend_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &huge).unwrap();
        assert!(replay(&path, None).is_err(), "absurd dimension must not drive allocations");
        // A v2 segment cut off before its base field is a bad header,
        // not a torn tail.
        let mut short_v2 = Vec::new();
        short_v2.extend_from_slice(WAL_MAGIC_V2);
        short_v2.extend_from_slice(&2u64.to_le_bytes());
        std::fs::write(&path, &short_v2).unwrap();
        assert!(replay(&path, None).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rotation_drops_covered_frames_but_preserves_logical_accounting() {
        let path = tmp("rotate.wal");
        let mut w = WalWriter::create(&path, 2).unwrap();
        w.append_rows(&toy_batch(5, 2, 1.0)).unwrap();
        w.rotate(5).unwrap();
        assert_eq!(w.rows(), 5, "rotation never moves the logical count");
        assert_eq!(w.base_rows(), 5);
        let back = replay(&path, Some(2)).unwrap();
        assert_eq!(back.base_rows, 5);
        assert_eq!(back.rows.len(), 0, "frames under the checkpoint are gone");
        assert!(!back.torn_tail);
        // Appends continue in the new generation; resume sees base + tail.
        let fresh = toy_batch(3, 2, 4.0);
        w.append_rows(&fresh).unwrap();
        assert_eq!(w.rows(), 8);
        drop(w);
        let (mut w, replayed) = WalWriter::resume(&path).unwrap();
        assert_eq!(replayed.base_rows, 5);
        assert_eq!(replayed.rows.len(), 3);
        assert_eq!(replayed.rows.row(0), fresh.row(0));
        assert_eq!(w.rows(), 8);
        // A rotation base under the logical count would drop acked rows
        // the checkpoint does not cover — refused.
        assert!(w.rotate(5).is_err());
        w.rotate(8).unwrap();
        assert_eq!(replay(&path, Some(2)).unwrap().base_rows, 8);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn a_stray_rotation_tmp_never_confuses_resume() {
        // Crash between writing the rotation tmp and the rename: the old
        // segment is still the installed WAL; the tmp is garbage the
        // next rotation truncates.
        let path = tmp("rotate-torn.wal");
        let mut w = WalWriter::create(&path, 2).unwrap();
        w.append_rows(&toy_batch(4, 2, 2.0)).unwrap();
        let tmp_path = path.with_extension("wal.tmp");
        let mut header = Vec::new();
        header.extend_from_slice(WAL_MAGIC_V2);
        header.extend_from_slice(&2u64.to_le_bytes());
        header.extend_from_slice(&4u64.to_le_bytes());
        std::fs::write(&tmp_path, &header).unwrap();
        drop(w);
        let (w, replayed) = WalWriter::resume(&path).unwrap();
        assert_eq!(replayed.base_rows, 0, "the old generation stays authoritative");
        assert_eq!(replayed.rows.len(), 4);
        assert_eq!(w.rows(), 4);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&tmp_path).ok();
    }

    #[test]
    fn checkpoint_round_trips_the_model_bit_exactly() {
        let mut m = AnyModel::new(3, KernelSpec::gaussian(0.8), 3).unwrap();
        m.push(&[1.0, -0.5, 0.25], 0.75);
        m.push(&[0.0, 2.0, -1.0], -0.5);
        m.set_bias(-0.125);
        m.fold_scale();
        let path = tmp("ckpt.bin");
        write_checkpoint(&path, &m, 1234, 7).unwrap();
        let back = read_checkpoint(&path).unwrap();
        assert_eq!(back.rows_covered, 1234);
        assert_eq!(back.version, 7);
        assert_eq!(back.model.num_sv(), 2);
        for probe in [[0.0f32, 0.0, 0.0], [0.3, -0.7, 1.1]] {
            assert_eq!(
                back.model.decision(&probe).to_bits(),
                m.decision(&probe).to_bits()
            );
        }
        // No stray tmp file is left behind.
        assert!(!path.with_extension("ckpt.tmp").exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checkpoint_corruption_is_always_a_typed_error() {
        let mut m = AnyModel::new(2, KernelSpec::linear(), 1).unwrap();
        m.push(&[1.0, 0.0], 1.0);
        let path = tmp("ckpt-corrupt.bin");
        write_checkpoint(&path, &m, 5, 1).unwrap();
        let good = std::fs::read(&path).unwrap();
        // Truncation at every section boundary plus mid-body.
        for cut in [0usize, 7, 8, 16, 24, 32, 36, good.len() - 1] {
            std::fs::write(&path, &good[..cut]).unwrap();
            assert!(read_checkpoint(&path).is_err(), "cut at {cut}");
        }
        // A flipped model byte fails the CRC.
        let mut flipped = good.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x80;
        std::fs::write(&path, &flipped).unwrap();
        let err = read_checkpoint(&path).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
        // Trailing bytes are rejected too.
        let mut extended = good.clone();
        extended.push(0);
        std::fs::write(&path, &extended).unwrap();
        assert!(read_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}

//! Micro-batching prediction front end.
//!
//! Concurrent callers enqueue feature rows and block on a private reply
//! channel; one drain thread coalesces everything queued (up to
//! [`BatcherOptions::max_batch_rows`] rows per wakeup) into a single
//! [`crate::model::AnyModel::decision_rows`] call against the current
//! registry snapshot. Every request therefore rides the blocked SoA tile
//! engine — and, for larger batches, the chunked parallel row split —
//! instead of a scalar per-request `decision_function`.
//!
//! Batching never changes results: `decision_rows` is row-independent and
//! bit-identical for every thread count, so the labels a request receives
//! are exactly what an offline `predict_batch` on the same snapshot
//! returns. The snapshot is resolved once per batch, so all rows of one
//! batch are answered by one model version (stamped in the reply).
//!
//! ## Deadlines
//!
//! A request may carry a deadline ([`BatcherClient::predict_deadline`]).
//! The drain thread discards requests whose deadline has passed while
//! they waited in the queue and answers them with
//! [`PredictError::Overloaded`] — a typed backpressure signal, distinct
//! from malformed-request failures — so under overload a client's wait is
//! bounded by its own budget instead of the queue depth ahead of it.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::telemetry::{registry as metrics, Counter, Stage};

use super::registry::ModelRegistry;

/// Tuning knobs of the prediction front end.
#[derive(Debug, Clone)]
pub struct BatcherOptions {
    /// Coalescing cap: rows evaluated per drain wakeup (at least one
    /// request is always taken, even if it alone exceeds the cap).
    pub max_batch_rows: usize,
    /// Worker threads inside each `decision_rows` call (0 = all cores).
    pub threads: usize,
}

impl Default for BatcherOptions {
    fn default() -> Self {
        BatcherOptions { max_batch_rows: 64, threads: 0 }
    }
}

/// One answered prediction request.
#[derive(Debug, Clone)]
pub struct PredictReply {
    /// ±1 labels, one per requested row.
    pub labels: Vec<f32>,
    /// Version of the snapshot that produced them.
    pub version: u64,
}

/// Why a prediction request was not answered with labels.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PredictError {
    /// The request's deadline passed while it waited in the queue — a
    /// typed backpressure reply, not a failure: the client should back
    /// off and retry.
    Overloaded {
        /// How long the request waited before being expired, in ms.
        waited_ms: u64,
    },
    /// The request failed (malformed, no model published, dimension
    /// mismatch, or the batcher shut down).
    Failed(String),
}

impl std::fmt::Display for PredictError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PredictError::Overloaded { waited_ms } => {
                write!(f, "overloaded: predict deadline exceeded after {waited_ms} ms")
            }
            PredictError::Failed(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for PredictError {}

/// Aggregate counters (monotonic over the batcher's lifetime).
#[derive(Debug, Clone, Copy, Default)]
pub struct BatcherStats {
    /// Drain wakeups that executed a prediction batch.
    pub batches: u64,
    /// Total rows predicted.
    pub rows: u64,
    /// Largest single coalesced batch, in rows.
    pub largest_batch: usize,
    /// Requests expired in queue past their deadline (answered with
    /// [`PredictError::Overloaded`]).
    pub expired: u64,
}

struct Request {
    rows: Vec<f32>,
    n_rows: usize,
    dim: usize,
    /// Absolute expiry; `None` = wait however long it takes.
    deadline: Option<Instant>,
    enqueued: Instant,
    reply: mpsc::Sender<Result<PredictReply, PredictError>>,
}

#[derive(Default)]
struct QueueState {
    pending: VecDeque<Request>,
    shutdown: bool,
    stats: BatcherStats,
}

struct Shared {
    state: Mutex<QueueState>,
    wake: Condvar,
}

/// Cloneable, `Send` submission handle (the per-connection side).
#[derive(Clone)]
pub struct BatcherClient {
    shared: Arc<Shared>,
}

impl BatcherClient {
    /// Predict `n_rows` rows packed row-major in `rows` (`rows.len() ==
    /// n_rows * dim`). Blocks until the drain thread answers. Errors if
    /// the buffer is malformed, no model is published, the dimension
    /// disagrees with the current snapshot, or the batcher shut down.
    pub fn predict(&self, rows: &[f32], dim: usize) -> Result<PredictReply> {
        self.predict_deadline(rows, dim, None).map_err(|e| anyhow!(e.to_string()))
    }

    /// [`BatcherClient::predict`] with an optional deadline: if the
    /// request is still queued `timeout` after submission it is answered
    /// with [`PredictError::Overloaded`] instead of waiting further. A
    /// zero timeout expires deterministically (useful for tests).
    pub fn predict_deadline(
        &self,
        rows: &[f32],
        dim: usize,
        timeout: Option<Duration>,
    ) -> Result<PredictReply, PredictError> {
        if dim == 0 {
            return Err(PredictError::Failed("dimension must be positive".to_string()));
        }
        if rows.is_empty() || rows.len() % dim != 0 {
            return Err(PredictError::Failed(format!(
                "row buffer length {} is not a positive multiple of dim {dim}",
                rows.len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        let enqueued = Instant::now();
        {
            let mut st = self.shared.state.lock().expect("batcher lock poisoned");
            if st.shutdown {
                return Err(PredictError::Failed("batcher is shut down".to_string()));
            }
            st.pending.push_back(Request {
                rows: rows.to_vec(),
                n_rows: rows.len() / dim,
                dim,
                deadline: timeout.map(|t| enqueued + t),
                enqueued,
                reply: tx,
            });
        }
        self.shared.wake.notify_one();
        rx.recv()
            .map_err(|_| PredictError::Failed("batcher terminated before answering".to_string()))?
    }

    /// Lifetime counters (shared with the owning [`MicroBatcher`]).
    pub fn stats(&self) -> BatcherStats {
        self.shared.state.lock().expect("batcher lock poisoned").stats
    }
}

/// The batching front end: owns the drain thread. Obtain cheap
/// [`BatcherClient`] handles via [`MicroBatcher::client`] for concurrent
/// submitters; dropping the batcher drains the queue and joins the
/// thread.
pub struct MicroBatcher {
    shared: Arc<Shared>,
    drain: Option<JoinHandle<()>>,
}

impl MicroBatcher {
    pub fn new(registry: Arc<ModelRegistry>, opts: BatcherOptions) -> Self {
        let max_rows = opts.max_batch_rows.max(1);
        let threads = opts.threads;
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState::default()),
            wake: Condvar::new(),
        });
        let worker_shared = Arc::clone(&shared);
        let drain = std::thread::Builder::new()
            .name("predict-batcher".to_string())
            .spawn(move || drain_loop(&worker_shared, &registry, max_rows, threads))
            .expect("failed to spawn batcher drain thread");
        MicroBatcher { shared, drain: Some(drain) }
    }

    /// A cloneable submission handle.
    pub fn client(&self) -> BatcherClient {
        BatcherClient { shared: Arc::clone(&self.shared) }
    }

    /// Lifetime counters.
    pub fn stats(&self) -> BatcherStats {
        self.shared.state.lock().expect("batcher lock poisoned").stats
    }

    /// Stop accepting requests, answer what is queued, join the drain
    /// thread.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        {
            let mut st = self.shared.state.lock().expect("batcher lock poisoned");
            st.shutdown = true;
        }
        self.shared.wake.notify_all();
        if let Some(h) = self.drain.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MicroBatcher {
    fn drop(&mut self) {
        self.stop();
    }
}

fn drain_loop(shared: &Shared, registry: &ModelRegistry, max_rows: usize, threads: usize) {
    loop {
        // Collect one coalesced batch (or exit on drained shutdown),
        // expiring deadline-passed requests instead of serving them.
        let mut expired: Vec<Request> = Vec::new();
        let batch: Vec<Request> = {
            let mut st = shared.state.lock().expect("batcher lock poisoned");
            while st.pending.is_empty() && !st.shutdown {
                st = shared.wake.wait(st).expect("batcher lock poisoned");
            }
            if st.pending.is_empty() {
                return; // shutdown with an empty queue
            }
            let now = Instant::now();
            let mut batch = Vec::new();
            let mut rows = 0usize;
            while let Some(front) = st.pending.front() {
                // `now >= deadline` so a zero timeout expires even when
                // the clock has not advanced (deterministic tests).
                if front.deadline.map_or(false, |d| now >= d) {
                    st.stats.expired += 1;
                    metrics::count(Counter::DeadlineExpired);
                    expired.push(st.pending.pop_front().unwrap());
                    continue;
                }
                if !batch.is_empty() && rows + front.n_rows > max_rows {
                    break;
                }
                rows += front.n_rows;
                batch.push(st.pending.pop_front().unwrap());
            }
            batch
        };
        for req in expired {
            let waited_ms = req.enqueued.elapsed().as_millis() as u64;
            let _ = req.reply.send(Err(PredictError::Overloaded { waited_ms }));
        }
        if batch.is_empty() {
            continue; // everything queued had expired
        }

        let snapshot = registry.current();
        let Some(snapshot) = snapshot else {
            for req in batch {
                let _ = req
                    .reply
                    .send(Err(PredictError::Failed("no model published yet".to_string())));
            }
            continue;
        };
        let d = snapshot.model().dim();
        let version = snapshot.version();

        // Reject dimension mismatches individually; evaluate the rest as
        // one flat buffer.
        let mut flat: Vec<f32> = Vec::new();
        let mut accepted: Vec<Request> = Vec::new();
        for req in batch {
            if req.dim != d {
                let _ = req.reply.send(Err(PredictError::Failed(format!(
                    "request dimension {} does not match the serving dimension {d}",
                    req.dim
                ))));
            } else {
                // Queue wait of a request that will actually be served:
                // submission to batch assembly (the tail the predict
                // deadline guards against).
                metrics::record_stage_ns(
                    Stage::BatchQueueWait,
                    req.enqueued.elapsed().as_nanos() as u64,
                );
                flat.extend_from_slice(&req.rows);
                accepted.push(req);
            }
        }
        if accepted.is_empty() {
            continue;
        }
        // Count only rows that actually get predicted (rejected requests
        // must not inflate the throughput counters).
        let batch_rows = flat.len() / d;
        {
            let mut st = shared.state.lock().expect("batcher lock poisoned");
            st.stats.batches += 1;
            st.stats.rows += batch_rows as u64;
            st.stats.largest_batch = st.stats.largest_batch.max(batch_rows);
        }
        let decisions = snapshot.model().decision_rows(&flat, threads);
        let mut offset = 0usize;
        for req in accepted {
            let labels: Vec<f32> = decisions[offset..offset + req.n_rows]
                .iter()
                .map(|&f| if f >= 0.0 { 1.0 } else { -1.0 })
                .collect();
            offset += req.n_rows;
            let _ = req.reply.send(Ok(PredictReply { labels, version }));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::KernelSpec;
    use crate::model::AnyModel;
    use crate::util::rng::Rng;

    fn registry_with_model(num_sv: usize, d: usize, seed: u64) -> Arc<ModelRegistry> {
        let mut rng = Rng::new(seed);
        let mut m = AnyModel::new(d, KernelSpec::gaussian(0.5), num_sv).unwrap();
        for _ in 0..num_sv {
            let row: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            m.push(&row, rng.normal());
        }
        let reg = Arc::new(ModelRegistry::new());
        reg.publish(m);
        reg
    }

    #[test]
    fn batched_labels_match_offline_predict_batch() {
        let reg = registry_with_model(12, 3, 7);
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let client = batcher.client();
        let mut rng = Rng::new(99);
        let rows: Vec<f32> = (0..3 * 40).map(|_| rng.normal() as f32).collect();
        let reply = client.predict(&rows, 3).unwrap();
        assert_eq!(reply.labels.len(), 40);
        assert_eq!(reply.version, 1);
        let snap = reg.current().unwrap();
        let offline: Vec<f32> = snap
            .model()
            .decision_rows(&rows, 1)
            .into_iter()
            .map(|f| if f >= 0.0 { 1.0 } else { -1.0 })
            .collect();
        assert_eq!(reply.labels, offline);
        let stats = batcher.stats();
        assert_eq!(stats.rows, 40);
        assert!(stats.batches >= 1);
        assert_eq!(stats.expired, 0);
        batcher.shutdown();
    }

    #[test]
    fn concurrent_clients_all_get_correct_answers() {
        let reg = registry_with_model(8, 2, 3);
        let batcher =
            MicroBatcher::new(Arc::clone(&reg), BatcherOptions { max_batch_rows: 16, threads: 1 });
        let snap = reg.current().unwrap();
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let client = batcher.client();
                let model = snap.model();
                scope.spawn(move || {
                    let mut rng = Rng::new(1000 + t);
                    for _ in 0..25 {
                        let row = [rng.normal() as f32, rng.normal() as f32];
                        let reply = client.predict(&row, 2).unwrap();
                        let expect = if model.decision(&row) >= 0.0 { 1.0 } else { -1.0 };
                        assert_eq!(reply.labels, vec![expect]);
                        assert_eq!(reply.version, 1);
                    }
                });
            }
        });
        let stats = batcher.stats();
        assert_eq!(stats.rows, 8 * 25);
        assert!(stats.largest_batch >= 1);
    }

    #[test]
    fn empty_registry_and_bad_dimensions_error_cleanly() {
        let empty = Arc::new(ModelRegistry::new());
        let batcher = MicroBatcher::new(Arc::clone(&empty), BatcherOptions::default());
        let client = batcher.client();
        let err = client.predict(&[0.0, 0.0], 2).unwrap_err().to_string();
        assert!(err.contains("no model published"), "{err}");
        // Malformed buffers are rejected before queuing.
        assert!(client.predict(&[], 2).is_err());
        assert!(client.predict(&[1.0, 2.0, 3.0], 2).is_err());
        drop(batcher);

        let reg = registry_with_model(4, 3, 1);
        let batcher = MicroBatcher::new(reg, BatcherOptions::default());
        let err = batcher.client().predict(&[1.0, 2.0], 2).unwrap_err().to_string();
        assert!(err.contains("serving dimension"), "{err}");
        batcher.shutdown();
    }

    #[test]
    fn predictions_follow_hot_swaps() {
        let reg = registry_with_model(4, 2, 5);
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let client = batcher.client();
        let probe = [0.25f32, -0.5];
        assert_eq!(client.predict(&probe, 2).unwrap().version, 1);
        // Publish a constant-positive and a constant-negative model.
        for (bias, expect_label) in [(5.0, 1.0f32), (-5.0, -1.0f32)] {
            let mut m = AnyModel::new(2, KernelSpec::gaussian(0.5), 1).unwrap();
            m.push(&[0.0, 0.0], 0.0);
            m.set_bias(bias);
            let v = reg.publish(m);
            let reply = client.predict(&probe, 2).unwrap();
            assert_eq!(reply.version, v);
            assert_eq!(reply.labels, vec![expect_label]);
        }
        batcher.shutdown();
    }

    #[test]
    fn zero_deadline_requests_expire_with_a_typed_overloaded_error() {
        let reg = registry_with_model(4, 2, 9);
        let batcher = MicroBatcher::new(Arc::clone(&reg), BatcherOptions::default());
        let client = batcher.client();
        // A zero timeout is already past its deadline when the drain
        // thread sees it: deterministic expiry, no wall-clock dependence.
        let err = client
            .predict_deadline(&[0.5, -0.5], 2, Some(Duration::ZERO))
            .unwrap_err();
        match err {
            PredictError::Overloaded { .. } => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert!(err.to_string().contains("overloaded"), "{err}");
        assert_eq!(batcher.stats().expired, 1);
        // A generous deadline still answers normally.
        let reply = client
            .predict_deadline(&[0.5, -0.5], 2, Some(Duration::from_secs(30)))
            .unwrap();
        assert_eq!(reply.labels.len(), 1);
        assert_eq!(client.stats().expired, 1);
        batcher.shutdown();
    }
}

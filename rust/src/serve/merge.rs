//! Shard-model merging for the publish step of the ingest pipeline.
//!
//! Semantics (documented invariants in [`super`] module docs): the merged
//! model is the step-weighted average `Σ_s w_s · f_s` of the shard
//! decision functions (`w_s ∝ SGD steps of shard s`, normalized), with
//! the same weighting applied to the biases. The concatenated expansion
//! can hold up to `S·B` support vectors, so the budget is re-enforced
//! through the *same* maintenance machinery training uses — the paper's
//! merge solvers for Gaussian models, removal/projection otherwise —
//! until at most `budget` SVs remain.
//!
//! `S = 1` short-circuits to a clone of the single shard model, keeping
//! the one-shard pipeline equivalent to serial `partial_fit`.

use anyhow::{ensure, Result};

use crate::budget::{gaussian_policy, generic_policy, MaintenanceConfig};
use crate::metrics::SectionProfiler;
use crate::model::AnyModel;

/// Merge shard models into one budget-respecting model.
///
/// `weights` are per-shard publish weights (normalized internally;
/// typically each shard's cumulative SGD step count). All shards must
/// share one kernel spec and dimension. `budget = 0` skips enforcement
/// (unbudgeted). Budget enforcement dispatches through the same
/// [`crate::budget::MaintenancePolicy`] pipeline training uses
/// (`maint.effective_pairs()` pairs per sweep — a shard merge holding up
/// to `S·B` SVs benefits directly from a multi-pair quota). The returned
/// model has its lazy scale folded by the construction (coefficients are
/// pushed in effective units into a fresh model).
pub fn merge_shard_models(
    shards: Vec<AnyModel>,
    weights: &[f64],
    budget: usize,
    maint: &MaintenanceConfig,
) -> Result<AnyModel> {
    ensure!(!shards.is_empty(), "cannot merge zero shard models");
    ensure!(shards.len() == weights.len(), "one weight per shard model required");
    let total: f64 = weights.iter().sum();
    ensure!(
        weights.iter().all(|w| w.is_finite() && *w >= 0.0) && total > 0.0,
        "shard weights must be non-negative with a positive sum"
    );

    let spec = shards[0].kernel_spec();
    let d = shards[0].dim();
    for m in &shards {
        ensure!(
            m.kernel_spec() == spec && m.dim() == d,
            "shard models disagree on kernel/dimension: {} d={} vs {} d={}",
            m.kernel_spec().describe(),
            m.dim(),
            spec.describe(),
            d
        );
    }

    if shards.len() == 1 {
        // Single shard: weight is 1 after normalization — publish the
        // model as-is so the one-shard pipeline stays equivalent to
        // serial partial_fit.
        return Ok(shards.into_iter().next().unwrap());
    }

    let capacity: usize = shards.iter().map(|m| m.num_sv()).sum::<usize>().max(budget + 1);
    let mut merged = AnyModel::new(d, spec, capacity)?;
    // Preserve the shards' exponential tier (a runtime execution choice
    // the kernel spec deliberately does not carry).
    merged.set_fast_exp(shards[0].fast_exp());
    let mut bias = 0.0f64;
    for (m, &w) in shards.iter().zip(weights) {
        let w = w / total;
        for j in 0..m.num_sv() {
            merged.push(m.sv(j), w * m.alpha(j));
        }
        bias += w * m.bias();
    }
    merged.set_bias(bias);

    if budget > 0 {
        let mut prof = SectionProfiler::new();
        match &mut merged {
            AnyModel::Gaussian(g) => {
                let mut policy = gaussian_policy(maint);
                policy.enforce(g, budget, &mut prof);
            }
            AnyModel::Linear(m) => {
                let mut policy = generic_policy(maint)?;
                policy.enforce(m, budget, &mut prof);
            }
            AnyModel::Polynomial(m) => {
                let mut policy = generic_policy(maint)?;
                policy.enforce(m, budget, &mut prof);
            }
        }
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::{MergeSolver, Strategy};
    use crate::kernel::KernelSpec;

    fn maint(strategy: Strategy) -> MaintenanceConfig {
        MaintenanceConfig::new(strategy, 50)
    }

    fn shard(spec: KernelSpec, points: &[([f32; 2], f64)], bias: f64) -> AnyModel {
        let mut m = AnyModel::new(2, spec, points.len().max(1)).unwrap();
        for (x, a) in points {
            m.push(x, *a);
        }
        m.set_bias(bias);
        m
    }

    #[test]
    fn two_shard_merge_is_the_weighted_average() {
        let spec = KernelSpec::gaussian(0.5);
        let a = shard(spec, &[([0.0, 0.0], 1.0)], 0.5);
        let b = shard(spec, &[([1.0, 1.0], -2.0)], -0.25);
        // Weights 3:1 → w_a = 0.75, w_b = 0.25; budget large enough that
        // no shrink happens.
        let merged =
            merge_shard_models(vec![a.clone(), b.clone()], &[3.0, 1.0], 10, &maint(Strategy::Removal))
                .unwrap();
        assert_eq!(merged.num_sv(), 2);
        for probe in [[0.2f32, -0.3], [1.5, 0.5]] {
            let expect = 0.75 * a.decision(&probe) + 0.25 * b.decision(&probe);
            assert!(
                (merged.decision(&probe) - expect).abs() < 1e-12,
                "{} vs {expect}",
                merged.decision(&probe)
            );
        }
    }

    #[test]
    fn single_shard_merge_returns_the_model_unchanged() {
        let spec = KernelSpec::gaussian(0.5);
        let a = shard(spec, &[([0.3, -0.6], 0.8), ([1.0, 0.0], -0.4)], 0.125);
        let merged =
            merge_shard_models(vec![a.clone()], &[17.0], 10, &maint(Strategy::Removal)).unwrap();
        let probe = [0.7f32, 0.1];
        assert_eq!(merged.decision(&probe).to_bits(), a.decision(&probe).to_bits());
    }

    #[test]
    fn budget_is_enforced_on_the_merged_model() {
        let spec = KernelSpec::gaussian(0.5);
        let mk = |seed: f32| {
            let pts: Vec<([f32; 2], f64)> =
                (0..6).map(|j| ([seed + j as f32 * 0.3, seed - j as f32 * 0.2], 0.4)).collect();
            shard(spec, &pts, 0.0)
        };
        for strategy in
            [Strategy::Merge(MergeSolver::LookupWd), Strategy::Removal, Strategy::Projection]
        {
            let merged = merge_shard_models(
                vec![mk(0.0), mk(1.0), mk(-1.0)],
                &[1.0, 1.0, 1.0],
                5,
                &maint(strategy),
            )
            .unwrap();
            assert!(merged.num_sv() <= 5, "{strategy:?}: {}", merged.num_sv());
        }
    }

    #[test]
    fn multi_pair_quota_enforces_the_same_budget() {
        // A merged pool of 18 SVs shrunk to 5 through multi-pair sweeps
        // must land exactly on the budget, like the single-pair path.
        let spec = KernelSpec::gaussian(0.5);
        let mk = |seed: f32| {
            let pts: Vec<([f32; 2], f64)> =
                (0..6).map(|j| ([seed + j as f32 * 0.3, seed - j as f32 * 0.2], 0.4)).collect();
            shard(spec, &pts, 0.0)
        };
        let cfg = MaintenanceConfig {
            pairs: 4,
            ..maint(Strategy::Merge(MergeSolver::LookupWd))
        };
        let merged =
            merge_shard_models(vec![mk(0.0), mk(1.0), mk(-1.0)], &[1.0, 1.0, 1.0], 5, &cfg)
                .unwrap();
        assert_eq!(merged.num_sv(), 5);
    }

    #[test]
    fn non_gaussian_shards_merge_under_removal_and_projection() {
        for spec in [KernelSpec::linear(), KernelSpec::polynomial(2, 1.0)] {
            let a = shard(spec, &[([1.0, 0.0], 1.0), ([0.5, 0.5], 0.3)], 0.0);
            let b = shard(spec, &[([0.0, 1.0], -1.0), ([0.25, 0.75], 0.1)], 0.0);
            for strategy in [Strategy::Removal, Strategy::Projection] {
                let merged =
                    merge_shard_models(vec![a.clone(), b.clone()], &[1.0, 1.0], 3, &maint(strategy))
                        .unwrap();
                assert!(merged.num_sv() <= 3, "{}", spec.describe());
                assert_eq!(merged.kernel_spec(), spec);
            }
        }
    }

    #[test]
    fn merge_rejects_bad_inputs() {
        let spec = KernelSpec::gaussian(0.5);
        let m = maint(Strategy::Removal);
        let a = shard(spec, &[([0.0, 0.0], 1.0)], 0.0);
        assert!(merge_shard_models(Vec::new(), &[], 5, &m).is_err());
        assert!(merge_shard_models(vec![a.clone()], &[], 5, &m).is_err());
        assert!(merge_shard_models(vec![a.clone()], &[0.0], 5, &m).is_err());
        let other = shard(KernelSpec::linear(), &[([0.0, 0.0], 1.0)], 0.0);
        assert!(merge_shard_models(vec![a, other], &[1.0, 1.0], 5, &m).is_err());
    }
}

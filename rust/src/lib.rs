//! # budgetsvm — Budgeted SGD SVM training with precomputed golden section search
//!
//! A production reproduction of *"Speeding Up Budgeted Stochastic Gradient
//! Descent SVM Training with Precomputed Golden Section Search"*
//! (Glasmachers & Qaadan, 2018) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the full BSGD training system: data
//!   pipeline, Gaussian-kernel sparse model with lazy scaling, golden
//!   section search, the paper's precomputed lookup tables with bilinear
//!   interpolation, merge/removal/projection budget maintenance, the
//!   instrumented trainer, an SMO reference solver, and the experiment
//!   runner that regenerates every table and figure of the paper.
//! * **Layer 2 (python/compile/model.py, build-time only)** — the batched
//!   decision function and merge-candidate scan as JAX graphs, AOT-lowered
//!   to HLO text.
//! * **Layer 1 (python/compile/kernels/, build-time only)** — Pallas
//!   kernels for the Gaussian decision hot spot and the table-lookup merge
//!   scan, verified against pure-jnp oracles.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate) so the compute path runs with **no Python at runtime**.
//!
//! ## Quickstart
//!
//! ```no_run
//! use budgetsvm::data::synthetic::two_moons;
//! use budgetsvm::solver::{train_bsgd, BsgdOptions};
//!
//! let data = two_moons(2000, 0.12, 42);
//! let opts = BsgdOptions::with_c(/*budget=*/ 50, /*C=*/ 10.0, /*gamma=*/ 2.0, data.len());
//! let report = train_bsgd(&data, &opts);
//! println!("accuracy = {:.3}", report.model.accuracy(&data));
//! println!("merging frequency = {:.3}", report.merging_frequency());
//! ```

pub mod budget;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod kernel;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod solver;
pub mod util;

//! # budgetsvm — Budgeted SGD SVM training with precomputed golden section search
//!
//! A production reproduction of *"Speeding Up Budgeted Stochastic Gradient
//! Descent SVM Training with Precomputed Golden Section Search"*
//! (Glasmachers & Qaadan, 2018) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the full BSGD training system: data
//!   pipeline, kernel-generic sparse models with lazy scaling, golden
//!   section search, the paper's precomputed lookup tables with bilinear
//!   interpolation, merge/removal/projection budget maintenance, the
//!   instrumented trainers behind one [`solver::Estimator`] surface, an SMO
//!   reference solver, and the experiment runner that regenerates every
//!   table and figure of the paper.
//! * **Layer 2 (python/compile/model.py, build-time only)** — the batched
//!   decision function and merge-candidate scan as JAX graphs, AOT-lowered
//!   to HLO text.
//! * **Layer 1 (python/compile/kernels/, build-time only)** — Pallas
//!   kernels for the Gaussian decision hot spot and the table-lookup merge
//!   scan, verified against pure-jnp oracles.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate, behind the `pjrt` cargo feature) so the compute path runs
//! with **no Python at runtime**.
//!
//! ## Quickstart
//!
//! Every trainer implements the same [`solver::Estimator`] contract —
//! `fit`, `partial_fit` (streaming ingest), `decision_function`,
//! `predict_batch` — configured by a [`solver::SvmConfig`] builder with a
//! typed [`kernel::KernelSpec`]:
//!
//! ```no_run
//! use budgetsvm::data::synthetic::two_moons;
//! use budgetsvm::prelude::*;
//!
//! let train = two_moons(2000, 0.12, 42);
//!
//! // Gaussian kernel with the paper's Lookup-WD merging.
//! let config = SvmConfig::new()
//!     .kernel(KernelSpec::gaussian(2.0))
//!     .budget(50)
//!     .c(10.0, train.len())
//!     .strategy(Strategy::Merge(MergeSolver::LookupWd));
//! let mut est = BsgdEstimator::new(config, RunConfig::new().passes(5)).unwrap();
//! est.fit(&train).unwrap();
//! println!("support vectors = {}", est.model().unwrap().num_sv());
//! println!("merging frequency = {:.3}", est.summary().unwrap().merging_frequency());
//!
//! // Non-Gaussian kernels use removal maintenance (merging is
//! // Gaussian-specific); models persist in the versioned BSVMMDL2 format.
//! let poly = SvmConfig::new()
//!     .kernel(KernelSpec::polynomial(3, 1.0))
//!     .budget(50)
//!     .c(10.0, train.len())
//!     .strategy(Strategy::Removal);
//! let mut est = BsgdEstimator::new(poly, RunConfig::new().passes(5)).unwrap();
//! est.fit(&train).unwrap();
//! budgetsvm::model::io::save_any(est.model().unwrap(), "model.bsvm").unwrap();
//! let back = budgetsvm::model::io::load_any("model.bsvm").unwrap();
//! # let _ = back;
//! ```
//!
//! Streaming ingest — the production path — continues training without a
//! reset: `est.partial_fit(&batch)` consumes each batch in presented
//! order, so a `fit` with `RunConfig::new().shuffle(false)` over one pass
//! and a single `partial_fit` of the same rows produce identical models.
//!
//! The [`serve`] subsystem (`repro serve`) runs training and prediction
//! *concurrently* on one model lineage: a hot-swap
//! [`serve::ModelRegistry`] of versioned snapshots, a micro-batching
//! prediction front end riding the blocked tile engine, and a sharded
//! `partial_fit` ingest pipeline that periodically merges shard models
//! into one budget-respecting snapshot and publishes it without pausing
//! readers.

pub mod budget;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod kernel;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod telemetry;
pub mod util;

/// One-line import for the estimator surface: configuration types, the
/// [`solver::Estimator`] trait, the four estimator implementations, the
/// runtime-polymorphic [`model::AnyModel`], and the serving subsystem's
/// registry + configuration ([`serve`]).
pub mod prelude {
    pub use crate::budget::{MaintenanceConfig, MaintenancePolicy, MergeSolver, Strategy};
    pub use crate::kernel::KernelSpec;
    pub use crate::model::AnyModel;
    pub use crate::serve::{ModelRegistry, ServeConfig};
    pub use crate::solver::{
        AnyEstimator, BdcaEstimator, BsgdEstimator, Estimator, FitSummary, OneVsRestEstimator,
        PegasosEstimator, RunConfig, SmoEstimator, SolverSpec, SvmConfig,
    };
}

//! Model persistence: save/load a trained [`BudgetModel`] in a compact
//! binary format so training and serving can be separate processes
//! (`repro train --model-out m.bsvm` → `repro eval m.bsvm data.libsvm`).
//!
//! Format: magic `BSVMMDL1`, then little-endian u64 `d`, u64 `count`,
//! f64 `gamma`, f64 `bias`, `count` f64 effective coefficients, and
//! `count·d` f32 support-vector values.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::kernel::Gaussian;

use super::BudgetModel;

const MAGIC: &[u8; 8] = b"BSVMMDL1";

/// Serialize a model (effective coefficients; the lazy scale is folded).
pub fn save(model: &BudgetModel, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("cannot create {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(model.dim() as u64).to_le_bytes())?;
    w.write_all(&(model.num_sv() as u64).to_le_bytes())?;
    w.write_all(&model.kernel().gamma.to_le_bytes())?;
    w.write_all(&model.bias.to_le_bytes())?;
    for j in 0..model.num_sv() {
        w.write_all(&model.alpha(j).to_le_bytes())?;
    }
    for j in 0..model.num_sv() {
        for &v in model.sv(j) {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Load a model saved by [`save`].
pub fn load(path: impl AsRef<Path>) -> Result<BudgetModel> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("cannot open {}", path.as_ref().display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a budgetsvm model file (bad magic)");
    }
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let d = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let count = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8)?;
    let gamma = f64::from_le_bytes(b8);
    r.read_exact(&mut b8)?;
    let bias = f64::from_le_bytes(b8);
    if d == 0 || d > 1_000_000 || count > 10_000_000 {
        bail!("implausible model header: d={d}, count={count}");
    }
    if !(gamma.is_finite() && gamma > 0.0 && bias.is_finite()) {
        bail!("implausible model parameters: gamma={gamma}, bias={bias}");
    }
    let mut alphas = vec![0.0f64; count];
    for a in alphas.iter_mut() {
        r.read_exact(&mut b8)?;
        *a = f64::from_le_bytes(b8);
    }
    let mut model = BudgetModel::new(d, Gaussian::new(gamma), count);
    model.bias = bias;
    let mut b4 = [0u8; 4];
    let mut row = vec![0.0f32; d];
    for &alpha in &alphas {
        for v in row.iter_mut() {
            r.read_exact(&mut b4)?;
            *v = f32::from_le_bytes(b4);
        }
        model.push(&row, alpha);
    }
    Ok(model)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::solver::{train_bsgd, BsgdOptions};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("budgetsvm-model-io");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip_preserves_decision_function() {
        let ds = two_moons(400, 0.12, 3);
        let mut opts = BsgdOptions::with_c(25, 10.0, 2.0, ds.len());
        opts.passes = 3;
        let report = train_bsgd(&ds, &opts);
        let path = tmp("m.bsvm");
        save(&report.model, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.num_sv(), report.model.num_sv());
        assert_eq!(loaded.dim(), 2);
        for i in 0..ds.len() {
            let a = report.model.decision(ds.row(i));
            let b = loaded.decision(ds.row(i));
            assert!((a - b).abs() < 1e-9, "row {i}: {a} vs {b}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corrupt_files() {
        let path = tmp("bad.bsvm");
        std::fs::write(&path, b"BSVMMDL1 but truncated").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, b"WRONGMAG").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scale_is_folded_on_save() {
        let mut m = BudgetModel::new(2, Gaussian::new(1.0), 2);
        m.push(&[1.0, 0.0], 2.0);
        m.rescale(0.25);
        let path = tmp("scaled.bsvm");
        save(&m, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert!((loaded.alpha(0) - 0.5).abs() < 1e-12);
        assert!((loaded.decision(&[1.0, 0.0]) - m.decision(&[1.0, 0.0])).abs() < 1e-12);
        std::fs::remove_file(&path).ok();
    }
}

//! Model persistence: save/load a trained model in a compact binary format
//! so training and serving can be separate processes
//! (`repro train --model-out m.bsvm` → `repro eval m.bsvm data.libsvm`).
//!
//! Two format versions:
//!
//! * **`BSVMMDL2`** (current, written by [`save`]/[`save_any`]): magic,
//!   little-endian u64 `d`, u64 `count`, u32 kernel tag
//!   (0 = gaussian, 1 = linear, 2 = polynomial) followed by the kernel
//!   parameters (gaussian: f64 `gamma`; linear: none; polynomial: u32
//!   `degree`, f64 `coef0`), f64 `bias`, `count` f64 effective
//!   coefficients, and `count·d` f32 support-vector values. The kernel
//!   spec in the header is what makes a saved model self-describing across
//!   kernel families.
//! * **`BSVMMDL1`** (legacy, read-only): magic, u64 `d`, u64 `count`,
//!   f64 `gamma`, f64 `bias`, coefficients, support vectors — always a
//!   Gaussian model. [`load_any`]/[`load`] accept both versions, so every
//!   pre-refactor model file keeps loading.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::kernel::{Gaussian, Kernel, KernelSpec};

use super::{AnyModel, BudgetModel};

const MAGIC_V1: &[u8; 8] = b"BSVMMDL1";
const MAGIC_V2: &[u8; 8] = b"BSVMMDL2";

/// Kernel tags of the v2 header.
const TAG_GAUSSIAN: u32 = 0;
const TAG_LINEAR: u32 = 1;
const TAG_POLYNOMIAL: u32 = 2;

/// Serialize a model in the v2 format to any writer (effective
/// coefficients; the lazy scale is folded into them). Works for any kernel
/// whose parameters round-trip through its [`KernelSpec`] — a hand-built
/// `Polynomial` with `scale != 1` is rejected rather than silently
/// altered. This is the in-memory entry point the serving registry uses to
/// dump live snapshots without touching the filesystem.
pub fn save_writer<K: Kernel + Copy>(model: &BudgetModel<K>, writer: impl Write) -> Result<()> {
    let spec = model.kernel().spec();
    ensure!(
        spec.describe() == model.kernel().describe(),
        "kernel {} does not round-trip through its spec and cannot be serialized",
        model.kernel().describe()
    );
    let mut w = BufWriter::new(writer);
    w.write_all(MAGIC_V2)?;
    w.write_all(&(model.dim() as u64).to_le_bytes())?;
    w.write_all(&(model.num_sv() as u64).to_le_bytes())?;
    match spec {
        KernelSpec::Gaussian { gamma } => {
            w.write_all(&TAG_GAUSSIAN.to_le_bytes())?;
            w.write_all(&gamma.to_le_bytes())?;
        }
        KernelSpec::Linear => {
            w.write_all(&TAG_LINEAR.to_le_bytes())?;
        }
        KernelSpec::Polynomial { degree, coef0 } => {
            w.write_all(&TAG_POLYNOMIAL.to_le_bytes())?;
            w.write_all(&degree.to_le_bytes())?;
            w.write_all(&coef0.to_le_bytes())?;
        }
    }
    w.write_all(&model.bias.to_le_bytes())?;
    for j in 0..model.num_sv() {
        w.write_all(&model.alpha(j).to_le_bytes())?;
    }
    for j in 0..model.num_sv() {
        for &v in model.sv(j) {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Serialize a model in the v2 format to a file.
pub fn save<K: Kernel + Copy>(model: &BudgetModel<K>, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("cannot create {}", path.as_ref().display()))?;
    save_writer(model, f)
}

/// Serialize an [`AnyModel`] in the v2 format to any writer.
pub fn save_any_writer(model: &AnyModel, writer: impl Write) -> Result<()> {
    match model {
        AnyModel::Gaussian(m) => save_writer(m, writer),
        AnyModel::Linear(m) => save_writer(m, writer),
        AnyModel::Polynomial(m) => save_writer(m, writer),
    }
}

/// Serialize an [`AnyModel`] in the v2 format to a file.
pub fn save_any(model: &AnyModel, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("cannot create {}", path.as_ref().display()))?;
    save_any_writer(model, f)
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

/// Read the common body (bias, coefficients, support vectors) into a fresh
/// model for `spec`.
fn read_body(r: &mut impl Read, d: usize, count: usize, spec: KernelSpec) -> Result<AnyModel> {
    if d == 0 || d > 1_000_000 || count > 10_000_000 {
        bail!("implausible model header: d={d}, count={count}");
    }
    // Bound the total buffer too: d and count can each pass their own
    // check while count·d would demand an absurd allocation (a crafted
    // 40-byte header must produce an error, not an allocation abort).
    if count.saturating_mul(d) > 100_000_000 {
        bail!("implausible model size: count={count} × d={d} support-vector values");
    }
    spec.validate().context("implausible kernel parameters")?;
    let bias = read_f64(r)?;
    ensure!(bias.is_finite(), "implausible model bias {bias}");
    let mut alphas = vec![0.0f64; count];
    for (j, a) in alphas.iter_mut().enumerate() {
        *a = read_f64(r)?;
        ensure!(a.is_finite(), "non-finite coefficient {a} at index {j} (corrupt file)");
    }
    let mut model = AnyModel::new(d, spec, count)?;
    model.set_bias(bias);
    let mut b4 = [0u8; 4];
    let mut row = vec![0.0f32; d];
    for &alpha in &alphas {
        for v in row.iter_mut() {
            r.read_exact(&mut b4)?;
            *v = f32::from_le_bytes(b4);
        }
        model.push(&row, alpha);
    }
    Ok(model)
}

/// Load a model in either format version from any reader (the in-memory
/// sibling of [`load_any`], used by the serving registry to rehydrate
/// snapshots).
pub fn load_any_reader(reader: impl Read) -> Result<AnyModel> {
    let mut r = BufReader::new(reader);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    let model = if &magic == MAGIC_V1 {
        // Legacy layout: d, count, gamma, bias, body — always Gaussian.
        let d = read_u64(&mut r)? as usize;
        let count = read_u64(&mut r)? as usize;
        let gamma = read_f64(&mut r)?;
        read_body(&mut r, d, count, KernelSpec::Gaussian { gamma })?
    } else if &magic == MAGIC_V2 {
        let d = read_u64(&mut r)? as usize;
        let count = read_u64(&mut r)? as usize;
        let spec = match read_u32(&mut r)? {
            TAG_GAUSSIAN => KernelSpec::Gaussian { gamma: read_f64(&mut r)? },
            TAG_LINEAR => KernelSpec::Linear,
            TAG_POLYNOMIAL => {
                let degree = read_u32(&mut r)?;
                let coef0 = read_f64(&mut r)?;
                KernelSpec::Polynomial { degree, coef0 }
            }
            tag => bail!("unknown kernel tag {tag} in model header"),
        };
        read_body(&mut r, d, count, spec)?
    } else {
        bail!("not a budgetsvm model file (bad magic)");
    };
    // The body must be the end of the stream: trailing bytes mean either a
    // corrupted length field (the declared sections did not consume the
    // file) or an appended payload — both are load errors, not data to
    // silently ignore.
    let mut probe = [0u8; 1];
    ensure!(
        r.read(&mut probe)? == 0,
        "trailing bytes after model body (corrupt length field or oversized file)"
    );
    Ok(model)
}

/// Load a model saved in either format version from a file.
pub fn load_any(path: impl AsRef<Path>) -> Result<AnyModel> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("cannot open {}", path.as_ref().display()))?;
    load_any_reader(f)
}

/// Load a Gaussian model (either format version). Errors if the file holds
/// a non-Gaussian model — use [`load_any`] for the kernel-generic path.
pub fn load(path: impl AsRef<Path>) -> Result<BudgetModel<Gaussian>> {
    load_any(path)?.into_gaussian()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::kernel::{Linear, Polynomial};
    use crate::solver::{train_bsgd, BsgdOptions};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("budgetsvm-model-io");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// Byte-for-byte writer of the legacy v1 format (what the pre-refactor
    /// `save` produced) — the reader must keep accepting these files.
    fn write_v1(model: &BudgetModel<Gaussian>, path: &std::path::Path) {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&(model.dim() as u64).to_le_bytes());
        bytes.extend_from_slice(&(model.num_sv() as u64).to_le_bytes());
        bytes.extend_from_slice(&model.kernel().gamma.to_le_bytes());
        bytes.extend_from_slice(&model.bias.to_le_bytes());
        for j in 0..model.num_sv() {
            bytes.extend_from_slice(&model.alpha(j).to_le_bytes());
        }
        for j in 0..model.num_sv() {
            for &v in model.sv(j) {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        std::fs::write(path, bytes).unwrap();
    }

    #[test]
    fn round_trip_preserves_decision_function() {
        let ds = two_moons(400, 0.12, 3);
        let mut opts = BsgdOptions::with_c(25, 10.0, 2.0, ds.len());
        opts.passes = 3;
        let report = train_bsgd(&ds, &opts);
        let path = tmp("m.bsvm");
        save(&report.model, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.num_sv(), report.model.num_sv());
        assert_eq!(loaded.dim(), 2);
        for i in 0..ds.len() {
            let a = report.model.decision(ds.row(i));
            let b = loaded.decision(ds.row(i));
            assert!((a - b).abs() < 1e-9, "row {i}: {a} vs {b}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_files_load_through_the_v2_reader() {
        let mut m = BudgetModel::new(3, Gaussian::new(0.75), 4);
        m.push(&[1.0, 0.0, -1.0], 0.5);
        m.push(&[0.0, 2.0, 0.5], -1.25);
        m.bias = 0.125;
        let path = tmp("legacy.bsvm");
        write_v1(&m, &path);
        // Kernel-generic reader.
        let any = load_any(&path).unwrap();
        assert_eq!(any.kernel_spec(), KernelSpec::gaussian(0.75));
        assert_eq!(any.num_sv(), 2);
        assert_eq!(any.bias(), 0.125);
        // Legacy typed reader.
        let loaded = load(&path).unwrap();
        let probe = [0.3f32, -0.4, 1.1];
        assert!((loaded.decision(&probe) - m.decision(&probe)).abs() < 1e-12);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_round_trips_every_kernel_family() {
        let specs = [
            KernelSpec::gaussian(1.5),
            KernelSpec::linear(),
            KernelSpec::polynomial(3, 0.5),
        ];
        for (i, spec) in specs.into_iter().enumerate() {
            let mut m = AnyModel::new(2, spec, 3).unwrap();
            m.push(&[1.0, -0.5], 0.8);
            m.push(&[-0.25, 2.0], -0.3);
            m.set_bias(0.0625);
            let path = tmp(&format!("k{i}.bsvm"));
            save_any(&m, &path).unwrap();
            let loaded = load_any(&path).unwrap();
            assert_eq!(loaded.kernel_spec(), spec, "{}", spec.describe());
            assert_eq!(loaded.num_sv(), 2);
            for probe in [[0.0f32, 0.0], [1.0, 1.0], [-0.7, 0.3]] {
                assert!(
                    (loaded.decision(&probe) - m.decision(&probe)).abs() < 1e-9,
                    "{}",
                    spec.describe()
                );
            }
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn non_gaussian_file_rejected_by_typed_loader() {
        let m = AnyModel::new(2, KernelSpec::linear(), 1).unwrap();
        let path = tmp("linear-only.bsvm");
        save_any(&m, &path).unwrap();
        assert!(load(&path).is_err());
        assert!(load_any(&path).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scaled_polynomial_kernel_is_rejected_not_corrupted() {
        let mut m = BudgetModel::new(2, Polynomial::new(2.0, 1.0, 2), 1);
        m.push(&[1.0, 1.0], 1.0);
        let path = tmp("poly-scaled.bsvm");
        assert!(save(&m, &path).is_err(), "scale != 1 must not serialize silently");
        // scale = 1 is fine.
        let mut ok = BudgetModel::new(2, Polynomial::new(1.0, 1.0, 2), 1);
        ok.push(&[1.0, 1.0], 1.0);
        save(&ok, &path).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn linear_save_via_typed_writer() {
        let mut m = BudgetModel::new(2, Linear, 2);
        m.push(&[2.0, 0.0], 1.0);
        let path = tmp("linear-typed.bsvm");
        save(&m, &path).unwrap();
        let back = load_any(&path).unwrap();
        assert_eq!(back.kernel_spec(), KernelSpec::linear());
        assert!((back.decision(&[1.0, 0.0]) - 2.0).abs() < 1e-9);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corrupt_files() {
        let path = tmp("bad.bsvm");
        std::fs::write(&path, b"BSVMMDL1 but truncated").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, b"BSVMMDL2 but truncated").unwrap();
        assert!(load_any(&path).is_err());
        std::fs::write(&path, b"WRONGMAG").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_matrix_never_panics_and_detects_structural_damage() {
        // Dump → mangle → load over a deliberately awkward v2 file; every
        // mangled variant must return through `Result` (no panic, no
        // unbounded allocation). Structural damage — truncation at any
        // section boundary, trailing bytes, length-field flips — must be a
        // typed error.
        let mut m = AnyModel::new(3, KernelSpec::gaussian(0.8), 3).unwrap();
        m.push(&[1.0, -0.5, 0.25], 0.75);
        m.push(&[0.0, 2.0, -1.0], -0.5);
        m.push(&[0.5, 0.5, 0.5], 0.125);
        m.set_bias(-0.25);
        let mut bytes: Vec<u8> = Vec::new();
        save_any_writer(&m, &mut bytes).unwrap();
        // Section boundaries of the v2 layout for d=3, count=3, gaussian:
        // magic(8) | d(8) | count(8) | tag(4) | gamma(8) | bias(8) |
        // alphas(3·8) | svs(3·3·4).
        let boundaries = [0usize, 8, 16, 24, 28, 36, 44, 44 + 24, 44 + 24 + 36];
        assert_eq!(*boundaries.last().unwrap(), bytes.len(), "layout drifted");
        // Truncation at (and one byte before) every section boundary is a
        // typed error, never a panic.
        for &b in &boundaries[..boundaries.len() - 1] {
            for cut in [b, b.saturating_sub(1)] {
                let err = load_any_reader(&bytes[..cut]);
                assert!(err.is_err(), "truncation at byte {cut} must fail");
            }
        }
        // Trailing garbage is detected (a flipped count field would
        // otherwise mis-parse coefficients as support vectors).
        let mut extended = bytes.clone();
        extended.push(0xAB);
        assert!(load_any_reader(extended.as_slice()).is_err());
        // Bit-flip matrix: flip the low and high bit of every byte. Each
        // variant must come back through Result; structural fields (the
        // first 28 bytes: magic + lengths + tag) must always error.
        for i in 0..bytes.len() {
            for bit in [0u8, 7] {
                let mut mangled = bytes.clone();
                mangled[i] ^= 1 << bit;
                let res = load_any_reader(mangled.as_slice());
                if i < 28 {
                    assert!(res.is_err(), "flip of structural byte {i} bit {bit} must fail");
                } else if let Ok(back) = res {
                    // Payload flips may still parse; the result must at
                    // least be structurally sound.
                    assert_eq!(back.num_sv(), 3);
                    assert_eq!(back.dim(), 3);
                }
            }
        }
        // Oversized length fields must error before allocating: claim
        // u64::MAX support vectors.
        let mut huge = bytes.clone();
        huge[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(load_any_reader(huge.as_slice()).is_err());
        // And a plausible-looking but absurd count × d product.
        let mut wide = bytes.clone();
        wide[8..16].copy_from_slice(&900_000u64.to_le_bytes());
        wide[16..24].copy_from_slice(&9_000_000u64.to_le_bytes());
        assert!(load_any_reader(wide.as_slice()).is_err());
    }

    #[test]
    fn non_finite_coefficients_are_rejected() {
        let mut m = AnyModel::new(2, KernelSpec::gaussian(1.0), 2).unwrap();
        m.push(&[1.0, 0.0], 1.0);
        m.push(&[0.0, 1.0], -1.0);
        let mut bytes: Vec<u8> = Vec::new();
        save_any_writer(&m, &mut bytes).unwrap();
        // First alpha starts after magic(8)+d(8)+count(8)+tag(4)+gamma(8)+
        // bias(8) = 44 bytes.
        let mut nan_alpha = bytes.clone();
        nan_alpha[44..52].copy_from_slice(&f64::NAN.to_le_bytes());
        let err = load_any_reader(nan_alpha.as_slice()).unwrap_err().to_string();
        assert!(err.contains("non-finite coefficient"), "{err}");
        // Non-finite bias likewise.
        let mut inf_bias = bytes.clone();
        inf_bias[36..44].copy_from_slice(&f64::INFINITY.to_le_bytes());
        assert!(load_any_reader(inf_bias.as_slice()).is_err());
    }

    #[test]
    fn writer_reader_round_trip_in_memory_is_bit_identical_when_folded() {
        // A snapshot whose scale is folded (the serving registry publishes
        // only folded models) must predict bit-identically after a
        // dump→load through a byte buffer: the saved effective α equal the
        // raw α exactly, and the tiled summation order is unchanged.
        let mut m = BudgetModel::new(3, Gaussian::new(0.6), 5);
        m.push(&[1.0, 0.0, -0.5], 0.75);
        m.push(&[0.25, -1.0, 2.0], -1.5);
        m.push(&[0.0, 0.5, 0.125], 0.375);
        m.rescale(0.5);
        m.fold_scale();
        m.bias = -0.0625;
        let any: AnyModel = m.clone().into();
        let mut buf: Vec<u8> = Vec::new();
        save_any_writer(&any, &mut buf).unwrap();
        let back = load_any_reader(buf.as_slice()).unwrap();
        assert_eq!(back.num_sv(), any.num_sv());
        assert_eq!(back.kernel_spec(), any.kernel_spec());
        for probe in [[0.0f32, 0.0, 0.0], [1.0, -1.0, 0.5], [0.3, 0.7, -0.2]] {
            let a = any.decision(&probe);
            let b = back.decision(&probe);
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn scale_is_folded_on_save() {
        let mut m = BudgetModel::new(2, Gaussian::new(1.0), 2);
        m.push(&[1.0, 0.0], 2.0);
        m.rescale(0.25);
        let path = tmp("scaled.bsvm");
        save(&m, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert!((loaded.alpha(0) - 0.5).abs() < 1e-12);
        assert!((loaded.decision(&[1.0, 0.0]) - m.decision(&[1.0, 0.0])).abs() < 1e-12);
        std::fs::remove_file(&path).ok();
    }
}

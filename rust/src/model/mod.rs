//! Sparse kernel expansion model with budget support.
//!
//! [`BudgetModel`] stores the support vectors in a flat row-major matrix
//! with precomputed squared norms (the kernel row loop is the trainer's hot
//! path) and keeps coefficients behind a lazy global scale factor `Φ` so the
//! Pegasos shrink step `w ← (1 − 1/t)·w` is O(1) instead of O(B).
//!
//! The model is generic over the [`Kernel`]: `BudgetModel<Gaussian>` (the
//! default type parameter, so plain `BudgetModel` keeps meaning the
//! Gaussian model) is what the merge-based budget maintenance operates on,
//! while `BudgetModel<Linear>` / `BudgetModel<Polynomial>` support the
//! removal/projection maintenance paths and the unbudgeted solvers. The
//! kernel type is a monomorphized parameter — the decision hot loop
//! compiles to the same fused code as the previously Gaussian-only version.
//!
//! [`AnyModel`] is the runtime-polymorphic wrapper the [`crate::solver`]
//! estimator surface and the versioned model format ([`io`]) work with.

pub mod io;

use crate::kernel::{norm2, Gaussian, Kernel, KernelSpec, Linear, Polynomial};

/// Lower bound on `Φ` before it is folded back into the raw coefficients
/// (guards against underflow after very many SGD steps).
const SCALE_FOLD_THRESHOLD: f64 = 1e-6;

/// A budgeted kernel SVM model `f(x) = Σ_j α_j k(x_j, x) + b` with at most
/// `capacity` support vectors.
#[derive(Debug, Clone)]
pub struct BudgetModel<K: Kernel + Copy = Gaussian> {
    d: usize,
    kernel: K,
    /// Flat row-major support vectors, `count * d` valid entries.
    sv: Vec<f32>,
    /// Raw coefficients; effective `α_j = Φ · alpha[j]`.
    alpha: Vec<f64>,
    /// Squared L2 norms of each SV row.
    norms: Vec<f32>,
    count: usize,
    /// Global lazy scale Φ.
    scale: f64,
    /// Bias term (0 unless trained with bias).
    pub bias: f64,
}

impl<K: Kernel + Copy> BudgetModel<K> {
    /// New empty model; `capacity` is a hint used to reserve storage (the
    /// trainer passes `B + 1`).
    pub fn new(d: usize, kernel: K, capacity: usize) -> Self {
        BudgetModel {
            d,
            kernel,
            sv: Vec::with_capacity(capacity * d),
            alpha: Vec::with_capacity(capacity),
            norms: Vec::with_capacity(capacity),
            count: 0,
            scale: 1.0,
            bias: 0.0,
        }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn kernel(&self) -> K {
        self.kernel
    }

    /// The serializable spec of this model's kernel.
    pub fn kernel_spec(&self) -> KernelSpec {
        self.kernel.spec()
    }

    /// Number of support vectors currently stored.
    #[inline]
    pub fn num_sv(&self) -> usize {
        self.count
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Support vector row `j`.
    #[inline]
    pub fn sv(&self, j: usize) -> &[f32] {
        &self.sv[j * self.d..(j + 1) * self.d]
    }

    /// Squared norm of SV `j`.
    #[inline]
    pub fn sv_norm2(&self, j: usize) -> f32 {
        self.norms[j]
    }

    /// Effective coefficient `α_j = Φ·a_j`.
    #[inline]
    pub fn alpha(&self, j: usize) -> f64 {
        self.scale * self.alpha[j]
    }

    /// All effective coefficients (allocates).
    pub fn alphas(&self) -> Vec<f64> {
        self.alpha[..self.count].iter().map(|a| a * self.scale).collect()
    }

    /// Current global scale Φ (exposed for tests/diagnostics).
    pub fn global_scale(&self) -> f64 {
        self.scale
    }

    /// Multiply the whole expansion by `factor` in O(1) (Pegasos shrink).
    pub fn rescale(&mut self, factor: f64) {
        debug_assert!(factor.is_finite());
        if self.count == 0 {
            // An empty expansion times anything is still empty; keep Φ sane.
            self.scale = 1.0;
            return;
        }
        self.scale *= factor;
        if self.scale.abs() < SCALE_FOLD_THRESHOLD {
            self.fold_scale();
        }
    }

    /// Fold Φ into the raw coefficients and reset it to 1.
    pub fn fold_scale(&mut self) {
        if self.scale == 1.0 {
            return;
        }
        for a in &mut self.alpha[..self.count] {
            *a *= self.scale;
        }
        self.scale = 1.0;
    }

    /// Append a support vector with *effective* coefficient `alpha_eff`.
    pub fn push(&mut self, x: &[f32], alpha_eff: f64) {
        assert_eq!(x.len(), self.d);
        if self.scale == 0.0 {
            // Degenerate state (all coefficients are exactly zero anyway).
            self.clear();
        }
        self.sv.extend_from_slice(x);
        self.norms.push(norm2(x));
        self.alpha.push(alpha_eff / self.scale);
        self.count += 1;
    }

    /// Remove SV `j` (swap-remove; order is not preserved).
    pub fn swap_remove(&mut self, j: usize) {
        assert!(j < self.count);
        let last = self.count - 1;
        if j != last {
            let (head, tail) = self.sv.split_at_mut(last * self.d);
            head[j * self.d..(j + 1) * self.d].copy_from_slice(&tail[..self.d]);
            self.alpha[j] = self.alpha[last];
            self.norms[j] = self.norms[last];
        }
        self.sv.truncate(last * self.d);
        self.alpha.truncate(last);
        self.norms.truncate(last);
        self.count = last;
    }

    /// Remove all support vectors.
    pub fn clear(&mut self) {
        self.sv.clear();
        self.alpha.clear();
        self.norms.clear();
        self.count = 0;
        self.scale = 1.0;
    }

    /// Add `delta_eff` (effective units) to coefficient `j`.
    pub fn add_alpha(&mut self, j: usize, delta_eff: f64) {
        self.alpha[j] += delta_eff / self.scale;
    }

    /// Index of the SV with minimal `|α|` (None if empty). Ties break to the
    /// lowest index.
    pub fn argmin_abs_alpha(&self) -> Option<usize> {
        // Raw |a_j| ordering equals effective |Φ·a_j| ordering (Φ is global).
        (0..self.count).min_by(|&i, &j| {
            self.alpha[i].abs().partial_cmp(&self.alpha[j].abs()).unwrap()
        })
    }

    /// Decision value `f(x) = Φ·Σ_j a_j k(x_j, x) + b` for a row with known
    /// squared norm. This is THE hot function of the whole system; `K` is a
    /// monomorphized parameter, so the kernel evaluation inlines exactly as
    /// the hand-fused Gaussian loop did.
    pub fn decision_with_norm(&self, x: &[f32], x_norm2: f32) -> f64 {
        debug_assert_eq!(x.len(), self.d);
        let d = self.d;
        let mut acc = 0.0f64;
        for j in 0..self.count {
            let s = &self.sv[j * d..(j + 1) * d];
            acc += self.alpha[j] * self.kernel.eval(x, x_norm2, s, self.norms[j]);
        }
        self.scale * acc + self.bias
    }

    /// Decision value, computing the norm on the fly.
    pub fn decision(&self, x: &[f32]) -> f64 {
        self.decision_with_norm(x, norm2(x))
    }

    /// Predicted label (±1) for a row.
    pub fn predict(&self, x: &[f32]) -> f32 {
        if self.decision(x) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Kernel row `κ_j = k(x, sv_j)` written into `out` (length ≥ count).
    /// Returns the number of entries written.
    pub fn kernel_row(&self, x: &[f32], x_norm2: f32, out: &mut [f64]) -> usize {
        let d = self.d;
        for j in 0..self.count {
            let s = &self.sv[j * d..(j + 1) * d];
            out[j] = self.kernel.eval(x, x_norm2, s, self.norms[j]);
        }
        self.count
    }

    /// Squared RKHS norm `‖w‖² = Σ_ij α_i α_j k(x_i, x_j)` — O(B²), used by
    /// objective evaluation and tests, not by the hot loop.
    pub fn weight_norm2(&self) -> f64 {
        let mut acc = 0.0;
        for i in 0..self.count {
            for j in 0..self.count {
                let k = self.kernel.eval(self.sv(i), self.norms[i], self.sv(j), self.norms[j]);
                acc += self.alpha[i] * self.alpha[j] * k;
            }
        }
        self.scale * self.scale * acc
    }

    /// Classification accuracy on a dataset.
    pub fn accuracy(&self, ds: &crate::data::Dataset) -> f64 {
        if ds.is_empty() {
            return 0.0;
        }
        let mut correct = 0usize;
        for i in 0..ds.len() {
            if self.predict(ds.row(i)) == ds.label(i) {
                correct += 1;
            }
        }
        correct as f64 / ds.len() as f64
    }

    /// Decision values for every row of a dataset (allocates the output).
    pub fn decision_batch(&self, ds: &crate::data::Dataset) -> Vec<f64> {
        (0..ds.len()).map(|i| self.decision(ds.row(i))).collect()
    }
}

/// Dispatch a method call to whichever kernel variant an [`AnyModel`] holds.
macro_rules! for_any_model {
    ($any:expr, $m:ident => $body:expr) => {
        match $any {
            AnyModel::Gaussian($m) => $body,
            AnyModel::Linear($m) => $body,
            AnyModel::Polynomial($m) => $body,
        }
    };
}

/// Runtime-polymorphic budget model: one variant per supported kernel
/// family. This is the type the [`crate::solver`] estimators and the
/// versioned model format exchange; code that statically needs the Gaussian
/// geometry (merge-based maintenance, the PJRT runtime) extracts the
/// concrete variant via [`AnyModel::as_gaussian`] / [`AnyModel::into_gaussian`].
#[derive(Debug, Clone)]
pub enum AnyModel {
    Gaussian(BudgetModel<Gaussian>),
    Linear(BudgetModel<Linear>),
    Polynomial(BudgetModel<Polynomial>),
}

impl AnyModel {
    /// New empty model for a kernel spec (validates the spec).
    pub fn new(d: usize, spec: KernelSpec, capacity: usize) -> anyhow::Result<AnyModel> {
        spec.validate()?;
        Ok(match spec {
            KernelSpec::Gaussian { gamma } => {
                AnyModel::Gaussian(BudgetModel::new(d, Gaussian::new(gamma), capacity))
            }
            KernelSpec::Linear => AnyModel::Linear(BudgetModel::new(d, Linear, capacity)),
            KernelSpec::Polynomial { degree, coef0 } => AnyModel::Polynomial(BudgetModel::new(
                d,
                Polynomial::new(1.0, coef0, degree),
                capacity,
            )),
        })
    }

    pub fn dim(&self) -> usize {
        for_any_model!(self, m => m.dim())
    }

    pub fn num_sv(&self) -> usize {
        for_any_model!(self, m => m.num_sv())
    }

    pub fn is_empty(&self) -> bool {
        for_any_model!(self, m => m.is_empty())
    }

    pub fn kernel_spec(&self) -> KernelSpec {
        for_any_model!(self, m => m.kernel_spec())
    }

    pub fn bias(&self) -> f64 {
        for_any_model!(self, m => m.bias)
    }

    pub fn set_bias(&mut self, bias: f64) {
        for_any_model!(self, m => m.bias = bias)
    }

    /// Support vector row `j`.
    pub fn sv(&self, j: usize) -> &[f32] {
        for_any_model!(self, m => m.sv(j))
    }

    /// Effective coefficient `α_j`.
    pub fn alpha(&self, j: usize) -> f64 {
        for_any_model!(self, m => m.alpha(j))
    }

    /// Append a support vector with effective coefficient `alpha_eff`.
    pub fn push(&mut self, x: &[f32], alpha_eff: f64) {
        for_any_model!(self, m => m.push(x, alpha_eff))
    }

    /// Decision value `f(x)`.
    pub fn decision(&self, x: &[f32]) -> f64 {
        for_any_model!(self, m => m.decision(x))
    }

    /// Predicted label (±1).
    pub fn predict(&self, x: &[f32]) -> f32 {
        for_any_model!(self, m => m.predict(x))
    }

    /// Classification accuracy on a dataset.
    pub fn accuracy(&self, ds: &crate::data::Dataset) -> f64 {
        for_any_model!(self, m => m.accuracy(ds))
    }

    /// Decision values for every row of a dataset.
    pub fn decision_batch(&self, ds: &crate::data::Dataset) -> Vec<f64> {
        for_any_model!(self, m => m.decision_batch(ds))
    }

    /// Borrow the Gaussian variant, if that is what this model is.
    pub fn as_gaussian(&self) -> Option<&BudgetModel<Gaussian>> {
        match self {
            AnyModel::Gaussian(m) => Some(m),
            _ => None,
        }
    }

    /// Consume into the Gaussian variant; errors with the actual kernel
    /// family otherwise.
    pub fn into_gaussian(self) -> anyhow::Result<BudgetModel<Gaussian>> {
        match self {
            AnyModel::Gaussian(m) => Ok(m),
            other => anyhow::bail!(
                "expected a gaussian-kernel model, found {}",
                other.kernel_spec().describe()
            ),
        }
    }
}

impl From<BudgetModel<Gaussian>> for AnyModel {
    fn from(m: BudgetModel<Gaussian>) -> Self {
        AnyModel::Gaussian(m)
    }
}

impl From<BudgetModel<Linear>> for AnyModel {
    fn from(m: BudgetModel<Linear>) -> Self {
        AnyModel::Linear(m)
    }
}

impl From<BudgetModel<Polynomial>> for AnyModel {
    fn from(m: BudgetModel<Polynomial>) -> Self {
        AnyModel::Polynomial(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_with(points: &[(&[f32], f64)]) -> BudgetModel {
        let d = points[0].0.len();
        let mut m = BudgetModel::new(d, Gaussian::new(0.5), points.len());
        for (x, a) in points {
            m.push(x, *a);
        }
        m
    }

    #[test]
    fn decision_matches_manual_sum() {
        let m = model_with(&[(&[0.0, 0.0], 1.0), (&[1.0, 0.0], -0.5)]);
        let x = [0.5f32, 0.5];
        let k1 = (-0.5f64 * 0.5).exp(); // d² = 0.25+0.25
        let k2 = (-0.5f64 * 0.5).exp();
        let expect = 1.0 * k1 - 0.5 * k2;
        assert!((m.decision(&x) - expect).abs() < 1e-9);
    }

    #[test]
    fn rescale_is_lazy_and_correct() {
        let mut m = model_with(&[(&[1.0, 2.0], 2.0)]);
        let before = m.decision(&[0.0, 0.0]);
        m.rescale(0.5);
        let after = m.decision(&[0.0, 0.0]);
        assert!((after - 0.5 * before).abs() < 1e-12);
        assert!((m.alpha(0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn push_after_rescale_uses_effective_units() {
        let mut m = model_with(&[(&[0.0, 0.0], 1.0)]);
        m.rescale(0.25);
        m.push(&[3.0, 3.0], 0.8);
        assert!((m.alpha(1) - 0.8).abs() < 1e-12);
        assert!((m.alpha(0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn scale_folding_keeps_decision_invariant() {
        let mut m = model_with(&[(&[1.0, 0.0], 1.0), (&[0.0, 1.0], -2.0)]);
        let x = [0.3f32, 0.7];
        let before = m.decision(&x);
        // Shrink hard enough to trigger folding.
        for _ in 0..40 {
            m.rescale(0.5);
        }
        assert_eq!(m.global_scale(), 1.0, "scale should have folded");
        let expect = before * 0.5f64.powi(40);
        assert!((m.decision(&x) - expect).abs() < 1e-15 + expect.abs() * 1e-9);
    }

    #[test]
    fn swap_remove_keeps_remaining_svs() {
        let mut m = model_with(&[
            (&[0.0, 0.0], 1.0),
            (&[1.0, 1.0], 2.0),
            (&[2.0, 2.0], 3.0),
        ]);
        m.swap_remove(0);
        assert_eq!(m.num_sv(), 2);
        // last row moved into slot 0
        assert_eq!(m.sv(0), &[2.0, 2.0]);
        assert!((m.alpha(0) - 3.0).abs() < 1e-12);
        assert_eq!(m.sv(1), &[1.0, 1.0]);
    }

    #[test]
    fn argmin_abs_alpha_finds_smallest() {
        let m = model_with(&[(&[0.0, 0.0], -3.0), (&[1.0, 1.0], 0.5), (&[2.0, 2.0], 2.0)]);
        assert_eq!(m.argmin_abs_alpha(), Some(1));
        let empty = BudgetModel::new(2, Gaussian::new(1.0), 4);
        assert_eq!(empty.argmin_abs_alpha(), None);
    }

    #[test]
    fn kernel_row_matches_decision() {
        let m = model_with(&[(&[0.0, 1.0], 1.5), (&[1.0, 0.0], -0.5), (&[1.0, 1.0], 0.25)]);
        let x = [0.2f32, 0.8];
        let mut row = vec![0.0f64; 3];
        let n = m.kernel_row(&x, norm2(&x), &mut row);
        assert_eq!(n, 3);
        let via_row: f64 =
            (0..3).map(|j| m.alpha(j) * row[j]).sum::<f64>() + m.bias;
        assert!((via_row - m.decision(&x)).abs() < 1e-12);
    }

    #[test]
    fn weight_norm2_single_sv() {
        let m = model_with(&[(&[1.0, 1.0], 2.0)]);
        // ‖2φ(x)‖² = 4·k(x,x) = 4
        assert!((m.weight_norm2() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_model_predicts_nonnegative_class() {
        let m = BudgetModel::new(2, Gaussian::new(1.0), 4);
        assert_eq!(m.decision(&[1.0, 2.0]), 0.0);
        assert_eq!(m.predict(&[1.0, 2.0]), 1.0);
    }

    #[test]
    fn accuracy_on_trivial_dataset() {
        let m = model_with(&[(&[0.0, 0.0], 1.0), (&[4.0, 4.0], -1.0)]);
        let ds = crate::data::Dataset::new(
            "t",
            vec![0.1, 0.1, 3.9, 3.9],
            vec![1.0, -1.0],
            2,
        );
        assert_eq!(m.accuracy(&ds), 1.0);
    }

    #[test]
    fn linear_model_decision_matches_dot_expansion() {
        let mut m = BudgetModel::new(2, Linear, 2);
        m.push(&[1.0, 0.0], 2.0);
        m.push(&[0.0, 1.0], -1.0);
        // f(x) = 2·⟨(1,0),x⟩ − 1·⟨(0,1),x⟩ = 2x₀ − x₁
        let x = [0.5f32, 0.25];
        assert!((m.decision(&x) - (2.0 * 0.5 - 0.25)).abs() < 1e-6);
    }

    #[test]
    fn polynomial_model_weight_norm_uses_kernel_diagonal() {
        let mut m = BudgetModel::new(2, Polynomial::new(1.0, 1.0, 2), 1);
        m.push(&[1.0, 1.0], 1.0);
        // ‖w‖² = k(x,x) = (⟨x,x⟩ + 1)² = 9
        assert!((m.weight_norm2() - 9.0).abs() < 1e-6);
    }

    #[test]
    fn any_model_dispatches_by_kernel() {
        for spec in [
            KernelSpec::gaussian(0.5),
            KernelSpec::linear(),
            KernelSpec::polynomial(2, 1.0),
        ] {
            let mut m = AnyModel::new(2, spec, 4).unwrap();
            m.push(&[1.0, 0.0], 1.0);
            m.push(&[0.0, 1.0], -0.5);
            m.set_bias(0.25);
            assert_eq!(m.dim(), 2);
            assert_eq!(m.num_sv(), 2);
            assert_eq!(m.kernel_spec(), spec);
            assert_eq!(m.bias(), 0.25);
            assert!((m.alpha(1) + 0.5).abs() < 1e-12);
            assert_eq!(m.sv(0), &[1.0, 0.0]);
            // decision must match the concrete kernel expansion + bias.
            let x = [0.3f32, 0.7];
            let expect = 1.0 * spec.eval(&x, norm2(&x), &[1.0, 0.0], 1.0)
                - 0.5 * spec.eval(&x, norm2(&x), &[0.0, 1.0], 1.0)
                + 0.25;
            assert!((m.decision(&x) - expect).abs() < 1e-9, "{}", spec.describe());
            assert_eq!(m.predict(&x), if expect >= 0.0 { 1.0 } else { -1.0 });
        }
    }

    #[test]
    fn any_model_gaussian_extraction() {
        let g = AnyModel::new(3, KernelSpec::gaussian(1.0), 2).unwrap();
        assert!(g.as_gaussian().is_some());
        assert!(g.into_gaussian().is_ok());
        let l = AnyModel::new(3, KernelSpec::linear(), 2).unwrap();
        assert!(l.as_gaussian().is_none());
        assert!(l.into_gaussian().is_err());
        assert!(AnyModel::new(3, KernelSpec::gaussian(-1.0), 2).is_err());
    }
}

//! Sparse kernel expansion model with budget support, running on a blocked
//! kernel-row engine.
//!
//! # Storage: the SoA tile layout
//!
//! [`BudgetModel`] keeps its support vectors in an [`SvStore`]: a
//! cache-blocked layout of `TILE = 8` consecutive SVs per tile, stored
//! feature-major within the tile with co-located squared norms (plus a
//! row-major mirror for random access and serialization — see the
//! [`store`] module docs for the exact invariants: tile size, zeroed
//! padding lanes, swap-remove semantics, 64-byte-aligned tile base). The
//! hot kernel row `k(x, sv_j), j = 1..B` is then computed tile-by-tile:
//! one pass over `x` yields all eight inner products of a tile through
//! the runtime-dispatched FMA micro-kernel ([`SvStore::tile_dots`] —
//! AVX2+FMA or the portable 8-lane loop, see [`crate::kernel::simd`]),
//! and the kernel finishes the tile in one fused pass
//! ([`crate::kernel::Kernel::eval_block`] — the Gaussian shares a single
//! distance-reconstruction + `exp` loop; the opt-in `--fast-exp` tier
//! swaps the libm `exp` for the vectorized `exp_v` under a pinned
//! ≤ 1e-14 relative-error bound).
//!
//! To add a fused kernel, follow the four-layer contract documented in
//! [`crate::kernel`] (module docs): `eval_dot` for correctness,
//! `eval_block` for tile fusion, [`crate::kernel::Kernel::op`] +
//! [`crate::kernel::simd::tile_decision`] for reduction fusion, and an
//! optional [`crate::kernel::simd`] micro-kernel per vector tier — plus
//! the fast-exp accuracy policy for any transcendental shortcut.
//! Padding lanes carry zero data and zero norms; consumers mask them by
//! coefficient range, never inside the micro-kernel.
//!
//! # One resolved execution plan per row
//!
//! Every kernel-row loop here resolves the SIMD tier
//! ([`crate::kernel::simd::active`]) and the kernel's finish descriptor
//! ([`crate::kernel::Kernel::op`]) **once at the top of the row**, then
//! threads both through the `*_with(tier, …)` seams — no per-tile
//! re-dispatch. The decision paths ([`BudgetModel::decision_with_norm`],
//! `decision_rows`, `weight_norm2`) additionally run the fused
//! [`SvStore::tile_decision`]: dots → kernel finish → α-weighted
//! accumulate in one pass per tile, never materializing the κ row.
//!
//! Coefficients stay behind a lazy global scale factor `Φ` so the Pegasos
//! shrink step `w ← (1 − 1/t)·w` is O(1) instead of O(B).
//!
//! The model is generic over the [`Kernel`]: `BudgetModel<Gaussian>` (the
//! default type parameter) is what the merge-based budget maintenance
//! operates on, while `BudgetModel<Linear>` / `BudgetModel<Polynomial>`
//! support the removal/projection maintenance paths and the unbudgeted
//! solvers. The kernel type is a monomorphized parameter — the decision
//! hot loop compiles to straight-line tile code per kernel.
//!
//! The pre-tiling scalar loops survive as `*_scalar` reference methods
//! (used by the conformance tests and the bench harness to measure the
//! blocked engine's speedup).
//!
//! [`AnyModel`] is the runtime-polymorphic wrapper the [`crate::solver`]
//! estimator surface and the versioned model format ([`io`]) work with.

pub mod io;
mod store;

pub use store::SvStore;

use crate::kernel::{norm2, simd, Gaussian, Kernel, KernelSpec, Linear, Polynomial, TILE};
use crate::util::parallel;

/// Lower bound on `Φ` before it is folded back into the raw coefficients
/// (guards against underflow after very many SGD steps).
const SCALE_FOLD_THRESHOLD: f64 = 1e-6;

/// A budgeted kernel SVM model `f(x) = Σ_j α_j k(x_j, x) + b` with at most
/// `capacity` support vectors.
#[derive(Debug, Clone)]
pub struct BudgetModel<K: Kernel + Copy = Gaussian> {
    kernel: K,
    /// Blocked support-vector storage (SoA tiles + row mirror + norms).
    store: SvStore,
    /// Raw coefficients; effective `α_j = Φ · alpha[j]`.
    alpha: Vec<f64>,
    /// Global lazy scale Φ.
    scale: f64,
    /// Bias term (0 unless trained with bias).
    pub bias: f64,
}

impl<K: Kernel + Copy> BudgetModel<K> {
    /// New empty model; `capacity` is a hint used to reserve storage (the
    /// trainer passes `B + 1`).
    pub fn new(d: usize, kernel: K, capacity: usize) -> Self {
        BudgetModel {
            kernel,
            store: SvStore::new(d, capacity),
            alpha: Vec::with_capacity(capacity),
            scale: 1.0,
            bias: 0.0,
        }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.store.dim()
    }

    #[inline]
    pub fn kernel(&self) -> K {
        self.kernel
    }

    /// The serializable spec of this model's kernel.
    pub fn kernel_spec(&self) -> KernelSpec {
        self.kernel.spec()
    }

    /// Number of support vectors currently stored.
    #[inline]
    pub fn num_sv(&self) -> usize {
        self.store.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// Support vector row `j`.
    #[inline]
    pub fn sv(&self, j: usize) -> &[f32] {
        self.store.row(j)
    }

    /// Squared norm of SV `j`.
    #[inline]
    pub fn sv_norm2(&self, j: usize) -> f32 {
        self.store.norm2(j)
    }

    /// Effective coefficient `α_j = Φ·a_j`.
    #[inline]
    pub fn alpha(&self, j: usize) -> f64 {
        self.scale * self.alpha[j]
    }

    /// All effective coefficients, allocation-free: the lazy scale Φ is
    /// folded into the raw coefficients first, after which the raw slice
    /// *is* the effective one.
    pub fn alphas(&mut self) -> &[f64] {
        self.fold_scale();
        &self.alpha
    }

    /// Current global scale Φ (exposed for tests/diagnostics).
    pub fn global_scale(&self) -> f64 {
        self.scale
    }

    /// Multiply the whole expansion by `factor` in O(1) (Pegasos shrink).
    pub fn rescale(&mut self, factor: f64) {
        debug_assert!(factor.is_finite());
        if self.store.is_empty() {
            // An empty expansion times anything is still empty; keep Φ sane.
            self.scale = 1.0;
            return;
        }
        self.scale *= factor;
        if self.scale.abs() < SCALE_FOLD_THRESHOLD {
            self.fold_scale();
        }
    }

    /// Fold Φ into the raw coefficients and reset it to 1.
    pub fn fold_scale(&mut self) {
        if self.scale == 1.0 {
            return;
        }
        for a in &mut self.alpha {
            *a *= self.scale;
        }
        self.scale = 1.0;
    }

    /// Append a support vector with *effective* coefficient `alpha_eff`.
    pub fn push(&mut self, x: &[f32], alpha_eff: f64) {
        if self.scale == 0.0 {
            // Degenerate state (all coefficients are exactly zero anyway).
            self.clear();
        }
        self.store.push(x);
        self.alpha.push(alpha_eff / self.scale);
    }

    /// Remove SV `j` (swap-remove; order is not preserved).
    pub fn swap_remove(&mut self, j: usize) {
        let count = self.store.len();
        assert!(j < count, "swap_remove index {j} out of range {count}");
        let last = count - 1;
        if j != last {
            self.alpha[j] = self.alpha[last];
        }
        self.alpha.truncate(last);
        self.store.swap_remove(j);
    }

    /// Remove all support vectors.
    pub fn clear(&mut self) {
        self.store.clear();
        self.alpha.clear();
        self.scale = 1.0;
    }

    /// Add `delta_eff` (effective units) to coefficient `j`.
    pub fn add_alpha(&mut self, j: usize, delta_eff: f64) {
        self.alpha[j] += delta_eff / self.scale;
    }

    /// Overwrite the *effective* coefficient of SV `j` exactly (no
    /// accumulate-then-round drift): the dual solver clips coefficients
    /// onto its box boundary with this, which an `add_alpha` of the
    /// difference could miss by an ulp.
    pub fn set_alpha(&mut self, j: usize, alpha_eff: f64) {
        self.alpha[j] = alpha_eff / self.scale;
    }

    /// Index of the SV with minimal `|α|` (None if empty). Ties break to the
    /// lowest index.
    pub fn argmin_abs_alpha(&self) -> Option<usize> {
        // Raw |a_j| ordering equals effective |Φ·a_j| ordering (Φ is global).
        (0..self.store.len()).min_by(|&i, &j| {
            self.alpha[i].abs().partial_cmp(&self.alpha[j].abs()).unwrap()
        })
    }

    /// Decision value `f(x) = Φ·Σ_j a_j k(x_j, x) + b` for a row with known
    /// squared norm. This is THE hot function of the whole system: the
    /// tier and the kernel's finish descriptor are resolved once, then
    /// the sum runs tile-by-tile through the fused
    /// [`SvStore::tile_decision`] — dots → kernel finish → α-weighted
    /// accumulate in one pass per 8 SVs, no materialized κ buffer.
    pub fn decision_with_norm(&self, x: &[f32], x_norm2: f32) -> f64 {
        debug_assert_eq!(x.len(), self.store.dim());
        let count = self.store.len();
        let tier = simd::active();
        let op = self.kernel.op();
        let mut acc = 0.0f64;
        for t in 0..self.store.num_tiles() {
            let base = t * TILE;
            let lanes = TILE.min(count - base);
            acc += self.store.tile_decision(
                tier,
                op,
                t,
                x,
                x_norm2,
                &self.alpha[base..base + lanes],
            );
        }
        self.scale * acc + self.bias
    }

    /// Scalar reference for [`BudgetModel::decision_with_norm`]: the
    /// pre-tiling one-SV-at-a-time loop. Kept for conformance tests and
    /// the bench harness's speedup baseline.
    pub fn decision_with_norm_scalar(&self, x: &[f32], x_norm2: f32) -> f64 {
        debug_assert_eq!(x.len(), self.store.dim());
        let mut acc = 0.0f64;
        for j in 0..self.store.len() {
            let k = self.kernel.eval(x, x_norm2, self.store.row(j), self.store.norm2(j));
            acc += self.alpha[j] * k;
        }
        self.scale * acc + self.bias
    }

    /// Decision value, computing the norm on the fly.
    pub fn decision(&self, x: &[f32]) -> f64 {
        self.decision_with_norm(x, norm2(x))
    }

    /// Predicted label (±1) for a row.
    pub fn predict(&self, x: &[f32]) -> f32 {
        if self.decision(x) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Kernel row `κ_j = k(x, sv_j)` written into `out` (length ≥ count),
    /// computed through the blocked engine. Returns the number of entries
    /// written.
    pub fn kernel_row(&self, x: &[f32], x_norm2: f32, out: &mut [f64]) -> usize {
        self.kernel_row_prefix(x, x_norm2, self.store.len(), out)
    }

    /// [`BudgetModel::kernel_row`] truncated to the first `upto` SVs:
    /// writes `κ_j` for `j < min(upto, count)` only, touching just the
    /// tiles that cover that prefix. Lets symmetric consumers (Gram
    /// construction) keep the triangle saving while staying blocked.
    pub fn kernel_row_prefix(
        &self,
        x: &[f32],
        x_norm2: f32,
        upto: usize,
        out: &mut [f64],
    ) -> usize {
        let count = self.store.len().min(upto);
        debug_assert!(out.len() >= count);
        let tier = simd::active();
        let op = self.kernel.op();
        let mut dots = [0.0f32; TILE];
        let mut kvals = [0.0f64; TILE];
        for t in 0..count.div_ceil(TILE) {
            self.store.tile_dots_with(tier, t, x, &mut dots);
            simd::finish_with(tier, op, x_norm2, &dots, self.store.tile_norms(t), &mut kvals);
            let base = t * TILE;
            let lanes = TILE.min(count - base);
            out[base..base + lanes].copy_from_slice(&kvals[..lanes]);
        }
        count
    }

    /// κ rows of several *stored* SVs against every SV, in ONE pass over
    /// the blocked tile store: each tile's feature data is loaded once and
    /// dotted against all `queries` before moving on
    /// ([`SvStore::tile_dots_multi`] — in the AVX2 tier every loaded
    /// 8-lane feature vector feeds four pivots' accumulators; a
    /// tall-skinny matrix product rather than `queries.len()` independent
    /// row scans — the amortized candidate scan of multi-pair budget
    /// maintenance). Row `q` of `out` (stride `num_sv`) is bit-identical
    /// to `kernel_row(sv(queries[q]), ...)`: every entry runs the exact
    /// same blocked arithmetic, only the traversal order differs.
    pub fn kernel_rows_for_svs(&self, queries: &[usize], out: &mut [f64]) {
        let count = self.store.len();
        debug_assert!(out.len() >= queries.len() * count);
        if queries.is_empty() || count == 0 {
            return;
        }
        let tier = simd::active();
        let op = self.kernel.op();
        let qrows: Vec<&[f32]> = queries.iter().map(|&sv| self.store.row(sv)).collect();
        let mut dots = vec![[0.0f32; TILE]; queries.len()];
        let mut kvals = [0.0f64; TILE];
        for t in 0..count.div_ceil(TILE) {
            let base = t * TILE;
            let lanes = TILE.min(count - base);
            self.store.tile_dots_multi_with(tier, t, &qrows, &mut dots);
            for (q, &sv) in queries.iter().enumerate() {
                simd::finish_with(
                    tier,
                    op,
                    self.store.norm2(sv),
                    &dots[q],
                    self.store.tile_norms(t),
                    &mut kvals,
                );
                out[q * count + base..q * count + base + lanes]
                    .copy_from_slice(&kvals[..lanes]);
            }
        }
    }

    /// Scalar reference for [`BudgetModel::kernel_row`] (one `Kernel::eval`
    /// per SV); bench baseline and conformance oracle.
    pub fn kernel_row_scalar(&self, x: &[f32], x_norm2: f32, out: &mut [f64]) -> usize {
        let count = self.store.len();
        for j in 0..count {
            out[j] = self.kernel.eval(x, x_norm2, self.store.row(j), self.store.norm2(j));
        }
        count
    }

    /// Squared RKHS norm `‖w‖² = Σ_ij α_i α_j k(x_i, x_j)` — used by
    /// objective evaluation and tests, not by the hot loop. Exploits
    /// symmetry: the diagonal comes from `self_eval`, the strict upper
    /// triangle is computed once over the blocked engine and doubled, so
    /// the work is half the naive full-matrix loop.
    pub fn weight_norm2(&self) -> f64 {
        let count = self.store.len();
        let tier = simd::active();
        let op = self.kernel.op();
        let mut diag = 0.0f64;
        let mut off = 0.0f64;
        for i in 0..count {
            let ai = self.alpha[i];
            diag += ai * ai * self.kernel.self_eval(self.store.norm2(i));
            let xi = self.store.row(i);
            let ni = self.store.norm2(i);
            // Tiles covering j < i (the last one partially), each through
            // the fused dots → finish → α-weighted accumulate pass.
            for t in 0..i.div_ceil(TILE) {
                let base = t * TILE;
                let lanes = TILE.min(i - base);
                off += ai
                    * self.store.tile_decision(
                        tier,
                        op,
                        t,
                        xi,
                        ni,
                        &self.alpha[base..base + lanes],
                    );
            }
        }
        self.scale * self.scale * (diag + 2.0 * off)
    }

    /// Classification accuracy on a dataset (uses the dataset's cached row
    /// norms — no per-row `norm2` recomputation).
    pub fn accuracy(&self, ds: &crate::data::Dataset) -> f64 {
        if ds.is_empty() {
            return 0.0;
        }
        let norms = ds.norms();
        let mut correct = 0usize;
        for i in 0..ds.len() {
            let f = self.decision_with_norm(ds.row(i), norms[i]);
            let pred = if f >= 0.0 { 1.0 } else { -1.0 };
            if pred == ds.label(i) {
                correct += 1;
            }
        }
        correct as f64 / ds.len() as f64
    }

    /// Classification accuracy evaluated on `threads` workers (0 = all
    /// hardware threads). Row-granular split + integer reduction: the
    /// result is identical for every thread count.
    pub fn accuracy_threaded(&self, ds: &crate::data::Dataset, threads: usize) -> f64 {
        if ds.is_empty() {
            return 0.0;
        }
        let norms = ds.norms();
        let correct: usize = parallel::map_ranges(ds.len(), threads, |r| {
            let mut correct = 0usize;
            for i in r {
                let f = self.decision_with_norm(ds.row(i), norms[i]);
                let pred = if f >= 0.0 { 1.0 } else { -1.0 };
                if pred == ds.label(i) {
                    correct += 1;
                }
            }
            correct
        })
        .into_iter()
        .sum();
        correct as f64 / ds.len() as f64
    }

    /// Decision values for every row of a dataset (allocates the output).
    pub fn decision_batch(&self, ds: &crate::data::Dataset) -> Vec<f64> {
        let norms = ds.norms();
        (0..ds.len()).map(|i| self.decision_with_norm(ds.row(i), norms[i])).collect()
    }

    /// Decision values for every row, evaluated on `threads` workers
    /// (0 = all hardware threads). Chunked at row granularity and
    /// concatenated in order — bit-identical for every thread count.
    pub fn decision_batch_threaded(&self, ds: &crate::data::Dataset, threads: usize) -> Vec<f64> {
        let norms = ds.norms();
        parallel::map_ranges(ds.len(), threads, |r| {
            r.map(|i| self.decision_with_norm(ds.row(i), norms[i])).collect::<Vec<f64>>()
        })
        .into_iter()
        .flatten()
        .collect()
    }

    /// Decision values for a flat row-major buffer (`x.len()` must be a
    /// multiple of the model dimension), evaluated on `threads` workers
    /// (0 = all hardware threads). Each row's norm is computed exactly
    /// once.
    pub fn decision_rows(&self, x: &[f32], threads: usize) -> Vec<f64> {
        let d = self.store.dim();
        assert!(d > 0, "model dimension must be positive");
        assert_eq!(x.len() % d, 0, "flat buffer is not a multiple of the model dimension");
        parallel::map_ranges(x.len() / d, threads, |r| {
            r.map(|i| {
                let row = &x[i * d..(i + 1) * d];
                self.decision_with_norm(row, norm2(row))
            })
            .collect::<Vec<f64>>()
        })
        .into_iter()
        .flatten()
        .collect()
    }
}

impl BudgetModel<Gaussian> {
    /// Select the exponential tier of the blocked Gaussian tile path:
    /// `false` (default) = libm `exp` semantics, `true` = the vectorized
    /// [`crate::kernel::simd::exp_v`] (≤ 1e-14 relative). A runtime
    /// execution choice only — never serialized with the model.
    pub fn set_fast_exp(&mut self, fast_exp: bool) {
        self.kernel.fast_exp = fast_exp;
    }

    /// Whether the fast-exp tier is selected.
    pub fn fast_exp(&self) -> bool {
        self.kernel.fast_exp
    }
}

/// Dispatch a method call to whichever kernel variant an [`AnyModel`] holds.
macro_rules! for_any_model {
    ($any:expr, $m:ident => $body:expr) => {
        match $any {
            AnyModel::Gaussian($m) => $body,
            AnyModel::Linear($m) => $body,
            AnyModel::Polynomial($m) => $body,
        }
    };
}

/// Runtime-polymorphic budget model: one variant per supported kernel
/// family. This is the type the [`crate::solver`] estimators and the
/// versioned model format exchange; code that statically needs the Gaussian
/// geometry (merge-based maintenance, the PJRT runtime) extracts the
/// concrete variant via [`AnyModel::as_gaussian`] / [`AnyModel::into_gaussian`].
#[derive(Debug, Clone)]
pub enum AnyModel {
    Gaussian(BudgetModel<Gaussian>),
    Linear(BudgetModel<Linear>),
    Polynomial(BudgetModel<Polynomial>),
}

impl AnyModel {
    /// New empty model for a kernel spec (validates the spec).
    pub fn new(d: usize, spec: KernelSpec, capacity: usize) -> anyhow::Result<AnyModel> {
        spec.validate()?;
        Ok(match spec {
            KernelSpec::Gaussian { gamma } => {
                AnyModel::Gaussian(BudgetModel::new(d, Gaussian::new(gamma), capacity))
            }
            KernelSpec::Linear => AnyModel::Linear(BudgetModel::new(d, Linear, capacity)),
            KernelSpec::Polynomial { degree, coef0 } => AnyModel::Polynomial(BudgetModel::new(
                d,
                Polynomial::new(1.0, coef0, degree),
                capacity,
            )),
        })
    }

    pub fn dim(&self) -> usize {
        for_any_model!(self, m => m.dim())
    }

    pub fn num_sv(&self) -> usize {
        for_any_model!(self, m => m.num_sv())
    }

    pub fn is_empty(&self) -> bool {
        for_any_model!(self, m => m.is_empty())
    }

    pub fn kernel_spec(&self) -> KernelSpec {
        for_any_model!(self, m => m.kernel_spec())
    }

    pub fn bias(&self) -> f64 {
        for_any_model!(self, m => m.bias)
    }

    pub fn set_bias(&mut self, bias: f64) {
        for_any_model!(self, m => m.bias = bias)
    }

    /// Support vector row `j`.
    pub fn sv(&self, j: usize) -> &[f32] {
        for_any_model!(self, m => m.sv(j))
    }

    /// Effective coefficient `α_j`.
    pub fn alpha(&self, j: usize) -> f64 {
        for_any_model!(self, m => m.alpha(j))
    }

    /// Append a support vector with effective coefficient `alpha_eff`.
    pub fn push(&mut self, x: &[f32], alpha_eff: f64) {
        for_any_model!(self, m => m.push(x, alpha_eff))
    }

    /// Fold the lazy global scale Φ into the raw coefficients (see
    /// [`BudgetModel::fold_scale`]). The serving registry folds every
    /// published snapshot so that a `BSVMMDL2` dump→load round trip is
    /// bit-identical to the in-memory snapshot.
    pub fn fold_scale(&mut self) {
        for_any_model!(self, m => m.fold_scale())
    }

    /// Decision value `f(x)`.
    pub fn decision(&self, x: &[f32]) -> f64 {
        for_any_model!(self, m => m.decision(x))
    }

    /// Decision value for a row with known squared norm.
    pub fn decision_with_norm(&self, x: &[f32], x_norm2: f32) -> f64 {
        for_any_model!(self, m => m.decision_with_norm(x, x_norm2))
    }

    /// Predicted label (±1).
    pub fn predict(&self, x: &[f32]) -> f32 {
        for_any_model!(self, m => m.predict(x))
    }

    /// Classification accuracy on a dataset.
    pub fn accuracy(&self, ds: &crate::data::Dataset) -> f64 {
        for_any_model!(self, m => m.accuracy(ds))
    }

    /// Classification accuracy on `threads` workers (0 = all hardware
    /// threads); identical result for every thread count.
    pub fn accuracy_threaded(&self, ds: &crate::data::Dataset, threads: usize) -> f64 {
        for_any_model!(self, m => m.accuracy_threaded(ds, threads))
    }

    /// Decision values for every row of a dataset.
    pub fn decision_batch(&self, ds: &crate::data::Dataset) -> Vec<f64> {
        for_any_model!(self, m => m.decision_batch(ds))
    }

    /// Decision values for every row on `threads` workers (0 = all
    /// hardware threads); bit-identical for every thread count.
    pub fn decision_batch_threaded(&self, ds: &crate::data::Dataset, threads: usize) -> Vec<f64> {
        for_any_model!(self, m => m.decision_batch_threaded(ds, threads))
    }

    /// Decision values for a flat row-major buffer on `threads` workers.
    pub fn decision_rows(&self, x: &[f32], threads: usize) -> Vec<f64> {
        for_any_model!(self, m => m.decision_rows(x, threads))
    }

    /// Select the fast-exp tier on a Gaussian model (no-op for the other
    /// kernels, which evaluate no exponential). See
    /// [`BudgetModel::set_fast_exp`].
    pub fn set_fast_exp(&mut self, fast_exp: bool) {
        if let AnyModel::Gaussian(m) = self {
            m.set_fast_exp(fast_exp);
        }
    }

    /// Whether the fast-exp tier is selected (always `false` for
    /// non-Gaussian kernels).
    pub fn fast_exp(&self) -> bool {
        match self {
            AnyModel::Gaussian(m) => m.fast_exp(),
            _ => false,
        }
    }

    /// Borrow the Gaussian variant, if that is what this model is.
    pub fn as_gaussian(&self) -> Option<&BudgetModel<Gaussian>> {
        match self {
            AnyModel::Gaussian(m) => Some(m),
            _ => None,
        }
    }

    /// Consume into the Gaussian variant; errors with the actual kernel
    /// family otherwise.
    pub fn into_gaussian(self) -> anyhow::Result<BudgetModel<Gaussian>> {
        match self {
            AnyModel::Gaussian(m) => Ok(m),
            other => anyhow::bail!(
                "expected a gaussian-kernel model, found {}",
                other.kernel_spec().describe()
            ),
        }
    }
}

impl From<BudgetModel<Gaussian>> for AnyModel {
    fn from(m: BudgetModel<Gaussian>) -> Self {
        AnyModel::Gaussian(m)
    }
}

impl From<BudgetModel<Linear>> for AnyModel {
    fn from(m: BudgetModel<Linear>) -> Self {
        AnyModel::Linear(m)
    }
}

impl From<BudgetModel<Polynomial>> for AnyModel {
    fn from(m: BudgetModel<Polynomial>) -> Self {
        AnyModel::Polynomial(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn model_with(points: &[(&[f32], f64)]) -> BudgetModel {
        let d = points[0].0.len();
        let mut m = BudgetModel::new(d, Gaussian::new(0.5), points.len());
        for (x, a) in points {
            m.push(x, *a);
        }
        m
    }

    #[test]
    fn decision_matches_manual_sum() {
        let m = model_with(&[(&[0.0, 0.0], 1.0), (&[1.0, 0.0], -0.5)]);
        let x = [0.5f32, 0.5];
        let k1 = (-0.5f64 * 0.5).exp(); // d² = 0.25+0.25
        let k2 = (-0.5f64 * 0.5).exp();
        let expect = 1.0 * k1 - 0.5 * k2;
        assert!((m.decision(&x) - expect).abs() < 1e-9);
    }

    #[test]
    fn rescale_is_lazy_and_correct() {
        let mut m = model_with(&[(&[1.0, 2.0], 2.0)]);
        let before = m.decision(&[0.0, 0.0]);
        m.rescale(0.5);
        let after = m.decision(&[0.0, 0.0]);
        assert!((after - 0.5 * before).abs() < 1e-12);
        assert!((m.alpha(0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn push_after_rescale_uses_effective_units() {
        let mut m = model_with(&[(&[0.0, 0.0], 1.0)]);
        m.rescale(0.25);
        m.push(&[3.0, 3.0], 0.8);
        assert!((m.alpha(1) - 0.8).abs() < 1e-12);
        assert!((m.alpha(0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn scale_folding_keeps_decision_invariant() {
        let mut m = model_with(&[(&[1.0, 0.0], 1.0), (&[0.0, 1.0], -2.0)]);
        let x = [0.3f32, 0.7];
        let before = m.decision(&x);
        // Shrink hard enough to trigger folding.
        for _ in 0..40 {
            m.rescale(0.5);
        }
        assert_eq!(m.global_scale(), 1.0, "scale should have folded");
        let expect = before * 0.5f64.powi(40);
        assert!((m.decision(&x) - expect).abs() < 1e-15 + expect.abs() * 1e-9);
    }

    #[test]
    fn swap_remove_keeps_remaining_svs() {
        let mut m = model_with(&[
            (&[0.0, 0.0], 1.0),
            (&[1.0, 1.0], 2.0),
            (&[2.0, 2.0], 3.0),
        ]);
        m.swap_remove(0);
        assert_eq!(m.num_sv(), 2);
        // last row moved into slot 0
        assert_eq!(m.sv(0), &[2.0, 2.0]);
        assert!((m.alpha(0) - 3.0).abs() < 1e-12);
        assert_eq!(m.sv(1), &[1.0, 1.0]);
    }

    #[test]
    fn argmin_abs_alpha_finds_smallest() {
        let m = model_with(&[(&[0.0, 0.0], -3.0), (&[1.0, 1.0], 0.5), (&[2.0, 2.0], 2.0)]);
        assert_eq!(m.argmin_abs_alpha(), Some(1));
        let empty = BudgetModel::new(2, Gaussian::new(1.0), 4);
        assert_eq!(empty.argmin_abs_alpha(), None);
    }

    #[test]
    fn kernel_row_prefix_matches_full_row() {
        let mut rng = Rng::new(41);
        let mut m = BudgetModel::new(3, Gaussian::new(0.4), 19);
        for _ in 0..19 {
            let row: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
            m.push(&row, rng.normal());
        }
        let x: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
        let xn = norm2(&x);
        let mut full = vec![0.0f64; 19];
        assert_eq!(m.kernel_row(&x, xn, &mut full), 19);
        for upto in [0usize, 1, 7, 8, 9, 16, 19, 25] {
            let expect = upto.min(19);
            let mut prefix = vec![f64::NAN; 19];
            assert_eq!(m.kernel_row_prefix(&x, xn, upto, &mut prefix), expect);
            for j in 0..expect {
                assert_eq!(prefix[j], full[j], "upto={upto} j={j}");
            }
            // Entries past the prefix are untouched.
            for j in expect..19 {
                assert!(prefix[j].is_nan(), "upto={upto} j={j} was written");
            }
        }
    }

    #[test]
    fn kernel_rows_for_svs_bit_match_single_rows() {
        let mut rng = Rng::new(53);
        let mut m = BudgetModel::new(4, Gaussian::new(0.3), 21);
        for _ in 0..21 {
            let row: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
            m.push(&row, rng.normal());
        }
        let queries = [0usize, 7, 8, 20, 3];
        let mut multi = vec![0.0f64; queries.len() * 21];
        m.kernel_rows_for_svs(&queries, &mut multi);
        let mut single = vec![0.0f64; 21];
        for (q, &sv) in queries.iter().enumerate() {
            m.kernel_row(m.sv(sv), m.sv_norm2(sv), &mut single);
            for j in 0..21 {
                assert_eq!(
                    multi[q * 21 + j].to_bits(),
                    single[j].to_bits(),
                    "query {q} (sv {sv}) col {j}"
                );
            }
        }
    }

    #[test]
    fn kernel_row_matches_decision() {
        let m = model_with(&[(&[0.0, 1.0], 1.5), (&[1.0, 0.0], -0.5), (&[1.0, 1.0], 0.25)]);
        let x = [0.2f32, 0.8];
        let mut row = vec![0.0f64; 3];
        let n = m.kernel_row(&x, norm2(&x), &mut row);
        assert_eq!(n, 3);
        let via_row: f64 =
            (0..3).map(|j| m.alpha(j) * row[j]).sum::<f64>() + m.bias;
        assert!((via_row - m.decision(&x)).abs() < 1e-12);
    }

    #[test]
    fn weight_norm2_single_sv() {
        let m = model_with(&[(&[1.0, 1.0], 2.0)]);
        // ‖2φ(x)‖² = 4·k(x,x) = 4
        assert!((m.weight_norm2() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn weight_norm2_symmetry_matches_full_matrix() {
        // The halved (upper-triangle) computation must equal the naive
        // full-matrix double loop it replaced.
        let mut rng = Rng::new(31);
        let mut m = BudgetModel::new(3, Gaussian::new(0.4), 13);
        for _ in 0..13 {
            let row: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
            m.push(&row, rng.normal());
        }
        m.rescale(0.7);
        let mut naive = 0.0f64;
        for i in 0..m.num_sv() {
            for j in 0..m.num_sv() {
                let k = m.kernel().eval(m.sv(i), m.sv_norm2(i), m.sv(j), m.sv_norm2(j));
                naive += m.alpha(i) * m.alpha(j) * k;
            }
        }
        let fast = m.weight_norm2();
        assert!(
            (fast - naive).abs() <= 1e-9 * (1.0 + naive.abs()),
            "fast={fast} naive={naive}"
        );
    }

    #[test]
    fn blocked_decision_matches_scalar_reference() {
        // Odd sizes around the tile boundary; Gaussian-random data (the
        // two summation orders agree to f32 rounding, checked loosely here
        // — the exact ≤1e-12 property lives in tests/block_engine.rs on
        // dyadic inputs).
        let mut rng = Rng::new(77);
        for &n_sv in &[1usize, 7, 8, 9, 16, 23] {
            let mut m = BudgetModel::new(5, Gaussian::new(0.3), n_sv);
            for _ in 0..n_sv {
                let row: Vec<f32> = (0..5).map(|_| rng.normal() as f32).collect();
                m.push(&row, rng.normal());
            }
            let x: Vec<f32> = (0..5).map(|_| rng.normal() as f32).collect();
            let xn = norm2(&x);
            let blocked = m.decision_with_norm(&x, xn);
            let scalar = m.decision_with_norm_scalar(&x, xn);
            assert!(
                (blocked - scalar).abs() <= 1e-5 * (1.0 + scalar.abs()),
                "n_sv={n_sv}: blocked={blocked} scalar={scalar}"
            );
        }
    }

    #[test]
    fn alphas_slice_is_effective_and_allocation_free() {
        let mut m = model_with(&[(&[0.0, 0.0], 1.0), (&[1.0, 1.0], -2.0)]);
        m.rescale(0.5);
        let a: Vec<f64> = m.alphas().to_vec();
        assert_eq!(a.len(), 2);
        assert!((a[0] - 0.5).abs() < 1e-15);
        assert!((a[1] + 1.0).abs() < 1e-15);
        // Folding happened: the scale is back to 1 and alpha(j) agrees.
        assert_eq!(m.global_scale(), 1.0);
        assert!((m.alpha(1) + 1.0).abs() < 1e-15);
    }

    #[test]
    fn threaded_batch_matches_sequential() {
        let mut rng = Rng::new(9);
        let mut m = BudgetModel::new(2, Gaussian::new(0.8), 10);
        for _ in 0..10 {
            m.push(&[rng.normal() as f32, rng.normal() as f32], rng.normal());
        }
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..53 {
            x.push(rng.normal() as f32);
            x.push(rng.normal() as f32);
            y.push(if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        let ds = crate::data::Dataset::new("t", x.clone(), y, 2);
        let seq = m.decision_batch(&ds);
        for threads in [1usize, 2, 4, 7] {
            let par = m.decision_batch_threaded(&ds, threads);
            assert_eq!(seq.len(), par.len());
            for (a, b) in seq.iter().zip(&par) {
                assert!((a - b).abs() == 0.0, "threads={threads}: {a} vs {b}");
            }
            assert_eq!(m.accuracy(&ds), m.accuracy_threaded(&ds, threads));
        }
        let rows = m.decision_rows(&x, 3);
        for (a, b) in seq.iter().zip(&rows) {
            assert!((a - b).abs() == 0.0);
        }
    }

    #[test]
    fn empty_model_predicts_nonnegative_class() {
        let m = BudgetModel::new(2, Gaussian::new(1.0), 4);
        assert_eq!(m.decision(&[1.0, 2.0]), 0.0);
        assert_eq!(m.predict(&[1.0, 2.0]), 1.0);
    }

    #[test]
    fn accuracy_on_trivial_dataset() {
        let m = model_with(&[(&[0.0, 0.0], 1.0), (&[4.0, 4.0], -1.0)]);
        let ds = crate::data::Dataset::new(
            "t",
            vec![0.1, 0.1, 3.9, 3.9],
            vec![1.0, -1.0],
            2,
        );
        assert_eq!(m.accuracy(&ds), 1.0);
    }

    #[test]
    fn linear_model_decision_matches_dot_expansion() {
        let mut m = BudgetModel::new(2, Linear, 2);
        m.push(&[1.0, 0.0], 2.0);
        m.push(&[0.0, 1.0], -1.0);
        // f(x) = 2·⟨(1,0),x⟩ − 1·⟨(0,1),x⟩ = 2x₀ − x₁
        let x = [0.5f32, 0.25];
        assert!((m.decision(&x) - (2.0 * 0.5 - 0.25)).abs() < 1e-6);
    }

    #[test]
    fn polynomial_model_weight_norm_uses_kernel_diagonal() {
        let mut m = BudgetModel::new(2, Polynomial::new(1.0, 1.0, 2), 1);
        m.push(&[1.0, 1.0], 1.0);
        // ‖w‖² = k(x,x) = (⟨x,x⟩ + 1)² = 9
        assert!((m.weight_norm2() - 9.0).abs() < 1e-6);
    }

    #[test]
    fn any_model_dispatches_by_kernel() {
        for spec in [
            KernelSpec::gaussian(0.5),
            KernelSpec::linear(),
            KernelSpec::polynomial(2, 1.0),
        ] {
            let mut m = AnyModel::new(2, spec, 4).unwrap();
            m.push(&[1.0, 0.0], 1.0);
            m.push(&[0.0, 1.0], -0.5);
            m.set_bias(0.25);
            assert_eq!(m.dim(), 2);
            assert_eq!(m.num_sv(), 2);
            assert_eq!(m.kernel_spec(), spec);
            assert_eq!(m.bias(), 0.25);
            assert!((m.alpha(1) + 0.5).abs() < 1e-12);
            assert_eq!(m.sv(0), &[1.0, 0.0]);
            // decision must match the concrete kernel expansion + bias.
            let x = [0.3f32, 0.7];
            let expect = 1.0 * spec.eval(&x, norm2(&x), &[1.0, 0.0], 1.0)
                - 0.5 * spec.eval(&x, norm2(&x), &[0.0, 1.0], 1.0)
                + 0.25;
            assert!((m.decision(&x) - expect).abs() < 1e-9, "{}", spec.describe());
            assert_eq!(m.predict(&x), if expect >= 0.0 { 1.0 } else { -1.0 });
        }
    }

    #[test]
    fn fast_exp_toggle_is_close_gaussian_only_and_not_serialized() {
        let mut m = model_with(&[(&[0.0, 0.5], 1.25), (&[1.0, -0.5], -0.75)]);
        let x = [0.4f32, 0.1];
        let before = m.decision(&x);
        assert!(!m.fast_exp());
        m.set_fast_exp(true);
        assert!(m.fast_exp());
        let after = m.decision(&x);
        assert!(
            (before - after).abs() <= 1e-12 * (1.0 + before.abs()),
            "fast-exp decision drifted: {before} vs {after}"
        );
        // The tier is not a model property: the spec is unchanged.
        assert_eq!(m.kernel_spec(), KernelSpec::gaussian(0.5));
        // Non-Gaussian kernels have no exponential: the toggle is a no-op.
        let mut lm = AnyModel::new(2, KernelSpec::linear(), 2).unwrap();
        lm.set_fast_exp(true);
        assert!(!lm.fast_exp());
        let mut gm = AnyModel::new(2, KernelSpec::gaussian(1.0), 2).unwrap();
        gm.set_fast_exp(true);
        assert!(gm.fast_exp());
    }

    #[test]
    fn any_model_gaussian_extraction() {
        let g = AnyModel::new(3, KernelSpec::gaussian(1.0), 2).unwrap();
        assert!(g.as_gaussian().is_some());
        assert!(g.into_gaussian().is_ok());
        let l = AnyModel::new(3, KernelSpec::linear(), 2).unwrap();
        assert!(l.as_gaussian().is_none());
        assert!(l.into_gaussian().is_err());
        assert!(AnyModel::new(3, KernelSpec::gaussian(-1.0), 2).is_err());
    }

    #[test]
    fn store_survives_heavy_churn() {
        // Interleaved push/swap_remove across tile boundaries keeps the
        // blocked and scalar paths agreeing.
        forall("model churn keeps layouts in sync", 32, 0xBEEF7, |rng| {
            let mut m = BudgetModel::new(3, Gaussian::new(0.6), 8);
            for _ in 0..60 {
                if m.is_empty() || rng.bernoulli(0.6) {
                    let row: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
                    m.push(&row, rng.normal());
                } else {
                    let j = rng.below(m.num_sv());
                    m.swap_remove(j);
                }
            }
            let x: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
            let xn = norm2(&x);
            let blocked = m.decision_with_norm(&x, xn);
            let scalar = m.decision_with_norm_scalar(&x, xn);
            let ok = (blocked - scalar).abs() <= 1e-5 * (1.0 + scalar.abs());
            (ok, format!("n_sv={} blocked={blocked} scalar={scalar}", m.num_sv()))
        });
    }
}

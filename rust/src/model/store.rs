//! Cache-blocked support-vector storage.
//!
//! [`SvStore`] keeps the SV matrix in two synchronized layouts:
//!
//! * **rows** — the classic flat row-major matrix (`count · d` values),
//!   serving random row access (`sv(j)`), serialization, and the scalar
//!   reference path;
//! * **tiles** — the blocked SoA layout the kernel-row engine runs on:
//!   groups of `TILE = 8` consecutive SVs, stored *feature-major within
//!   the tile* (`tiles[t·d·T + k·T + l]` is feature `k` of SV `t·T + l`).
//!   One pass over a query row `x` then computes all `TILE` inner products
//!   of a tile with a broadcast-FMA micro-kernel: `x[k]` is loaded once
//!   and multiplied against 8 contiguous lane values — one 8-wide `f32`
//!   FMA per feature, executed by the runtime-dispatched
//!   [`crate::kernel::simd`] layer (hand-written AVX2+FMA when the CPU
//!   supports it, the portable scalar loop otherwise).
//!
//! Invariants (relied on by [`crate::model::BudgetModel`] and the tests):
//!
//! * `tiles.len() == ⌈count/T⌉ · d · T` and `norms.len() == ⌈count/T⌉ · T`;
//!   both layouts always describe the same `count` rows.
//! * `tiles` and `norms` live in [`AlignedF32`] buffers whose base is
//!   64-byte aligned; since one tile spans `32·d` bytes, every 8-lane
//!   feature group starts on a 32-byte boundary — the AVX2 loads are
//!   always aligned (push/swap_remove/clear never change the base).
//! * Padding lanes of the last tile hold zero data and zero norms, so a
//!   kernel evaluated on a padding lane is a well-defined (if meaningless)
//!   number — consumers mask padding by *coefficient range*, never by
//!   branching inside the micro-kernel.
//! * [`SvStore::swap_remove`] mirrors the classic swap-remove in both
//!   layouts (order is not preserved) and re-zeroes the vacated lane.

use crate::kernel::{norm2, simd, TILE};
use crate::util::aligned::AlignedF32;

/// Support vectors in synchronized row-major + SoA-tile layouts with
/// co-located squared norms.
#[derive(Debug, Clone)]
pub struct SvStore {
    d: usize,
    count: usize,
    /// Row-major mirror, `count * d` valid entries.
    rows: Vec<f32>,
    /// SoA tiles, `⌈count/TILE⌉ * d * TILE` entries, padding lanes zero;
    /// 64-byte-aligned base so vector loads never straddle unaligned.
    tiles: AlignedF32,
    /// Squared L2 norms, padded to a TILE multiple (padding entries zero).
    norms: AlignedF32,
}

impl SvStore {
    /// New empty store; `capacity` is a row-count reservation hint.
    pub fn new(d: usize, capacity: usize) -> Self {
        let cap_tiles = capacity.div_ceil(TILE);
        SvStore {
            d,
            count: 0,
            rows: Vec::with_capacity(capacity * d),
            tiles: AlignedF32::with_capacity(cap_tiles * d * TILE),
            norms: AlignedF32::with_capacity(cap_tiles * TILE),
        }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.count
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Row `j` (row-major mirror).
    #[inline]
    pub fn row(&self, j: usize) -> &[f32] {
        &self.rows[j * self.d..(j + 1) * self.d]
    }

    /// Squared norm of row `j`.
    #[inline]
    pub fn norm2(&self, j: usize) -> f32 {
        debug_assert!(j < self.count);
        self.norms[j]
    }

    /// Number of SoA tiles (`⌈len/TILE⌉`).
    #[inline]
    pub fn num_tiles(&self) -> usize {
        self.count.div_ceil(TILE)
    }

    /// Squared norms of tile `t`'s lanes (padding lanes read 0).
    #[inline]
    pub fn tile_norms(&self, t: usize) -> &[f32; TILE] {
        let s = &self.norms[t * TILE..(t + 1) * TILE];
        s.try_into().expect("tile norm slice has TILE entries")
    }

    /// Feature-major data of tile `t` (`d * TILE` entries).
    #[inline]
    fn tile_data(&self, t: usize) -> &[f32] {
        &self.tiles[t * self.d * TILE..(t + 1) * self.d * TILE]
    }

    /// The 8-lane FMA micro-kernel: one pass over `x` computing the inner
    /// products against all `TILE` lanes of tile `t`, through the
    /// runtime-dispatched [`crate::kernel::simd`] layer (AVX2+FMA when
    /// available, the portable 8-lane-unrolled loop otherwise).
    #[inline]
    pub fn tile_dots(&self, t: usize, x: &[f32], out: &mut [f32; TILE]) {
        debug_assert_eq!(x.len(), self.d);
        simd::tile_dots(self.tile_data(t), x, out);
    }

    /// [`SvStore::tile_dots`] on an explicit tier — the per-row seam:
    /// callers resolve [`simd::active`] once per kernel row and thread
    /// the tier through every tile instead of re-dispatching per tile.
    #[inline]
    pub fn tile_dots_with(&self, tier: simd::Tier, t: usize, x: &[f32], out: &mut [f32; TILE]) {
        debug_assert_eq!(x.len(), self.d);
        simd::tile_dots_with(tier, self.tile_data(t), x, out);
    }

    /// Fused decision contribution of tile `t`: dots → kernel finish →
    /// α-weighted accumulate in one pass ([`simd::tile_decision_with`]),
    /// no materialized κ buffer. `alphas` holds the live coefficients
    /// for this tile (`len ≤ TILE`); padding lanes are never read.
    #[inline]
    pub fn tile_decision(
        &self,
        tier: simd::Tier,
        op: simd::KernelOp,
        t: usize,
        x: &[f32],
        x_norm2: f32,
        alphas: &[f64],
    ) -> f64 {
        debug_assert_eq!(x.len(), self.d);
        simd::tile_decision_with(
            tier,
            op,
            self.tile_data(t),
            x,
            x_norm2,
            self.tile_norms(t),
            alphas,
        )
    }

    /// Inner products of several query rows against tile `t`, visiting the
    /// tile's feature data once for all queries (the amortized multi-pivot
    /// scan of `BudgetModel::kernel_rows_for_svs`). Row `q` of `out` is
    /// bit-identical to `tile_dots(t, xs[q], ...)`.
    #[inline]
    pub fn tile_dots_multi(&self, t: usize, xs: &[&[f32]], out: &mut [[f32; TILE]]) {
        for x in xs {
            debug_assert_eq!(x.len(), self.d);
        }
        simd::tile_dots_multi(self.tile_data(t), xs, out);
    }

    /// [`SvStore::tile_dots_multi`] on an explicit tier (the per-scan
    /// seam of `BudgetModel::kernel_rows_for_svs`).
    #[inline]
    pub fn tile_dots_multi_with(
        &self,
        tier: simd::Tier,
        t: usize,
        xs: &[&[f32]],
        out: &mut [[f32; TILE]],
    ) {
        for x in xs {
            debug_assert_eq!(x.len(), self.d);
        }
        simd::tile_dots_multi_with(tier, self.tile_data(t), xs, out);
    }

    /// Append a row; its squared norm is computed here (same `norm2` as
    /// the scalar path, so cached norms are bit-identical to recomputed
    /// ones).
    pub fn push(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.d, "row has wrong dimension");
        let lane = self.count % TILE;
        if lane == 0 {
            // Open a fresh zeroed tile.
            self.tiles.resize(self.tiles.len() + self.d * TILE, 0.0);
            self.norms.resize(self.norms.len() + TILE, 0.0);
        }
        let t = self.count / TILE;
        let base = t * self.d * TILE + lane;
        for (k, &v) in x.iter().enumerate() {
            self.tiles[base + k * TILE] = v;
        }
        self.rows.extend_from_slice(x);
        self.norms[t * TILE + lane] = norm2(x);
        self.count += 1;
    }

    /// Swap-remove row `j` (order is not preserved): the last row moves
    /// into slot `j` in both layouts, the vacated last lane is re-zeroed,
    /// and an emptied trailing tile is dropped.
    pub fn swap_remove(&mut self, j: usize) {
        assert!(j < self.count, "swap_remove index {j} out of range {}", self.count);
        let last = self.count - 1;
        let d = self.d;
        if j != last {
            let (head, tail) = self.rows.split_at_mut(last * d);
            head[j * d..(j + 1) * d].copy_from_slice(&tail[..d]);
            self.norms[j] = self.norms[last];
            let (tj, lj) = (j / TILE, j % TILE);
            let (tl, ll) = (last / TILE, last % TILE);
            for k in 0..d {
                self.tiles[tj * d * TILE + k * TILE + lj] =
                    self.tiles[tl * d * TILE + k * TILE + ll];
            }
        }
        let (tl, ll) = (last / TILE, last % TILE);
        for k in 0..d {
            self.tiles[tl * d * TILE + k * TILE + ll] = 0.0;
        }
        self.norms[last] = 0.0;
        self.rows.truncate(last * d);
        self.count = last;
        if ll == 0 {
            // The trailing tile just became empty: drop it entirely.
            self.tiles.truncate(tl * d * TILE);
            self.norms.truncate(tl * TILE);
        }
    }

    /// Remove all rows.
    pub fn clear(&mut self) {
        self.rows.clear();
        self.tiles.clear();
        self.norms.clear();
        self.count = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dot;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn dots_reference(store: &SvStore, x: &[f32]) -> Vec<f32> {
        (0..store.len()).map(|j| dot(x, store.row(j))).collect()
    }

    fn tile_dots_all(store: &SvStore, x: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        let mut buf = [0.0f32; TILE];
        for t in 0..store.num_tiles() {
            store.tile_dots(t, x, &mut buf);
            let lanes = TILE.min(store.len() - t * TILE);
            out.extend_from_slice(&buf[..lanes]);
        }
        out
    }

    #[test]
    fn push_and_row_roundtrip_across_tile_boundary() {
        let mut s = SvStore::new(3, 4);
        for j in 0..11 {
            let row = [j as f32, j as f32 + 0.5, -(j as f32)];
            s.push(&row);
        }
        assert_eq!(s.len(), 11);
        assert_eq!(s.num_tiles(), 2);
        for j in 0..11 {
            assert_eq!(s.row(j), &[j as f32, j as f32 + 0.5, -(j as f32)]);
            assert!((s.norm2(j) - dot(s.row(j), s.row(j))).abs() < 1e-4);
        }
        // Padding lanes of the last tile are inert.
        let tn = s.tile_norms(1);
        for l in 3..TILE {
            assert_eq!(tn[l], 0.0);
        }
    }

    #[test]
    fn tile_dots_match_rowwise_dot_on_dyadic_data() {
        // Dyadic-rational inputs make every product and partial sum exact
        // in f32, so the two accumulation orders agree bit-for-bit.
        forall("tile dots = row dots", 64, 0x71135, |rng| {
            let d = [1, 3, 8, 17][rng.below(4)];
            let n = 1 + rng.below(21);
            let mut s = SvStore::new(d, n);
            let mut gen = |rng: &mut Rng| ((rng.below(129) as i64 - 64) as f32) / 16.0;
            for _ in 0..n {
                let row: Vec<f32> = (0..d).map(|_| gen(rng)).collect();
                s.push(&row);
            }
            let x: Vec<f32> = (0..d).map(|_| gen(rng)).collect();
            let blocked = tile_dots_all(&s, &x);
            let scalar = dots_reference(&s, &x);
            let ok = blocked
                .iter()
                .zip(&scalar)
                .all(|(a, b)| (a - b).abs() <= 1e-12 * (1.0 + b.abs()));
            (ok, format!("d={d} n={n} blocked={blocked:?} scalar={scalar:?}"))
        });
    }

    #[test]
    fn swap_remove_keeps_layouts_synchronized() {
        forall("swap_remove layout sync", 48, 0xDEAD5, |rng| {
            let d = 1 + rng.below(9);
            let mut s = SvStore::new(d, 8);
            let mut gen = |rng: &mut Rng| ((rng.below(65) as i64 - 32) as f32) / 8.0;
            // Random interleaving of pushes and removals.
            for _ in 0..40 {
                if s.is_empty() || rng.bernoulli(0.65) {
                    let row: Vec<f32> = (0..d).map(|_| gen(rng)).collect();
                    s.push(&row);
                } else {
                    let j = rng.below(s.len());
                    s.swap_remove(j);
                }
            }
            if s.is_empty() {
                return (true, "emptied".to_string());
            }
            let x: Vec<f32> = (0..d).map(|_| gen(rng)).collect();
            let blocked = tile_dots_all(&s, &x);
            let scalar = dots_reference(&s, &x);
            let ok = blocked.len() == scalar.len()
                && blocked
                    .iter()
                    .zip(&scalar)
                    .all(|(a, b)| (a - b).abs() <= 1e-12 * (1.0 + b.abs()));
            (ok, format!("d={d} len={} blocked={blocked:?} scalar={scalar:?}", s.len()))
        });
    }

    #[test]
    fn clear_resets_everything() {
        let mut s = SvStore::new(2, 4);
        s.push(&[1.0, 2.0]);
        s.push(&[3.0, 4.0]);
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.num_tiles(), 0);
        s.push(&[5.0, 6.0]);
        assert_eq!(s.row(0), &[5.0, 6.0]);
        assert_eq!(tile_dots_all(&s, &[1.0, 1.0]), vec![11.0]);
    }

    #[test]
    fn removing_the_only_row_drops_the_tile() {
        let mut s = SvStore::new(2, 2);
        s.push(&[1.0, 1.0]);
        s.swap_remove(0);
        assert!(s.is_empty());
        assert_eq!(s.num_tiles(), 0);
    }

    #[test]
    fn tile_storage_stays_64_byte_aligned_through_churn() {
        // The AVX2 micro-kernels rely on the aligned-buffer invariant:
        // the tile base is 64-byte aligned whenever an allocation exists,
        // and push / swap_remove / clear never break it.
        let check = |s: &SvStore, what: &str| {
            if s.tiles.capacity() > 0 {
                assert_eq!(
                    s.tiles.as_ptr() as usize % crate::util::aligned::ALIGN,
                    0,
                    "tile base unaligned {what}"
                );
            }
            if s.norms.capacity() > 0 {
                assert_eq!(
                    s.norms.as_ptr() as usize % crate::util::aligned::ALIGN,
                    0,
                    "norm base unaligned {what}"
                );
            }
        };
        let mut rng = Rng::new(0xA11A);
        let mut s = SvStore::new(5, 2);
        check(&s, "after new");
        for step in 0..120 {
            if s.is_empty() || rng.bernoulli(0.6) {
                let row: Vec<f32> = (0..5).map(|_| rng.normal() as f32).collect();
                s.push(&row);
            } else {
                let j = rng.below(s.len());
                s.swap_remove(j);
            }
            check(&s, &format!("at churn step {step}"));
        }
        s.clear();
        check(&s, "after clear");
        s.push(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        check(&s, "after post-clear push");
    }

    #[test]
    fn tile_dots_multi_bit_matches_single_queries() {
        let d = 7usize;
        let mut rng = Rng::new(0x517E);
        let mut s = SvStore::new(d, 8);
        for _ in 0..19 {
            let row: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            s.push(&row);
        }
        // 1..=6 queries cover the 4-wide SIMD block plus remainders.
        let queries: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
            .collect();
        for nq in 1..=queries.len() {
            let refs: Vec<&[f32]> = queries[..nq].iter().map(|v| v.as_slice()).collect();
            let mut multi = vec![[0.0f32; TILE]; nq];
            let mut single = [0.0f32; TILE];
            for t in 0..s.num_tiles() {
                s.tile_dots_multi(t, &refs, &mut multi);
                for (q, x) in refs.iter().enumerate() {
                    s.tile_dots(t, x, &mut single);
                    for l in 0..TILE {
                        assert_eq!(
                            multi[q][l].to_bits(),
                            single[l].to_bits(),
                            "nq={nq} tile {t} query {q} lane {l}"
                        );
                    }
                }
            }
        }
    }
}

//! Cache-blocked support-vector storage.
//!
//! [`SvStore`] keeps the SV matrix in two synchronized layouts:
//!
//! * **rows** — the classic flat row-major matrix (`count · d` values),
//!   serving random row access (`sv(j)`), serialization, and the scalar
//!   reference path;
//! * **tiles** — the blocked SoA layout the kernel-row engine runs on:
//!   groups of `TILE = 8` consecutive SVs, stored *feature-major within
//!   the tile* (`tiles[t·d·T + k·T + l]` is feature `k` of SV `t·T + l`).
//!   One pass over a query row `x` then computes all `TILE` inner products
//!   of a tile with a broadcast-FMA micro-kernel — `x[k]` is loaded once
//!   and multiplied against 8 contiguous lane values, which the
//!   auto-vectorizer turns into a single 8-wide `f32` FMA per feature.
//!
//! Invariants (relied on by [`crate::model::BudgetModel`] and the tests):
//!
//! * `tiles.len() == ⌈count/T⌉ · d · T` and `norms.len() == ⌈count/T⌉ · T`;
//!   both layouts always describe the same `count` rows.
//! * Padding lanes of the last tile hold zero data and zero norms, so a
//!   kernel evaluated on a padding lane is a well-defined (if meaningless)
//!   number — consumers mask padding by *coefficient range*, never by
//!   branching inside the micro-kernel.
//! * [`SvStore::swap_remove`] mirrors the classic swap-remove in both
//!   layouts (order is not preserved) and re-zeroes the vacated lane.

use crate::kernel::{norm2, TILE};

/// Support vectors in synchronized row-major + SoA-tile layouts with
/// co-located squared norms.
#[derive(Debug, Clone)]
pub struct SvStore {
    d: usize,
    count: usize,
    /// Row-major mirror, `count * d` valid entries.
    rows: Vec<f32>,
    /// SoA tiles, `⌈count/TILE⌉ * d * TILE` entries, padding lanes zero.
    tiles: Vec<f32>,
    /// Squared L2 norms, padded to a TILE multiple (padding entries zero).
    norms: Vec<f32>,
}

impl SvStore {
    /// New empty store; `capacity` is a row-count reservation hint.
    pub fn new(d: usize, capacity: usize) -> Self {
        let cap_tiles = capacity.div_ceil(TILE);
        SvStore {
            d,
            count: 0,
            rows: Vec::with_capacity(capacity * d),
            tiles: Vec::with_capacity(cap_tiles * d * TILE),
            norms: Vec::with_capacity(cap_tiles * TILE),
        }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.count
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Row `j` (row-major mirror).
    #[inline]
    pub fn row(&self, j: usize) -> &[f32] {
        &self.rows[j * self.d..(j + 1) * self.d]
    }

    /// Squared norm of row `j`.
    #[inline]
    pub fn norm2(&self, j: usize) -> f32 {
        debug_assert!(j < self.count);
        self.norms[j]
    }

    /// Number of SoA tiles (`⌈len/TILE⌉`).
    #[inline]
    pub fn num_tiles(&self) -> usize {
        self.count.div_ceil(TILE)
    }

    /// Squared norms of tile `t`'s lanes (padding lanes read 0).
    #[inline]
    pub fn tile_norms(&self, t: usize) -> &[f32; TILE] {
        let s = &self.norms[t * TILE..(t + 1) * TILE];
        s.try_into().expect("tile norm slice has TILE entries")
    }

    /// The 8-lane-unrolled FMA micro-kernel: one pass over `x` computing
    /// the inner products against all `TILE` lanes of tile `t`. The inner
    /// fixed-bound loop compiles to one 8-wide f32 multiply-add per
    /// feature (the `chunks_exact` iterator keeps bounds checks out of the
    /// loop body).
    #[inline]
    pub fn tile_dots(&self, t: usize, x: &[f32], out: &mut [f32; TILE]) {
        debug_assert_eq!(x.len(), self.d);
        let tile = &self.tiles[t * self.d * TILE..(t + 1) * self.d * TILE];
        let mut acc = [0.0f32; TILE];
        for (lanes, &xk) in tile.chunks_exact(TILE).zip(x.iter()) {
            for (a, &v) in acc.iter_mut().zip(lanes) {
                *a += xk * v;
            }
        }
        *out = acc;
    }

    /// Append a row; its squared norm is computed here (same `norm2` as
    /// the scalar path, so cached norms are bit-identical to recomputed
    /// ones).
    pub fn push(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.d, "row has wrong dimension");
        let lane = self.count % TILE;
        if lane == 0 {
            // Open a fresh zeroed tile.
            self.tiles.resize(self.tiles.len() + self.d * TILE, 0.0);
            self.norms.resize(self.norms.len() + TILE, 0.0);
        }
        let t = self.count / TILE;
        let base = t * self.d * TILE + lane;
        for (k, &v) in x.iter().enumerate() {
            self.tiles[base + k * TILE] = v;
        }
        self.rows.extend_from_slice(x);
        self.norms[t * TILE + lane] = norm2(x);
        self.count += 1;
    }

    /// Swap-remove row `j` (order is not preserved): the last row moves
    /// into slot `j` in both layouts, the vacated last lane is re-zeroed,
    /// and an emptied trailing tile is dropped.
    pub fn swap_remove(&mut self, j: usize) {
        assert!(j < self.count, "swap_remove index {j} out of range {}", self.count);
        let last = self.count - 1;
        let d = self.d;
        if j != last {
            let (head, tail) = self.rows.split_at_mut(last * d);
            head[j * d..(j + 1) * d].copy_from_slice(&tail[..d]);
            self.norms[j] = self.norms[last];
            let (tj, lj) = (j / TILE, j % TILE);
            let (tl, ll) = (last / TILE, last % TILE);
            for k in 0..d {
                self.tiles[tj * d * TILE + k * TILE + lj] =
                    self.tiles[tl * d * TILE + k * TILE + ll];
            }
        }
        let (tl, ll) = (last / TILE, last % TILE);
        for k in 0..d {
            self.tiles[tl * d * TILE + k * TILE + ll] = 0.0;
        }
        self.norms[last] = 0.0;
        self.rows.truncate(last * d);
        self.count = last;
        if ll == 0 {
            // The trailing tile just became empty: drop it entirely.
            self.tiles.truncate(tl * d * TILE);
            self.norms.truncate(tl * TILE);
        }
    }

    /// Remove all rows.
    pub fn clear(&mut self) {
        self.rows.clear();
        self.tiles.clear();
        self.norms.clear();
        self.count = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::dot;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn dots_reference(store: &SvStore, x: &[f32]) -> Vec<f32> {
        (0..store.len()).map(|j| dot(x, store.row(j))).collect()
    }

    fn tile_dots_all(store: &SvStore, x: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        let mut buf = [0.0f32; TILE];
        for t in 0..store.num_tiles() {
            store.tile_dots(t, x, &mut buf);
            let lanes = TILE.min(store.len() - t * TILE);
            out.extend_from_slice(&buf[..lanes]);
        }
        out
    }

    #[test]
    fn push_and_row_roundtrip_across_tile_boundary() {
        let mut s = SvStore::new(3, 4);
        for j in 0..11 {
            let row = [j as f32, j as f32 + 0.5, -(j as f32)];
            s.push(&row);
        }
        assert_eq!(s.len(), 11);
        assert_eq!(s.num_tiles(), 2);
        for j in 0..11 {
            assert_eq!(s.row(j), &[j as f32, j as f32 + 0.5, -(j as f32)]);
            assert!((s.norm2(j) - dot(s.row(j), s.row(j))).abs() < 1e-4);
        }
        // Padding lanes of the last tile are inert.
        let tn = s.tile_norms(1);
        for l in 3..TILE {
            assert_eq!(tn[l], 0.0);
        }
    }

    #[test]
    fn tile_dots_match_rowwise_dot_on_dyadic_data() {
        // Dyadic-rational inputs make every product and partial sum exact
        // in f32, so the two accumulation orders agree bit-for-bit.
        forall("tile dots = row dots", 64, 0x71135, |rng| {
            let d = [1, 3, 8, 17][rng.below(4)];
            let n = 1 + rng.below(21);
            let mut s = SvStore::new(d, n);
            let mut gen = |rng: &mut Rng| ((rng.below(129) as i64 - 64) as f32) / 16.0;
            for _ in 0..n {
                let row: Vec<f32> = (0..d).map(|_| gen(rng)).collect();
                s.push(&row);
            }
            let x: Vec<f32> = (0..d).map(|_| gen(rng)).collect();
            let blocked = tile_dots_all(&s, &x);
            let scalar = dots_reference(&s, &x);
            let ok = blocked
                .iter()
                .zip(&scalar)
                .all(|(a, b)| (a - b).abs() <= 1e-12 * (1.0 + b.abs()));
            (ok, format!("d={d} n={n} blocked={blocked:?} scalar={scalar:?}"))
        });
    }

    #[test]
    fn swap_remove_keeps_layouts_synchronized() {
        forall("swap_remove layout sync", 48, 0xDEAD5, |rng| {
            let d = 1 + rng.below(9);
            let mut s = SvStore::new(d, 8);
            let mut gen = |rng: &mut Rng| ((rng.below(65) as i64 - 32) as f32) / 8.0;
            // Random interleaving of pushes and removals.
            for _ in 0..40 {
                if s.is_empty() || rng.bernoulli(0.65) {
                    let row: Vec<f32> = (0..d).map(|_| gen(rng)).collect();
                    s.push(&row);
                } else {
                    let j = rng.below(s.len());
                    s.swap_remove(j);
                }
            }
            if s.is_empty() {
                return (true, "emptied".to_string());
            }
            let x: Vec<f32> = (0..d).map(|_| gen(rng)).collect();
            let blocked = tile_dots_all(&s, &x);
            let scalar = dots_reference(&s, &x);
            let ok = blocked.len() == scalar.len()
                && blocked
                    .iter()
                    .zip(&scalar)
                    .all(|(a, b)| (a - b).abs() <= 1e-12 * (1.0 + b.abs()));
            (ok, format!("d={d} len={} blocked={blocked:?} scalar={scalar:?}", s.len()))
        });
    }

    #[test]
    fn clear_resets_everything() {
        let mut s = SvStore::new(2, 4);
        s.push(&[1.0, 2.0]);
        s.push(&[3.0, 4.0]);
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.num_tiles(), 0);
        s.push(&[5.0, 6.0]);
        assert_eq!(s.row(0), &[5.0, 6.0]);
        assert_eq!(tile_dots_all(&s, &[1.0, 1.0]), vec![11.0]);
    }

    #[test]
    fn removing_the_only_row_drops_the_tile() {
        let mut s = SvStore::new(2, 2);
        s.push(&[1.0, 1.0]);
        s.swap_remove(0);
        assert!(s.is_empty());
        assert_eq!(s.num_tiles(), 0);
    }
}

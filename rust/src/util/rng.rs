//! Deterministic pseudo-random number generation.
//!
//! Implements xoshiro256** (Blackman & Vigna) seeded through splitmix64,
//! plus the handful of distributions the synthetic data generators and the
//! stochastic solvers need: uniform reals/ints, Bernoulli, standard normal
//! (Box–Muller with a cached spare), and Fisher–Yates shuffling.
//!
//! Every run of every experiment takes an explicit `u64` seed so that all
//! tables and figures are exactly reproducible.

/// xoshiro256** pseudo-random generator.
///
/// Passes BigCrush; period 2^256 − 1. Not cryptographic — this is a
/// simulation RNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box–Muller transform.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed. Any seed (including 0) is fine;
    /// the state is expanded through splitmix64 as recommended by the
    /// xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child generator (for per-thread / per-run
    /// streams). Uses the jump-free "seed a new state from the output
    /// stream" construction, which is adequate for statistically independent
    /// simulation streams.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        // Widening multiply rejection sampling (unbiased).
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as usize;
            }
            // threshold = (2^64 - n) mod n = (-n) mod n
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller (polar-free, trigonometric form), with
    /// the second variate cached.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n), order unspecified.
    /// Floyd's algorithm; O(k) expected.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_and_in_range() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            let k = r.below(7);
            assert!(k < 7);
            counts[k] += 1;
        }
        for &c in &counts {
            let expected = n as f64 / 7.0;
            assert!((c as f64 - expected).abs() < 5.0 * expected.sqrt());
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        for _ in 0..20 {
            let s = r.sample_indices(100, 30);
            assert_eq!(s.len(), 30);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 30);
            assert!(s.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn fork_streams_are_decorrelated() {
        let mut parent = Rng::new(123);
        let mut a = parent.fork();
        let mut b = parent.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}

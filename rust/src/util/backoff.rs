//! Seeded-jitter exponential backoff with a bounded retry budget.
//!
//! Every coordinator↔node link in the cluster tier retries through one
//! of these: the delay sequence is exponential with **equal jitter**
//! (delay drawn uniformly from `[raw/2, raw)` where
//! `raw = min(cap, base · 2^attempt)`), so synchronized retries from
//! many links decorrelate without ever collapsing below half the
//! nominal step. The jitter stream comes from [`crate::util::rng::Rng`]
//! seeded per link, which keeps every retry schedule — and therefore
//! every cluster bench scenario — deterministic under a fixed seed.
//!
//! A `Backoff` also carries a **retry budget**: once `budget` delays
//! have been handed out, [`Backoff::next_delay`] returns the typed
//! [`RetryBudgetExhausted`] error instead of another delay, which is
//! the caller's signal to mark the link down rather than spin forever.

use std::fmt;
use std::time::Duration;

use crate::util::rng::Rng;

/// Typed error returned when a [`Backoff`]'s retry budget is spent.
///
/// Carries the number of attempts that were made so callers can report
/// it without re-deriving state from the backoff handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryBudgetExhausted {
    /// Attempts made before the budget ran out.
    pub attempts: u32,
}

impl fmt::Display for RetryBudgetExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "retry budget exhausted after {} attempts", self.attempts)
    }
}

impl std::error::Error for RetryBudgetExhausted {}

/// Deterministic equal-jitter exponential backoff with a retry budget.
#[derive(Debug)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    budget: u32,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    /// A backoff starting at `base`, doubling per attempt up to `cap`,
    /// allowing at most `budget` delays, jittered by a stream seeded
    /// with `seed`.
    pub fn new(base: Duration, cap: Duration, budget: u32, seed: u64) -> Self {
        Backoff { base, cap, budget, attempt: 0, rng: Rng::new(seed) }
    }

    /// The next delay to sleep before retrying, or the typed
    /// [`RetryBudgetExhausted`] error once `budget` delays have been
    /// consumed. Equal jitter: uniform in `[raw/2, raw)` with
    /// `raw = min(cap, base · 2^attempt)`.
    pub fn next_delay(&mut self) -> Result<Duration, RetryBudgetExhausted> {
        if self.attempt >= self.budget {
            return Err(RetryBudgetExhausted { attempts: self.attempt });
        }
        let raw = self.raw_delay(self.attempt);
        self.attempt += 1;
        let raw_ns = raw.as_nanos() as u64;
        let half = raw_ns / 2;
        let jittered = half + ((raw_ns - half) as f64 * self.rng.uniform()) as u64;
        Ok(Duration::from_nanos(jittered))
    }

    /// Reset the attempt counter after a successful exchange so the
    /// next failure starts from the base delay again. The jitter
    /// stream is *not* rewound — determinism comes from the seed plus
    /// the (deterministic, in benches) sequence of failures.
    pub fn reset(&mut self) {
        self.attempt = 0;
    }

    /// Delays handed out since construction or the last [`reset`].
    ///
    /// [`reset`]: Backoff::reset
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    fn raw_delay(&self, attempt: u32) -> Duration {
        let mult = 1u32.checked_shl(attempt).unwrap_or(u32::MAX);
        self.base.checked_mul(mult).map_or(self.cap, |d| d.min(self.cap))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(b: &mut Backoff, n: usize) -> Vec<Duration> {
        (0..n).map(|_| b.next_delay().unwrap()).collect()
    }

    #[test]
    fn same_seed_gives_identical_delay_sequences() {
        let mk = || Backoff::new(Duration::from_millis(10), Duration::from_secs(1), 8, 42);
        let (mut a, mut b) = (mk(), mk());
        assert_eq!(collect(&mut a, 8), collect(&mut b, 8));
    }

    #[test]
    fn different_seeds_give_different_jitter() {
        let mut a = Backoff::new(Duration::from_millis(10), Duration::from_secs(1), 8, 1);
        let mut b = Backoff::new(Duration::from_millis(10), Duration::from_secs(1), 8, 2);
        assert_ne!(collect(&mut a, 8), collect(&mut b, 8));
    }

    #[test]
    fn delays_stay_in_the_equal_jitter_window_and_honor_the_cap() {
        let base = Duration::from_millis(10);
        let cap = Duration::from_millis(160);
        let mut b = Backoff::new(base, cap, 10, 7);
        for attempt in 0..10u32 {
            let raw = (base * 2u32.pow(attempt.min(20))).min(cap);
            let d = b.next_delay().unwrap();
            assert!(d >= raw / 2, "attempt {attempt}: {d:?} below jitter floor {:?}", raw / 2);
            assert!(d < raw, "attempt {attempt}: {d:?} at or above raw {raw:?}");
            assert!(d <= cap, "attempt {attempt}: {d:?} exceeds cap");
        }
    }

    #[test]
    fn exhausted_budget_surfaces_the_typed_error() {
        let mut b = Backoff::new(Duration::from_millis(1), Duration::from_millis(8), 3, 5);
        for _ in 0..3 {
            b.next_delay().unwrap();
        }
        let err = b.next_delay().unwrap_err();
        assert_eq!(err, RetryBudgetExhausted { attempts: 3 });
        assert!(err.to_string().contains("after 3 attempts"));
        assert_eq!(b.attempts(), 3);
    }

    #[test]
    fn reset_restores_the_full_budget_and_base_delay() {
        let base = Duration::from_millis(4);
        let mut b = Backoff::new(base, Duration::from_secs(1), 2, 11);
        b.next_delay().unwrap();
        b.next_delay().unwrap();
        assert!(b.next_delay().is_err());
        b.reset();
        assert_eq!(b.attempts(), 0);
        let d = b.next_delay().unwrap();
        assert!(d >= base / 2 && d < base);
    }

    #[test]
    fn huge_attempt_counts_saturate_at_the_cap() {
        let cap = Duration::from_millis(50);
        let mut b = Backoff::new(Duration::from_millis(1), cap, 64, 3);
        let mut last = Duration::ZERO;
        for _ in 0..64 {
            last = b.next_delay().unwrap();
        }
        assert!(last >= cap / 2 && last < cap);
    }
}

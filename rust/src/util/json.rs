//! Minimal JSON parser/serializer (serde is not in the offline vendor set).
//!
//! Supports the full JSON grammar: objects, arrays, strings with escapes
//! (including `\uXXXX`), numbers, booleans, null. Used for the artifact
//! manifest, experiment configuration files, and machine-readable result
//! dumps.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Field access on objects (None for missing key or non-object).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Builder helpers for serialization.
    pub fn object(pairs: Vec<(&str, Json)>) -> Json {
        Json::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn array(items: Vec<Json>) -> Json {
        Json::Array(items)
    }

    pub fn num(n: f64) -> Json {
        Json::Number(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::String(s.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Number(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::String(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Array(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Object(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::String(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos);
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected character '{}' at byte {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn literal(&mut self, text: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos);
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Number(text.parse()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(map));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Number(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::String("hi\n".into()));
    }

    #[test]
    fn parses_nested_document() {
        let doc = r#"{"a": [1, 2, {"b": "x"}], "c": {"d": null, "e": false}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c").unwrap().get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{"batch_n": 1024, "decision": [{"file": "d.hlo.txt", "b": 128, "d": 32, "n": 1024}]}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("batch_n").unwrap().as_usize(), Some(1024));
        let d0 = &v.get("decision").unwrap().as_array().unwrap()[0];
        assert_eq!(d0.get("b").unwrap().as_usize(), Some(128));
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let v = Json::object(vec![
            ("x", Json::num(1.5)),
            ("y", Json::array(vec![Json::Bool(true), Json::Null])),
            ("s", Json::str("a\"b\\c\n")),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn integer_display_has_no_decimal_point() {
        assert_eq!(Json::num(1024.0).to_string(), "1024");
        assert_eq!(Json::num(1.25).to_string(), "1.25");
    }
}

//! 64-byte-aligned growable `f32` buffer.
//!
//! The SoA tile storage of [`crate::model::SvStore`] is consumed by the
//! AVX2 micro-kernels in [`crate::kernel::simd`] as 8-lane (32-byte)
//! vector loads. A `Vec<f32>` only guarantees 4-byte alignment; this
//! buffer guarantees a 64-byte (cache-line) aligned base, and because
//! every tile spans `d · TILE · 4 = 32·d` bytes, *every* 8-lane feature
//! group in the tile array then starts on a 32-byte boundary.
//!
//! Only the small `Vec` subset the tile store needs is implemented
//! (`with_capacity` / `resize` / `truncate` / `clear` plus slice access
//! through `Deref`); elements are plain `f32`, so there is no drop glue
//! and truncation is O(1).

use std::alloc::{alloc, dealloc, handle_alloc_error, Layout};
use std::ptr::NonNull;

/// Guaranteed base alignment in bytes.
pub const ALIGN: usize = 64;

/// Growable `f32` buffer whose backing allocation is always
/// [`ALIGN`]-byte aligned (the empty buffer holds no allocation; its
/// dangling pointer is never dereferenced).
pub struct AlignedF32 {
    ptr: NonNull<f32>,
    len: usize,
    cap: usize,
}

// SAFETY: the buffer exclusively owns its allocation; `f32` is Send+Sync.
unsafe impl Send for AlignedF32 {}
unsafe impl Sync for AlignedF32 {}

impl AlignedF32 {
    /// New empty buffer (no allocation).
    pub fn new() -> Self {
        AlignedF32 { ptr: NonNull::dangling(), len: 0, cap: 0 }
    }

    /// New empty buffer with room for `cap` elements.
    pub fn with_capacity(cap: usize) -> Self {
        let mut v = Self::new();
        if cap > 0 {
            v.grow_to(cap);
        }
        v
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn capacity(&self) -> usize {
        self.cap
    }

    fn layout(cap: usize) -> Layout {
        Layout::from_size_align(cap * std::mem::size_of::<f32>(), ALIGN)
            .expect("aligned buffer layout overflow")
    }

    /// Reallocate to at least `min_cap` elements (amortized doubling),
    /// preserving the first `len` elements.
    fn grow_to(&mut self, min_cap: usize) {
        debug_assert!(min_cap > 0);
        let new_cap = min_cap.max(self.cap.saturating_mul(2));
        let layout = Self::layout(new_cap);
        // SAFETY: layout has non-zero size (new_cap ≥ min_cap ≥ 1).
        let raw = unsafe { alloc(layout) } as *mut f32;
        let new_ptr = match NonNull::new(raw) {
            Some(p) => p,
            None => handle_alloc_error(layout),
        };
        if self.len > 0 {
            // SAFETY: both regions are valid for `len` elements and
            // distinct allocations.
            unsafe {
                std::ptr::copy_nonoverlapping(self.ptr.as_ptr(), new_ptr.as_ptr(), self.len);
            }
        }
        self.release();
        self.ptr = new_ptr;
        self.cap = new_cap;
    }

    fn release(&mut self) {
        if self.cap > 0 {
            // SAFETY: `ptr` was allocated with exactly this layout.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.cap)) };
        }
    }

    /// Resize to `new_len`, filling any newly exposed tail with `value`
    /// (matching `Vec::resize` — memory past a previous `truncate` is
    /// refilled, never re-exposed stale).
    pub fn resize(&mut self, new_len: usize, value: f32) {
        if new_len > self.cap {
            self.grow_to(new_len);
        }
        if new_len > self.len {
            for i in self.len..new_len {
                // SAFETY: i < new_len ≤ cap, and the slot is plain f32.
                unsafe { self.ptr.as_ptr().add(i).write(value) };
            }
        }
        self.len = new_len;
    }

    /// Shorten to `new_len` (no-op if already shorter); O(1).
    pub fn truncate(&mut self, new_len: usize) {
        if new_len < self.len {
            self.len = new_len;
        }
    }

    /// Remove all elements (keeps the allocation).
    pub fn clear(&mut self) {
        self.len = 0;
    }
}

impl Default for AlignedF32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for AlignedF32 {
    fn drop(&mut self) {
        self.release();
    }
}

impl Clone for AlignedF32 {
    fn clone(&self) -> Self {
        let mut v = Self::with_capacity(self.len);
        if self.len > 0 {
            // SAFETY: both allocations hold at least `len` elements.
            unsafe {
                std::ptr::copy_nonoverlapping(self.ptr.as_ptr(), v.ptr.as_ptr(), self.len);
            }
        }
        v.len = self.len;
        v
    }
}

impl std::ops::Deref for AlignedF32 {
    type Target = [f32];

    #[inline]
    fn deref(&self) -> &[f32] {
        // SAFETY: `ptr` is valid for `len` initialized elements (dangling
        // only when len == 0, which from_raw_parts permits).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl std::ops::DerefMut for AlignedF32 {
    #[inline]
    fn deref_mut(&mut self) -> &mut [f32] {
        // SAFETY: as in `deref`, with exclusive access through `&mut self`.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl std::fmt::Debug for AlignedF32 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.iter()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn aligned(v: &AlignedF32) -> bool {
        v.capacity() == 0 || (v.as_ptr() as usize) % ALIGN == 0
    }

    #[test]
    fn base_pointer_is_64_byte_aligned_across_growth() {
        let mut v = AlignedF32::with_capacity(4);
        assert!(aligned(&v));
        for round in 1..=8usize {
            v.resize(round * 37, round as f32);
            assert!(aligned(&v), "round {round}");
            assert_eq!(v.len(), round * 37);
            assert_eq!(v[v.len() - 1], round as f32);
        }
    }

    #[test]
    fn resize_fills_and_truncate_then_regrow_refills() {
        let mut v = AlignedF32::new();
        v.resize(5, 1.5);
        assert_eq!(&v[..], &[1.5; 5]);
        v.truncate(2);
        assert_eq!(v.len(), 2);
        v.resize(6, 0.0);
        assert_eq!(&v[..], &[1.5, 1.5, 0.0, 0.0, 0.0, 0.0]);
        v.clear();
        assert!(v.is_empty());
        v.resize(3, 2.0);
        assert_eq!(&v[..], &[2.0; 3]);
    }

    #[test]
    fn clone_is_deep_and_aligned() {
        let mut v = AlignedF32::with_capacity(2);
        v.resize(10, 0.25);
        v[3] = -1.0;
        let mut c = v.clone();
        assert!(aligned(&c));
        assert_eq!(&c[..], &v[..]);
        c[3] = 9.0;
        assert_eq!(v[3], -1.0);
        // Cloning an empty buffer allocates nothing.
        let empty = AlignedF32::new().clone();
        assert_eq!(empty.capacity(), 0);
        assert!(empty.is_empty());
    }

    #[test]
    fn slice_views_support_mutation() {
        let mut v = AlignedF32::with_capacity(8);
        v.resize(8, 0.0);
        for (i, x) in v.iter_mut().enumerate() {
            *x = i as f32;
        }
        assert_eq!(v[7], 7.0);
        let s: &[f32] = &v[2..5];
        assert_eq!(s, &[2.0, 3.0, 4.0]);
    }
}

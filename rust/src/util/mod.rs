//! Small self-contained utility substrates.
//!
//! The build environment is fully offline (the only dependency is the
//! in-repo `anyhow` shim under `vendor/anyhow`; the `xla` crate is opt-in
//! behind the `pjrt` feature), so the usual ecosystem crates (`rand`,
//! `proptest`, `criterion`, `serde`, `clap`) are unavailable. Everything the
//! system needs from them is implemented here from scratch:
//!
//! * [`aligned`] — a 64-byte-aligned growable `f32` buffer backing the
//!   SoA tile storage so the AVX2 micro-kernels run on aligned lanes,
//! * [`backoff`] — seeded-jitter exponential backoff with retry budgets,
//!   the retry discipline on every coordinator↔node cluster link,
//! * [`rng`] — a deterministic xoshiro256** PRNG with the sampling
//!   distributions the data generators need,
//! * [`stats`] — streaming/batch summary statistics used by the experiment
//!   aggregation and the bench harness,
//! * [`prop`] — a miniature property-based testing harness (seeded random
//!   case generation with failing-seed reporting),
//! * [`bench`] — a criterion-style micro-benchmark runner used by all
//!   `cargo bench` targets,
//! * [`parallel`] — the scoped-thread work-queue pool shared by
//!   one-vs-rest training, batch prediction, and the experiment runner.

pub mod aligned;
pub mod backoff;
pub mod bench;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod stats;

//! Criterion-style micro-benchmark harness (criterion itself is not in the
//! offline vendor set).
//!
//! Provides warmup, adaptive iteration-count calibration, multiple timed
//! samples, and a report with mean / std / median / min as well as derived
//! throughput. All `cargo bench` targets (`harness = false`) use this via
//! [`Bencher`].

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Result of one benchmark: per-iteration timings in nanoseconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time (ns), one entry per sample.
    pub ns_per_iter: Vec<f64>,
    /// Iterations executed per sample.
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn summary(&self) -> Summary {
        Summary::of(&self.ns_per_iter)
    }

    /// Mean nanoseconds per iteration.
    pub fn mean_ns(&self) -> f64 {
        self.summary().mean
    }

    /// Print a human-readable report line, optionally with an
    /// elements-per-iteration throughput figure.
    pub fn report(&self, elements_per_iter: Option<f64>) {
        let s = self.summary();
        let thr = elements_per_iter
            .map(|e| format!("  {:>10}/s", si(e * 1e9 / s.mean)))
            .unwrap_or_default();
        println!(
            "bench {:<44} {:>12}/iter  (median {:>10}, min {:>10}, ±{:>9}, {} samples × {} iters){}",
            self.name,
            fmt_ns(s.mean),
            fmt_ns(s.median),
            fmt_ns(s.min),
            fmt_ns(s.std),
            s.n,
            self.iters_per_sample,
            thr
        );
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

fn si(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Benchmark runner with criterion-like calibration.
pub struct Bencher {
    /// Target wall time per sample.
    pub sample_time: Duration,
    /// Number of timed samples.
    pub samples: usize,
    /// Warmup duration before calibration.
    pub warmup: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Keep bench wall-time moderate; CI-style runs can override.
        let quick = std::env::var("BENCH_QUICK").is_ok();
        Bencher {
            sample_time: Duration::from_millis(if quick { 20 } else { 100 }),
            samples: if quick { 5 } else { 15 },
            warmup: Duration::from_millis(if quick { 50 } else { 300 }),
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which performs ONE logical iteration per call and
    /// returns a value that is passed to `std::hint::black_box`.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warmup + calibration: find iteration count that fills sample_time.
        let warm_end = Instant::now() + self.warmup;
        let mut warm_iters: u64 = 0;
        let warm_start = Instant::now();
        while Instant::now() < warm_end {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64;
        let iters = ((self.sample_time.as_nanos() as f64 / per_iter.max(1.0)).ceil() as u64).max(1);

        let mut ns_per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            ns_per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            ns_per_iter,
            iters_per_sample: iters,
        });
        self.results.last().unwrap()
    }

    /// Benchmark and immediately print the report line.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, f: F) {
        self.bench(name, f).report(None);
    }

    /// Benchmark with a throughput figure (`elements` logical items per iteration).
    pub fn run_throughput<T, F: FnMut() -> T>(&mut self, name: &str, elements: f64, f: F) {
        self.bench(name, f).report(Some(elements));
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Ratio of mean times between two completed benchmarks (a / b).
    pub fn ratio(&self, a: &str, b: &str) -> Option<f64> {
        let fa = self.results.iter().find(|r| r.name == a)?.mean_ns();
        let fb = self.results.iter().find(|r| r.name == b)?.mean_ns();
        Some(fa / fb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_timings() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bencher::new();
        let r = b.bench("noop-sum", || (0..100u64).sum::<u64>()).clone();
        assert_eq!(r.ns_per_iter.len(), b.samples);
        let s = r.summary();
        assert!(s.mean > 0.0 && s.mean < 1e7, "mean={}", s.mean);
    }

    #[test]
    fn ratio_between_benches() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bencher::new();
        b.bench("fast", || (0..10u64).sum::<u64>());
        b.bench("slow", || (0..10_000u64).sum::<u64>());
        let r = b.ratio("slow", "fast").unwrap();
        assert!(r > 1.0, "slow/fast ratio {r} should exceed 1");
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.3).ends_with("ns"));
        assert!(fmt_ns(12_300.0).ends_with("µs"));
        assert!(fmt_ns(12_300_000.0).ends_with("ms"));
        assert!(fmt_ns(2.3e9).ends_with('s'));
    }
}

//! Miniature property-based testing harness.
//!
//! `proptest` is not available in the offline vendor set, so this module
//! provides the subset we need: run a property over `N` randomly generated
//! cases drawn from an explicit seed, and on failure report the case index
//! and derived seed so the exact case can be replayed in a debugger.
//!
//! Usage:
//! ```no_run
//! use budgetsvm::util::prop::forall;
//! forall("addition commutes", 256, 0xC0FFEE, |rng| {
//!     let (a, b) = (rng.uniform(), rng.uniform());
//!     let ok = (a + b - (b + a)).abs() < 1e-15;
//!     (ok, format!("a={a} b={b}"))
//! });
//! ```

use super::rng::Rng;

/// Run `cases` random test cases of `property`. Each case gets a fresh
/// child RNG forked deterministically from `seed`. The property returns
/// `(holds, context)`; on the first violation the harness panics with the
/// property name, case index, replay seed, and the property's own context
/// string.
pub fn forall<F>(name: &str, cases: usize, seed: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> (bool, String),
{
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let mut rng = Rng::new(case_seed);
        let (ok, ctx) = property(&mut rng);
        assert!(
            ok,
            "property '{name}' failed at case {case}/{cases} (replay seed: {case_seed:#x}): {ctx}"
        );
    }
}

/// Replay a single case of a property with the seed reported by [`forall`].
pub fn replay<F>(case_seed: u64, mut property: F) -> (bool, String)
where
    F: FnMut(&mut Rng) -> (bool, String),
{
    let mut rng = Rng::new(case_seed);
    property(&mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivial", 64, 1, |rng| {
            count += 1;
            let x = rng.uniform();
            ((0.0..1.0).contains(&x), format!("x={x}"))
        });
        assert_eq!(count, 64);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_context() {
        forall("always-false", 8, 2, |_| (false, "ctx".into()));
    }

    #[test]
    fn replay_reproduces_case() {
        let mut failing_seed = None;
        let mut root = Rng::new(99);
        for _ in 0..128 {
            let s = root.next_u64();
            let mut rng = Rng::new(s);
            if rng.uniform() > 0.9 {
                failing_seed = Some(s);
                break;
            }
        }
        let s = failing_seed.expect("should find a case with u>0.9");
        let (ok, _) = replay(s, |rng| {
            let u = rng.uniform();
            (u > 0.9, format!("u={u}"))
        });
        assert!(ok);
    }
}

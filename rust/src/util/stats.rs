//! Summary statistics used by experiment aggregation and the bench harness.

/// Streaming mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n−1 denominator); 0 for fewer than two samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Batch summary of a sample: mean, sample std, median, quantiles.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std: w.std(),
            min: sorted[0],
            p25: quantile_sorted(&sorted, 0.25),
            median: quantile_sorted(&sorted, 0.5),
            p75: quantile_sorted(&sorted, 0.75),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Linear-interpolated quantile of an ascending-sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (n−1); 0 for fewer than two samples.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.5, -3.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), -3.0);
        assert_eq!(w.max(), 16.5);
    }

    #[test]
    fn welford_merge_matches_single_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = Welford::new();
        let mut b = Welford::new();
        let mut whole = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.push(x);
            } else {
                b.push(x);
            }
            whole.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.std() - whole.std()).abs() < 1e-10);
    }

    #[test]
    fn summary_quantiles() {
        let xs: Vec<f64> = (1..=101).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.median, 51.0);
        assert_eq!(s.p25, 26.0);
        assert_eq!(s.p75, 76.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 101.0);
        assert!((s.mean - 51.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_single_element() {
        assert_eq!(quantile_sorted(&[3.5], 0.99), 3.5);
    }
}

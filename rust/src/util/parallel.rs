//! Scoped-thread work-queue parallelism and long-lived command workers.
//!
//! Hoisted out of `experiments::runner` so every layer — one-vs-rest
//! training, batch prediction, curve evaluation, the experiment suite —
//! shares one pool implementation (std scoped threads + a mutexed queue;
//! tokio/rayon are not in the offline vendor set and all jobs are
//! CPU-bound).
//!
//! Determinism contract: [`run_jobs`] slots results by submission index, so
//! for *independent* jobs the output is identical for every thread count.
//! All in-crate consumers split work at row / machine granularity and
//! reduce sequentially afterwards, which keeps `threads = N` bit-identical
//! to `threads = 1`.
//!
//! [`spawn_worker`] is the second primitive: a *long-lived* worker thread
//! owning mutable state across commands (the serving layer's shard
//! trainers), as opposed to the scoped fan-out above where every job is
//! one-shot. Commands on one worker are processed strictly in send order,
//! which is what lets the sharded-ingest pipeline snapshot a shard by
//! simply enqueueing a snapshot command after the training batches.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result as AnyResult};

/// Number of hardware threads (fallback 4 when undetectable).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Resolve a user-facing thread knob: `0` means "all hardware threads".
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        available_threads()
    } else {
        requested
    }
}

/// Split `0..n` into at most `parts` contiguous ranges of near-equal
/// length (earlier ranges get the remainder). Never returns an empty
/// vector; `n == 0` yields one empty range.
pub fn chunk_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// The one chunked-parallel-map used by every batch path in the crate:
/// split `0..n` into at most `threads` contiguous ranges (`0` = all
/// hardware threads), apply `f` to each, and return the per-range results
/// in range order. `threads <= 1` (or `n <= 1`) calls `f(0..n)` inline —
/// no worker is spawned — and because the split is contiguous and the
/// output ordered, callers that concatenate or reduce the results
/// sequentially get identical output for every thread count. Centralizing
/// the pattern here is what keeps that bit-identity contract in one
/// place.
pub fn map_ranges<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(Range<usize>) -> T + Sync,
{
    let threads = resolve_threads(threads).min(n.max(1));
    if threads <= 1 {
        return vec![f(0..n)];
    }
    let jobs: Vec<_> = chunk_ranges(n, threads)
        .into_iter()
        .map(|r| {
            let f = &f;
            move || f(r)
        })
        .collect();
    run_jobs(jobs, threads)
}

/// Run `jobs` on `threads` workers; returns results in job order.
pub fn run_jobs<T, F>(jobs: Vec<F>, threads: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = jobs.len();
    let threads = threads.clamp(1, n.max(1));
    // Queue of (index, job); results slotted by index.
    let queue: Arc<Mutex<VecDeque<(usize, F)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().collect()));
    let results: Arc<Mutex<Vec<Option<T>>>> =
        Arc::new(Mutex::new((0..n).map(|_| None).collect()));

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let queue = Arc::clone(&queue);
            let results = Arc::clone(&results);
            scope.spawn(move || loop {
                let job = queue.lock().unwrap().pop_front();
                match job {
                    Some((idx, f)) => {
                        let out = f();
                        results.lock().unwrap()[idx] = Some(out);
                    }
                    None => break,
                }
            });
        }
    });

    Arc::try_unwrap(results)
        .unwrap_or_else(|_| panic!("worker leaked a results handle"))
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every job must produce a result"))
        .collect()
}

/// A long-lived worker thread processing typed commands in send order.
///
/// Unlike the scoped fan-out of [`run_jobs`], the worker owns its closure
/// state for its whole lifetime, so stateful consumers (a shard's
/// streaming trainer, a metrics accumulator) can live *inside* the worker
/// and be driven purely through the channel. Dropping the handle closes
/// the channel and joins the thread; [`Worker::join`] does the same
/// explicitly.
pub struct Worker<Cmd: Send + 'static> {
    tx: Option<Sender<Cmd>>,
    handle: Option<JoinHandle<()>>,
}

/// Spawn a named long-lived worker; `f` is invoked once per command, in
/// exactly the order commands were sent. The thread exits when every
/// sender (the [`Worker`] handle and any clones obtained before sending)
/// is gone.
pub fn spawn_worker<Cmd, F>(name: &str, mut f: F) -> Worker<Cmd>
where
    Cmd: Send + 'static,
    F: FnMut(Cmd) + Send + 'static,
{
    let (tx, rx) = channel::<Cmd>();
    let handle = std::thread::Builder::new()
        .name(name.to_string())
        .spawn(move || {
            while let Ok(cmd) = rx.recv() {
                f(cmd);
            }
        })
        .expect("failed to spawn worker thread");
    Worker { tx: Some(tx), handle: Some(handle) }
}

impl<Cmd: Send + 'static> Worker<Cmd> {
    /// Enqueue a command; errors if the worker thread has terminated.
    pub fn send(&self, cmd: Cmd) -> AnyResult<()> {
        self.tx
            .as_ref()
            .ok_or_else(|| anyhow!("worker channel already closed"))?
            .send(cmd)
            .map_err(|_| anyhow!("worker thread terminated"))
    }

    /// Close the channel and wait for the worker to drain its queue.
    pub fn join(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<Cmd: Send + 'static> Drop for Worker<Cmd> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_preserve_submission_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..50)
            .map(|i| {
                Box::new(move || {
                    // Uneven work so completion order scrambles.
                    let mut acc = 0usize;
                    for k in 0..((50 - i) * 1000) {
                        acc = acc.wrapping_add(k);
                    }
                    std::hint::black_box(acc);
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = run_jobs(jobs, 8);
        assert_eq!(out, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_works() {
        let jobs: Vec<_> = (0..5).map(|i| move || i * 2).collect();
        assert_eq!(run_jobs(jobs, 1), vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn more_threads_than_jobs() {
        let jobs: Vec<_> = (0..3).map(|i| move || i).collect();
        assert_eq!(run_jobs(jobs, 64), vec![0, 1, 2]);
    }

    #[test]
    fn empty_job_list() {
        let jobs: Vec<fn() -> u8> = Vec::new();
        assert!(run_jobs(jobs, 4).is_empty());
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (n, parts) in [(10, 3), (3, 10), (0, 4), (16, 4), (1, 1), (7, 7)] {
            let ranges = chunk_ranges(n, parts);
            assert!(!ranges.is_empty());
            let mut expect = 0usize;
            for r in &ranges {
                assert_eq!(r.start, expect, "n={n} parts={parts}");
                expect = r.end;
            }
            assert_eq!(expect, n, "n={n} parts={parts}");
            let (min, max) = ranges
                .iter()
                .fold((usize::MAX, 0), |(lo, hi), r| (lo.min(r.len()), hi.max(r.len())));
            assert!(max - min <= 1, "near-equal split: n={n} parts={parts}");
        }
    }

    #[test]
    fn resolve_threads_zero_means_all() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn worker_processes_commands_in_order_with_state() {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&seen);
        // Stateful closure: accumulates across commands.
        let mut running = 0u64;
        let w = spawn_worker("acc", move |x: u64| {
            running += x;
            sink.lock().unwrap().push(running);
        });
        for x in 1..=5u64 {
            w.send(x).unwrap();
        }
        w.join();
        assert_eq!(*seen.lock().unwrap(), vec![1, 3, 6, 10, 15]);
    }

    #[test]
    fn worker_reply_channels_round_trip() {
        let w = spawn_worker("echo", |(x, reply): (u64, Sender<u64>)| {
            let _ = reply.send(x * 2);
        });
        let mut rxs = Vec::new();
        for x in 0..10u64 {
            let (tx, rx) = channel();
            w.send((x, tx)).unwrap();
            rxs.push(rx);
        }
        let out: Vec<u64> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(out, (0..10).map(|x| x * 2).collect::<Vec<_>>());
        // Dropping joins cleanly.
        drop(w);
    }

    #[test]
    fn map_ranges_is_thread_count_invariant() {
        let data: Vec<u64> = (0..997).map(|i| i * 7 + 3).collect();
        let serial: Vec<u64> =
            map_ranges(data.len(), 1, |r| data[r].to_vec()).into_iter().flatten().collect();
        assert_eq!(serial, data);
        for threads in [2usize, 3, 8, 64] {
            let par: Vec<u64> = map_ranges(data.len(), threads, |r| data[r].to_vec())
                .into_iter()
                .flatten()
                .collect();
            assert_eq!(par, data, "threads={threads}");
            let sum: u64 = map_ranges(data.len(), threads, |r| data[r].iter().sum::<u64>())
                .into_iter()
                .sum();
            assert_eq!(sum, data.iter().sum::<u64>(), "threads={threads}");
        }
        // n = 0 still yields exactly one (empty) range.
        let empty: Vec<Vec<u64>> = map_ranges(0, 4, |r| data[r].to_vec());
        assert_eq!(empty, vec![Vec::<u64>::new()]);
    }
}

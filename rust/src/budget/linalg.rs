//! Minimal dense linear algebra for the projection baseline: Cholesky
//! factorization and solve for symmetric positive definite systems
//! (the kernel Gram matrix of the remaining support vectors, plus ridge).

use anyhow::{bail, Result};

/// Dense symmetric positive definite solver via Cholesky (`A = L·Lᵀ`).
/// `a` is row-major `n×n` and is consumed as workspace; `b` is overwritten
/// with the solution. Fails if the matrix is not (numerically) SPD.
pub fn cholesky_solve_in_place(a: &mut [f64], n: usize, b: &mut [f64]) -> Result<()> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    // Factorize: lower triangle of `a` becomes L.
    for j in 0..n {
        let mut diag = a[j * n + j];
        for k in 0..j {
            diag -= a[j * n + k] * a[j * n + k];
        }
        if diag <= 0.0 || !diag.is_finite() {
            bail!("matrix not positive definite at pivot {j} (d={diag})");
        }
        let ljj = diag.sqrt();
        a[j * n + j] = ljj;
        for i in (j + 1)..n {
            let mut v = a[i * n + j];
            for k in 0..j {
                v -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = v / ljj;
        }
    }
    // Forward substitution: L·y = b.
    for i in 0..n {
        let mut v = b[i];
        for k in 0..i {
            v -= a[i * n + k] * b[k];
        }
        b[i] = v / a[i * n + i];
    }
    // Back substitution: Lᵀ·x = y.
    for i in (0..n).rev() {
        let mut v = b[i];
        for k in (i + 1)..n {
            v -= a[k * n + i] * b[k];
        }
        b[i] = v / a[i * n + i];
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn solves_identity() {
        let mut a = vec![0.0; 9];
        for i in 0..3 {
            a[i * 3 + i] = 1.0;
        }
        let mut b = vec![3.0, -1.0, 2.0];
        cholesky_solve_in_place(&mut a, 3, &mut b).unwrap();
        assert_eq!(b, vec![3.0, -1.0, 2.0]);
    }

    #[test]
    fn solves_known_system() {
        // A = [[4,2],[2,3]], b = [10, 9] → x = [1.5, 2]
        let mut a = vec![4.0, 2.0, 2.0, 3.0];
        let mut b = vec![10.0, 9.0];
        cholesky_solve_in_place(&mut a, 2, &mut b).unwrap();
        assert!((b[0] - 1.5).abs() < 1e-12);
        assert!((b[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, −1
        let mut b = vec![1.0, 1.0];
        assert!(cholesky_solve_in_place(&mut a, 2, &mut b).is_err());
    }

    #[test]
    fn random_spd_systems_property() {
        forall("cholesky solves random SPD", 40, 0xCAFE, |rng: &mut Rng| {
            let n = 2 + rng.below(10);
            // A = MᵀM + I is SPD.
            let m: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
            let mut a = vec![0.0f64; n * n];
            for i in 0..n {
                for j in 0..n {
                    let mut v = 0.0;
                    for k in 0..n {
                        v += m[k * n + i] * m[k * n + j];
                    }
                    a[i * n + j] = v + if i == j { 1.0 } else { 0.0 };
                }
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut b = vec![0.0f64; n];
            for i in 0..n {
                for j in 0..n {
                    b[i] += a[i * n + j] * x_true[j];
                }
            }
            let mut a_work = a.clone();
            if cholesky_solve_in_place(&mut a_work, n, &mut b).is_err() {
                return (false, format!("SPD system rejected, n={n}"));
            }
            let err = b
                .iter()
                .zip(&x_true)
                .map(|(x, t)| (x - t).abs())
                .fold(0.0f64, f64::max);
            (err < 1e-8, format!("n={n} max err={err}"))
        });
    }
}

//! Budget maintenance: keeping the support-vector count at `B`.
//!
//! The paper's contribution lives here: [`merge`] implements Algorithm 1
//! with the four interchangeable per-candidate solvers (GSS-standard,
//! GSS-precise, Lookup-h, Lookup-WD); [`lookup`] holds the precomputed
//! tables with bilinear interpolation; [`gss`] the iterative baseline;
//! [`geometry`] the shared closed-form merge math; [`removal`] and
//! [`projection`] the alternative strategies of Wang et al. (2012) used as
//! ablation baselines; [`linalg`] a minimal Cholesky solver for projection.

pub mod geometry;
pub mod gss;
pub mod linalg;
pub mod lookup;
pub mod merge;
pub mod projection;
pub mod removal;

pub use lookup::LookupTable;
pub use merge::{audit_event, AuditRecord, MergeEngine, MergeOutcome, MergeSolver};

use crate::metrics::SectionProfiler;
use crate::model::BudgetModel;

/// Budget maintenance strategy selected for a training run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Merging with one of the four per-candidate solvers (the paper).
    Merge(MergeSolver),
    /// Drop the smallest-|α| SV (baseline).
    Removal,
    /// Drop and project onto the remaining SVs (baseline, O(B³) per event).
    Projection,
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::Merge(s) => s.name().to_string(),
            Strategy::Removal => "Removal".to_string(),
            Strategy::Projection => "Projection".to_string(),
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "removal" | "remove" => Some(Strategy::Removal),
            "projection" | "project" => Some(Strategy::Projection),
            other => MergeSolver::parse(other).map(Strategy::Merge),
        }
    }
}

/// A ready-to-run maintenance executor with its scratch state.
pub enum Maintainer {
    Merge(MergeEngine),
    Removal,
    Projection,
}

impl Maintainer {
    /// Build a maintainer; `grid` is the lookup-table resolution for the
    /// lookup solvers.
    pub fn new(strategy: Strategy, grid: usize) -> Self {
        match strategy {
            Strategy::Merge(solver) => Maintainer::Merge(MergeEngine::new(solver, grid)),
            Strategy::Removal => Maintainer::Removal,
            Strategy::Projection => Maintainer::Projection,
        }
    }

    /// Execute one maintenance event; returns the incurred weight
    /// degradation.
    pub fn maintain(&mut self, model: &mut BudgetModel, prof: &mut SectionProfiler) -> f64 {
        match self {
            Maintainer::Merge(engine) => engine.maintain(model, prof).weight_degradation,
            Maintainer::Removal => removal::maintain_removal(model, prof),
            Maintainer::Projection => projection::maintain_projection(model, prof)
                .unwrap_or_else(|_| {
                    // Numerically degenerate Gram matrix: fall back to removal.
                    removal::maintain_removal(model, prof)
                }),
        }
    }

    pub fn strategy(&self) -> Strategy {
        match self {
            Maintainer::Merge(e) => Strategy::Merge(e.solver()),
            Maintainer::Removal => Strategy::Removal,
            Maintainer::Projection => Strategy::Projection,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::Gaussian;
    use crate::util::rng::Rng;

    #[test]
    fn strategy_parsing() {
        assert_eq!(Strategy::parse("lookup-wd"), Some(Strategy::Merge(MergeSolver::LookupWd)));
        assert_eq!(Strategy::parse("GSS"), Some(Strategy::Merge(MergeSolver::GssStandard)));
        assert_eq!(Strategy::parse("removal"), Some(Strategy::Removal));
        assert_eq!(Strategy::parse("projection"), Some(Strategy::Projection));
        assert_eq!(Strategy::parse("bogus"), None);
    }

    #[test]
    fn all_maintainers_shrink_the_model() {
        let strategies = [
            Strategy::Merge(MergeSolver::GssStandard),
            Strategy::Merge(MergeSolver::LookupWd),
            Strategy::Removal,
            Strategy::Projection,
        ];
        for strat in strategies {
            let mut rng = Rng::new(13);
            let mut model = BudgetModel::new(3, Gaussian::new(0.5), 6);
            for _ in 0..6 {
                let row: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
                model.push(&row, 0.1 + rng.uniform());
            }
            let mut m = Maintainer::new(strat, 50);
            let mut prof = SectionProfiler::new();
            let wd = m.maintain(&mut model, &mut prof);
            assert_eq!(model.num_sv(), 5, "{:?}", strat);
            assert!(wd >= 0.0);
            assert_eq!(m.strategy(), strat);
        }
    }
}

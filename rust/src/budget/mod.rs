//! Budget maintenance: keeping the support-vector count at `B` — as a
//! pluggable **policy pipeline**.
//!
//! The paper's contribution lives here: [`merge`] implements Algorithm 1
//! with the four interchangeable per-candidate solvers (GSS-standard,
//! GSS-precise, Lookup-h, Lookup-WD) plus the amortized multi-pair sweep;
//! [`lookup`] holds the precomputed tables with bilinear interpolation;
//! [`gss`] the iterative baseline; [`geometry`] the shared closed-form
//! merge math; [`removal`] and [`projection`] the alternative strategies
//! of Wang et al. (2012); [`linalg`] a minimal Cholesky solver for
//! projection; [`policy`] the [`MaintenancePolicy`] trait everything
//! dispatches through; [`gram`] the budget-sized Gram slab cache the dual
//! solver family reads its kernel rows from, kept exact under churn via
//! the [`policy::ChurnObserver`] notification hook
//! ([`MaintenancePolicy::maintain_observed`]).
//!
//! # Pipeline invariants
//!
//! **Trigger semantics.** A policy's `trigger(num_sv, budget)` fires once
//! the overshoot exceeds the configured slack `W`:
//! `num_sv − budget > W`. With `W = 0` this is the classic
//! `num_sv > budget` — one event per overflowing SGD step. With `W > 0`
//! the model may transiently hold up to `budget + ⌈W⌉` SVs; the trigger
//! then guarantees an overshoot of at least `⌈W⌉ + 1`, which is exactly
//! the auto pair quota of one event (`MaintenanceConfig::effective_pairs`).
//!
//! **Slack accounting.** Slack trades peak model size for amortization:
//! the *number of pairs merged over a training run is unchanged* (every
//! insert beyond the budget is eventually shed), but events are `⌈W⌉ + 1`
//! times rarer and each event shares one candidate scan, one pivot
//! argsort and the one process-wide lookup table across its whole batch.
//! Consumers that hand a model onward (end of every `fit`/`partial_fit`
//! ingest call, the serving layer's shard merge) run
//! `MaintenancePolicy::enforce`, so models that *leave* the training loop
//! always satisfy `num_sv ≤ budget` regardless of slack.
//!
//! **Stage contracts** (shared by single-pair events, multi-pair sweeps
//! and the serve-side shard merge; see [`merge::MergeEngine`]):
//!
//! 1. *candidate search* — read-only on the model; produces pivot(s) and
//!    per-candidate `(κ, m, (α_a+α_b)²)` through the blocked kernel-row
//!    engine (one batched tile pass for a whole sweep);
//! 2. *solver* — pure `(m, κ) → (h, WD)` per candidate via the configured
//!    [`MergeSolver`] (the paper's Section A; profiled as
//!    `Section::MaintA`);
//! 3. *apply* — the only stage mutating the model: winner selection,
//!    `α_z`, merge-vector construction, descending swap-removes, pushes.
//!
//! Profiler attribution follows the stages (`MaintScan` / `MaintA` /
//! `MaintApply`); `MaintScan + MaintApply` is the paper's Figure 3
//! "Section B".
//!
//! **Equivalence pin.** With `slack = 0` and a single pair per event the
//! pipeline is bit-identical to the pre-pipeline per-step maintainers for
//! every strategy × kernel combination (pinned by `tests/maintenance.rs`
//! and the in-module sweep/removal tests).
//!
//! # Kernel / strategy compatibility
//!
//! Merge-based maintenance depends on the Gaussian kernel's closed-form
//! geometry (`k(x_a, z) = κ^{(1−h)²}` for `z` on the connecting line —
//! paper Section 3); removal and projection only need Gram-matrix
//! evaluations and work with every kernel:
//!
//! | Strategy                    | Gaussian | Linear | Polynomial |
//! |-----------------------------|----------|--------|------------|
//! | `Merge(*)` (all 4 solvers)  | ✓        | ✗      | ✗          |
//! | `Removal`                   | ✓        | ✓      | ✓          |
//! | `Projection`                | ✓        | ✓      | ✓          |
//!
//! [`Strategy::valid_for`] encodes this table; the estimator configuration
//! layer (`SvmConfig::validate`) rejects invalid combinations with an
//! explanatory error instead of panicking mid-run, and non-Gaussian
//! budgeted models default to removal maintenance. [`policy::generic_policy`]
//! enforces the same rule at construction for callers that bypass the
//! estimator surface.
//!
//! Lookup tables are shared process-wide per grid resolution via
//! [`lookup::shared`], so K one-vs-rest machines (and repeated experiment
//! runs) reuse one `Arc<LookupTable>` instead of paying the ~100 ms
//! 400×400 build K times.

pub mod geometry;
pub mod gram;
pub mod gss;
pub mod linalg;
pub mod lookup;
pub mod merge;
pub mod policy;
pub mod projection;
pub mod removal;

pub use gram::GramCache;
pub use lookup::{shared as shared_lookup_table, LookupTable};
pub use merge::{audit_event, AuditRecord, MergeEngine, MergeOutcome, MergeSolver};
pub use policy::{
    gaussian_policy, generic_policy, AnyPolicy, ChurnObserver, MaintenanceConfig,
    MaintenancePolicy,
};
pub use removal::MinAlphaIndex;

use crate::kernel::KernelSpec;

/// Budget maintenance strategy selected for a training run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Merging with one of the four per-candidate solvers (the paper).
    Merge(MergeSolver),
    /// Drop the smallest-|α| SV (baseline).
    Removal,
    /// Drop and project onto the remaining SVs (baseline, O(B³) per event).
    Projection,
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::Merge(s) => s.name().to_string(),
            Strategy::Removal => "Removal".to_string(),
            Strategy::Projection => "Projection".to_string(),
        }
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "removal" | "remove" => Some(Strategy::Removal),
            "projection" | "project" => Some(Strategy::Projection),
            other => MergeSolver::parse(other).map(Strategy::Merge),
        }
    }

    /// Whether this strategy is usable with the given kernel (see the
    /// module-level compatibility matrix): merging requires the Gaussian
    /// closed-form geometry, removal/projection work with every kernel.
    pub fn valid_for(&self, kernel: &KernelSpec) -> bool {
        match self {
            Strategy::Merge(_) => kernel.supports_merging(),
            Strategy::Removal | Strategy::Projection => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::Gaussian;
    use crate::metrics::SectionProfiler;
    use crate::model::BudgetModel;
    use crate::util::rng::Rng;

    #[test]
    fn strategy_parsing() {
        assert_eq!(Strategy::parse("lookup-wd"), Some(Strategy::Merge(MergeSolver::LookupWd)));
        assert_eq!(Strategy::parse("GSS"), Some(Strategy::Merge(MergeSolver::GssStandard)));
        assert_eq!(Strategy::parse("removal"), Some(Strategy::Removal));
        assert_eq!(Strategy::parse("projection"), Some(Strategy::Projection));
        assert_eq!(Strategy::parse("bogus"), None);
    }

    #[test]
    fn compatibility_matrix() {
        let gauss = KernelSpec::gaussian(1.0);
        let linear = KernelSpec::linear();
        let poly = KernelSpec::polynomial(3, 1.0);
        for solver in MergeSolver::ALL {
            assert!(Strategy::Merge(solver).valid_for(&gauss));
            assert!(!Strategy::Merge(solver).valid_for(&linear));
            assert!(!Strategy::Merge(solver).valid_for(&poly));
        }
        for strat in [Strategy::Removal, Strategy::Projection] {
            for k in [gauss, linear, poly] {
                assert!(strat.valid_for(&k));
            }
        }
    }

    #[test]
    fn shared_lookup_table_is_cached_per_grid() {
        let a = shared_lookup_table(37);
        let b = shared_lookup_table(37);
        assert!(std::sync::Arc::ptr_eq(&a, &b), "same grid must share one table");
        assert_eq!(a.grid(), 37);
        let c = shared_lookup_table(23);
        assert_eq!(c.grid(), 23);
        assert!(!std::sync::Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn all_policies_shrink_the_model() {
        let strategies = [
            Strategy::Merge(MergeSolver::GssStandard),
            Strategy::Merge(MergeSolver::LookupWd),
            Strategy::Removal,
            Strategy::Projection,
        ];
        for strat in strategies {
            let mut rng = Rng::new(13);
            let mut model = BudgetModel::new(3, Gaussian::new(0.5), 6);
            for _ in 0..6 {
                let row: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
                model.push(&row, 0.1 + rng.uniform());
            }
            let mut p = gaussian_policy(&MaintenanceConfig::new(strat, 50));
            let mut prof = SectionProfiler::new();
            assert!(p.trigger(model.num_sv(), 5), "{strat:?}");
            let wd = p.maintain(&mut model, 5, &mut prof);
            assert_eq!(model.num_sv(), 5, "{strat:?}");
            assert!(wd >= 0.0);
            assert_eq!(p.strategy(), strat);
        }
    }
}

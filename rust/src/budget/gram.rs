//! A budget-sized Gram slab cache for the dual solver family.
//!
//! The dual coordinate-ascent solver ([`crate::solver::bdca`]) evaluates
//! `f(x_j) = Σ_i α_i k(x_i, x_j)` for *stored* support vectors over and
//! over — every epoch sweep touches every coordinate. Recomputing those
//! kernel rows per sweep would cost one blocked row scan per coordinate
//! per epoch; caching the full `(B + slack)²` Gram matrix once makes each
//! coordinate update a dot product over a resident row.
//!
//! [`GramCache`] is that cache: a row-major `capacity × capacity` slab of
//! `f64` kernel values of which the leading `n × n` block mirrors the
//! model's SV set. It is filled through the model's blocked kernel-row
//! engine ([`crate::model::BudgetModel::kernel_row_prefix`] →
//! `SvStore::tile_dots` + `Kernel::eval_block`), so every SIMD tier of the
//! tile micro-kernels applies for free, and it exploits symmetry: only the
//! lower triangle is ever *computed*; the upper triangle is mirrored.
//!
//! Churn discipline — the cache stays **exact** (bit-identical to a fresh
//! recomputation, see the property tests) under every mutation of the SV
//! set:
//!
//! * **insert** — [`GramCache::push_row`] computes the one new row through
//!   the blocked engine and mirrors it into the new column;
//! * **removal churn** — [`GramCache::swap_remove`] replays the model's
//!   swap-remove move (last row/column into the vacated slot) on cached
//!   values, no kernel evaluation at all; the removal maintenance policy
//!   reports each victim through the [`ChurnObserver`] hook
//!   ([`crate::budget::policy::MaintenancePolicy::maintain_observed`]);
//! * **merge / projection churn** — those events push merged vectors
//!   mid-event against a shifting SV set and rewrite survivor
//!   coefficients, which no after-the-fact journal can reconstruct
//!   exactly, so the policy invalidates the cache ([`GramCache::is_stale`])
//!   and the owner rebuilds it from the model ([`GramCache::rebuild`] —
//!   by construction identical to a fresh recomputation).
//!
//! Cached rows are exposed read-only ([`GramCache::row`] /
//! [`GramCache::entry`]) so consumers that need kernel rows of stored SVs
//! — the dual epoch sweep, a κ candidate scan, projection's survivor Gram
//! assembly — can borrow them instead of re-running the blocked engine.

use crate::kernel::Kernel;
use crate::model::BudgetModel;

use super::policy::ChurnObserver;

/// Budget-sized Gram slab: the leading `len() × len()` block of a
/// row-major `capacity × capacity` buffer, mirroring `k(sv_i, sv_j)` of a
/// [`BudgetModel`]. See the module docs for the churn discipline.
#[derive(Debug, Clone)]
pub struct GramCache {
    /// Row stride of the slab (maximum SV count mirrored).
    cap: usize,
    /// Live rows/columns (= SVs currently mirrored).
    n: usize,
    /// Row-major slab, stride `cap`; entries beyond the leading `n × n`
    /// block are dead values.
    g: Vec<f64>,
    /// Set by [`ChurnObserver::invalidate`]: opaque churn happened and the
    /// mirror must be rebuilt from the model before its next use.
    stale: bool,
}

impl GramCache {
    /// An empty cache able to mirror up to `capacity` support vectors
    /// (budgeted estimators size this as budget + slack overshoot).
    pub fn new(capacity: usize) -> Self {
        GramCache { cap: capacity, n: 0, g: vec![0.0; capacity * capacity], stale: false }
    }

    /// Mirrored SV count.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Maximum SV count the slab can mirror.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Whether opaque churn invalidated the mirror (rebuild before use).
    pub fn is_stale(&self) -> bool {
        self.stale
    }

    /// Cached kernel row of SV `j` against every mirrored SV: exactly the
    /// κ row a candidate scan or a coordinate update needs, without
    /// touching the blocked engine.
    pub fn row(&self, j: usize) -> &[f64] {
        debug_assert!(!self.stale, "stale GramCache read");
        assert!(j < self.n, "row {j} out of range {}", self.n);
        &self.g[j * self.cap..j * self.cap + self.n]
    }

    /// One cached kernel value `k(sv_i, sv_j)`.
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        debug_assert!(!self.stale, "stale GramCache read");
        assert!(i < self.n && j < self.n, "entry ({i}, {j}) out of range {}", self.n);
        self.g[i * self.cap + j]
    }

    /// Forget all mirrored rows (the slab allocation is kept).
    pub fn clear(&mut self) {
        self.n = 0;
        self.stale = false;
    }

    /// Mirror the SV the model just pushed (call immediately after
    /// `model.push(..)`): computes the one new row through the blocked
    /// engine and mirrors it into the new column. The diagonal entry is
    /// computed by the same tile path as every other entry, not by
    /// `self_eval`, so the row is exactly what [`GramCache::rebuild`]
    /// would produce.
    pub fn push_row<K: Kernel + Copy>(&mut self, model: &BudgetModel<K>) {
        assert!(!self.stale, "stale GramCache: rebuild before push_row");
        let j = self.n;
        assert!(j < self.cap, "GramCache capacity {} exhausted", self.cap);
        assert_eq!(
            model.num_sv(),
            j + 1,
            "push_row must run right after the model push it mirrors"
        );
        let row = &mut self.g[j * self.cap..j * self.cap + j + 1];
        let wrote = model.kernel_row_prefix(model.sv(j), model.sv_norm2(j), j + 1, row);
        debug_assert_eq!(wrote, j + 1);
        for i in 0..j {
            self.g[i * self.cap + j] = self.g[j * self.cap + i];
        }
        self.n = j + 1;
    }

    /// Replay the model's `swap_remove(j)` on cached values: the last row
    /// and column move into slot `j`, the mirrored set shrinks by one. No
    /// kernel evaluation — moved entries are verbatim copies of already
    /// computed values, so exactness is preserved bit-for-bit.
    pub fn swap_remove(&mut self, j: usize) {
        assert!(j < self.n, "swap_remove index {j} out of range {}", self.n);
        let last = self.n - 1;
        if j != last {
            // Row `last` → row `j` first; the column pass then reads the
            // already-moved `(j, last)` entry, landing the old `(last,
            // last)` diagonal value on the new `(j, j)` slot.
            for i in 0..self.n {
                self.g[j * self.cap + i] = self.g[last * self.cap + i];
            }
            for i in 0..self.n {
                self.g[i * self.cap + j] = self.g[i * self.cap + last];
            }
        }
        self.n = last;
    }

    /// Rebuild the mirror from the model, from scratch: the blocked
    /// triangle fill (row `j` up to the diagonal via
    /// [`BudgetModel::kernel_row_prefix`], mirrored into the column) —
    /// the same procedure incremental growth uses, so a cache maintained
    /// through [`GramCache::push_row`] / [`GramCache::swap_remove`] is
    /// bit-identical to a rebuilt one. Clears the stale flag.
    pub fn rebuild<K: Kernel + Copy>(&mut self, model: &BudgetModel<K>) {
        let n = model.num_sv();
        assert!(n <= self.cap, "model has {n} SVs, GramCache capacity is {}", self.cap);
        for j in 0..n {
            let row = &mut self.g[j * self.cap..j * self.cap + j + 1];
            let wrote = model.kernel_row_prefix(model.sv(j), model.sv_norm2(j), j + 1, row);
            debug_assert_eq!(wrote, j + 1);
            for i in 0..j {
                self.g[i * self.cap + j] = self.g[j * self.cap + i];
            }
        }
        self.n = n;
        self.stale = false;
    }
}

/// The cache is its own churn observer: removal victims are replayed
/// exactly; opaque events mark it stale for the owner to rebuild. Once
/// stale, further itemized notifications are ignored (indices no longer
/// correspond to mirrored slots) — the rebuild resynchronizes everything.
impl ChurnObserver for GramCache {
    fn on_swap_remove(&mut self, j: usize) {
        if !self.stale {
            self.swap_remove(j);
        }
    }

    fn invalidate(&mut self) {
        self.stale = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::budget::policy::{
        gaussian_policy, MaintenanceConfig, MaintenancePolicy, RemovalMaintenance,
    };
    use crate::budget::{MergeSolver, Strategy};
    use crate::kernel::Gaussian;
    use crate::metrics::SectionProfiler;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    const DIM: usize = 4;

    fn random_sv(rng: &mut Rng) -> Vec<f32> {
        (0..DIM).map(|_| rng.normal() as f32).collect()
    }

    fn random_model(n_sv: usize, capacity: usize, seed: u64) -> BudgetModel {
        let mut rng = Rng::new(seed);
        let mut m = BudgetModel::new(DIM, Gaussian::new(0.7), capacity);
        for _ in 0..n_sv {
            m.push(&random_sv(&mut rng), 0.05 + rng.uniform());
        }
        m
    }

    fn rebuilt(model: &BudgetModel, capacity: usize) -> GramCache {
        let mut g = GramCache::new(capacity);
        g.rebuild(model);
        g
    }

    fn assert_bit_identical(a: &GramCache, b: &GramCache) -> (bool, String) {
        if a.len() != b.len() {
            return (false, format!("len {} vs {}", a.len(), b.len()));
        }
        for i in 0..a.len() {
            for j in 0..a.len() {
                if a.entry(i, j).to_bits() != b.entry(i, j).to_bits() {
                    return (
                        false,
                        format!("entry ({i}, {j}): {} vs {}", a.entry(i, j), b.entry(i, j)),
                    );
                }
            }
        }
        (true, String::new())
    }

    #[test]
    fn incremental_fill_matches_rebuild_bit_for_bit() {
        let cap = 24;
        let mut rng = Rng::new(0x6_4A11);
        let mut model = BudgetModel::new(DIM, Gaussian::new(0.7), cap);
        let mut gram = GramCache::new(cap);
        for step in 0..20 {
            model.push(&random_sv(&mut rng), 0.05 + rng.uniform());
            gram.push_row(&model);
            let (ok, ctx) = assert_bit_identical(&gram, &rebuilt(&model, cap));
            assert!(ok, "step {step}: {ctx}");
        }
    }

    #[test]
    fn rows_are_symmetric_and_match_the_blocked_engine() {
        let cap = 16;
        let model = random_model(13, cap, 7);
        let gram = rebuilt(&model, cap);
        let n = model.num_sv();
        let mut direct = vec![0.0f64; n];
        for i in 0..n {
            assert_eq!(gram.row(i).len(), n);
            model.kernel_row(model.sv(i), model.sv_norm2(i), &mut direct);
            for j in 0..n {
                // Symmetric mirror, bit-for-bit.
                assert_eq!(gram.entry(i, j).to_bits(), gram.entry(j, i).to_bits(), "({i},{j})");
                // The triangle below the diagonal is the blocked row
                // itself; mirrored entries agree with the direct row up
                // to kernel symmetry rounding.
                if j <= i {
                    assert_eq!(gram.entry(i, j).to_bits(), direct[j].to_bits(), "({i},{j})");
                } else {
                    assert!((gram.entry(i, j) - direct[j]).abs() < 1e-12, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn swap_remove_replays_the_model_move_exactly() {
        let cap = 16;
        let mut model = random_model(9, cap, 11);
        let mut gram = rebuilt(&model, cap);
        // Remove a middle slot, the first slot, and the last slot.
        for &victim in &[4usize, 0, model.num_sv() - 3] {
            model.swap_remove(victim);
            gram.swap_remove(victim);
            let (ok, ctx) = assert_bit_identical(&gram, &rebuilt(&model, cap));
            assert!(ok, "victim {victim}: {ctx}");
        }
    }

    #[test]
    fn randomized_push_swap_remove_churn_stays_bit_identical() {
        forall("gram mirror == fresh recomputation under churn", 32, 0x6_4A12, |rng| {
            let cap = 20;
            let mut model = BudgetModel::new(DIM, Gaussian::new(0.9), cap);
            let mut gram = GramCache::new(cap);
            for _ in 0..60 {
                let n = model.num_sv();
                if n == 0 || (n < cap && rng.bernoulli(0.6)) {
                    model.push(&random_sv(rng), 0.05 + rng.uniform());
                    gram.push_row(&model);
                } else {
                    let victim = rng.below(n);
                    gram.swap_remove(victim);
                    model.swap_remove(victim);
                }
                let (ok, ctx) = assert_bit_identical(&gram, &rebuilt(&model, cap));
                if !ok {
                    return (false, ctx);
                }
            }
            (true, String::new())
        });
    }

    #[test]
    fn observed_removal_maintenance_keeps_the_mirror_exact() {
        forall("gram mirror survives removal-policy churn", 24, 0x6_4A13, |rng| {
            let cap = 24;
            let n0 = 12 + rng.below(10);
            let mut model = random_model(n0, cap, rng.next_u64());
            let mut gram = rebuilt(&model, cap);
            let cfg = MaintenanceConfig::new(Strategy::Removal, 50);
            let mut policy = RemovalMaintenance::new(&cfg);
            let mut prof = SectionProfiler::new();
            let budget = 4 + rng.below(4);
            while model.num_sv() > budget {
                MaintenancePolicy::<Gaussian>::maintain_observed(
                    &mut policy,
                    &mut model,
                    budget,
                    &mut prof,
                    &mut gram,
                );
            }
            if gram.is_stale() {
                return (false, "removal churn must not invalidate".into());
            }
            assert_bit_identical(&gram, &rebuilt(&model, cap))
        });
    }

    #[test]
    fn merge_churn_invalidates_and_rebuild_resynchronizes() {
        let cap = 24;
        let mut model = random_model(16, cap, 23);
        let mut gram = rebuilt(&model, cap);
        let cfg = MaintenanceConfig::new(Strategy::Merge(MergeSolver::LookupWd), 50);
        let mut policy = gaussian_policy(&cfg);
        let mut prof = SectionProfiler::new();
        policy.maintain_observed(&mut model, 12, &mut prof, &mut gram);
        assert!(gram.is_stale(), "merge churn is opaque");
        gram.rebuild(&model);
        assert!(!gram.is_stale());
        let (ok, ctx) = assert_bit_identical(&gram, &rebuilt(&model, cap));
        assert!(ok, "{ctx}");
    }

    #[test]
    fn clear_and_capacity_bookkeeping() {
        let mut gram = GramCache::new(8);
        assert!(gram.is_empty());
        assert_eq!(gram.capacity(), 8);
        let model = random_model(5, 8, 3);
        gram.rebuild(&model);
        assert_eq!(gram.len(), 5);
        gram.clear();
        assert!(gram.is_empty());
        assert!(!gram.is_stale());
    }
}

//! Normalized merge-problem geometry (Section 3 of the paper).
//!
//! Merging support vectors `(α_a, x_a)` and `(α_b, x_b)` under a Gaussian
//! kernel reduces to a problem in two scalars:
//!
//! * `m = α_b / (α_a + α_b)` — relative coefficient of the candidate,
//! * `κ = k(x_a, x_b)` — kernel value between the pair,
//!
//! both in `[0, 1]` when the pair has equal label signs. With
//! `z = h·x_a + (1−h)·x_b` the kernel shortcuts
//! `k(x_a, z) = κ^{(1−h)²}`, `k(x_b, z) = κ^{h²}` give the normalized
//! objective (to MAXIMIZE over `h ∈ [0,1]`):
//!
//! ```text
//! s_{m,κ}(h) = (1−m)·κ^{(1−h)²} + m·κ^{h²}  =  α_z(h) / (α_a + α_b)
//! ```
//!
//! and the normalized weight degradation (to MINIMIZE):
//!
//! ```text
//! wd(m,κ) = m² + (1−m)² + 2m(1−m)κ − s_{m,κ}(h*)²,   WD = (α_a+α_b)²·wd
//! ```
//!
//! (The paper's Algorithm 1 lines 5/7/8 mix two conventions related by
//! `h ↔ 1−h`; we fix the one consistent with its lines 8 and 13, see
//! DESIGN.md §7.)

/// Normalized merge objective `s_{m,κ}(h)`; equals `α_z(h)/(α_a+α_b)`.
#[inline]
pub fn s_value(m: f64, kappa: f64, h: f64) -> f64 {
    let omh = 1.0 - h;
    (1.0 - m) * kappa.powf(omh * omh) + m * kappa.powf(h * h)
}

/// Normalized weight degradation given the optimal objective value `s_star`.
#[inline]
pub fn wd_from_s(m: f64, kappa: f64, s_star: f64) -> f64 {
    // ‖m φ_b + (1−m) φ_a‖² − s*² ; clamp tiny negative round-off.
    (m * m + (1.0 - m) * (1.0 - m) + 2.0 * m * (1.0 - m) * kappa - s_star * s_star).max(0.0)
}

/// Un-normalized merged coefficient `α_z = α_a κ^{(1−h)²} + α_b κ^{h²}`.
#[inline]
pub fn alpha_z(alpha_a: f64, alpha_b: f64, kappa: f64, h: f64) -> f64 {
    let omh = 1.0 - h;
    alpha_a * kappa.powf(omh * omh) + alpha_b * kappa.powf(h * h)
}

/// Un-normalized weight degradation
/// `WD = α_a² + α_b² + 2 α_a α_b κ − α_z²` (paper's Alg. 1 line 9; note its
/// printed line 9 has `−…+2αaαbκ` grouped differently, this is the
/// ‖before‖² − ‖projection‖² form, non-negative).
#[inline]
pub fn wd_unnormalized(alpha_a: f64, alpha_b: f64, kappa: f64, az: f64) -> f64 {
    (alpha_a * alpha_a + alpha_b * alpha_b + 2.0 * alpha_a * alpha_b * kappa - az * az).max(0.0)
}

/// Below this κ the objective can become bimodal (Lemma 1: two modes iff
/// `κ < e^{−2}` at `m = 1/2`).
pub const KAPPA_BIMODAL: f64 = 0.135_335_283_236_612_7; // e^{-2}

/// Brute-force oracle for `h* = argmax_h s_{m,κ}(h)`: dense grid scan plus
/// local ternary refinement. Slow; used by tests and table validation only.
pub fn oracle_h(m: f64, kappa: f64, grid: usize) -> f64 {
    let mut best_h = 0.0;
    let mut best_s = f64::NEG_INFINITY;
    for i in 0..=grid {
        let h = i as f64 / grid as f64;
        let s = s_value(m, kappa, h);
        if s > best_s {
            best_s = s;
            best_h = h;
        }
    }
    // Ternary-search refinement within ±1 grid cell (the function is
    // unimodal within one cell at reasonable grid sizes).
    let mut lo = (best_h - 1.0 / grid as f64).max(0.0);
    let mut hi = (best_h + 1.0 / grid as f64).min(1.0);
    for _ in 0..200 {
        let m1 = lo + (hi - lo) / 3.0;
        let m2 = hi - (hi - lo) / 3.0;
        if s_value(m, kappa, m1) < s_value(m, kappa, m2) {
            lo = m1;
        } else {
            hi = m2;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn s_value_limits() {
        // κ → 1: merging identical points, s ≡ 1 for any h.
        for &h in &[0.0, 0.3, 1.0] {
            assert!((s_value(0.3, 1.0, h) - 1.0).abs() < 1e-12);
        }
        // h = 0 → z = x_b: s = (1−m)·κ + m.
        assert!((s_value(0.25, 0.5, 0.0) - (0.75 * 0.5 + 0.25)).abs() < 1e-12);
        // h = 1 → z = x_a: s = (1−m) + m·κ.
        assert!((s_value(0.25, 0.5, 1.0) - (0.75 + 0.25 * 0.5)).abs() < 1e-12);
    }

    #[test]
    fn wd_zero_for_identical_points() {
        let s = s_value(0.4, 1.0, 0.5);
        assert!(wd_from_s(0.4, 1.0, s).abs() < 1e-12);
    }

    #[test]
    fn wd_nonnegative_everywhere() {
        for i in 0..=20 {
            for j in 0..=20 {
                let m = i as f64 / 20.0;
                let k = j as f64 / 20.0;
                let h = oracle_h(m, k, 512);
                let wd = wd_from_s(m, k, s_value(m, k, h));
                assert!(wd >= 0.0, "wd({m},{k}) = {wd}");
                // wd is a squared relative distance, bounded by the no-merge
                // worst case ‖m φ_b + (1−m) φ_a‖² ≤ (m + (1−m))² = 1.
                assert!(wd <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn symmetric_m_half_prefers_midpoint_for_large_kappa() {
        // For κ > e^{-2} and m = 1/2 the optimum is h = 1/2.
        let h = oracle_h(0.5, 0.5, 1024);
        assert!((h - 0.5).abs() < 1e-3, "h = {h}");
    }

    #[test]
    fn small_kappa_extreme_m_is_removal_like() {
        // κ ≪ 1 and m ≈ 1 (candidate dominates): optimum keeps x_b, i.e.
        // h ≈ 0 (z = x_b).
        let h = oracle_h(0.97, 0.01, 2048);
        assert!(h < 0.05, "h = {h}");
        // Mirror case.
        let h = oracle_h(0.03, 0.01, 2048);
        assert!(h > 0.95, "h = {h}");
    }

    #[test]
    fn h_symmetry_under_m_flip() {
        // s_{m,κ}(h) = s_{1−m,κ}(1−h) ⇒ h(m) = 1 − h(1−m).
        for &(m, k) in &[(0.2, 0.6), (0.35, 0.3), (0.45, 0.9)] {
            let h1 = oracle_h(m, k, 1024);
            let h2 = oracle_h(1.0 - m, k, 1024);
            assert!((h1 - (1.0 - h2)).abs() < 1e-3, "m={m} κ={k}: {h1} vs 1-{h2}");
        }
    }

    #[test]
    fn alpha_z_consistent_with_s_value() {
        let (aa, ab) = (0.3, 0.7);
        let m = ab / (aa + ab);
        let kappa = 0.55;
        for &h in &[0.1, 0.5, 0.9] {
            let az = alpha_z(aa, ab, kappa, h);
            assert!((az - (aa + ab) * s_value(m, kappa, h)).abs() < 1e-12);
        }
    }

    #[test]
    fn unnormalized_wd_scales_quadratically() {
        let (aa, ab, kappa) = (0.4, 1.1, 0.45);
        let m = ab / (aa + ab);
        let h = oracle_h(m, kappa, 1024);
        let az = alpha_z(aa, ab, kappa, h);
        let wd = wd_unnormalized(aa, ab, kappa, az);
        let wd_norm = wd_from_s(m, kappa, s_value(m, kappa, h));
        let scale = (aa + ab) * (aa + ab);
        assert!((wd - scale * wd_norm).abs() < 1e-9, "{wd} vs {}", scale * wd_norm);
    }

    #[test]
    fn bimodal_threshold_matches_lemma() {
        // At m = 1/2: s''(1/2) > 0 (local minimum at the midpoint, two
        // modes) iff κ < e^{-2}. Check just either side of the threshold.
        let eps = 1e-3;
        let second_deriv = |kappa: f64| {
            let f = |h: f64| s_value(0.5, kappa, h);
            (f(0.5 + eps) - 2.0 * f(0.5) + f(0.5 - eps)) / (eps * eps)
        };
        assert!(second_deriv(KAPPA_BIMODAL * 0.8) > 0.0);
        assert!(second_deriv(KAPPA_BIMODAL * 1.2) < 0.0);
    }
}

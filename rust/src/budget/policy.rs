//! The pluggable budget-maintenance pipeline: [`MaintenancePolicy`] is the
//! single dispatch surface every consumer of budget maintenance goes
//! through — the BSGD solver hot loop, end-of-ingest budget enforcement,
//! and the serving layer's shard-model merge. See the [`crate::budget`]
//! module docs for the pipeline's invariants page (trigger semantics,
//! slack accounting, stage contracts).
//!
//! Three policies implement the trait:
//!
//! * [`MergeMaintenance`] (Gaussian only) — the paper's merge maintenance,
//!   with the amortized multi-pair sweep
//!   ([`MergeEngine::maintain_sweep`]) once `slack > 0` or `pairs > 1`;
//! * [`RemovalMaintenance`] (kernel-generic) — min-|α| removal backed by
//!   the lazily-repaired [`MinAlphaIndex`] (amortized victim selection,
//!   bit-identical to the full scan);
//! * [`ProjectionMaintenance`] (kernel-generic) — Wang-style projection
//!   with removal fallback on a numerically degenerate Gram matrix.
//!
//! Policies are built from a [`MaintenanceConfig`] through
//! [`gaussian_policy`] / [`generic_policy`]; [`AnyPolicy`] is the
//! runtime-polymorphic holder mirroring [`crate::model::AnyModel`].


use anyhow::{bail, ensure, Result};

use crate::kernel::{Gaussian, Kernel, Linear, Polynomial};
use crate::metrics::{Section, SectionProfiler};
use crate::model::BudgetModel;

use super::merge::{MergeEngine, MergeSolver};
use super::projection::maintain_projection;
use super::removal::{maintain_removal, MinAlphaIndex};
use super::Strategy;

/// Everything that parameterizes budget maintenance, independent of the
/// model hyperparameters it is attached to (`SvmConfig::maintenance()`
/// derives one).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MaintenanceConfig {
    /// Strategy (merge solver / removal / projection).
    pub strategy: Strategy,
    /// Lookup-table grid resolution for the lookup merge solvers.
    pub grid: usize,
    /// Slack `W`: the model may overshoot the budget by up to `W` support
    /// vectors before a maintenance event triggers (`0` = the classic
    /// maintain-every-overflow regime).
    pub slack: f64,
    /// Pairs merged (SVs shed) per maintenance event; `0` = auto, the
    /// paper's `⌈W⌉ + 1` (so one event returns the model to the budget).
    pub pairs: usize,
}

impl MaintenanceConfig {
    /// Classic configuration: per-overflow single-pair maintenance.
    pub fn new(strategy: Strategy, grid: usize) -> Self {
        MaintenanceConfig { strategy, grid, slack: 0.0, pairs: 0 }
    }

    /// Pairs shed per triggered event: the explicit cap, or `⌈slack⌉ + 1`
    /// when `pairs == 0` (exactly the overshoot a trigger guarantees).
    pub fn effective_pairs(&self) -> usize {
        if self.pairs > 0 {
            self.pairs
        } else {
            (self.slack.ceil() as usize) + 1
        }
    }

    /// Upper bound on the slack: the overshoot buffer is pre-allocated
    /// alongside the budget, so an absurd value must fail validation with
    /// a clear message instead of aborting inside the allocator.
    pub const MAX_SLACK: f64 = 1e6;

    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.slack.is_finite() && (0.0..=Self::MAX_SLACK).contains(&self.slack),
            "maintenance slack must be a finite number in [0, {}], got {}",
            Self::MAX_SLACK,
            self.slack
        );
        Ok(())
    }
}

/// The shared trigger rule: fire once the overshoot exceeds the slack.
/// `slack = 0` reduces to the pre-pipeline `num_sv > budget`.
#[inline]
fn slack_trigger(num_sv: usize, budget: usize, slack: f64) -> bool {
    num_sv > budget && (num_sv - budget) as f64 > slack
}

/// Receiver of budget-churn notifications from
/// [`MaintenancePolicy::maintain_observed`]: anything mirroring the SV set
/// by index — the [`super::gram::GramCache`] slab, an auxiliary index —
/// keeps itself exact under removal churn and learns when opaque churn
/// forces a rebuild from the model.
pub trait ChurnObserver {
    /// The model is about to execute `swap_remove(j)` (the last SV moves
    /// into slot `j`, the set shrinks by one); mirror it exactly.
    fn on_swap_remove(&mut self, j: usize);

    /// Opaque structural churn happened — merged vectors were pushed
    /// mid-event against a shifting SV set, or survivor coefficients were
    /// rewritten together with a removal the event does not itemize. The
    /// mirror must be rebuilt from the model before its next use.
    fn invalidate(&mut self);
}

/// One budget-maintenance policy: the trigger rule plus the event
/// executor. This is the only surface through which the solver loop, the
/// end-of-ingest enforcement, and the serving layer's shard merge reach
/// budget maintenance — no strategy enum is branched on outside the
/// policy constructors.
///
/// `Send` so estimators owning a policy can live on shard worker threads.
pub trait MaintenancePolicy<K: Kernel + Copy>: Send {
    /// Whether a maintenance event should run now (evaluated after every
    /// SGD step of a budgeted run).
    fn trigger(&self, num_sv: usize, budget: usize) -> bool;

    /// Execute one maintenance event: shed up to the policy's per-event
    /// pair quota (never less than one SV — guaranteed progress), timing
    /// scan/solver/apply into `prof`. Returns the summed weight
    /// degradation.
    fn maintain(
        &mut self,
        model: &mut BudgetModel<K>,
        budget: usize,
        prof: &mut SectionProfiler,
    ) -> f64;

    /// [`MaintenancePolicy::maintain`] with churn notification: structural
    /// mutations of the SV set are reported to `observer` so Gram-style
    /// mirrors stay synchronized without recomputation. The default runs
    /// the un-observed event — bit-identical model outcome — and then
    /// conservatively invalidates the observer: merge events push merged
    /// vectors *mid-event* against a shifting SV set, so a post-hoc journal
    /// cannot reconstruct the rows they would need, and projection rewrites
    /// every survivor coefficient. [`RemovalMaintenance`] overrides this
    /// with exact per-victim [`ChurnObserver::on_swap_remove`] calls.
    fn maintain_observed(
        &mut self,
        model: &mut BudgetModel<K>,
        budget: usize,
        prof: &mut SectionProfiler,
        observer: &mut dyn ChurnObserver,
    ) -> f64 {
        let wd = self.maintain(model, budget, prof);
        observer.invalidate();
        wd
    }

    /// Hard budget enforcement: run events until `num_sv ≤ budget`. Used
    /// at the end of every ingest call (so published/returned models
    /// always respect the budget even when slack allowed a transient
    /// overshoot) and by the serving layer's shard merge. A no-op when
    /// already within budget, hence free in the `slack = 0` regime.
    fn enforce(
        &mut self,
        model: &mut BudgetModel<K>,
        budget: usize,
        prof: &mut SectionProfiler,
    ) -> f64 {
        let mut wd = 0.0;
        while model.num_sv() > budget {
            wd += self.maintain(model, budget, prof);
        }
        wd
    }

    /// The strategy this policy implements.
    fn strategy(&self) -> Strategy;
}

/// Merge-based maintenance (the paper), Gaussian-only: single-pair events
/// in the classic regime, the amortized multi-pair sweep once the slack
/// (or an explicit pair cap) batches work.
pub struct MergeMaintenance {
    engine: MergeEngine,
    slack: f64,
    pairs: usize,
}

impl MergeMaintenance {
    pub fn new(solver: MergeSolver, cfg: &MaintenanceConfig) -> Self {
        MergeMaintenance {
            engine: MergeEngine::new(solver, cfg.grid),
            slack: cfg.slack,
            pairs: cfg.effective_pairs(),
        }
    }
}

impl MaintenancePolicy<Gaussian> for MergeMaintenance {
    fn trigger(&self, num_sv: usize, budget: usize) -> bool {
        slack_trigger(num_sv, budget, self.slack)
    }

    fn maintain(
        &mut self,
        model: &mut BudgetModel<Gaussian>,
        budget: usize,
        prof: &mut SectionProfiler,
    ) -> f64 {
        let over = model.num_sv().saturating_sub(budget).max(1);
        let sweep = self.pairs.min(over);
        if sweep <= 1 {
            // The classic single-pair event — bit-identical to the
            // pre-pipeline per-step merge.
            self.engine.maintain(model, prof).weight_degradation
        } else {
            self.engine.maintain_sweep(model, sweep, prof)
        }
    }

    fn strategy(&self) -> Strategy {
        Strategy::Merge(self.engine.solver())
    }
}

/// Min-|α| removal, kernel-generic, with amortized victim selection
/// through the lazily-repaired [`MinAlphaIndex`] (every mutation the
/// policy performs is routed through the index's bookkeeping, so selection
/// stays bit-identical to a full scan).
pub struct RemovalMaintenance {
    slack: f64,
    pairs: usize,
    index: MinAlphaIndex,
}

impl RemovalMaintenance {
    pub fn new(cfg: &MaintenanceConfig) -> Self {
        RemovalMaintenance {
            slack: cfg.slack,
            pairs: cfg.effective_pairs(),
            index: MinAlphaIndex::new(),
        }
    }

    /// One removal event; identical with and without an observer (the
    /// notification is issued right before each `swap_remove`, outside the
    /// timed sections, so the observed path stays bit-identical).
    fn run_event<K: Kernel + Copy>(
        &mut self,
        model: &mut BudgetModel<K>,
        budget: usize,
        prof: &mut SectionProfiler,
        mut observer: Option<&mut dyn ChurnObserver>,
    ) -> f64 {
        let over = model.num_sv().saturating_sub(budget).max(1);
        let count = self.pairs.min(over);
        let mut wd = 0.0;
        for _ in 0..count {
            if model.is_empty() {
                break;
            }
            let victim = {
                let _scan = crate::telemetry::span(Section::MaintScan, prof);
                self.index.pick(model).expect("non-empty model")
            };
            if let Some(obs) = observer.as_mut() {
                obs.on_swap_remove(victim);
            }
            let (alpha, self_k) = {
                let _apply = crate::telemetry::span(Section::MaintApply, prof);
                let alpha = model.alpha(victim);
                let self_k = model.kernel().self_eval(model.sv_norm2(victim));
                self.index.note_swap_remove(model, victim);
                model.swap_remove(victim);
                (alpha, self_k)
            };
            wd += alpha * alpha * self_k;
        }
        wd
    }
}

impl<K: Kernel + Copy> MaintenancePolicy<K> for RemovalMaintenance {
    fn trigger(&self, num_sv: usize, budget: usize) -> bool {
        slack_trigger(num_sv, budget, self.slack)
    }

    fn maintain(
        &mut self,
        model: &mut BudgetModel<K>,
        budget: usize,
        prof: &mut SectionProfiler,
    ) -> f64 {
        self.run_event(model, budget, prof, None)
    }

    /// Removal churn is exactly itemizable: each victim is reported via
    /// [`ChurnObserver::on_swap_remove`] before the model mutates, so a
    /// Gram mirror tracks the event without any recomputation.
    fn maintain_observed(
        &mut self,
        model: &mut BudgetModel<K>,
        budget: usize,
        prof: &mut SectionProfiler,
        observer: &mut dyn ChurnObserver,
    ) -> f64 {
        self.run_event(model, budget, prof, Some(observer))
    }

    fn strategy(&self) -> Strategy {
        Strategy::Removal
    }
}

/// Wang-style projection, kernel-generic; falls back to removal when the
/// survivor Gram matrix is numerically degenerate. Projection rewrites
/// survivor coefficients every event, so victim selection stays a full
/// scan (a cached index would be invalidated each time).
pub struct ProjectionMaintenance {
    slack: f64,
    pairs: usize,
}

impl ProjectionMaintenance {
    pub fn new(cfg: &MaintenanceConfig) -> Self {
        ProjectionMaintenance { slack: cfg.slack, pairs: cfg.effective_pairs() }
    }
}

impl<K: Kernel + Copy> MaintenancePolicy<K> for ProjectionMaintenance {
    fn trigger(&self, num_sv: usize, budget: usize) -> bool {
        slack_trigger(num_sv, budget, self.slack)
    }

    fn maintain(
        &mut self,
        model: &mut BudgetModel<K>,
        budget: usize,
        prof: &mut SectionProfiler,
    ) -> f64 {
        let over = model.num_sv().saturating_sub(budget).max(1);
        let count = self.pairs.min(over);
        let mut wd = 0.0;
        for _ in 0..count {
            if model.is_empty() {
                break;
            }
            wd += maintain_projection(model, prof).unwrap_or_else(|_| {
                // Numerically degenerate Gram matrix: fall back to removal.
                maintain_removal(model, prof)
            });
        }
        wd
    }

    fn strategy(&self) -> Strategy {
        Strategy::Projection
    }
}

/// Build the policy for a Gaussian model: the full strategy menu.
pub fn gaussian_policy(cfg: &MaintenanceConfig) -> Box<dyn MaintenancePolicy<Gaussian>> {
    match cfg.strategy {
        Strategy::Merge(solver) => Box::new(MergeMaintenance::new(solver, cfg)),
        Strategy::Removal => Box::new(RemovalMaintenance::new(cfg)),
        Strategy::Projection => Box::new(ProjectionMaintenance::new(cfg)),
    }
}

/// Build the policy for an arbitrary kernel: removal/projection only
/// (merge-based maintenance needs the Gaussian closed-form geometry; the
/// configuration layer rejects that combination before training starts,
/// so hitting this error indicates a caller bypassed validation).
pub fn generic_policy<K: Kernel + Copy>(
    cfg: &MaintenanceConfig,
) -> Result<Box<dyn MaintenancePolicy<K>>> {
    match cfg.strategy {
        Strategy::Merge(_) => bail!(
            "merge-based maintenance requires the Gaussian kernel; use the removal or \
             projection strategy"
        ),
        Strategy::Removal => Ok(Box::new(RemovalMaintenance::new(cfg))),
        Strategy::Projection => Ok(Box::new(ProjectionMaintenance::new(cfg))),
    }
}

/// Runtime-polymorphic policy holder: one variant per kernel family,
/// mirroring [`crate::model::AnyModel`] so estimator state can keep the
/// policy (and its scratch/index caches) alive across `partial_fit` calls.
pub enum AnyPolicy {
    Gaussian(Box<dyn MaintenancePolicy<Gaussian>>),
    Linear(Box<dyn MaintenancePolicy<Linear>>),
    Polynomial(Box<dyn MaintenancePolicy<Polynomial>>),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_model(n_sv: usize, seed: u64) -> BudgetModel {
        let mut rng = Rng::new(seed);
        let mut m = BudgetModel::new(3, Gaussian::new(0.5), n_sv);
        for _ in 0..n_sv {
            let row: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
            m.push(&row, 0.05 + rng.uniform());
        }
        m
    }

    #[test]
    fn trigger_respects_slack() {
        let cfg = MaintenanceConfig { slack: 4.0, ..MaintenanceConfig::new(Strategy::Removal, 50) };
        let p = RemovalMaintenance::new(&cfg);
        let budget = 10;
        for num_sv in 0..=14 {
            assert!(!MaintenancePolicy::<Gaussian>::trigger(&p, num_sv, budget), "{num_sv}");
        }
        assert!(MaintenancePolicy::<Gaussian>::trigger(&p, 15, budget));
        // slack = 0 is the classic rule.
        let p0 = RemovalMaintenance::new(&MaintenanceConfig::new(Strategy::Removal, 50));
        assert!(!MaintenancePolicy::<Gaussian>::trigger(&p0, 10, budget));
        assert!(MaintenancePolicy::<Gaussian>::trigger(&p0, 11, budget));
    }

    #[test]
    fn effective_pairs_auto_is_ceil_slack_plus_one() {
        let mut cfg = MaintenanceConfig::new(Strategy::Removal, 50);
        assert_eq!(cfg.effective_pairs(), 1);
        cfg.slack = 4.0;
        assert_eq!(cfg.effective_pairs(), 5);
        cfg.slack = 2.5;
        assert_eq!(cfg.effective_pairs(), 4); // ⌈2.5⌉ + 1
        cfg.pairs = 2;
        assert_eq!(cfg.effective_pairs(), 2); // explicit cap wins
    }

    #[test]
    fn config_validation() {
        let mut cfg = MaintenanceConfig::new(Strategy::Removal, 50);
        cfg.validate().unwrap();
        cfg.slack = -1.0;
        assert!(cfg.validate().is_err());
        cfg.slack = f64::NAN;
        assert!(cfg.validate().is_err());
        // Absurd slack must be a clean validation error, not an allocator
        // abort when the model pre-allocates budget + slack capacity.
        cfg.slack = 1e15;
        assert!(cfg.validate().is_err());
        cfg.slack = MaintenanceConfig::MAX_SLACK;
        cfg.validate().unwrap();
    }

    #[test]
    fn every_policy_enforces_the_budget() {
        for strategy in [
            Strategy::Merge(MergeSolver::LookupWd),
            Strategy::Merge(MergeSolver::GssStandard),
            Strategy::Removal,
            Strategy::Projection,
        ] {
            for (slack, pairs) in [(0.0, 0), (3.0, 0), (0.0, 4)] {
                let cfg = MaintenanceConfig { strategy, grid: 50, slack, pairs };
                let mut policy = gaussian_policy(&cfg);
                assert_eq!(policy.strategy(), strategy);
                let mut model = random_model(17, 9);
                let mut prof = SectionProfiler::new();
                let wd = policy.enforce(&mut model, 6, &mut prof);
                assert_eq!(model.num_sv(), 6, "{strategy:?} slack={slack} pairs={pairs}");
                assert!(wd >= 0.0 && wd.is_finite());
            }
        }
    }

    #[test]
    fn removal_policy_matches_full_scan_reference() {
        let cfg = MaintenanceConfig::new(Strategy::Removal, 50);
        let mut policy = RemovalMaintenance::new(&cfg);
        let mut a = random_model(12, 4);
        let mut b = a.clone();
        let mut prof = SectionProfiler::new();
        for _ in 0..8 {
            let wd_p = MaintenancePolicy::<Gaussian>::maintain(&mut policy, &mut a, 0, &mut prof);
            let wd_r = maintain_removal(&mut b, &mut prof);
            assert_eq!(wd_p.to_bits(), wd_r.to_bits());
            assert_eq!(a.num_sv(), b.num_sv());
            for j in 0..a.num_sv() {
                assert_eq!(a.alpha(j).to_bits(), b.alpha(j).to_bits(), "alpha {j}");
                assert_eq!(a.sv(j), b.sv(j), "sv {j}");
            }
        }
    }

    struct RecordingObserver {
        removed: Vec<usize>,
        invalidated: bool,
    }

    impl ChurnObserver for RecordingObserver {
        fn on_swap_remove(&mut self, j: usize) {
            self.removed.push(j);
        }

        fn invalidate(&mut self) {
            self.invalidated = true;
        }
    }

    #[test]
    fn removal_reports_exact_churn_and_stays_bit_identical() {
        let cfg = MaintenanceConfig::new(Strategy::Removal, 50);
        let mut prof = SectionProfiler::new();

        let mut observed_policy = RemovalMaintenance::new(&cfg);
        let mut observed = random_model(12, 4);
        let mut obs = RecordingObserver { removed: Vec::new(), invalidated: false };
        let wd_o = MaintenancePolicy::<Gaussian>::maintain_observed(
            &mut observed_policy,
            &mut observed,
            0,
            &mut prof,
            &mut obs,
        );

        let mut plain_policy = RemovalMaintenance::new(&cfg);
        let mut plain = random_model(12, 4);
        let wd_p =
            MaintenancePolicy::<Gaussian>::maintain(&mut plain_policy, &mut plain, 0, &mut prof);

        assert_eq!(wd_o.to_bits(), wd_p.to_bits());
        assert_eq!(obs.removed.len(), 1, "one victim per single-pair event");
        assert!(!obs.invalidated, "removal churn is exactly itemized");
        assert_eq!(observed.num_sv(), plain.num_sv());
        for j in 0..observed.num_sv() {
            assert_eq!(observed.alpha(j).to_bits(), plain.alpha(j).to_bits(), "alpha {j}");
            assert_eq!(observed.sv(j), plain.sv(j), "sv {j}");
        }
    }

    #[test]
    fn opaque_policies_invalidate_the_observer() {
        let mut prof = SectionProfiler::new();
        for strategy in
            [Strategy::Merge(MergeSolver::LookupWd), Strategy::Projection, Strategy::Removal]
        {
            let cfg = MaintenanceConfig::new(strategy, 50);
            let mut policy = gaussian_policy(&cfg);
            let mut model = random_model(12, 7);
            let mut obs = RecordingObserver { removed: Vec::new(), invalidated: false };
            policy.maintain_observed(&mut model, 8, &mut prof, &mut obs);
            match strategy {
                Strategy::Removal => {
                    assert!(!obs.invalidated);
                    assert!(!obs.removed.is_empty());
                }
                _ => {
                    assert!(obs.invalidated, "{strategy:?} must invalidate");
                    assert!(obs.removed.is_empty());
                }
            }
        }
    }

    #[test]
    fn generic_policy_rejects_merge() {
        let cfg = MaintenanceConfig::new(Strategy::Merge(MergeSolver::LookupWd), 50);
        assert!(generic_policy::<Linear>(&cfg).is_err());
        assert!(generic_policy::<Linear>(&MaintenanceConfig::new(Strategy::Removal, 50)).is_ok());
        assert!(
            generic_policy::<Polynomial>(&MaintenanceConfig::new(Strategy::Projection, 50)).is_ok()
        );
    }

    #[test]
    fn merge_policy_sweeps_when_slack_batches_work() {
        let cfg = MaintenanceConfig {
            slack: 3.0,
            ..MaintenanceConfig::new(Strategy::Merge(MergeSolver::LookupWd), 50)
        };
        let mut policy = gaussian_policy(&cfg);
        let budget = 8;
        // Overshoot of 4 (> slack 3): one event shrinks back to budget.
        let mut model = random_model(12, 11);
        assert!(policy.trigger(model.num_sv(), budget));
        let mut prof = SectionProfiler::new();
        policy.maintain(&mut model, budget, &mut prof);
        assert_eq!(model.num_sv(), 8);
        assert!(!policy.trigger(model.num_sv(), budget));
    }
}
